#!/usr/bin/env python3
"""Perf regression gate for `fleet-sim bench` snapshots.

Compares a new BENCH_N.json against a baseline (normally the committed
BENCH_1.json) and exits non-zero on regression:

* For every scenario in the baseline, the new snapshot must contain the
  scenario, its engines must not have disagreed (``bit_identical`` must
  not be false), and each shared numeric metric must not have dropped by
  more than ``--tolerance`` (default 15%).
* Metrics that are null on either side are skipped: absolute numbers
  (``events_per_sec``) are machine-dependent and the committed baseline
  carries null there, while ``speedup_vs_reference`` — production-engine
  events/sec divided by reference-engine events/sec *on the same host* —
  is machine-portable and is the primary gated metric.
* ``--min-speedup X`` additionally requires every scenario's new
  ``speedup_vs_reference`` to be at least X (the repo's bar is 2.0: the
  calendar-queue engine must simulate >= 2x the events/sec of the
  all-events-heap baseline engine).

* ``--min-events-per-sec NAME=FLOOR`` (repeatable) gates absolute
  throughput floors on the *new* snapshot alone — used for the sharded
  scale scenario (``lmsys_1e8``), whose row has no reference engine to
  compute a speedup against. The scenario must be present, its
  ``events_per_sec`` non-null and at least FLOOR, and its
  ``bit_identical`` (sharded-vs-serial cross-check) must not be false.
* ``--max-peak-rss-mb X`` gates the snapshot's top-level ``peak_rss_mb``
  — the bounded-memory claim for generator-driven runs.
* When only floor/RSS gates are requested, ``--baseline`` is optional:
  these are absolute bars, not regressions against a snapshot.

``--selftest`` runs the embedded unit cases (including the "deliberate
>15% slowdown must fail" check) with no snapshot files needed.
"""

import argparse
import json
import sys

GATED_METRICS = ("speedup_vs_reference", "events_per_sec")


def load(path):
    with open(path) as f:
        return json.load(f)


def compare(baseline, new, tolerance, min_speedup):
    """Return a list of human-readable failure strings (empty = pass)."""
    failures = []
    base_scenarios = baseline.get("scenarios", {})
    new_scenarios = new.get("scenarios", {})
    if not base_scenarios:
        failures.append("baseline has no scenarios")
    for name, base_row in base_scenarios.items():
        new_row = new_scenarios.get(name)
        if new_row is None:
            failures.append(f"{name}: missing from new snapshot")
            continue
        if new_row.get("bit_identical") is False:
            failures.append(
                f"{name}: production and reference engines disagreed "
                "(bit_identical = false)"
            )
        for metric in GATED_METRICS:
            base_v = base_row.get(metric)
            new_v = new_row.get(metric)
            if base_v is None or new_v is None:
                continue  # machine-dependent or not measured on this side
            floor = base_v * (1.0 - tolerance)
            if new_v < floor:
                failures.append(
                    f"{name}: {metric} regressed {base_v:.4g} -> "
                    f"{new_v:.4g} (floor {floor:.4g} at "
                    f"{tolerance:.0%} tolerance)"
                )
        if min_speedup is not None:
            speedup = new_row.get("speedup_vs_reference")
            if speedup is None:
                failures.append(
                    f"{name}: no speedup_vs_reference in new snapshot "
                    "(run fleet-sim bench with --engine both)"
                )
            elif speedup < min_speedup:
                failures.append(
                    f"{name}: speedup {speedup:.2f}x below required "
                    f"{min_speedup:.2f}x"
                )
    return failures


def parse_floors(specs):
    """Parse repeated ``NAME=FLOOR`` strings into a dict."""
    floors = {}
    for spec in specs or []:
        name, sep, value = spec.partition("=")
        if not sep or not name:
            raise ValueError(
                f"bad --min-events-per-sec {spec!r} (want NAME=FLOOR)"
            )
        floors[name] = float(value)
    return floors


def check_floors(new, floors):
    """Absolute events/sec floors on the new snapshot (no baseline)."""
    failures = []
    scenarios = new.get("scenarios", {})
    for name, floor in floors.items():
        row = scenarios.get(name)
        if row is None:
            failures.append(f"{name}: missing from new snapshot")
            continue
        if row.get("bit_identical") is False:
            failures.append(
                f"{name}: sharded and serial runs disagreed "
                "(bit_identical = false)"
            )
        eps = row.get("events_per_sec")
        if eps is None:
            failures.append(f"{name}: events_per_sec not measured")
        elif eps < floor:
            failures.append(
                f"{name}: events_per_sec {eps:.4g} below floor {floor:.4g}"
            )
    return failures


def check_rss(new, max_rss_mb):
    """Top-level peak-RSS ceiling (the bounded-memory gate)."""
    rss = new.get("peak_rss_mb")
    if rss is None:
        return ["peak_rss_mb not recorded in new snapshot"]
    if rss > max_rss_mb:
        return [
            f"peak_rss_mb {rss:.1f} exceeds ceiling {max_rss_mb:.1f}"
        ]
    return []


def selftest():
    base = {
        "scenarios": {
            "s": {"speedup_vs_reference": 2.5, "events_per_sec": 1000.0}
        }
    }
    ok = {
        "scenarios": {
            "s": {
                "speedup_vs_reference": 2.4,
                "events_per_sec": 950.0,
                "bit_identical": True,
            }
        }
    }
    assert compare(base, ok, 0.15, 2.0) == [], "healthy snapshot must pass"

    slow = {
        "scenarios": {
            "s": {
                "speedup_vs_reference": 2.0,
                "events_per_sec": 800.0,
                "bit_identical": True,
            }
        }
    }
    fails = compare(base, slow, 0.15, None)
    assert fails, "a deliberate 20% slowdown must fail the 15% gate"

    weak = {
        "scenarios": {
            "s": {
                "speedup_vs_reference": 1.5,
                "events_per_sec": 2000.0,
                "bit_identical": True,
            }
        }
    }
    null_base = {
        "scenarios": {
            "s": {"speedup_vs_reference": None, "events_per_sec": None}
        }
    }
    fails = compare(null_base, weak, 0.15, 2.0)
    assert any("below required" in f for f in fails), "min-speedup gate"
    assert not any("regressed" in f for f in fails), "nulls must be skipped"

    # Null-baseline fallback: the committed BENCH_1.json carries null
    # absolute fields (and a machine-portable speedup). A healthy new
    # snapshot must pass the tolerance gate outright, and the min-speedup
    # bar must still be enforced from the new snapshot alone.
    committed_style = {
        "scenarios": {
            "s": {
                "events": None,
                "wall_ms": None,
                "events_per_sec": None,
                "ref_events_per_sec": None,
                "speedup_vs_reference": 1.0,
                "bit_identical": None,
            }
        }
    }
    fresh = {
        "scenarios": {
            "s": {
                "events": 60000,
                "events_per_sec": 5.0e6,
                "ref_events_per_sec": 2.0e6,
                "speedup_vs_reference": 2.5,
                "bit_identical": True,
            }
        }
    }
    assert compare(committed_style, fresh, 0.15, 2.0) == [], (
        "null-baseline fallback: healthy snapshot must pass"
    )
    # bit_identical: null means "not cross-checked", which must not fail.
    assert compare(committed_style, committed_style, 0.15, None) == [], (
        "null bit_identical must not be treated as a disagreement"
    )
    # Metrics null on the NEW side are skipped too (reference-only run).
    ref_only = {
        "scenarios": {
            "s": {"events_per_sec": None, "speedup_vs_reference": None}
        }
    }
    fails = compare(fresh, ref_only, 0.15, None)
    assert not any("regressed" in f for f in fails), (
        "new-side nulls must be skipped"
    )

    fails = compare(
        {"scenarios": {"s": {}, "t": {}}}, {"scenarios": {"s": {}}}, 0.15, None
    )
    assert any("missing" in f for f in fails), "scenario coverage gate"

    fails = compare(
        {"scenarios": {"s": {}}},
        {"scenarios": {"s": {"bit_identical": False}}},
        0.15,
        None,
    )
    assert any("bit_identical" in f for f in fails), "bit-identity gate"

    # Absolute floors: the scale scenario has no reference speedup, so
    # it is gated by events/sec floors on the new snapshot alone.
    floors = parse_floors(["lmsys_1e8=1e6"])
    assert floors == {"lmsys_1e8": 1e6}
    scale_ok = {
        "peak_rss_mb": 512.0,
        "scenarios": {
            "lmsys_1e8": {
                "events_per_sec": 1.2e7,
                "speedup_vs_reference": None,
                "bit_identical": True,
            }
        },
    }
    assert check_floors(scale_ok, floors) == [], "healthy floor must pass"
    slow_scale = {
        "scenarios": {
            "lmsys_1e8": {"events_per_sec": 5e5, "bit_identical": True}
        }
    }
    fails = check_floors(slow_scale, floors)
    assert any("below floor" in f for f in fails), "floor gate"
    fails = check_floors({"scenarios": {}}, floors)
    assert any("missing" in f for f in fails), "floor coverage gate"
    fails = check_floors(
        {"scenarios": {"lmsys_1e8": {"events_per_sec": None}}}, floors
    )
    assert any("not measured" in f for f in fails), "null floor gate"
    fails = check_floors(
        {
            "scenarios": {
                "lmsys_1e8": {
                    "events_per_sec": 1.2e7,
                    "bit_identical": False,
                }
            }
        },
        floors,
    )
    assert any("disagreed" in f for f in fails), "shard identity gate"
    try:
        parse_floors(["no_equals_sign"])
        raise AssertionError("bad floor spec must raise")
    except ValueError:
        pass

    # RSS ceiling.
    assert check_rss(scale_ok, 1024.0) == [], "healthy RSS must pass"
    fails = check_rss({"peak_rss_mb": 2048.0}, 1024.0)
    assert any("exceeds ceiling" in f for f in fails), "RSS gate"
    fails = check_rss({}, 1024.0)
    assert any("not recorded" in f for f in fails), "missing RSS gate"

    print("perf_gate selftest OK")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", help="baseline snapshot (BENCH_1.json)")
    ap.add_argument("--new", dest="new_path", help="new snapshot to gate")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="allowed fractional regression (default 0.15)")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="required speedup_vs_reference per scenario")
    ap.add_argument("--min-events-per-sec", action="append",
                    metavar="NAME=FLOOR", dest="floors",
                    help="absolute events/sec floor for one scenario in "
                         "the new snapshot (repeatable)")
    ap.add_argument("--max-peak-rss-mb", type=float, default=None,
                    help="ceiling on the new snapshot's peak_rss_mb")
    ap.add_argument("--selftest", action="store_true",
                    help="run embedded unit cases and exit")
    args = ap.parse_args()

    if args.selftest:
        selftest()
        return 0

    try:
        floors = parse_floors(args.floors)
    except ValueError as e:
        ap.error(str(e))
    absolute_gates = bool(floors) or args.max_peak_rss_mb is not None
    if not args.new_path:
        ap.error("--new is required (or use --selftest)")
    if not args.baseline and not absolute_gates:
        ap.error("--baseline is required unless an absolute gate "
                 "(--min-events-per-sec / --max-peak-rss-mb) is given")

    new = load(args.new_path)
    failures = []
    checked = []
    if args.baseline:
        baseline = load(args.baseline)
        failures += compare(baseline, new, args.tolerance,
                            args.min_speedup)
        checked.append(
            f"{len(baseline.get('scenarios', {}))} scenario(s) within "
            f"{args.tolerance:.0%} of {args.baseline}"
        )
        if args.min_speedup is not None:
            checked.append(
                f"all >= {args.min_speedup:.2f}x over reference"
            )
    if floors:
        failures += check_floors(new, floors)
        checked.append(f"{len(floors)} events/sec floor(s)")
    if args.max_peak_rss_mb is not None:
        failures += check_rss(new, args.max_peak_rss_mb)
        checked.append(f"peak RSS <= {args.max_peak_rss_mb:.0f} MB")
    if failures:
        print(f"PERF GATE FAILED ({len(failures)} problem(s)):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print("perf gate passed: " + ", ".join(checked))
    return 0


if __name__ == "__main__":
    sys.exit(main())
