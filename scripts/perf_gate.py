#!/usr/bin/env python3
"""Perf regression gate for `fleet-sim bench` snapshots.

Compares a new BENCH_N.json against a baseline (normally the committed
BENCH_1.json) and exits non-zero on regression:

* For every scenario in the baseline, the new snapshot must contain the
  scenario, its engines must not have disagreed (``bit_identical`` must
  not be false), and each shared numeric metric must not have dropped by
  more than ``--tolerance`` (default 15%).
* Metrics that are null on either side are skipped: absolute numbers
  (``events_per_sec``) are machine-dependent and the committed baseline
  carries null there, while ``speedup_vs_reference`` — production-engine
  events/sec divided by reference-engine events/sec *on the same host* —
  is machine-portable and is the primary gated metric.
* ``--min-speedup X`` additionally requires every scenario's new
  ``speedup_vs_reference`` to be at least X (the repo's bar is 2.0: the
  calendar-queue engine must simulate >= 2x the events/sec of the
  all-events-heap baseline engine).

``--selftest`` runs the embedded unit cases (including the "deliberate
>15% slowdown must fail" check) with no snapshot files needed.
"""

import argparse
import json
import sys

GATED_METRICS = ("speedup_vs_reference", "events_per_sec")


def load(path):
    with open(path) as f:
        return json.load(f)


def compare(baseline, new, tolerance, min_speedup):
    """Return a list of human-readable failure strings (empty = pass)."""
    failures = []
    base_scenarios = baseline.get("scenarios", {})
    new_scenarios = new.get("scenarios", {})
    if not base_scenarios:
        failures.append("baseline has no scenarios")
    for name, base_row in base_scenarios.items():
        new_row = new_scenarios.get(name)
        if new_row is None:
            failures.append(f"{name}: missing from new snapshot")
            continue
        if new_row.get("bit_identical") is False:
            failures.append(
                f"{name}: production and reference engines disagreed "
                "(bit_identical = false)"
            )
        for metric in GATED_METRICS:
            base_v = base_row.get(metric)
            new_v = new_row.get(metric)
            if base_v is None or new_v is None:
                continue  # machine-dependent or not measured on this side
            floor = base_v * (1.0 - tolerance)
            if new_v < floor:
                failures.append(
                    f"{name}: {metric} regressed {base_v:.4g} -> "
                    f"{new_v:.4g} (floor {floor:.4g} at "
                    f"{tolerance:.0%} tolerance)"
                )
        if min_speedup is not None:
            speedup = new_row.get("speedup_vs_reference")
            if speedup is None:
                failures.append(
                    f"{name}: no speedup_vs_reference in new snapshot "
                    "(run fleet-sim bench with --engine both)"
                )
            elif speedup < min_speedup:
                failures.append(
                    f"{name}: speedup {speedup:.2f}x below required "
                    f"{min_speedup:.2f}x"
                )
    return failures


def selftest():
    base = {
        "scenarios": {
            "s": {"speedup_vs_reference": 2.5, "events_per_sec": 1000.0}
        }
    }
    ok = {
        "scenarios": {
            "s": {
                "speedup_vs_reference": 2.4,
                "events_per_sec": 950.0,
                "bit_identical": True,
            }
        }
    }
    assert compare(base, ok, 0.15, 2.0) == [], "healthy snapshot must pass"

    slow = {
        "scenarios": {
            "s": {
                "speedup_vs_reference": 2.0,
                "events_per_sec": 800.0,
                "bit_identical": True,
            }
        }
    }
    fails = compare(base, slow, 0.15, None)
    assert fails, "a deliberate 20% slowdown must fail the 15% gate"

    weak = {
        "scenarios": {
            "s": {
                "speedup_vs_reference": 1.5,
                "events_per_sec": 2000.0,
                "bit_identical": True,
            }
        }
    }
    null_base = {
        "scenarios": {
            "s": {"speedup_vs_reference": None, "events_per_sec": None}
        }
    }
    fails = compare(null_base, weak, 0.15, 2.0)
    assert any("below required" in f for f in fails), "min-speedup gate"
    assert not any("regressed" in f for f in fails), "nulls must be skipped"

    # Null-baseline fallback: the committed BENCH_1.json carries null
    # absolute fields (and a machine-portable speedup). A healthy new
    # snapshot must pass the tolerance gate outright, and the min-speedup
    # bar must still be enforced from the new snapshot alone.
    committed_style = {
        "scenarios": {
            "s": {
                "events": None,
                "wall_ms": None,
                "events_per_sec": None,
                "ref_events_per_sec": None,
                "speedup_vs_reference": 1.0,
                "bit_identical": None,
            }
        }
    }
    fresh = {
        "scenarios": {
            "s": {
                "events": 60000,
                "events_per_sec": 5.0e6,
                "ref_events_per_sec": 2.0e6,
                "speedup_vs_reference": 2.5,
                "bit_identical": True,
            }
        }
    }
    assert compare(committed_style, fresh, 0.15, 2.0) == [], (
        "null-baseline fallback: healthy snapshot must pass"
    )
    # bit_identical: null means "not cross-checked", which must not fail.
    assert compare(committed_style, committed_style, 0.15, None) == [], (
        "null bit_identical must not be treated as a disagreement"
    )
    # Metrics null on the NEW side are skipped too (reference-only run).
    ref_only = {
        "scenarios": {
            "s": {"events_per_sec": None, "speedup_vs_reference": None}
        }
    }
    fails = compare(fresh, ref_only, 0.15, None)
    assert not any("regressed" in f for f in fails), (
        "new-side nulls must be skipped"
    )

    fails = compare(
        {"scenarios": {"s": {}, "t": {}}}, {"scenarios": {"s": {}}}, 0.15, None
    )
    assert any("missing" in f for f in fails), "scenario coverage gate"

    fails = compare(
        {"scenarios": {"s": {}}},
        {"scenarios": {"s": {"bit_identical": False}}},
        0.15,
        None,
    )
    assert any("bit_identical" in f for f in fails), "bit-identity gate"

    print("perf_gate selftest OK")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", help="baseline snapshot (BENCH_1.json)")
    ap.add_argument("--new", dest="new_path", help="new snapshot to gate")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="allowed fractional regression (default 0.15)")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="required speedup_vs_reference per scenario")
    ap.add_argument("--selftest", action="store_true",
                    help="run embedded unit cases and exit")
    args = ap.parse_args()

    if args.selftest:
        selftest()
        return 0

    if not args.baseline or not args.new_path:
        ap.error("--baseline and --new are required (or use --selftest)")

    baseline = load(args.baseline)
    new = load(args.new_path)
    failures = compare(baseline, new, args.tolerance, args.min_speedup)
    if failures:
        print(f"PERF GATE FAILED ({len(failures)} problem(s)):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(
        f"perf gate passed: {len(baseline.get('scenarios', {}))} scenario(s) "
        f"within {args.tolerance:.0%} of {args.baseline}"
        + (
            f", all >= {args.min_speedup:.2f}x over reference"
            if args.min_speedup is not None
            else ""
        )
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
