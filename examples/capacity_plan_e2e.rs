//! End-to-end driver: the full three-layer system on a real small
//! workload.
//!
//! This example proves the layers compose:
//!   L1/L2 — the Phase-1 analytical sweep authored in JAX/Pallas,
//!           AOT-compiled to `artifacts/sweep.hlo.txt` (`make artifacts`),
//!   runtime — loaded and executed here through the PJRT C API,
//!   L3   — the rust coordinator generates candidates, ranks them through
//!          the artifact, DES-verifies the winners, applies
//!          reliability-aware sizing, and sweeps growth headroom.
//!
//! Run:  make artifacts && cargo run --release --example capacity_plan_e2e
//!
//! Falls back to the native evaluator (with a warning) if artifacts are
//! missing, so the example always runs.

use fleet_sim::gpu::catalog::GpuCatalog;
use fleet_sim::optimizer::analytic::{NativeSweep, SweepEval};
use fleet_sim::optimizer::planner::FleetOptimizer;
use fleet_sim::optimizer::reliability::NodeAvail;
use fleet_sim::optimizer::whatif::WhatIfSweep;
use fleet_sim::runtime::sweep::AotSweep;
use fleet_sim::util::table::{dollars, millis, Table};
use fleet_sim::workload::spec::{BuiltinTrace, WorkloadSpec};

fn main() -> anyhow::Result<()> {
    let workload = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
    let slo = 500.0;
    println!(
        "== inference-fleet-sim end-to-end ==\nworkload: {} (λ = {} req/s, \
         prompt fraction {:.2}, max ctx {} tokens), SLO: P99 TTFT <= {slo} ms\n",
        workload.name,
        workload.lambda_rps,
        workload.input_fraction,
        workload.cdf.max_len()
    );

    // Phase-1 evaluator: AOT artifact via PJRT if present.
    let aot = AotSweep::load(&AotSweep::default_dir());
    let evaluator: Box<dyn SweepEval> = match aot {
        Ok(a) => {
            println!(
                "Phase-1 backend: AOT JAX/Pallas artifact ({}) on PJRT \
                 platform '{}'",
                a.artifact_path.display(),
                a.platform()
            );
            Box::new(a)
        }
        Err(e) => {
            eprintln!(
                "WARNING: artifacts missing ({e}); falling back to the \
                 native evaluator. Run `make artifacts` for the full \
                 three-layer path."
            );
            Box::new(NativeSweep)
        }
    };

    let mut opt = FleetOptimizer::new(GpuCatalog::standard(), slo);
    opt.gen.allow_mixed = true;
    opt.node_avail = NodeAvail::hard_failure();
    opt.des.n_requests = 15_000;

    let t0 = std::time::Instant::now();
    let plan = opt.plan_with(&workload, evaluator.as_ref())?;
    let elapsed = t0.elapsed();

    println!(
        "\nPhase 1 [{}]: {} candidates evaluated, {} analytically feasible.",
        plan.backend, plan.n_candidates, plan.n_phase1_feasible
    );
    println!("Phase 2 [DES]: verified the top {} by cost:\n",
             plan.verified.len());
    let mut t = Table::new(&["Candidate", "$/yr", "DES P99 TTFT", "verdict"]);
    for e in &plan.verified {
        let v = e.verification.as_ref().unwrap();
        t.row(&[
            e.candidate.label(),
            dollars(e.analytic.cost_yr),
            millis(v.p99_ttft_ms),
            if v.passed { "pass".into() } else { "fail".into() },
        ]);
    }
    println!("{}", t.render());

    let chosen = plan
        .chosen
        .as_ref()
        .ok_or_else(|| anyhow::anyhow!("no feasible configuration"))?;
    println!(
        "\nChosen: {} at {} per year.",
        chosen.candidate.label(),
        dollars(chosen.analytic.cost_yr)
    );
    println!(
        "Reliability-aware production sizing (hard-failure node_avail = \
         {:.4}): {} short + {} long GPUs.",
        opt.node_avail.a, plan.production_n_s, plan.production_n_l
    );

    // Growth headroom for the chosen GPU type.
    let sweep = WhatIfSweep::new(GpuCatalog::standard(), slo)
        .for_gpu(&chosen.candidate.gpu_s);
    let headroom = sweep.headroom(&workload, &chosen.candidate,
                                  workload.lambda_rps, 2_000.0);
    println!(
        "Headroom: this fleet holds until λ ≈ {headroom:.0} req/s — \
         provision more before then."
    );
    println!("\n[total planning time {:.2} s, {} DES-verified candidates]",
             elapsed.as_secs_f64(), plan.verified.len());
    Ok(())
}
