//! Grid demand-response analysis (Puzzle 8, §4.8): how much power can a
//! 40x H100 fleet shed before breaching its SLO?
//!
//!     cargo run --release --example grid_flex

use fleet_sim::gpu::catalog::GpuCatalog;
use fleet_sim::optimizer::gridflex::{grid_flex_analysis, GridFlexConfig};
use fleet_sim::workload::spec::{BuiltinTrace, WorkloadSpec};

fn main() {
    let gpu = GpuCatalog::standard().get("H100").unwrap().clone();
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 200.0);
    let cfg = GridFlexConfig::default();
    println!(
        "Grid flexibility, {} H100s at λ = {} req/s (SLO {} ms):",
        cfg.n_gpus, w.lambda_rps, cfg.slo_ms
    );
    println!("{:>5} {:>6} {:>7} {:>9} {:>11} {:>9} {:>10}  verdicts",
             "flex", "n_max", "W/GPU", "fleet kW", "P99 anal.", "P99 DES",
             "P99 event");
    for r in grid_flex_analysis(&w, &gpu, &cfg) {
        println!(
            "{:>4.0}% {:>6} {:>6.0}W {:>8.1} {:>10.1} {:>9.0} {:>10.0}  \
             steady:{} event:{}",
            r.flex * 100.0,
            r.n_max,
            r.w_per_gpu,
            r.fleet_kw,
            r.p99_analytic_ms,
            r.p99_des_ms,
            r.p99_event_ms,
            if r.steady_ok { "ok" } else { "NO" },
            if r.event_ok { "ok" } else { "NO" },
        );
    }
}
