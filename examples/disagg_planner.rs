//! Disaggregated prefill/decode planning (Puzzle 7, §4.7): sweep the
//! (prefill GPU, decode GPU) pairings on Azure at 100 req/s, verify the
//! winner with the two-stage DES.
//!
//!     cargo run --release --example disagg_planner

use fleet_sim::gpu::catalog::GpuCatalog;
use fleet_sim::optimizer::disagg::{simulate_disagg, DisaggFleetOptimizer};
use fleet_sim::workload::spec::{BuiltinTrace, WorkloadSpec};

fn main() {
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
    let o = DisaggFleetOptimizer::new(GpuCatalog::standard(), 500.0, 100.0);
    println!("Disaggregated configs (TTFT SLO 500 ms, TPOT SLO 100 ms):");
    for (cfg, a) in o.sweep(&w) {
        let (des_ttft, des_e2e, occ) = simulate_disagg(&w, &cfg, 10_000, 42);
        println!(
            "  {:28} ${:>6.0}K/yr  TTFT {:>4.0} ms (DES {:>4.0}) TPOT \
             {:>3.0} ms  decode occ {:>3.0}%  {}",
            cfg.label(),
            a.cost_yr / 1e3,
            a.ttft99_ms,
            des_ttft,
            a.tpot_ms,
            occ * 100.0,
            if a.feasible { "ok" } else { "infeasible" },
        );
        let _ = des_e2e;
    }
    for name in ["A100", "H100"] {
        let cat = GpuCatalog::standard();
        if let Some((n, cost, ttft)) =
            o.aggregated_baseline(&w, cat.get(name).unwrap())
        {
            println!(
                "  aggregated all-{name:5}: {n} GPUs, ${:.0}K/yr, TTFT \
                 {ttft:.0} ms",
                cost / 1e3
            );
        }
    }
    println!("\nInsight 7: the premium GPU earns its cost in decode, not \
              prefill.");
}
