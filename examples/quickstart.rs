//! Quickstart: plan a minimum-cost fleet for the Azure trace at
//! 100 req/s with a 500 ms P99 TTFT SLO.
//!
//!     cargo run --release --example quickstart

use fleet_sim::prelude::*;

fn main() {
    let workload = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
    let optimizer = FleetOptimizer::new(GpuCatalog::standard(), 500.0);
    let plan = optimizer.plan(&workload);
    println!("{}", plan.summary());
    if let Some(chosen) = &plan.chosen {
        let v = chosen.verification.as_ref().unwrap();
        println!(
            "\nPhase 1 ranked {} candidates ({} feasible); the winner was \
             verified by DES at P99 TTFT = {:.0} ms (short pool {:.0} ms).",
            plan.n_candidates,
            plan.n_phase1_feasible,
            v.p99_ttft_ms,
            v.p99_ttft_short_ms,
        );
    }
}
