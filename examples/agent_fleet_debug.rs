//! Debugging an agent fleet that fails its SLO at low utilization —
//! the Puzzle 2 (§4.2) investigation as an API walkthrough:
//! analytics say the queue is healthy, the DES shows the SLO breach,
//! and a two-pool split isolates the interactive traffic.
//!
//!     cargo run --release --example agent_fleet_debug

use fleet_sim::des::engine::{DesConfig, SimPool, Simulator};
use fleet_sim::gpu::catalog::GpuCatalog;
use fleet_sim::queueing::mgc::{analyze_pool, PoolSpec, WorkloadHist};
use fleet_sim::router::RoutingPolicy;
use fleet_sim::workload::spec::{BuiltinTrace, WorkloadSpec};

fn main() {
    let gpu = GpuCatalog::standard().get("H100").unwrap().clone();
    let w = WorkloadSpec::builtin(BuiltinTrace::Agent, 20.0);
    let ctx = w.cdf.max_len();
    let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
    let slo = 1000.0;

    println!("Agent trace at λ = {} req/s, SLO = {slo} ms", w.lambda_rps);
    for n in [64usize, 128] {
        let a = analyze_pool(&hist, 0.0, 1e12, w.lambda_per_ms(),
                             &PoolSpec { gpu: gpu.clone(), n_gpus: n,
                                         ctx_budget: ctx });
        let sim = Simulator::new(
            w.clone(),
            vec![SimPool { gpu: gpu.clone(), n_gpus: n, ctx_budget: ctx,
                           batch_cap: None }],
            RoutingPolicy::Random { n_pools: 1 },
            DesConfig { n_requests: 15_000, ..Default::default() },
        );
        let mut r = sim.run();
        println!(
            "\n{n} x H100 homogeneous: analytic rho = {:.2}, Erlang W99 = \
             {:.1} ms (queue looks healthy!)\n  DES: utilization {:.0}%, \
             wait99 {:.0} ms, P99 TTFT = {:.0} ms -> {}",
            a.rho,
            a.w99_ms,
            r.per_pool[0].utilization * 100.0,
            r.overall.wait.p99(),
            r.overall.p99_ttft(),
            if r.overall.p99_ttft() <= slo { "meets SLO" } else { "FAILS SLO" }
        );
    }
    println!("\nAdding GPUs does not help: the tail is giant-prompt service,");
    println!("not queueing. Isolate the interactive traffic instead:");
    let pools = vec![
        SimPool { gpu: gpu.clone(), n_gpus: 4, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu, n_gpus: 60, ctx_budget: ctx, batch_cap: None },
    ];
    let sim = Simulator::new(
        w, pools, RoutingPolicy::Length { b_short: 4096.0 },
        DesConfig { n_requests: 15_000, ..Default::default() },
    );
    let mut r = sim.run();
    let short_p99 = r.per_pool[0].stats.ttft.p99();
    let short_count = r.per_pool[0].stats.count;
    let long_p99 = r.per_pool[1].stats.ttft.p99();
    println!(
        "  Two-pool 4K split (4 + 60 H100): short-pool P99 TTFT = {short_p99:.0} ms \
         ({short_count} requests protected), long-pool P99 = {long_p99:.0} ms",
    );
}
