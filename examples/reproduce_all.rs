//! Regenerate every paper case study (Tables 1-9 of §4) plus the §3.2
//! model-fidelity table. This is the driver behind EXPERIMENTS.md.
//!
//!     cargo run --release --example reproduce_all [-- --fast]

use fleet_sim::gpu::catalog::GpuCatalog;
use fleet_sim::report::fidelity::fidelity_table;
use fleet_sim::scenarios::{self, ScenarioOpts};

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let opts = if fast { ScenarioOpts::fast() } else { ScenarioOpts::default() };
    let t0 = std::time::Instant::now();
    for report in scenarios::run_all(&opts) {
        println!("{}", report.render());
    }
    let gpu = GpuCatalog::standard().get("H100").unwrap().clone();
    println!("=== Model fidelity (paper §3.2) ===");
    println!("{}", fidelity_table(&gpu, opts.n_requests).render());
    eprintln!("[reproduce_all completed in {:.1} s]",
              t0.elapsed().as_secs_f64());
}
