//! Source preprocessing: comment/string stripping, `#[cfg(test)]`
//! blanking, and `// detlint:` pragma extraction.
//!
//! The scanner rewrites a source file into a same-shape "code view":
//! every comment, string literal, and char literal is replaced by
//! spaces (newlines preserved), so downstream rules can match tokens
//! without tripping over prose. `#[cfg(test)]` items are blanked the
//! same way — unit tests are free to use `HashMap`, wall clocks, and
//! literal RNG streams.

/// A `// detlint:` pragma attached to a source line.
///
/// Grammar (inside a line comment):
///
/// ```text
/// // detlint: allow(R1) -- justification text
/// // detlint: allow(R1, R4) -- justification text
/// // detlint: ulp-ok -- justification text        (alias: allow(R4))
/// ```
///
/// The justification after ` -- ` is mandatory; an unjustified pragma
/// is itself reported (rule `P0`). A pragma on a line with code
/// suppresses findings on that line; a pragma on its own line
/// suppresses findings on the next *code* line (continuation comment
/// lines and blank lines in between are skipped, so a justification
/// may wrap).
#[derive(Debug, Clone)]
pub struct Pragma {
    /// 1-based line the pragma comment sits on.
    pub line: usize,
    /// 1-based line whose findings it suppresses.
    pub target: usize,
    /// Uppercased rule ids, e.g. `["R1", "R4"]`.
    pub rules: Vec<String>,
    /// Whether a non-empty ` -- justification` was supplied.
    pub justified: bool,
}

/// Result of scanning one file.
pub struct Scanned {
    /// The code view: same line structure as the input, with comments,
    /// strings, chars, and `#[cfg(test)]` regions blanked to spaces.
    pub code: String,
    pub pragmas: Vec<Pragma>,
}

impl Scanned {
    /// 1-based line number of a byte offset into `self.code`.
    pub fn line_of(&self, offset: usize) -> usize {
        self.code.as_bytes()[..offset]
            .iter()
            .filter(|&&b| b == b'\n')
            .count()
            + 1
    }

    /// True if `rule` is suppressed on `line` by a justified pragma.
    pub fn allows(&self, rule: &str, line: usize) -> bool {
        self.pragmas.iter().any(|p| {
            p.justified
                && p.target == line
                && p.rules.iter().any(|r| r.eq_ignore_ascii_case(rule))
        })
    }
}

/// Parse the text of one line comment into a pragma, if it is one.
fn parse_pragma(
    comment: &str,
    line: usize,
    target: usize,
) -> Option<Pragma> {
    let body = comment.trim().strip_prefix("detlint:")?.trim();
    let (directive, justification) = match body.split_once("--") {
        Some((d, j)) => (d.trim(), j.trim()),
        None => (body, ""),
    };
    let rules: Vec<String> = if directive == "ulp-ok" {
        vec!["R4".to_string()]
    } else if let Some(inner) = directive
        .strip_prefix("allow(")
        .and_then(|r| r.strip_suffix(')'))
    {
        inner
            .split(',')
            .map(|r| r.trim().to_ascii_uppercase())
            .filter(|r| !r.is_empty())
            .collect()
    } else {
        // Unknown directive: treat as an unjustified pragma so it
        // surfaces instead of silently doing nothing.
        Vec::new()
    };
    Some(Pragma {
        line,
        target,
        justified: !justification.is_empty() && !rules.is_empty(),
        rules,
    })
}

/// Strip comments/strings/chars and collect pragmas.
pub fn scan(src: &str) -> Scanned {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = String::with_capacity(src.len());
    let mut pragmas = Vec::new();
    let mut i = 0;
    let mut line = 1usize;
    let mut line_has_code = false;

    while i < n {
        let c = chars[i];
        // Line comment (and doc comment).
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            let text: String = chars[start + 2..i].iter().collect();
            let target = if line_has_code { line } else { line + 1 };
            if let Some(p) = parse_pragma(&text, line, target) {
                pragmas.push(p);
            }
            for _ in start..i {
                out.push(' ');
            }
            continue;
        }
        // Block comment (nesting per the Rust grammar).
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1;
            i += 2;
            out.push_str("  ");
            while i < n && depth > 0 {
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    out.push_str("  ");
                    i += 2;
                } else if chars[i] == '*'
                    && i + 1 < n
                    && chars[i + 1] == '/'
                {
                    depth -= 1;
                    out.push_str("  ");
                    i += 2;
                } else {
                    if chars[i] == '\n' {
                        out.push('\n');
                        line += 1;
                        line_has_code = false;
                    } else {
                        out.push(' ');
                    }
                    i += 1;
                }
            }
            continue;
        }
        // Raw string r"..." / r#"..."# (also br / b prefixes).
        if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
            if let Some(end) = raw_string_end(&chars, i) {
                for j in i..end {
                    if chars[j] == '\n' {
                        out.push('\n');
                        line += 1;
                    } else {
                        out.push(' ');
                    }
                }
                line_has_code = true;
                i = end;
                continue;
            }
        }
        // Ordinary string literal.
        if c == '"' {
            out.push(' ');
            i += 1;
            while i < n {
                if chars[i] == '\\' && i + 1 < n {
                    out.push_str("  ");
                    i += 2;
                    continue;
                }
                if chars[i] == '"' {
                    out.push(' ');
                    i += 1;
                    break;
                }
                if chars[i] == '\n' {
                    out.push('\n');
                    line += 1;
                } else {
                    out.push(' ');
                }
                i += 1;
            }
            line_has_code = true;
            continue;
        }
        // Char literal vs lifetime: 'x' or '\n' is a literal; 'a in
        // `&'a str` is a lifetime and passes through untouched.
        if c == '\'' {
            let is_char = if i + 1 < n && chars[i + 1] == '\\' {
                true
            } else {
                i + 2 < n && chars[i + 2] == '\''
            };
            if is_char {
                out.push(' ');
                i += 1;
                while i < n && chars[i] != '\'' {
                    if chars[i] == '\\' && i + 1 < n {
                        out.push_str("  ");
                        i += 2;
                        continue;
                    }
                    out.push(' ');
                    i += 1;
                }
                if i < n {
                    out.push(' ');
                    i += 1;
                }
                line_has_code = true;
                continue;
            }
        }
        if c == '\n' {
            out.push('\n');
            line += 1;
            line_has_code = false;
        } else {
            if !c.is_whitespace() {
                line_has_code = true;
            }
            out.push(c);
        }
        i += 1;
    }

    // Resolve own-line pragmas to the next line that actually has
    // code: comments are already blanked in `out`, so "blank line in
    // the code view" covers both empty lines and continuation
    // comments (wrapped justifications).
    let line_is_code: Vec<bool> = std::iter::once(false)
        .chain(
            out.lines()
                .map(|l| l.chars().any(|c| !c.is_whitespace())),
        )
        .collect();
    for p in pragmas.iter_mut() {
        while p.target > p.line
            && p.target < line_is_code.len()
            && !line_is_code[p.target]
        {
            p.target += 1;
        }
    }

    let code = blank_cfg_test(out);
    Scanned { code, pragmas }
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1] == '_' || chars[i - 1].is_ascii_alphanumeric())
}

/// If `chars[i..]` opens a raw string literal, return the index one
/// past its closing quote.
fn raw_string_end(chars: &[char], i: usize) -> Option<usize> {
    let n = chars.len();
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
        if j >= n || chars[j] != 'r' {
            return None;
        }
    }
    if j >= n || chars[j] != 'r' {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while j < n && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || chars[j] != '"' {
        return None;
    }
    j += 1;
    while j < n {
        if chars[j] == '"' {
            let mut k = j + 1;
            let mut h = 0;
            while k < n && chars[k] == '#' && h < hashes {
                h += 1;
                k += 1;
            }
            if h == hashes {
                return Some(k);
            }
        }
        j += 1;
    }
    Some(n)
}

/// Blank every `#[cfg(test)]` item (attribute through the matching
/// close brace, or through `;` for block-less items), preserving
/// newlines. Unit tests are exempt from every rule.
fn blank_cfg_test(code: String) -> String {
    let mut bytes = code.into_bytes();
    let needle = b"#[cfg(test)]";
    let mut from = 0;
    while let Some(pos) = find_bytes(&bytes, needle, from) {
        let mut j = pos + needle.len();
        // Find the item's opening `{` or a terminating `;`.
        let mut open = None;
        while j < bytes.len() {
            match bytes[j] {
                b'{' => {
                    open = Some(j);
                    break;
                }
                b';' => break,
                _ => j += 1,
            }
        }
        let end = match open {
            Some(o) => {
                let mut depth = 0usize;
                let mut k = o;
                loop {
                    if k >= bytes.len() {
                        break k;
                    }
                    match bytes[k] {
                        b'{' => depth += 1,
                        b'}' => {
                            depth -= 1;
                            if depth == 0 {
                                break k + 1;
                            }
                        }
                        _ => {}
                    }
                    k += 1;
                }
            }
            None => j.min(bytes.len()),
        };
        for b in bytes[pos..end].iter_mut() {
            if *b != b'\n' {
                *b = b' ';
            }
        }
        from = end.max(pos + 1);
    }
    String::from_utf8(bytes).expect("blanking preserves UTF-8")
}

fn find_bytes(hay: &[u8], needle: &[u8], from: usize) -> Option<usize> {
    if from >= hay.len() {
        return None;
    }
    hay[from..]
        .windows(needle.len())
        .position(|w| w == needle)
        .map(|p| p + from)
}
