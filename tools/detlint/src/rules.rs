//! The declarative rule table (R1–R7) and each rule's matcher.
//!
//! Every rule is scoped to a set of directory prefixes (relative to
//! the scanned root, e.g. `des/`), runs over the blanked code view
//! produced by [`crate::scan`], and can be suppressed line-by-line
//! with a justified `// detlint: allow(<rule>)` pragma.
//!
//! These are token-level heuristics, not type-aware analysis (the
//! offline build image has no crates.io access, so there is no `syn`);
//! each rule documents exactly what it matches. The fixture trees
//! under `fixtures/` pin both directions: `violations/` must trip
//! every rule, `clean/` must not.

use crate::scan::Scanned;

/// One diagnostic.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Path as given to the walker (root-relative for trees).
    pub file: String,
    /// 1-based line.
    pub line: usize,
    /// Rule id (`R1`..`R7`, or `P0` for pragma problems).
    pub rule: &'static str,
    /// Short rule name, e.g. `hash-iter`.
    pub name: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}: [{} {}] {}",
            self.file, self.line, self.rule, self.name, self.msg
        )
    }
}

/// Matcher kinds. `ForbiddenTokens` carries `(token, advice)` pairs.
pub enum RuleKind {
    ForbiddenTokens(&'static [(&'static str, &'static str)]),
    RngStreamLiteral,
    FloatMergeAccumulation,
    EntryPointSignature,
    MemPolicyString,
}

/// One row of the rule table.
pub struct Rule {
    pub id: &'static str,
    pub name: &'static str,
    /// Directory prefixes (relative to the scan root) the rule polices.
    pub dirs: &'static [&'static str],
    pub rationale: &'static str,
    pub kind: RuleKind,
}

/// The determinism/soundness rule table. CONTRIBUTING.md documents
/// each rule with its full rationale; the one-liners here feed
/// `detlint --rules`.
pub static RULES: [Rule; 7] = [
    Rule {
        id: "R1",
        name: "hash-iter",
        dirs: &["des/", "workload/", "router/", "optimizer/"],
        rationale: "HashMap/HashSet iteration order is randomized per \
                    process; simulation-result paths must use BTreeMap/\
                    BTreeSet or sorted iteration",
        kind: RuleKind::ForbiddenTokens(&[
            ("HashMap", "use BTreeMap (or collect + sort) instead"),
            ("HashSet", "use BTreeSet (or collect + sort) instead"),
        ]),
    },
    Rule {
        id: "R2",
        name: "wall-clock",
        dirs: &["des/", "workload/"],
        rationale: "wall-clock time, thread identity, and the \
                    environment must never influence simulation state",
        kind: RuleKind::ForbiddenTokens(&[
            ("Instant", "wall-clock reads are nondeterministic here"),
            ("SystemTime", "wall-clock reads are nondeterministic here"),
            ("thread::current", "thread identity must not leak into \
                                 sim state"),
            ("env::var", "environment reads must stay in the CLI layer"),
            ("env::var_os", "environment reads must stay in the CLI \
                             layer"),
            ("env::vars", "environment reads must stay in the CLI \
                           layer"),
            ("env::args", "argv parsing must stay in the CLI layer"),
            ("temp_dir", "filesystem paths must not reach sim state"),
        ]),
    },
    Rule {
        id: "R3",
        name: "rng-stream",
        dirs: &["des/", "workload/"],
        rationale: "every Pcg64 stream id must come from the \
                    workload::streams registry so stream indices \
                    (4+2k/5+2k, ...) cannot silently collide",
        kind: RuleKind::RngStreamLiteral,
    },
    Rule {
        id: "R4",
        name: "float-merge-order",
        dirs: &["des/", "util/"],
        rationale: "float accumulation is order-dependent; merge paths \
                    must keep reductions commutative-exact or mark the \
                    ULP-level exception",
        kind: RuleKind::FloatMergeAccumulation,
    },
    Rule {
        id: "R5",
        name: "siminput-entry",
        dirs: &["des/"],
        rationale: "public DES entry points must take SimInput; the \
                    #[deprecated] wrappers are the only exceptions",
        kind: RuleKind::EntryPointSignature,
    },
    Rule {
        id: "R6",
        name: "real-sleep",
        dirs: &["des/", "workload/"],
        rationale: "simulated time advances only through the event \
                    queue; real sleeps and scheduler yields stall the \
                    process without moving the clock and make host \
                    timing an input (closed-loop backoff waits must be \
                    Retry events, never thread::sleep)",
        kind: RuleKind::ForbiddenTokens(&[
            ("thread::sleep", "schedule an event at now + delay \
                               instead of sleeping the process"),
            ("yield_now", "scheduler yields leak host timing into sim \
                           code; restructure instead"),
        ]),
    },
    Rule {
        id: "R7",
        name: "mem-policy-entry",
        dirs: &["des/"],
        rationale: "public DES functions must take preemption policies \
                    as the typed PreemptionPolicy/PolicyKind values, \
                    never as strings; string dispatch at call depth \
                    invites per-engine divergence (parse once at the \
                    config boundary)",
        kind: RuleKind::MemPolicyString,
    },
];

fn is_ident(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}

/// Byte offsets of `tok` in `code` with identifier boundaries: the
/// char before must not be an identifier char (a `:` is fine — that
/// is just path qualification), the char after must not be one.
fn token_offsets(code: &str, tok: &str) -> Vec<usize> {
    let hay = code.as_bytes();
    let nee = tok.as_bytes();
    let mut out = Vec::new();
    if nee.is_empty() || hay.len() < nee.len() {
        return out;
    }
    for i in 0..=hay.len() - nee.len() {
        if &hay[i..i + nee.len()] != nee {
            continue;
        }
        if i > 0 && is_ident(hay[i - 1]) {
            continue;
        }
        let after = i + nee.len();
        if after < hay.len() && is_ident(hay[after]) {
            continue;
        }
        out.push(i);
    }
    out
}

/// Does this root-relative path fall under the rule's directories?
fn in_scope(rule: &Rule, rel: &str) -> bool {
    rule.dirs.iter().any(|d| rel.starts_with(d))
}

/// Run every applicable rule over one scanned file.
pub fn apply_rules(rel: &str, scanned: &Scanned) -> Vec<Finding> {
    let mut out = Vec::new();
    for rule in RULES.iter() {
        if !in_scope(rule, rel) {
            continue;
        }
        let found = match &rule.kind {
            RuleKind::ForbiddenTokens(toks) => {
                forbidden_tokens(rel, scanned, rule, toks)
            }
            RuleKind::RngStreamLiteral => {
                rng_stream_literal(rel, scanned, rule)
            }
            RuleKind::FloatMergeAccumulation => {
                float_merge(rel, scanned, rule)
            }
            RuleKind::EntryPointSignature => {
                entry_points(rel, scanned, rule)
            }
            RuleKind::MemPolicyString => {
                mem_policy_string(rel, scanned, rule)
            }
        };
        out.extend(found);
    }
    // Malformed / unjustified pragmas are findings everywhere.
    for p in &scanned.pragmas {
        if !p.justified {
            out.push(Finding {
                file: rel.to_string(),
                line: p.line,
                rule: "P0",
                name: "pragma",
                msg: "detlint pragma without a `-- justification` \
                      (or with an unknown directive)"
                    .to_string(),
            });
        }
    }
    out.sort_by(|a, b| a.line.cmp(&b.line).then(a.rule.cmp(b.rule)));
    out
}

fn forbidden_tokens(
    rel: &str,
    scanned: &Scanned,
    rule: &'static Rule,
    toks: &[(&'static str, &'static str)],
) -> Vec<Finding> {
    let mut out = Vec::new();
    for (tok, advice) in toks {
        for off in token_offsets(&scanned.code, tok) {
            let line = scanned.line_of(off);
            if scanned.allows(rule.id, line) {
                continue;
            }
            out.push(Finding {
                file: rel.to_string(),
                line,
                rule: rule.id,
                name: rule.name,
                msg: format!("`{tok}` is forbidden here: {advice}"),
            });
        }
    }
    out
}

/// R3: `Pcg64::new(seed, <literal>)` — the stream id (second argument)
/// must be a named constant from `workload::streams`, never a bare
/// integer literal.
fn rng_stream_literal(
    rel: &str,
    scanned: &Scanned,
    rule: &'static Rule,
) -> Vec<Finding> {
    let mut out = Vec::new();
    if rel.ends_with("workload/streams.rs") {
        return out; // the registry itself
    }
    let code = &scanned.code;
    for off in token_offsets(code, "Pcg64::new") {
        let Some(args) = call_args(code, off + "Pcg64::new".len()) else {
            continue;
        };
        if args.len() < 2 {
            continue;
        }
        let stream = args[1].trim();
        let literal =
            stream.bytes().next().is_some_and(|b| b.is_ascii_digit());
        if !literal {
            continue;
        }
        let line = scanned.line_of(off);
        if scanned.allows(rule.id, line) {
            continue;
        }
        out.push(Finding {
            file: rel.to_string(),
            line,
            rule: rule.id,
            name: rule.name,
            msg: format!(
                "literal RNG stream id `{stream}`: use a named \
                 constant from workload::streams"
            ),
        });
    }
    out
}

/// Split the argument list starting at the `(` at/after `start` into
/// top-level comma-separated pieces.
fn call_args(code: &str, start: usize) -> Option<Vec<String>> {
    let bytes = code.as_bytes();
    let mut i = start;
    while i < bytes.len() && bytes[i].is_ascii_whitespace() {
        i += 1;
    }
    if i >= bytes.len() || bytes[i] != b'(' {
        return None;
    }
    let mut depth = 0usize;
    let mut args = Vec::new();
    let mut cur = String::new();
    loop {
        if i >= bytes.len() {
            return None;
        }
        let c = bytes[i] as char;
        match c {
            '(' | '[' | '{' => {
                depth += 1;
                if depth > 1 {
                    cur.push(c);
                }
            }
            ')' | ']' | '}' => {
                depth -= 1;
                if depth == 0 {
                    args.push(cur);
                    return Some(args);
                }
                cur.push(c);
            }
            ',' if depth == 1 => {
                args.push(std::mem::take(&mut cur));
            }
            _ => {
                if depth >= 1 {
                    cur.push(c);
                }
            }
        }
        i += 1;
    }
}

const INT_EVIDENCE: [&str; 17] = [
    "::<usize", "::<isize", "::<u8", "::<u16", "::<u32", "::<u64",
    "::<u128", "::<i8", "::<i16", "::<i32", "::<i64", "::<i128",
    ": usize", ": u64", ": u32", ": u16", ": isize",
];

const FLOAT_HINTS: [&str; 12] = [
    "sum", "mean", "m2", "sq", "_ms", "ttft", "wait", "e2e", "frac",
    "util", "weight", "var",
];

/// R4: inside any `fn` whose name contains `merge`, flag
/// `.sum()`-style reductions without integer-type evidence and `+=`
/// onto float-suggestive accumulators, unless marked
/// `// detlint: ulp-ok` (== `allow(R4)`).
fn float_merge(
    rel: &str,
    scanned: &Scanned,
    rule: &'static Rule,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let code = &scanned.code;
    for (body_start, body_end) in merge_fn_bodies(code) {
        let body = &code[body_start..body_end];
        // `.sum()` reductions, statement by statement.
        let mut stmt_start = 0usize;
        for (i, b) in body.bytes().enumerate() {
            let boundary = b == b';' || b == b'{' || b == b'}';
            if !boundary && i + 1 != body.len() {
                continue;
            }
            let stmt = &body[stmt_start..i];
            stmt_start = i + 1;
            let Some(sum_at) = stmt.find(".sum(").or_else(|| {
                stmt.find(".sum::<")
            }) else {
                continue;
            };
            let norm = normalize_ws(stmt);
            if INT_EVIDENCE.iter().any(|e| norm.contains(e)) {
                continue;
            }
            let line = scanned.line_of(body_start + stmt_start - 1
                - (stmt.len() - sum_at));
            if scanned.allows(rule.id, line) {
                continue;
            }
            out.push(Finding {
                file: rel.to_string(),
                line,
                rule: rule.id,
                name: rule.name,
                msg: "float (or untyped) `.sum()` in a merge path: \
                      accumulation order is not commutative-exact; \
                      state the integer type, restructure, or mark \
                      `// detlint: ulp-ok -- <why>`"
                    .to_string(),
            });
        }
        // `+=` onto float-suggestive accumulators.
        let bb = body.as_bytes();
        for i in 0..bb.len().saturating_sub(1) {
            if &bb[i..i + 2] != b"+=" {
                continue;
            }
            let Some(ident) = lhs_ident(body, i) else {
                continue;
            };
            let lower = ident.to_ascii_lowercase();
            if !FLOAT_HINTS.iter().any(|h| lower.contains(h)) {
                continue;
            }
            let line = scanned.line_of(body_start + i);
            if scanned.allows(rule.id, line) {
                continue;
            }
            out.push(Finding {
                file: rel.to_string(),
                line,
                rule: rule.id,
                name: rule.name,
                msg: format!(
                    "`{ident} += ...` in a merge path looks like a \
                     float accumulation (order-dependent); make it \
                     commutative-exact or mark \
                     `// detlint: ulp-ok -- <why>`"
                ),
            });
        }
    }
    out
}

fn normalize_ws(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut in_ws = false;
    for c in s.chars() {
        if c.is_whitespace() {
            if !in_ws {
                out.push(' ');
            }
            in_ws = true;
        } else {
            out.push(c);
            in_ws = false;
        }
    }
    out
}

/// Byte ranges of bodies of fns whose name contains `merge`.
fn merge_fn_bodies(code: &str) -> Vec<(usize, usize)> {
    let mut out = Vec::new();
    let bytes = code.as_bytes();
    for off in token_offsets(code, "fn") {
        let mut i = off + 2;
        while i < bytes.len() && bytes[i].is_ascii_whitespace() {
            i += 1;
        }
        let name_start = i;
        while i < bytes.len() && is_ident(bytes[i]) {
            i += 1;
        }
        let name = &code[name_start..i];
        if !name.contains("merge") {
            continue;
        }
        // Find the body's opening brace (skipping the signature).
        let mut depth = 0usize;
        let mut open = None;
        while i < bytes.len() {
            match bytes[i] {
                b'(' | b'[' => depth += 1,
                b')' | b']' => depth = depth.saturating_sub(1),
                b'{' if depth == 0 => {
                    open = Some(i);
                    break;
                }
                b';' if depth == 0 => break,
                _ => {}
            }
            i += 1;
        }
        let Some(o) = open else { continue };
        let mut d = 0usize;
        let mut k = o;
        while k < bytes.len() {
            match bytes[k] {
                b'{' => d += 1,
                b'}' => {
                    d -= 1;
                    if d == 0 {
                        break;
                    }
                }
                _ => {}
            }
            k += 1;
        }
        out.push((o + 1, k.min(bytes.len())));
    }
    out
}

/// The identifier being assigned by a `+=` at byte offset `at`
/// (e.g. `self.sum_sq +=` -> `sum_sq`, `arrived[off + i] +=` ->
/// `arrived`, `*a +=` -> `a`).
fn lhs_ident(body: &str, at: usize) -> Option<String> {
    let bytes = body.as_bytes();
    let mut i = at;
    while i > 0 && bytes[i - 1].is_ascii_whitespace() {
        i -= 1;
    }
    // Skip one balanced indexing suffix.
    if i > 0 && bytes[i - 1] == b']' {
        let mut depth = 0usize;
        while i > 0 {
            i -= 1;
            match bytes[i] {
                b']' => depth += 1,
                b'[' => {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                }
                _ => {}
            }
        }
    }
    let end = i;
    while i > 0 && is_ident(bytes[i - 1]) {
        i -= 1;
    }
    if i == end {
        return None;
    }
    Some(body[i..end].to_string())
}

/// R5: a `pub fn run*` in `des/` whose signature carries the legacy
/// drifted shape (`&[SimPool]` / `&[SampledRequest]`) without taking
/// `SimInput` must be `#[deprecated]`.
fn entry_points(
    rel: &str,
    scanned: &Scanned,
    rule: &'static Rule,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let code = &scanned.code;
    let bytes = code.as_bytes();
    for off in token_offsets(code, "pub") {
        // Expect `pub fn run...` (no visibility modifiers in scope).
        let mut i = off + 3;
        while i < bytes.len() && bytes[i].is_ascii_whitespace() {
            i += 1;
        }
        if !code[i..].starts_with("fn") {
            continue;
        }
        i += 2;
        while i < bytes.len() && bytes[i].is_ascii_whitespace() {
            i += 1;
        }
        let name_start = i;
        while i < bytes.len() && is_ident(bytes[i]) {
            i += 1;
        }
        let name = &code[name_start..i];
        if !name.starts_with("run") {
            continue;
        }
        // Signature: up to the body `{` or a `;`.
        let sig_end = bytes[i..]
            .iter()
            .position(|&b| b == b'{' || b == b';')
            .map(|p| p + i)
            .unwrap_or(bytes.len());
        let sig: String = code[i..sig_end]
            .chars()
            .filter(|c| !c.is_whitespace())
            .collect();
        let legacy_shape = sig.contains("&[SimPool]")
            || sig.contains("&[SampledRequest]");
        if !legacy_shape || sig.contains("SimInput") {
            continue;
        }
        if preceded_by_deprecated(code, off) {
            continue;
        }
        let line = scanned.line_of(off);
        if scanned.allows(rule.id, line) {
            continue;
        }
        out.push(Finding {
            file: rel.to_string(),
            line,
            rule: rule.id,
            name: rule.name,
            msg: format!(
                "`pub fn {name}` takes the legacy pools/router \
                 argument shape without SimInput; route through \
                 SimInput or mark the wrapper #[deprecated]"
            ),
        });
    }
    out
}

/// R7: a `pub fn` in `des/` must not take a preemption policy as a
/// string (`policy: &str` / `policy: String`). Policies are parsed
/// exactly once at the config boundary (`MemoryConfig::from_toml_str`)
/// into `PolicyKind`, and every engine dispatches through the
/// `PreemptionPolicy` trait; string dispatch below that boundary is
/// how per-engine behavioural drift starts.
fn mem_policy_string(
    rel: &str,
    scanned: &Scanned,
    rule: &'static Rule,
) -> Vec<Finding> {
    let mut out = Vec::new();
    let code = &scanned.code;
    let bytes = code.as_bytes();
    for off in token_offsets(code, "pub") {
        let mut i = off + 3;
        while i < bytes.len() && bytes[i].is_ascii_whitespace() {
            i += 1;
        }
        // Accept `pub fn` and `pub(crate) fn` alike.
        if code[i..].starts_with('(') {
            let Some(close) = code[i..].find(')') else { continue };
            i += close + 1;
            while i < bytes.len() && bytes[i].is_ascii_whitespace() {
                i += 1;
            }
        }
        if !code[i..].starts_with("fn") {
            continue;
        }
        i += 2;
        while i < bytes.len() && bytes[i].is_ascii_whitespace() {
            i += 1;
        }
        let name_start = i;
        while i < bytes.len() && is_ident(bytes[i]) {
            i += 1;
        }
        let name = &code[name_start..i];
        if name.is_empty() {
            continue;
        }
        let sig_end = bytes[i..]
            .iter()
            .position(|&b| b == b'{' || b == b';')
            .map(|p| p + i)
            .unwrap_or(bytes.len());
        let sig: String = code[i..sig_end]
            .chars()
            .filter(|c| !c.is_whitespace())
            .collect();
        if !sig.contains("policy:&str") && !sig.contains("policy:String")
        {
            continue;
        }
        let line = scanned.line_of(off);
        if scanned.allows(rule.id, line) {
            continue;
        }
        out.push(Finding {
            file: rel.to_string(),
            line,
            rule: rule.id,
            name: rule.name,
            msg: format!(
                "`pub fn {name}` takes a preemption policy as a \
                 string; parse it once at the config boundary and \
                 pass PolicyKind / dispatch through the \
                 PreemptionPolicy trait"
            ),
        });
    }
    out
}

/// Look back a few lines for a `#[deprecated` attribute directly above
/// the item (attributes and blanked doc comments only in between).
fn preceded_by_deprecated(code: &str, off: usize) -> bool {
    let before = &code[..off];
    let tail: Vec<&str> = before.lines().rev().take(6).collect();
    for l in &tail {
        let t = l.trim();
        if t.contains("#[deprecated") {
            return true;
        }
        // Attributes, blank(ed) lines, and the item's own indentation
        // may sit between; anything else ends the attribute block.
        if !t.is_empty() && !t.starts_with("#[") && !t.ends_with(']') {
            return false;
        }
    }
    false
}
