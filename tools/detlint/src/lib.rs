//! detlint — the repo's determinism/soundness static-analysis pass.
//!
//! The load-bearing guarantee of this codebase is bit-identical
//! results across the production, reference, and sharded DES engines
//! for any shard count. The regression suites enforce it dynamically;
//! detlint enforces the *static* discipline that keeps new code from
//! eroding it: no hash-order iteration in result paths (R1), no
//! wall-clock/thread/env input to sim state (R2), RNG stream ids from
//! a single named registry (R3), acknowledged float-accumulation
//! order in merge paths (R4), `SimInput`-only public DES entry
//! points (R5), no real sleeps or scheduler yields where only
//! simulated time may pass (R6), and no string-typed preemption
//! policies past the config boundary (R7).
//!
//! Run it over a tree:
//!
//! ```text
//! cargo run -p detlint -- rust/src
//! ```
//!
//! Exit status is 0 iff no findings. See `src/rules.rs` for the rule
//! table and CONTRIBUTING.md for the full contract and pragma format.

pub mod rules;
pub mod scan;

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use rules::{Finding, Rule, RuleKind, RULES};

/// Lint every `.rs` file under `root` (which should be a source root
/// like `rust/src`, so that rule directory scopes such as `des/`
/// resolve). Findings are sorted by file, then line.
pub fn lint_tree(root: &Path) -> io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for f in &files {
        let rel = f
            .strip_prefix(root)
            .unwrap_or(f)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(f)?;
        out.extend(lint_source(&rel, &src));
    }
    Ok(out)
}

/// Lint one already-loaded source file. `rel` is the path relative to
/// the source root (it drives rule scoping).
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let scanned = scan::scan(src);
    rules::apply_rules(rel, &scanned)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if path.is_dir() {
            // Never descend into build output or vendored code.
            if name == "target" || name == "vendor" || name == ".git" {
                continue;
            }
            collect_rs_files(&path, out)?;
        } else if name.ends_with(".rs") {
            out.push(path);
        }
    }
    Ok(())
}
