//! CLI for the determinism/soundness lint. See lib.rs.

use std::path::Path;
use std::process::ExitCode;

use detlint::{lint_tree, RuleKind, RULES};

const USAGE: &str = "\
usage: detlint [--rules] <source-root>...

Lints every .rs file under each source root (e.g. rust/src) against
the repo determinism/soundness rules R1-R7. Exits nonzero iff any
finding is reported. --rules prints the rule table and exits.";

fn print_rules() {
    for r in RULES.iter() {
        println!("{} {} (scope: {})", r.id, r.name, r.dirs.join(" "));
        println!("    {}", r.rationale);
        if let RuleKind::ForbiddenTokens(toks) = &r.kind {
            for (tok, _) in toks.iter() {
                println!("    forbids: {tok}");
            }
        }
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--help" || a == "-h") {
        println!("{USAGE}");
        return ExitCode::SUCCESS;
    }
    if args.iter().any(|a| a == "--rules") {
        print_rules();
        return ExitCode::SUCCESS;
    }
    if args.is_empty() {
        eprintln!("{USAGE}");
        return ExitCode::from(2);
    }
    let mut n_findings = 0usize;
    for root in &args {
        match lint_tree(Path::new(root)) {
            Ok(findings) => {
                for f in &findings {
                    println!("{root}/{f}");
                }
                n_findings += findings.len();
            }
            Err(e) => {
                eprintln!("detlint: {root}: {e}");
                return ExitCode::from(2);
            }
        }
    }
    if n_findings == 0 {
        println!("detlint: clean");
        ExitCode::SUCCESS
    } else {
        println!("detlint: {n_findings} finding(s)");
        ExitCode::FAILURE
    }
}
