//! detlint self-tests.
//!
//! Three properties gate CI:
//!   1. the real simulator tree (`rust/src`) lints clean,
//!   2. the seeded fixture tree trips every rule R1-R7 plus P0,
//!   3. the clean fixture tree (every sanctioned escape hatch)
//!      produces no findings.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use detlint::lint_tree;

fn fixture(sub: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("fixtures")
        .join(sub)
}

#[test]
fn real_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("..")
        .join("rust")
        .join("src");
    let findings = lint_tree(&root).expect("lint rust/src");
    assert!(
        findings.is_empty(),
        "rust/src must lint clean, got:\n{}",
        findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn violations_tree_trips_every_rule() {
    let findings =
        lint_tree(&fixture("violations")).expect("lint fixtures");
    let tripped: BTreeSet<&str> =
        findings.iter().map(|f| f.rule).collect();
    for rule in ["R1", "R2", "R3", "R4", "R5", "R6", "R7", "P0"] {
        assert!(
            tripped.contains(rule),
            "fixture tree must trip {rule}, only saw {tripped:?}"
        );
    }
}

#[test]
fn violations_are_attributed_to_the_seeded_files() {
    let findings =
        lint_tree(&fixture("violations")).expect("lint fixtures");
    let has = |rule: &str, file: &str| {
        findings
            .iter()
            .any(|f| f.rule == rule && f.file.ends_with(file))
    };
    assert!(has("R1", "des/r1_hash_iter.rs"));
    assert!(has("R2", "des/r2_wall_clock.rs"));
    assert!(has("R3", "workload/r3_stream_literal.rs"));
    assert!(has("R4", "des/r4_float_merge.rs"));
    assert!(has("R5", "des/r5_entry_point.rs"));
    assert!(has("R6", "des/r6_sleep.rs"));
    assert!(has("R7", "des/r7_policy_string.rs"));
    assert!(has("P0", "des/p0_bad_pragma.rs"));
    // The unjustified pragma must not suppress its rule.
    assert!(has("R1", "des/p0_bad_pragma.rs"));
}

#[test]
fn r4_fixture_flags_floats_but_not_integer_counts() {
    let findings =
        lint_tree(&fixture("violations")).expect("lint fixtures");
    let r4: Vec<_> = findings
        .iter()
        .filter(|f| {
            f.rule == "R4" && f.file.ends_with("r4_float_merge.rs")
        })
        .collect();
    // `self.sum += other.sum` and the untyped `.sum()` — exactly two;
    // `self.count += other.count` stays unflagged.
    assert_eq!(
        r4.len(),
        2,
        "expected 2 R4 findings, got: {r4:?}"
    );
}

#[test]
fn clean_tree_has_no_findings() {
    let findings =
        lint_tree(&fixture("clean")).expect("lint clean fixtures");
    assert!(
        findings.is_empty(),
        "clean fixtures must pass, got:\n{}",
        findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
