//! Seeded R3 violation: a bare integer RNG stream id. Stream 3 is the
//! routing stream — this sampler would silently consume the same
//! substream as the DES router.

use crate::workload::rng::Pcg64;

pub fn sample_noise(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = Pcg64::new(seed, 3);
    (0..n).map(|_| rng.uniform()).collect()
}
