//! Seeded R7 violation: a public DES function that takes the
//! preemption policy as a string instead of the typed PolicyKind,
//! pushing parsing (and divergence risk) below the config boundary.

use crate::des::engine::DesPool;

pub fn apply_preemption(pools: &mut [DesPool], policy: &str) {
    unimplemented!("parse policies once at the config boundary")
}

pub(crate) fn resolve_policy_name(policy: String) -> u8 {
    unimplemented!("dispatch through the PreemptionPolicy trait")
}
