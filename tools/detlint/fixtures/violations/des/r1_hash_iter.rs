//! Seeded R1 violation: hash-order iteration in a result path.

use std::collections::HashMap;

pub fn per_pool_totals(samples: &[(usize, f64)]) -> Vec<f64> {
    let mut by_pool: HashMap<usize, f64> = HashMap::new();
    for &(pool, v) in samples {
        *by_pool.entry(pool).or_insert(0.0) += v;
    }
    // Iteration order is randomized per process: the returned vector
    // (and anything accumulated from it) differs run to run.
    by_pool.values().copied().collect()
}

#[cfg(test)]
mod tests {
    use std::collections::HashSet;

    #[test]
    fn hash_containers_are_fine_in_tests() {
        let s: HashSet<u32> = (0..4).collect();
        assert_eq!(s.len(), 4);
    }
}
