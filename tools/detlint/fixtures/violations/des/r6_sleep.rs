//! Seeded R6 violation: real sleeps in place of simulated time.

use std::thread;
use std::time::Duration;

pub fn wait_for_backoff(delay_ms: u64) {
    // Stalls the process; the simulated clock never moves. A backoff
    // wait must be a Retry event at `now + delay`, not a sleep.
    thread::sleep(Duration::from_millis(delay_ms));
    std::thread::yield_now();
}
