//! Seeded P0 violation: a pragma with no justification text. The
//! suppression is ignored, so the R1 finding fires as well.

// detlint: allow(R1)
use std::collections::HashSet;

pub fn distinct(xs: &[u32]) -> usize {
    xs.iter().collect::<HashSet<_>>().len()
}
