//! Seeded R4 violations: order-dependent float reductions in a merge
//! path, with no `// detlint: ulp-ok` acknowledgment.

pub struct Stats {
    pub sum: f64,
    pub count: u64,
    pub values: Vec<f64>,
}

impl Stats {
    pub fn merge(&mut self, other: &Stats) {
        // Float accumulation: result depends on merge order.
        self.sum += other.sum;
        // Integer accumulation is exact and passes unflagged.
        self.count += other.count;
        // Untyped reduction over a float container.
        let total = other.values.iter().sum();
        self.values.push(total);
    }
}
