//! Seeded R2 violation: wall-clock input to simulation state.

use std::time::Instant;

pub fn jittered_seed(base: u64) -> u64 {
    let t0 = Instant::now();
    // Wall-clock-derived state: two identical runs now diverge.
    base ^ t0.elapsed().subsec_nanos() as u64
}
