//! Seeded R5 violation: a new public DES entry point with the legacy
//! drifted argument shape and no `#[deprecated]` escape hatch.

use crate::des::engine::{DesConfig, SimPool};
use crate::des::metrics::DesResult;
use crate::router::RoutingPolicy;

pub fn run_adhoc(
    pools: &[SimPool],
    router: &RoutingPolicy,
    config: &DesConfig,
) -> DesResult {
    unimplemented!("entry points must take SimInput")
}
