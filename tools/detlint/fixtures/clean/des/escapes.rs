//! Clean fixtures: every escape hatch detlint honors, in one file.
//! This tree must lint clean; each construct here is a regression
//! test against a false positive.

use crate::des::input::SimInput;
use crate::des::metrics::DesResult;

// A justified allow pragma scopes the next code line.
// detlint: allow(R1) -- build-only scratch map, drained into a sorted Vec
use std::collections::HashMap;

pub struct Merged {
    pub sum: f64,
    pub count: u64,
    pub lat_ms: Vec<f64>,
}

impl Merged {
    pub fn merge(&mut self, other: &Merged) {
        // detlint: ulp-ok -- commutative to within 1 ulp, asserted by tests
        self.sum += other.sum;
        // Integer accumulation needs no pragma.
        self.count += other.count;
        // Turbofish integer reductions are recognized as exact.
        let n = other.lat_ms.iter().map(|_| 1).sum::<usize>();
        let _ = n;
    }
}

pub fn scratch_index(keys: &[u64]) -> usize {
    // detlint: allow(R1) -- len-only use, no iteration over the map
    let mut m: HashMap<u64, usize> = HashMap::new();
    for (i, &k) in keys.iter().enumerate() {
        m.insert(k, i);
    }
    m.len()
}

// Deprecated wrappers are the one sanctioned non-SimInput entry shape.
#[deprecated(since = "0.2.0", note = "use run_input")]
pub fn run_legacy(
    pools: &[SimPool],
    router: &RoutingPolicy,
    config: &DesConfig,
) -> DesResult {
    unimplemented!()
}

// The replacement shape: SimInput in the signature satisfies R5.
pub fn run_input(input: &SimInput<'_>) -> DesResult {
    unimplemented!()
}

#[cfg(test)]
mod tests {
    // Test code is out of scope for every rule: wall clocks, hash
    // iteration, and literal streams are all legal here.
    use std::collections::HashSet;
    use std::time::Instant;

    #[test]
    fn scope_exclusion_smoke() {
        let t0 = Instant::now();
        let s: HashSet<u32> = (0..3).collect();
        assert!(t0.elapsed().as_secs() < 60);
        assert_eq!(s.len(), 3);
    }
}
