//! Clean fixture: RNG constructed from named registry constants only.

use crate::workload::rng::Pcg64;
use crate::workload::streams;

pub fn routing_rng(seed: u64) -> Pcg64 {
    Pcg64::new(seed, streams::ROUTING)
}

pub fn block_rngs(seed: u64, block: u64) -> (Pcg64, Pcg64) {
    let (arrivals, lengths) = streams::block_streams(block);
    (Pcg64::new(seed, arrivals), Pcg64::new(seed, lengths))
}
