//! Integration tests for the scenario registry + unified evaluation
//! engine: registry lookups, equivalence of the generic `run --scenario`
//! path with the legacy per-puzzle entry points, parallel-vs-serial sweep
//! determinism, and the shared request-stream cache.

use fleet_sim::optimizer::engine::EvalEngine;
use fleet_sim::scenarios::{self, Scenario, ScenarioOpts};
use fleet_sim::workload::spec::{BuiltinTrace, WorkloadSpec};

fn fast_opts() -> ScenarioOpts {
    ScenarioOpts { n_requests: 2_000, ..ScenarioOpts::fast() }
}

#[test]
fn registry_run_matches_legacy_entry_points() {
    // The generic registry path (`run --scenario puzzleN`) must reproduce
    // the same tables as the old per-puzzle run() functions.
    let opts = fast_opts();
    let via_registry = scenarios::run(5, &opts).unwrap().render();
    let legacy = fleet_sim::scenarios::puzzle5_routers::run(&opts).render();
    assert_eq!(via_registry, legacy);

    let via_registry4 = scenarios::run(4, &opts).unwrap().render();
    let legacy4 = fleet_sim::scenarios::puzzle4_steps::run(&opts).render();
    assert_eq!(via_registry4, legacy4);

    let mm = scenarios::find("multi-model").unwrap();
    let engine = scenarios::default_engine(&opts);
    let via_registry_mm = mm.run(&engine, &opts).render();
    let legacy_mm = fleet_sim::scenarios::multi_model::run(&opts).render();
    assert_eq!(via_registry_mm, legacy_mm);
}

#[test]
fn parallel_and_serial_sweeps_produce_identical_tables() {
    // The engine's par_map fan-out must not change any table cell: same
    // candidates, same DES results, same rendering, independent of the
    // worker-thread count.
    let serial = fast_opts().serial();
    let parallel = ScenarioOpts { threads: 8, ..fast_opts() };
    for scenario_id in ["puzzle3", "puzzle5"] {
        let s = scenarios::find(scenario_id).unwrap();
        let a = s
            .run(&scenarios::default_engine(&serial), &serial)
            .render();
        let b = s
            .run(&scenarios::default_engine(&parallel), &parallel)
            .render();
        assert_eq!(a, b, "{scenario_id}: parallel != serial");
    }
}

#[test]
fn engine_stream_cache_is_shared_across_a_scenario_run() {
    // Puzzle 5 simulates three routers on the same (workload, n, seed):
    // the engine must sample the request stream exactly once.
    let opts = fast_opts();
    let engine = scenarios::default_engine(&opts);
    let s = scenarios::find("routers").unwrap();
    let _ = s.run(&engine, &opts);
    assert_eq!(engine.cached_streams(), 1,
               "three router sims should share one sampled stream");
}

#[test]
fn engine_verify_is_identical_to_fresh_simulation() {
    // The cached-stream verification path must equal a from-scratch
    // Simulator::run for the same candidate (guards the cache key).
    use fleet_sim::optimizer::planner::plan_pools;
    use fleet_sim::des::engine::{DesConfig, Simulator};
    use fleet_sim::queueing::mgc::WorkloadHist;

    let engine = EvalEngine::standard();
    let w = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 100.0);
    let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
    let a100 = engine.catalog.get("A100").unwrap().clone();
    let cand = EvalEngine::min_two_pool(&w, &hist, &a100, &a100, 2048.0,
                                        500.0, 256)
        .expect("feasible");
    let cfg = DesConfig { n_requests: 2_000, ..Default::default() };
    // Twice through the engine: second call hits the cache.
    let v1 = engine.verify(&w, &cand, &cfg, 500.0);
    let v2 = engine.verify(&w, &cand, &cfg, 500.0);
    assert_eq!(v1.p99_ttft_ms, v2.p99_ttft_ms);
    assert_eq!(engine.cached_streams(), 1);
    let (pools, router) = plan_pools(&cand);
    let mut fresh = Simulator::new(w.clone(), pools, router, cfg).run();
    assert_eq!(v1.p99_ttft_ms, fresh.overall.p99_ttft());
}

#[test]
fn scenario_specs_name_real_traces_and_gpus() {
    let catalog = fleet_sim::gpu::catalog::GpuCatalog::standard();
    for s in scenarios::registry() {
        let spec = s.spec();
        for (trace, lambda) in &spec.workloads {
            assert!(BuiltinTrace::parse(trace).is_ok(),
                    "{}: unknown trace {trace}", s.id());
            assert!(*lambda > 0.0);
        }
        for gpu in &spec.gpus {
            assert!(catalog.get(gpu).is_some(),
                    "{}: unknown GPU {gpu}", s.id());
        }
    }
}
