//! Streaming-sketch accuracy against exact-sorted statistics, on the
//! three embedded trace CDFs (the satellite check for the O(pools)-memory
//! metrics path): sketch P99 must track exact P99 within the documented
//! ~1% bin width on every workload the planner ships.

use fleet_sim::des::engine::{DesConfig, SimPool, Simulator};
use fleet_sim::des::input::SimInput;
use fleet_sim::des::metrics::MetricsMode;
use fleet_sim::gpu::catalog::GpuCatalog;
use fleet_sim::router::RoutingPolicy;
use fleet_sim::util::stats::Samples;
use fleet_sim::workload::rng::Pcg64;
use fleet_sim::workload::spec::{BuiltinTrace, WorkloadSpec};

const TRACES: [BuiltinTrace; 3] =
    [BuiltinTrace::Lmsys, BuiltinTrace::Azure, BuiltinTrace::Agent];

#[test]
fn sketch_p99_matches_exact_p99_on_all_embedded_traces() {
    for trace in TRACES {
        let w = WorkloadSpec::builtin(trace, 50.0);
        let mut rng = Pcg64::new(1234, 9);
        let mut exact = Samples::new();
        let mut sketch = Samples::streaming();
        for _ in 0..20_000 {
            let total = w.cdf.sample(&mut rng);
            exact.push(total);
            sketch.push(total);
        }
        for q in [50.0, 90.0, 99.0] {
            let e = exact.percentile(q);
            let s = sketch.percentile(q);
            assert!(
                (s / e - 1.0).abs() < 0.02,
                "{}: q={q} exact {e} sketch {s}",
                w.name
            );
        }
        let (em, sm) = (exact.mean(), sketch.mean());
        assert!((em - sm).abs() < em.abs() * 1e-9 + 1e-9, "{}", w.name);
        assert_eq!(exact.min(), sketch.min(), "{}", w.name);
        assert_eq!(exact.max(), sketch.max(), "{}", w.name);
    }
}

#[test]
fn windowed_stats_parity_between_exact_and_streaming() {
    // Windowed TTFT series on an embedded trace driven by the diurnal
    // NHPP profile: window structure and counts must be identical across
    // metrics modes, and per-window P99 / attainment must agree within
    // the sketch's documented ~1-2% bin width.
    let gpu = GpuCatalog::standard().get("H100").unwrap().clone();
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 120.0)
        .with_nhpp(vec![(0.0, 40.0), (10_000.0, 200.0)], 20_000.0);
    let pools = vec![SimPool {
        gpu, n_gpus: 24, ctx_budget: 8192.0, batch_cap: None,
    }];
    let router = RoutingPolicy::Random { n_pools: 1 };
    let base = DesConfig {
        n_requests: 8_000,
        seed: 17,
        window_ms: Some(5_000.0),
        ..Default::default()
    };
    let sampled = w.sample_requests(base.n_requests, base.seed);
    let mut exact = Simulator::run_input(&SimInput::stream(
        &pools, &router, &base, &sampled,
    ))
    .unwrap();
    let stream_cfg =
        DesConfig { metrics: MetricsMode::Streaming, ..base };
    let mut sketch = Simulator::run_input(&SimInput::stream(
        &pools, &router, &stream_cfg, &sampled,
    ))
    .unwrap();
    let we = exact.windows.as_mut().expect("exact windows");
    let ws = sketch.windows.as_mut().expect("streaming windows");
    assert_eq!(we.n_windows(), ws.n_windows());
    assert!(we.n_windows() >= 8, "windows = {}", we.n_windows());
    for i in 0..we.n_windows() {
        assert_eq!(we.n_arrived(i), ws.n_arrived(i), "window {i}");
        assert_eq!(we.n_served(i), ws.n_served(i), "window {i}");
        assert_eq!(we.n_unserved(i), 0, "window {i}");
        let (pe, ps) = (we.p99_ttft(i), ws.p99_ttft(i));
        assert!(
            (ps / pe - 1.0).abs() < 0.02,
            "window {i}: exact P99 {pe} sketch {ps}"
        );
        let (ae, asx) =
            (we.attainment(i, 500.0), ws.attainment(i, 500.0));
        assert!(
            (ae - asx).abs() < 0.02,
            "window {i}: exact att {ae} sketch {asx}"
        );
    }
}

#[test]
fn sketch_attainment_matches_exact_on_des_runs() {
    // End-to-end: run the same fleet in both metrics modes on each trace
    // and compare SLO attainment (Table-5-style numbers) and P99 TTFT.
    let gpu = GpuCatalog::standard().get("H100").unwrap().clone();
    for (trace, lambda) in [
        (BuiltinTrace::Lmsys, 60.0),
        (BuiltinTrace::Azure, 60.0),
        (BuiltinTrace::Agent, 10.0),
    ] {
        let w = WorkloadSpec::builtin(trace, lambda);
        let max_len = w.cdf.max_len();
        let pools = vec![
            SimPool { gpu: gpu.clone(), n_gpus: 4, ctx_budget: 4096.0,
                      batch_cap: None },
            SimPool { gpu: gpu.clone(), n_gpus: 8, ctx_budget: max_len,
                      batch_cap: None },
        ];
        let router = RoutingPolicy::Length { b_short: 4096.0 };
        let base = DesConfig { n_requests: 6_000, seed: 3,
                               ..Default::default() };
        let sampled = w.sample_requests(base.n_requests, base.seed);
        let mut exact = Simulator::run_input(&SimInput::stream(
            &pools, &router, &base, &sampled,
        ))
        .unwrap();
        let stream_cfg = DesConfig { metrics: MetricsMode::Streaming,
                                     ..base };
        let mut sketch = Simulator::run_input(&SimInput::stream(
            &pools, &router, &stream_cfg, &sampled,
        ))
        .unwrap();
        let (e, s) = (exact.overall.p99_ttft(), sketch.overall.p99_ttft());
        assert!((s / e - 1.0).abs() < 0.02,
                "{}: exact P99 {e} sketch P99 {s}", w.name);
        for slo in [250.0, 500.0, 2_000.0] {
            let ae = exact.attainment(slo);
            let asx = sketch.attainment(slo);
            assert!((ae - asx).abs() < 0.02,
                    "{}: slo {slo} exact {ae} sketch {asx}", w.name);
        }
    }
}
