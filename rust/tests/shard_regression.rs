//! Shard regression suite: the generator-driven sharded executor
//! against the serial production engine.
//!
//! Generalizes the `des_regression` pinning pattern one level up: that
//! suite pins the calendar-queue engine against the all-events-heap
//! reference; this one pins [`fleet_sim::des::shard::run_sharded`] (any
//! shard count, any chunk size) against `Simulator::run_stream` on the
//! materialized stream — bit-identical percentiles, counts, horizons,
//! event counts, utilizations, windows, and unserved accounting, in
//! both metrics modes. Generator-vs-materialized identity is implied
//! transitively (`sample_requests` is itself generator-backed, pinned
//! in `workload::generator` unit tests).
//!
//! Shard counts honor `FLEET_SIM_TEST_SHARDS` (CI runs a 1-vs-4 thread
//! matrix); any value is also exercised against 1 and 2 because the
//! executor clamps shards to the pool count.

// This suite deliberately keeps calling the deprecated `run_stream` /
// `run_sharded` / `run_streamed` wrappers: they stay public until the
// next major bump, and the regression oracle must keep proving they
// match the `SimInput`-based entry points bit for bit.
#![allow(deprecated)]

use fleet_sim::des::engine::{CapWindow, DesConfig, SimPool, Simulator};
use fleet_sim::des::faults::{FaultScript, GpuFailure, Straggler};
use fleet_sim::des::input::SimInput;
use fleet_sim::des::memory::{MemoryConfig, MemorySpec, PolicyKind};
use fleet_sim::des::metrics::{DesResult, MetricsMode};
use fleet_sim::des::reference::run_reference_input;
use fleet_sim::des::retry::{AdmissionSpec, RetryConfig, RetrySpec};
use fleet_sim::des::shard::{run_sharded, run_sharded_input, run_streamed,
                            run_streamed_input};
use fleet_sim::router::RoutingPolicy;
use fleet_sim::workload::spec::{BuiltinTrace, WorkloadSpec};

/// Reference summary of one simulation (the `des_regression` shape plus
/// the horizon and the closed-loop counters; means are deliberately
/// absent — merged overall stats accumulate in shard order, so float
/// sums differ in the last ulp while every order-statistic and count is
/// bit-identical).
#[derive(Debug, PartialEq)]
struct Summary {
    overall_p99_ttft: f64,
    overall_p99_wait: f64,
    overall_p99_e2e: f64,
    overall_count: usize,
    pool_p99_ttft: Vec<f64>,
    pool_counts: Vec<usize>,
    pool_unserved: Vec<usize>,
    utilization: Vec<f64>,
    max_queue_depth: Vec<usize>,
    n_compressed: usize,
    n_events: usize,
    n_unserved: usize,
    n_attempts: usize,
    n_abandoned: usize,
    n_shed: usize,
    n_preempted: usize,
    preempt_stall_ms: f64,
    kv_peak_util: f64,
    kv_mean_util: f64,
    max_unserved_wait_ms: f64,
    horizon_ms: f64,
    /// Per-window (start, arrived, served, shed, abandoned, preempted,
    /// p99 TTFT) when windowed.
    windows: Option<Vec<(f64, usize, usize, usize, usize, usize, f64)>>,
}

fn summarize(mut r: DesResult) -> Summary {
    let windows = r.windows.as_mut().map(|w| {
        (0..w.n_windows())
            .map(|i| {
                let p99 = w.p99_ttft(i);
                (w.start_ms(i), w.n_arrived(i), w.n_served(i),
                 w.n_shed(i), w.n_abandoned(i), w.n_preempted(i),
                 if p99.is_nan() { -1.0 } else { p99 })
            })
            .collect()
    });
    Summary {
        overall_p99_ttft: r.overall.ttft.p99(),
        overall_p99_wait: r.overall.wait.p99(),
        overall_p99_e2e: r.overall.e2e.p99(),
        overall_count: r.overall.count,
        pool_p99_ttft: r.per_pool.iter_mut().map(|p| p.stats.ttft.p99())
            .collect(),
        pool_counts: r.per_pool.iter().map(|p| p.stats.count).collect(),
        pool_unserved: r.per_pool.iter().map(|p| p.n_unserved).collect(),
        utilization: r.per_pool.iter().map(|p| p.utilization).collect(),
        max_queue_depth: r.per_pool.iter().map(|p| p.max_queue_depth)
            .collect(),
        n_compressed: r.n_compressed,
        n_events: r.n_events,
        n_unserved: r.n_unserved,
        n_attempts: r.n_attempts,
        n_abandoned: r.n_abandoned,
        n_shed: r.n_shed,
        n_preempted: r.n_preempted,
        preempt_stall_ms: r.preempt_stall_ms,
        kv_peak_util: r.kv_peak_util,
        kv_mean_util: r.kv_mean_util,
        max_unserved_wait_ms: r.max_unserved_wait_ms,
        horizon_ms: r.horizon_ms,
        windows,
    }
}

/// Shard counts to exercise: always 1 (the pure generator path) and 2,
/// plus the CI matrix value from `FLEET_SIM_TEST_SHARDS` if set (the
/// executor clamps to the pool count, so oversubscription is also a
/// valid — and tested — input).
fn shard_counts() -> Vec<usize> {
    let mut counts = vec![1usize, 2];
    if let Some(n) = std::env::var("FLEET_SIM_TEST_SHARDS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
    {
        let n = n.max(1);
        if !counts.contains(&n) {
            counts.push(n);
        }
    } else {
        counts.push(4);
    }
    counts
}

/// Assert sharded == serial, bit for bit, in both metrics modes, for
/// every shard count and a block-straddling chunk size.
fn assert_sharded_matches(
    w: &WorkloadSpec,
    pools: Vec<SimPool>,
    router: RoutingPolicy,
    cfg: DesConfig,
    label: &str,
) {
    let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
    for mode in [MetricsMode::Exact, MetricsMode::Streaming] {
        let cfg = DesConfig { metrics: mode, ..cfg.clone() };
        let serial = summarize(Simulator::run_stream(
            &pools, &router, &cfg, &sampled,
        ));
        for shards in shard_counts() {
            let (r, _) = run_sharded(&pools, &router, &cfg, w, shards, 997);
            assert_eq!(
                summarize(r), serial,
                "{label} [{mode:?} shards={shards}]: sharded run \
                 diverged from serial"
            );
        }
    }
}

fn gpu(name: &str) -> fleet_sim::gpu::profile::GpuProfile {
    fleet_sim::gpu::catalog::GpuCatalog::standard()
        .get(name)
        .unwrap()
        .clone()
}

#[test]
fn sharded_matches_serial_two_pool_length_router() {
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 120.0);
    let pools = vec![
        SimPool { gpu: gpu("A100"), n_gpus: 4, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("A100"), n_gpus: 4, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    assert_sharded_matches(
        &w, pools, RoutingPolicy::Length { b_short: 4096.0 },
        DesConfig { n_requests: 4_000, seed: 11, ..Default::default() },
        "azure two-pool",
    );
}

#[test]
fn sharded_matches_serial_compress_router() {
    // CompressAndRoute mutates requests in flight and counts
    // compressions — both must merge exactly.
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 60.0);
    let pools = vec![
        SimPool { gpu: gpu("H100"), n_gpus: 2, ctx_budget: 2048.0,
                  batch_cap: None },
        SimPool { gpu: gpu("H100"), n_gpus: 3, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    assert_sharded_matches(
        &w, pools,
        RoutingPolicy::CompressAndRoute { b_short: 2048.0, gamma: 1.5 },
        DesConfig { n_requests: 3_000, seed: 23, ..Default::default() },
        "azure compress",
    );
}

#[test]
fn sharded_matches_serial_on_nhpp_stream_with_windows() {
    // Non-stationary arrivals + windowed stats: the per-window series
    // must merge to the serial one exactly (bases re-anchor, counts
    // add, per-window percentiles are order statistics).
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 120.0)
        .with_nhpp(vec![(0.0, 40.0), (10_000.0, 200.0)], 20_000.0);
    let pools = vec![
        SimPool { gpu: gpu("A100"), n_gpus: 5, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("A100"), n_gpus: 5, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    assert_sharded_matches(
        &w, pools, RoutingPolicy::Length { b_short: 4096.0 },
        DesConfig { n_requests: 4_000, seed: 19,
                    window_ms: Some(5_000.0), ..Default::default() },
        "azure diurnal NHPP",
    );
}

#[test]
fn sharded_matches_serial_on_replayed_stream_with_windows() {
    let mut ts = Vec::new();
    let mut t = 0.0;
    for i in 0..500 {
        t += if i % 10 == 0 { 480.0 } else { 2.0 };
        ts.push(t);
    }
    let w = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 50.0)
        .with_replay(ts, 1.5);
    let pools = vec![
        SimPool { gpu: gpu("H100"), n_gpus: 2, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("H100"), n_gpus: 3, ctx_budget: 65536.0,
                  batch_cap: None },
    ];
    assert_sharded_matches(
        &w, pools, RoutingPolicy::Length { b_short: 4096.0 },
        DesConfig { n_requests: 3_000, seed: 29,
                    window_ms: Some(10_000.0), ..Default::default() },
        "lmsys burst replay",
    );
}

#[test]
fn sharded_matches_serial_with_cap_window_and_classes() {
    // Three pools over two-to-four shards, cap-window drains, and the
    // class-probability routing draw — the full tie-breaking and
    // RNG-replay surface.
    let w = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 80.0);
    let pools = vec![
        SimPool { gpu: gpu("A10G"), n_gpus: 6, ctx_budget: 4096.0,
                  batch_cap: Some(32) },
        SimPool { gpu: gpu("A100"), n_gpus: 4, ctx_budget: 8192.0,
                  batch_cap: None },
        SimPool { gpu: gpu("H100"), n_gpus: 4, ctx_budget: 65536.0,
                  batch_cap: None },
    ];
    let cfg = DesConfig {
        n_requests: 3_000,
        seed: 31,
        cap_window: Some(CapWindow { start_ms: 10_000.0, end_ms: 40_000.0,
                                     cap: 2 }),
        class_probs: Some(vec![0.6, 0.3, 0.1]),
        ..Default::default()
    };
    assert_sharded_matches(
        &w, pools,
        RoutingPolicy::Model { class_to_pool: vec![0, 1, 2] },
        cfg, "lmsys capped multi-pool",
    );
}

#[test]
fn sharded_matches_serial_with_dead_pool_censoring() {
    // Requests routed to a zero-GPU pool never drain: the unserved
    // counts, the per-pool attribution, and `max_unserved_wait` (global
    // horizon minus earliest unserved arrival) must merge exactly.
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 20.0);
    let pools = vec![
        SimPool { gpu: gpu("H100"), n_gpus: 4, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("H100"), n_gpus: 0, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    let router = RoutingPolicy::Length { b_short: 4096.0 };
    let cfg = DesConfig { n_requests: 3_000, seed: 43,
                          ..Default::default() };
    assert_sharded_matches(&w, pools.clone(), router.clone(), cfg.clone(),
                           "dead long pool");
    // And the backlog really exists (the test bites).
    let (r, _) = run_sharded(&pools, &router, &cfg, &w, 2, 997);
    assert!(r.n_unserved > 0, "expected a censored backlog");
    assert!(r.max_unserved_wait_ms > 0.0);
}

/// Assert a fault-scripted run is bit-identical across the serial
/// engine, the single-shard streamed executor, and every shard count —
/// from both arrival sources (borrowed stream and generator) and in
/// both metrics modes.
fn assert_faulted_sharded_matches(
    w: &WorkloadSpec,
    pools: Vec<SimPool>,
    router: RoutingPolicy,
    cfg: DesConfig,
    script: &FaultScript,
    label: &str,
) {
    let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
    for mode in [MetricsMode::Exact, MetricsMode::Streaming] {
        let cfg = DesConfig { metrics: mode, ..cfg.clone() };
        let stream_in = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_faults(script);
        let gen_in = SimInput::generated(&pools, &router, &cfg, w)
            .with_faults(script);
        let serial = summarize(Simulator::run_input(&stream_in).unwrap());
        let (r, _) = run_streamed_input(&gen_in, 1_024).unwrap();
        assert_eq!(
            summarize(r), serial,
            "{label} [{mode:?}]: streamed generator run diverged"
        );
        for shards in shard_counts() {
            let (r, _) = run_sharded_input(&gen_in, shards, 997).unwrap();
            assert_eq!(
                summarize(r), serial,
                "{label} [{mode:?} shards={shards}]: faulted sharded run \
                 diverged from serial (generator source)"
            );
            let (r, _) = run_sharded_input(&stream_in, shards, 997)
                .unwrap();
            assert_eq!(
                summarize(r), serial,
                "{label} [{mode:?} shards={shards}]: faulted sharded run \
                 diverged from serial (stream source)"
            );
        }
    }
}

#[test]
fn faulted_mid_peak_failure_is_bit_identical_across_shards() {
    // Two GPUs on the long pool fail through the diurnal peak; windowed
    // stats on. Every executor must agree on the degraded windows.
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 120.0)
        .with_nhpp(vec![(0.0, 40.0), (10_000.0, 200.0)], 20_000.0);
    let pools = vec![
        SimPool { gpu: gpu("A100"), n_gpus: 5, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("A100"), n_gpus: 5, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    let script = FaultScript {
        failures: vec![GpuFailure {
            pool: 1,
            n_gpus: 2,
            start_ms: 10_000.0,
            recover_ms: 18_000.0,
            warm_ms: 0.0,
            warm_factor: 1.0,
        }],
        stragglers: vec![],
    };
    assert_faulted_sharded_matches(
        &w, pools, RoutingPolicy::Length { b_short: 4096.0 },
        DesConfig { n_requests: 4_000, seed: 19,
                    window_ms: Some(5_000.0), ..Default::default() },
        &script, "mid-peak failure",
    );
}

#[test]
fn faulted_straggler_and_cold_start_is_bit_identical_across_shards() {
    // A straggler on the short pool overlapping a failure whose
    // recovery carries a cold-start inflation — the multiplicative
    // slowdown path and the recovery Drain, across every shard count.
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
    let pools = vec![
        SimPool { gpu: gpu("H100"), n_gpus: 3, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("H100"), n_gpus: 3, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    let script = FaultScript {
        failures: vec![GpuFailure {
            pool: 0,
            n_gpus: 1,
            start_ms: 5_000.0,
            recover_ms: 20_000.0,
            warm_ms: 3_000.0,
            warm_factor: 2.5,
        }],
        stragglers: vec![Straggler {
            pool: 1,
            n_gpus: 2,
            start_ms: 10_000.0,
            end_ms: 30_000.0,
            factor: 1.7,
        }],
    };
    let cfg = DesConfig { n_requests: 3_000, seed: 23,
                          ..Default::default() };
    assert_faulted_sharded_matches(
        &w, pools.clone(), RoutingPolicy::Length { b_short: 4096.0 },
        cfg.clone(), &script, "straggler + cold start",
    );
    // The script is not a no-op: faulted and clean runs differ.
    let router = RoutingPolicy::Length { b_short: 4096.0 };
    let clean_in = SimInput::generated(&pools, &router, &cfg, &w);
    let (clean, _) = run_sharded_input(&clean_in, 2, 997).unwrap();
    let faulted_in = SimInput::generated(&pools, &router, &cfg, &w)
        .with_faults(&script);
    let (faulted, _) = run_sharded_input(&faulted_in, 2, 997).unwrap();
    assert_ne!(summarize(clean), summarize(faulted),
               "fault script was a no-op");
}

/// Assert a closed-loop (retry + admission) run is bit-identical across
/// the serial engine, the streamed executor, and every shard count —
/// from both arrival sources, in both metrics modes, and at both an
/// aligned and a block-straddling chunk size. Retries draw backoff from
/// the id-keyed RETRY substream, so shard order must not matter.
fn assert_retry_sharded_matches(
    w: &WorkloadSpec,
    pools: Vec<SimPool>,
    router: RoutingPolicy,
    cfg: DesConfig,
    clients: &RetryConfig,
    label: &str,
) {
    let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
    for mode in [MetricsMode::Exact, MetricsMode::Streaming] {
        let cfg = DesConfig { metrics: mode, ..cfg.clone() };
        let stream_in = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_retries(clients);
        let gen_in = SimInput::generated(&pools, &router, &cfg, w)
            .with_retries(clients);
        let serial = summarize(Simulator::run_input(&stream_in).unwrap());
        for chunk in [1_024usize, 997] {
            let (r, _) = run_streamed_input(&gen_in, chunk).unwrap();
            assert_eq!(
                summarize(r), serial,
                "{label} [{mode:?} chunk={chunk}]: streamed closed-loop \
                 run diverged from serial"
            );
            for shards in shard_counts() {
                let (r, _) =
                    run_sharded_input(&gen_in, shards, chunk).unwrap();
                assert_eq!(
                    summarize(r), serial,
                    "{label} [{mode:?} shards={shards} chunk={chunk}]: \
                     closed-loop sharded run diverged (generator source)"
                );
                let (r, _) =
                    run_sharded_input(&stream_in, shards, chunk).unwrap();
                assert_eq!(
                    summarize(r), serial,
                    "{label} [{mode:?} shards={shards} chunk={chunk}]: \
                     closed-loop sharded run diverged (stream source)"
                );
            }
        }
    }
}

#[test]
fn closed_loop_retries_are_bit_identical_across_shards_and_chunks() {
    // A deliberately undersized fleet: waits blow past the 2 s client
    // timeout, retries amplify the load, the bounded queue sheds, and
    // the retry budget abandons — every closed-loop code path fires,
    // and every executor must agree on all of it bit for bit.
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 120.0);
    let pools = vec![
        SimPool { gpu: gpu("A100"), n_gpus: 1, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("A100"), n_gpus: 1, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    let router = RoutingPolicy::Length { b_short: 4096.0 };
    let cfg = DesConfig { n_requests: 3_000, seed: 23,
                          window_ms: Some(5_000.0), ..Default::default() };
    let clients = RetryConfig {
        retry: Some(RetrySpec {
            max_attempts: 3,
            timeout_ms: 2_000.0,
            backoff_base_ms: 100.0,
            backoff_cap_ms: 800.0,
        }),
        admission: Some(AdmissionSpec {
            max_queue_depth: 32,
            breaker_open_depth: 24,
            breaker_close_depth: 4,
        }),
    };
    assert_retry_sharded_matches(
        &w, pools.clone(), router.clone(), cfg.clone(), &clients,
        "closed-loop storm",
    );
    // The closed loop bites: retries amplify attempts beyond successes,
    // the bounded queue sheds, and every request ends terminally.
    let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
    let input = SimInput::stream(&pools, &router, &cfg, &sampled)
        .with_retries(&clients);
    let r = Simulator::run_input(&input).unwrap();
    assert!(r.n_attempts > r.overall.count, "no retries fired");
    assert!(r.n_shed > 0, "bounded queue never shed");
    assert_eq!(
        r.overall.count + r.n_abandoned + r.n_shed + r.n_unserved,
        cfg.n_requests,
        "closed-loop conservation"
    );
}

/// Assert a memory-bounded run is bit-identical across the serial
/// engine, the all-events reference heap, the streamed executor, and
/// every shard count — from both arrival sources, in both metrics
/// modes, and at both an aligned and a block-straddling chunk size.
/// The KV counters (preemptions, stall time, peak/mean utilization,
/// per-window preempted series) are part of the compared summary.
fn assert_memory_sharded_matches(
    w: &WorkloadSpec,
    pools: Vec<SimPool>,
    router: RoutingPolicy,
    cfg: DesConfig,
    memory: &MemoryConfig,
    label: &str,
) {
    let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
    for mode in [MetricsMode::Exact, MetricsMode::Streaming] {
        let cfg = DesConfig { metrics: mode, ..cfg.clone() };
        let stream_in = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_memory(memory);
        let gen_in = SimInput::generated(&pools, &router, &cfg, w)
            .with_memory(memory);
        let serial = summarize(Simulator::run_input(&stream_in).unwrap());
        let reference =
            summarize(run_reference_input(&stream_in).unwrap());
        assert_eq!(
            reference, serial,
            "{label} [{mode:?}]: reference heap diverged under memory"
        );
        for chunk in [1_024usize, 997] {
            let (r, _) = run_streamed_input(&gen_in, chunk).unwrap();
            assert_eq!(
                summarize(r), serial,
                "{label} [{mode:?} chunk={chunk}]: streamed \
                 memory-bounded run diverged from serial"
            );
            for shards in shard_counts() {
                let (r, _) =
                    run_sharded_input(&gen_in, shards, chunk).unwrap();
                assert_eq!(
                    summarize(r), serial,
                    "{label} [{mode:?} shards={shards} chunk={chunk}]: \
                     memory-bounded sharded run diverged (generator \
                     source)"
                );
                let (r, _) =
                    run_sharded_input(&stream_in, shards, chunk).unwrap();
                assert_eq!(
                    summarize(r), serial,
                    "{label} [{mode:?} shards={shards} chunk={chunk}]: \
                     memory-bounded sharded run diverged (stream source)"
                );
            }
        }
    }
}

fn tight_memory(policy: PolicyKind) -> MemoryConfig {
    // 9,000 token-slots per A100 (80 GB HBM, 71 GB weights, 1 MB per
    // token): barely above one max-context request, so admission
    // pressure and preemption both fire at moderate load.
    MemoryConfig {
        spec: MemorySpec {
            hbm_gb: None,
            weights_gb: 71.0,
            bytes_per_token: 1e6,
        },
        policy,
        swap_out_ms: 2.0,
        swap_in_ms: 4.0,
    }
}

#[test]
fn memory_bounded_runs_are_bit_identical_across_shards_and_chunks() {
    // A KV-starved fleet under every preemption policy: admission
    // blocking, evict-recompute requeues, and evict-swap stalls all
    // fire, and every executor must agree on all of it bit for bit —
    // including the new preemption/utilization counters.
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 60.0);
    let pools = vec![
        SimPool { gpu: gpu("A100"), n_gpus: 2, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("A100"), n_gpus: 2, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    let router = RoutingPolicy::Length { b_short: 4096.0 };
    let cfg = DesConfig { n_requests: 3_000, seed: 37,
                          window_ms: Some(5_000.0), ..Default::default() };
    for policy in [
        PolicyKind::None,
        PolicyKind::EvictRecompute,
        PolicyKind::EvictSwap,
    ] {
        assert_memory_sharded_matches(
            &w, pools.clone(), router.clone(), cfg.clone(),
            &tight_memory(policy), &format!("kv-bounded {policy:?}"),
        );
    }
    // The memory model bites (it is not a no-op against the open
    // loop), preemptions really fire, and accounting conserves: every
    // request either completes or is left in flight at stream end.
    let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
    let open_in = SimInput::stream(&pools, &router, &cfg, &sampled);
    let open = summarize(Simulator::run_input(&open_in).unwrap());
    let mem_in = SimInput::stream(&pools, &router, &cfg, &sampled)
        .with_memory(&tight_memory(PolicyKind::EvictRecompute));
    let r = Simulator::run_input(&mem_in).unwrap();
    assert!(r.n_preempted > 0, "tight memory never preempted");
    assert!(r.preempt_stall_ms > 0.0, "preemptions cost no time");
    assert!(r.kv_peak_util > 0.5, "pool never came under KV pressure");
    assert_eq!(
        r.overall.count + r.n_unserved,
        cfg.n_requests,
        "memory-bounded conservation"
    );
    assert_ne!(summarize(r), open, "memory model was a no-op");
}

#[test]
fn chunk_size_never_changes_results() {
    // The consumer-side chunk size is a pure batching knob: any size,
    // aligned or straddling GEN_BLOCK, yields the identical result.
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 120.0);
    let pools = vec![
        SimPool { gpu: gpu("A100"), n_gpus: 4, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("A100"), n_gpus: 4, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    let router = RoutingPolicy::Length { b_short: 4096.0 };
    let cfg = DesConfig {
        n_requests: 10_000,
        seed: 7,
        metrics: MetricsMode::Streaming,
        ..Default::default()
    };
    let (base, _) = run_streamed(&pools, &router, &cfg, &w, 8_192);
    let base = summarize(base);
    for chunk in [1usize, 100, 8_191, 8_193, 100_000] {
        let (r, _) = run_streamed(&pools, &router, &cfg, &w, chunk);
        assert_eq!(summarize(r), base, "chunk={chunk}");
    }
}

#[test]
fn arena_memory_stays_flat_as_request_count_grows() {
    // The bounded-memory claim, measured at the arena: quadrupling the
    // stream must not grow the in-flight high-water mark with it (the
    // fleet is stable, so in-flight depends on load, not run length).
    // CI additionally gates whole-process RSS on the scale scenario.
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
    let pools = vec![
        SimPool { gpu: gpu("A100"), n_gpus: 4, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("A100"), n_gpus: 4, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    let router = RoutingPolicy::Length { b_short: 4096.0 };
    let peak_at = |n: usize| {
        let cfg = DesConfig {
            n_requests: n,
            metrics: MetricsMode::Streaming,
            ..Default::default()
        };
        let (_, stats) = run_streamed(&pools, &router, &cfg, &w, 4_096);
        stats.arena_peak_slots
    };
    let small = peak_at(20_000);
    let big = peak_at(80_000);
    assert!(small > 0);
    assert!(
        big <= small.max(64) * 3,
        "arena peak grew with the stream: {small} -> {big}"
    );
    assert!(big < 20_000 / 4, "arena peak {big} is not O(in-flight)");
}
