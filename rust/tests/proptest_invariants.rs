//! Property-based tests over coordinator invariants (routing, batching,
//! queueing state). The `proptest` crate is unavailable in the offline
//! build, so properties are checked over seeded PCG64-driven random cases
//! (200+ cases per property) with failing inputs printed for replay.

use fleet_sim::des::engine::{DesConfig, SimPool, Simulator};
use fleet_sim::des::input::SimInput;
use fleet_sim::des::memory::{MemoryConfig, MemorySpec, PolicyKind};
use fleet_sim::des::retry::{backoff_ms, RetrySpec};
use fleet_sim::gpu::catalog::GpuCatalog;
use fleet_sim::gpu::profile::GpuProfile;
use fleet_sim::queueing::erlang::erlang_c;
use fleet_sim::queueing::kimura;
use fleet_sim::router::{RouteRequest, RoutingPolicy};
use fleet_sim::workload::cdf::EmpiricalCdf;
use fleet_sim::workload::rng::Pcg64;
use fleet_sim::workload::spec::WorkloadSpec;

fn random_cdf(rng: &mut Pcg64) -> EmpiricalCdf {
    let n = 3 + rng.below(10) as usize;
    let mut len = 32.0 + rng.uniform() * 200.0;
    let mut prob = 0.05 + rng.uniform() * 0.3;
    let mut pts = Vec::new();
    for i in 0..n {
        pts.push((len, if i == n - 1 { 1.0 } else { prob }));
        len *= 1.5 + rng.uniform() * 3.0;
        prob += (1.0 - prob) * (0.2 + rng.uniform() * 0.5);
        if prob >= 0.9999 {
            prob = 0.9999;
        }
    }
    pts.last_mut().unwrap().1 = 1.0;
    EmpiricalCdf::new(pts).unwrap()
}

fn random_gpu(rng: &mut Pcg64) -> GpuProfile {
    let cat = GpuCatalog::standard();
    let names = ["A10G", "A100", "H100"];
    cat.get(names[rng.below(3) as usize]).unwrap().clone()
}

/// Property: every router maps every request to a pool within range, and
/// LengthRouter is consistent with the threshold.
#[test]
fn prop_router_decisions_in_range() {
    let mut rng = Pcg64::new(1001, 0);
    for case in 0..300 {
        let b = 256.0 + rng.uniform() * 30_000.0;
        let gamma = 1.0 + rng.uniform() * 2.0;
        let policies = [
            RoutingPolicy::Length { b_short: b },
            RoutingPolicy::CompressAndRoute { b_short: b, gamma },
            RoutingPolicy::Random { n_pools: 1 + rng.below(6) as usize },
        ];
        for policy in &policies {
            let req = RouteRequest {
                l_in: 1.0 + rng.uniform() * 60_000.0,
                l_out: 1.0 + rng.uniform() * 4_000.0,
                class: 0,
            };
            let d = policy.route(req, &mut rng);
            assert!(d.pool < policy.n_pools(), "case {case}: {policy:?}");
            if let RoutingPolicy::Length { b_short } = policy {
                let want = usize::from(req.total() > *b_short);
                assert_eq!(d.pool, want, "case {case}: length routing");
            }
            if let RoutingPolicy::CompressAndRoute { b_short, .. } = policy {
                if d.pool == 0 {
                    assert!(d.request.total() <= *b_short + 1e-9,
                            "case {case}: compressed request too long");
                }
                assert_eq!(d.request.l_out, req.l_out,
                           "case {case}: completion must be preserved");
            }
        }
    }
}

/// Property: the DES conserves requests and produces non-negative,
/// ordered latencies (wait <= ttft <= wait + hold = e2e ... ttft <= e2e)
/// for arbitrary workloads, pool layouts, and loads.
#[test]
fn prop_des_conserves_and_orders() {
    let mut rng = Pcg64::new(2002, 0);
    for case in 0..25 {
        let cdf = random_cdf(&mut rng);
        let max_len = cdf.max_len();
        let w = WorkloadSpec::new(
            format!("case{case}"),
            cdf,
            0.3 + rng.uniform() * 0.6,
            1.0 + rng.uniform() * 150.0,
        );
        let b = max_len * (0.1 + rng.uniform() * 0.8);
        let gpu_s = random_gpu(&mut rng);
        let gpu_l = random_gpu(&mut rng);
        let pools = vec![
            SimPool {
                gpu: gpu_s,
                n_gpus: 1 + rng.below(6) as usize,
                ctx_budget: b,
                batch_cap: None,
            },
            SimPool {
                gpu: gpu_l,
                n_gpus: 1 + rng.below(6) as usize,
                ctx_budget: max_len,
                batch_cap: None,
            },
        ];
        let n = 1_500;
        let sim = Simulator::new(
            w,
            pools,
            RoutingPolicy::Length { b_short: b },
            DesConfig {
                n_requests: n,
                seed: 3000 + case,
                ..Default::default()
            },
        );
        let r = sim.run();
        assert_eq!(r.overall.count, n, "case {case}: lost requests");
        let pool_sum: usize = r.per_pool.iter().map(|p| p.stats.count).sum();
        assert_eq!(pool_sum, n, "case {case}: pool counts");
        let waits = r.overall.wait.values();
        let ttfts = r.overall.ttft.values();
        let e2es = r.overall.e2e.values();
        for i in 0..n {
            assert!(waits[i] >= 0.0, "case {case}: negative wait");
            assert!(ttfts[i] >= waits[i], "case {case}: ttft < wait");
            assert!(e2es[i] >= waits[i], "case {case}: e2e < wait");
            assert!(e2es[i] + 1e-9 >= ttfts[i] - 1e-6
                    || ttfts[i] - e2es[i] < 1e6,
                    "case {case}: ordering");
        }
        for p in &r.per_pool {
            assert!((0.0..=1.0 + 1e-9).contains(&p.utilization),
                    "case {case}: utilization {}", p.utilization);
        }
    }
}

/// Property: DES with more GPUs never has (statistically) worse P99 wait.
#[test]
fn prop_more_gpus_never_hurt() {
    let mut rng = Pcg64::new(3003, 0);
    for case in 0..10 {
        let cdf = random_cdf(&mut rng);
        let max_len = cdf.max_len();
        let w = WorkloadSpec::new(
            format!("case{case}"),
            cdf,
            0.5,
            20.0 + rng.uniform() * 80.0,
        );
        let gpu = random_gpu(&mut rng);
        let small = 1 + rng.below(3) as usize;
        let big = small * 2 + 2;
        let mk = |n_gpus| {
            let sim = Simulator::new(
                w.clone(),
                vec![SimPool {
                    gpu: gpu.clone(),
                    n_gpus,
                    ctx_budget: max_len,
                    batch_cap: None,
                }],
                RoutingPolicy::Random { n_pools: 1 },
                DesConfig { n_requests: 3_000, seed: 7000 + case,
                            ..Default::default() },
            );
            let mut r = sim.run();
            r.overall.wait.p99()
        };
        let w_small = mk(small);
        let w_big = mk(big);
        assert!(
            w_big <= w_small + 1.0,
            "case {case}: {big} GPUs wait {w_big} > {small} GPUs wait {w_small}"
        );
    }
}

/// Property: Erlang-C and Kimura invariants over random parameters.
#[test]
fn prop_queueing_bounds() {
    let mut rng = Pcg64::new(4004, 0);
    for case in 0..500 {
        let rho = rng.uniform() * 1.2;
        let c = 1 + rng.below(512) as usize;
        let v = erlang_c(rho, c);
        assert!((0.0..=1.0).contains(&v), "case {case}: C={v}");
        let es = 1.0 + rng.uniform() * 5_000.0;
        let cs2 = rng.uniform() * 40.0;
        let w = kimura::w99(rho, c, es, cs2);
        if rho < 1.0 {
            assert!(w >= 0.0 && w.is_finite(), "case {case}: w99={w}");
            // Wait grows with variance.
            let w_higher = kimura::w99(rho, c, es, cs2 + 1.0);
            assert!(w_higher >= w, "case {case}");
        } else {
            assert!(w.is_infinite(), "case {case}");
        }
    }
}

/// Property: CDF quantile/cdf round-trip and histogram mass conservation
/// for arbitrary CDFs.
#[test]
fn prop_cdf_roundtrip() {
    let mut rng = Pcg64::new(5005, 0);
    for case in 0..200 {
        let cdf = random_cdf(&mut rng);
        for _ in 0..20 {
            let q = rng.uniform();
            let l = cdf.quantile(q);
            let back = cdf.cdf(l);
            assert!(back + 1e-6 >= q, "case {case}: F(F^-1({q})) = {back}");
        }
        let (probs, lens) = cdf.histogram(64);
        let total: f64 = probs.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "case {case}: mass {total}");
        assert!(lens.windows(2).all(|w| w[0] < w[1]), "case {case}");
        assert!(probs.iter().all(|&p| p >= 0.0), "case {case}");
    }
}

/// Property: `backoff_ms` is a pure function of
/// `(seed, global_id, attempt, spec)` — re-evaluating it yields the
/// bit-identical delay (this is what makes retry schedules independent
/// of engine, shard count, and event interleaving) — and the jittered
/// delay always lands in `[0.5, 1.5)` times the capped exponential
/// nominal, for arbitrary seeds, ids, attempts (including the 2^63
/// shift-saturation range), and specs.
#[test]
fn prop_backoff_is_pure_and_jitter_bounded() {
    let mut rng = Pcg64::new(9009, 0);
    for case in 0..300 {
        let base = 1.0 + rng.uniform() * 2_000.0;
        let spec = RetrySpec {
            max_attempts: 1 + rng.below(8) as u32,
            timeout_ms: 100.0 + rng.uniform() * 10_000.0,
            backoff_base_ms: base,
            backoff_cap_ms: base * (1.0 + rng.uniform() * 16.0),
        };
        let seed = rng.below(u64::MAX);
        let gid = rng.below(u64::MAX);
        let attempt = 1 + rng.below(80) as u32;
        let d = backoff_ms(seed, gid, attempt, &spec);
        let again = backoff_ms(seed, gid, attempt, &spec);
        assert_eq!(
            d.to_bits(),
            again.to_bits(),
            "case {case}: backoff_ms is not pure"
        );
        let exp = attempt.saturating_sub(1).min(63);
        let nominal = (spec.backoff_base_ms * (1u64 << exp) as f64)
            .min(spec.backoff_cap_ms);
        assert!(
            (0.5 * nominal..1.5 * nominal).contains(&d),
            "case {case}: delay {d} outside [0.5, 1.5) x nominal \
             {nominal} (attempt {attempt})"
        );
    }
}

/// Property: with KV memory attached, resident occupancy never exceeds
/// pool capacity — the recorded per-pool peak utilization stays <= 1
/// under every preemption policy (blocking reserves peak footprints;
/// the evict policies preempt exactly at the projected crossing) — and
/// accounting conserves requests, for arbitrary workloads, layouts,
/// and capacities.
#[test]
fn prop_kv_occupancy_never_exceeds_capacity() {
    let mut rng = Pcg64::new(7007, 0);
    for case in 0..20 {
        let cdf = random_cdf(&mut rng);
        let max_len = cdf.max_len();
        let w = WorkloadSpec::new(
            format!("case{case}"),
            cdf,
            0.3 + rng.uniform() * 0.6,
            5.0 + rng.uniform() * 120.0,
        );
        let b = max_len * (0.2 + rng.uniform() * 0.6);
        let pools = vec![
            SimPool {
                gpu: random_gpu(&mut rng),
                n_gpus: 1 + rng.below(4) as usize,
                ctx_budget: b,
                batch_cap: None,
            },
            SimPool {
                gpu: random_gpu(&mut rng),
                n_gpus: 1 + rng.below(4) as usize,
                ctx_budget: max_len,
                batch_cap: None,
            },
        ];
        // Capacity between one and a handful of max-context requests
        // per GPU: tight enough to come under pressure, always valid
        // (the +2 margin keeps floor(capacity) above every ctx budget).
        let cap_tokens = (max_len + 2.0)
            * (1.0 + rng.below(4) as f64)
            + rng.uniform() * max_len;
        let policy = match rng.below(3) {
            0 => PolicyKind::None,
            1 => PolicyKind::EvictRecompute,
            _ => PolicyKind::EvictSwap,
        };
        let mem = MemoryConfig {
            spec: MemorySpec {
                hbm_gb: Some(80.0),
                weights_gb: 0.0,
                bytes_per_token: 80.0e9 / cap_tokens,
            },
            policy,
            swap_out_ms: rng.uniform() * 5.0,
            swap_in_ms: rng.uniform() * 5.0,
        };
        let n = 1_200;
        let cfg = DesConfig {
            n_requests: n,
            seed: 9_100 + case,
            ..Default::default()
        };
        let router = RoutingPolicy::Length { b_short: b };
        let sampled = w.sample_requests(n, cfg.seed);
        let input = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_memory(&mem);
        let r = Simulator::run_input(&input).unwrap();
        assert_eq!(
            r.overall.count + r.n_unserved,
            n,
            "case {case} ({policy:?}): conservation"
        );
        for (i, p) in r.per_pool.iter().enumerate() {
            assert!(
                p.kv_peak_util <= 1.0 + 1e-6,
                "case {case} ({policy:?}): pool {i} KV peak {} > capacity",
                p.kv_peak_util
            );
            assert!(
                (0.0..=p.kv_peak_util + 1e-9).contains(&p.kv_mean_util),
                "case {case} ({policy:?}): pool {i} mean {} vs peak {}",
                p.kv_mean_util,
                p.kv_peak_util
            );
        }
        if policy == PolicyKind::None {
            assert_eq!(
                r.n_preempted, 0,
                "case {case}: the blocking policy must never preempt"
            );
        }
    }
}

/// Property: evict-recompute victims always terminate. LIFO
/// newest-victim selection means an evicted request can only be
/// displaced by requests admitted after its own re-admission, so every
/// request either completes or is still waiting when the stream ends —
/// none is lost to an eviction loop — across a sweep of loads.
#[test]
fn prop_evict_recompute_victims_terminate() {
    let mem = MemoryConfig {
        spec: MemorySpec {
            hbm_gb: None,
            weights_gb: 71.0,
            bytes_per_token: 1e6,
        },
        policy: PolicyKind::EvictRecompute,
        swap_out_ms: 0.0,
        swap_in_ms: 0.0,
    };
    let gpu = GpuCatalog::standard().get("A100").unwrap().clone();
    let pools = vec![
        SimPool { gpu: gpu.clone(), n_gpus: 2, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu, n_gpus: 2, ctx_budget: 8192.0, batch_cap: None },
    ];
    let router = RoutingPolicy::Length { b_short: 4096.0 };
    let mut total_preempted = 0usize;
    for case in 0..12 {
        let lambda = 20.0 + 15.0 * case as f64;
        let w = WorkloadSpec::builtin(
            fleet_sim::workload::spec::BuiltinTrace::Azure,
            lambda,
        );
        let n = 1_500;
        let cfg = DesConfig {
            n_requests: n,
            seed: 9_500 + case,
            ..Default::default()
        };
        let sampled = w.sample_requests(n, cfg.seed);
        let input = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_memory(&mem);
        let r = Simulator::run_input(&input).unwrap();
        assert_eq!(
            r.overall.count + r.n_unserved,
            n,
            "case {case} (lambda {lambda}): a victim vanished"
        );
        total_preempted += r.n_preempted;
    }
    assert!(total_preempted > 0, "the sweep never triggered eviction");
}

/// Property: batch caps only ever reduce DES slot capacity, and capped
/// pools never admit beyond the cap (checked via utilization ceiling).
#[test]
fn prop_batch_cap_monotone() {
    let mut rng = Pcg64::new(6006, 0);
    for case in 0..10 {
        let gpu = random_gpu(&mut rng);
        let ctx = 4096.0 * (1.0 + rng.below(8) as f64);
        let kv = gpu.n_eff(ctx) as u32;
        let cap = 1 + rng.below(kv as u64) as u32;
        let w = WorkloadSpec::builtin(
            fleet_sim::workload::spec::BuiltinTrace::Azure,
            30.0 + rng.uniform() * 100.0,
        );
        let mk = |batch_cap| {
            let sim = Simulator::new(
                w.clone(),
                vec![SimPool { gpu: gpu.clone(), n_gpus: 2, ctx_budget: ctx,
                               batch_cap }],
                RoutingPolicy::Random { n_pools: 1 },
                DesConfig { n_requests: 2_000, seed: 8000 + case,
                            ..Default::default() },
            );
            sim.run()
        };
        let capped = mk(Some(cap));
        assert_eq!(capped.per_pool[0].slots_per_gpu, cap.min(kv).max(1));
        let uncapped = mk(None);
        assert_eq!(uncapped.per_pool[0].slots_per_gpu, kv.max(1));
        // Tighter caps cannot reduce waiting time.
        let mut cw = capped.overall.wait.clone();
        let mut uw = uncapped.overall.wait.clone();
        assert!(cw.p99() + 1e-6 >= uw.p99() - 1e-6,
                "case {case}: cap {cap} wait {} < uncapped {}",
                cw.p99(), uw.p99());
    }
}
