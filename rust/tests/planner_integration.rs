//! Cross-module integration: workload -> optimizer -> DES, and the
//! consistency between analytics and simulation the paper's two-phase
//! design relies on (§3.1, §3.2 "Model fidelity").

use fleet_sim::des::engine::{DesConfig, SimPool, Simulator};
use fleet_sim::gpu::catalog::GpuCatalog;
use fleet_sim::optimizer::analytic::NativeSweep;
use fleet_sim::optimizer::planner::{plan_pools, FleetOptimizer};
use fleet_sim::queueing::mgc::{analyze_pool, PoolSpec, WorkloadHist};
use fleet_sim::router::RoutingPolicy;
use fleet_sim::workload::spec::{BuiltinTrace, WorkloadSpec};

/// §3.2: "for chatbot workloads (low Cs²) the Kimura model is conservative
/// vs DES: it over-predicts P99 TTFT" — verify on Azure at moderate load.
#[test]
fn kimura_is_conservative_on_chatbot_workloads() {
    let cat = GpuCatalog::standard();
    let gpu = cat.get("H100").unwrap().clone();
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
    let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
    let n_gpus = 9;
    let spec = PoolSpec { gpu: gpu.clone(), n_gpus, ctx_budget: 8192.0 };
    let a = analyze_pool(&hist, 0.0, 8192.0, w.lambda_per_ms(), &spec);
    assert!(a.rho < 0.85, "test setup: want moderate load, rho = {}", a.rho);

    let sim = Simulator::new(
        w,
        vec![SimPool { gpu, n_gpus, ctx_budget: 8192.0, batch_cap: None }],
        RoutingPolicy::Random { n_pools: 1 },
        DesConfig { n_requests: 20_000, seed: 9, ..Default::default() },
    );
    let mut r = sim.run();
    let des_p99 = r.overall.p99_ttft();
    // Conservative: analytic >= DES (with slack for the service-model
    // differences); and both in the same order of magnitude.
    assert!(
        a.ttft99_ms >= des_p99 * 0.8,
        "analytic {} should not wildly underestimate DES {}",
        a.ttft99_ms,
        des_p99
    );
    assert!(a.ttft99_ms < des_p99 * 10.0 + 100.0);
}

/// §4.2 mechanism (Puzzle 2): an agent fleet at ~30% utilization with zero
/// queue wait still fails its SLO — the failure is giant-prompt service,
/// invisible to Erlang-C (Eq. 2) — and adding GPUs does not fix it. A
/// two-pool split protects the short traffic.
#[test]
fn agent_fleet_fails_slo_at_low_utilization() {
    let cat = GpuCatalog::standard();
    let gpu = cat.get("H100").unwrap().clone();
    let w = WorkloadSpec::builtin(BuiltinTrace::Agent, 20.0);
    let ctx = w.cdf.max_len();
    let slo = 1000.0;
    let run_homo = |n_gpus: usize| {
        let sim = Simulator::new(
            w.clone(),
            vec![SimPool { gpu: gpu.clone(), n_gpus, ctx_budget: ctx,
                           batch_cap: None }],
            RoutingPolicy::Random { n_pools: 1 },
            DesConfig { n_requests: 15_000, seed: 2, ..Default::default() },
        );
        sim.run()
    };
    let r64 = run_homo(64);
    let mut s64 = r64.overall.clone();
    assert!(r64.per_pool[0].utilization < 0.45,
            "util = {}", r64.per_pool[0].utilization);
    assert!(s64.wait.p99() < 10.0, "queue wait should read ~zero");
    assert!(s64.p99_ttft() > slo,
            "fleet must fail SLO anyway: {}", s64.p99_ttft());
    // Erlang-C / Kimura on the same pool sees no queueing problem.
    let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
    let a = analyze_pool(&hist, 0.0, 1e9, w.lambda_per_ms(),
                         &PoolSpec { gpu: gpu.clone(), n_gpus: 64,
                                     ctx_budget: ctx });
    assert!(a.w99_ms < 10.0, "Eq. 2 says the queue is fine: {}", a.w99_ms);
    // Doubling the fleet does not fix it (Insight: adding GPUs cannot buy
    // back prefill time).
    let mut s128 = run_homo(128).overall.clone();
    assert!(s128.p99_ttft() > slo, "128 GPUs: {}", s128.p99_ttft());
    // Two-pool split: short requests are isolated and fast.
    let pools = vec![
        SimPool { gpu: gpu.clone(), n_gpus: 4, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu.clone(), n_gpus: 60, ctx_budget: ctx,
                  batch_cap: None },
    ];
    let sim = Simulator::new(
        w.clone(), pools, RoutingPolicy::Length { b_short: 4096.0 },
        DesConfig { n_requests: 15_000, seed: 2, ..Default::default() },
    );
    let mut r = sim.run();
    let short_p99 = r.per_pool[0].stats.ttft.p99();
    assert!(short_p99 < 100.0,
            "short pool must be protected: {short_p99}");
}

/// The planner's chosen fleet must actually pass its own DES check when
/// re-simulated with a different seed (no seed overfitting).
#[test]
fn chosen_plan_is_robust_across_seeds() {
    let mut opt = FleetOptimizer::new(GpuCatalog::standard(), 500.0);
    opt.des.n_requests = 8_000;
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
    let plan = opt.plan(&w);
    let chosen = plan.chosen.expect("plan found");
    let (pools, router) = plan_pools(&chosen.candidate);
    for seed in [101, 202, 303] {
        let sim = Simulator::new(
            w.clone(),
            pools.clone(),
            router.clone(),
            DesConfig { n_requests: 8_000, seed, ..Default::default() },
        );
        let mut r = sim.run();
        let p99 = r.overall.p99_ttft();
        assert!(
            p99 <= 500.0 * 1.3,
            "seed {seed}: P99 {p99} blows the SLO by more than 30%"
        );
    }
}

/// Phase-1 ranking and Phase-2 verification agree on feasibility for the
/// top candidates on a low-variance workload (the regime where the paper
/// says the analytic model is trustworthy).
#[test]
fn phase1_winners_pass_phase2_on_azure() {
    let mut opt = FleetOptimizer::new(GpuCatalog::standard(), 500.0);
    opt.des.n_requests = 6_000;
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
    let (cands, _res, ranked) = opt.phase1(&w, &NativeSweep).unwrap();
    assert!(!ranked.is_empty());
    let mut passes = 0;
    let k = ranked.len().min(5);
    for &i in ranked.iter().take(k) {
        if opt.verify(&w, &cands[i]).passed {
            passes += 1;
        }
    }
    assert!(passes >= k - 1, "only {passes}/{k} phase-1 winners pass DES");
}

/// End-to-end determinism: the whole two-phase plan is reproducible.
#[test]
fn planning_is_deterministic() {
    let mk = || {
        let mut opt = FleetOptimizer::new(GpuCatalog::standard(), 1000.0);
        opt.des.n_requests = 4_000;
        let w = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 50.0);
        opt.plan(&w)
    };
    let (a, b) = (mk(), mk());
    let (ca, cb) = (a.chosen.unwrap(), b.chosen.unwrap());
    assert_eq!(ca.candidate.label(), cb.candidate.label());
    assert_eq!(
        ca.verification.unwrap().p99_ttft_ms,
        cb.verification.unwrap().p99_ttft_ms
    );
}
