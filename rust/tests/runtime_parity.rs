//! Integration: the AOT-compiled JAX/Pallas Phase-1 evaluator must agree
//! with the pure-rust NativeSweep — the three layers compose.
//!
//! Requires `make artifacts` (skips with a message if missing, so plain
//! `cargo test` works in a fresh checkout; `make test` always builds the
//! artifacts first).

use std::path::PathBuf;

use fleet_sim::gpu::catalog::GpuCatalog;
use fleet_sim::optimizer::analytic::{NativeSweep, SweepEval};
use fleet_sim::optimizer::candidates::{generate, GenOptions};
use fleet_sim::runtime::sweep::AotSweep;
use fleet_sim::workload::spec::{BuiltinTrace, WorkloadSpec};

fn artifacts_dir() -> PathBuf {
    let mut d = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    d.push("artifacts");
    d
}

fn load_aot() -> Option<AotSweep> {
    let dir = artifacts_dir();
    if !dir.join("sweep.hlo.txt").exists() {
        eprintln!(
            "SKIP: {} missing — run `make artifacts` first",
            dir.join("sweep.hlo.txt").display()
        );
        return None;
    }
    if cfg!(not(all(feature = "pjrt", feature = "xla"))) {
        // Artifacts are present but this build carries a stub (no XLA
        // client linked): parity cannot be checked, which is a skip, not
        // a failure.
        eprintln!(
            "SKIP: built without the `xla` feature — rebuild with \
             `--features xla` (and the xla crate) to run the AOT parity \
             checks"
        );
        return None;
    }
    Some(AotSweep::load(&dir).expect("artifact loads and compiles"))
}

fn assert_close(a: f64, b: f64, rel: f64, abs: f64, what: &str) {
    if a.is_infinite() || b.is_infinite() {
        // Native uses f64 inf for unstable lanes; the f32 artifact may
        // saturate to a large finite value. Both must be enormous.
        assert!(
            a > 1e6 && b > 1e6,
            "{what}: inf mismatch native={a} aot={b}"
        );
        return;
    }
    let tol = abs + rel * a.abs().max(b.abs());
    assert!((a - b).abs() <= tol, "{what}: native={a} aot={b}");
}

#[test]
fn aot_matches_native_on_all_builtin_workloads() {
    let Some(aot) = load_aot() else { return };
    let catalog = GpuCatalog::standard();
    let mut opts = GenOptions::default();
    opts.allow_mixed = true;
    opts.headroom = 3;
    for (trace, lam, slo) in [
        (BuiltinTrace::Lmsys, 100.0, 500.0),
        (BuiltinTrace::Azure, 100.0, 500.0),
        (BuiltinTrace::Agent, 20.0, 1000.0),
    ] {
        let w = WorkloadSpec::builtin(trace, lam);
        let cands = generate(&w, &catalog, &opts);
        assert!(!cands.is_empty());
        let native = NativeSweep.eval(&w, &cands, slo).unwrap();
        let aot_res = aot.eval(&w, &cands, slo).unwrap();
        assert_eq!(native.len(), aot_res.len());
        let mut feasible_agree = 0;
        for (i, (nv, av)) in native.iter().zip(&aot_res).enumerate() {
            let what =
                format!("{} cand {i} ({})", trace.name(), cands[i].label());
            assert_close(nv.rho_s, av.rho_s, 2e-3, 1e-4,
                         &format!("{what} rho_s"));
            assert_close(nv.rho_l, av.rho_l, 2e-3, 1e-4,
                         &format!("{what} rho_l"));
            assert_close(nv.cost_yr, av.cost_yr, 1e-4, 1.0,
                         &format!("{what} cost"));
            assert_close(nv.ttft99_s, av.ttft99_s, 5e-3, 0.5,
                         &format!("{what} ttft_s"));
            assert_close(nv.ttft99_l, av.ttft99_l, 5e-3, 0.5,
                         &format!("{what} ttft_l"));
            if nv.feasible == av.feasible {
                feasible_agree += 1;
            } else {
                // f32-vs-f64 rounding at an SLO/rho boundary may flip a
                // candidate; it must be a genuine boundary case.
                let near = (nv.rho_s - 0.85).abs() < 2e-3
                    || (nv.rho_l - 0.85).abs() < 2e-3
                    || (nv.ttft99_s - slo).abs() < 2.0
                    || (nv.ttft99_l - slo).abs() < 2.0;
                assert!(near, "{what}: feasibility flip away from boundary \
                               (native {nv:?} aot {av:?})");
            }
        }
        // At least 99% exact feasibility agreement.
        assert!(
            feasible_agree * 100 >= native.len() * 99,
            "{}: only {feasible_agree}/{} feasibility matches",
            trace.name(),
            native.len()
        );
    }
}

#[test]
fn aot_handles_multi_batch_sweeps() {
    let Some(aot) = load_aot() else { return };
    // More candidates than one artifact batch (N_CAND = 4096) by
    // repeating the grid; results must be consistent across chunks.
    let catalog = GpuCatalog::standard();
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
    let mut opts = GenOptions::default();
    opts.allow_mixed = true;
    opts.headroom = 3;
    let base = generate(&w, &catalog, &opts);
    let mut cands = Vec::new();
    while cands.len() <= 4096 {
        cands.extend(base.iter().cloned());
    }
    let res = aot.eval(&w, &cands, 500.0).unwrap();
    assert_eq!(res.len(), cands.len());
    // Repetition i of candidate j must equal repetition 0.
    for (i, r) in res.iter().enumerate() {
        let r0 = &res[i % base.len()];
        assert_eq!(r.feasible, r0.feasible, "cand {i}");
        assert!((r.cost_yr - r0.cost_yr).abs() < 1.0);
    }
}

#[test]
fn aot_planner_end_to_end() {
    let Some(aot) = load_aot() else { return };
    use fleet_sim::optimizer::planner::FleetOptimizer;
    let mut opt = FleetOptimizer::new(GpuCatalog::standard(), 500.0);
    opt.des.n_requests = 4000;
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
    let plan_aot = opt.plan_with(&w, &aot).unwrap();
    let plan_native = opt.plan(&w);
    assert_eq!(plan_aot.backend, "aot-pjrt");
    let a = plan_aot.chosen.expect("aot plan found");
    let n = plan_native.chosen.expect("native plan found");
    // Same winner cost (the exact candidate may tie-break differently).
    assert!((a.analytic.cost_yr - n.analytic.cost_yr).abs() < 1.0,
            "aot {} vs native {}", a.analytic.cost_yr, n.analytic.cost_yr);
}
