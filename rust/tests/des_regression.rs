//! DES regression tests for the merge-consumed-arrivals fast path.
//!
//! The production engine keeps only completions (and cap-window drains) in
//! the event heap and merge-consumes the time-sorted arrival vector
//! ("perf pass iteration 3"). This file re-implements the original
//! all-events-in-the-heap semantics as a reference simulator and asserts
//! the fast path is *bit-identical* to it — same P99s, same per-pool
//! counts, same utilization — across workloads, routers, cap windows, and
//! class mixes. A fixed seed therefore pins exact P99 TTFT values without
//! golden files.

use fleet_sim::des::engine::{CapWindow, DesConfig, SimPool, Simulator};
use fleet_sim::des::event::{EventKind, EventQueue};
use fleet_sim::des::pool::DesPool;
use fleet_sim::gpu::catalog::GpuCatalog;
use fleet_sim::router::{RouteRequest, RoutingPolicy};
use fleet_sim::util::stats::Samples;
use fleet_sim::workload::rng::Pcg64;
use fleet_sim::workload::spec::{BuiltinTrace, WorkloadSpec};

/// Reference summary of one simulation.
#[derive(Debug, PartialEq)]
struct Summary {
    overall_p99_ttft: f64,
    overall_p99_wait: f64,
    overall_p99_e2e: f64,
    overall_count: usize,
    pool_p99_ttft: Vec<f64>,
    pool_counts: Vec<usize>,
    utilization: Vec<f64>,
    max_queue_depth: Vec<usize>,
    n_compressed: usize,
}

fn summarize(mut r: fleet_sim::des::metrics::DesResult) -> Summary {
    Summary {
        overall_p99_ttft: r.overall.ttft.p99(),
        overall_p99_wait: r.overall.wait.p99(),
        overall_p99_e2e: r.overall.e2e.p99(),
        overall_count: r.overall.count,
        pool_p99_ttft: r.per_pool.iter_mut().map(|p| p.stats.ttft.p99())
            .collect(),
        pool_counts: r.per_pool.iter().map(|p| p.stats.count).collect(),
        utilization: r.per_pool.iter().map(|p| p.utilization).collect(),
        max_queue_depth: r.per_pool.iter().map(|p| p.max_queue_depth)
            .collect(),
        n_compressed: r.n_compressed,
    }
}

struct RefReq {
    arrival_ms: f64,
    l_in: f64,
    l_out: f64,
    pool: usize,
}

/// The original all-events-heap DES: arrivals are heap events (pushed
/// first, so they win time ties against completions and drains by
/// sequence number), everything else mirrors the engine exactly.
fn reference_run(
    w: &WorkloadSpec,
    pool_specs: &[SimPool],
    router: &RoutingPolicy,
    cfg: &DesConfig,
) -> Summary {
    let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
    let n = sampled.len();
    let mut route_rng = Pcg64::new(cfg.seed, 3);
    let mut pools: Vec<DesPool> = pool_specs
        .iter()
        .map(|p| DesPool::new(p.gpu.clone(), p.n_gpus, p.ctx_budget,
                              p.batch_cap))
        .collect();
    let mut reqs: Vec<RefReq> = sampled
        .iter()
        .map(|s| RefReq { arrival_ms: s.arrival_ms, l_in: s.l_in,
                          l_out: s.l_out, pool: 0 })
        .collect();

    let mut events = EventQueue::with_capacity(2 * n + 4);
    for (i, r) in reqs.iter().enumerate() {
        events.push(r.arrival_ms, EventKind::Arrival { req: i as u32 });
    }
    if let Some(win) = &cfg.cap_window {
        for p in 0..pools.len() {
            events.push(win.end_ms, EventKind::Drain { pool: p as u16 });
        }
    }

    let warmup_cutoff = (cfg.warmup_frac * n as f64) as usize;
    let mut pool_wait: Vec<Samples> = pools.iter().map(|_| Samples::new())
        .collect();
    let mut pool_ttft: Vec<Samples> = pools.iter().map(|_| Samples::new())
        .collect();
    let mut pool_count: Vec<usize> = vec![0; pools.len()];
    let mut all_wait = Samples::new();
    let mut all_ttft = Samples::new();
    let mut all_e2e = Samples::new();
    let mut all_count = 0usize;
    let mut n_compressed = 0usize;
    let mut horizon = 0.0f64;

    let eff_cap = |pool: &DesPool, t: f64| -> u32 {
        let mut cap = pool.slots_per_gpu;
        if let Some(win) = &cfg.cap_window {
            if t >= win.start_ms && t < win.end_ms {
                cap = cap.min(win.cap.max(1));
            }
        }
        cap
    };

    // Returns true if admitted (mirrors Simulator::try_admit).
    #[allow(clippy::too_many_arguments)]
    fn try_admit(
        pools: &mut [DesPool],
        pool_idx: usize,
        req_id: u32,
        reqs: &[RefReq],
        now: f64,
        events: &mut EventQueue,
        eff: u32,
        warmup_cutoff: usize,
        pool_wait: &mut [Samples],
        pool_ttft: &mut [Samples],
        pool_count: &mut [usize],
        all_wait: &mut Samples,
        all_ttft: &mut Samples,
        all_e2e: &mut Samples,
        all_count: &mut usize,
    ) -> bool {
        let pool = &mut pools[pool_idx];
        let mut best: Option<(usize, u32)> = None;
        for (i, inst) in pool.instances.iter().enumerate() {
            if inst.busy < eff {
                let free = eff - inst.busy;
                if best.map_or(true, |(_, bf)| free > bf) {
                    best = Some((i, free));
                }
            }
        }
        let Some((inst, _)) = best else { return false };
        pool.acquire(inst, now);
        let req = &reqs[req_id as usize];
        let n_at_admit = pool.instances[inst].busy as f64;
        let t_iter = pool.gpu.t_iter(n_at_admit);
        let hold = pool.gpu.iters(req.l_in, req.l_out) * t_iter;
        events.push(
            now + hold,
            EventKind::Completion { req: req_id, pool: pool_idx as u16,
                                    instance: inst as u16 },
        );
        let wait = now - req.arrival_ms;
        let prefill = (req.l_in / pool.gpu.chunk).ceil() * t_iter;
        let ttft = wait + prefill + t_iter;
        let e2e = wait + hold;
        if req_id as usize >= warmup_cutoff {
            pool_wait[pool_idx].push(wait);
            pool_ttft[pool_idx].push(ttft);
            pool_count[pool_idx] += 1;
            all_wait.push(wait);
            all_ttft.push(ttft);
            all_e2e.push(e2e);
            *all_count += 1;
        }
        true
    }

    while let Some(ev) = events.pop() {
        let now = ev.time_ms;
        horizon = horizon.max(now);
        match ev.kind {
            EventKind::Arrival { req } => {
                let r = &reqs[req as usize];
                let class = match &cfg.class_probs {
                    None => 0,
                    Some(probs) => {
                        let u = route_rng.uniform();
                        let mut cum = 0.0;
                        let mut cls = probs.len() - 1;
                        for (i, p) in probs.iter().enumerate() {
                            cum += p;
                            if u < cum {
                                cls = i;
                                break;
                            }
                        }
                        cls
                    }
                };
                let decision = router.route(
                    RouteRequest { l_in: r.l_in, l_out: r.l_out, class },
                    &mut route_rng,
                );
                let r = &mut reqs[req as usize];
                r.pool = decision.pool;
                r.l_in = decision.request.l_in;
                r.l_out = decision.request.l_out;
                if decision.compressed {
                    n_compressed += 1;
                }
                let eff = eff_cap(&pools[decision.pool], now);
                if !try_admit(&mut pools, decision.pool, req, &reqs, now,
                              &mut events, eff, warmup_cutoff,
                              &mut pool_wait, &mut pool_ttft, &mut pool_count,
                              &mut all_wait, &mut all_ttft, &mut all_e2e,
                              &mut all_count) {
                    pools[decision.pool].enqueue(req);
                }
            }
            EventKind::Completion { req: _, pool, instance } => {
                pools[pool as usize].release(instance as usize, now);
                loop {
                    let Some(&head) = pools[pool as usize].queue.front()
                    else { break };
                    let eff = eff_cap(&pools[pool as usize], now);
                    if !try_admit(&mut pools, pool as usize, head, &reqs, now,
                                  &mut events, eff, warmup_cutoff,
                                  &mut pool_wait, &mut pool_ttft,
                                  &mut pool_count, &mut all_wait,
                                  &mut all_ttft, &mut all_e2e,
                                  &mut all_count) {
                        break;
                    }
                    pools[pool as usize].queue.pop_front();
                }
            }
            EventKind::Drain { pool } => loop {
                let Some(&head) = pools[pool as usize].queue.front()
                else { break };
                let eff = eff_cap(&pools[pool as usize], now);
                if !try_admit(&mut pools, pool as usize, head, &reqs, now,
                              &mut events, eff, warmup_cutoff,
                              &mut pool_wait, &mut pool_ttft, &mut pool_count,
                              &mut all_wait, &mut all_ttft, &mut all_e2e,
                              &mut all_count) {
                    break;
                }
                pools[pool as usize].queue.pop_front();
            },
        }
    }

    Summary {
        overall_p99_ttft: all_ttft.p99(),
        overall_p99_wait: all_wait.p99(),
        overall_p99_e2e: all_e2e.p99(),
        overall_count: all_count,
        pool_p99_ttft: pool_ttft.iter_mut().map(|s| s.p99()).collect(),
        pool_counts: pool_count,
        utilization: pools.iter().map(|p| p.utilization(horizon)).collect(),
        max_queue_depth: pools.iter().map(|p| p.max_queue_depth).collect(),
        n_compressed,
    }
}

fn assert_fast_path_matches(
    w: &WorkloadSpec,
    pools: Vec<SimPool>,
    router: RoutingPolicy,
    cfg: DesConfig,
    label: &str,
) {
    let fast = summarize(
        Simulator::new(w.clone(), pools.clone(), router.clone(), cfg.clone())
            .run(),
    );
    let reference = reference_run(w, &pools, &router, &cfg);
    assert_eq!(fast, reference, "{label}: fast path diverged from reference");
    assert!(fast.overall_p99_ttft > 0.0, "{label}");
}

fn gpu(name: &str) -> fleet_sim::gpu::profile::GpuProfile {
    GpuCatalog::standard().get(name).unwrap().clone()
}

#[test]
fn fast_path_matches_reference_two_pool_length_router() {
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 120.0);
    let pools = vec![
        SimPool { gpu: gpu("A100"), n_gpus: 4, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("A100"), n_gpus: 4, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    assert_fast_path_matches(
        &w, pools, RoutingPolicy::Length { b_short: 4096.0 },
        DesConfig { n_requests: 5_000, seed: 11, ..Default::default() },
        "azure two-pool",
    );
}

#[test]
fn fast_path_matches_reference_heavy_tail_random_router() {
    let w = WorkloadSpec::builtin(BuiltinTrace::Agent, 20.0);
    let ctx = w.cdf.max_len();
    let pools = vec![SimPool { gpu: gpu("H100"), n_gpus: 24, ctx_budget: ctx,
                               batch_cap: None }];
    assert_fast_path_matches(
        &w, pools, RoutingPolicy::Random { n_pools: 1 },
        DesConfig { n_requests: 4_000, seed: 5, ..Default::default() },
        "agent homogeneous",
    );
}

#[test]
fn fast_path_matches_reference_compress_router() {
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 60.0);
    let pools = vec![
        SimPool { gpu: gpu("H100"), n_gpus: 2, ctx_budget: 2048.0,
                  batch_cap: None },
        SimPool { gpu: gpu("H100"), n_gpus: 3, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    assert_fast_path_matches(
        &w, pools,
        RoutingPolicy::CompressAndRoute { b_short: 2048.0, gamma: 1.5 },
        DesConfig { n_requests: 4_000, seed: 23, ..Default::default() },
        "azure compress",
    );
}

#[test]
fn fast_path_matches_reference_with_cap_window_and_classes() {
    // Cap-window drains and class-probability routing both touch the
    // event-ordering edge cases the merge fast path must preserve.
    let w = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 80.0);
    let pools = vec![
        SimPool { gpu: gpu("A10G"), n_gpus: 6, ctx_budget: 4096.0,
                  batch_cap: Some(32) },
        SimPool { gpu: gpu("A100"), n_gpus: 4, ctx_budget: 8192.0,
                  batch_cap: None },
        SimPool { gpu: gpu("H100"), n_gpus: 4, ctx_budget: 65536.0,
                  batch_cap: None },
    ];
    let cfg = DesConfig {
        n_requests: 4_000,
        seed: 31,
        cap_window: Some(CapWindow { start_ms: 10_000.0, end_ms: 40_000.0,
                                     cap: 2 }),
        class_probs: Some(vec![0.6, 0.3, 0.1]),
        ..Default::default()
    };
    assert_fast_path_matches(
        &w, pools,
        RoutingPolicy::Model { class_to_pool: vec![0, 1, 2] },
        cfg, "lmsys capped multi-pool",
    );
}

#[test]
fn fixed_seed_p99_is_reproducible_across_runs() {
    // Exact-value determinism: the same seed must produce the same P99s
    // run after run (this is what makes the reference comparison above a
    // stable regression oracle).
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
    let mk = || {
        let pools = vec![
            SimPool { gpu: gpu("H100"), n_gpus: 3, ctx_budget: 4096.0,
                      batch_cap: None },
            SimPool { gpu: gpu("H100"), n_gpus: 4, ctx_budget: 8192.0,
                      batch_cap: None },
        ];
        summarize(
            Simulator::new(
                w.clone(), pools, RoutingPolicy::Length { b_short: 4096.0 },
                DesConfig { n_requests: 6_000, seed: 42,
                            ..Default::default() },
            )
            .run(),
        )
    };
    let a = mk();
    let b = mk();
    assert_eq!(a, b);
}
