//! DES regression suite: the calendar-queue production engine against
//! the reference all-events-heap simulator.
//!
//! The production engine ([`fleet_sim::des::engine`]) schedules
//! completions and cap-window drains on a calendar queue and
//! merge-consumes the time-sorted arrival slice; the reference
//! ([`fleet_sim::des::reference`]) keeps every arrival in a `BinaryHeap`.
//! This suite asserts the production engine is *bit-identical* to the
//! reference — same P99s, same per-pool counts, same utilization, same
//! event counts — across workloads, routers, cap windows, class mixes,
//! and both metrics modes (exact vectors and the streaming sketch). A
//! fixed seed therefore pins exact P99 TTFT values without golden files.

// This suite deliberately keeps calling the deprecated `run_stream` /
// `run_reference` wrappers: they are part of the public API until the
// next major bump, and the regression oracle must keep proving they
// match the `SimInput`-based entry points bit for bit.
#![allow(deprecated)]

use fleet_sim::des::engine::{CapWindow, DesConfig, SimPool, Simulator};
use fleet_sim::des::faults::{FaultScript, GpuFailure, Straggler};
use fleet_sim::des::input::SimInput;
use fleet_sim::des::metrics::{DesResult, MetricsMode};
use fleet_sim::des::reference::{run_reference, run_reference_input};
use fleet_sim::router::RoutingPolicy;
use fleet_sim::workload::spec::{BuiltinTrace, WorkloadSpec};

/// Reference summary of one simulation.
#[derive(Debug, PartialEq)]
struct Summary {
    overall_p99_ttft: f64,
    overall_p99_wait: f64,
    overall_p99_e2e: f64,
    overall_count: usize,
    pool_p99_ttft: Vec<f64>,
    pool_counts: Vec<usize>,
    pool_unserved: Vec<usize>,
    utilization: Vec<f64>,
    max_queue_depth: Vec<usize>,
    n_compressed: usize,
    n_events: usize,
    n_unserved: usize,
    max_unserved_wait_ms: f64,
    /// Per-window (arrived, served, p99 TTFT) when windowed stats ran.
    windows: Option<Vec<(usize, usize, f64)>>,
}

fn summarize(mut r: DesResult) -> Summary {
    let windows = r.windows.as_mut().map(|w| {
        (0..w.n_windows())
            .map(|i| {
                let p99 = w.p99_ttft(i);
                // NaN != NaN would make empty windows "diverge"; compare
                // them as a sentinel instead.
                (w.n_arrived(i), w.n_served(i),
                 if p99.is_nan() { -1.0 } else { p99 })
            })
            .collect()
    });
    Summary {
        overall_p99_ttft: r.overall.ttft.p99(),
        overall_p99_wait: r.overall.wait.p99(),
        overall_p99_e2e: r.overall.e2e.p99(),
        overall_count: r.overall.count,
        pool_p99_ttft: r.per_pool.iter_mut().map(|p| p.stats.ttft.p99())
            .collect(),
        pool_counts: r.per_pool.iter().map(|p| p.stats.count).collect(),
        pool_unserved: r.per_pool.iter().map(|p| p.n_unserved).collect(),
        utilization: r.per_pool.iter().map(|p| p.utilization).collect(),
        max_queue_depth: r.per_pool.iter().map(|p| p.max_queue_depth)
            .collect(),
        n_compressed: r.n_compressed,
        n_events: r.n_events,
        n_unserved: r.n_unserved,
        max_unserved_wait_ms: r.max_unserved_wait_ms,
        windows,
    }
}

/// Assert production == reference, bit for bit, in both metrics modes.
fn assert_fast_path_matches(
    w: &WorkloadSpec,
    pools: Vec<SimPool>,
    router: RoutingPolicy,
    cfg: DesConfig,
    label: &str,
) {
    let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
    for mode in [MetricsMode::Exact, MetricsMode::Streaming] {
        let cfg = DesConfig { metrics: mode, ..cfg.clone() };
        let fast = summarize(Simulator::run_stream(
            &pools, &router, &cfg, &sampled,
        ));
        let reference = summarize(run_reference(&pools, &router, &cfg,
                                                &sampled));
        assert_eq!(
            fast, reference,
            "{label} [{mode:?}]: production engine diverged from reference"
        );
        assert!(fast.overall_p99_ttft > 0.0, "{label} [{mode:?}]");
    }
    // And `Simulator::run` (which samples internally) matches run_stream
    // on the externally sampled stream.
    let via_run = summarize(
        Simulator::new(w.clone(), pools.clone(), router.clone(), cfg.clone())
            .run(),
    );
    let via_stream = summarize(Simulator::run_stream(&pools, &router, &cfg,
                                                     &sampled));
    assert_eq!(via_run, via_stream, "{label}: run() vs run_stream()");
}

fn gpu(name: &str) -> fleet_sim::gpu::profile::GpuProfile {
    fleet_sim::gpu::catalog::GpuCatalog::standard()
        .get(name)
        .unwrap()
        .clone()
}

#[test]
fn fast_path_matches_reference_two_pool_length_router() {
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 120.0);
    let pools = vec![
        SimPool { gpu: gpu("A100"), n_gpus: 4, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("A100"), n_gpus: 4, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    assert_fast_path_matches(
        &w, pools, RoutingPolicy::Length { b_short: 4096.0 },
        DesConfig { n_requests: 5_000, seed: 11, ..Default::default() },
        "azure two-pool",
    );
}

#[test]
fn fast_path_matches_reference_heavy_tail_random_router() {
    let w = WorkloadSpec::builtin(BuiltinTrace::Agent, 20.0);
    let ctx = w.cdf.max_len();
    let pools = vec![SimPool { gpu: gpu("H100"), n_gpus: 24, ctx_budget: ctx,
                               batch_cap: None }];
    assert_fast_path_matches(
        &w, pools, RoutingPolicy::Random { n_pools: 1 },
        DesConfig { n_requests: 4_000, seed: 5, ..Default::default() },
        "agent homogeneous",
    );
}

#[test]
fn fast_path_matches_reference_compress_router() {
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 60.0);
    let pools = vec![
        SimPool { gpu: gpu("H100"), n_gpus: 2, ctx_budget: 2048.0,
                  batch_cap: None },
        SimPool { gpu: gpu("H100"), n_gpus: 3, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    assert_fast_path_matches(
        &w, pools,
        RoutingPolicy::CompressAndRoute { b_short: 2048.0, gamma: 1.5 },
        DesConfig { n_requests: 4_000, seed: 23, ..Default::default() },
        "azure compress",
    );
}

#[test]
fn fast_path_matches_reference_with_cap_window_and_classes() {
    // Cap-window drains and class-probability routing both touch the
    // event-ordering edge cases the calendar queue must preserve
    // (same-time drain/arrival/completion ties resolve by push order).
    let w = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 80.0);
    let pools = vec![
        SimPool { gpu: gpu("A10G"), n_gpus: 6, ctx_budget: 4096.0,
                  batch_cap: Some(32) },
        SimPool { gpu: gpu("A100"), n_gpus: 4, ctx_budget: 8192.0,
                  batch_cap: None },
        SimPool { gpu: gpu("H100"), n_gpus: 4, ctx_budget: 65536.0,
                  batch_cap: None },
    ];
    let cfg = DesConfig {
        n_requests: 4_000,
        seed: 31,
        cap_window: Some(CapWindow { start_ms: 10_000.0, end_ms: 40_000.0,
                                     cap: 2 }),
        class_probs: Some(vec![0.6, 0.3, 0.1]),
        ..Default::default()
    };
    assert_fast_path_matches(
        &w, pools,
        RoutingPolicy::Model { class_to_pool: vec![0, 1, 2] },
        cfg, "lmsys capped multi-pool",
    );
}

#[test]
fn fast_path_matches_reference_under_overload() {
    // Deep FIFO backlogs keep hundreds of completions in flight — the
    // calendar queue's resize/rewind paths see real churn here.
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 300.0);
    let pools = vec![SimPool { gpu: gpu("A100"), n_gpus: 2,
                               ctx_budget: 8192.0, batch_cap: None }];
    assert_fast_path_matches(
        &w, pools, RoutingPolicy::Random { n_pools: 1 },
        DesConfig { n_requests: 6_000, seed: 41, ..Default::default() },
        "azure overload",
    );
}

#[test]
fn fast_path_matches_reference_on_nhpp_stream() {
    // Non-stationary arrivals (two-phase diurnal NHPP) with windowed
    // stats enabled: production and reference must agree bit-for-bit on
    // the aggregate AND the per-window series, in both metrics modes.
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 120.0)
        .with_nhpp(vec![(0.0, 40.0), (10_000.0, 200.0)], 20_000.0);
    let pools = vec![
        SimPool { gpu: gpu("A100"), n_gpus: 5, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("A100"), n_gpus: 5, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    assert_fast_path_matches(
        &w, pools, RoutingPolicy::Length { b_short: 4096.0 },
        DesConfig { n_requests: 5_000, seed: 19,
                    window_ms: Some(5_000.0), ..Default::default() },
        "azure diurnal NHPP",
    );
}

#[test]
fn fast_path_matches_reference_on_replayed_stream() {
    // Replayed explicit timestamps (a bursty hand-built cadence, rate-
    // scaled) — the trace-driven path the stationary pipeline could not
    // express.
    let mut ts = Vec::new();
    let mut t = 0.0;
    for i in 0..500 {
        // Ten-request bursts every ~500 ms, tight 2 ms spacing inside.
        t += if i % 10 == 0 { 480.0 } else { 2.0 };
        ts.push(t);
    }
    let w = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 50.0)
        .with_replay(ts, 1.5);
    let pools = vec![
        SimPool { gpu: gpu("H100"), n_gpus: 2, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("H100"), n_gpus: 3, ctx_budget: 65536.0,
                  batch_cap: None },
    ];
    assert_fast_path_matches(
        &w, pools, RoutingPolicy::Length { b_short: 4096.0 },
        DesConfig { n_requests: 4_000, seed: 29,
                    window_ms: Some(10_000.0), ..Default::default() },
        "lmsys burst replay",
    );
}

#[test]
fn fast_path_matches_reference_with_time_based_warmup() {
    // Nonzero warmup: both engines must drop exactly the same
    // (time-based) prefix, stationary or not.
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
    let pools = vec![
        SimPool { gpu: gpu("A100"), n_gpus: 4, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("A100"), n_gpus: 4, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    assert_fast_path_matches(
        &w, pools, RoutingPolicy::Length { b_short: 4096.0 },
        DesConfig { n_requests: 4_000, seed: 37, warmup_frac: 0.25,
                    ..Default::default() },
        "azure warmup 25%",
    );
}

#[test]
fn overload_censoring_is_fixed_and_pinned_against_reference() {
    // The regression the bugfix exists for: long requests route to a
    // dead pool (zero GPUs) and sit in its queue until the event stream
    // drains. The pre-fix engine recorded only at admission, so those
    // requests vanished: served-only P99 was fast, `fraction_le` on the
    // starved samples said 100%, and the broken fleet "met" its SLO.
    // Post-fix they surface as n_unserved, poison attainment, and fail
    // meets_slo — identically in both engines.
    // 20 req/s keeps the live short pool comfortably under its SLO
    // (ρ ≈ 0.4), which is exactly what made the censoring invisible.
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 20.0);
    let pools = vec![
        SimPool { gpu: gpu("H100"), n_gpus: 4, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("H100"), n_gpus: 0, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    let router = RoutingPolicy::Length { b_short: 4096.0 };
    let cfg = DesConfig { n_requests: 5_000, seed: 43,
                          ..Default::default() };
    let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
    let mut prod = Simulator::run_stream(&pools, &router, &cfg, &sampled);
    let refr = run_reference(&pools, &router, &cfg, &sampled);
    assert_eq!(summarize(prod.clone()), summarize(refr),
               "dead-pool run diverged");

    assert!(prod.n_unserved > 0, "expected a censored backlog");
    assert_eq!(prod.overall.count + prod.n_unserved, 5_000);
    // The buggy behavior would pass here: served-only P99 is well under
    // the SLO…
    assert!(prod.overall.p99_ttft() <= 500.0,
            "served traffic should look healthy: {}",
            prod.overall.p99_ttft());
    // …and the fixed check fails anyway, because the backlog's wait
    // already exceeds the SLO.
    assert!(prod.max_unserved_wait_ms > 500.0);
    assert!(!prod.meets_slo(500.0));
    // Attainment includes the unserved in its denominator.
    let att = prod.attainment(500.0);
    assert!(att < 1.0 - prod.n_unserved as f64 / 5_000.0 + 1e-9,
            "attainment {att} still censored");
    // The dead pool itself reports NaN attainment, not a vacuous 100%.
    assert!(prod.per_pool[1].stats.ttft.fraction_le(500.0).is_nan());
}

#[test]
fn fast_path_matches_reference_under_fault_scripts() {
    // Fail-stop outage with a post-recovery cold start on the long pool,
    // plus a straggler on the short pool, over a diurnal NHPP stream
    // with windowed stats: the production engine must track the
    // reference bit for bit through down-instance skipping, slowdown
    // inflation, and the recovery Drain, in both metrics modes.
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 120.0)
        .with_nhpp(vec![(0.0, 40.0), (10_000.0, 200.0)], 20_000.0);
    let pools = vec![
        SimPool { gpu: gpu("A100"), n_gpus: 5, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("A100"), n_gpus: 5, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    let router = RoutingPolicy::Length { b_short: 4096.0 };
    let script = FaultScript {
        failures: vec![GpuFailure {
            pool: 1,
            n_gpus: 2,
            start_ms: 10_000.0,
            recover_ms: 18_000.0,
            warm_ms: 3_000.0,
            warm_factor: 2.0,
        }],
        stragglers: vec![Straggler {
            pool: 0,
            n_gpus: 1,
            start_ms: 0.0,
            end_ms: 15_000.0,
            factor: 1.5,
        }],
    };
    let base = DesConfig { n_requests: 4_000, seed: 13,
                           window_ms: Some(5_000.0), ..Default::default() };
    let sampled = w.sample_requests(base.n_requests, base.seed);
    for mode in [MetricsMode::Exact, MetricsMode::Streaming] {
        let cfg = DesConfig { metrics: mode, ..base.clone() };
        let input = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_faults(&script);
        let fast = summarize(Simulator::run_input(&input).unwrap());
        let reference = summarize(run_reference_input(&input).unwrap());
        assert_eq!(
            fast, reference,
            "faulted run [{mode:?}]: production engine diverged from \
             reference"
        );
        assert!(fast.overall_p99_ttft > 0.0, "[{mode:?}]");
    }
    // And the script really changed the run (the parity check bites).
    let faulted_in = SimInput::stream(&pools, &router, &base, &sampled)
        .with_faults(&script);
    let clean_in = SimInput::stream(&pools, &router, &base, &sampled);
    assert_ne!(
        summarize(Simulator::run_input(&faulted_in).unwrap()),
        summarize(Simulator::run_input(&clean_in).unwrap()),
        "fault script was a no-op"
    );
}

#[test]
fn fixed_seed_p99_is_reproducible_across_runs() {
    // Exact-value determinism: the same seed must produce the same P99s
    // run after run (this is what makes the reference comparison above a
    // stable regression oracle).
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
    let mk = || {
        let pools = vec![
            SimPool { gpu: gpu("H100"), n_gpus: 3, ctx_budget: 4096.0,
                      batch_cap: None },
            SimPool { gpu: gpu("H100"), n_gpus: 4, ctx_budget: 8192.0,
                      batch_cap: None },
        ];
        summarize(
            Simulator::new(
                w.clone(), pools, RoutingPolicy::Length { b_short: 4096.0 },
                DesConfig { n_requests: 6_000, seed: 42,
                            ..Default::default() },
            )
            .run(),
        )
    };
    let a = mk();
    let b = mk();
    assert_eq!(a, b);
}

#[test]
fn streaming_sketch_p99_close_to_exact_on_des_output() {
    // The streaming sketch is not bit-equal to exact collection (that is
    // the point: it keeps O(pools) memory) but its P99s must stay within
    // the sketch's documented ~1% bin width on real DES output.
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 120.0);
    let pools = vec![
        SimPool { gpu: gpu("A100"), n_gpus: 4, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu("A100"), n_gpus: 4, ctx_budget: 8192.0,
                  batch_cap: None },
    ];
    let router = RoutingPolicy::Length { b_short: 4096.0 };
    let base = DesConfig { n_requests: 8_000, seed: 11, ..Default::default() };
    let sampled = w.sample_requests(base.n_requests, base.seed);
    let mut exact = Simulator::run_stream(&pools, &router, &base, &sampled);
    let streaming_cfg = DesConfig { metrics: MetricsMode::Streaming, ..base };
    let mut sketch = Simulator::run_stream(&pools, &router, &streaming_cfg,
                                           &sampled);
    let (e, s) = (exact.overall.p99_ttft(), sketch.overall.p99_ttft());
    assert!((s / e - 1.0).abs() < 0.02, "exact {e} sketch {s}");
    let (ee, se) = (exact.overall.e2e.p99(), sketch.overall.e2e.p99());
    assert!((se / ee - 1.0).abs() < 0.02, "exact {ee} sketch {se}");
    assert_eq!(exact.overall.count, sketch.overall.count);
}
