//! Table-driven coverage of every [`ConfigError`] variant, plus the
//! panic-text contract of the deprecated pre-`SimInput` wrappers.
//!
//! Two things are pinned here:
//!
//! 1. every variant is reachable through the public validation paths
//!    and renders the exact Display text callers match on, and
//! 2. the `#[deprecated]` wrappers keep panicking with that same text
//!    (they are public API until the next major bump; scripts grep
//!    their panic messages).

#![allow(deprecated)]

use std::panic::{catch_unwind, AssertUnwindSafe};

use fleet_sim::des::engine::CapWindow;
use fleet_sim::prelude::*;

fn a100_pools(n: usize) -> Vec<SimPool> {
    let gpu = GpuCatalog::standard().get("A100").unwrap().clone();
    (0..n)
        .map(|_| SimPool {
            gpu: gpu.clone(),
            n_gpus: 2,
            ctx_budget: 4096.0,
            batch_cap: None,
        })
        .collect()
}

fn two_pool_router() -> RoutingPolicy {
    RoutingPolicy::Length { b_short: 4096.0 }
}

fn workload() -> WorkloadSpec {
    WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0)
}

/// Validate a stream-source input built from `config` against a
/// healthy two-pool fleet, returning the error.
fn config_err(config: &DesConfig) -> ConfigError {
    let pools = a100_pools(2);
    let router = two_pool_router();
    let input = SimInput::stream(&pools, &router, config, &[]);
    input.validate().expect_err("config must be rejected")
}

#[test]
fn every_variant_renders_its_contract_text() {
    let router_mismatch = {
        let pools = a100_pools(1);
        let router = two_pool_router();
        let config = DesConfig::default();
        let input = SimInput::stream(&pools, &router, &config, &[]);
        input.validate().expect_err("1 pool for a 2-pool router")
    };
    let invalid_warmup = config_err(&DesConfig {
        warmup_frac: 1.5,
        ..Default::default()
    });
    let warmup_unsupported = {
        let pools = a100_pools(2);
        let router = two_pool_router();
        let w = workload();
        let config = DesConfig {
            warmup_frac: 0.5,
            ..Default::default()
        };
        let input = SimInput::generated(&pools, &router, &config, &w);
        run_streamed_input(&input, 64)
            .map(|_| ())
            .expect_err("streaming must reject warmup")
    };
    let invalid_window = config_err(&DesConfig {
        window_ms: Some(0.0),
        ..Default::default()
    });
    let invalid_class_probs = config_err(&DesConfig {
        class_probs: Some(vec![]),
        ..Default::default()
    });
    let invalid_cap_window = config_err(&DesConfig {
        cap_window: Some(CapWindow {
            start_ms: 5.0,
            end_ms: 1.0,
            cap: 1,
        }),
        ..Default::default()
    });
    let invalid_retries = {
        let pools = a100_pools(2);
        let router = two_pool_router();
        let config = DesConfig::default();
        let empty = RetryConfig::default();
        let input = SimInput::stream(&pools, &router, &config, &[])
            .with_retries(&empty);
        input.validate().expect_err("empty retry config is rejected")
    };
    let invalid_memory = {
        let pools = a100_pools(2);
        let router = two_pool_router();
        let config = DesConfig::default();
        let bad = MemoryConfig {
            spec: MemorySpec {
                hbm_gb: None,
                weights_gb: 0.0,
                bytes_per_token: 0.0,
            },
            policy: PolicyKind::EvictRecompute,
            swap_out_ms: 0.0,
            swap_in_ms: 0.0,
        };
        let input = SimInput::stream(&pools, &router, &config, &[])
            .with_memory(&bad);
        input
            .validate()
            .expect_err("bytes_per_token = 0 must be rejected")
    };
    let invalid_faults = {
        let pools = a100_pools(1);
        let router = RoutingPolicy::Random { n_pools: 1 };
        let config = DesConfig::default();
        let script = FaultScript {
            failures: vec![GpuFailure {
                pool: 7,
                n_gpus: 1,
                start_ms: 0.0,
                recover_ms: 1.0,
                warm_ms: 0.0,
                warm_factor: 1.0,
            }],
            stragglers: vec![],
        };
        let input = SimInput::stream(&pools, &router, &config, &[])
            .with_faults(&script);
        input.validate().expect_err("pool 7 of 1 must be rejected")
    };

    let table: Vec<(&str, ConfigError, &str)> = vec![
        (
            "RouterPoolMismatch",
            router_mismatch,
            "router expects 2 pools, got 1",
        ),
        (
            "InvalidWarmup",
            invalid_warmup,
            "warmup_frac must be in [0, 1), got 1.5",
        ),
        (
            "WarmupUnsupported",
            warmup_unsupported,
            // The load-bearing historical substring is
            // "warmup_frac = 0"; the trailing value is also pinned.
            "warmup_frac = 0",
        ),
        (
            "InvalidWindow",
            invalid_window,
            "window_ms must be finite and > 0, got 0",
        ),
        (
            "InvalidClassProbs",
            invalid_class_probs,
            "invalid class_probs: empty class distribution",
        ),
        (
            "InvalidCapWindow",
            invalid_cap_window,
            "invalid cap_window: [5, 1) is not a valid time window",
        ),
        (
            "InvalidFaults",
            invalid_faults,
            "invalid fault script: failure #0: pool 7 out of range \
             (1 pools)",
        ),
        (
            "InvalidRetries",
            invalid_retries,
            "invalid retry config: at least one of [retry] or \
             [admission] is required",
        ),
        (
            "InvalidMemory",
            invalid_memory,
            "invalid memory config: bytes_per_token 0 must be finite \
             and > 0",
        ),
    ];
    for (variant, err, want) in &table {
        let text = err.to_string();
        assert!(
            text.contains(want),
            "{variant}: Display {text:?} must contain {want:?}"
        );
    }

    // Variant identity, not just text: the matches below fail to
    // compile if a variant is renamed and fail to run if validation
    // starts returning a different variant for the same input.
    assert!(matches!(
        table[0].1,
        ConfigError::RouterPoolMismatch { expected: 2, got: 1 }
    ));
    assert!(matches!(
        table[1].1,
        ConfigError::InvalidWarmup { warmup_frac } if warmup_frac == 1.5
    ));
    assert!(matches!(
        table[2].1,
        ConfigError::WarmupUnsupported { warmup_frac }
            if warmup_frac == 0.5
    ));
    assert!(matches!(
        table[3].1,
        ConfigError::InvalidWindow { window_ms } if window_ms == 0.0
    ));
    assert!(matches!(table[4].1, ConfigError::InvalidClassProbs(_)));
    assert!(matches!(table[5].1, ConfigError::InvalidCapWindow(_)));
    assert!(matches!(table[6].1, ConfigError::InvalidFaults(_)));
    assert!(matches!(table[7].1, ConfigError::InvalidRetries(_)));
    assert!(matches!(table[8].1, ConfigError::InvalidMemory(_)));
}

/// The streaming entry points reject warmup through `SimInput`
/// validation as a `ConfigError` — at every shard count, including
/// the `n_shards == 1` fast path that delegates to
/// `run_streamed_input`. Only the deprecated wrappers still panic
/// (pinned below).
#[test]
fn run_sharded_input_rejects_warmup_as_config_error() {
    let pools = a100_pools(2);
    let router = two_pool_router();
    let w = workload();
    let config = DesConfig {
        warmup_frac: 0.5,
        n_requests: 100,
        ..Default::default()
    };
    let input = SimInput::generated(&pools, &router, &config, &w);
    for shards in [1usize, 4] {
        let err = run_sharded_input(&input, shards, 64)
            .map(|_| ())
            .expect_err("sharded warmup must be a ConfigError");
        assert!(
            matches!(
                err,
                ConfigError::WarmupUnsupported { warmup_frac }
                    if warmup_frac == 0.5
            ),
            "shards = {shards}: {err}"
        );
        assert!(err.to_string().contains("warmup_frac = 0"), "{err}");
    }
}

/// The deprecated wrappers turn `Err(ConfigError)` into a panic whose
/// payload is exactly the error's Display — callers that predate
/// `SimInput` grep these strings out of crash logs.
fn panic_text<F: FnOnce()>(f: F) -> String {
    let payload = catch_unwind(AssertUnwindSafe(f))
        .expect_err("wrapper must panic on invalid input");
    if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else {
        panic!("panic payload is not a string")
    }
}

#[test]
fn deprecated_wrappers_preserve_legacy_panic_texts() {
    let pools = a100_pools(1);
    let router = two_pool_router();
    let config = DesConfig::default();

    let text = panic_text(|| {
        Simulator::run_stream(&pools, &router, &config, &[]);
    });
    assert_eq!(text, "router expects 2 pools, got 1");

    let w = workload();
    let warm = DesConfig {
        warmup_frac: 0.25,
        n_requests: 10,
        ..Default::default()
    };
    let pools2 = a100_pools(2);
    let text = panic_text(|| {
        run_streamed(&pools2, &router, &warm, &w, 64);
    });
    assert!(
        text.contains("warmup_frac = 0") && text.contains("got 0.25"),
        "streaming wrapper panic drifted: {text:?}"
    );

    let text = panic_text(|| {
        run_sharded(&pools2, &router, &warm, &w, 2, 64);
    });
    assert!(
        text.contains("warmup_frac = 0"),
        "sharded wrapper panic drifted: {text:?}"
    );
}
