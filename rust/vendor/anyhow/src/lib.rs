//! Minimal, vendored stand-in for the `anyhow` crate.
//!
//! The authoring environment is fully offline (no crates.io), so the
//! workspace vendors the narrow slice of the anyhow API the planner
//! actually uses: the type-erased [`Error`], the [`Result`] alias, the
//! [`Context`] extension trait, and the `anyhow!` / `bail!` / `ensure!`
//! macros. Keeping the crate name and paths identical means call sites
//! are untouched and the build is hermetic, which in turn lets the
//! repository commit an exact `Cargo.lock` with no registry checksums.
//!
//! Differences from real anyhow: errors are eagerly rendered to a
//! `String` (no source chain, no backtrace, no `downcast`). Nothing in
//! this workspace relies on those.

use std::fmt;

/// `Result<T, anyhow::Error>` — drop-in alias, default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A type-erased error message.
///
/// Like `anyhow::Error`, this deliberately does **not** implement
/// `std::error::Error`, which is what makes the blanket
/// `From<E: std::error::Error>` conversion below coherent.
pub struct Error(String);

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error(message.to_string())
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Self {
        Error(format!("{context}: {}", self.0))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        Error(e.to_string())
    }
}

/// `anyhow::Context` — attach context to `Result` and `Option` errors.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/nonexistent/fleet-sim-shim")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().starts_with("reading config: "));
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        let e = none.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");

        let e = anyhow!("bad value {}", 7);
        assert_eq!(format!("{e}"), "bad value 7");
        assert_eq!(format!("{e:?}"), "bad value 7");

        fn guarded(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(guarded(4).unwrap(), 4);
        assert_eq!(guarded(12).unwrap_err().to_string(), "x too big: 12");
        assert!(guarded(3).is_err());
    }
}
