// Shared micro-bench harness (criterion is unavailable offline).
//
// Each table bench (1) regenerates its paper table via the scenario
// library and prints it — the reproduction artifact — and (2) times the
// core computation with warmup + repeated samples, reporting
// min/mean/p50/max like criterion's summary line.
//
// Used via `include!("harness.rs")` from each bench target.

use std::time::Instant;

pub struct BenchStats {
    pub name: String,
    pub samples_ms: Vec<f64>,
}

impl BenchStats {
    pub fn report(&self) -> String {
        let mut s = self.samples_ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = s.iter().sum::<f64>() / s.len() as f64;
        format!(
            "bench {:<40} min {:>9.3} ms  mean {:>9.3} ms  p50 {:>9.3} ms  \
             max {:>9.3} ms  ({} samples)",
            self.name,
            s[0],
            mean,
            s[s.len() / 2],
            s[s.len() - 1],
            s.len()
        )
    }
}

/// Time `f` with one warmup call and `samples` measured calls.
pub fn bench<F: FnMut()>(name: &str, samples: usize, mut f: F) -> BenchStats {
    f(); // warmup
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let stats = BenchStats { name: name.to_string(), samples_ms: out };
    println!("{}", stats.report());
    stats
}

/// Standard banner for table-regeneration benches.
pub fn banner(table: &str) {
    println!("\n================ {table} ================");
}
