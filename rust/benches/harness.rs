// Shared micro-bench harness (criterion is unavailable offline).
//
// Each table bench (1) regenerates its paper table via the scenario
// registry and prints it — the reproduction artifact — and (2) times the
// core computation with warmup + repeated samples, reporting
// min/mean/p50/max like criterion's summary line, and (3) merges its
// numbers into a local perf snapshot (`BENCH_local.json` at the repo
// root, or `$FLEET_SIM_BENCH_SNAPSHOT`) so the perf trajectory is
// recorded across PRs.
//
// The committed `BENCH_1.json` / `BENCH_2.json` snapshots that the CI
// perf gate compares are NOT written here — they come from the
// `fleet-sim bench` subcommand (src/report/perf.rs), which measures the
// DES engines on fixed scenarios. This file is for per-table timings.
//
// Used via `include!("harness.rs")` from each bench target.

use std::time::Instant;

#[allow(dead_code)]
pub struct BenchStats {
    pub name: String,
    pub samples_ms: Vec<f64>,
}

#[allow(dead_code)]
impl BenchStats {
    /// (min, mean, p50, max) from one sort pass.
    fn summary(&self) -> (f64, f64, f64, f64) {
        let mut s = self.samples_ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = s.iter().sum::<f64>() / s.len() as f64;
        (s[0], mean, s[s.len() / 2], s[s.len() - 1])
    }

    pub fn mean_ms(&self) -> f64 {
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    pub fn report(&self) -> String {
        let (min, mean, p50, max) = self.summary();
        format!(
            "bench {:<40} min {:>9.3} ms  mean {:>9.3} ms  p50 {:>9.3} ms  \
             max {:>9.3} ms  ({} samples)",
            self.name, min, mean, p50, max, self.samples_ms.len()
        )
    }

    fn to_json(&self) -> fleet_sim::util::json::Json {
        use fleet_sim::util::json::Json;
        let (min, mean, p50, max) = self.summary();
        Json::Obj(vec![
            ("min_ms".into(), Json::Num(min)),
            ("mean_ms".into(), Json::Num(mean)),
            ("p50_ms".into(), Json::Num(p50)),
            ("max_ms".into(), Json::Num(max)),
            ("samples".into(), Json::Num(self.samples_ms.len() as f64)),
        ])
    }
}

/// Time `f` with one warmup call and `samples` measured calls.
#[allow(dead_code)]
pub fn bench<F: FnMut()>(name: &str, samples: usize, mut f: F) -> BenchStats {
    f(); // warmup
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        out.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let stats = BenchStats { name: name.to_string(), samples_ms: out };
    println!("{}", stats.report());
    stats
}

/// Standard banner for table-regeneration benches.
#[allow(dead_code)]
pub fn banner(table: &str) {
    println!("\n================ {table} ================");
}

/// DES throughput implied by a timed run: requests / mean wall-time.
#[allow(dead_code)]
pub fn requests_per_sec(n_requests: usize, stats: &BenchStats) -> f64 {
    n_requests as f64 / (stats.mean_ms() / 1e3)
}

/// Merge this bench target's results into the local perf snapshot
/// (`$FLEET_SIM_BENCH_SNAPSHOT`, default `BENCH_local.json` at the repo
/// root): one object per bench target, one entry per timed section plus
/// free-form scalar extras (e.g. DES requests/sec).
#[allow(dead_code)]
pub fn write_snapshot(target: &str, stats: &[&BenchStats],
                      extras: &[(&str, f64)]) {
    use fleet_sim::util::json::Json;
    let path = std::env::var("FLEET_SIM_BENCH_SNAPSHOT").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_local.json")
            .to_string()
    });
    let mut root = std::fs::read_to_string(&path)
        .ok()
        .and_then(|t| Json::parse(&t).ok())
        .and_then(|j| match j {
            Json::Obj(pairs) => Some(pairs),
            _ => None,
        })
        .unwrap_or_default();

    let mut entry: Vec<(String, Json)> = stats
        .iter()
        .map(|s| (s.name.clone(), s.to_json()))
        .collect();
    for (k, v) in extras {
        entry.push(((*k).to_string(), Json::Num(*v)));
    }
    let value = Json::Obj(entry);
    if let Some(slot) = root.iter_mut().find(|(k, _)| k == target) {
        slot.1 = value;
    } else {
        root.push((target.to_string(), value));
    }
    let doc = Json::Obj(root);
    match std::fs::write(&path, doc.to_string_pretty() + "\n") {
        Ok(()) => println!("perf snapshot updated: {path} [{target}]"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
