//! Table 9 (§4.8): grid flexibility curve. Regenerates the table and
//! times the full analysis (power inversion + recalibrated M/G/c + two
//! DES runs per flex level).
include!("harness.rs");

use fleet_sim::gpu::catalog::GpuCatalog;
use fleet_sim::optimizer::gridflex::{grid_flex_analysis, GridFlexConfig};
use fleet_sim::scenarios::{self, ScenarioOpts};
use fleet_sim::workload::spec::{BuiltinTrace, WorkloadSpec};

fn main() {
    banner("Table 9 — grid flexibility curve");
    let opts = ScenarioOpts::fast();
    println!("{}", scenarios::run(8, &opts).unwrap().render());
    let gpu = GpuCatalog::standard().get("H100").unwrap().clone();
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 200.0);
    let mut cfg = GridFlexConfig::default();
    cfg.n_requests = 8_000;
    let flex = bench("grid_flex_analysis_6_levels", 3, || {
        let _ = grid_flex_analysis(&w, &gpu, &cfg);
    });
    // 6 flex levels x 2 DES runs per level at cfg.n_requests each.
    let rps = requests_per_sec(12 * cfg.n_requests, &flex);
    write_snapshot("table9_gridflex", &[&flex],
                   &[("des_requests_per_sec", rps)]);
}
