//! Table 2 (§4.2): agent fleet SLO analysis. Regenerates the table and
//! times the 15K-request heavy-tail DES run.
include!("harness.rs");

use fleet_sim::des::engine::{DesConfig, SimPool, Simulator};
use fleet_sim::gpu::catalog::GpuCatalog;
use fleet_sim::router::RoutingPolicy;
use fleet_sim::scenarios::{self, ScenarioOpts};
use fleet_sim::workload::spec::{BuiltinTrace, WorkloadSpec};

fn main() {
    banner("Table 2 — agent fleet SLO analysis");
    let opts = ScenarioOpts::fast();
    println!("{}", scenarios::run(2, &opts).unwrap().render());
    let gpu = GpuCatalog::standard().get("H100").unwrap().clone();
    let w = WorkloadSpec::builtin(BuiltinTrace::Agent, 20.0);
    let ctx = w.cdf.max_len();
    let des = bench("agent_des_15k_requests", 5, || {
        let sim = Simulator::new(
            w.clone(),
            vec![SimPool { gpu: gpu.clone(), n_gpus: 64, ctx_budget: ctx,
                           batch_cap: None }],
            RoutingPolicy::Random { n_pools: 1 },
            DesConfig { n_requests: 15_000, ..Default::default() },
        );
        let _ = sim.run();
    });
    let rps = requests_per_sec(15_000, &des);
    write_snapshot("table2_agent_slo", &[&des],
                   &[("des_requests_per_sec", rps)]);
}
