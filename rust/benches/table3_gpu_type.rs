//! Table 3 (§4.3): GPU type vs layout. Regenerates the table and times
//! the per-type minimal-fleet search.
include!("harness.rs");

use fleet_sim::scenarios::{self, puzzle3_gpu_type, ScenarioOpts};

fn main() {
    banner("Table 3 — GPU type vs layout");
    let opts = ScenarioOpts::fast();
    println!("{}", scenarios::run(3, &opts).unwrap().render());
    let search = bench("gpu_type_layout_search", 3, || {
        let _ = puzzle3_gpu_type::evaluate(&opts);
    });
    write_snapshot("table3_gpu_type", &[&search], &[]);
}
