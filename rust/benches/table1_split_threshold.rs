//! Table 1 (§4.1): B_short Pareto frontier. Regenerates the table and
//! times one full threshold sweep (Phase 1 + DES verification per row).
include!("harness.rs");

use fleet_sim::scenarios::{self, ScenarioOpts};

fn main() {
    banner("Table 1 — B_short Pareto frontier");
    let opts = ScenarioOpts::fast();
    let report = scenarios::run(1, &opts).unwrap();
    println!("{}", report.render());
    let sweep = bench("puzzle1_full_sweep", 3, || {
        let _ = scenarios::run(1, &opts).unwrap();
    });
    write_snapshot("table1_split_threshold", &[&sweep], &[]);
}
