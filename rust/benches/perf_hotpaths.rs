//! Hot-path micro-benchmarks for the performance pass (EXPERIMENTS.md
//! §Perf): Phase-1 sweep (native + AOT), DES event loop, Erlang kernel.
include!("harness.rs");

use fleet_sim::des::engine::{DesConfig, SimPool, Simulator};
use fleet_sim::gpu::catalog::GpuCatalog;
use fleet_sim::optimizer::analytic::{NativeSweep, SweepEval};
use fleet_sim::optimizer::candidates::{generate, GenOptions};
use fleet_sim::queueing::erlang::erlang_c;
use fleet_sim::router::RoutingPolicy;
use fleet_sim::runtime::sweep::AotSweep;
use fleet_sim::workload::spec::{BuiltinTrace, WorkloadSpec};

fn main() {
    banner("Perf hot paths");
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
    let mut opts = GenOptions::default();
    opts.allow_mixed = true;
    opts.headroom = 7;
    let cands = generate(&w, &GpuCatalog::standard(), &opts);
    println!("candidate grid: {} configurations", cands.len());

    let phase1 = bench("phase1_native_sweep", 20, || {
        let _ = NativeSweep.eval(&w, &cands, 500.0).unwrap();
    });
    match AotSweep::load(&AotSweep::default_dir()) {
        Ok(aot) => {
            bench("phase1_aot_pjrt_sweep", 20, || {
                let _ = aot.eval(&w, &cands, 500.0).unwrap();
            });
        }
        Err(e) => println!("phase1_aot_pjrt_sweep SKIPPED: {e}"),
    }

    let gpu = GpuCatalog::standard().get("H100").unwrap().clone();
    let des = bench("des_10k_requests_two_pool", 20, || {
        let pools = vec![
            SimPool { gpu: gpu.clone(), n_gpus: 3, ctx_budget: 4096.0,
                      batch_cap: None },
            SimPool { gpu: gpu.clone(), n_gpus: 4, ctx_budget: 8192.0,
                      batch_cap: None },
        ];
        let sim = Simulator::new(
            w.clone(), pools, RoutingPolicy::Length { b_short: 4096.0 },
            DesConfig { n_requests: 10_000, ..Default::default() },
        );
        let _ = sim.run();
    });

    let erlang = bench("erlang_c_native_4096_lanes", 50, || {
        let mut acc = 0.0;
        for i in 0..4096 {
            acc += erlang_c(0.5 + (i % 45) as f64 * 0.01,
                            1 + (i % 512));
        }
        std::hint::black_box(acc);
    });
    let rps = requests_per_sec(10_000, &des);
    write_snapshot("perf_hotpaths", &[&phase1, &des, &erlang],
                   &[("des_requests_per_sec", rps)]);
}
