//! Table 5 (§4.5): router comparison. Regenerates the table and times one
//! router's DES pass over the agent fleet.
include!("harness.rs");

use fleet_sim::scenarios::{self, puzzle5_routers, ScenarioOpts};

fn main() {
    banner("Table 5 — router comparison");
    let opts = ScenarioOpts::fast();
    println!("{}", scenarios::run(5, &opts).unwrap().render());
    let cmp = bench("three_router_comparison", 3, || {
        let _ = puzzle5_routers::evaluate(&opts);
    });
    let rps = requests_per_sec(3 * opts.n_requests, &cmp);
    write_snapshot("table5_routers", &[&cmp],
                   &[("des_requests_per_sec", rps)]);
}
