//! Table 8 (§4.7): disaggregated P/D configurations. Regenerates the
//! table and times the optimizer sweep + two-stage DES.
include!("harness.rs");

use fleet_sim::gpu::catalog::GpuCatalog;
use fleet_sim::optimizer::disagg::{simulate_disagg, DisaggFleetOptimizer};
use fleet_sim::scenarios::{self, ScenarioOpts};
use fleet_sim::workload::spec::{BuiltinTrace, WorkloadSpec};

fn main() {
    banner("Table 8 — disaggregated P/D configurations");
    let opts = ScenarioOpts::fast();
    println!("{}", scenarios::run(7, &opts).unwrap().render());
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
    let o = DisaggFleetOptimizer::new(GpuCatalog::standard(), 500.0, 100.0);
    let sweep = bench("disagg_sweep", 5, || {
        let _ = o.sweep(&w);
    });
    let best = o.sweep(&w).into_iter().next().unwrap().0;
    let des = bench("disagg_two_stage_des_10k", 5, || {
        let _ = simulate_disagg(&w, &best, 10_000, 42);
    });
    let rps = requests_per_sec(10_000, &des);
    write_snapshot("table8_disagg", &[&sweep, &des],
                   &[("des_requests_per_sec", rps)]);
}
