//! Table 4 (§4.4): GPU step thresholds. Regenerates the table and times
//! the whatif λ sweep including the headroom bisection.
include!("harness.rs");

use fleet_sim::gpu::catalog::GpuCatalog;
use fleet_sim::optimizer::whatif::WhatIfSweep;
use fleet_sim::scenarios::{self, ScenarioOpts};
use fleet_sim::workload::spec::{BuiltinTrace, WorkloadSpec};

fn main() {
    banner("Table 4 — GPU step thresholds");
    let opts = ScenarioOpts::fast();
    println!("{}", scenarios::run(4, &opts).unwrap().render());
    let cat = GpuCatalog::standard();
    let h100 = cat.get("H100").unwrap().clone();
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
    let sweep = bench("whatif_lambda_sweep", 5, || {
        let s = WhatIfSweep::new(GpuCatalog::standard(), 500.0)
            .for_gpu(&h100);
        let _ = s.sweep(&w, &[25.0, 100.0, 400.0]);
    });
    write_snapshot("table4_step_thresholds", &[&sweep], &[]);
}
