//! Tables 6 & 7 (§4.6): mixed GPU types on Azure and LMSYS. Regenerates
//! both tables and times the pairing sweep.
include!("harness.rs");

use fleet_sim::scenarios::{self, puzzle6_mixed, ScenarioOpts};
use fleet_sim::workload::spec::BuiltinTrace;

fn main() {
    banner("Tables 6 & 7 — mixed GPU types");
    let opts = ScenarioOpts::fast();
    println!("{}", scenarios::run(6, &opts).unwrap().render());
    let sweep = bench("mixed_pairing_sweep_azure", 3, || {
        let _ = puzzle6_mixed::evaluate(BuiltinTrace::Azure, 3072.0, &opts);
    });
    write_snapshot("table6_7_mixed_gpu", &[&sweep], &[]);
}
