//! WorkloadSpec: the planner's unit of workload description — a token-length
//! CDF, a prompt fraction, an arrival rate λ, and an arrival process
//! (stationary Poisson by default; paper §3.1 inputs).

use crate::workload::arrivals::ArrivalProcess;
use crate::workload::builtin::Trace;
use crate::workload::cdf::EmpiricalCdf;

/// The three traces that ship with the tool (paper §3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BuiltinTrace {
    Lmsys,
    Azure,
    Agent,
}

impl BuiltinTrace {
    pub fn parse(name: &str) -> anyhow::Result<Self> {
        match name.to_ascii_lowercase().as_str() {
            "lmsys" => Ok(BuiltinTrace::Lmsys),
            "azure" => Ok(BuiltinTrace::Azure),
            "agent" => Ok(BuiltinTrace::Agent),
            other => {
                anyhow::bail!("unknown trace '{other}' (lmsys|azure|agent)")
            }
        }
    }

    pub fn trace(self) -> Trace {
        match self {
            BuiltinTrace::Lmsys => Trace::lmsys(),
            BuiltinTrace::Azure => Trace::azure(),
            BuiltinTrace::Agent => Trace::agent(),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BuiltinTrace::Lmsys => "lmsys",
            BuiltinTrace::Azure => "azure",
            BuiltinTrace::Agent => "agent",
        }
    }
}

/// One sampled request before routing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SampledRequest {
    pub arrival_ms: f64,
    /// Prompt tokens.
    pub l_in: f64,
    /// Completion tokens.
    pub l_out: f64,
}

impl SampledRequest {
    pub fn total(&self) -> f64 {
        self.l_in + self.l_out
    }
}

/// How a workload's arrival timestamps are generated. The default
/// stationary Poisson is what every paper table uses; the other variants
/// open the non-stationary scenario family (diurnal profiles, trace
/// replay) that windowed SLO evaluation exists for.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum ArrivalSpec {
    /// Stationary Poisson at the workload's `lambda_rps`.
    #[default]
    Poisson,
    /// Piecewise-constant NHPP: `(t_ms, req/s)` breakpoints repeating
    /// every `period_ms` (infinite = non-cyclic).
    Nhpp { profile_rps: Vec<(f64, f64)>, period_ms: f64 },
    /// Replay explicit arrival timestamps, rate-scaled by `rate_scale`.
    Replay { timestamps: Vec<f64>, rate_scale: f64 },
}

/// A complete workload: lengths ~ CDF, arrivals ~ the arrival spec
/// (Poisson(λ) unless overridden).
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub name: String,
    pub cdf: EmpiricalCdf,
    /// Fraction of the token budget that is prompt.
    pub input_fraction: f64,
    /// Long-run mean arrival rate in requests per second (for
    /// non-stationary arrivals this is the mean the analytic Phase 1
    /// sizes against; the DES sees the full profile).
    pub lambda_rps: f64,
    /// Arrival-process selector (stationary Poisson by default).
    pub arrivals: ArrivalSpec,
}

impl WorkloadSpec {
    pub fn new(
        name: impl Into<String>,
        cdf: EmpiricalCdf,
        input_fraction: f64,
        lambda_rps: f64,
    ) -> Self {
        assert!((0.0..1.0).contains(&input_fraction));
        assert!(lambda_rps > 0.0);
        WorkloadSpec {
            name: name.into(),
            cdf,
            input_fraction,
            lambda_rps,
            arrivals: ArrivalSpec::Poisson,
        }
    }

    pub fn builtin(trace: BuiltinTrace, lambda_rps: f64) -> Self {
        let t = trace.trace();
        WorkloadSpec::new(t.name, t.cdf, t.input_fraction, lambda_rps)
    }

    pub fn from_trace(t: &Trace, lambda_rps: f64) -> Self {
        WorkloadSpec::new(
            t.name.clone(),
            t.cdf.clone(),
            t.input_fraction,
            lambda_rps,
        )
    }

    /// Arrival rate in req/ms (the simulator's native time unit).
    pub fn lambda_per_ms(&self) -> f64 {
        self.lambda_rps / 1000.0
    }

    /// Replace the CDF with a version truncated at `cap` tokens.
    pub fn truncated(&self, cap: f64) -> anyhow::Result<Self> {
        Ok(WorkloadSpec {
            name: format!("{}@{}k", self.name, (cap / 1024.0).round() as u64),
            cdf: self.cdf.truncated(cap)?,
            input_fraction: self.input_fraction,
            lambda_rps: self.lambda_rps,
            arrivals: self.arrivals.clone(),
        })
    }

    /// Switch to a cyclic piecewise-rate NHPP arrival profile.
    /// `lambda_rps` is reset to the profile's time-weighted mean so the
    /// analytic Phase 1 keeps sizing against the long-run rate.
    pub fn with_nhpp(
        mut self,
        profile_rps: Vec<(f64, f64)>,
        period_ms: f64,
    ) -> Self {
        let proc = ArrivalProcess::nhpp_rps(&profile_rps, period_ms);
        self.lambda_rps = proc.mean_rate() * 1000.0;
        self.arrivals = ArrivalSpec::Nhpp { profile_rps, period_ms };
        self
    }

    /// Switch to replaying explicit arrival timestamps (ms), rate-scaled
    /// by `rate_scale`. Timestamps are normalized so the first arrival
    /// lands at t = 0 (epoch-style exports replay correctly: the
    /// absolute origin of a trace carries no workload information), and
    /// `lambda_rps` is reset to the trace's effective mean rate.
    pub fn with_replay(
        mut self,
        timestamps: Vec<f64>,
        rate_scale: f64,
    ) -> Self {
        // Both DES engines assume a time-sorted arrival stream (the
        // production engine merge-consumes it in index order): reject an
        // out-of-order trace here instead of silently diverging later.
        assert!(
            timestamps.first().is_some_and(|&t| t >= 0.0),
            "replay trace must be non-empty with non-negative timestamps"
        );
        assert!(
            timestamps.windows(2).all(|w| w[0] <= w[1]),
            "replay timestamps must be ascending"
        );
        let t0 = timestamps[0];
        let timestamps: Vec<f64> =
            timestamps.iter().map(|t| t - t0).collect();
        let proc = ArrivalProcess::TraceReplay {
            timestamps: timestamps.clone(),
            rate_scale,
        };
        let mean = proc.mean_rate() * 1000.0;
        assert!(mean > 0.0, "replay trace must span positive time");
        self.lambda_rps = mean;
        self.arrivals = ArrivalSpec::Replay { timestamps, rate_scale };
        self
    }

    /// The concrete arrival process this workload samples from.
    pub fn arrival_process(&self) -> ArrivalProcess {
        match &self.arrivals {
            ArrivalSpec::Poisson => {
                ArrivalProcess::poisson_rps(self.lambda_rps)
            }
            ArrivalSpec::Nhpp { profile_rps, period_ms } => {
                ArrivalProcess::nhpp_rps(profile_rps, *period_ms)
            }
            ArrivalSpec::Replay { timestamps, rate_scale } => {
                ArrivalProcess::TraceReplay {
                    timestamps: timestamps.clone(),
                    rate_scale: *rate_scale,
                }
            }
        }
    }

    /// Same workload at a different mean arrival rate (whatif sweeps).
    /// Non-stationary arrival specs rescale proportionally: NHPP
    /// breakpoint rates and the replay `rate_scale` are multiplied by
    /// `lambda_rps / self.lambda_rps`, preserving the profile's shape.
    pub fn at_lambda(&self, lambda_rps: f64) -> Self {
        let mut s = self.clone();
        let k = lambda_rps / self.lambda_rps;
        match &mut s.arrivals {
            ArrivalSpec::Poisson => {}
            ArrivalSpec::Nhpp { profile_rps, .. } => {
                for (_, r) in profile_rps.iter_mut() {
                    *r *= k;
                }
            }
            ArrivalSpec::Replay { rate_scale, .. } => {
                *rate_scale *= k;
            }
        }
        s.lambda_rps = lambda_rps;
        s
    }

    /// Split a total token budget into (prompt, completion).
    pub fn split(&self, total: f64) -> (f64, f64) {
        let l_in = (total * self.input_fraction).ceil().max(1.0);
        let l_out = (total - l_in).max(1.0);
        (l_in, l_out)
    }

    /// Sample `n` requests from the arrival spec with i.i.d. CDF lengths
    /// (paper §3.1 Phase 2 steps 1–2).
    ///
    /// Implemented on top of the chunked
    /// [`RequestGenerator`](crate::workload::generator::RequestGenerator)
    /// so the materialized stream is bit-identical to what a lazy
    /// (chunked or sharded) consumer sees for the same `(self, seed)`.
    pub fn sample_requests(&self, n: usize, seed: u64) -> Vec<SampledRequest> {
        let mut gen =
            crate::workload::generator::RequestGenerator::new(self, seed);
        let mut out = Vec::new();
        gen.fill(&mut out, n);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_construction() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
        assert_eq!(w.name, "azure");
        assert!((w.lambda_per_ms() - 0.1).abs() < 1e-12);
        assert!((w.input_fraction - 0.8).abs() < 1e-12);
    }

    #[test]
    fn parse_names() {
        assert_eq!(BuiltinTrace::parse("LMSYS").unwrap(), BuiltinTrace::Lmsys);
        assert!(BuiltinTrace::parse("nope").is_err());
    }

    #[test]
    fn split_respects_fraction_and_floors() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 1.0);
        let (li, lo) = w.split(1000.0);
        assert_eq!(li, 800.0);
        assert_eq!(lo, 200.0);
        // Tiny requests still get at least 1 output token.
        let (li2, lo2) = w.split(1.0);
        assert!(li2 >= 1.0 && lo2 >= 1.0);
    }

    #[test]
    fn sampled_requests_are_ordered_and_sized() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 200.0);
        let reqs = w.sample_requests(5_000, 42);
        assert_eq!(reqs.len(), 5_000);
        assert!(reqs.windows(2).all(|r| r[0].arrival_ms < r[1].arrival_ms));
        assert!(reqs.iter().all(|r| r.total() <= 65536.0 + 1.0));
        // ~98.4% under 4096 (Table 1).
        let short = reqs.iter().filter(|r| r.total() <= 4096.0).count();
        let frac = short as f64 / reqs.len() as f64;
        assert!((frac - 0.984).abs() < 0.01, "short frac = {frac}");
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Agent, 20.0);
        assert_eq!(w.sample_requests(100, 7), w.sample_requests(100, 7));
        assert_ne!(w.sample_requests(100, 7), w.sample_requests(100, 8));
    }

    #[test]
    fn nhpp_workload_samples_the_profile() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0)
            .with_nhpp(vec![(0.0, 40.0), (10_000.0, 200.0)], 20_000.0);
        // λ is reset to the profile's time-weighted mean.
        assert!((w.lambda_rps - 120.0).abs() < 1e-9);
        let reqs = w.sample_requests(20_000, 5);
        assert_eq!(reqs.len(), 20_000);
        assert!(reqs.windows(2).all(|r| r[0].arrival_ms <= r[1].arrival_ms));
        // Peak phases must be visibly denser than off-peak phases.
        let (mut n_lo, mut n_hi) = (0usize, 0usize);
        for r in &reqs {
            if r.arrival_ms % 20_000.0 < 10_000.0 {
                n_lo += 1;
            } else {
                n_hi += 1;
            }
        }
        assert!(n_hi > 3 * n_lo, "lo {n_lo} hi {n_hi}");
        // Determinism and λ-rescale of the profile.
        assert_eq!(w.sample_requests(500, 7), w.sample_requests(500, 7));
        let w2 = w.at_lambda(60.0);
        assert!((w2.lambda_rps - 60.0).abs() < 1e-9);
        match &w2.arrivals {
            ArrivalSpec::Nhpp { profile_rps, .. } => {
                assert!((profile_rps[0].1 - 20.0).abs() < 1e-9);
                assert!((profile_rps[1].1 - 100.0).abs() < 1e-9);
            }
            other => panic!("expected NHPP, got {other:?}"),
        }
    }

    #[test]
    fn replay_workload_normalizes_and_reproduces_timestamps() {
        // An epoch-offset export: first arrival at 1.7e12 ms. The offset
        // carries no workload information and is stripped, so the gaps
        // replay verbatim from t = 0.
        let epoch = 1.7e12;
        let ts: Vec<f64> =
            (0..100).map(|i| epoch + i as f64 * 10.0).collect();
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0)
            .with_replay(ts, 1.0);
        // 100 arrivals over a 990 ms span.
        let expect_rps = 100.0 / 990.0 * 1000.0;
        assert!((w.lambda_rps - expect_rps).abs() < 1e-9, "{}", w.lambda_rps);
        let reqs = w.sample_requests(100, 3);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.arrival_ms, i as f64 * 10.0);
        }
        // Doubling λ compresses every replayed gap via rate_scale.
        let w2 = w.at_lambda(2.0 * expect_rps);
        let fast = w2.sample_requests(100, 3);
        assert_eq!(fast[0].arrival_ms, 0.0);
        assert!((fast[99].arrival_ms - 495.0).abs() < 1e-6);
    }

    #[test]
    fn truncation_and_rescale() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Agent, 20.0)
            .truncated(65536.0)
            .unwrap();
        assert_eq!(w.cdf.max_len(), 65536.0);
        let w2 = w.at_lambda(50.0);
        assert_eq!(w2.lambda_rps, 50.0);
        assert_eq!(w.lambda_rps, 20.0);
    }
}
