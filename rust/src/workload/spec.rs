//! WorkloadSpec: the planner's unit of workload description — a token-length
//! CDF, a prompt fraction, and an arrival rate λ (paper §3.1 inputs).

use crate::workload::arrivals::ArrivalProcess;
use crate::workload::builtin::Trace;
use crate::workload::cdf::EmpiricalCdf;
use crate::workload::rng::Pcg64;

/// The three traces that ship with the tool (paper §3.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BuiltinTrace {
    Lmsys,
    Azure,
    Agent,
}

impl BuiltinTrace {
    pub fn parse(name: &str) -> anyhow::Result<Self> {
        match name.to_ascii_lowercase().as_str() {
            "lmsys" => Ok(BuiltinTrace::Lmsys),
            "azure" => Ok(BuiltinTrace::Azure),
            "agent" => Ok(BuiltinTrace::Agent),
            other => anyhow::bail!("unknown trace '{other}' (lmsys|azure|agent)"),
        }
    }

    pub fn trace(self) -> Trace {
        match self {
            BuiltinTrace::Lmsys => Trace::lmsys(),
            BuiltinTrace::Azure => Trace::azure(),
            BuiltinTrace::Agent => Trace::agent(),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BuiltinTrace::Lmsys => "lmsys",
            BuiltinTrace::Azure => "azure",
            BuiltinTrace::Agent => "agent",
        }
    }
}

/// One sampled request before routing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SampledRequest {
    pub arrival_ms: f64,
    /// Prompt tokens.
    pub l_in: f64,
    /// Completion tokens.
    pub l_out: f64,
}

impl SampledRequest {
    pub fn total(&self) -> f64 {
        self.l_in + self.l_out
    }
}

/// A complete workload: lengths ~ CDF, arrivals ~ Poisson(λ).
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub name: String,
    pub cdf: EmpiricalCdf,
    /// Fraction of the token budget that is prompt.
    pub input_fraction: f64,
    /// Arrival rate in requests per second.
    pub lambda_rps: f64,
}

impl WorkloadSpec {
    pub fn new(
        name: impl Into<String>,
        cdf: EmpiricalCdf,
        input_fraction: f64,
        lambda_rps: f64,
    ) -> Self {
        assert!((0.0..1.0).contains(&input_fraction));
        assert!(lambda_rps > 0.0);
        WorkloadSpec { name: name.into(), cdf, input_fraction, lambda_rps }
    }

    pub fn builtin(trace: BuiltinTrace, lambda_rps: f64) -> Self {
        let t = trace.trace();
        WorkloadSpec::new(t.name, t.cdf, t.input_fraction, lambda_rps)
    }

    pub fn from_trace(t: &Trace, lambda_rps: f64) -> Self {
        WorkloadSpec::new(t.name.clone(), t.cdf.clone(), t.input_fraction, lambda_rps)
    }

    /// Arrival rate in req/ms (the simulator's native time unit).
    pub fn lambda_per_ms(&self) -> f64 {
        self.lambda_rps / 1000.0
    }

    /// Replace the CDF with a version truncated at `cap` tokens.
    pub fn truncated(&self, cap: f64) -> anyhow::Result<Self> {
        Ok(WorkloadSpec {
            name: format!("{}@{}k", self.name, (cap / 1024.0).round() as u64),
            cdf: self.cdf.truncated(cap)?,
            input_fraction: self.input_fraction,
            lambda_rps: self.lambda_rps,
        })
    }

    /// Same workload at a different arrival rate (whatif sweeps).
    pub fn at_lambda(&self, lambda_rps: f64) -> Self {
        let mut s = self.clone();
        s.lambda_rps = lambda_rps;
        s
    }

    /// Split a total token budget into (prompt, completion).
    pub fn split(&self, total: f64) -> (f64, f64) {
        let l_in = (total * self.input_fraction).ceil().max(1.0);
        let l_out = (total - l_in).max(1.0);
        (l_in, l_out)
    }

    /// Sample `n` requests with Poisson arrivals and i.i.d. CDF lengths
    /// (paper §3.1 Phase 2 steps 1–2).
    pub fn sample_requests(&self, n: usize, seed: u64) -> Vec<SampledRequest> {
        let mut arr_rng = Pcg64::new(seed, 1);
        let mut len_rng = Pcg64::new(seed, 2);
        let arrivals =
            ArrivalProcess::poisson_rps(self.lambda_rps).generate(n, &mut arr_rng);
        arrivals
            .into_iter()
            .map(|t| {
                let total = self.cdf.sample(&mut len_rng);
                let (l_in, l_out) = self.split(total);
                SampledRequest { arrival_ms: t, l_in, l_out }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_construction() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
        assert_eq!(w.name, "azure");
        assert!((w.lambda_per_ms() - 0.1).abs() < 1e-12);
        assert!((w.input_fraction - 0.8).abs() < 1e-12);
    }

    #[test]
    fn parse_names() {
        assert_eq!(BuiltinTrace::parse("LMSYS").unwrap(), BuiltinTrace::Lmsys);
        assert!(BuiltinTrace::parse("nope").is_err());
    }

    #[test]
    fn split_respects_fraction_and_floors() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 1.0);
        let (li, lo) = w.split(1000.0);
        assert_eq!(li, 800.0);
        assert_eq!(lo, 200.0);
        // Tiny requests still get at least 1 output token.
        let (li2, lo2) = w.split(1.0);
        assert!(li2 >= 1.0 && lo2 >= 1.0);
    }

    #[test]
    fn sampled_requests_are_ordered_and_sized() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 200.0);
        let reqs = w.sample_requests(5_000, 42);
        assert_eq!(reqs.len(), 5_000);
        assert!(reqs.windows(2).all(|r| r[0].arrival_ms < r[1].arrival_ms));
        assert!(reqs.iter().all(|r| r.total() <= 65536.0 + 1.0));
        // ~98.4% under 4096 (Table 1).
        let short = reqs.iter().filter(|r| r.total() <= 4096.0).count();
        let frac = short as f64 / reqs.len() as f64;
        assert!((frac - 0.984).abs() < 0.01, "short frac = {frac}");
    }

    #[test]
    fn sampling_is_seed_deterministic() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Agent, 20.0);
        assert_eq!(w.sample_requests(100, 7), w.sample_requests(100, 7));
        assert_ne!(w.sample_requests(100, 7), w.sample_requests(100, 8));
    }

    #[test]
    fn truncation_and_rescale() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Agent, 20.0).truncated(65536.0)
            .unwrap();
        assert_eq!(w.cdf.max_len(), 65536.0);
        let w2 = w.at_lambda(50.0);
        assert_eq!(w2.lambda_rps, 50.0);
        assert_eq!(w.lambda_rps, 20.0);
    }
}
