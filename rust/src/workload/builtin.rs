//! The three workload traces that ship with the tool (paper §3.3).
//!
//! The JSON files under `data/cdf/` are the single source of truth; they are
//! embedded at compile time so the binary is self-contained, and can also be
//! loaded from disk (or replaced by the user) via [`EmpiricalCdf::load`].

use crate::util::json::Json;
use crate::workload::cdf::EmpiricalCdf;

pub const LMSYS_JSON: &str = include_str!("../../../data/cdf/lmsys.json");
pub const AZURE_JSON: &str = include_str!("../../../data/cdf/azure.json");
pub const AGENT_JSON: &str = include_str!("../../../data/cdf/agent.json");

/// A parsed builtin trace: CDF plus its prompt fraction.
#[derive(Debug, Clone)]
pub struct Trace {
    pub name: String,
    pub description: String,
    pub cdf: EmpiricalCdf,
    /// Fraction of the token budget that is prompt (L_in / L_total).
    pub input_fraction: f64,
}

impl Trace {
    pub fn from_json_str(text: &str) -> anyhow::Result<Trace> {
        let doc = Json::parse(text)?;
        let cdf = EmpiricalCdf::from_json(&doc)?;
        let input_fraction = doc
            .get("input_fraction")
            .and_then(Json::as_f64)
            .unwrap_or(0.5);
        anyhow::ensure!(
            (0.0..1.0).contains(&input_fraction),
            "input_fraction must be in [0,1)"
        );
        Ok(Trace {
            name: doc
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("unnamed")
                .to_string(),
            description: doc
                .get("description")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            cdf,
            input_fraction,
        })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Trace> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_json_str(&text)
    }

    pub fn lmsys() -> Trace {
        Self::from_json_str(LMSYS_JSON).expect("embedded lmsys.json is valid")
    }

    pub fn azure() -> Trace {
        Self::from_json_str(AZURE_JSON).expect("embedded azure.json is valid")
    }

    pub fn agent() -> Trace {
        Self::from_json_str(AGENT_JSON).expect("embedded agent.json is valid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lmsys_matches_paper_quantiles() {
        // Table 1's alpha_s column pins these.
        let t = Trace::lmsys();
        for (len, want) in [
            (512.0, 0.638),
            (1024.0, 0.831),
            (2048.0, 0.948),
            (4096.0, 0.984),
            (8192.0, 0.997),
            (12288.0, 0.999),
        ] {
            let got = t.cdf.cdf(len);
            assert!((got - want).abs() < 1e-9, "F({len}) = {got}, want {want}");
        }
        assert_eq!(t.cdf.max_len(), 65536.0);
    }

    #[test]
    fn azure_matches_paper_facts() {
        let t = Trace::azure();
        // "78% of requests below 2K tokens; max context 8K" (§3.3).
        assert!((t.cdf.cdf(2048.0) - 0.78).abs() < 1e-9);
        assert_eq!(t.cdf.max_len(), 8192.0);
    }

    #[test]
    fn agent_matches_paper_facts() {
        let t = Trace::agent();
        // "46% of requests above 4K tokens and a heavy tail to 300K" (§3.3).
        assert!((1.0 - t.cdf.cdf(4096.0) - 0.46).abs() < 1e-9);
        assert_eq!(t.cdf.max_len(), 300000.0);
    }

    #[test]
    fn input_fractions_loaded() {
        assert!((Trace::lmsys().input_fraction - 0.85).abs() < 1e-12);
        assert!((Trace::azure().input_fraction - 0.8).abs() < 1e-12);
        assert!((Trace::agent().input_fraction - 0.93).abs() < 1e-12);
    }

    #[test]
    fn names_and_descriptions_present() {
        for t in [Trace::lmsys(), Trace::azure(), Trace::agent()] {
            assert!(!t.name.is_empty());
            assert!(!t.description.is_empty());
        }
    }
}
