//! Empirical token-length CDFs (paper §3.3, "Empirical CDF" format).
//!
//! A CDF is a list of `(token_budget, cumulative_probability)` breakpoints.
//! Between breakpoints we interpolate **log-linearly in length** — token
//! budgets span decades (64 … 300 000) and log-space interpolation is the
//! standard choice for heavy-tailed length data. The struct answers the
//! queries the planner needs:
//!
//! * `cdf(L)` — fraction of requests with total budget ≤ L (splits λ,
//!   paper §3.1 step 1),
//! * `quantile(q)` — inverse CDF (drawing DES request lengths, P99 lengths),
//! * `histogram(k)` — a k-bin discretization feeding the Phase-1 moment
//!   kernel (L1 `moments.py` and the rust fallback),
//! * conditional moments over a pool's length range.

use crate::util::json::Json;
use crate::workload::rng::Pcg64;

/// Empirical CDF over total token budget (prompt + completion).
#[derive(Debug, Clone)]
pub struct EmpiricalCdf {
    /// Breakpoints (length, cum_prob); strictly increasing in both fields,
    /// last cum_prob == 1.0.
    points: Vec<(f64, f64)>,
    /// Smallest representable budget (left edge of the support).
    min_len: f64,
}

impl EmpiricalCdf {
    /// Build from breakpoints. Requirements: non-empty, lengths strictly
    /// increasing, probabilities strictly increasing and ending at 1.0.
    pub fn new(points: Vec<(f64, f64)>) -> anyhow::Result<Self> {
        anyhow::ensure!(
            !points.is_empty(),
            "CDF needs at least one breakpoint"
        );
        for w in points.windows(2) {
            anyhow::ensure!(w[0].0 < w[1].0, "lengths must strictly increase");
            anyhow::ensure!(w[0].1 < w[1].1, "probs must strictly increase");
        }
        let last = points.last().unwrap();
        anyhow::ensure!(
            (last.1 - 1.0).abs() < 1e-9,
            "last breakpoint must have cum_prob 1.0, got {}",
            last.1
        );
        for &(l, p) in &points {
            anyhow::ensure!(l > 0.0, "lengths must be positive");
            anyhow::ensure!(
                p > 0.0 && p <= 1.0 + 1e-12,
                "probs must be in (0,1]"
            );
        }
        let min_len = (points[0].0 / 4.0).max(1.0);
        Ok(EmpiricalCdf { points, min_len })
    }

    /// Parse the JSON CDF format:
    /// `{"name": ..., "points": [[len, cum_prob], ...]}`.
    pub fn from_json(doc: &Json) -> anyhow::Result<Self> {
        let pts = doc
            .get("points")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing 'points' array"))?;
        let mut points = Vec::with_capacity(pts.len());
        for p in pts {
            let pair = p.as_arr().filter(|a| a.len() == 2).ok_or_else(
                || anyhow::anyhow!("each point must be [len, prob]"),
            )?;
            let l = pair[0]
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("bad len"))?;
            let q = pair[1]
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("bad prob"))?;
            points.push((l, q));
        }
        Self::new(points)
    }

    pub fn from_json_str(text: &str) -> anyhow::Result<Self> {
        Self::from_json(&Json::parse(text)?)
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::from_json_str(&text)
    }

    /// Maximum token budget in the support.
    pub fn max_len(&self) -> f64 {
        self.points.last().unwrap().0
    }

    /// The raw `(length, cum_prob)` breakpoints (used e.g. to fingerprint
    /// a workload for the evaluation engine's request-stream cache).
    pub fn points(&self) -> &[(f64, f64)] {
        &self.points
    }

    /// F(L): fraction of requests with budget <= L.
    pub fn cdf(&self, len: f64) -> f64 {
        if len < self.min_len {
            return 0.0;
        }
        if len >= self.max_len() {
            return 1.0;
        }
        // Find the bracketing breakpoints.
        let mut lo = (self.min_len, 0.0);
        for &(l, p) in &self.points {
            if len < l {
                let hi = (l, p);
                let t = (len.ln() - lo.0.ln()) / (hi.0.ln() - lo.0.ln());
                return lo.1 + t * (hi.1 - lo.1);
            }
            lo = (l, p);
        }
        1.0
    }

    /// Inverse CDF: the smallest length L with F(L) >= q, q in [0, 1].
    pub fn quantile(&self, q: f64) -> f64 {
        let q = q.clamp(0.0, 1.0);
        let mut lo = (self.min_len, 0.0);
        for &(l, p) in &self.points {
            if q <= p {
                let t = if p - lo.1 > 1e-15 {
                    (q - lo.1) / (p - lo.1)
                } else {
                    1.0
                };
                if t >= 1.0 {
                    return l; // avoid exp(ln(l)) rounding at breakpoints
                }
                return (lo.0.ln() + t * (l.ln() - lo.0.ln())).exp();
            }
            lo = (l, p);
        }
        self.max_len()
    }

    /// Draw one total token budget.
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        self.quantile(rng.uniform())
    }

    /// Return a CDF truncated at `cap` tokens: mass above the cap collapses
    /// onto it (used e.g. by Puzzle 2's 65K-context agent fleet).
    pub fn truncated(&self, cap: f64) -> anyhow::Result<Self> {
        anyhow::ensure!(cap > self.points[0].0, "cap below CDF support");
        if cap >= self.max_len() {
            return Ok(self.clone());
        }
        let mut pts: Vec<(f64, f64)> =
            self.points.iter().copied().filter(|&(l, _)| l < cap).collect();
        pts.push((cap, 1.0));
        Self::new(pts)
    }

    /// Discretize into `k` log-spaced bins: returns (probabilities, centers).
    /// Probabilities sum to 1; centers are log-midpoints of the bin edges.
    /// This is the histogram fed to the Phase-1 moment kernel.
    pub fn histogram(&self, k: usize) -> (Vec<f64>, Vec<f64>) {
        assert!(k >= 2);
        let lo = self.min_len.ln();
        let hi = self.max_len().ln();
        let mut probs = Vec::with_capacity(k);
        let mut centers = Vec::with_capacity(k);
        let mut prev_edge = self.min_len;
        let mut prev_cdf = 0.0;
        for i in 0..k {
            let edge = ((i + 1) as f64 / k as f64 * (hi - lo) + lo).exp();
            let c = if i == k - 1 { 1.0 } else { self.cdf(edge) };
            probs.push((c - prev_cdf).max(0.0));
            centers.push((prev_edge.ln() * 0.5 + edge.ln() * 0.5).exp());
            prev_edge = edge;
            prev_cdf = c;
        }
        // Normalize away any interpolation residue.
        let total: f64 = probs.iter().sum();
        if total > 0.0 {
            for p in &mut probs {
                *p /= total;
            }
        }
        (probs, centers)
    }

    /// Mean token budget (from the k-bin discretization).
    pub fn mean(&self, k: usize) -> f64 {
        let (p, c) = self.histogram(k);
        p.iter().zip(&c).map(|(p, c)| p * c).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple() -> EmpiricalCdf {
        EmpiricalCdf::new(vec![
            (512.0, 0.638),
            (1024.0, 0.831),
            (2048.0, 0.948),
            (4096.0, 0.984),
            (8192.0, 0.997),
            (65536.0, 1.0),
        ])
        .unwrap()
    }

    #[test]
    fn cdf_hits_breakpoints() {
        let c = simple();
        assert!((c.cdf(512.0) - 0.638).abs() < 1e-12);
        assert!((c.cdf(4096.0) - 0.984).abs() < 1e-12);
        assert_eq!(c.cdf(65536.0), 1.0);
        assert_eq!(c.cdf(1e9), 1.0);
        assert_eq!(c.cdf(1.0), 0.0);
    }

    #[test]
    fn cdf_monotone() {
        let c = simple();
        let mut prev = -1.0;
        for i in 0..200 {
            let l = 64.0 * 1.04f64.powi(i);
            let v = c.cdf(l);
            assert!(v >= prev);
            prev = v;
        }
    }

    #[test]
    fn quantile_inverts_cdf() {
        let c = simple();
        for q in [0.1, 0.3, 0.638, 0.9, 0.984, 0.999] {
            let l = c.quantile(q);
            assert!((c.cdf(l) - q).abs() < 1e-9, "q={q} l={l}");
        }
        assert_eq!(c.quantile(1.0), 65536.0);
    }

    #[test]
    fn histogram_sums_to_one_and_matches_cdf() {
        let c = simple();
        let (p, centers) = c.histogram(256);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert_eq!(p.len(), 256);
        // Cumulative histogram approximates the CDF at the threshold.
        let below: f64 = p
            .iter()
            .zip(&centers)
            .filter(|(_, &c)| c <= 4096.0)
            .map(|(p, _)| p)
            .sum();
        assert!((below - 0.984).abs() < 0.01, "below = {below}");
    }

    #[test]
    fn sampling_matches_cdf() {
        let c = simple();
        let mut rng = Pcg64::new(5, 0);
        let n = 50_000;
        let short = (0..n).filter(|_| c.sample(&mut rng) <= 4096.0).count();
        let frac = short as f64 / n as f64;
        assert!((frac - 0.984).abs() < 0.005, "frac = {frac}");
    }

    #[test]
    fn truncation() {
        let c = simple().truncated(8192.0).unwrap();
        assert_eq!(c.max_len(), 8192.0);
        assert_eq!(c.cdf(8192.0), 1.0);
        assert!((c.cdf(512.0) - 0.638).abs() < 1e-12);
    }

    #[test]
    fn json_round_trip() {
        let text = r#"{"name": "t", "points": [[512, 0.5], [1024, 1.0]]}"#;
        let c = EmpiricalCdf::from_json_str(text).unwrap();
        assert_eq!(c.max_len(), 1024.0);
        assert!((c.cdf(512.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn degenerate_single_point_table() {
        // A one-breakpoint table (all mass at one budget) is valid and
        // must stay well-behaved at both ends of the quantile range.
        let c = EmpiricalCdf::new(vec![(1024.0, 1.0)]).unwrap();
        assert_eq!(c.max_len(), 1024.0);
        assert_eq!(c.quantile(1.0), 1024.0);
        assert_eq!(c.cdf(1024.0), 1.0);
        assert_eq!(c.cdf(1e9), 1.0);
        // Support floor: min_len = 1024/4.
        assert_eq!(c.cdf(255.0), 0.0);
        let mut prev = 0.0;
        for i in 0..=100 {
            let q = i as f64 / 100.0;
            let l = c.quantile(q);
            assert!(l >= prev * (1.0 - 1e-12), "quantile({q}) = {l} < {prev}");
            assert!((255.9..=1024.0).contains(&l), "quantile({q}) = {l}");
            prev = l;
        }
        // Histogram of a single-point table still conserves mass.
        let (p, _) = c.histogram(16);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_monotone_under_interpolation_on_builtin_traces() {
        use crate::workload::builtin::Trace;
        for t in [Trace::lmsys(), Trace::azure(), Trace::agent()] {
            let c = &t.cdf;
            let mut prev = 0.0;
            for i in 0..=2_000 {
                let q = i as f64 / 2_000.0;
                let l = c.quantile(q);
                assert!(
                    l >= prev * (1.0 - 1e-12),
                    "{}: quantile({q}) = {l} < previous {prev}",
                    t.name
                );
                assert!(l <= c.max_len() + 1e-9, "{}: {l}", t.name);
                prev = l;
            }
            assert_eq!(c.quantile(1.0), c.max_len(), "{}", t.name);
            // And cdf() is monotone over a fine log grid of lengths.
            let mut prev_f = -1.0;
            let mut len = 1.0;
            while len < c.max_len() * 2.0 {
                let f = c.cdf(len);
                assert!(f >= prev_f, "{}: cdf({len}) = {f}", t.name);
                assert!((0.0..=1.0).contains(&f), "{}: cdf({len})", t.name);
                prev_f = f;
                len *= 1.05;
            }
        }
    }

    #[test]
    fn rejects_bad_input() {
        assert!(EmpiricalCdf::new(vec![]).is_err());
        assert!(EmpiricalCdf::new(vec![(10.0, 0.5)]).is_err()); // not 1.0
        assert!(EmpiricalCdf::new(vec![(10.0, 0.5), (5.0, 1.0)]).is_err());
        assert!(EmpiricalCdf::new(vec![(10.0, 0.8), (20.0, 0.7)]).is_err());
        assert!(EmpiricalCdf::from_json_str("{}").is_err());
    }
}
