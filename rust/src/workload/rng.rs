//! Deterministic PCG64 RNG plus the distributions the simulator draws from.
//!
//! Offline build: the `rand` crate is unavailable, so we implement
//! PCG-XSL-RR-128/64 (O'Neill 2014) directly. Every simulation takes an
//! explicit seed, so runs are reproducible bit-for-bit — a requirement for
//! the paper's case studies to be re-generable.

/// PCG-XSL-RR 128/64: 128-bit LCG state, xor-shift-low + random-rotate output.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with a stream id; distinct `(seed, stream)` pairs give
    /// independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let initseq = ((stream as u128) << 64) | 0xda3e_39cb_94b9_5bdb;
        let mut rng = Pcg64 { state: 0, inc: (initseq << 1) | 1 };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xored = ((self.state >> 64) ^ self.state) as u64;
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1] — safe to pass to `ln`.
    pub fn uniform_open(&mut self) -> f64 {
        1.0 - self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // Lemire's multiply-shift with rejection for exactness.
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.uniform_open().ln() / lambda
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform_open();
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the given log-space mean and sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pareto with scale x_m and shape alpha.
    pub fn pareto(&mut self, x_m: f64, alpha: f64) -> f64 {
        debug_assert!(x_m > 0.0 && alpha > 0.0);
        x_m / self.uniform_open().powf(1.0 / alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 0);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_and_streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(43, 0);
        let mut c = Pcg64::new(42, 1);
        let (x, y, z) = (a.next_u64(), b.next_u64(), c.next_u64());
        assert_ne!(x, y);
        assert_ne!(x, z);
    }

    #[test]
    fn uniform_in_range_and_balanced() {
        let mut rng = Pcg64::new(7, 0);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean = {mean}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut rng = Pcg64::new(9, 0);
        let mut counts = [0u32; 10];
        for _ in 0..100_000 {
            counts[rng.below(10) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::new(11, 0);
        let lambda = 0.25;
        let n = 200_000;
        let mean: f64 =
            (0..n).map(|_| rng.exponential(lambda)).sum::<f64>() / n as f64;
        assert!((mean - 4.0).abs() < 0.05, "mean = {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(13, 0);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.01, "mean = {mean}");
        assert!((var - 1.0).abs() < 0.02, "var = {var}");
    }

    #[test]
    fn pareto_exceeds_scale() {
        let mut rng = Pcg64::new(17, 0);
        for _ in 0..1000 {
            assert!(rng.pareto(100.0, 1.5) >= 100.0);
        }
    }

    #[test]
    fn lognormal_median() {
        let mut rng = Pcg64::new(19, 0);
        let n = 100_001;
        let mut xs: Vec<f64> =
            (0..n).map(|_| rng.lognormal(2.0, 0.7)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[n / 2];
        // Median of lognormal is e^mu.
        assert!((median - 2.0f64.exp()).abs() / 2.0f64.exp() < 0.03);
    }
}
