//! Synthetic token-length distributions for sensitivity analysis
//! (paper §3.3, "Poisson with synthetic lengths"): Pareto or log-normal
//! lengths, clamped to a [min, max] support.

use crate::workload::cdf::EmpiricalCdf;
use crate::workload::rng::Pcg64;
use crate::workload::streams;

/// A parametric length distribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LengthDist {
    /// Pareto(scale x_m, shape alpha); heavy-tailed for alpha near 1.
    Pareto { x_m: f64, alpha: f64 },
    /// Log-normal with log-space mean mu and sigma.
    LogNormal { mu: f64, sigma: f64 },
}

/// Synthetic length generator with a clamped support.
#[derive(Debug, Clone)]
pub struct SynthLengths {
    pub dist: LengthDist,
    pub min_len: f64,
    pub max_len: f64,
}

impl SynthLengths {
    pub fn new(
        dist: LengthDist,
        min_len: f64,
        max_len: f64,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(min_len > 0.0 && max_len > min_len, "bad support");
        Ok(SynthLengths { dist, min_len, max_len })
    }

    /// Draw one total token budget.
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        let raw = match self.dist {
            LengthDist::Pareto { x_m, alpha } => rng.pareto(x_m, alpha),
            LengthDist::LogNormal { mu, sigma } => rng.lognormal(mu, sigma),
        };
        raw.clamp(self.min_len, self.max_len)
    }

    /// Build an empirical CDF from `n` Monte-Carlo draws so the synthetic
    /// workload can flow through the same Phase-1 machinery as trace CDFs.
    pub fn to_cdf(&self, n: usize, seed: u64) -> anyhow::Result<EmpiricalCdf> {
        let mut rng = Pcg64::new(seed, streams::SYNTH_CDF);
        let mut draws: Vec<f64> =
            (0..n).map(|_| self.sample(&mut rng)).collect();
        draws.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Take ~64 quantile breakpoints; dedupe equal lengths.
        let mut points: Vec<(f64, f64)> = Vec::new();
        let k = 64.min(n);
        for i in 1..=k {
            let q = i as f64 / k as f64;
            let idx = ((q * n as f64).ceil() as usize).clamp(1, n) - 1;
            let len = draws[idx];
            if let Some(last) = points.last_mut() {
                if len <= last.0 {
                    last.1 = q;
                    continue;
                }
            }
            points.push((len, q));
        }
        if let Some(last) = points.last_mut() {
            last.1 = 1.0;
        }
        EmpiricalCdf::new(points)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_respect_support() {
        let s = SynthLengths::new(
            LengthDist::Pareto { x_m: 100.0, alpha: 1.2 },
            128.0,
            65536.0,
        )
        .unwrap();
        let mut rng = Pcg64::new(1, 0);
        for _ in 0..10_000 {
            let v = s.sample(&mut rng);
            assert!((128.0..=65536.0).contains(&v));
        }
    }

    #[test]
    fn pareto_is_heavier_tailed_than_lognormal() {
        let pareto = SynthLengths::new(
            LengthDist::Pareto { x_m: 200.0, alpha: 1.1 },
            64.0,
            300_000.0,
        )
        .unwrap();
        let logn = SynthLengths::new(
            LengthDist::LogNormal { mu: 5.3, sigma: 0.8 },
            64.0,
            300_000.0,
        )
        .unwrap();
        let mut rng = Pcg64::new(2, 0);
        let n = 50_000;
        let big_p =
            (0..n).filter(|_| pareto.sample(&mut rng) > 10_000.0).count();
        let big_l = (0..n).filter(|_| logn.sample(&mut rng) > 10_000.0).count();
        assert!(big_p > big_l * 5, "pareto {big_p} vs lognormal {big_l}");
    }

    #[test]
    fn to_cdf_matches_sampler() {
        let s = SynthLengths::new(
            LengthDist::LogNormal { mu: 6.0, sigma: 1.0 },
            64.0,
            65536.0,
        )
        .unwrap();
        let cdf = s.to_cdf(50_000, 3).unwrap();
        // Median of the CDF should be near e^6 ~ 403.
        let med = cdf.quantile(0.5);
        assert!((med - 403.0).abs() / 403.0 < 0.1, "median = {med}");
    }

    #[test]
    fn rejects_bad_support() {
        assert!(SynthLengths::new(
            LengthDist::Pareto { x_m: 1.0, alpha: 1.0 },
            0.0,
            10.0
        )
        .is_err());
        assert!(SynthLengths::new(
            LengthDist::Pareto { x_m: 1.0, alpha: 1.0 },
            10.0,
            5.0
        )
        .is_err());
    }
}
