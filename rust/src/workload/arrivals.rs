//! Poisson arrival process (paper §3.1 Phase 2, step 1).
//!
//! Inter-arrival gaps are Exp(λ); the generator also supports a bursty
//! (Markov-modulated) variant used by the router case study to stress the
//! sub-stream-Poisson approximation the paper calls out in §3.3.

use crate::workload::rng::Pcg64;

/// Generates arrival timestamps in milliseconds.
#[derive(Debug, Clone)]
pub enum ArrivalProcess {
    /// Stationary Poisson at `rate_per_ms`.
    Poisson { rate_per_ms: f64 },
    /// Two-state Markov-modulated Poisson process: alternates between a
    /// base rate and a burst rate with exponentially distributed dwell
    /// times. Mean rate = weighted average by dwell fractions.
    Mmpp {
        base_per_ms: f64,
        burst_per_ms: f64,
        mean_base_dwell_ms: f64,
        mean_burst_dwell_ms: f64,
    },
}

impl ArrivalProcess {
    /// Poisson process from a req/s rate (the paper quotes λ in req/s).
    pub fn poisson_rps(rate_per_s: f64) -> Self {
        ArrivalProcess::Poisson { rate_per_ms: rate_per_s / 1000.0 }
    }

    /// Long-run mean arrival rate (req/ms).
    pub fn mean_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate_per_ms } => rate_per_ms,
            ArrivalProcess::Mmpp {
                base_per_ms,
                burst_per_ms,
                mean_base_dwell_ms,
                mean_burst_dwell_ms,
            } => {
                let total = mean_base_dwell_ms + mean_burst_dwell_ms;
                (base_per_ms * mean_base_dwell_ms
                    + burst_per_ms * mean_burst_dwell_ms)
                    / total
            }
        }
    }

    /// Generate the first `n` arrival times (ms, ascending from ~0).
    pub fn generate(&self, n: usize, rng: &mut Pcg64) -> Vec<f64> {
        let mut times = Vec::with_capacity(n);
        match *self {
            ArrivalProcess::Poisson { rate_per_ms } => {
                assert!(rate_per_ms > 0.0);
                let mut t = 0.0;
                for _ in 0..n {
                    t += rng.exponential(rate_per_ms);
                    times.push(t);
                }
            }
            ArrivalProcess::Mmpp {
                base_per_ms,
                burst_per_ms,
                mean_base_dwell_ms,
                mean_burst_dwell_ms,
            } => {
                assert!(base_per_ms > 0.0 && burst_per_ms > 0.0);
                let mut t = 0.0;
                let mut in_burst = false;
                let mut phase_end = rng.exponential(1.0 / mean_base_dwell_ms);
                while times.len() < n {
                    let rate = if in_burst { burst_per_ms } else { base_per_ms };
                    let next = t + rng.exponential(rate);
                    if next > phase_end {
                        t = phase_end;
                        in_burst = !in_burst;
                        let dwell = if in_burst {
                            mean_burst_dwell_ms
                        } else {
                            mean_base_dwell_ms
                        };
                        phase_end = t + rng.exponential(1.0 / dwell);
                    } else {
                        t = next;
                        times.push(t);
                    }
                }
            }
        }
        times
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_calibrated() {
        let p = ArrivalProcess::poisson_rps(100.0);
        let mut rng = Pcg64::new(21, 0);
        let n = 100_000;
        let times = p.generate(n, &mut rng);
        let rate = n as f64 / times.last().unwrap();
        assert!((rate - 0.1).abs() / 0.1 < 0.02, "rate = {rate}/ms");
    }

    #[test]
    fn arrivals_ascend() {
        let p = ArrivalProcess::poisson_rps(50.0);
        let mut rng = Pcg64::new(22, 0);
        let times = p.generate(10_000, &mut rng);
        assert!(times.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn poisson_gap_scv_is_one() {
        let p = ArrivalProcess::poisson_rps(10.0);
        let mut rng = Pcg64::new(23, 0);
        let times = p.generate(100_000, &mut rng);
        let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>()
            / gaps.len() as f64;
        let scv = var / (mean * mean);
        assert!((scv - 1.0).abs() < 0.03, "scv = {scv}");
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        let m = ArrivalProcess::Mmpp {
            base_per_ms: 0.01,
            burst_per_ms: 0.2,
            mean_base_dwell_ms: 5_000.0,
            mean_burst_dwell_ms: 1_000.0,
        };
        let mut rng = Pcg64::new(24, 0);
        let times = m.generate(50_000, &mut rng);
        let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>()
            / gaps.len() as f64;
        let scv = var / (mean * mean);
        assert!(scv > 1.5, "MMPP scv = {scv}, expected bursty (>1)");
    }

    #[test]
    fn mmpp_mean_rate() {
        let m = ArrivalProcess::Mmpp {
            base_per_ms: 0.01,
            burst_per_ms: 0.05,
            mean_base_dwell_ms: 3_000.0,
            mean_burst_dwell_ms: 1_000.0,
        };
        assert!((m.mean_rate() - 0.02).abs() < 1e-12);
        let mut rng = Pcg64::new(25, 0);
        let n = 200_000;
        let times = m.generate(n, &mut rng);
        let rate = n as f64 / times.last().unwrap();
        assert!((rate - 0.02).abs() / 0.02 < 0.05, "rate = {rate}");
    }
}
