//! Arrival processes (paper §3.1 Phase 2, step 1).
//!
//! The stationary default draws Exp(λ) inter-arrival gaps; the generator
//! also supports a bursty (Markov-modulated) variant used by the router
//! case study to stress the sub-stream-Poisson approximation the paper
//! calls out in §3.3, a piecewise-rate **non-homogeneous** Poisson
//! process (NHPP, thinning-based) for diurnal/peaked load, and a
//! **trace replay** variant that consumes explicit arrival timestamps.
//! The last two are what the windowed-SLO scenarios run on: a fleet
//! sized for the long-run mean rate can fail its P99 SLO during peak
//! windows, which stationary arrivals cannot express.

use crate::workload::rng::Pcg64;

/// Generates arrival timestamps in milliseconds.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Stationary Poisson at `rate_per_ms`.
    Poisson { rate_per_ms: f64 },
    /// Two-state Markov-modulated Poisson process: alternates between a
    /// base rate and a burst rate with exponentially distributed dwell
    /// times. Mean rate = weighted average by dwell fractions.
    Mmpp {
        base_per_ms: f64,
        burst_per_ms: f64,
        mean_base_dwell_ms: f64,
        mean_burst_dwell_ms: f64,
    },
    /// Piecewise-constant-rate NHPP, sampled by thinning (Lewis &
    /// Shedler): candidates are drawn at the profile's max rate and
    /// accepted with probability `rate(t) / rate_max`.
    ///
    /// `profile` is a sorted list of `(t_ms, rate_per_ms)` breakpoints
    /// starting at `t_ms = 0`; the rate at time `t` is the rate of the
    /// last breakpoint at or before `t`. When `period_ms` is finite the
    /// profile repeats cyclically (diurnal load); when infinite, the
    /// final rate extends forever.
    Nhpp { profile: Vec<(f64, f64)>, period_ms: f64 },
    /// Replay explicit arrival timestamps (ms, ascending from ~0 — the
    /// wrap-around lap offset and `mean_rate` treat the last timestamp
    /// as the trace span, so offset traces must be normalized first;
    /// [`crate::workload::spec::WorkloadSpec::with_replay`] does this),
    /// with a rate-scaling knob: `rate_scale = 2.0` compresses every gap
    /// so the trace arrives twice as fast. Asking for more arrivals than
    /// the trace holds wraps around, offsetting each lap by the trace
    /// span (so long simulations replay the trace end to end).
    TraceReplay { timestamps: Vec<f64>, rate_scale: f64 },
}

impl ArrivalProcess {
    /// Poisson process from a req/s rate (the paper quotes λ in req/s).
    pub fn poisson_rps(rate_per_s: f64) -> Self {
        ArrivalProcess::Poisson { rate_per_ms: rate_per_s / 1000.0 }
    }

    /// NHPP from `(t_ms, req/s)` breakpoints repeating every `period_ms`
    /// (pass `f64::INFINITY` for a non-cyclic profile).
    pub fn nhpp_rps(profile_rps: &[(f64, f64)], period_ms: f64) -> Self {
        let profile: Vec<(f64, f64)> = profile_rps
            .iter()
            .map(|&(t, rps)| (t, rps / 1000.0))
            .collect();
        validate_profile(&profile, period_ms);
        ArrivalProcess::Nhpp { profile, period_ms }
    }

    /// Long-run mean arrival rate (req/ms).
    pub fn mean_rate(&self) -> f64 {
        match self {
            ArrivalProcess::Poisson { rate_per_ms } => *rate_per_ms,
            ArrivalProcess::Mmpp {
                base_per_ms,
                burst_per_ms,
                mean_base_dwell_ms,
                mean_burst_dwell_ms,
            } => {
                let total = mean_base_dwell_ms + mean_burst_dwell_ms;
                (base_per_ms * mean_base_dwell_ms
                    + burst_per_ms * mean_burst_dwell_ms)
                    / total
            }
            ArrivalProcess::Nhpp { profile, period_ms } => {
                if !period_ms.is_finite() {
                    // Non-cyclic: the final segment dominates the long run.
                    return profile.last().map_or(0.0, |&(_, r)| r);
                }
                // Time-weighted average over one period.
                let mut acc = 0.0;
                for (i, &(t, r)) in profile.iter().enumerate() {
                    let end = profile
                        .get(i + 1)
                        .map_or(*period_ms, |&(t_next, _)| t_next);
                    acc += r * (end - t);
                }
                acc / period_ms
            }
            ArrivalProcess::TraceReplay { timestamps, rate_scale } => {
                let span = timestamps.last().copied().unwrap_or(0.0);
                if span <= 0.0 {
                    return 0.0;
                }
                timestamps.len() as f64 / span * rate_scale
            }
        }
    }

    /// Generate the first `n` arrival times (ms, ascending from ~0).
    pub fn generate(&self, n: usize, rng: &mut Pcg64) -> Vec<f64> {
        let mut times = Vec::with_capacity(n);
        match self {
            ArrivalProcess::Poisson { rate_per_ms } => {
                assert!(*rate_per_ms > 0.0);
                let mut t = 0.0;
                for _ in 0..n {
                    t += rng.exponential(*rate_per_ms);
                    times.push(t);
                }
            }
            ArrivalProcess::Mmpp {
                base_per_ms,
                burst_per_ms,
                mean_base_dwell_ms,
                mean_burst_dwell_ms,
            } => {
                assert!(*base_per_ms > 0.0 && *burst_per_ms > 0.0);
                let mut t = 0.0;
                let mut in_burst = false;
                let mut phase_end = rng.exponential(1.0 / mean_base_dwell_ms);
                while times.len() < n {
                    let rate =
                        if in_burst { *burst_per_ms } else { *base_per_ms };
                    let next = t + rng.exponential(rate);
                    if next > phase_end {
                        t = phase_end;
                        in_burst = !in_burst;
                        let dwell = if in_burst {
                            *mean_burst_dwell_ms
                        } else {
                            *mean_base_dwell_ms
                        };
                        phase_end = t + rng.exponential(1.0 / dwell);
                    } else {
                        t = next;
                        times.push(t);
                    }
                }
            }
            ArrivalProcess::Nhpp { profile, period_ms } => {
                validate_profile(profile, *period_ms);
                let rate_max = profile
                    .iter()
                    .map(|&(_, r)| r)
                    .fold(0.0f64, f64::max);
                assert!(rate_max > 0.0);
                let mut t = 0.0;
                while times.len() < n {
                    t += rng.exponential(rate_max);
                    let rate = rate_at(profile, *period_ms, t);
                    if rng.uniform() < rate / rate_max {
                        times.push(t);
                    }
                }
            }
            ArrivalProcess::TraceReplay { timestamps, rate_scale } => {
                assert!(!timestamps.is_empty(), "empty replay trace");
                assert!(*rate_scale > 0.0);
                assert!(
                    timestamps[0] >= 0.0
                        && timestamps.windows(2).all(|w| w[0] <= w[1]),
                    "replay timestamps must be ascending and non-negative"
                );
                let span = *timestamps.last().unwrap();
                assert!(span > 0.0, "replay trace span must be positive");
                for i in 0..n {
                    let lap = (i / timestamps.len()) as f64;
                    let t = timestamps[i % timestamps.len()];
                    times.push((lap * span + t) / rate_scale);
                }
            }
        }
        times
    }
}

/// The profile rate (req/ms) in effect at absolute time `t` (shared
/// with the chunked generator in `workload::generator`).
pub(crate) fn rate_at(profile: &[(f64, f64)], period_ms: f64, t: f64) -> f64 {
    let phase = if period_ms.is_finite() { t % period_ms } else { t };
    let mut rate = profile[0].1;
    for &(start, r) in profile {
        if start <= phase {
            rate = r;
        } else {
            break;
        }
    }
    rate
}

fn validate_profile(profile: &[(f64, f64)], period_ms: f64) {
    assert!(!profile.is_empty(), "NHPP profile must have breakpoints");
    assert!(profile[0].0 == 0.0, "NHPP profile must start at t = 0");
    assert!(
        profile.windows(2).all(|w| w[0].0 < w[1].0),
        "NHPP breakpoints must be strictly ascending"
    );
    assert!(
        profile.iter().all(|&(_, r)| r > 0.0 && r.is_finite()),
        "NHPP rates must be positive"
    );
    assert!(
        period_ms > profile.last().unwrap().0,
        "NHPP period must cover the last breakpoint"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_is_calibrated() {
        let p = ArrivalProcess::poisson_rps(100.0);
        let mut rng = Pcg64::new(21, 0);
        let n = 100_000;
        let times = p.generate(n, &mut rng);
        let rate = n as f64 / times.last().unwrap();
        assert!((rate - 0.1).abs() / 0.1 < 0.02, "rate = {rate}/ms");
    }

    #[test]
    fn arrivals_ascend() {
        let p = ArrivalProcess::poisson_rps(50.0);
        let mut rng = Pcg64::new(22, 0);
        let times = p.generate(10_000, &mut rng);
        assert!(times.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn poisson_gap_scv_is_one() {
        let p = ArrivalProcess::poisson_rps(10.0);
        let mut rng = Pcg64::new(23, 0);
        let times = p.generate(100_000, &mut rng);
        let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>()
            / gaps.len() as f64;
        let scv = var / (mean * mean);
        assert!((scv - 1.0).abs() < 0.03, "scv = {scv}");
    }

    #[test]
    fn mmpp_is_burstier_than_poisson() {
        let m = ArrivalProcess::Mmpp {
            base_per_ms: 0.01,
            burst_per_ms: 0.2,
            mean_base_dwell_ms: 5_000.0,
            mean_burst_dwell_ms: 1_000.0,
        };
        let mut rng = Pcg64::new(24, 0);
        let times = m.generate(50_000, &mut rng);
        let gaps: Vec<f64> = times.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean) * (g - mean)).sum::<f64>()
            / gaps.len() as f64;
        let scv = var / (mean * mean);
        assert!(scv > 1.5, "MMPP scv = {scv}, expected bursty (>1)");
    }

    #[test]
    fn mmpp_mean_rate() {
        let m = ArrivalProcess::Mmpp {
            base_per_ms: 0.01,
            burst_per_ms: 0.05,
            mean_base_dwell_ms: 3_000.0,
            mean_burst_dwell_ms: 1_000.0,
        };
        assert!((m.mean_rate() - 0.02).abs() < 1e-12);
        let mut rng = Pcg64::new(25, 0);
        let n = 200_000;
        let times = m.generate(n, &mut rng);
        let rate = n as f64 / times.last().unwrap();
        assert!((rate - 0.02).abs() / 0.02 < 0.05, "rate = {rate}");
    }

    /// Empirical per-phase rate of a cyclic NHPP must track the profile
    /// within 3% (the calibration bar for the diurnal scenarios).
    #[test]
    fn nhpp_windowed_rate_matches_profile() {
        let period = 20_000.0;
        let p = ArrivalProcess::nhpp_rps(
            &[(0.0, 40.0), (10_000.0, 200.0)],
            period,
        );
        assert!((p.mean_rate() - 0.120).abs() < 1e-12);
        let mut rng = Pcg64::new(26, 0);
        let n = 240_000; // ~2000 s of simulated arrivals, ~100 cycles
        let times = p.generate(n, &mut rng);
        let horizon = *times.last().unwrap();
        let full_cycles = (horizon / period).floor();
        assert!(full_cycles >= 50.0, "cycles = {full_cycles}");
        let (mut n_lo, mut n_hi) = (0u64, 0u64);
        for &t in &times {
            if t >= full_cycles * period {
                break; // only count whole cycles
            }
            if t % period < 10_000.0 {
                n_lo += 1;
            } else {
                n_hi += 1;
            }
        }
        let lo_rate = n_lo as f64 / (full_cycles * 10_000.0);
        let hi_rate = n_hi as f64 / (full_cycles * 10_000.0);
        assert!((lo_rate - 0.040).abs() / 0.040 < 0.03, "lo = {lo_rate}");
        assert!((hi_rate - 0.200).abs() / 0.200 < 0.03, "hi = {hi_rate}");
    }

    #[test]
    fn nhpp_noncyclic_uses_last_segment_rate() {
        let p = ArrivalProcess::nhpp_rps(
            &[(0.0, 10.0), (1_000.0, 50.0)],
            f64::INFINITY,
        );
        assert!((p.mean_rate() - 0.050).abs() < 1e-12);
        let mut rng = Pcg64::new(27, 0);
        let times = p.generate(50_000, &mut rng);
        // Deep into the tail the empirical rate is the final 50 req/s.
        let tail: Vec<f64> =
            times.iter().copied().filter(|&t| t >= 10_000.0).collect();
        let rate = tail.len() as f64 / (times.last().unwrap() - 10_000.0);
        assert!((rate - 0.050).abs() / 0.050 < 0.03, "tail rate = {rate}");
        assert!(times.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn replay_reproduces_and_scales_timestamps() {
        let ts = vec![1.0, 4.0, 9.0, 10.0];
        let p = ArrivalProcess::TraceReplay {
            timestamps: ts.clone(),
            rate_scale: 1.0,
        };
        let mut rng = Pcg64::new(28, 0);
        assert_eq!(p.generate(4, &mut rng), ts);
        // Wrap-around: lap 2 is offset by the trace span (10 ms).
        let wrapped = p.generate(6, &mut rng);
        assert_eq!(&wrapped[4..], &[11.0, 14.0]);
        // rate_scale = 2 halves every timestamp (twice the arrival rate).
        let fast = ArrivalProcess::TraceReplay {
            timestamps: ts,
            rate_scale: 2.0,
        };
        assert_eq!(fast.generate(4, &mut rng), vec![0.5, 2.0, 4.5, 5.0]);
        assert!((fast.mean_rate() - 0.8).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "start at t = 0")]
    fn nhpp_profile_must_start_at_zero() {
        ArrivalProcess::nhpp_rps(&[(5.0, 10.0)], 100.0);
    }
}
