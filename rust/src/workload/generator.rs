//! Pull-based chunked request generation (the scale path).
//!
//! [`WorkloadSpec::sample_requests`] materializes the whole stream — fine
//! for 10^4-10^5 requests, hopeless for 10^8. [`RequestGenerator`] yields
//! the *identical* stream lazily, so the DES holds only the chunk it is
//! currently consuming: O(in-flight) memory instead of O(requests).
//! `sample_requests` itself is implemented on top of the generator, which
//! makes "generator vs materialized" bit-identity true by construction
//! (and pinned by tests anyway).
//!
//! # Determinism: per-block RNG substreams
//!
//! Request indices are split into fixed blocks of [`GEN_BLOCK`]. Block
//! `k` draws arrivals from stream `4 + 2k` and token lengths from
//! stream `5 + 2k` — see [`crate::workload::streams`] for the full
//! allocation map (streams 1-3 are reserved by the simulator for the
//! legacy whole-run arrival/length/routing streams).
//! Consequences:
//!
//! * a request's random draws depend only on its global index, the seed,
//!   and the carried arrival clock — never on the consumer's chunk size;
//! * any block can be regenerated in isolation from a tiny
//!   [`GenState`] checkpoint (block start index + arrival clock), which
//!   is what lets a sharded or resumed run re-derive an arbitrary slice
//!   of the stream without replaying everything before it.
//!
//! The arrival clock `t_ms` is part of the checkpoint because arrival
//! processes are cumulative (Poisson/NHPP gaps add up); trace replay is
//! a pure function of the index and carries no RNG state at all.
//!
//! MMPP ([`ArrivalProcess::Mmpp`]) is deliberately not supported here:
//! `WorkloadSpec` cannot express it, and its phase state would bloat the
//! checkpoint. The batch [`ArrivalProcess::generate`] path still covers
//! it for the router case study.

use crate::workload::arrivals::{rate_at, ArrivalProcess};
use crate::workload::rng::Pcg64;
use crate::workload::spec::{SampledRequest, WorkloadSpec};
use crate::workload::streams;

/// Requests per RNG block. Fixed by the determinism contract — changing
/// it changes every sampled stream (it is *not* a tuning knob; the
/// consumer-side chunk size is independent and free to vary).
pub const GEN_BLOCK: usize = 8192;

/// A resumable generator position: the next global request index plus
/// the arrival clock carried into it. Only block-boundary checkpoints
/// (`next_index % GEN_BLOCK == 0`) are resumable, because within a block
/// the RNG streams have consumed draws the checkpoint does not capture.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GenState {
    /// Global index of the next request to be generated.
    pub next_index: u64,
    /// Arrival time of the previous request (0 at the stream origin).
    pub t_ms: f64,
}

impl GenState {
    /// The stream origin.
    pub fn origin() -> Self {
        GenState { next_index: 0, t_ms: 0.0 }
    }
}

enum ArrivalGen {
    Poisson {
        rate_per_ms: f64,
    },
    Nhpp {
        profile: Vec<(f64, f64)>,
        period_ms: f64,
        rate_max: f64,
    },
    Replay {
        timestamps: Vec<f64>,
        rate_scale: f64,
        span: f64,
    },
}

/// Lazy, deterministic sampled-request stream for one `(workload, seed)`
/// pair. See the module docs for the substream scheme.
pub struct RequestGenerator {
    arrivals: ArrivalGen,
    cdf: crate::workload::cdf::EmpiricalCdf,
    input_fraction: f64,
    seed: u64,
    state: GenState,
    arr_rng: Pcg64,
    len_rng: Pcg64,
}

impl RequestGenerator {
    /// Generator positioned at the stream origin.
    pub fn new(spec: &WorkloadSpec, seed: u64) -> Self {
        Self::resume(spec, seed, GenState::origin())
    }

    /// Generator positioned at a block-boundary checkpoint previously
    /// returned by [`RequestGenerator::state`].
    pub fn resume(spec: &WorkloadSpec, seed: u64, state: GenState) -> Self {
        assert!(
            state.next_index % GEN_BLOCK as u64 == 0,
            "GenState must sit on a GEN_BLOCK boundary (got index {})",
            state.next_index
        );
        let arrivals = match spec.arrival_process() {
            ArrivalProcess::Poisson { rate_per_ms } => {
                assert!(rate_per_ms > 0.0);
                ArrivalGen::Poisson { rate_per_ms }
            }
            ArrivalProcess::Nhpp { profile, period_ms } => {
                let rate_max =
                    profile.iter().map(|&(_, r)| r).fold(0.0f64, f64::max);
                assert!(rate_max > 0.0);
                ArrivalGen::Nhpp { profile, period_ms, rate_max }
            }
            ArrivalProcess::TraceReplay { timestamps, rate_scale } => {
                assert!(!timestamps.is_empty(), "empty replay trace");
                assert!(rate_scale > 0.0);
                let span = *timestamps.last().unwrap();
                assert!(span > 0.0, "replay trace span must be positive");
                ArrivalGen::Replay { timestamps, rate_scale, span }
            }
            ArrivalProcess::Mmpp { .. } => {
                unreachable!("WorkloadSpec cannot express MMPP arrivals")
            }
        };
        let block = state.next_index / GEN_BLOCK as u64;
        let (arr_rng, len_rng) = Self::block_rngs(seed, block);
        RequestGenerator {
            arrivals,
            cdf: spec.cdf.clone(),
            input_fraction: spec.input_fraction,
            seed,
            state,
            arr_rng,
            len_rng,
        }
    }

    fn block_rngs(seed: u64, block: u64) -> (Pcg64, Pcg64) {
        let (arr, len) = streams::block_streams(block);
        (Pcg64::new(seed, arr), Pcg64::new(seed, len))
    }

    /// The current position. Resumable via [`RequestGenerator::resume`]
    /// exactly when it sits on a `GEN_BLOCK` boundary (capture it right
    /// after a multiple of `GEN_BLOCK` requests have been generated).
    pub fn state(&self) -> GenState {
        self.state
    }

    fn next_arrival(&mut self) -> f64 {
        match &self.arrivals {
            ArrivalGen::Poisson { rate_per_ms } => {
                self.state.t_ms += self.arr_rng.exponential(*rate_per_ms);
                self.state.t_ms
            }
            ArrivalGen::Nhpp { profile, period_ms, rate_max } => {
                // Lewis-Shedler thinning, continuing from the carried
                // clock. The candidate loop may span a block boundary;
                // that is fine because rotation is keyed on *emitted*
                // requests, and the clock is part of the checkpoint.
                let mut t = self.state.t_ms;
                loop {
                    t += self.arr_rng.exponential(*rate_max);
                    let rate = rate_at(profile, *period_ms, t);
                    if self.arr_rng.uniform() < rate / rate_max {
                        self.state.t_ms = t;
                        return t;
                    }
                }
            }
            ArrivalGen::Replay { timestamps, rate_scale, span } => {
                // Identical formula to ArrivalProcess::generate: a pure
                // function of the global index (no RNG draws).
                let i = self.state.next_index as usize;
                let lap = (i / timestamps.len()) as f64;
                let t = timestamps[i % timestamps.len()];
                self.state.t_ms = (lap * span + t) / rate_scale;
                self.state.t_ms
            }
        }
    }

    /// Generate the next request in the stream.
    pub fn next_request(&mut self) -> SampledRequest {
        let arrival_ms = self.next_arrival();
        let total = self.cdf.sample(&mut self.len_rng);
        let l_in = (total * self.input_fraction).ceil().max(1.0);
        let l_out = (total - l_in).max(1.0);
        self.state.next_index += 1;
        if self.state.next_index % GEN_BLOCK as u64 == 0 {
            let block = self.state.next_index / GEN_BLOCK as u64;
            let (a, l) = Self::block_rngs(self.seed, block);
            self.arr_rng = a;
            self.len_rng = l;
        }
        SampledRequest { arrival_ms, l_in, l_out }
    }

    /// Append the next `n` requests to `out` (the chunked-pull API: the
    /// caller owns the buffer and its size; determinism is unaffected).
    pub fn fill(&mut self, out: &mut Vec<SampledRequest>, n: usize) {
        out.reserve(n);
        for _ in 0..n {
            out.push(self.next_request());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec::BuiltinTrace;

    fn specs() -> Vec<WorkloadSpec> {
        vec![
            WorkloadSpec::builtin(BuiltinTrace::Lmsys, 200.0),
            WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0)
                .with_nhpp(vec![(0.0, 40.0), (10_000.0, 200.0)], 20_000.0),
            WorkloadSpec::builtin(BuiltinTrace::Agent, 20.0).with_replay(
                (0..500).map(|i| i as f64 * 7.0).collect(),
                1.0,
            ),
        ]
    }

    #[test]
    fn chunked_pulls_match_materialized_for_any_chunk_size() {
        for w in specs() {
            let want = w.sample_requests(3 * GEN_BLOCK + 100, 42);
            for chunk in [1usize, 7, 1000, GEN_BLOCK, GEN_BLOCK + 1] {
                let mut gen = RequestGenerator::new(&w, 42);
                let mut got = Vec::new();
                while got.len() < want.len() {
                    let n = chunk.min(want.len() - got.len());
                    gen.fill(&mut got, n);
                }
                assert_eq!(got, want, "{} chunk={chunk}", w.name);
            }
        }
    }

    #[test]
    fn block_checkpoint_resumes_in_isolation() {
        for w in specs() {
            let mut gen = RequestGenerator::new(&w, 7);
            let mut head = Vec::new();
            gen.fill(&mut head, 2 * GEN_BLOCK);
            let ckpt = gen.state();
            assert_eq!(ckpt.next_index, 2 * GEN_BLOCK as u64);
            let mut tail_live = Vec::new();
            gen.fill(&mut tail_live, GEN_BLOCK);

            // A fresh generator seeded only with the checkpoint must
            // reproduce the third block bit-for-bit.
            let mut resumed = RequestGenerator::resume(&w, 7, ckpt);
            let mut tail_resumed = Vec::new();
            resumed.fill(&mut tail_resumed, GEN_BLOCK);
            assert_eq!(tail_live, tail_resumed, "{}", w.name);
        }
    }

    #[test]
    #[should_panic(expected = "GEN_BLOCK boundary")]
    fn mid_block_resume_is_rejected() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 200.0);
        let state = GenState { next_index: 17, t_ms: 0.0 };
        RequestGenerator::resume(&w, 42, state);
    }

    #[test]
    fn seeds_produce_distinct_streams() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
        let mut a = RequestGenerator::new(&w, 1);
        let mut b = RequestGenerator::new(&w, 2);
        let (mut va, mut vb) = (Vec::new(), Vec::new());
        a.fill(&mut va, 64);
        b.fill(&mut vb, 64);
        assert_ne!(va, vb);
    }
}
