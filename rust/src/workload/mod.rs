//! Workload substrate: token-length CDFs, synthetic length distributions,
//! Poisson arrival processes, and the RNG they share (paper §3.3).

pub mod arrivals;
pub mod builtin;
pub mod cdf;
pub mod generator;
pub mod rng;
pub mod spec;
pub mod streams;
pub mod synth;
