//! Named registry of PCG64 stream ids.
//!
//! Every non-test `Pcg64::new(seed, stream)` in the simulator must
//! take its stream id from this module; detlint rule R3 rejects bare
//! integer literals. Centralizing the ids makes collisions visible in
//! one place: two call sites sharing a `(seed, stream)` pair silently
//! correlate their draws, which breaks the independence assumptions
//! behind the generator/stream equivalence tests and the
//! fault-injection determinism contract.
//!
//! Allocation map:
//!
//! | stream        | owner                                        |
//! |---------------|----------------------------------------------|
//! | 0             | free (tests use it ad hoc)                   |
//! | 1             | fault-script sampling (salted seed)          |
//! | 2             | retry backoff jitter (salted seed)           |
//! | 3             | DES routing (all three engines)              |
//! | 4 + 2k        | generator block `k`: arrival gaps            |
//! | 5 + 2k        | generator block `k`: token lengths           |
//! | 9             | disaggregated-pool sizing simulation         |
//! | 11            | correlated-burst substream diagnostic        |
//! | 77            | synthetic length-distribution CDF sampling   |
//!
//! The generator block lattice occupies every id from 4 upward, so
//! `DISAGG_SIM`, `CORRELATED_BURST`, and `SYNTH_CDF` numerically
//! coincide with the length streams of blocks 2, 3, and 36. The ids
//! are kept anyway for bit-compatibility with existing results, and
//! the overlap is harmless today: none of those three paths feeds
//! draws into the same statistical estimate as a generator block at
//! the same seed. The hard invariant — checked by the tests below —
//! is that the streams which *do* coexist inside one DES run
//! (`ROUTING`, `FAULT_SCRIPT`, and the block lattice) never collide.

/// Routing decisions for the production, reference, and sharded DES
/// engines. All three must draw from the same stream so their
/// per-request pool choices are bit-identical.
pub const ROUTING: u64 = 3;

/// Fault-script sampling. Paired with a salted seed
/// (`seed.wrapping_add(FAULT_SEED_SALT)`) so fault timing never
/// correlates with workload draws even where stream ids coincide.
pub const FAULT_SCRIPT: u64 = 1;

/// Retry backoff jitter ([`crate::des::retry`]). Paired with a salted
/// seed mixed with the global request id and attempt number, so every
/// engine (and every shard) derives the identical backoff schedule as
/// a pure function of `(seed, request, attempt)` — no draw-order
/// coupling with any other stream.
pub const RETRY: u64 = 2;

/// First stream of the generator block lattice; block `k` uses
/// `BLOCK_BASE + 2k` (arrivals) and `BLOCK_BASE + 2k + 1` (lengths).
pub const BLOCK_BASE: u64 = 4;

/// Sampling a synthetic length distribution into an empirical CDF.
pub const SYNTH_CDF: u64 = 77;

/// Monte-Carlo sizing runs inside the disaggregated-pool optimizer.
pub const DISAGG_SIM: u64 = 9;

/// Correlated-burst generator in the substream diagnostic report.
pub const CORRELATED_BURST: u64 = 11;

/// Stream ids for generator block `k`: `(arrivals, lengths)`.
pub fn block_streams(block: u64) -> (u64, u64) {
    let base = BLOCK_BASE + 2 * block;
    (base, base + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn within_run_streams_never_collide() {
        // ROUTING and FAULT_SCRIPT share a run (and FAULT_SCRIPT
        // additionally salts its seed) with the block lattice; they
        // must sit strictly below BLOCK_BASE.
        assert!(ROUTING < BLOCK_BASE);
        assert!(FAULT_SCRIPT < BLOCK_BASE);
        assert!(RETRY < BLOCK_BASE);
        assert_ne!(ROUTING, FAULT_SCRIPT);
        assert_ne!(ROUTING, RETRY);
        assert_ne!(FAULT_SCRIPT, RETRY);
    }

    #[test]
    fn block_lattice_shape() {
        for k in 0..64 {
            let (a, l) = block_streams(k);
            assert_eq!(a, 4 + 2 * k);
            assert_eq!(l, a + 1);
        }
        // Adjacent blocks tile the id space without gaps or overlap.
        let (_, l0) = block_streams(0);
        let (a1, _) = block_streams(1);
        assert_eq!(a1, l0 + 1);
    }

    #[test]
    fn legacy_ids_are_pinned() {
        // These values are part of the bit-compatibility surface:
        // changing any of them changes published results.
        assert_eq!(SYNTH_CDF, 77);
        assert_eq!(DISAGG_SIM, 9);
        assert_eq!(CORRELATED_BURST, 11);
    }
}
