//! Windowed-SLO reporting: render a DES run's per-window TTFT series
//! (the table behind `fleet-sim simulate --window` and the diurnal
//! scenario). Unserved arrivals and empty windows are shown honestly:
//! `-` marks an undefined statistic, never a vacuous 100%.

use crate::des::metrics::{DesResult, WindowedStats};
use crate::util::table::{millis, percent, Table};

/// Shared `[t0, t0+w) s` label so every windowed table (CLI simulate,
/// the diurnal scenario) renders windows identically.
pub fn window_label(w: &WindowedStats, i: usize) -> String {
    let width_s = w.width_ms() / 1000.0;
    let start_s = w.start_ms(i) / 1000.0;
    format!("[{:.0}, {:.0}) s", start_s, start_s + width_s)
}

/// Shared SLO verdict cell: `-` for an empty window, else yes/FAIL.
pub fn window_verdict(
    w: &mut WindowedStats,
    i: usize,
    slo_ms: f64,
) -> String {
    if w.n_arrived(i) == 0 {
        "-".to_string()
    } else if w.meets_slo(i, slo_ms) {
        "yes".to_string()
    } else {
        "FAIL".to_string()
    }
}

/// One window's rendered row: `[t0, t0+w) | arrivals | unserved |
/// dropped | preempted | P99 | attainment | SLO`. "dropped" counts
/// closed-loop terminal failures — shed by admission control plus
/// abandoned after the retry budget — among the window's arrivals (0 on
/// open-loop runs). "preempted" counts KV-cache eviction events in the
/// window (0 without a memory model).
fn window_row(w: &mut WindowedStats, i: usize, slo_ms: f64) -> Vec<String> {
    vec![
        window_label(w, i),
        w.n_arrived(i).to_string(),
        w.n_unserved(i).to_string(),
        (w.n_shed(i) + w.n_abandoned(i)).to_string(),
        w.n_preempted(i).to_string(),
        millis(w.p99_ttft(i)),
        percent(w.attainment(i, slo_ms)),
        window_verdict(w, i, slo_ms),
    ]
}

/// Per-window P99-TTFT / attainment table for a windowed DES run.
/// Returns None when the run collected no windows (no
/// `DesConfig::window_ms`).
pub fn windowed_table(r: &mut DesResult, slo_ms: f64) -> Option<Table> {
    let w = r.windows.as_mut()?;
    let mut t = Table::new(&[
        "window", "arrivals", "unserved", "dropped", "preempted",
        "P99 TTFT", "attainment", "SLO",
    ])
    .with_title(format!(
        "Windowed SLO evaluation ({} ms windows, SLO {} ms)",
        w.width_ms(),
        slo_ms
    ));
    for i in 0..w.n_windows() {
        t.row(&window_row(w, i, slo_ms));
    }
    Some(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::engine::{DesConfig, SimPool, Simulator};
    use crate::gpu::catalog::GpuCatalog;
    use crate::router::RoutingPolicy;
    use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

    #[test]
    fn renders_one_row_per_window() {
        let gpu = GpuCatalog::standard().get("H100").unwrap().clone();
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 80.0);
        let pools = vec![SimPool {
            gpu, n_gpus: 6, ctx_budget: 8192.0, batch_cap: None,
        }];
        let cfg = DesConfig {
            n_requests: 3_000,
            window_ms: Some(10_000.0),
            ..Default::default()
        };
        let mut r = Simulator::new(
            w, pools, RoutingPolicy::Random { n_pools: 1 }, cfg,
        )
        .run();
        let n_windows = r.windows.as_ref().unwrap().n_windows();
        let table = windowed_table(&mut r, 500.0).unwrap();
        assert_eq!(table.n_rows(), n_windows);
        let body = table.render();
        assert!(body.contains("Windowed SLO evaluation"), "{body}");

        // A run without window collection renders nothing.
        let mut plain = Simulator::new(
            WorkloadSpec::builtin(BuiltinTrace::Azure, 80.0),
            vec![SimPool {
                gpu: GpuCatalog::standard().get("H100").unwrap().clone(),
                n_gpus: 6,
                ctx_budget: 8192.0,
                batch_cap: None,
            }],
            RoutingPolicy::Random { n_pools: 1 },
            DesConfig { n_requests: 500, ..Default::default() },
        )
        .run();
        assert!(windowed_table(&mut plain, 500.0).is_none());
    }
}
