//! Ablation study for the reproduction's key modeling choice
//! (DESIGN.md "Key modeling decisions" #2): evaluating `t_iter` at the
//! pool's *equilibrium* concurrency versus the paper's literal Eq. 4
//! reading (`t_iter(n_max)`).
//!
//! The ablation replays the Phase-1 sizing of a homogeneous fleet under
//! both service models and compares each against the DES — showing why
//! the equilibrium model was adopted: the n_max model over-sizes fleets
//! and over-predicts lightly-loaded TTFT by the full batch-inflation
//! factor, and it cannot reproduce Table 9's cap-independent analytic
//! column.

use crate::des::engine::{DesConfig, SimPool, Simulator};
use crate::gpu::profile::GpuProfile;
use crate::queueing::kimura;
use crate::queueing::mgc::{analyze_pool, PoolSpec, WorkloadHist};
use crate::router::RoutingPolicy;
use crate::util::table::{millis, Align, Table};
use crate::workload::spec::WorkloadSpec;

/// P99 TTFT under the literal-Eq.4 ablation: t_iter fixed at n_eff.
pub fn nmax_model_p99(
    hist: &WorkloadHist,
    gpu: &GpuProfile,
    n_gpus: usize,
    ctx: f64,
    lambda_ms: f64,
) -> (f64, f64) {
    let n = gpu.n_eff(ctx);
    let t = gpu.t_iter(n);
    let mut i1 = 0.0;
    let mut i2 = 0.0;
    for (p, &l) in hist.probs.iter().zip(&hist.lens) {
        let l_in = (l * hist.input_frac).ceil();
        let l_out = (l - l_in).max(1.0);
        let it = gpu.iters(l_in, l_out);
        i1 += p * it;
        i2 += p * it * it;
    }
    let cs2 = (i2 / (i1 * i1) - 1.0).max(0.0);
    let es = i1 * t / n;
    let rho = lambda_ms * es / n_gpus as f64;
    let w99 = kimura::w99(rho, n_gpus.min(512), es, cs2);
    let p99_len = hist.conditional_quantile(0.0, ctx, 0.99);
    let prefill = ((p99_len * hist.input_frac).ceil() / gpu.chunk).ceil() * t;
    (w99 + prefill + t, rho)
}

/// One ablation row: (n_gpus, equilibrium P99, n_max P99, DES P99).
pub fn compare(
    w: &WorkloadSpec,
    gpu: &GpuProfile,
    sizes: &[usize],
    n_requests: usize,
) -> Vec<(usize, f64, f64, f64)> {
    let ctx = w.cdf.max_len();
    let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
    sizes
        .iter()
        .map(|&n| {
            let eq = analyze_pool(&hist, 0.0, 1e12, w.lambda_per_ms(),
                                  &PoolSpec { gpu: gpu.clone(), n_gpus: n,
                                              ctx_budget: ctx })
                .ttft99_ms;
            let (nm, _) = nmax_model_p99(&hist, gpu, n, ctx, w.lambda_per_ms());
            let sim = Simulator::new(
                w.clone(),
                vec![SimPool { gpu: gpu.clone(), n_gpus: n, ctx_budget: ctx,
                               batch_cap: None }],
                RoutingPolicy::Random { n_pools: 1 },
                DesConfig { n_requests, seed: 13, ..Default::default() },
            );
            let mut r = sim.run();
            (n, eq, nm, r.overall.p99_ttft())
        })
        .collect()
}

/// Render the ablation table.
pub fn table(w: &WorkloadSpec, gpu: &GpuProfile, sizes: &[usize],
             n_requests: usize) -> Table {
    let mut t = Table::new(&["GPUs", "equilibrium model", "n_max model",
                             "DES"])
        .with_title(format!(
            "Service-model ablation ({}, λ={} req/s, {}): P99 TTFT",
            w.name, w.lambda_rps, gpu.name
        ))
        .align(&[Align::Right, Align::Right, Align::Right, Align::Right]);
    for (n, eq, nm, des) in compare(w, gpu, sizes, n_requests) {
        t.row(&[n.to_string(), millis(eq), millis(nm), millis(des)]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::catalog::GpuCatalog;
    use crate::workload::spec::BuiltinTrace;

    #[test]
    fn equilibrium_model_tracks_des_better_than_nmax() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
        let gpu = GpuCatalog::standard().get("H100").unwrap().clone();
        let rows = compare(&w, &gpu, &[10, 14], 6_000);
        for (n, eq, nm, des) in rows {
            let err_eq = (eq - des).abs() / des;
            let err_nm = (nm - des).abs() / des;
            assert!(
                err_eq < err_nm,
                "n={n}: equilibrium err {err_eq:.2} should beat n_max \
                 {err_nm:.2} (eq {eq:.0} nm {nm:.0} des {des:.0})"
            );
            // The n_max model over-predicts lightly-loaded TTFT by the
            // batch-inflation factor.
            assert!(nm > des * 1.5, "n={n}: nm {nm} vs des {des}");
        }
    }
}
