//! Reporting: model-fidelity analysis (paper §3.2), the DES perf
//! harness, windowed-SLO tables, and shared rendering.

pub mod ablation;
pub mod fidelity;
pub mod perf;
pub mod sensitivity;
pub mod substream;
pub mod windows;
