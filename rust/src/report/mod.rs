//! Reporting: model-fidelity analysis (paper §3.2) and shared rendering.

pub mod ablation;
pub mod fidelity;
pub mod sensitivity;
pub mod substream;
