//! Model-fidelity analysis (paper §3.2): Kimura analytical P99 TTFT vs
//! DES, per workload, across utilization levels.
//!
//! The paper's claim: for chatbot workloads (low Cs²) the analytical model
//! is conservative by ~8-14% versus DES; for agent workloads it is not
//! trustworthy and DES is authoritative. This module measures exactly
//! that table for our calibration.

use crate::des::engine::{DesConfig, SimPool, Simulator};
use crate::gpu::profile::GpuProfile;
use crate::queueing::mgc::{analyze_pool, PoolSpec, WorkloadHist};
use crate::router::RoutingPolicy;
use crate::util::table::{millis, Align, Table};
use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

/// One fidelity measurement.
#[derive(Debug, Clone)]
pub struct FidelityRow {
    pub trace: String,
    pub n_gpus: usize,
    pub rho: f64,
    pub cs2: f64,
    pub analytic_ms: f64,
    pub des_ms: f64,
    /// analytic / DES (>1 = conservative).
    pub ratio: f64,
}

/// Measure analytic-vs-DES P99 TTFT for a homogeneous pool at several
/// fleet sizes.
pub fn measure(
    trace: BuiltinTrace,
    lambda: f64,
    gpu: &GpuProfile,
    sizes: &[usize],
    n_requests: usize,
) -> Vec<FidelityRow> {
    let w = WorkloadSpec::builtin(trace, lambda);
    let ctx = w.cdf.max_len();
    let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
    sizes
        .iter()
        .map(|&n| {
            let a = analyze_pool(
                &hist, 0.0, 1e12, w.lambda_per_ms(),
                &PoolSpec { gpu: gpu.clone(), n_gpus: n, ctx_budget: ctx },
            );
            let sim = Simulator::new(
                w.clone(),
                vec![SimPool { gpu: gpu.clone(), n_gpus: n, ctx_budget: ctx,
                               batch_cap: None }],
                RoutingPolicy::Random { n_pools: 1 },
                DesConfig { n_requests, seed: 7, ..Default::default() },
            );
            let mut r = sim.run();
            let des = r.overall.p99_ttft();
            FidelityRow {
                trace: trace.name().into(),
                n_gpus: n,
                rho: a.rho,
                cs2: a.cs2,
                analytic_ms: a.ttft99_ms,
                des_ms: des,
                ratio: if des > 0.0 { a.ttft99_ms / des } else { f64::NAN },
            }
        })
        .collect()
}

/// Render the §3.2 fidelity table for the three builtin traces.
pub fn fidelity_table(gpu: &GpuProfile, n_requests: usize) -> Table {
    let mut t = Table::new(&["Trace", "GPUs", "rho", "Cs2", "Analytic P99",
                             "DES P99", "anal/DES"])
        .with_title("Model fidelity: Kimura (Eq. 2 + Eq. 5) vs DES, \
                     homogeneous H100 pools")
        .align(&[Align::Left, Align::Right, Align::Right, Align::Right,
                 Align::Right, Align::Right, Align::Right]);
    for (trace, lam, sizes) in [
        (BuiltinTrace::Azure, 100.0, [6usize, 8, 12]),
        (BuiltinTrace::Lmsys, 100.0, [14, 18, 24]),
        (BuiltinTrace::Agent, 20.0, [40, 64, 96]),
    ] {
        for r in measure(trace, lam, gpu, &sizes, n_requests) {
            t.row(&[
                r.trace.clone(),
                r.n_gpus.to_string(),
                format!("{:.2}", r.rho),
                format!("{:.1}", r.cs2),
                millis(r.analytic_ms),
                millis(r.des_ms),
                format!("{:.2}", r.ratio),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::catalog::GpuCatalog;

    #[test]
    fn chat_traces_have_low_cs2_agent_high() {
        let gpu = GpuCatalog::standard().get("H100").unwrap().clone();
        let azure = measure(BuiltinTrace::Azure, 100.0, &gpu, &[8], 3000);
        let agent = measure(BuiltinTrace::Agent, 20.0, &gpu, &[64], 3000);
        assert!(azure[0].cs2 < 3.0, "azure cs2 = {}", azure[0].cs2);
        assert!(agent[0].cs2 > azure[0].cs2 * 2.0,
                "agent {} vs azure {}", agent[0].cs2, azure[0].cs2);
    }

    #[test]
    fn fidelity_table_renders_nine_rows() {
        let gpu = GpuCatalog::standard().get("H100").unwrap().clone();
        let t = fidelity_table(&gpu, 2000);
        assert_eq!(t.n_rows(), 9);
    }
}
