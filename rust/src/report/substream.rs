//! Sub-stream Poisson approximation check (paper §3.3 note + §5
//! Limitations).
//!
//! Splitting a Poisson stream by token length is a deterministic rule, not
//! random thinning, so the per-pool sub-streams are not strictly Poisson;
//! and when prompt length correlates with arrival time (long requests
//! arriving in bursts) the analytical queue-length estimates drift. The
//! paper's remedy is "the DES checks whether the approximation holds in
//! each case" — this module is that check, plus the adversarial variant
//! with a Markov-modulated arrival process whose burst state carries
//! longer requests.

use crate::des::engine::{DesConfig, SimPool, Simulator};
use crate::des::metrics::DesResult;
use crate::gpu::profile::GpuProfile;
use crate::queueing::mgc::{analyze_two_pool, PoolSpec, WorkloadHist};
use crate::router::RoutingPolicy;
use crate::util::stats::Samples;
use crate::workload::rng::Pcg64;
use crate::workload::spec::{SampledRequest, WorkloadSpec};
use crate::workload::streams;

/// Result of one approximation check.
#[derive(Debug, Clone)]
pub struct SubstreamCheck {
    /// Analytical P99 TTFT per pool under the Poisson-split assumption.
    pub analytic_short_ms: f64,
    pub analytic_long_ms: f64,
    /// DES-measured P99 TTFT per pool (i.i.d. lengths).
    pub des_short_ms: f64,
    pub des_long_ms: f64,
    /// DES-measured with length-correlated (bursty) arrivals.
    pub bursty_short_ms: f64,
    pub bursty_long_ms: f64,
    /// SCV of the long-pool inter-arrival gaps in the bursty trace
    /// (1 = Poisson; > 1 = bursty).
    pub long_gap_scv: f64,
}

impl SubstreamCheck {
    /// The approximation "holds" when i.i.d. DES is within `tol` of the
    /// analytic prediction on the pool that carries the traffic.
    pub fn holds(&self, tol: f64) -> bool {
        let rel = |a: f64, b: f64| {
            if b <= 1.0 {
                a <= 1.0 + tol
            } else {
                (a - b).abs() / b <= tol
            }
        };
        rel(self.des_short_ms, self.analytic_short_ms)
    }
}

/// Generate a length-correlated request stream: a two-state process where
/// the burst state both raises the arrival rate and draws lengths from the
/// upper `burst_quantile` tail of the CDF — the §5 adversary.
pub fn correlated_requests(
    w: &WorkloadSpec,
    n: usize,
    burst_quantile: f64,
    seed: u64,
) -> Vec<SampledRequest> {
    let mut rng = Pcg64::new(seed, streams::CORRELATED_BURST);
    let base_rate = w.lambda_per_ms();
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    let mut in_burst = false;
    let mut phase_left: f64 = 20_000.0; // ms
    while out.len() < n {
        let rate = if in_burst { base_rate * 2.0 } else { base_rate * 0.8 };
        let gap = rng.exponential(rate);
        t += gap;
        phase_left -= gap;
        if phase_left <= 0.0 {
            in_burst = !in_burst;
            phase_left = if in_burst { 5_000.0 } else { 20_000.0 };
        }
        let q = if in_burst {
            burst_quantile + rng.uniform() * (1.0 - burst_quantile)
        } else {
            rng.uniform() * burst_quantile
        };
        let total = w.cdf.quantile(q);
        let (l_in, l_out) = w.split(total);
        out.push(SampledRequest { arrival_ms: t, l_in, l_out });
    }
    out
}

/// Replay an explicit request stream through the DES core (no workload
/// spec or stream copy needed — `SimInput` borrows everything).
fn simulate_stream(
    reqs: &[SampledRequest],
    pools: Vec<SimPool>,
    b_short: f64,
) -> DesResult {
    let router = RoutingPolicy::Length { b_short };
    let cfg = DesConfig { n_requests: reqs.len(), ..Default::default() };
    let input = crate::des::input::SimInput::stream(
        &pools, &router, &cfg, reqs,
    );
    Simulator::run_input(&input).unwrap()
}

/// Run the full §5 check on a two-pool fleet.
#[allow(clippy::too_many_arguments)]
pub fn substream_check(
    w: &WorkloadSpec,
    gpu: &GpuProfile,
    n_s: usize,
    n_l: usize,
    b_short: f64,
    n_requests: usize,
    burst_quantile: f64,
    seed: u64,
) -> SubstreamCheck {
    let max_len = w.cdf.max_len();
    let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
    let (a_s, a_l) = analyze_two_pool(
        &hist,
        b_short,
        max_len,
        w.lambda_per_ms(),
        &PoolSpec { gpu: gpu.clone(), n_gpus: n_s, ctx_budget: b_short },
        &PoolSpec { gpu: gpu.clone(), n_gpus: n_l, ctx_budget: max_len },
    );
    let pools = || {
        vec![
            SimPool { gpu: gpu.clone(), n_gpus: n_s, ctx_budget: b_short,
                      batch_cap: None },
            SimPool { gpu: gpu.clone(), n_gpus: n_l, ctx_budget: max_len,
                      batch_cap: None },
        ]
    };
    // i.i.d. Poisson baseline.
    let iid = w.sample_requests(n_requests, seed);
    let mut r_iid = simulate_stream(&iid, pools(), b_short);
    // Length-correlated bursts.
    let bursty = correlated_requests(w, n_requests, burst_quantile, seed);
    let mut gaps = Samples::new();
    let mut prev = 0.0;
    for r in bursty.iter().filter(|r| r.total() > b_short) {
        gaps.push(r.arrival_ms - prev);
        prev = r.arrival_ms;
    }
    let scv = gaps.scv();
    let mut r_burst = simulate_stream(&bursty, pools(), b_short);

    SubstreamCheck {
        analytic_short_ms: a_s.ttft99_ms,
        analytic_long_ms: a_l.ttft99_ms,
        des_short_ms: r_iid.per_pool[0].stats.ttft.p99(),
        des_long_ms: r_iid.per_pool[1].stats.ttft.p99(),
        bursty_short_ms: r_burst.per_pool[0].stats.ttft.p99(),
        bursty_long_ms: r_burst.per_pool[1].stats.ttft.p99(),
        long_gap_scv: scv,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::catalog::GpuCatalog;
    use crate::workload::spec::BuiltinTrace;

    fn setup() -> (WorkloadSpec, GpuProfile) {
        (
            WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0),
            GpuCatalog::standard().get("H100").unwrap().clone(),
        )
    }

    #[test]
    fn iid_approximation_holds_on_chat_workload() {
        let (w, gpu) = setup();
        let c = substream_check(&w, &gpu, 6, 3, 3072.0, 10_000, 0.9, 5);
        assert!(c.holds(0.5),
                "analytic {} vs DES {}", c.analytic_short_ms, c.des_short_ms);
    }

    #[test]
    fn correlated_arrivals_are_bursty_and_degrade_tails() {
        let (w, gpu) = setup();
        let c = substream_check(&w, &gpu, 6, 3, 3072.0, 10_000, 0.9, 5);
        // The adversarial stream is genuinely bursty on the long pool…
        assert!(c.long_gap_scv > 1.3, "scv = {}", c.long_gap_scv);
        // …and bursty long-pool latency is no better than i.i.d.
        assert!(c.bursty_long_ms >= c.des_long_ms * 0.9,
                "bursty {} vs iid {}", c.bursty_long_ms, c.des_long_ms);
    }

    #[test]
    fn correlated_stream_is_time_ordered_and_sized() {
        let (w, _) = setup();
        let reqs = correlated_requests(&w, 5_000, 0.9, 7);
        assert_eq!(reqs.len(), 5_000);
        assert!(reqs.windows(2).all(|p| p[0].arrival_ms <= p[1].arrival_ms));
        // Burst draws come from the tail: the stream contains both halves.
        let long = reqs.iter().filter(|r| r.total() > w.cdf.quantile(0.9))
            .count();
        assert!(long > 500, "{long}");
    }
}
