//! Sensitivity analysis over synthetic workloads (paper §3.3, "Poisson
//! with synthetic lengths"): how the optimal split and cost respond to the
//! tail weight of the length distribution.

use crate::gpu::catalog::GpuCatalog;
use crate::optimizer::analytic::{rank_feasible, NativeSweep, SweepEval};
use crate::optimizer::candidates::{generate, GenOptions};
use crate::util::table::{dollars, Align, Table};
use crate::workload::spec::WorkloadSpec;
use crate::workload::synth::{LengthDist, SynthLengths};

/// One sensitivity point.
#[derive(Debug, Clone)]
pub struct SensitivityRow {
    pub label: String,
    pub mean_tokens: f64,
    /// Fraction of requests above 8K tokens (tail weight proxy).
    pub tail_8k: f64,
    /// Best configuration found (label + cost), if any.
    pub best: Option<(String, f64)>,
}

/// Sweep Pareto tail indices and log-normal sigmas at a fixed arrival
/// rate / SLO; returns one row per distribution.
pub fn sweep(lambda_rps: f64, slo_ms: f64, input_frac: f64, seed: u64)
    -> Vec<SensitivityRow>
{
    let catalog = GpuCatalog::standard();
    let mut rows = Vec::new();
    let dists: Vec<(String, LengthDist)> = vec![
        ("pareto a=2.5".into(), LengthDist::Pareto { x_m: 300.0, alpha: 2.5 }),
        ("pareto a=1.5".into(), LengthDist::Pareto { x_m: 300.0, alpha: 1.5 }),
        ("pareto a=1.1".into(), LengthDist::Pareto { x_m: 300.0, alpha: 1.1 }),
        ("lognorm s=0.8".into(), LengthDist::LogNormal { mu: 6.2, sigma: 0.8 }),
        ("lognorm s=1.6".into(), LengthDist::LogNormal { mu: 6.2, sigma: 1.6 }),
    ];
    for (label, dist) in dists {
        let synth = SynthLengths::new(dist, 64.0, 131_072.0).unwrap();
        let cdf = synth.to_cdf(60_000, seed).unwrap();
        let mean = cdf.mean(256);
        let tail = 1.0 - cdf.cdf(8_192.0);
        let w = WorkloadSpec::new(label.clone(), cdf, input_frac, lambda_rps);
        let cands = generate(&w, &catalog, &GenOptions::default());
        let res = NativeSweep.eval(&w, &cands, slo_ms).unwrap();
        let best = rank_feasible(&cands, &res)
            .first()
            .map(|&i| (cands[i].label(), res[i].cost_yr));
        rows.push(SensitivityRow { label, mean_tokens: mean, tail_8k: tail,
                                   best });
    }
    rows
}

/// Render the sensitivity table.
pub fn table(lambda_rps: f64, slo_ms: f64, seed: u64) -> Table {
    let rows = sweep(lambda_rps, slo_ms, 0.8, seed);
    let mut t = Table::new(&["Distribution", "mean tok", ">8K", "best config",
                             "$/yr"])
        .with_title(format!(
            "Synthetic-length sensitivity (λ={lambda_rps} req/s, \
             SLO={slo_ms} ms, prompt fraction 0.8)"
        ))
        .align(&[Align::Left, Align::Right, Align::Right, Align::Left,
                 Align::Right]);
    for r in &rows {
        match &r.best {
            Some((label, cost)) => t.row(&[
                r.label.clone(),
                format!("{:.0}", r.mean_tokens),
                format!("{:.1}%", r.tail_8k * 100.0),
                label.clone(),
                dollars(*cost),
            ]),
            None => t.row(&[
                r.label.clone(),
                format!("{:.0}", r.mean_tokens),
                format!("{:.1}%", r.tail_8k * 100.0),
                "infeasible".into(),
                "-".into(),
            ]),
        };
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heavier_tails_cost_more() {
        let rows = sweep(50.0, 1000.0, 0.8, 3);
        let cost = |label: &str| {
            rows.iter()
                .find(|r| r.label == label)
                .and_then(|r| r.best.as_ref().map(|b| b.1))
        };
        let light = cost("pareto a=2.5");
        let heavy = cost("pareto a=1.5");
        if let (Some(l), Some(h)) = (light, heavy) {
            assert!(h >= l, "heavy tail {h} should cost >= light {l}");
        } else {
            // At minimum the light tail must be plannable.
            assert!(light.is_some(), "{rows:?}");
        }
        // Tail fractions are ordered by alpha.
        let t25 = rows.iter().find(|r| r.label == "pareto a=2.5").unwrap();
        let t11 = rows.iter().find(|r| r.label == "pareto a=1.1").unwrap();
        assert!(t11.tail_8k > t25.tail_8k);
    }

    #[test]
    fn table_has_five_rows() {
        assert_eq!(table(50.0, 1000.0, 3).n_rows(), 5);
    }
}
