//! Deterministic DES perf harness (the engine behind `fleet-sim bench`).
//!
//! Five fixed scenarios — mirroring the regression matrices so the
//! timed code path is exactly the verified one — are replayed on a
//! pre-sampled request stream (sampling is excluded from timing):
//!
//! * `azure_two_pool_length` — the paper's core two-pool split fleet,
//! * `agent_heavy_tail` — heavy-tailed agent trace on one large pool,
//! * `lmsys_multipool_capped` — three pools, ModelRouter class mix, and a
//!   mid-run demand-response cap window,
//! * `azure_diurnal_nhpp` — the two-phase diurnal NHPP profile (bursty
//!   event cadence: peak phases churn deep completion backlogs),
//! * `azure_two_pool_memory` — the split fleet under a KV-starved
//!   memory model with evict-recompute preemption (occupancy tracking,
//!   eviction, and re-prefill all on the timed path).
//!
//! For each scenario the harness times the **production** engine
//! (calendar queue + streaming metrics, the configuration high-volume
//! sweeps run in) and the **reference** engine (all-events `BinaryHeap` +
//! exact sample vectors — the seed baseline), reports simulated events
//! per second for both, their ratio (`speedup_vs_reference`, the
//! machine-portable number the CI perf gate compares), and cross-checks
//! that the two engines are bit-identical on the same stream before
//! trusting either timing.
//!
//! Output is a `BENCH_N.json` snapshot (schema documented in the README;
//! consumed by `scripts/perf_gate.py`).

use std::time::Instant;

use crate::des::engine::{CapWindow, DesConfig, SimPool, Simulator};
use crate::des::memory::{MemoryConfig, MemorySpec, PolicyKind};
use crate::des::metrics::MetricsMode;
use crate::des::input::SimInput;
use crate::des::reference::run_reference_input;
use crate::des::shard::{run_sharded_input, StreamStats,
                        DEFAULT_CHUNK_SIZE};
use crate::gpu::catalog::GpuCatalog;
use crate::router::RoutingPolicy;
use crate::util::json::Json;
use crate::util::parallel::default_threads;
use crate::util::table::{Align, Table};
use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

/// Snapshot schema tag (bump when the JSON layout changes).
pub const SCHEMA: &str = "fleet-sim-bench-v2";

/// Which engine(s) to time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BenchEngine {
    Production,
    Reference,
    Both,
}

impl BenchEngine {
    pub fn parse(name: &str) -> anyhow::Result<Self> {
        match name {
            "production" => Ok(BenchEngine::Production),
            "reference" => Ok(BenchEngine::Reference),
            "both" => Ok(BenchEngine::Both),
            other => anyhow::bail!(
                "--engine: 'production', 'reference', or 'both', got '{other}'"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BenchEngine::Production => "production",
            BenchEngine::Reference => "reference",
            BenchEngine::Both => "both",
        }
    }

    fn times_production(&self) -> bool {
        matches!(self, BenchEngine::Production | BenchEngine::Both)
    }

    fn times_reference(&self) -> bool {
        matches!(self, BenchEngine::Reference | BenchEngine::Both)
    }
}

/// Harness knobs (the CLI's fidelity flags map onto these).
#[derive(Debug, Clone)]
pub struct BenchOpts {
    /// Requests per scenario (`--requests`; `--fast` lowers the default).
    pub n_requests: usize,
    pub seed: u64,
    /// Timed repetitions per engine; the minimum wall time is reported.
    pub samples: usize,
    pub engine: BenchEngine,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            n_requests: 30_000,
            seed: 42,
            samples: 3,
            engine: BenchEngine::Both,
        }
    }
}

/// One scenario's measurements. `None` = not measured at this engine
/// selection (serialized as JSON null).
#[derive(Debug, Clone)]
pub struct BenchRow {
    pub name: &'static str,
    /// Simulated events processed per run (deterministic given the seed).
    pub events: usize,
    pub wall_ms: Option<f64>,
    pub events_per_sec: Option<f64>,
    pub ref_wall_ms: Option<f64>,
    pub ref_events_per_sec: Option<f64>,
    /// events_per_sec / ref_events_per_sec — machine-portable, the number
    /// the CI perf gate compares across snapshots.
    pub speedup_vs_reference: Option<f64>,
    /// Production and reference engines agreed bit-for-bit on this
    /// stream (only checked when both run).
    pub bit_identical: Option<bool>,
}

struct BenchCase {
    name: &'static str,
    workload: WorkloadSpec,
    pools: Vec<SimPool>,
    router: RoutingPolicy,
    cfg: DesConfig,
    /// KV-cache memory model attached to every input (None = open loop).
    memory: Option<MemoryConfig>,
}

fn cases(n_requests: usize, seed: u64) -> Vec<BenchCase> {
    let cat = GpuCatalog::standard();
    let a100 = cat.get("A100").unwrap().clone();
    let a100_d = a100.clone();
    let h100 = cat.get("H100").unwrap().clone();
    let a10g = cat.get("A10G").unwrap().clone();
    let base = DesConfig { n_requests, seed, ..Default::default() };

    let azure = WorkloadSpec::builtin(BuiltinTrace::Azure, 120.0);
    let agent = WorkloadSpec::builtin(BuiltinTrace::Agent, 20.0);
    let agent_ctx = agent.cdf.max_len();
    let lmsys = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 80.0);

    vec![
        BenchCase {
            name: "azure_two_pool_length",
            workload: azure,
            pools: vec![
                SimPool { gpu: a100.clone(), n_gpus: 4, ctx_budget: 4096.0,
                          batch_cap: None },
                SimPool { gpu: a100.clone(), n_gpus: 4, ctx_budget: 8192.0,
                          batch_cap: None },
            ],
            router: RoutingPolicy::Length { b_short: 4096.0 },
            cfg: base.clone(),
            memory: None,
        },
        BenchCase {
            name: "agent_heavy_tail",
            workload: agent,
            pools: vec![SimPool { gpu: h100.clone(), n_gpus: 24,
                                  ctx_budget: agent_ctx, batch_cap: None }],
            router: RoutingPolicy::Random { n_pools: 1 },
            cfg: base.clone(),
            memory: None,
        },
        BenchCase {
            name: "lmsys_multipool_capped",
            workload: lmsys,
            pools: vec![
                SimPool { gpu: a10g, n_gpus: 6, ctx_budget: 4096.0,
                          batch_cap: Some(32) },
                SimPool { gpu: a100, n_gpus: 4, ctx_budget: 8192.0,
                          batch_cap: None },
                SimPool { gpu: h100, n_gpus: 4, ctx_budget: 65536.0,
                          batch_cap: None },
            ],
            router: RoutingPolicy::Model { class_to_pool: vec![0, 1, 2] },
            cfg: DesConfig {
                cap_window: Some(CapWindow {
                    start_ms: 10_000.0,
                    end_ms: 40_000.0,
                    cap: 2,
                }),
                class_probs: Some(vec![0.6, 0.3, 0.1]),
                ..base.clone()
            },
            memory: None,
        },
        BenchCase {
            name: "azure_diurnal_nhpp",
            workload: WorkloadSpec::builtin(BuiltinTrace::Azure, 120.0)
                .with_nhpp(vec![(0.0, 40.0), (10_000.0, 200.0)], 20_000.0),
            pools: vec![
                SimPool { gpu: a100_d.clone(), n_gpus: 6,
                          ctx_budget: 4096.0, batch_cap: None },
                SimPool { gpu: a100_d.clone(), n_gpus: 6,
                          ctx_budget: 8192.0, batch_cap: None },
            ],
            router: RoutingPolicy::Length { b_short: 4096.0 },
            cfg: base.clone(),
            memory: None,
        },
        BenchCase {
            // The split fleet starved for KV (9,000 token-slots per
            // A100): occupancy tracking, pressure scheduling, eviction,
            // and re-prefill all land on the timed event loop.
            name: "azure_two_pool_memory",
            workload: WorkloadSpec::builtin(BuiltinTrace::Azure, 120.0),
            pools: vec![
                SimPool { gpu: a100_d.clone(), n_gpus: 4,
                          ctx_budget: 4096.0, batch_cap: None },
                SimPool { gpu: a100_d, n_gpus: 4, ctx_budget: 8192.0,
                          batch_cap: None },
            ],
            router: RoutingPolicy::Length { b_short: 4096.0 },
            cfg: base,
            memory: Some(MemoryConfig {
                spec: MemorySpec {
                    hbm_gb: None,
                    weights_gb: 71.0,
                    bytes_per_token: 1e6,
                },
                policy: PolicyKind::EvictRecompute,
                swap_out_ms: 0.0,
                swap_in_ms: 0.0,
            }),
        },
    ]
}

/// Attach a case's optional memory model to an input.
fn attach_memory<'a>(
    input: SimInput<'a>,
    memory: &'a Option<MemoryConfig>,
) -> SimInput<'a> {
    match memory {
        Some(m) => input.with_memory(m),
        None => input,
    }
}

/// Minimum wall time (ms) over `samples` runs of `f`.
fn time_min<F: FnMut() -> usize>(samples: usize, mut f: F) -> (f64, usize) {
    let mut best = f64::INFINITY;
    let mut events = 0usize;
    for _ in 0..samples.max(1) {
        let t0 = Instant::now();
        events = f();
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    (best, events)
}

/// Run the harness. Panics never; a bit-identity mismatch is reported in
/// the row (and fails the CI gate), not here.
pub fn run_bench(opts: &BenchOpts) -> Vec<BenchRow> {
    let mut rows = Vec::new();
    for case in cases(opts.n_requests, opts.seed) {
        let stream = case
            .workload
            .sample_requests(case.cfg.n_requests, case.cfg.seed);

        let mut row = BenchRow {
            name: case.name,
            events: 0,
            wall_ms: None,
            events_per_sec: None,
            ref_wall_ms: None,
            ref_events_per_sec: None,
            speedup_vs_reference: None,
            bit_identical: None,
        };

        if opts.engine == BenchEngine::Both {
            // Untimed exact-mode cross-check: both engines, same stream,
            // must agree bit-for-bit before either timing is trusted.
            let input = attach_memory(
                SimInput::stream(&case.pools, &case.router, &case.cfg,
                                 &stream),
                &case.memory,
            );
            let mut prod = Simulator::run_input(&input).unwrap();
            let mut refr = run_reference_input(&input).unwrap();
            row.events = prod.n_events;
            row.bit_identical = Some(
                prod.overall.p99_ttft() == refr.overall.p99_ttft()
                    && prod.overall.count == refr.overall.count
                    && prod.n_events == refr.n_events
                    && prod.horizon_ms == refr.horizon_ms,
            );
        }

        if opts.engine.times_production() {
            // Production configuration: calendar queue + streaming sketch.
            let cfg = DesConfig {
                metrics: MetricsMode::Streaming,
                ..case.cfg.clone()
            };
            let input = attach_memory(
                SimInput::stream(&case.pools, &case.router, &cfg, &stream),
                &case.memory,
            );
            let (wall, events) = time_min(opts.samples, || {
                let r = Simulator::run_input(&input).unwrap();
                std::hint::black_box(r.n_events)
            });
            row.events = events;
            row.wall_ms = Some(wall);
            row.events_per_sec = Some(events as f64 / (wall / 1e3));
        }

        if opts.engine.times_reference() {
            // Seed baseline: all-events heap + exact sample vectors.
            let input = attach_memory(
                SimInput::stream(&case.pools, &case.router, &case.cfg,
                                 &stream),
                &case.memory,
            );
            let (wall, events) = time_min(opts.samples, || {
                let r = run_reference_input(&input).unwrap();
                std::hint::black_box(r.n_events)
            });
            row.events = events;
            row.ref_wall_ms = Some(wall);
            row.ref_events_per_sec = Some(events as f64 / (wall / 1e3));
        }

        row.speedup_vs_reference =
            match (row.events_per_sec, row.ref_events_per_sec) {
                (Some(p), Some(r)) if r > 0.0 => Some(p / r),
                _ => None,
            };
        rows.push(row);
    }
    rows
}

/// Knobs for the `lmsys_1e8` scale scenario (`fleet-sim bench --scale`):
/// the generator-driven sharded executor at production volume. Unlike
/// the four [`BenchOpts`] scenarios the stream is never materialized —
/// that is the point — so the reference engine does not participate and
/// the row's `ref_*`/`speedup` fields stay null; the gate instead checks
/// an absolute events/sec floor and the process RSS.
#[derive(Debug, Clone)]
pub struct ScaleBenchOpts {
    /// Requests in the timed run (default 10^8; `--fast` drops it to
    /// 2 x 10^6 so CI finishes in seconds).
    pub n_requests: usize,
    pub seed: u64,
    /// Shard threads (`--shards`; clamped to the pool count).
    pub n_shards: usize,
    /// Generator chunk size (`--chunk-size`).
    pub chunk_size: usize,
    /// Requests for the untimed sharded-vs-serial bit-identity prefix
    /// check (this many *are* materialized, so keep it modest).
    pub verify_requests: usize,
}

impl Default for ScaleBenchOpts {
    fn default() -> Self {
        ScaleBenchOpts {
            n_requests: 100_000_000,
            seed: 42,
            n_shards: default_threads(),
            chunk_size: DEFAULT_CHUNK_SIZE,
            verify_requests: 200_000,
        }
    }
}

/// The scale scenario: the LMSYS trace at 1600 rps on a two-pool split
/// fleet sized to run hot (~0.8 utilization) but stable, so the event
/// loop is dominated by real queueing work rather than empty pools.
fn scale_case(seed: u64) -> BenchCase {
    let cat = GpuCatalog::standard();
    let a100 = cat.get("A100").unwrap().clone();
    let h100 = cat.get("H100").unwrap().clone();
    BenchCase {
        name: "lmsys_1e8",
        workload: WorkloadSpec::builtin(BuiltinTrace::Lmsys, 1600.0),
        pools: vec![
            SimPool { gpu: a100, n_gpus: 64, ctx_budget: 4096.0,
                      batch_cap: None },
            SimPool { gpu: h100, n_gpus: 24, ctx_budget: 65536.0,
                      batch_cap: None },
        ],
        router: RoutingPolicy::Length { b_short: 4096.0 },
        cfg: DesConfig { seed, ..Default::default() },
        memory: None,
    }
}

/// Run the scale scenario: an untimed sharded-vs-serial bit-identity
/// prefix check in *both* metrics modes, then one timed sharded run in
/// the production configuration (streaming metrics). Returns the row
/// plus the run's [`StreamStats`] (bounded-memory evidence).
pub fn run_scale_bench(opts: &ScaleBenchOpts) -> (BenchRow, StreamStats) {
    let case = scale_case(opts.seed);
    let mut identical = true;
    for mode in [MetricsMode::Exact, MetricsMode::Streaming] {
        let cfg = DesConfig {
            n_requests: opts.verify_requests,
            metrics: mode,
            ..case.cfg.clone()
        };
        let stream = case
            .workload
            .sample_requests(cfg.n_requests, cfg.seed);
        let serial_in = SimInput::stream(&case.pools, &case.router, &cfg,
                                         &stream);
        let mut serial = Simulator::run_input(&serial_in).unwrap();
        let gen_in = SimInput::generated(&case.pools, &case.router, &cfg,
                                         &case.workload);
        let (mut sharded, _) =
            run_sharded_input(&gen_in, opts.n_shards, opts.chunk_size)
                .unwrap();
        identical &= serial.overall.p99_ttft() == sharded.overall.p99_ttft()
            && serial.overall.count == sharded.overall.count
            && serial.n_events == sharded.n_events
            && serial.horizon_ms == sharded.horizon_ms
            && serial.n_unserved == sharded.n_unserved;
    }

    let cfg = DesConfig {
        n_requests: opts.n_requests,
        metrics: MetricsMode::Streaming,
        ..case.cfg.clone()
    };
    let input = SimInput::generated(&case.pools, &case.router, &cfg,
                                    &case.workload);
    let t0 = Instant::now();
    let (r, stats) =
        run_sharded_input(&input, opts.n_shards, opts.chunk_size).unwrap();
    let wall = t0.elapsed().as_secs_f64() * 1e3;
    let events = std::hint::black_box(r.n_events);
    let row = BenchRow {
        name: case.name,
        events,
        wall_ms: Some(wall),
        events_per_sec: Some(events as f64 / (wall / 1e3)),
        ref_wall_ms: None,
        ref_events_per_sec: None,
        speedup_vs_reference: None,
        bit_identical: Some(identical),
    };
    (row, stats)
}

/// Peak resident set size of this process, MB (linux `VmHWM`; `None`
/// elsewhere). A process-lifetime high-water mark — a coarse memory
/// proxy for the snapshot, not a per-scenario measurement.
pub fn peak_rss_mb() -> Option<f64> {
    let text = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: f64 = rest.trim().trim_end_matches("kB").trim()
                .parse().ok()?;
            return Some(kb / 1024.0);
        }
    }
    None
}

fn opt_num(v: Option<f64>) -> Json {
    v.map_or(Json::Null, Json::Num)
}

/// Serialize a snapshot (`BENCH_N.json` layout).
pub fn to_json(opts: &BenchOpts, rows: &[BenchRow]) -> Json {
    let scenarios: Vec<(String, Json)> = rows
        .iter()
        .map(|r| {
            (
                r.name.to_string(),
                Json::Obj(vec![
                    ("events".into(), Json::Num(r.events as f64)),
                    ("wall_ms".into(), opt_num(r.wall_ms)),
                    ("events_per_sec".into(), opt_num(r.events_per_sec)),
                    ("ref_wall_ms".into(), opt_num(r.ref_wall_ms)),
                    ("ref_events_per_sec".into(),
                     opt_num(r.ref_events_per_sec)),
                    ("speedup_vs_reference".into(),
                     opt_num(r.speedup_vs_reference)),
                    ("bit_identical".into(),
                     r.bit_identical.map_or(Json::Null, Json::Bool)),
                ]),
            )
        })
        .collect();
    Json::Obj(vec![
        ("schema".into(), Json::Str(SCHEMA.to_string())),
        ("engine".into(), Json::Str(opts.engine.name().to_string())),
        ("n_requests".into(), Json::Num(opts.n_requests as f64)),
        ("seed".into(), Json::Num(opts.seed as f64)),
        ("samples".into(), Json::Num(opts.samples as f64)),
        ("peak_rss_mb".into(), opt_num(peak_rss_mb())),
        ("scenarios".into(), Json::Obj(scenarios)),
    ])
}

fn fmt_opt(v: Option<f64>, prec: usize) -> String {
    match v {
        Some(x) => format!("{:.*}", prec, x),
        None => "-".to_string(),
    }
}

/// Human-readable summary table.
pub fn render_table(rows: &[BenchRow]) -> String {
    let mut t = Table::new(&[
        "scenario", "events", "prod ms", "prod ev/s", "ref ms", "ref ev/s",
        "speedup", "bit-identical",
    ])
    .align(&[
        Align::Left, Align::Right, Align::Right, Align::Right, Align::Right,
        Align::Right, Align::Right, Align::Right,
    ]);
    for r in rows {
        t.row(&[
            r.name.to_string(),
            r.events.to_string(),
            fmt_opt(r.wall_ms, 2),
            fmt_opt(r.events_per_sec, 0),
            fmt_opt(r.ref_wall_ms, 2),
            fmt_opt(r.ref_events_per_sec, 0),
            fmt_opt(r.speedup_vs_reference, 2),
            r.bit_identical
                .map_or("-".to_string(), |b| b.to_string()),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_rows_cover_all_scenarios_and_agree() {
        let opts = BenchOpts {
            n_requests: 1_500,
            samples: 1,
            ..Default::default()
        };
        let rows = run_bench(&opts);
        assert_eq!(rows.len(), 5);
        for r in &rows {
            assert_eq!(r.bit_identical, Some(true), "{}", r.name);
            assert!(r.events >= 2 * 1_500, "{}: {}", r.name, r.events);
            assert!(r.events_per_sec.unwrap() > 0.0);
            assert!(r.ref_events_per_sec.unwrap() > 0.0);
            assert!(r.speedup_vs_reference.unwrap() > 0.0);
        }
        assert!(rows.iter().any(|r| r.name == "azure_diurnal_nhpp"));
        assert!(rows.iter().any(|r| r.name == "azure_two_pool_memory"));
        // The capped multi-pool case processes its drain events too.
        let capped = rows.iter().find(|r| r.name == "lmsys_multipool_capped")
            .unwrap();
        assert_eq!(capped.events, 2 * 1_500 + 3);
    }

    #[test]
    fn scale_bench_verifies_and_times_a_reduced_run() {
        let opts = ScaleBenchOpts {
            n_requests: 20_000,
            verify_requests: 4_000,
            n_shards: 2,
            chunk_size: 2_048,
            ..Default::default()
        };
        let (row, stats) = run_scale_bench(&opts);
        assert_eq!(row.name, "lmsys_1e8");
        assert_eq!(row.bit_identical, Some(true));
        // Live pools always drain: exactly two events per request.
        assert_eq!(row.events, 2 * 20_000);
        assert!(row.events_per_sec.unwrap() > 0.0);
        assert!(row.speedup_vs_reference.is_none());
        assert!(stats.arena_peak_slots > 0);
        assert!(stats.arena_peak_slots < 20_000);
    }

    #[test]
    fn snapshot_json_round_trips() {
        let opts = BenchOpts {
            n_requests: 800,
            samples: 1,
            engine: BenchEngine::Production,
            ..Default::default()
        };
        let rows = run_bench(&opts);
        let doc = to_json(&opts, &rows);
        let text = doc.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("schema").and_then(Json::as_str), Some(SCHEMA));
        let scen = back.get("scenarios").unwrap();
        let first = scen.get("azure_two_pool_length").unwrap();
        assert!(first.get("events_per_sec").and_then(Json::as_f64).is_some());
        // Reference not timed at this engine selection -> null.
        assert_eq!(first.get("ref_events_per_sec"), Some(&Json::Null));
        assert!(!render_table(&rows).is_empty());
    }
}
