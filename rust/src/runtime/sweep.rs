//! AotSweep: the Phase-1 evaluator backed by the AOT-compiled JAX/Pallas
//! artifact (`artifacts/sweep.hlo.txt` + `sweep.meta.json`).
//!
//! The artifact is lowered once at build time (`make artifacts`); at plan
//! time this module packs candidates into the artifact's static
//! `[F, N_CAND]` layout, executes via PJRT, and unpacks the `[N, 8]`
//! result. Padding lanes are inert (empty workload share, 1 GPU).
//! `rust/tests/runtime_parity.rs` checks AotSweep == NativeSweep.
//!
//! Build matrix (see [`crate::runtime`]): without `pjrt` the stub's
//! `load` errors immediately; with `pjrt` but not `xla` the stub loads
//! and validates the metadata sidecar but refuses to execute; with
//! `xla` the real PJRT client runs. In the stub configurations callers
//! fall back to [`crate::optimizer::analytic::NativeSweep`].

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::optimizer::analytic::SweepEval;
use crate::optimizer::candidates::{Candidate, CandidateResult};
use crate::queueing::mgc::K_BINS;
use crate::util::json::Json;
use crate::workload::spec::WorkloadSpec;

/// The candidate-field order baked into the artifact
/// (python/compile/model.py CANDIDATE_FIELDS).
pub const CANDIDATE_FIELDS: [&str; 16] = [
    "b_short", "n_s", "n_l", "chunk_s", "chunk_l", "nmax_s", "nmax_l",
    "w_s", "h_s", "w_l", "h_l", "cost_s", "cost_l", "input_frac", "lam",
    "slo",
];

/// Artifact metadata (sweep.meta.json sidecar).
#[derive(Debug, Clone)]
pub struct SweepMeta {
    pub n_cand: usize,
    pub k_bins: usize,
    pub candidate_fields: Vec<String>,
}

impl SweepMeta {
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = Json::parse(&text)?;
        let fields = doc
            .get("candidate_fields")
            .and_then(Json::as_arr)
            .context("candidate_fields missing")?
            .iter()
            .map(|v| v.as_str().unwrap_or("").to_string())
            .collect();
        Ok(SweepMeta {
            n_cand: doc.get("n_cand").and_then(Json::as_f64)
                .context("n_cand")? as usize,
            k_bins: doc.get("k_bins").and_then(Json::as_f64)
                .context("k_bins")? as usize,
            candidate_fields: fields,
        })
    }

    /// Validate the rust-side packing assumptions against the artifact.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(
            self.candidate_fields == CANDIDATE_FIELDS,
            "artifact field order {:?} != expected {:?} — rebuild artifacts",
            self.candidate_fields,
            CANDIDATE_FIELDS
        );
        anyhow::ensure!(
            self.k_bins == K_BINS,
            "artifact k_bins {} != planner K_BINS {K_BINS}",
            self.k_bins
        );
        Ok(())
    }
}

/// Default artifacts directory: $FLEET_SIM_ARTIFACTS or ./artifacts.
fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("FLEET_SIM_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(all(feature = "pjrt", feature = "xla"))]
mod imp {
    use super::*;
    use crate::runtime::pjrt::PjrtContext;

    /// Phase-1 evaluator backed by the AOT artifact.
    pub struct AotSweep {
        ctx: PjrtContext,
        exe: xla::PjRtLoadedExecutable,
        pub meta: SweepMeta,
        pub artifact_path: PathBuf,
    }

    impl AotSweep {
        /// Load from an artifacts directory (sweep.hlo.txt +
        /// sweep.meta.json).
        pub fn load(artifacts_dir: &Path) -> Result<Self> {
            let hlo = artifacts_dir.join("sweep.hlo.txt");
            let meta = SweepMeta::load(&artifacts_dir.join("sweep.meta.json"))?;
            meta.validate()?;
            let ctx = PjrtContext::cpu()?;
            let exe = ctx.compile_hlo_text_file(&hlo)?;
            Ok(AotSweep { ctx, exe, meta, artifact_path: hlo })
        }

        /// Default artifacts directory: $FLEET_SIM_ARTIFACTS or ./artifacts.
        pub fn default_dir() -> PathBuf {
            super::default_artifacts_dir()
        }

        pub fn platform(&self) -> String {
            self.ctx.platform()
        }

        /// Pack one batch (<= n_cand candidates), execute, unpack.
        fn eval_batch(
            &self,
            hist: &[f32],
            cands: &[Candidate],
            workload: &WorkloadSpec,
            slo_ms: f64,
        ) -> Result<Vec<CandidateResult>> {
            let n = self.meta.n_cand;
            let f = CANDIDATE_FIELDS.len();
            anyhow::ensure!(
                cands.len() <= n,
                "batch exceeds artifact capacity"
            );
            let mut cbuf = vec![0f32; f * n];
            let lam_ms = workload.lambda_per_ms() as f32;
            let frac = workload.input_fraction as f32;
            for (j, c) in cands.iter().enumerate() {
                let nmax_s = c.gpu_s.n_eff(c.ctx_s);
                let nmax_l = c.gpu_l.n_eff(c.ctx_l);
                let vals: [f32; 16] = [
                    c.b_short as f32,
                    c.n_s as f32,
                    c.n_l as f32,
                    c.gpu_s.chunk as f32,
                    c.gpu_l.chunk as f32,
                    nmax_s as f32,
                    nmax_l as f32,
                    c.gpu_s.w_ms as f32,
                    c.gpu_s.h_ms_per_slot as f32,
                    c.gpu_l.w_ms as f32,
                    c.gpu_l.h_ms_per_slot as f32,
                    c.gpu_s.cost_per_year() as f32,
                    c.gpu_l.cost_per_year() as f32,
                    frac,
                    lam_ms,
                    slo_ms as f32,
                ];
                for (i, v) in vals.iter().enumerate() {
                    cbuf[i * n + j] = *v;
                }
            }
            // Inert padding lanes: everything-short single cheap pool, zero
            // arrivals.
            for j in cands.len()..n {
                let vals: [f32; 16] = [
                    1e9, 1.0, 0.0, 512.0, 512.0, 1.0, 1.0, 1.0, 0.1, 1.0, 0.1,
                    0.0, 0.0, 0.5, 0.0, 1e9,
                ];
                for (i, v) in vals.iter().enumerate() {
                    cbuf[i * n + j] = *v;
                }
            }
            let k = self.meta.k_bins;
            let out = self.ctx.execute_f32(
                &self.exe,
                &[
                    (hist, &[2i64, k as i64]),
                    (&cbuf, &[f as i64, n as i64]),
                ],
            )?;
            anyhow::ensure!(
                out.len() == n * 8,
                "unexpected output size {}",
                out.len()
            );
            Ok(cands
                .iter()
                .enumerate()
                .map(|(j, _)| {
                    let row = &out[j * 8..j * 8 + 8];
                    CandidateResult {
                        rho_s: row[0] as f64,
                        rho_l: row[1] as f64,
                        ttft99_s: row[2] as f64,
                        ttft99_l: row[3] as f64,
                        w99_s: row[4] as f64,
                        w99_l: row[5] as f64,
                        cost_yr: row[6] as f64,
                        feasible: row[7] > 0.5,
                    }
                })
                .collect())
        }
    }

    impl SweepEval for AotSweep {
        fn eval(
            &self,
            workload: &WorkloadSpec,
            candidates: &[Candidate],
            slo_ms: f64,
        ) -> Result<Vec<CandidateResult>> {
            // Histogram row 0 = probs, row 1 = bin budgets.
            let (probs, lens) = workload.cdf.histogram(self.meta.k_bins);
            let mut hist = Vec::with_capacity(2 * self.meta.k_bins);
            hist.extend(probs.iter().map(|&p| p as f32));
            hist.extend(lens.iter().map(|&l| l as f32));

            let mut out = Vec::with_capacity(candidates.len());
            for chunk in candidates.chunks(self.meta.n_cand) {
                out.extend(self.eval_batch(&hist, chunk, workload, slo_ms)?);
            }
            Ok(out)
        }

        fn backend(&self) -> &'static str {
            "aot-pjrt"
        }
    }
}

#[cfg(all(feature = "pjrt", not(feature = "xla")))]
mod imp {
    use super::*;

    /// Artifact-contract stub (`pjrt` without `xla`): loads and validates
    /// the sweep artifact's metadata sidecar — keeping the packing
    /// contract (field order, k_bins) compiled and checkable in CI —
    /// but cannot execute without a linked XLA client.
    pub struct AotSweep {
        pub meta: SweepMeta,
        pub artifact_path: PathBuf,
    }

    impl AotSweep {
        /// Read + validate `sweep.meta.json`; succeeds without touching
        /// the HLO artifact (no compiler is linked to parse it).
        pub fn load(artifacts_dir: &Path) -> Result<Self> {
            let meta = SweepMeta::load(&artifacts_dir.join("sweep.meta.json"))?;
            meta.validate()?;
            Ok(AotSweep {
                meta,
                artifact_path: artifacts_dir.join("sweep.hlo.txt"),
            })
        }

        /// Default artifacts directory: $FLEET_SIM_ARTIFACTS or ./artifacts.
        pub fn default_dir() -> PathBuf {
            super::default_artifacts_dir()
        }

        pub fn platform(&self) -> String {
            "pjrt-stub (xla not linked)".to_string()
        }
    }

    impl SweepEval for AotSweep {
        fn eval(
            &self,
            _workload: &WorkloadSpec,
            _candidates: &[Candidate],
            _slo_ms: f64,
        ) -> Result<Vec<CandidateResult>> {
            anyhow::bail!(
                "PJRT execution unavailable: built with `pjrt` but without \
                 the `xla` feature (artifact: {}). Rebuild with `--features \
                 xla` and the xla crate, or use the native backend.",
                self.artifact_path.display()
            )
        }

        fn backend(&self) -> &'static str {
            "aot-pjrt"
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::*;

    /// Offline stub for the PJRT-backed evaluator: `load` always fails
    /// with an actionable message, so `--backend aot` degrades cleanly.
    pub struct AotSweep {
        pub meta: SweepMeta,
        pub artifact_path: PathBuf,
    }

    impl AotSweep {
        pub fn load(artifacts_dir: &Path) -> Result<Self> {
            anyhow::bail!(
                "PJRT runtime unavailable: this binary was built without the \
                 `pjrt` cargo feature (artifacts dir: {}). Rebuild with \
                 `--features pjrt` and the xla crate, or use the native \
                 backend.",
                artifacts_dir.display()
            )
        }

        /// Default artifacts directory: $FLEET_SIM_ARTIFACTS or ./artifacts.
        pub fn default_dir() -> PathBuf {
            super::default_artifacts_dir()
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }
    }

    impl SweepEval for AotSweep {
        fn eval(
            &self,
            _workload: &WorkloadSpec,
            _candidates: &[Candidate],
            _slo_ms: f64,
        ) -> Result<Vec<CandidateResult>> {
            anyhow::bail!("PJRT runtime unavailable (built without `pjrt`)")
        }

        fn backend(&self) -> &'static str {
            "aot-pjrt"
        }
    }
}

pub use imp::AotSweep;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidate_field_count_matches_packing() {
        assert_eq!(CANDIDATE_FIELDS.len(), 16);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_fails_with_actionable_error() {
        let err = AotSweep::load(Path::new("artifacts")).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("pjrt"), "{msg}");
    }

    #[cfg(all(feature = "pjrt", not(feature = "xla")))]
    #[test]
    fn pjrt_stub_loads_meta_and_refuses_eval() {
        use crate::workload::spec::{BuiltinTrace, WorkloadSpec};
        let dir = std::env::temp_dir().join("fleet_sim_pjrt_stub_test");
        std::fs::create_dir_all(&dir).unwrap();
        let fields: Vec<String> = CANDIDATE_FIELDS
            .iter()
            .map(|f| format!("\"{f}\""))
            .collect();
        let meta = format!(
            "{{\"n_cand\": 64, \"k_bins\": {K_BINS}, \
             \"candidate_fields\": [{}]}}",
            fields.join(", ")
        );
        std::fs::write(dir.join("sweep.meta.json"), meta).unwrap();
        let aot = AotSweep::load(&dir).expect("meta-only load succeeds");
        assert_eq!(aot.meta.n_cand, 64);
        assert!(aot.platform().contains("stub"));
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 50.0);
        let err = aot.eval(&w, &[], 500.0).unwrap_err();
        assert!(format!("{err}").contains("xla"), "{err}");
    }

    #[test]
    fn default_dir_honors_env() {
        // Avoid mutating the environment (other tests run in parallel):
        // just check the fallback.
        if std::env::var_os("FLEET_SIM_ARTIFACTS").is_none() {
            assert_eq!(AotSweep::default_dir(), PathBuf::from("artifacts"));
        }
    }
}
