//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Pattern follows /opt/xla-example/load_hlo.rs: HLO *text* is the
//! interchange format (jax >= 0.5 emits 64-bit-id protos that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids).

use anyhow::{Context, Result};

/// A PJRT CPU client plus compilation helpers.
pub struct PjrtContext {
    client: xla::PjRtClient,
}

impl PjrtContext {
    /// Create the CPU client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(PjrtContext { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn compile_hlo_text_file(
        &self,
        path: &std::path::Path,
    ) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {}: {e:?}", path.display()))
    }

    /// Execute with f32 tensor inputs; returns the flattened f32 output of
    /// the first element of the (1-tuple) result.
    pub fn execute_f32(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<f32>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = xla::Literal::vec1(data)
                .reshape(dims)
                .map_err(|e| anyhow::anyhow!("reshape {dims:?}: {e:?}"))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal_sync: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow::anyhow!("to_tuple1: {e:?}"))?;
        out.to_vec::<f32>()
            .map_err(|e| anyhow::anyhow!("to_vec<f32>: {e:?}"))
    }
}
