//! PJRT runtime: load the AOT-compiled Phase-1 sweep (artifacts/
//! sweep.hlo.txt, produced once by python/compile/aot.py) and execute it
//! from the planning hot path. Python is never on the request path.

pub mod pjrt;
pub mod sweep;
