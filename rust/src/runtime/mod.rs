//! PJRT runtime: load the AOT-compiled Phase-1 sweep (artifacts/
//! sweep.hlo.txt, produced once by python/compile/aot.py) and execute it
//! from the planning hot path. Python is never on the request path.
//!
//! The real PJRT client wraps the `xla` crate, which is unavailable in the
//! offline build; it is gated behind the `pjrt` cargo feature. Without the
//! feature, [`sweep::AotSweep`] is a stub whose `load` fails gracefully,
//! so `--backend aot` reports a clear error and everything else (the
//! native evaluator, the whole scenario registry) works unchanged.

#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod sweep;
