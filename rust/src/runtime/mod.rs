//! PJRT runtime: load the AOT-compiled Phase-1 sweep (artifacts/
//! sweep.hlo.txt, produced once by python/compile/aot.py) and execute it
//! from the planning hot path. Python is never on the request path.
//!
//! Three build configurations (CI's feature matrix checks the first two):
//!
//! * default (no features): [`sweep::AotSweep`] is a stub whose `load`
//!   fails gracefully, so `--backend aot` reports a clear error and
//!   everything else (the native evaluator, the whole scenario registry)
//!   works unchanged;
//! * `--features pjrt`: the artifact-contract stub — `load` reads and
//!   validates `sweep.meta.json` (field order, k_bins) but `eval` fails,
//!   because no XLA client is linked;
//! * `--features xla` (implies `pjrt`): the real PJRT CPU client, which
//!   requires the `xla` crate and a local XLA extension build.

#[cfg(all(feature = "pjrt", feature = "xla"))]
pub mod pjrt;
pub mod sweep;
