//! GpuProfile: the paper's physics-informed GPU performance model.
//!
//! Each GPU type is characterized by `(W, H, n_max, C_chunk)` (paper §3.2):
//!
//! * `W` (ms) — baseline compute per continuous-batching iteration,
//! * `H` (ms/slot) — memory-bandwidth cost per concurrent sequence,
//! * `kv_blocks` — PagedAttention block capacity; `n_max(B)` follows the
//!   slot math of §2.1: `n_max(B) = floor(kv_blocks / ceil(B/16))`,
//! * `C_chunk` — prefill chunk size,
//! * cost per GPU-hour, and the logistic power-curve parameters of §4.8.
//!
//! The constants in [`crate::gpu::catalog`] are the paper's hand-calibrated
//! ManualProfile values (targeting Llama-3-70B, single-node TP);
//! [`crate::gpu::builder::ProfileBuilder`] derives equivalents from roofline
//! first principles, and users can substitute measured constants.

/// Tokens per PagedAttention block (vLLM default, paper §2.1).
pub const BLOCK_TOKENS: f64 = 16.0;

/// Hours per year used for $/yr conversions.
pub const HOURS_PER_YEAR: f64 = 8760.0;

/// A GPU type's performance, capacity, cost, and power model.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuProfile {
    pub name: String,
    /// Baseline compute per iteration, ms.
    pub w_ms: f64,
    /// Memory-bandwidth cost per concurrent sequence, ms/slot.
    pub h_ms_per_slot: f64,
    /// PagedAttention block capacity (16 tokens each).
    pub kv_blocks: f64,
    /// VRAM in GB (drives validity checks for long-context pools).
    pub vram_gb: f64,
    /// Prefill chunk size in tokens.
    pub chunk: f64,
    /// Engine cap on concurrent sequences (vLLM `max_num_seqs`). The
    /// effective batch is `n_eff(B) = min(n_max(B), max_num_seqs)`; the
    /// paper's Table 9 baseline (H100 at 8K ctx running n_max = 128, not
    /// the KV-limited 256) fixes this at the vLLM default of 128.
    pub max_num_seqs: f64,
    /// On-demand cost per GPU-hour, dollars.
    pub cost_per_hr: f64,
    /// Idle power draw, watts (logistic curve floor, §4.8).
    pub p_idle_w: f64,
    /// Nominal (saturated) power draw, watts.
    pub p_nom_w: f64,
    /// Logistic power curve shape (paper: k = 1.0).
    pub power_logistic_k: f64,
    /// Logistic power curve midpoint in log2(batch) (paper: x0 = 4.2).
    pub power_logistic_x0: f64,
}

impl GpuProfile {
    /// Maximum concurrent KV slots at context budget `b` tokens
    /// (paper §2.1): `n_max(B) = floor(kv_blocks / ceil(B/16))`, >= 1.
    pub fn n_max(&self, b_tokens: f64) -> f64 {
        let blocks_per_seq = (b_tokens / BLOCK_TOKENS).ceil().max(1.0);
        (self.kv_blocks / blocks_per_seq).floor().max(1.0)
    }

    /// Effective concurrent batch at context budget `b`: KV-slot capacity
    /// clipped by the engine's `max_num_seqs`.
    pub fn n_eff(&self, b_tokens: f64) -> f64 {
        self.n_max(b_tokens).min(self.max_num_seqs).max(1.0)
    }

    /// Iteration latency under continuous batching with `n` concurrent
    /// sequences (paper Eq. 3): `t_iter(n) = W + H * n`, ms.
    pub fn t_iter(&self, n: f64) -> f64 {
        self.w_ms + self.h_ms_per_slot * n
    }

    /// Slot-hold iterations for a request (paper Eq. 4 numerator):
    /// `ceil(L_in / C_chunk) + L_out`.
    pub fn iters(&self, l_in: f64, l_out: f64) -> f64 {
        (l_in / self.chunk).ceil() + l_out.max(1.0)
    }

    /// Expected *server-level* service time (paper Eq. 4), ms: the GPU
    /// amortizes `n_max` concurrent slots, so per-request service time is
    /// `iters / n_max * t_iter(n_max)`.
    pub fn service_ms(&self, l_in: f64, l_out: f64, ctx_budget: f64) -> f64 {
        let n = self.n_eff(ctx_budget);
        self.iters(l_in, l_out) / n * self.t_iter(n)
    }

    /// Slot-hold duration for the DES (ms): a request occupies one KV slot
    /// for its full `iters * t_iter(n_max)` (conservative n = n_max; this
    /// is what exposes head-of-line blocking, paper §4.2).
    pub fn slot_hold_ms(&self, l_in: f64, l_out: f64, ctx_budget: f64) -> f64 {
        let n = self.n_eff(ctx_budget);
        self.iters(l_in, l_out) * self.t_iter(n)
    }

    /// Prefill latency (paper Eq. 5 middle term), ms.
    pub fn prefill_ms(&self, l_in: f64, ctx_budget: f64) -> f64 {
        let n = self.n_eff(ctx_budget);
        (l_in / self.chunk).ceil() * self.t_iter(n)
    }

    /// Time-per-output-token at batch level `n` (decode phase), ms.
    pub fn tpot_ms(&self, n: f64) -> f64 {
        self.t_iter(n)
    }

    /// Sustained token throughput at batch `n`, tokens/ms.
    pub fn token_rate(&self, n: f64) -> f64 {
        n / self.t_iter(n)
    }

    /// Whether this GPU can hold even one sequence of `ctx` tokens in KV
    /// cache (A10G cannot serve 300K-token contexts, §4.3).
    pub fn supports_context(&self, ctx: f64) -> bool {
        self.kv_blocks * BLOCK_TOKENS >= ctx
    }

    pub fn cost_per_year(&self) -> f64 {
        self.cost_per_hr * HOURS_PER_YEAR
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::catalog::GpuCatalog;

    fn a100() -> GpuProfile {
        GpuCatalog::standard().get("A100").unwrap().clone()
    }

    fn h100() -> GpuProfile {
        GpuCatalog::standard().get("H100").unwrap().clone()
    }

    #[test]
    fn slot_math_matches_paper_section_2_1() {
        // "An A100-80GB holds 65,536 blocks ... at B=8,192 this is 128; at
        // B=65,536 it drops to 16. That 8x ratio ..."
        let g = a100();
        assert_eq!(g.n_max(8192.0), 128.0);
        assert_eq!(g.n_max(65536.0), 16.0);
        assert_eq!(g.n_max(8192.0) / g.n_max(65536.0), 8.0);
        // At B=4096 the short pool runs 256 slots (§4.1).
        assert_eq!(g.n_max(4096.0), 256.0);
    }

    #[test]
    fn slot_math_rounds_up_blocks() {
        let g = a100();
        // 8193 tokens needs 513 blocks -> floor(65536/513) = 127.
        assert_eq!(g.n_max(8193.0), 127.0);
        // Tiny contexts: one block per sequence.
        assert_eq!(g.n_max(10.0), 65536.0);
    }

    #[test]
    fn t_iter_matches_paper_example() {
        // "For Llama-3-70B on A100-80GB: W = 8 ms, H = 0.65 ms/slot."
        let g = a100();
        assert!((g.t_iter(16.0) - 18.4).abs() < 1e-9);
        assert!((g.t_iter(128.0) - 91.2).abs() < 1e-9);
    }

    #[test]
    fn service_time_formula() {
        // Eq. 4 hand-check: L_in=1000, L_out=500, B=8192 on A100:
        // iters = ceil(1000/512) + 500 = 502; E[S] = 502/128 * 91.2.
        let g = a100();
        let want = 502.0 / 128.0 * 91.2;
        assert!((g.service_ms(1000.0, 500.0, 8192.0) - want).abs() < 1e-9);
    }

    #[test]
    fn slot_hold_is_nmax_times_service() {
        let g = h100();
        let (li, lo, b) = (2000.0, 300.0, 8192.0);
        let hold = g.slot_hold_ms(li, lo, b);
        let serv = g.service_ms(li, lo, b);
        assert!((hold / serv - g.n_eff(b)).abs() < 1e-9);
    }

    #[test]
    fn prefill_uses_chunks() {
        let g = h100(); // chunk=1024
        let t = g.prefill_ms(4096.0, 8192.0);
        assert!((t - 4.0 * g.t_iter(g.n_eff(8192.0))).abs() < 1e-9);
        // H100's larger chunk roughly halves prefill time vs A100 (§4.6).
        let a = a100();
        let ratio =
            a.prefill_ms(65536.0, 65536.0) / g.prefill_ms(65536.0, 65536.0);
        assert!(ratio > 2.0, "A100/H100 prefill ratio = {ratio}");
    }

    #[test]
    fn token_rate_saturates_at_inverse_h() {
        let g = h100();
        let r = g.token_rate(100_000.0);
        assert!((r - 1.0 / g.h_ms_per_slot).abs() < 0.01);
    }

    #[test]
    fn context_support() {
        let cat = GpuCatalog::standard();
        let a10g = cat.get("A10G").unwrap();
        // A10G: 32768 blocks * 16 = 524288 max tokens; supports 300K ctx
        // only nominally — VRAM check is separate. But a 1M ctx is out.
        assert!(!a10g.supports_context(1.0e6));
        assert!(a10g.supports_context(8192.0));
    }

    #[test]
    fn yearly_cost() {
        let g = a100();
        // $2.21/hr * 8760 = $19,360/yr ("A100 19.4K/yr", §4).
        assert!((g.cost_per_year() - 19_359.6).abs() < 1.0);
    }
}
