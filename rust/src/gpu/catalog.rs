//! The pre-built GPU profiles (paper §3.2 table + footnote 1 pricing).
//!
//! These are the hand-calibrated ManualProfile constants from the paper's
//! `fleet_sim/gpu_profiles/profiles.py`, targeting Llama-3-70B with
//! single-node TP serving:
//!
//! | GPU        | W (ms) | H (ms/slot) | n_max @ 8K | VRAM | $/hr  |
//! |------------|--------|-------------|------------|------|-------|
//! | A10G 24GB  | 12.0   | 0.90        | 64         | 24   | 1.010 |
//! | A100 80GB  | 8.0    | 0.65        | 128        | 80   | 2.21  |
//! | H100 80GB  | 4.0    | 0.32        | 256        | 80   | 4.02  |
//!
//! `kv_blocks` is derived from the printed `n_max @ 8K` column
//! (n_max(8192) = kv_blocks / 512). Power constants reproduce the paper's
//! §4.8 logistic fit for H100 (P(1) ≈ 304 W, P(128) ≈ 583 W against the
//! ML.ENERGY measurements); A100/A10G use their TDP envelopes.

use crate::gpu::profile::GpuProfile;

/// A set of available GPU types.
#[derive(Debug, Clone)]
pub struct GpuCatalog {
    profiles: Vec<GpuProfile>,
}

impl GpuCatalog {
    /// The paper's three pre-built profiles.
    pub fn standard() -> Self {
        GpuCatalog {
            profiles: vec![
                GpuProfile {
                    name: "A10G".into(),
                    w_ms: 12.0,
                    h_ms_per_slot: 0.90,
                    kv_blocks: 32_768.0, // n_max(8K) = 64
                    vram_gb: 24.0,
                    chunk: 512.0,
                    max_num_seqs: 128.0,
                    cost_per_hr: 1.0103, // $8.85K/yr (§4 pricing)
                    p_idle_w: 60.0,
                    p_nom_w: 300.0,
                    power_logistic_k: 1.0,
                    power_logistic_x0: 4.2,
                },
                GpuProfile {
                    name: "A100".into(),
                    w_ms: 8.0,
                    h_ms_per_slot: 0.65,
                    kv_blocks: 65_536.0, // n_max(8K) = 128
                    vram_gb: 80.0,
                    chunk: 512.0,
                    max_num_seqs: 128.0,
                    cost_per_hr: 2.21, // $19.4K/yr
                    p_idle_w: 100.0,
                    p_nom_w: 400.0,
                    power_logistic_k: 1.0,
                    power_logistic_x0: 4.2,
                },
                GpuProfile {
                    name: "H100".into(),
                    w_ms: 4.0,
                    h_ms_per_slot: 0.32,
                    kv_blocks: 131_072.0, // n_max(8K) = 256
                    vram_gb: 80.0,
                    chunk: 1024.0,
                    max_num_seqs: 128.0,
                    cost_per_hr: 4.02, // $35.2K/yr
                    p_idle_w: 300.0,
                    p_nom_w: 600.0,
                    power_logistic_k: 1.0,
                    power_logistic_x0: 4.2,
                },
            ],
        }
    }

    /// Catalog from explicit profiles (ManualProfile path).
    pub fn from_profiles(profiles: Vec<GpuProfile>) -> Self {
        GpuCatalog { profiles }
    }

    pub fn get(&self, name: &str) -> Option<&GpuProfile> {
        self.profiles
            .iter()
            .find(|p| p.name.eq_ignore_ascii_case(name))
    }

    pub fn require(&self, name: &str) -> anyhow::Result<&GpuProfile> {
        self.get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown GPU type '{name}'"))
    }

    pub fn profiles(&self) -> &[GpuProfile] {
        &self.profiles
    }

    pub fn names(&self) -> Vec<&str> {
        self.profiles.iter().map(|p| p.name.as_str()).collect()
    }

    /// Add or replace a profile (user-supplied ManualProfile overrides).
    pub fn upsert(&mut self, profile: GpuProfile) {
        if let Some(slot) = self
            .profiles
            .iter_mut()
            .find(|p| p.name.eq_ignore_ascii_case(&profile.name))
        {
            *slot = profile;
        } else {
            self.profiles.push(profile);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nmax_at_8k_matches_paper_table() {
        let cat = GpuCatalog::standard();
        assert_eq!(cat.require("A10G").unwrap().n_max(8192.0), 64.0);
        assert_eq!(cat.require("A100").unwrap().n_max(8192.0), 128.0);
        assert_eq!(cat.require("H100").unwrap().n_max(8192.0), 256.0);
    }

    #[test]
    fn yearly_costs_match_case_study_rates() {
        // §4: "A10G 8.85K/yr, A100 19.4K/yr, H100 35.2K/yr".
        let cat = GpuCatalog::standard();
        let a10g = cat.require("A10G").unwrap().cost_per_year();
        let a100 = cat.require("A100").unwrap().cost_per_year();
        let h100 = cat.require("H100").unwrap().cost_per_year();
        assert!((a10g - 8_850.0).abs() < 10.0, "{a10g}");
        assert!((a100 - 19_400.0).abs() < 50.0, "{a100}");
        assert!((h100 - 35_200.0).abs() < 50.0, "{h100}");
    }

    #[test]
    fn lookup_is_case_insensitive() {
        let cat = GpuCatalog::standard();
        assert!(cat.get("h100").is_some());
        assert!(cat.get("B200").is_none());
        assert!(cat.require("B200").is_err());
    }

    #[test]
    fn upsert_replaces_and_adds() {
        let mut cat = GpuCatalog::standard();
        let mut h = cat.get("H100").unwrap().clone();
        h.cost_per_hr = 9.99;
        cat.upsert(h);
        assert_eq!(cat.profiles().len(), 3);
        assert_eq!(cat.get("H100").unwrap().cost_per_hr, 9.99);
        let mut b200 = cat.get("H100").unwrap().clone();
        b200.name = "B200".into();
        cat.upsert(b200);
        assert_eq!(cat.profiles().len(), 4);
    }

    #[test]
    fn speed_ordering_is_sane() {
        // Faster generations have lower W and H.
        let cat = GpuCatalog::standard();
        let (a10g, a100, h100) = (
            cat.get("A10G").unwrap(),
            cat.get("A100").unwrap(),
            cat.get("H100").unwrap(),
        );
        assert!(a10g.w_ms > a100.w_ms && a100.w_ms > h100.w_ms);
        assert!(a10g.h_ms_per_slot > a100.h_ms_per_slot);
        assert!(a100.h_ms_per_slot > h100.h_ms_per_slot);
        assert!(a10g.cost_per_hr < a100.cost_per_hr);
        assert!(a100.cost_per_hr < h100.cost_per_hr);
    }
}
