//! ProfileBuilder: derive (W, H, n_max) from hardware first principles
//! (paper §3.2: "ProfileBuilder can derive equivalent constants from first
//! principles using the roofline decomposition from AIConfigurator").
//!
//! Decode iterations on a weight-streaming engine are memory-bound:
//!
//! * `W` ≈ time to stream this GPU's shard of the model weights from HBM
//!   once per iteration, plus a fixed kernel-launch overhead;
//! * `H` ≈ marginal per-sequence cost: the sequence's KV-cache read at the
//!   working context plus its marginal matmul FLOPs;
//! * `kv_blocks` ≈ the VRAM left after weights, divided by the KV bytes of
//!   one 16-token block.
//!
//! Raw roofline numbers land within a small factor of measured serving
//! latency (real engines overlap transfers and fuse kernels), so the
//! builder supports calibration against one measured reference profile —
//! the same workflow the paper describes for Vidur-derived ManualProfiles.

use crate::gpu::profile::GpuProfile;

/// Hardware datasheet numbers for a GPU generation.
#[derive(Debug, Clone)]
pub struct HardwareSpec {
    pub name: String,
    /// Dense bf16 throughput, TFLOP/s.
    pub tflops_bf16: f64,
    /// HBM bandwidth, GB/s.
    pub hbm_gb_s: f64,
    pub vram_gb: f64,
    /// Typical board power, watts.
    pub tdp_w: f64,
    /// Idle power, watts.
    pub idle_w: f64,
    pub cost_per_hr: f64,
}

impl HardwareSpec {
    pub fn a10g() -> Self {
        HardwareSpec {
            name: "A10G".into(),
            tflops_bf16: 125.0,
            hbm_gb_s: 600.0,
            vram_gb: 24.0,
            tdp_w: 300.0,
            idle_w: 60.0,
            cost_per_hr: 1.0103,
        }
    }

    pub fn a100() -> Self {
        HardwareSpec {
            name: "A100".into(),
            tflops_bf16: 312.0,
            hbm_gb_s: 2039.0,
            vram_gb: 80.0,
            tdp_w: 400.0,
            idle_w: 100.0,
            cost_per_hr: 2.21,
        }
    }

    pub fn h100() -> Self {
        HardwareSpec {
            name: "H100".into(),
            tflops_bf16: 989.0,
            hbm_gb_s: 3350.0,
            vram_gb: 80.0,
            tdp_w: 700.0,
            idle_w: 300.0,
            cost_per_hr: 4.02,
        }
    }
}

/// Model-architecture numbers the roofline needs.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub params_b: f64,
    /// Bytes per parameter (2 = bf16).
    pub bytes_per_param: f64,
    /// KV-cache bytes per token (all layers, K+V).
    pub kv_bytes_per_token: f64,
    /// Tensor-parallel degree of the serving deployment.
    pub tp: f64,
    /// Working context for the H estimate, tokens.
    pub ref_ctx: f64,
}

impl ModelSpec {
    /// Llama-3-70B: 80 layers, 8 KV heads x 128 dim, bf16 -> 320 KB/token.
    pub fn llama3_70b(tp: f64) -> Self {
        ModelSpec {
            name: "llama-3-70b".into(),
            params_b: 70.0,
            bytes_per_param: 2.0,
            kv_bytes_per_token: 327_680.0,
            tp,
            ref_ctx: 4096.0,
        }
    }
}

/// Builds GpuProfiles from first principles.
#[derive(Debug, Clone)]
pub struct ProfileBuilder {
    pub model: ModelSpec,
    /// Fixed kernel overhead per iteration, ms.
    pub kernel_overhead_ms: f64,
    /// Calibration multipliers (1.0 = raw roofline).
    pub w_scale: f64,
    pub h_scale: f64,
}

impl ProfileBuilder {
    pub fn new(model: ModelSpec) -> Self {
        ProfileBuilder {
            model,
            kernel_overhead_ms: 0.5,
            w_scale: 1.0,
            h_scale: 1.0,
        }
    }

    /// Raw roofline W (ms): weight streaming + kernel overhead.
    pub fn roofline_w_ms(&self, hw: &HardwareSpec) -> f64 {
        let weight_gb =
            self.model.params_b * self.model.bytes_per_param / self.model.tp;
        weight_gb / hw.hbm_gb_s * 1000.0 + self.kernel_overhead_ms
    }

    /// Raw roofline H (ms/slot): KV read at the reference context plus
    /// marginal matmul FLOPs for one sequence's token.
    pub fn roofline_h_ms(&self, hw: &HardwareSpec) -> f64 {
        let kv_gb = self.model.kv_bytes_per_token * self.model.ref_ctx
            / self.model.tp
            / 1e9;
        let t_kv = kv_gb / hw.hbm_gb_s * 1000.0;
        let flops = 2.0 * self.model.params_b * 1e9 / self.model.tp;
        let t_compute = flops / (hw.tflops_bf16 * 1e12) * 1000.0;
        t_kv + t_compute
    }

    /// KV block capacity: VRAM minus the weight shard, over block bytes.
    pub fn kv_blocks(&self, hw: &HardwareSpec) -> f64 {
        let weight_gb =
            self.model.params_b * self.model.bytes_per_param / self.model.tp;
        let free_gb = (hw.vram_gb - weight_gb).max(hw.vram_gb * 0.1);
        let block_bytes = self.model.kv_bytes_per_token * 16.0 / self.model.tp;
        (free_gb * 1e9 / block_bytes).floor()
    }

    /// Calibrate the builder's scale factors so that `hw` reproduces the
    /// measured `reference` profile exactly; other GPU types then inherit
    /// the same engine-efficiency correction.
    pub fn calibrate(&mut self, hw: &HardwareSpec, reference: &GpuProfile) {
        self.w_scale = reference.w_ms / self.roofline_w_ms(hw);
        self.h_scale = reference.h_ms_per_slot / self.roofline_h_ms(hw);
    }

    /// Build a profile. Chunk size scales with compute throughput.
    pub fn build(&self, hw: &HardwareSpec) -> GpuProfile {
        let chunk = if hw.tflops_bf16 >= 800.0 { 1024.0 } else { 512.0 };
        GpuProfile {
            name: hw.name.clone(),
            w_ms: self.roofline_w_ms(hw) * self.w_scale,
            h_ms_per_slot: self.roofline_h_ms(hw) * self.h_scale,
            kv_blocks: self.kv_blocks(hw),
            vram_gb: hw.vram_gb,
            chunk,
            max_num_seqs: 128.0,
            cost_per_hr: hw.cost_per_hr,
            p_idle_w: hw.idle_w,
            p_nom_w: hw.tdp_w.min(hw.idle_w + 300.0),
            power_logistic_k: 1.0,
            power_logistic_x0: 4.2,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::catalog::GpuCatalog;

    fn builder() -> ProfileBuilder {
        ProfileBuilder::new(ModelSpec::llama3_70b(8.0))
    }

    #[test]
    fn raw_roofline_orders_generations_correctly() {
        let b = builder();
        let w_a10g = b.roofline_w_ms(&HardwareSpec::a10g());
        let w_a100 = b.roofline_w_ms(&HardwareSpec::a100());
        let w_h100 = b.roofline_w_ms(&HardwareSpec::h100());
        assert!(w_a10g > w_a100 && w_a100 > w_h100);
        let h_a10g = b.roofline_h_ms(&HardwareSpec::a10g());
        let h_h100 = b.roofline_h_ms(&HardwareSpec::h100());
        assert!(h_a10g > h_h100);
    }

    #[test]
    fn raw_roofline_near_hand_calibrated_constants() {
        // The paper's constants should be within a small factor of the raw
        // roofline (they absorb FlashAttention, overlap, etc.).
        let b = builder();
        let cat = GpuCatalog::standard();
        for (hw, name) in [
            (HardwareSpec::a100(), "A100"),
            (HardwareSpec::h100(), "H100"),
        ] {
            let manual = cat.get(name).unwrap();
            let w = b.roofline_w_ms(&hw);
            let ratio = w / manual.w_ms;
            assert!(
                (0.3..3.0).contains(&ratio),
                "{name}: roofline W {w} vs manual {} (ratio {ratio})",
                manual.w_ms
            );
        }
    }

    #[test]
    fn calibration_reproduces_reference_and_transfers() {
        let mut b = builder();
        let cat = GpuCatalog::standard();
        let a100_manual = cat.get("A100").unwrap();
        b.calibrate(&HardwareSpec::a100(), a100_manual);
        let rebuilt = b.build(&HardwareSpec::a100());
        assert!((rebuilt.w_ms - a100_manual.w_ms).abs() < 1e-9);
        let dh = (rebuilt.h_ms_per_slot - a100_manual.h_ms_per_slot).abs();
        assert!(dh < 1e-9);
        // Transferred to H100, the derived constants land near the
        // hand-calibrated ones (within 2x).
        let h100 = b.build(&HardwareSpec::h100());
        let manual = cat.get("H100").unwrap();
        let wr = h100.w_ms / manual.w_ms;
        let hr = h100.h_ms_per_slot / manual.h_ms_per_slot;
        assert!((0.5..2.0).contains(&wr), "W ratio {wr}");
        assert!((0.5..2.0).contains(&hr), "H ratio {hr}");
    }

    #[test]
    fn kv_blocks_scale_with_free_vram() {
        let b = builder();
        let blocks_a100 = b.kv_blocks(&HardwareSpec::a100());
        let blocks_a10g = b.kv_blocks(&HardwareSpec::a10g());
        assert!(blocks_a100 > blocks_a10g * 5.0);
    }

    #[test]
    fn built_profile_is_usable() {
        let g = builder().build(&HardwareSpec::h100());
        assert!(g.n_max(8192.0) >= 1.0);
        assert!(g.t_iter(16.0) > 0.0);
        assert_eq!(g.chunk, 1024.0);
        assert!(g.power_w(128.0) <= g.p_nom_w);
    }
}
