//! Logistic GPU power model (paper §4.8, from the G2G framework).
//!
//! Power as a function of in-flight batch size b:
//!
//! ```text
//! P(b) = P_range / (1 + e^{-k (log2 b - x0)}) + P_idle,
//! P_range = P_nom - P_idle
//! ```
//!
//! with (k = 1.0, x0 = 4.2) fitted to ML.ENERGY Benchmark v3.0 H100-SXM5
//! data. The grid-flex analysis needs the *inverse*: given a target power,
//! find the largest batch cap that stays under it.

use crate::gpu::profile::GpuProfile;

impl GpuProfile {
    /// Power draw at in-flight batch size `b` (>= 1), watts.
    pub fn power_w(&self, b: f64) -> f64 {
        let b = b.max(1.0);
        let range = self.p_nom_w - self.p_idle_w;
        let x = b.log2();
        let z = -self.power_logistic_k * (x - self.power_logistic_x0);
        range / (1.0 + z.exp()) + self.p_idle_w
    }

    /// Largest integer batch cap whose power draw is <= `target_w`,
    /// clamped below at 1 — batch capping cannot shed power below P(1);
    /// check `power_w(1.0)` if the commitment must be strict (a cap of 1
    /// whose P(1) still exceeds the target means the node must be powered
    /// off instead, which is outside the G2G software-knob envelope).
    pub fn batch_cap_for_power(&self, target_w: f64) -> u64 {
        // Invert the logistic analytically, then floor + verify.
        let range = self.p_nom_w - self.p_idle_w;
        let frac = (target_w - self.p_idle_w) / range;
        let cap = if frac >= 1.0 {
            return u64::MAX;
        } else if frac <= 0.0 {
            1.0
        } else {
            let x = self.power_logistic_x0
                - (1.0 / frac - 1.0).ln() / self.power_logistic_k;
            x.exp2()
        };
        let mut b = cap.floor().max(1.0) as u64;
        // Guard against float slop at the boundary.
        while b > 1 && self.power_w(b as f64) > target_w {
            b -= 1;
        }
        b
    }

    /// Table-9 semantics: a demand-response request for `flex` fractional
    /// power reduction targets `(1 - flex) * P_nom`; returns the implied
    /// batch cap (>= 1).
    pub fn batch_cap_for_flex(&self, flex: f64) -> u64 {
        self.batch_cap_for_power(self.p_nom_w * (1.0 - flex))
    }
}

#[cfg(test)]
mod tests {
    use crate::gpu::catalog::GpuCatalog;

    fn h100() -> crate::gpu::profile::GpuProfile {
        GpuCatalog::standard().get("H100").unwrap().clone()
    }

    #[test]
    fn matches_paper_fit_points() {
        // §4.8: "the logistic fit gives P(1) ~ 304 W and P(128) ~ 583 W".
        let g = h100();
        assert!((g.power_w(1.0) - 304.0).abs() < 1.0, "{}", g.power_w(1.0));
        assert!((g.power_w(128.0) - 583.0).abs() < 1.0, "{}", g.power_w(128.0));
    }

    #[test]
    fn saturation_effect() {
        // §4.8: at full load power sits near nominal, so halving the batch
        // from 128 to 64 saves only a few percent. (The paper quotes
        // ~13 W; the printed (k=1.0, x0=4.2) fit gives ~25 W — both ~2-4%
        // of nominal. We assert the qualitative saturation claim.)
        let g = h100();
        let savings = g.power_w(128.0) - g.power_w(64.0);
        assert!(savings < 0.05 * g.p_nom_w, "savings = {savings}");
        assert!(g.power_w(128.0) > 0.95 * g.p_nom_w);
    }

    #[test]
    fn monotone_in_batch() {
        let g = h100();
        let mut prev = 0.0;
        for exp in 0..10 {
            let p = g.power_w((1u64 << exp) as f64);
            assert!(p > prev);
            prev = p;
        }
        assert!(prev <= g.p_nom_w);
    }

    #[test]
    fn inversion_reproduces_table9_caps() {
        // Table 9: flex % of nominal (600 W) -> n_max: 10% -> 48 (540 W),
        // 20% -> 24 (479 W), 30% -> 13 (413 W), 40% -> 6-7 (~355 W),
        // 50% -> 1 (304 W). The 40% row is fit-rounding sensitive; we
        // accept +-1 there and exact elsewhere.
        let g = h100();
        for (flex, want, tol) in [
            (0.10, 48i64, 0i64),
            (0.20, 24, 0),
            (0.30, 13, 0),
            (0.40, 6, 1),
            (0.50, 1, 0),
        ] {
            let cap = g.batch_cap_for_flex(flex) as i64;
            assert!(
                (cap - want).abs() <= tol,
                "flex {flex}: cap {cap} want {want}"
            );
        }
        // And the implied W/GPU matches the table's power column.
        assert!((g.power_w(48.0) - 540.0).abs() < 2.0);
        assert!((g.power_w(24.0) - 479.0).abs() < 2.0);
        assert!((g.power_w(13.0) - 413.0).abs() < 2.0);
    }

    #[test]
    fn impossible_targets_clamp_to_one() {
        let g = h100();
        assert_eq!(g.batch_cap_for_power(100.0), 1); // below P(1)
        assert!(g.power_w(1.0) > 100.0); // strictness check is the caller's
        assert_eq!(g.batch_cap_for_power(1e6), u64::MAX);
    }

    #[test]
    fn inverse_is_consistent_with_forward() {
        let g = h100();
        for target in [350.0, 420.0, 500.0, 560.0, 595.0] {
            let cap = g.batch_cap_for_power(target);
            assert!(g.power_w(cap as f64) <= target + 1e-9);
            assert!(g.power_w((cap + 1) as f64) > target);
        }
    }
}
