//! Physics-informed GPU performance model (paper §2.1, §3.2, §4.8):
//! profiles, the KV-slot math, the roofline ProfileBuilder, and the
//! logistic power model used by grid-flex analysis.

pub mod builder;
pub mod catalog;
pub mod power;
pub mod profile;
