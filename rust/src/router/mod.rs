//! Routing policies (paper §3.4): LengthRouter, CompressAndRoute,
//! RandomRouter, ModelRouter.
//!
//! A router maps an incoming request to a pool index and may transform the
//! request on the way (CompressAndRoute shrinks borderline prompts back
//! under the threshold, paper §2.1 / Chen et al. 2026). Routers are
//! deterministic given the request and the RNG stream, so DES runs are
//! reproducible. Closed-loop retries ([`crate::des::retry`]) are sticky:
//! a retry re-enters the pool chosen for attempt 1 and consumes **no**
//! additional routing draws, so attaching a retry config never perturbs
//! the ROUTING stream.

use crate::workload::rng::Pcg64;

/// A request as seen by the router.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RouteRequest {
    pub l_in: f64,
    pub l_out: f64,
    /// Semantic class for multi-model fleets (ModelRouter); 0 otherwise.
    pub class: usize,
}

impl RouteRequest {
    pub fn total(&self) -> f64 {
        self.l_in + self.l_out
    }
}

/// Routing decision: destination pool plus the (possibly transformed)
/// request that will actually be served.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RouteDecision {
    pub pool: usize,
    pub request: RouteRequest,
    /// True if the router compressed the request (CompressAndRoute).
    pub compressed: bool,
}

/// The four routing policies of paper §3.4.
#[derive(Debug, Clone, PartialEq)]
pub enum RoutingPolicy {
    /// Pool 0 if total budget <= b_short, else pool 1. Default production
    /// policy.
    Length { b_short: f64 },
    /// Compress borderline requests (b_short < total <= gamma * b_short)
    /// down to b_short and send them short; intended for fleet *sizing*,
    /// not production (paper §4.5 / Insight 5).
    CompressAndRoute { b_short: f64, gamma: f64 },
    /// Uniform random across `n_pools`; baseline.
    Random { n_pools: usize },
    /// Semantic classifier: request class -> pool index.
    Model { class_to_pool: Vec<usize> },
}

impl RoutingPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RoutingPolicy::Length { .. } => "LengthRouter",
            RoutingPolicy::CompressAndRoute { .. } => "CompressAndRoute",
            RoutingPolicy::Random { .. } => "RandomRouter",
            RoutingPolicy::Model { .. } => "ModelRouter",
        }
    }

    /// Number of pools this policy expects downstream.
    pub fn n_pools(&self) -> usize {
        match self {
            RoutingPolicy::Length { .. }
            | RoutingPolicy::CompressAndRoute { .. } => 2,
            RoutingPolicy::Random { n_pools } => *n_pools,
            RoutingPolicy::Model { class_to_pool } => {
                class_to_pool.iter().copied().max().map_or(1, |m| m + 1)
            }
        }
    }

    /// Route one request.
    pub fn route(&self, req: RouteRequest, rng: &mut Pcg64) -> RouteDecision {
        match self {
            RoutingPolicy::Length { b_short } => RouteDecision {
                pool: if req.total() <= *b_short { 0 } else { 1 },
                request: req,
                compressed: false,
            },
            RoutingPolicy::CompressAndRoute { b_short, gamma } => {
                let total = req.total();
                if total <= *b_short {
                    RouteDecision { pool: 0, request: req, compressed: false }
                } else if total <= gamma * b_short {
                    // Compress the prompt so that the *total* budget fits
                    // b_short; completion tokens are untouched (the
                    // gateway can squeeze the prompt, not the answer).
                    let l_in = (b_short - req.l_out).max(1.0);
                    let request = RouteRequest { l_in, ..req };
                    RouteDecision { pool: 0, request, compressed: true }
                } else {
                    RouteDecision { pool: 1, request: req, compressed: false }
                }
            }
            RoutingPolicy::Random { n_pools } => RouteDecision {
                pool: rng.below(*n_pools as u64) as usize,
                request: req,
                compressed: false,
            },
            RoutingPolicy::Model { class_to_pool } => RouteDecision {
                pool: class_to_pool[req.class.min(class_to_pool.len() - 1)],
                request: req,
                compressed: false,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(l_in: f64, l_out: f64) -> RouteRequest {
        RouteRequest { l_in, l_out, class: 0 }
    }

    #[test]
    fn length_router_splits_at_threshold() {
        let r = RoutingPolicy::Length { b_short: 4096.0 };
        let mut rng = Pcg64::new(1, 0);
        assert_eq!(r.route(req(2000.0, 2096.0), &mut rng).pool, 0); // == B
        assert_eq!(r.route(req(2000.0, 2097.0), &mut rng).pool, 1); // B + 1
        assert_eq!(r.route(req(100.0, 50.0), &mut rng).pool, 0);
    }

    #[test]
    fn compress_squeezes_borderline_only() {
        let r = RoutingPolicy::CompressAndRoute { b_short: 4096.0, gamma: 1.5 };
        let mut rng = Pcg64::new(2, 0);
        // Below threshold: untouched.
        let d = r.route(req(3000.0, 500.0), &mut rng);
        assert_eq!((d.pool, d.compressed), (0, false));
        // Borderline (4096 < 5000 <= 6144): compressed short.
        let d = r.route(req(4500.0, 500.0), &mut rng);
        assert_eq!((d.pool, d.compressed), (0, true));
        assert_eq!(d.request.total(), 4096.0);
        assert_eq!(d.request.l_out, 500.0); // completion preserved
        // Genuinely long (> gamma * B): long pool, untouched.
        let d = r.route(req(8000.0, 500.0), &mut rng);
        assert_eq!((d.pool, d.compressed), (1, false));
        assert_eq!(d.request.l_in, 8000.0);
    }

    #[test]
    fn compress_never_zeroes_prompt() {
        let r = RoutingPolicy::CompressAndRoute { b_short: 1000.0, gamma: 2.0 };
        let mut rng = Pcg64::new(3, 0);
        // l_out alone exceeds b_short: prompt floors at 1 token.
        let d = r.route(req(500.0, 1200.0), &mut rng);
        assert!(d.compressed);
        assert_eq!(d.request.l_in, 1.0);
    }

    #[test]
    fn random_router_is_roughly_uniform() {
        let r = RoutingPolicy::Random { n_pools: 4 };
        let mut rng = Pcg64::new(4, 0);
        let mut counts = [0usize; 4];
        for _ in 0..40_000 {
            counts[r.route(req(100.0, 10.0), &mut rng).pool] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn model_router_maps_classes() {
        let r = RoutingPolicy::Model { class_to_pool: vec![0, 2, 1] };
        let mut rng = Pcg64::new(5, 0);
        for (class, want) in [(0usize, 0usize), (1, 2), (2, 1), (9, 1)] {
            let req = RouteRequest { l_in: 10.0, l_out: 5.0, class };
            let d = r.route(req, &mut rng);
            assert_eq!(d.pool, want, "class {class}");
        }
        assert_eq!(r.n_pools(), 3);
    }

    #[test]
    fn pool_counts() {
        assert_eq!(RoutingPolicy::Length { b_short: 1.0 }.n_pools(), 2);
        assert_eq!(RoutingPolicy::Random { n_pools: 7 }.n_pools(), 7);
    }
}
