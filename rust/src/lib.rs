//! # inference-fleet-sim
//!
//! A queueing-theory-grounded fleet capacity planner for LLM inference —
//! a full reproduction of *"inference-fleet-sim: A Queueing-Theory-Grounded
//! Fleet Capacity Planner for LLM Inference"* (CS.DC 2026) as a
//! three-layer rust + JAX/Pallas system.
//!
//! Given a token-length CDF, an arrival rate λ, a P99-TTFT SLO, and a
//! catalog of GPU types, the planner finds the minimum-cost fleet
//! configuration — pool count, split threshold `B_short`, GPU type per
//! pool, routing policy — that empirically meets the SLO:
//!
//! 1. **Phase 1 — analytical sweep** (paper §3.1): M/G/c with Kimura's
//!    two-moment approximation over the whole candidate grid. The batched
//!    evaluator is a JAX/Pallas computation AOT-compiled to
//!    `artifacts/sweep.hlo.txt` and executed via PJRT ([`runtime`]), with
//!    a numerically-equivalent pure-rust fallback in [`optimizer::analytic`].
//! 2. **Phase 2 — DES verification** (paper §3.1): the top candidates are
//!    replayed through a request-level discrete-event simulation with
//!    slot-level continuous batching ([`des`]), which is authoritative for
//!    heavy-tailed workloads where Erlang-C under-estimates tail latency.
//!
//! The crate also contains every substrate the paper depends on: the
//! physics-informed GPU performance model ([`gpu`]), the workload model
//! with the LMSYS / Azure / agent CDFs ([`workload`]), the four routing
//! policies ([`router`]), disaggregated prefill/decode planning, grid
//! demand-response analysis, and reliability-aware sizing ([`optimizer`]).
//!
//! The paper's case studies live in the **scenario registry**
//! ([`scenarios`]): each puzzle is a declarative [`scenarios::Scenario`]
//! run by the shared [`optimizer::engine::EvalEngine`], which owns
//! Phase-1 backend selection, the cached sampled-request streams, and the
//! parallel minimal-fleet sweeps. `fleet-sim scenarios` lists them;
//! `fleet-sim run --scenario <id|name>` regenerates any paper table.
//!
//! ## Quickstart
//!
//! ```no_run
//! use fleet_sim::prelude::*;
//!
//! let workload = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
//! let optimizer = FleetOptimizer::new(GpuCatalog::standard(), 500.0);
//! let plan = optimizer.plan(&workload);
//! println!("{}", plan.summary());
//! ```

pub mod cli;
pub mod des;
pub mod gpu;
pub mod optimizer;
pub mod queueing;
pub mod report;
pub mod router;
pub mod runtime;
pub mod scenarios;
pub mod util;
pub mod workload;

/// Convenience re-exports of the main planner API surface.
pub mod prelude {
    pub use crate::des::engine::{DesConfig, SimPool, Simulator};
    pub use crate::des::faults::{FaultModel, FaultScript, GpuFailure,
                                 OutageSpec, Straggler};
    pub use crate::des::input::{ArrivalsSource, ConfigError, SimInput};
    pub use crate::des::memory::{MemoryConfig, MemorySpec, PolicyKind,
                                 PreemptionPolicy};
    pub use crate::des::metrics::{DesResult, MetricsMode};
    pub use crate::des::reference::run_reference_input;
    pub use crate::des::retry::{backoff_ms, AdmissionSpec, RetryConfig,
                                RetrySpec};
    pub use crate::des::shard::{run_sharded_input, run_streamed_input};
    pub use crate::gpu::catalog::GpuCatalog;
    pub use crate::gpu::profile::GpuProfile;
    pub use crate::optimizer::planner::{FleetOptimizer, FleetPlan};
    pub use crate::queueing::mgc::{PoolAnalysis, PoolSpec, WorkloadHist};
    pub use crate::router::RoutingPolicy;
    pub use crate::workload::spec::{BuiltinTrace, WorkloadSpec};
}
