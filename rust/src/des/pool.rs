//! Pool state for the DES: a FIFO queue in front of `n` GPU instances,
//! each with a KV-slot budget (paper §2.1 slot math + §3.1 Phase 2).

use crate::gpu::profile::GpuProfile;

/// One GPU instance: `n_max` concurrent KV slots, of which `busy` are held.
#[derive(Debug, Clone)]
pub struct GpuInstance {
    pub busy: u32,
    /// Slot capacity at the pool's context budget (possibly batch-capped).
    pub n_max: u32,
    /// Accumulated busy slot-milliseconds (for utilization reporting).
    pub busy_slot_ms: f64,
    last_change_ms: f64,
}

impl GpuInstance {
    fn new(n_max: u32) -> Self {
        GpuInstance { busy: 0, n_max, busy_slot_ms: 0.0, last_change_ms: 0.0 }
    }

    fn account(&mut self, now_ms: f64) {
        self.busy_slot_ms += self.busy as f64 * (now_ms - self.last_change_ms);
        self.last_change_ms = now_ms;
    }

    fn acquire(&mut self, now_ms: f64) {
        self.account(now_ms);
        self.busy += 1;
        debug_assert!(self.busy <= self.n_max);
    }

    fn release(&mut self, now_ms: f64) {
        self.account(now_ms);
        debug_assert!(self.busy > 0);
        self.busy -= 1;
    }

    pub fn free(&self) -> u32 {
        self.n_max.saturating_sub(self.busy)
    }
}

/// A serving pool: GPU type, context budget, FIFO queue, instances.
#[derive(Debug, Clone)]
pub struct DesPool {
    pub gpu: GpuProfile,
    /// Context budget the KV cache is provisioned for.
    pub ctx_budget: f64,
    /// Effective slot count per instance = min(n_max(ctx), batch_cap).
    pub slots_per_gpu: u32,
    pub instances: Vec<GpuInstance>,
    /// FIFO of request ids waiting for a slot.
    pub queue: std::collections::VecDeque<u32>,
    /// Peak queue depth observed (reporting).
    pub max_queue_depth: usize,
}

impl DesPool {
    /// Build a pool of `n_gpus` instances. `batch_cap` models vLLM's
    /// `max_num_seqs` (None = KV-limited only); grid-flex analysis lowers
    /// it to shed power (paper §4.8).
    pub fn new(
        gpu: GpuProfile,
        n_gpus: usize,
        ctx_budget: f64,
        batch_cap: Option<u32>,
    ) -> Self {
        let kv_slots = gpu.n_eff(ctx_budget) as u32;
        let slots = batch_cap.map_or(kv_slots, |c| c.min(kv_slots)).max(1);
        DesPool {
            gpu,
            ctx_budget,
            slots_per_gpu: slots,
            instances: (0..n_gpus).map(|_| GpuInstance::new(slots)).collect(),
            queue: std::collections::VecDeque::new(),
            max_queue_depth: 0,
        }
    }

    /// Index of the instance with the most free slots (least-loaded
    /// dispatch), or None if every slot in the pool is held.
    pub fn pick_instance(&self) -> Option<usize> {
        let (idx, inst) = self
            .instances
            .iter()
            .enumerate()
            .max_by_key(|(_, inst)| inst.free())?;
        if inst.free() > 0 {
            Some(idx)
        } else {
            None
        }
    }

    pub fn acquire(&mut self, instance: usize, now_ms: f64) {
        self.instances[instance].acquire(now_ms);
    }

    pub fn release(&mut self, instance: usize, now_ms: f64) {
        self.instances[instance].release(now_ms);
    }

    pub fn enqueue(&mut self, req: u32) {
        self.queue.push_back(req);
        self.max_queue_depth = self.max_queue_depth.max(self.queue.len());
    }

    /// Mean slot utilization over [0, horizon_ms]. The denominator is
    /// always the *nominal* capacity — under a fault script
    /// ([`crate::des::faults`]) an outage shows up as lost utilization,
    /// never as a shrunken fleet.
    pub fn utilization(&self, horizon_ms: f64) -> f64 {
        if horizon_ms <= 0.0 || self.instances.is_empty() {
            return 0.0;
        }
        let total: f64 = self
            .instances
            .iter()
            .map(|i| {
                i.busy_slot_ms
                    + i.busy as f64 * (horizon_ms - i.last_change_ms)
            })
            .sum();
        let slots =
            self.instances.len() as f64 * self.slots_per_gpu as f64;
        total / (horizon_ms * slots)
    }

    /// Total free slots across the pool.
    pub fn free_slots(&self) -> u32 {
        self.instances.iter().map(|i| i.free()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::catalog::GpuCatalog;

    fn a100() -> GpuProfile {
        GpuCatalog::standard().get("A100").unwrap().clone()
    }

    #[test]
    fn slots_follow_ctx_budget() {
        let p = DesPool::new(a100(), 3, 8192.0, None);
        assert_eq!(p.slots_per_gpu, 128);
        assert_eq!(p.free_slots(), 384);
        let p65k = DesPool::new(a100(), 3, 65536.0, None);
        assert_eq!(p65k.slots_per_gpu, 16);
    }

    #[test]
    fn batch_cap_limits_slots() {
        let p = DesPool::new(a100(), 1, 4096.0, Some(13));
        assert_eq!(p.slots_per_gpu, 13);
        // Cap above KV limit has no effect.
        let p2 = DesPool::new(a100(), 1, 65536.0, Some(10_000));
        assert_eq!(p2.slots_per_gpu, 16);
        // Cap of zero clamps to one slot.
        let p3 = DesPool::new(a100(), 1, 4096.0, Some(0));
        assert_eq!(p3.slots_per_gpu, 1);
    }

    #[test]
    fn least_loaded_dispatch() {
        let mut p = DesPool::new(a100(), 2, 65536.0, None);
        p.acquire(0, 0.0);
        p.acquire(0, 0.0);
        assert_eq!(p.pick_instance(), Some(1));
        p.acquire(1, 0.0);
        p.acquire(1, 0.0);
        p.acquire(1, 0.0);
        assert_eq!(p.pick_instance(), Some(0));
    }

    #[test]
    fn full_pool_returns_none() {
        let mut p = DesPool::new(a100(), 1, 65536.0, Some(2));
        p.acquire(0, 0.0);
        p.acquire(0, 0.0);
        assert_eq!(p.pick_instance(), None);
        p.release(0, 10.0);
        assert_eq!(p.pick_instance(), Some(0));
    }

    #[test]
    fn utilization_accounting() {
        let mut p = DesPool::new(a100(), 1, 65536.0, Some(2));
        // One slot busy for the whole horizon, the other for half.
        p.acquire(0, 0.0);
        p.acquire(0, 50.0);
        p.release(0, 100.0);
        let u = p.utilization(100.0);
        // slot-ms = 1*100 + 1*50 = 150 of 200 -> 0.75.
        assert!((u - 0.75).abs() < 1e-9, "u = {u}");
    }

    #[test]
    fn queue_depth_tracking() {
        let mut p = DesPool::new(a100(), 1, 65536.0, None);
        for i in 0..5 {
            p.enqueue(i);
        }
        p.queue.pop_front();
        p.enqueue(99);
        assert_eq!(p.max_queue_depth, 5);
    }
}
