//! The reference all-events-heap DES.
//!
//! This is the original engine structure: every arrival is a heap event
//! (pushed first, so arrivals win time ties against completions and
//! drains by sequence number), scheduled on the `BinaryHeap`-backed
//! [`EventQueue`]. The production engine ([`crate::des::engine`]) replaced
//! this with merge-consumed arrivals plus a calendar queue; this module is
//! the semantic anchor it is pinned against:
//!
//! * `rust/tests/des_regression.rs` asserts the production engine is
//!   *bit-identical* to `run_reference` across workloads, routers, cap
//!   windows, and class mixes;
//! * the perf harness (`fleet-sim bench`) times it as the baseline the
//!   calendar-queue engine's speedup is measured against.
//!
//! Keep this implementation boring. It trades speed for obviousness on
//! purpose — do not port engine optimizations back into it.

use crate::des::engine::{CapWindow, DesConfig, SimPool};
use crate::des::event::{EventKind, EventQueue};
use crate::des::faults::CompiledFaults;
use crate::des::input::{ArrivalsSource, ConfigError, SimInput};
use crate::des::memory::{self, MemState, MemoryConfig};
use crate::des::metrics::{DesResult, MetricsCollector, PoolResult};
use crate::des::pool::DesPool;
use crate::des::retry::{ClosedLoopState, Phase, RetryConfig};
use crate::router::{RouteRequest, RoutingPolicy};
use crate::workload::rng::Pcg64;
use crate::workload::spec::SampledRequest;
use crate::workload::streams;

struct RefReq {
    arrival_ms: f64,
    l_in: f64,
    l_out: f64,
}

fn eff_cap(cap_window: &Option<CapWindow>, pool: &DesPool, t: f64) -> u32 {
    let mut cap = pool.slots_per_gpu;
    if let Some(w) = cap_window {
        if t >= w.start_ms && t < w.end_ms {
            cap = cap.min(w.cap.max(1));
        }
    }
    cap
}

#[allow(clippy::too_many_arguments)]
fn try_admit(
    pools: &mut [DesPool],
    pool_idx: usize,
    req_id: u32,
    reqs: &[RefReq],
    now: f64,
    events: &mut EventQueue,
    cap_window: &Option<CapWindow>,
    faults: Option<&CompiledFaults>,
    metrics: &mut MetricsCollector,
) -> bool {
    let eff = eff_cap(cap_window, &pools[pool_idx], now);
    let pool = &mut pools[pool_idx];
    let mut best: Option<(usize, u32)> = None;
    for (i, inst) in pool.instances.iter().enumerate() {
        if faults.is_some_and(|f| f.is_down(pool_idx, i, now)) {
            continue;
        }
        if inst.busy < eff {
            let free = eff - inst.busy;
            if best.map_or(true, |(_, bf)| free > bf) {
                best = Some((i, free));
            }
        }
    }
    let Some((inst, _)) = best else { return false };
    pool.acquire(inst, now);
    let req = &reqs[req_id as usize];
    let n_at_admit = pool.instances[inst].busy as f64;
    let slow = faults.map_or(1.0, |f| f.slowdown(pool_idx, inst, now));
    let t_iter = pool.gpu.t_iter(n_at_admit) * slow;
    let hold = pool.gpu.iters(req.l_in, req.l_out) * t_iter;
    events.push(
        now + hold,
        EventKind::Completion {
            req: req_id,
            pool: pool_idx as u16,
            instance: inst as u16,
        },
    );
    let wait = now - req.arrival_ms;
    let prefill = (req.l_in / pool.gpu.chunk).ceil() * t_iter;
    let ttft = wait + prefill + t_iter;
    let e2e = wait + hold;
    metrics.record(pool_idx, req.arrival_ms, wait, ttft, e2e);
    true
}

#[allow(clippy::too_many_arguments)]
fn drain_queue(
    pools: &mut [DesPool],
    pool_idx: usize,
    reqs: &[RefReq],
    now: f64,
    events: &mut EventQueue,
    cap_window: &Option<CapWindow>,
    faults: Option<&CompiledFaults>,
    metrics: &mut MetricsCollector,
) {
    while let Some(&head) = pools[pool_idx].queue.front() {
        if !try_admit(
            pools, pool_idx, head, reqs, now, events, cap_window, faults,
            metrics,
        ) {
            break;
        }
        pools[pool_idx].queue.pop_front();
    }
}

/// Closed-loop mirror of `try_admit`: same slot selection and timing
/// math, plus the attempt-deadline check (see
/// `crate::des::engine::try_admit_closed`, which this pins).
#[allow(clippy::too_many_arguments)]
fn try_admit_closed(
    pools: &mut [DesPool],
    pool_idx: usize,
    req_id: u32,
    reqs: &[RefReq],
    now: f64,
    events: &mut EventQueue,
    cap_window: &Option<CapWindow>,
    faults: Option<&CompiledFaults>,
    metrics: &mut MetricsCollector,
    closed: &mut ClosedLoopState,
) -> bool {
    let eff = eff_cap(cap_window, &pools[pool_idx], now);
    let pool = &mut pools[pool_idx];
    let mut best: Option<(usize, u32)> = None;
    for (i, inst) in pool.instances.iter().enumerate() {
        if faults.is_some_and(|f| f.is_down(pool_idx, i, now)) {
            continue;
        }
        if inst.busy < eff {
            let free = eff - inst.busy;
            if best.map_or(true, |(_, bf)| free > bf) {
                best = Some((i, free));
            }
        }
    }
    let Some((inst, _)) = best else { return false };
    pool.acquire(inst, now);
    let req = &reqs[req_id as usize];
    let n_at_admit = pool.instances[inst].busy as f64;
    let slow = faults.map_or(1.0, |f| f.slowdown(pool_idx, inst, now));
    let t_iter = pool.gpu.t_iter(n_at_admit) * slow;
    let hold = pool.gpu.iters(req.l_in, req.l_out) * t_iter;
    let st = &mut closed.states[req_id as usize];
    st.instance = inst as u16;
    if now + hold <= st.deadline_ms {
        st.phase = Phase::InFlight;
        events.push(
            now + hold,
            EventKind::Completion {
                req: req_id,
                pool: pool_idx as u16,
                instance: inst as u16,
            },
        );
        let first = st.first_arrival_ms;
        let wait = now - first;
        let prefill = (req.l_in / pool.gpu.chunk).ceil() * t_iter;
        let ttft = wait + prefill + t_iter;
        let e2e = wait + hold;
        metrics.record(pool_idx, first, wait, ttft, e2e);
    } else {
        st.phase = Phase::Doomed;
    }
    true
}

/// Closed-loop mirror of `crate::des::engine::start_attempt`.
#[allow(clippy::too_many_arguments)]
fn start_attempt(
    pools: &mut [DesPool],
    req_id: u32,
    reqs: &[RefReq],
    now: f64,
    events: &mut EventQueue,
    cap_window: &Option<CapWindow>,
    faults: Option<&CompiledFaults>,
    metrics: &mut MetricsCollector,
    closed: &mut ClosedLoopState,
) {
    let (pool_idx, first, attempt) = {
        let st = &closed.states[req_id as usize];
        (st.pool as usize, st.first_arrival_ms, st.attempt)
    };
    metrics.record_attempt(first);
    if closed.breaker_is_open(pool_idx) {
        closed.states[req_id as usize].phase = Phase::Done;
        metrics.record_shed(first);
        return;
    }
    let deadline = closed.deadline_after(now);
    closed.states[req_id as usize].deadline_ms = deadline;
    if try_admit_closed(
        pools, pool_idx, req_id, reqs, now, events, cap_window, faults,
        metrics, closed,
    ) {
        if closed.states[req_id as usize].phase == Phase::Doomed {
            events.push(
                deadline,
                EventKind::Timeout {
                    req: req_id,
                    pool: pool_idx as u16,
                    attempt,
                },
            );
        }
        return;
    }
    let bound = closed.queue_bound();
    if bound > 0 && pools[pool_idx].queue.len() >= bound {
        closed.states[req_id as usize].phase = Phase::Done;
        metrics.record_shed(first);
        return;
    }
    closed.states[req_id as usize].phase = Phase::Queued;
    pools[pool_idx].enqueue(req_id);
    if deadline.is_finite() {
        events.push(
            deadline,
            EventKind::Timeout {
                req: req_id,
                pool: pool_idx as u16,
                attempt,
            },
        );
    }
    let len = pools[pool_idx].queue.len();
    closed.note_queue_len(pool_idx, len);
}

/// Closed-loop mirror of `crate::des::engine::abandon_or_retry`.
fn abandon_or_retry(
    req_id: u32,
    now: f64,
    events: &mut EventQueue,
    metrics: &mut MetricsCollector,
    closed: &mut ClosedLoopState,
) {
    let st = closed.states[req_id as usize];
    if st.attempt < closed.max_attempts() {
        closed.states[req_id as usize].phase = Phase::Backoff;
        let delay = closed.backoff_after(st.global_id, st.attempt);
        events.push(
            now + delay,
            EventKind::Retry { req: req_id, pool: st.pool },
        );
    } else {
        closed.states[req_id as usize].phase = Phase::Done;
        metrics.record_abandoned(st.first_arrival_ms);
    }
}

/// Closed-loop mirror of `crate::des::engine::drain_queue_closed`.
#[allow(clippy::too_many_arguments)]
fn drain_queue_closed(
    pools: &mut [DesPool],
    pool_idx: usize,
    reqs: &[RefReq],
    now: f64,
    events: &mut EventQueue,
    cap_window: &Option<CapWindow>,
    faults: Option<&CompiledFaults>,
    metrics: &mut MetricsCollector,
    closed: &mut ClosedLoopState,
) {
    while let Some(&head) = pools[pool_idx].queue.front() {
        if !try_admit_closed(
            pools, pool_idx, head, reqs, now, events, cap_window, faults,
            metrics, closed,
        ) {
            break;
        }
        pools[pool_idx].queue.pop_front();
        let len = pools[pool_idx].queue.len();
        closed.note_queue_len(pool_idx, len);
    }
}

/// Run the reference simulator on an explicit, time-ordered request
/// stream. Honors `config.metrics` so both exact and streaming
/// collection can be compared bit-for-bit against the production engine.
#[deprecated(note = "build a SimInput and call run_reference_input")]
pub fn run_reference(
    pool_specs: &[SimPool],
    router: &RoutingPolicy,
    config: &DesConfig,
    sampled: &[SampledRequest],
) -> DesResult {
    let input = SimInput::stream(pool_specs, router, config, sampled);
    match run_reference_input(&input) {
        Ok(r) => r,
        Err(e) => panic!("{e}"),
    }
}

/// Run the reference simulator on a validated [`SimInput`]. A
/// `Generator` arrivals source is materialized up front
/// (`config.n_requests` requests) — the reference engine is the
/// semantic anchor, not the streaming workhorse.
pub fn run_reference_input(
    input: &SimInput<'_>,
) -> Result<DesResult, ConfigError> {
    input.validate()?;
    let faults = input.compiled_faults();
    match input.arrivals {
        ArrivalsSource::Stream(sampled) => Ok(run_core(
            input.pools, input.router, input.config, sampled,
            faults.as_ref(), input.retries, input.memory,
        )),
        ArrivalsSource::Generator(w) => {
            let sampled = w.sample_requests(
                input.config.n_requests, input.config.seed,
            );
            Ok(run_core(
                input.pools, input.router, input.config, &sampled,
                faults.as_ref(), input.retries, input.memory,
            ))
        }
    }
}

fn run_core(
    pool_specs: &[SimPool],
    router: &RoutingPolicy,
    config: &DesConfig,
    sampled: &[SampledRequest],
    faults: Option<&CompiledFaults>,
    retries: Option<&RetryConfig>,
    mem_cfg: Option<&MemoryConfig>,
) -> DesResult {
    let n = sampled.len();
    let mut route_rng = Pcg64::new(config.seed, streams::ROUTING);
    let mut closed: Option<ClosedLoopState> =
        retries.map(|c| ClosedLoopState::new(c, config.seed,
                                             pool_specs.len()));
    let mut pools: Vec<DesPool> = pool_specs
        .iter()
        .map(|p| {
            DesPool::new(p.gpu.clone(), p.n_gpus, p.ctx_budget, p.batch_cap)
        })
        .collect();
    let mut reqs: Vec<RefReq> = sampled
        .iter()
        .map(|s| RefReq {
            arrival_ms: s.arrival_ms,
            l_in: s.l_in,
            l_out: s.l_out,
        })
        .collect();
    // The memory protocol lives entirely in [`crate::des::memory`],
    // generic over the event sink — the reference heap runs the exact
    // same state machine as the calendar-queue engine.
    let mut mem: Option<MemState> =
        mem_cfg.map(|m| MemState::new(m, &pools));

    let mut events = EventQueue::with_capacity(2 * n + 4);
    for (i, r) in reqs.iter().enumerate() {
        events.push(r.arrival_ms, EventKind::Arrival { req: i as u32 });
    }
    if let Some(w) = &config.cap_window {
        for p in 0..pools.len() {
            events.push(w.end_ms, EventKind::Drain { pool: p as u16 });
        }
    }
    // Fault-recovery drains, after cap drains and in script order — the
    // same init order every engine uses, so sequence numbers (and thus
    // same-time tie-breaks) agree bit-for-bit across engines and shard
    // counts.
    if let Some(f) = faults {
        for &(t, pool) in f.drains() {
            events.push(t, EventKind::Drain { pool });
        }
    }

    let warmup_time_ms = config.warmup_frac
        * sampled.last().map_or(0.0, |r| r.arrival_ms);
    let mut metrics = MetricsCollector::new(
        config.metrics, pools.len(), n, config.window_ms, warmup_time_ms,
    );
    let mut n_compressed = 0usize;
    let mut n_events = 0usize;
    let mut horizon = 0.0f64;

    while let Some(ev) = events.pop() {
        n_events += 1;
        let now = ev.time_ms;
        horizon = horizon.max(now);
        match ev.kind {
            EventKind::Arrival { req } => {
                let r = &reqs[req as usize];
                metrics.record_arrival(r.arrival_ms);
                let class = match &config.class_probs {
                    None => 0,
                    Some(probs) => {
                        let u = route_rng.uniform();
                        let mut cum = 0.0;
                        let mut cls = probs.len() - 1;
                        for (i, p) in probs.iter().enumerate() {
                            cum += p;
                            if u < cum {
                                cls = i;
                                break;
                            }
                        }
                        cls
                    }
                };
                let decision = router.route(
                    RouteRequest { l_in: r.l_in, l_out: r.l_out, class },
                    &mut route_rng,
                );
                let r = &mut reqs[req as usize];
                r.l_in = decision.request.l_in;
                r.l_out = decision.request.l_out;
                if decision.compressed {
                    n_compressed += 1;
                }
                if let Some(cl) = closed.as_mut() {
                    cl.init_request(req as usize, u64::from(req), now);
                    cl.states[req as usize].pool = decision.pool as u16;
                    start_attempt(
                        &mut pools, req, &reqs, now, &mut events,
                        &config.cap_window, faults, &mut metrics, cl,
                    );
                } else if let Some(ms) = mem.as_mut() {
                    let (l_in, l_out) = (r.l_in, r.l_out);
                    ms.init_request(req, l_in, l_out, now);
                    if !ms.try_admit(
                        &mut pools, decision.pool, req, now, &mut events,
                        &config.cap_window, faults,
                    ) {
                        pools[decision.pool].enqueue(req);
                    }
                } else if !try_admit(
                    &mut pools, decision.pool, req, &reqs, now, &mut events,
                    &config.cap_window, faults, &mut metrics,
                ) {
                    pools[decision.pool].enqueue(req);
                }
            }
            EventKind::Completion { req, pool, instance } => {
                pools[pool as usize].release(instance as usize, now);
                if let Some(cl) = closed.as_mut() {
                    cl.states[req as usize].phase = Phase::Done;
                    drain_queue_closed(
                        &mut pools, pool as usize, &reqs, now, &mut events,
                        &config.cap_window, faults, &mut metrics, cl,
                    );
                } else {
                    drain_queue(
                        &mut pools, pool as usize, &reqs, now, &mut events,
                        &config.cap_window, faults, &mut metrics,
                    );
                }
            }
            EventKind::Drain { pool } => {
                if let Some(cl) = closed.as_mut() {
                    drain_queue_closed(
                        &mut pools, pool as usize, &reqs, now, &mut events,
                        &config.cap_window, faults, &mut metrics, cl,
                    );
                } else if let Some(ms) = mem.as_mut() {
                    ms.drain(
                        &mut pools, pool as usize, now, &mut events,
                        &config.cap_window, faults,
                    );
                } else {
                    drain_queue(
                        &mut pools, pool as usize, &reqs, now, &mut events,
                        &config.cap_window, faults, &mut metrics,
                    );
                }
            }
            EventKind::MemCompletion { req, pool, instance, gen } => {
                let ms = mem
                    .as_mut()
                    .expect("memory events exist only in memory mode");
                ms.on_completion(
                    &mut pools, pool as usize, instance as usize, req, gen,
                    now, &mut events, &config.cap_window, faults,
                    &mut metrics,
                );
            }
            EventKind::MemPressure { pool, instance, epoch } => {
                let ms = mem
                    .as_mut()
                    .expect("memory events exist only in memory mode");
                ms.on_pressure(
                    &mut pools, pool as usize, instance as usize, epoch,
                    now, &mut events, &config.cap_window, faults,
                    &mut metrics,
                );
            }
            EventKind::Timeout { req, pool, attempt } => {
                let cl = closed
                    .as_mut()
                    .expect("timeouts exist only in closed-loop runs");
                let st = cl.states[req as usize];
                if st.attempt != attempt {
                    continue; // superseded by a later attempt
                }
                match st.phase {
                    Phase::Queued => {
                        let q = &mut pools[pool as usize].queue;
                        if let Some(pos) = q.iter().position(|&r| r == req) {
                            q.remove(pos);
                        }
                        let len = pools[pool as usize].queue.len();
                        cl.note_queue_len(pool as usize, len);
                        abandon_or_retry(
                            req, now, &mut events, &mut metrics, cl,
                        );
                    }
                    Phase::Doomed => {
                        pools[pool as usize]
                            .release(st.instance as usize, now);
                        abandon_or_retry(
                            req, now, &mut events, &mut metrics, cl,
                        );
                        drain_queue_closed(
                            &mut pools, pool as usize, &reqs, now,
                            &mut events, &config.cap_window, faults,
                            &mut metrics, cl,
                        );
                    }
                    _ => {}
                }
            }
            EventKind::Retry { req, pool: _ } => {
                let cl = closed
                    .as_mut()
                    .expect("retries exist only in closed-loop runs");
                cl.states[req as usize].attempt += 1;
                start_attempt(
                    &mut pools, req, &reqs, now, &mut events,
                    &config.cap_window, faults, &mut metrics, cl,
                );
            }
        }
    }

    let (n_unserved, max_unserved_wait, pool_unserved) = metrics
        .scan_unserved(&pools, |req| reqs[req as usize].arrival_ms, horizon);
    let mem_raw = mem.as_ref().map(|m| m.raws());
    let (kv_peak, kv_mean, n_preempted, preempt_stall) = match &mem_raw {
        Some(raws) => memory::overall_from_raw(raws, horizon),
        None => (0.0, 0.0, 0, 0.0),
    };

    DesResult {
        per_pool: pools
            .iter()
            .zip(metrics.per_pool)
            .zip(pool_unserved)
            .enumerate()
            .map(|(i, ((p, stats), n_unserved))| {
                let (pk, mn, np, st) = match &mem_raw {
                    Some(raws) => {
                        let (pk, mn) =
                            memory::pool_util_from_raw(&raws[i], horizon);
                        (pk, mn, raws[i].n_preempted, raws[i].stall_ms)
                    }
                    None => (0.0, 0.0, 0, 0.0),
                };
                PoolResult {
                    stats,
                    utilization: p.utilization(horizon),
                    max_queue_depth: p.max_queue_depth,
                    slots_per_gpu: p.slots_per_gpu,
                    n_gpus: p.instances.len(),
                    n_unserved,
                    n_preempted: np,
                    preempt_stall_ms: st,
                    kv_peak_util: pk,
                    kv_mean_util: mn,
                }
            })
            .collect(),
        overall: metrics.overall,
        horizon_ms: horizon,
        n_requests: n,
        n_compressed,
        n_events,
        n_unserved,
        max_unserved_wait_ms: max_unserved_wait,
        n_attempts: metrics.n_attempts,
        n_abandoned: metrics.n_abandoned,
        n_shed: metrics.n_shed,
        windows: metrics.windows,
        n_preempted,
        preempt_stall_ms: preempt_stall,
        kv_peak_util: kv_peak,
        kv_mean_util: kv_mean,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::engine::Simulator;
    use crate::gpu::catalog::GpuCatalog;
    use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

    #[test]
    fn reference_agrees_with_production_engine() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
        let gpu = GpuCatalog::standard().get("A100").unwrap().clone();
        let pools = vec![
            SimPool { gpu: gpu.clone(), n_gpus: 3, ctx_budget: 4096.0,
                      batch_cap: None },
            SimPool { gpu, n_gpus: 3, ctx_budget: 8192.0, batch_cap: None },
        ];
        let router = RoutingPolicy::Length { b_short: 4096.0 };
        let cfg =
            DesConfig { n_requests: 3_000, seed: 17, ..Default::default() };
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        let input = SimInput::stream(&pools, &router, &cfg, &sampled);
        let mut a = run_reference_input(&input).unwrap();
        let mut b = Simulator::run_input(&input).unwrap();
        assert_eq!(a.overall.p99_ttft(), b.overall.p99_ttft());
        assert_eq!(a.overall.count, b.overall.count);
        assert_eq!(a.horizon_ms, b.horizon_ms);
        assert_eq!(a.n_events, b.n_events);
    }

    #[test]
    fn reference_agrees_with_production_engine_under_faults() {
        use crate::des::faults::{FaultScript, GpuFailure, Straggler};
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 110.0);
        let gpu = GpuCatalog::standard().get("A100").unwrap().clone();
        let pools = vec![
            SimPool { gpu: gpu.clone(), n_gpus: 3, ctx_budget: 4096.0,
                      batch_cap: None },
            SimPool { gpu, n_gpus: 3, ctx_budget: 8192.0, batch_cap: None },
        ];
        let router = RoutingPolicy::Length { b_short: 4096.0 };
        let cfg =
            DesConfig { n_requests: 3_000, seed: 23, ..Default::default() };
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        let script = FaultScript {
            failures: vec![GpuFailure {
                pool: 1,
                n_gpus: 2,
                start_ms: 4_000.0,
                recover_ms: 12_000.0,
                warm_ms: 2_000.0,
                warm_factor: 2.0,
            }],
            stragglers: vec![Straggler {
                pool: 0,
                n_gpus: 1,
                start_ms: 0.0,
                end_ms: 8_000.0,
                factor: 1.5,
            }],
        };
        let input = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_faults(&script);
        let mut a = run_reference_input(&input).unwrap();
        let mut b = Simulator::run_input(&input).unwrap();
        assert_eq!(a.overall.p99_ttft(), b.overall.p99_ttft());
        assert_eq!(a.overall.count, b.overall.count);
        assert_eq!(a.horizon_ms, b.horizon_ms);
        assert_eq!(a.n_events, b.n_events);
    }

    #[test]
    fn reference_agrees_with_production_engine_under_retries() {
        use crate::des::retry::{AdmissionSpec, RetryConfig, RetrySpec};
        // Saturate a small fleet so timeouts, retries, doomed
        // admissions, sheds, and the breaker all fire, then pin the
        // two serial engines against each other bit for bit.
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 200.0);
        let gpu = GpuCatalog::standard().get("A100").unwrap().clone();
        let pools = vec![
            SimPool { gpu: gpu.clone(), n_gpus: 1, ctx_budget: 4096.0,
                      batch_cap: None },
            SimPool { gpu, n_gpus: 1, ctx_budget: 8192.0, batch_cap: None },
        ];
        let router = RoutingPolicy::Length { b_short: 4096.0 };
        let cfg =
            DesConfig { n_requests: 3_000, seed: 31, ..Default::default() };
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        let rc = RetryConfig {
            retry: Some(RetrySpec {
                max_attempts: 3,
                timeout_ms: 2_000.0,
                backoff_base_ms: 250.0,
                backoff_cap_ms: 1_000.0,
            }),
            admission: Some(AdmissionSpec {
                max_queue_depth: 64,
                breaker_open_depth: 32,
                breaker_close_depth: 8,
            }),
        };
        let input = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_retries(&rc);
        let mut a = run_reference_input(&input).unwrap();
        let mut b = Simulator::run_input(&input).unwrap();
        assert_eq!(a.overall.p99_ttft(), b.overall.p99_ttft());
        assert_eq!(a.overall.wait.p99(), b.overall.wait.p99());
        assert_eq!(a.overall.count, b.overall.count);
        assert_eq!(a.horizon_ms, b.horizon_ms);
        assert_eq!(a.n_events, b.n_events);
        assert_eq!(a.n_attempts, b.n_attempts);
        assert_eq!(a.n_abandoned, b.n_abandoned);
        assert_eq!(a.n_shed, b.n_shed);
        // And the run actually exercised the closed loop.
        assert!(a.n_attempts > 3_000);
        assert!(a.n_abandoned + a.n_shed > 0);
    }

    #[test]
    fn reference_agrees_with_production_engine_under_memory() {
        use crate::des::memory::{MemoryConfig, MemorySpec, PolicyKind};
        // Tight KV capacity so admissions block, pressure events fire,
        // and victims are evicted and resumed — then pin the two serial
        // engines against each other bit for bit on every counter.
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 60.0);
        let gpu = GpuCatalog::standard().get("A100").unwrap().clone();
        let pools = vec![
            SimPool { gpu: gpu.clone(), n_gpus: 2, ctx_budget: 4096.0,
                      batch_cap: None },
            SimPool { gpu, n_gpus: 2, ctx_budget: 8192.0, batch_cap: None },
        ];
        let router = RoutingPolicy::Length { b_short: 4096.0 };
        let cfg =
            DesConfig { n_requests: 3_000, seed: 37, ..Default::default() };
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        for policy in [
            PolicyKind::None,
            PolicyKind::EvictRecompute,
            PolicyKind::EvictSwap,
        ] {
            let mc = MemoryConfig {
                spec: MemorySpec {
                    hbm_gb: None,
                    weights_gb: 71.0,
                    bytes_per_token: 1e6,
                },
                policy,
                swap_out_ms: 2.0,
                swap_in_ms: 4.0,
            };
            let input = SimInput::stream(&pools, &router, &cfg, &sampled)
                .with_memory(&mc);
            let a = run_reference_input(&input).unwrap();
            let b = Simulator::run_input(&input).unwrap();
            assert_eq!(a.overall.p99_ttft(), b.overall.p99_ttft(),
                       "{policy:?}");
            assert_eq!(a.overall.wait.p99(), b.overall.wait.p99());
            assert_eq!(a.overall.e2e.p99(), b.overall.e2e.p99());
            assert_eq!(a.overall.count, b.overall.count);
            assert_eq!(a.horizon_ms, b.horizon_ms, "{policy:?}");
            assert_eq!(a.n_events, b.n_events, "{policy:?}");
            assert_eq!(a.n_unserved, b.n_unserved);
            assert_eq!(a.n_preempted, b.n_preempted, "{policy:?}");
            assert_eq!(a.preempt_stall_ms, b.preempt_stall_ms);
            assert_eq!(a.kv_peak_util, b.kv_peak_util, "{policy:?}");
            assert_eq!(a.kv_mean_util, b.kv_mean_util, "{policy:?}");
            for (pa, pb) in a.per_pool.iter().zip(&b.per_pool) {
                assert_eq!(pa.n_preempted, pb.n_preempted);
                assert_eq!(pa.preempt_stall_ms, pb.preempt_stall_ms);
                assert_eq!(pa.kv_peak_util, pb.kv_peak_util);
                assert_eq!(pa.kv_mean_util, pb.kv_mean_util);
                assert_eq!(pa.stats.count, pb.stats.count);
            }
            // The eviction policies must actually thrash here.
            if matches!(policy, PolicyKind::EvictRecompute
                                | PolicyKind::EvictSwap)
            {
                assert!(a.n_preempted > 0, "{policy:?}: no preemptions");
            } else {
                assert_eq!(a.n_preempted, 0);
            }
        }
    }
}
