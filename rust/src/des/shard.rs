//! Generator-driven and sharded DES execution (the 10^8-request path).
//!
//! [`Simulator::run_stream`](crate::des::engine::Simulator::run_stream)
//! needs the whole request stream materialized up front — O(requests)
//! memory. This module runs the *same* simulation over a pull-based
//! [`RequestGenerator`] in fixed-size chunks, holding only the chunk
//! being consumed plus the in-flight request arena: O(in-flight) memory.
//!
//! # Sharding model
//!
//! Pools are coupled only through the router: a routing decision depends
//! on the request and the routing RNG stream, never on pool state (see
//! [`crate::router::RoutingPolicy::route`]). So the fleet partitions
//! cleanly by destination pool — shard `s` of `N` owns every pool with
//! `index % N == s`. Each shard replays the *entire* arrival sequence
//! and the *identical* routing RNG stream (class draw + route per
//! arrival, exactly as the serial engine consumes it), then simulates
//! only the arrivals routed to its own pools.
//!
//! # Determinism: why the merge is bit-identical
//!
//! * Per-pool state (utilization accounting, queue depths, admission
//!   order, per-pool latency samples) evolves through the same
//!   acquire/release/record sequence as the serial run restricted to
//!   that pool: events for one shard's pools are pushed in the same
//!   relative order as in the serial run (drains in pool-index order,
//!   completions at admission), so same-time ties resolve identically.
//! * Overall latency distributions merge as sample *multisets*
//!   (exact-mode vectors concatenate, streaming histogram bins add), so
//!   percentiles, counts, and attainment are bit-identical to the
//!   serial run; only sample-vector order (and thus the accumulation
//!   order behind floating-point means) differs.
//! * Shard results merge in shard-id order, the horizon is the max over
//!   shards (each shard's horizon covers every arrival plus its own
//!   completions), and `max_unserved_wait = horizon - min(unserved
//!   arrival)` — algebraically and bit-wise what the serial scan
//!   computes.
//!
//! The `shard_regression` suite pins sharded-vs-serial bit-identity in
//! both metrics modes, generalizing the `des_regression` pattern that
//! pins the production engine against the all-events-heap reference.
//!
//! # Constraints
//!
//! * `warmup_frac` must be 0 (the paper's measure-everything behavior):
//!   the time-based cutoff needs the last arrival, which a streaming
//!   run does not know up front.
//! * Exact metrics mode still stores every sample — bounded *total*
//!   memory requires [`MetricsMode::Streaming`]
//!   (`crate::des::metrics::MetricsMode`); the arena and chunk buffers
//!   are bounded in both modes.

use std::sync::mpsc;
use std::sync::Arc;

use crate::des::engine::{abandon_or_retry, drain_queue_closed,
                         start_attempt, try_admit, DesConfig, Req, SimPool};
use crate::des::event::{CalendarQueue, EventKind};
use crate::des::faults::CompiledFaults;
use crate::des::input::{ArrivalsSource, ConfigError, SimInput};
use crate::des::memory::{self, MemPoolRaw, MemState, MemoryConfig};
use crate::des::metrics::{DesResult, LatencyStats, MetricsCollector,
                          PoolResult, WindowedStats};
use crate::des::pool::DesPool;
use crate::des::retry::{ClosedLoopState, Phase, RetryConfig};
use crate::router::{RouteRequest, RoutingPolicy};
use crate::workload::generator::RequestGenerator;
use crate::workload::rng::Pcg64;
use crate::workload::spec::{SampledRequest, WorkloadSpec};
use crate::workload::streams;

/// Default consumer-side chunk size (requests per generator pull). A
/// free tuning knob: chunking never changes results, only the
/// generation/simulation interleave and producer-consumer batching.
pub const DEFAULT_CHUNK_SIZE: usize = 65_536;

/// Execution counters for the streaming/sharded paths (memory evidence
/// for the bounded-memory claim, surfaced by the perf harness).
#[derive(Debug, Clone, Copy)]
pub struct StreamStats {
    /// Summed high-water mark of the per-shard request arenas: an upper
    /// bound on simultaneously-resident `Req` slots across the fleet.
    /// Stays O(in-flight) — flat in the total request count.
    pub arena_peak_slots: usize,
    /// Generator chunks produced.
    pub n_chunks: usize,
}

/// In-flight request arena with slot recycling. A slot is held from
/// arrival until *admission* (completion events carry pool/instance and
/// never read the request back), so the live set is queued requests
/// only — the quantity that is O(in-flight) even at 10^8 requests.
struct Arena {
    slots: Vec<Req>,
    free: Vec<u32>,
}

impl Arena {
    fn new() -> Self {
        Arena { slots: Vec::new(), free: Vec::new() }
    }

    fn alloc(&mut self, req: Req) -> u32 {
        match self.free.pop() {
            Some(i) => {
                self.slots[i as usize] = req;
                i
            }
            None => {
                let i = self.slots.len() as u32;
                self.slots.push(req);
                i
            }
        }
    }

    fn release(&mut self, id: u32) {
        self.free.push(id);
    }

    /// High-water mark of allocated slots.
    fn peak(&self) -> usize {
        self.slots.len()
    }
}

/// One shard's simulation state. With `n_shards == 1` this is the
/// whole-fleet generator-driven engine.
struct ShardSim<'a> {
    shard_id: usize,
    n_shards: usize,
    router: &'a RoutingPolicy,
    config: &'a DesConfig,
    faults: Option<&'a CompiledFaults>,
    pools: Vec<DesPool>,
    events: CalendarQueue,
    route_rng: Pcg64,
    metrics: MetricsCollector,
    arena: Arena,
    n_events: usize,
    n_compressed: usize,
    horizon: f64,
    /// Closed-loop state, indexed by *arena slot*; present iff a retry
    /// config is attached. In closed-loop mode arena slots are held
    /// until the request is terminal (served/abandoned/shed), because
    /// timeout and retry events read the request back.
    closed: Option<ClosedLoopState>,
    /// Stream-global arrival counter: every shard sees every arrival,
    /// so this is the serial engines' stream index — the id backoff
    /// jitter is keyed on, making retry schedules shard-invariant.
    global_arrivals: u64,
    /// KV-memory state ([`crate::des::memory`]); present iff a memory
    /// config is attached. In memory mode arena slots are held until the
    /// request's *final* completion commits (eviction requeues the slot
    /// id, so recycling it early would alias two live requests).
    mem: Option<MemState>,
}

/// What a shard hands to the merge step.
struct ShardOutput {
    pools: Vec<DesPool>,
    per_pool_stats: Vec<LatencyStats>,
    overall: LatencyStats,
    windows: Option<WindowedStats>,
    n_events: usize,
    n_compressed: usize,
    horizon: f64,
    per_pool_unserved: Vec<usize>,
    min_unserved_arrival: f64,
    arena_peak: usize,
    n_attempts: usize,
    n_abandoned: usize,
    n_shed: usize,
    /// Per-pool KV ledger raws (empty when no memory config is
    /// attached). Only this shard's owned pools carry activity; the
    /// merge picks pool `p` from shard `p % n_shards`.
    mem_raw: Vec<MemPoolRaw>,
}

impl<'a> ShardSim<'a> {
    fn new(
        pool_specs: &[SimPool],
        router: &'a RoutingPolicy,
        config: &'a DesConfig,
        faults: Option<&'a CompiledFaults>,
        retries: Option<&'a RetryConfig>,
        mem_cfg: Option<&'a MemoryConfig>,
        shard_id: usize,
        n_shards: usize,
    ) -> Self {
        debug_assert!(shard_id < n_shards);
        let pools: Vec<DesPool> = pool_specs
            .iter()
            .map(|p| {
                DesPool::new(p.gpu.clone(), p.n_gpus, p.ctx_budget,
                             p.batch_cap)
            })
            .collect();
        let mut events = CalendarQueue::with_capacity(64);
        if let Some(w) = &config.cap_window {
            // Owned pools only, in pool-index order — the serial engine
            // pushes all-pool drains in pool-index order, so the
            // restriction to this shard's pools keeps the same relative
            // (and hence tie-breaking) order.
            for p in 0..pools.len() {
                if p % n_shards == shard_id {
                    events.push(w.end_ms, EventKind::Drain { pool: p as u16 });
                }
            }
        }
        // Fault-recovery drains for owned pools, after cap drains and in
        // script order — the same relative order as the serial engine's
        // all-pool push, so same-time ties resolve identically for any
        // shard count.
        if let Some(f) = faults {
            for &(t, pool) in f.drains() {
                if pool as usize % n_shards == shard_id {
                    events.push(t, EventKind::Drain { pool });
                }
            }
        }
        // Exact-mode pre-size hint: this shard's expected share, capped
        // so a 10^8-request config never pre-allocates gigabytes.
        let hint = (config.n_requests / n_shards).min(1 << 20);
        let metrics = MetricsCollector::new(
            config.metrics, pools.len(), hint, config.window_ms, 0.0,
        );
        let n_pools = pools.len();
        let mem = mem_cfg.map(|m| MemState::new(m, &pools));
        ShardSim {
            shard_id,
            n_shards,
            router,
            config,
            faults,
            pools,
            events,
            route_rng: Pcg64::new(config.seed, streams::ROUTING),
            metrics,
            arena: Arena::new(),
            n_events: 0,
            n_compressed: 0,
            horizon: 0.0,
            closed: retries
                .map(|c| ClosedLoopState::new(c, config.seed, n_pools)),
            global_arrivals: 0,
            mem,
        }
    }

    /// Process one arrival from the global stream. Every shard sees
    /// every arrival (to replay the routing RNG and track the horizon);
    /// only the owner of the routed pool simulates it.
    fn feed(&mut self, r: &SampledRequest) {
        // Arrivals win ties, exactly as in the serial merge loop
        // (`arrival_ms <= next event time` takes the arrival).
        while let Some(t) = self.events.next_time() {
            if t < r.arrival_ms {
                self.step_event();
            } else {
                break;
            }
        }
        let now = r.arrival_ms;
        self.horizon = self.horizon.max(now);
        let class = match &self.config.class_probs {
            None => 0,
            Some(probs) => {
                let u = self.route_rng.uniform();
                let mut cum = 0.0;
                let mut cls = probs.len() - 1;
                for (i, p) in probs.iter().enumerate() {
                    cum += p;
                    if u < cum {
                        cls = i;
                        break;
                    }
                }
                cls
            }
        };
        let decision = self.router.route(
            RouteRequest { l_in: r.l_in, l_out: r.l_out, class },
            &mut self.route_rng,
        );
        // Stream-global id of this arrival: counted on every shard
        // (serial engines use the stream index; see `global_arrivals`).
        let gid = self.global_arrivals;
        self.global_arrivals += 1;
        if decision.pool % self.n_shards != self.shard_id {
            return;
        }
        self.n_events += 1;
        self.metrics.record_arrival(now);
        if decision.compressed {
            self.n_compressed += 1;
        }
        let id = self.arena.alloc(Req {
            arrival_ms: now,
            l_in: decision.request.l_in,
            l_out: decision.request.l_out,
        });
        if let Some(cl) = self.closed.as_mut() {
            cl.init_request(id as usize, gid, now);
            cl.states[id as usize].pool = decision.pool as u16;
            start_attempt(
                &mut self.pools, id, &self.arena.slots, now,
                &mut self.events, &self.config.cap_window, self.faults,
                &mut self.metrics, cl,
            );
            // Immediate shed is the only terminal outcome of a fresh
            // attempt — recycle the slot right away.
            if cl.states[id as usize].phase == Phase::Done {
                self.arena.release(id);
            }
            return;
        }
        if let Some(ms) = self.mem.as_mut() {
            // The slot stays allocated until the final completion
            // commits — eviction keeps the id live in the pool queue.
            ms.init_request(id, decision.request.l_in,
                            decision.request.l_out, now);
            if !ms.try_admit(
                &mut self.pools, decision.pool, id, now,
                &mut self.events, &self.config.cap_window, self.faults,
            ) {
                self.pools[decision.pool].enqueue(id);
            }
            return;
        }
        let admitted = try_admit(
            &mut self.pools, decision.pool, id, &self.arena.slots, now,
            &mut self.events, &self.config.cap_window, self.faults,
            &mut self.metrics,
        );
        if admitted {
            self.arena.release(id);
        } else {
            self.pools[decision.pool].enqueue(id);
        }
    }

    fn step_event(&mut self) {
        let Some(ev) = self.events.pop() else { return };
        self.n_events += 1;
        let now = ev.time_ms;
        self.horizon = self.horizon.max(now);
        match ev.kind {
            EventKind::Arrival { .. } => {
                unreachable!("arrivals come from the generator stream")
            }
            EventKind::Completion { req, pool, instance } => {
                self.pools[pool as usize].release(instance as usize, now);
                if let Some(cl) = self.closed.as_mut() {
                    cl.states[req as usize].phase = Phase::Done;
                    self.arena.release(req);
                    drain_queue_closed(
                        &mut self.pools, pool as usize, &self.arena.slots,
                        now, &mut self.events, &self.config.cap_window,
                        self.faults, &mut self.metrics, cl,
                    );
                } else {
                    self.drain_pool(pool as usize, now);
                }
            }
            EventKind::Drain { pool } => {
                if let Some(cl) = self.closed.as_mut() {
                    drain_queue_closed(
                        &mut self.pools, pool as usize, &self.arena.slots,
                        now, &mut self.events, &self.config.cap_window,
                        self.faults, &mut self.metrics, cl,
                    );
                } else if let Some(ms) = self.mem.as_mut() {
                    ms.drain(
                        &mut self.pools, pool as usize, now,
                        &mut self.events, &self.config.cap_window,
                        self.faults,
                    );
                } else {
                    self.drain_pool(pool as usize, now);
                }
            }
            EventKind::MemCompletion { req, pool, instance, gen } => {
                let ms = self
                    .mem
                    .as_mut()
                    .expect("memory events exist only in memory mode");
                if ms.on_completion(
                    &mut self.pools, pool as usize, instance as usize,
                    req, gen, now, &mut self.events,
                    &self.config.cap_window, self.faults,
                    &mut self.metrics,
                ) {
                    self.arena.release(req);
                }
            }
            EventKind::MemPressure { pool, instance, epoch } => {
                let ms = self
                    .mem
                    .as_mut()
                    .expect("memory events exist only in memory mode");
                ms.on_pressure(
                    &mut self.pools, pool as usize, instance as usize,
                    epoch, now, &mut self.events,
                    &self.config.cap_window, self.faults,
                    &mut self.metrics,
                );
            }
            EventKind::Timeout { req, pool, attempt } => {
                let cl = self
                    .closed
                    .as_mut()
                    .expect("timeouts exist only in closed-loop runs");
                let st = cl.states[req as usize];
                if st.attempt != attempt {
                    return; // superseded by a later attempt
                }
                match st.phase {
                    Phase::Queued => {
                        let q = &mut self.pools[pool as usize].queue;
                        if let Some(pos) = q.iter().position(|&r| r == req)
                        {
                            q.remove(pos);
                        }
                        let len = self.pools[pool as usize].queue.len();
                        cl.note_queue_len(pool as usize, len);
                        abandon_or_retry(
                            req, now, &mut self.events, &mut self.metrics,
                            cl,
                        );
                        if cl.states[req as usize].phase == Phase::Done {
                            self.arena.release(req);
                        }
                    }
                    Phase::Doomed => {
                        self.pools[pool as usize]
                            .release(st.instance as usize, now);
                        abandon_or_retry(
                            req, now, &mut self.events, &mut self.metrics,
                            cl,
                        );
                        if cl.states[req as usize].phase == Phase::Done {
                            self.arena.release(req);
                        }
                        drain_queue_closed(
                            &mut self.pools, pool as usize,
                            &self.arena.slots, now, &mut self.events,
                            &self.config.cap_window, self.faults,
                            &mut self.metrics, cl,
                        );
                    }
                    _ => {}
                }
            }
            EventKind::Retry { req, pool: _ } => {
                let cl = self
                    .closed
                    .as_mut()
                    .expect("retries exist only in closed-loop runs");
                cl.states[req as usize].attempt += 1;
                start_attempt(
                    &mut self.pools, req, &self.arena.slots, now,
                    &mut self.events, &self.config.cap_window, self.faults,
                    &mut self.metrics, cl,
                );
                if cl.states[req as usize].phase == Phase::Done {
                    self.arena.release(req);
                }
            }
        }
    }

    /// Admit queued requests while capacity allows, recycling arena
    /// slots at admission (the only divergence from the serial
    /// `drain_queue`, which keeps its whole-stream arena).
    fn drain_pool(&mut self, pool_idx: usize, now: f64) {
        while let Some(&head) = self.pools[pool_idx].queue.front() {
            let admitted = try_admit(
                &mut self.pools, pool_idx, head, &self.arena.slots, now,
                &mut self.events, &self.config.cap_window, self.faults,
                &mut self.metrics,
            );
            if !admitted {
                break;
            }
            self.pools[pool_idx].queue.pop_front();
            self.arena.release(head);
        }
    }

    /// Drain remaining events and scan for unserved requests (requests
    /// still queued when the stream drained keep their arena slots, so
    /// the anti-censoring scan works exactly as in the serial engine).
    fn finish(mut self) -> ShardOutput {
        while !self.events.is_empty() {
            self.step_event();
        }
        let mut per_pool_unserved = vec![0usize; self.pools.len()];
        let mut min_unserved_arrival = f64::INFINITY;
        for (p, pool) in self.pools.iter().enumerate() {
            for &req in &pool.queue {
                let arrival = self.arena.slots[req as usize].arrival_ms;
                if !self.metrics.measured(arrival) {
                    continue;
                }
                per_pool_unserved[p] += 1;
                min_unserved_arrival = min_unserved_arrival.min(arrival);
            }
        }
        ShardOutput {
            pools: self.pools,
            per_pool_stats: self.metrics.per_pool,
            overall: self.metrics.overall,
            windows: self.metrics.windows,
            n_events: self.n_events,
            n_compressed: self.n_compressed,
            horizon: self.horizon,
            per_pool_unserved,
            min_unserved_arrival,
            arena_peak: self.arena.peak(),
            n_attempts: self.metrics.n_attempts,
            n_abandoned: self.metrics.n_abandoned,
            n_shed: self.metrics.n_shed,
            mem_raw: self
                .mem
                .as_ref()
                .map(|m| m.raws())
                .unwrap_or_default(),
        }
    }
}

/// Deterministic shard merge (shard-id order). See the module docs for
/// the bit-identity argument.
fn merge_outputs(
    mut outputs: Vec<ShardOutput>,
    n_requests: usize,
) -> (DesResult, usize) {
    let n_shards = outputs.len();
    let n_pools = outputs[0].pools.len();
    let horizon = outputs.iter().map(|o| o.horizon).fold(0.0f64, f64::max);
    let n_events: usize = outputs.iter().map(|o| o.n_events).sum();
    let n_compressed: usize =
        outputs.iter().map(|o| o.n_compressed).sum();
    let n_unserved: usize = outputs
        .iter()
        .map(|o| o.per_pool_unserved.iter().sum::<usize>())
        .sum();
    let arena_peak: usize = outputs.iter().map(|o| o.arena_peak).sum();
    let n_attempts: usize = outputs.iter().map(|o| o.n_attempts).sum();
    let n_abandoned: usize = outputs.iter().map(|o| o.n_abandoned).sum();
    let n_shed: usize = outputs.iter().map(|o| o.n_shed).sum();
    // max over unserved of (horizon - arrival) == horizon - min(arrival):
    // f64 subtraction with a fixed minuend is monotone, so this is the
    // serial scan's result bit-for-bit.
    let max_unserved_wait = if n_unserved > 0 {
        let min_arr = outputs
            .iter()
            .map(|o| o.min_unserved_arrival)
            .fold(f64::INFINITY, f64::min);
        horizon - min_arr
    } else {
        0.0
    };
    // Reassemble the KV ledger raws in pool order from each pool's
    // owner shard, then aggregate with the *same* free functions (and
    // hence the same f64 operation order) as the serial engines.
    let mem_raw: Option<Vec<MemPoolRaw>> =
        if outputs[0].mem_raw.is_empty() {
            None
        } else {
            Some(
                (0..n_pools)
                    .map(|p| outputs[p % n_shards].mem_raw[p].clone())
                    .collect(),
            )
        };
    let (kv_peak, kv_mean, n_preempted, preempt_stall) = match &mem_raw {
        Some(raws) => memory::overall_from_raw(raws, horizon),
        None => (0.0, 0.0, 0, 0.0),
    };
    // Each pool's state lives wholly in its owner shard; utilization is
    // evaluated against the *global* horizon, as in the serial run.
    let per_pool: Vec<PoolResult> = (0..n_pools)
        .map(|p| {
            let (pk, mn, np, st) = match &mem_raw {
                Some(raws) => {
                    let (pk, mn) =
                        memory::pool_util_from_raw(&raws[p], horizon);
                    (pk, mn, raws[p].n_preempted, raws[p].stall_ms)
                }
                None => (0.0, 0.0, 0, 0.0),
            };
            let o = &mut outputs[p % n_shards];
            let stats = std::mem::take(&mut o.per_pool_stats[p]);
            let pool = &o.pools[p];
            PoolResult {
                stats,
                utilization: pool.utilization(horizon),
                max_queue_depth: pool.max_queue_depth,
                slots_per_gpu: pool.slots_per_gpu,
                n_gpus: pool.instances.len(),
                n_unserved: o.per_pool_unserved[p],
                n_preempted: np,
                preempt_stall_ms: st,
                kv_peak_util: pk,
                kv_mean_util: mn,
            }
        })
        .collect();
    let mut outputs = outputs.into_iter();
    let first = outputs.next().expect("at least one shard");
    let mut overall = first.overall;
    let mut windows = first.windows;
    for o in outputs {
        overall.merge(&o.overall);
        if let (Some(acc), Some(w)) = (&mut windows, &o.windows) {
            acc.merge(w);
        }
    }
    let result = DesResult {
        per_pool,
        overall,
        horizon_ms: horizon,
        n_requests,
        n_compressed,
        n_events,
        n_unserved,
        max_unserved_wait_ms: max_unserved_wait,
        n_attempts,
        n_abandoned,
        n_shed,
        windows,
        n_preempted,
        preempt_stall_ms: preempt_stall,
        kv_peak_util: kv_peak,
        kv_mean_util: kv_mean,
    };
    (result, arena_peak)
}

/// Generator-driven, single-threaded run: bit-identical to
/// [`Simulator::run_stream`](crate::des::engine::Simulator::run_stream)
/// on the materialized stream, in O(in-flight) memory.
#[deprecated(note = "build a SimInput and call run_streamed_input")]
pub fn run_streamed(
    pool_specs: &[SimPool],
    router: &RoutingPolicy,
    config: &DesConfig,
    workload: &WorkloadSpec,
    chunk_size: usize,
) -> (DesResult, StreamStats) {
    let input = SimInput::generated(pool_specs, router, config, workload);
    match run_streamed_input(&input, chunk_size) {
        Ok(r) => r,
        Err(e) => panic!("{e}"),
    }
}

/// Generator-driven, sharded run: one thread per shard, pools
/// partitioned by `index % n_shards`, results merged deterministically.
/// Bit-identical to the serial engine for any shard count (pinned by
/// the `shard_regression` suite); see the module docs.
#[deprecated(note = "build a SimInput and call run_sharded_input")]
pub fn run_sharded(
    pool_specs: &[SimPool],
    router: &RoutingPolicy,
    config: &DesConfig,
    workload: &WorkloadSpec,
    n_shards: usize,
    chunk_size: usize,
) -> (DesResult, StreamStats) {
    let input = SimInput::generated(pool_specs, router, config, workload);
    match run_sharded_input(&input, n_shards, chunk_size) {
        Ok(r) => r,
        Err(e) => panic!("{e}"),
    }
}

/// Single-threaded streaming run over a validated [`SimInput`], in
/// O(in-flight) memory. A `Stream` arrivals source is consumed in
/// place (`config.n_requests` is ignored — the slice is the stream); a
/// `Generator` source pulls `config.n_requests` arrivals chunk by
/// chunk. Bit-identical to
/// [`Simulator::run_input`](crate::des::engine::Simulator::run_input)
/// on the same arrivals, faulted or not.
pub fn run_streamed_input(
    input: &SimInput<'_>,
    chunk_size: usize,
) -> Result<(DesResult, StreamStats), ConfigError> {
    input.validate_streaming()?;
    let compiled = input.compiled_faults();
    let chunk_size = chunk_size.max(1);
    let mut n_chunks = 0usize;
    let n;
    let mut sim = ShardSim::new(
        input.pools, input.router, input.config, compiled.as_ref(),
        input.retries, input.memory, 0, 1,
    );
    match input.arrivals {
        ArrivalsSource::Stream(sampled) => {
            // Already materialized: no generator chunks to count.
            n = sampled.len();
            for r in sampled {
                sim.feed(r);
            }
        }
        ArrivalsSource::Generator(w) => {
            n = input.config.n_requests;
            let mut gen = RequestGenerator::new(w, input.config.seed);
            let mut chunk = Vec::with_capacity(chunk_size.min(n.max(1)));
            let mut produced = 0usize;
            while produced < n {
                let take = chunk_size.min(n - produced);
                chunk.clear();
                gen.fill(&mut chunk, take);
                produced += take;
                n_chunks += 1;
                for r in &chunk {
                    sim.feed(r);
                }
            }
        }
    }
    let (result, arena_peak) = merge_outputs(vec![sim.finish()], n);
    Ok((result, StreamStats { arena_peak_slots: arena_peak, n_chunks }))
}

/// Sharded run over a validated [`SimInput`]: one thread per shard,
/// pools partitioned by `index % n_shards`, results merged
/// deterministically — bit-identical to the serial engine for any
/// shard count, with or without a fault script (pinned by the
/// `shard_regression` suite).
///
/// A `Generator` source is produced once on the calling thread and
/// Arc-broadcast in bounded chunks; a `Stream` source is already
/// resident, so every shard just iterates the borrowed slice.
///
/// `n_shards` is clamped to the pool count — a shard owning no pools
/// would only burn a core replaying the routing stream.
pub fn run_sharded_input(
    input: &SimInput<'_>,
    n_shards: usize,
    chunk_size: usize,
) -> Result<(DesResult, StreamStats), ConfigError> {
    input.validate_streaming()?;
    let n_shards = n_shards.clamp(1, input.pools.len().max(1));
    if n_shards == 1 {
        return run_streamed_input(input, chunk_size);
    }
    let compiled = input.compiled_faults();
    let faults = compiled.as_ref();
    let retries = input.retries;
    let mem_cfg = input.memory;
    let chunk_size = chunk_size.max(1);
    let (pool_specs, router, config) =
        (input.pools, input.router, input.config);
    if let ArrivalsSource::Stream(sampled) = input.arrivals {
        // The stream is already materialized and shared — no producer
        // thread, no channels; every shard walks the same slice.
        let outputs: Vec<ShardOutput> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n_shards)
                .map(|sid| {
                    s.spawn(move || {
                        let mut sim = ShardSim::new(
                            pool_specs, router, config, faults, retries,
                            mem_cfg, sid, n_shards,
                        );
                        for r in sampled {
                            sim.feed(r);
                        }
                        sim.finish()
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard thread panicked"))
                .collect()
        });
        let (result, arena_peak) = merge_outputs(outputs, sampled.len());
        return Ok((
            result,
            StreamStats { arena_peak_slots: arena_peak, n_chunks: 0 },
        ));
    }
    let ArrivalsSource::Generator(workload) = input.arrivals else {
        unreachable!("stream sources handled above")
    };
    let n = config.n_requests;
    let mut senders = Vec::with_capacity(n_shards);
    let mut receivers = Vec::with_capacity(n_shards);
    for _ in 0..n_shards {
        // Bounded fan-out: the producer stays at most 2 chunks ahead of
        // the slowest shard, so resident chunk memory is O(shards).
        let (tx, rx) = mpsc::sync_channel::<Arc<Vec<SampledRequest>>>(2);
        senders.push(tx);
        receivers.push(rx);
    }
    let (outputs, n_chunks) = std::thread::scope(|s| {
        let handles: Vec<_> = receivers
            .into_iter()
            .enumerate()
            .map(|(sid, rx)| {
                s.spawn(move || {
                    let mut sim = ShardSim::new(
                        pool_specs, router, config, faults, retries,
                        mem_cfg, sid, n_shards,
                    );
                    while let Ok(chunk) = rx.recv() {
                        for r in chunk.iter() {
                            sim.feed(r);
                        }
                    }
                    sim.finish()
                })
            })
            .collect();
        // This thread is the producer: generate once, broadcast the Arc.
        let mut gen = RequestGenerator::new(workload, config.seed);
        let mut produced = 0usize;
        let mut n_chunks = 0usize;
        while produced < n {
            let take = chunk_size.min(n - produced);
            let mut chunk = Vec::with_capacity(take);
            gen.fill(&mut chunk, take);
            produced += take;
            n_chunks += 1;
            let chunk = Arc::new(chunk);
            for tx in &senders {
                tx.send(Arc::clone(&chunk)).expect("shard thread died");
            }
        }
        drop(senders);
        let outs: Vec<ShardOutput> = handles
            .into_iter()
            .map(|h| h.join().expect("shard thread panicked"))
            .collect();
        (outs, n_chunks)
    });
    let (result, arena_peak) = merge_outputs(outputs, n);
    Ok((result, StreamStats { arena_peak_slots: arena_peak, n_chunks }))
}

#[cfg(test)]
// The smoke test deliberately exercises the deprecated wrappers — they
// are public API until the next major bump and must keep matching the
// SimInput-based entry points bit-for-bit.
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::des::engine::Simulator;
    use crate::des::faults::{FaultScript, GpuFailure, Straggler};
    use crate::des::metrics::MetricsMode;
    use crate::gpu::catalog::GpuCatalog;
    use crate::gpu::profile::GpuProfile;
    use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

    fn a100() -> GpuProfile {
        GpuCatalog::standard().get("A100").unwrap().clone()
    }

    fn setup() -> (WorkloadSpec, Vec<SimPool>, RoutingPolicy) {
        let pools = vec![
            SimPool { gpu: a100(), n_gpus: 4, ctx_budget: 4096.0,
                      batch_cap: None },
            SimPool { gpu: a100(), n_gpus: 4, ctx_budget: 8192.0,
                      batch_cap: None },
        ];
        (
            WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0),
            pools,
            RoutingPolicy::Length { b_short: 4096.0 },
        )
    }

    fn summary(r: &mut DesResult) -> Vec<f64> {
        let mut v = vec![
            r.overall.wait.p99(),
            r.overall.ttft.p99(),
            r.overall.e2e.p99(),
            r.overall.count as f64,
            r.horizon_ms,
            r.n_events as f64,
            r.n_unserved as f64,
            r.max_unserved_wait_ms,
            r.n_attempts as f64,
            r.n_abandoned as f64,
            r.n_shed as f64,
        ];
        for p in &mut r.per_pool {
            v.push(p.stats.ttft.p99());
            v.push(p.stats.count as f64);
            v.push(p.utilization);
            v.push(p.max_queue_depth as f64);
        }
        v
    }

    #[test]
    fn arena_recycles_slots_and_tracks_peak() {
        // Pure-data-structure test: this is the miri target for the
        // arena (the sim-driving tests below are skipped under miri).
        let req = |t: f64| Req { arrival_ms: t, l_in: 1.0, l_out: 1.0 };
        let mut a = Arena::new();
        let i0 = a.alloc(req(0.0));
        let i1 = a.alloc(req(1.0));
        assert_eq!((i0, i1), (0, 1));
        assert_eq!(a.peak(), 2);
        // Freed slots are reused LIFO before the arena grows.
        a.release(i0);
        let i2 = a.alloc(req(2.0));
        assert_eq!(i2, i0);
        assert_eq!(a.peak(), 2);
        assert_eq!(a.slots[i2 as usize].arrival_ms, 2.0);
        // Releasing everything caps the peak at the high-water mark.
        a.release(i1);
        a.release(i2);
        let i3 = a.alloc(req(3.0));
        let i4 = a.alloc(req(4.0));
        assert_eq!(a.peak(), 2, "alloc after release must not grow");
        let i5 = a.alloc(req(5.0));
        assert_eq!(a.peak(), 3);
        assert_eq!((i3.min(i4), i3.max(i4), i5), (0, 1, 2));
    }

    #[test]
    #[cfg_attr(miri, ignore = "drives full simulations; too slow")]
    fn streamed_and_sharded_match_serial_smoke() {
        let (w, pools, router) = setup();
        for mode in [MetricsMode::Exact, MetricsMode::Streaming] {
            let cfg = DesConfig {
                n_requests: 6_000,
                seed: 11,
                metrics: mode,
                ..Default::default()
            };
            let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
            let mut serial =
                Simulator::run_stream(&pools, &router, &cfg, &sampled);
            let want = summary(&mut serial);
            for shards in [1usize, 2] {
                for chunk in [777usize, DEFAULT_CHUNK_SIZE] {
                    let (mut got, stats) = run_sharded(
                        &pools, &router, &cfg, &w, shards, chunk,
                    );
                    assert_eq!(summary(&mut got), want,
                               "{mode:?} shards={shards} chunk={chunk}");
                    assert!(stats.arena_peak_slots <= cfg.n_requests);
                }
            }
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "drives full simulations; too slow")]
    fn arena_stays_small_on_a_stable_fleet() {
        let (w, pools, router) = setup();
        let cfg = DesConfig {
            n_requests: 30_000,
            metrics: MetricsMode::Streaming,
            ..Default::default()
        };
        let (_, stats) = run_streamed(&pools, &router, &cfg, &w, 2_048);
        // A stable fleet keeps the in-flight set tiny relative to the
        // stream: the arena must not scale with n_requests.
        assert!(stats.arena_peak_slots < 2_000,
                "arena peak = {}", stats.arena_peak_slots);
        assert_eq!(stats.n_chunks, 15);
    }

    #[test]
    fn warmup_is_rejected_in_streaming_mode() {
        let (w, pools, router) = setup();
        let cfg = DesConfig {
            n_requests: 100,
            warmup_frac: 0.1,
            ..Default::default()
        };
        let input = SimInput::generated(&pools, &router, &cfg, &w);
        let err =
            run_streamed_input(&input, 64).map(|_| ()).unwrap_err();
        assert!(matches!(
            err,
            ConfigError::WarmupUnsupported { warmup_frac } if
                warmup_frac == 0.1
        ));
        // The deprecated wrapper panics with this Display; it must keep
        // the historical "warmup_frac = 0" substring.
        assert!(err.to_string().contains("warmup_frac = 0"));
    }

    #[test]
    #[cfg_attr(miri, ignore = "drives full simulations; too slow")]
    fn stream_source_matches_generator_source_for_any_shard_count() {
        let (w, pools, router) = setup();
        let cfg = DesConfig {
            n_requests: 6_000,
            seed: 29,
            ..Default::default()
        };
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        let gen_input = SimInput::generated(&pools, &router, &cfg, &w);
        let str_input = SimInput::stream(&pools, &router, &cfg, &sampled);
        for shards in [1usize, 2] {
            let (mut a, _) =
                run_sharded_input(&gen_input, shards, 1_024).unwrap();
            let (mut b, _) =
                run_sharded_input(&str_input, shards, 1_024).unwrap();
            assert_eq!(summary(&mut a), summary(&mut b),
                       "shards={shards}");
        }
    }

    #[test]
    #[cfg_attr(miri, ignore = "drives full simulations; too slow")]
    fn faulted_runs_stay_bit_identical_across_shard_counts() {
        let (w, pools, router) = setup();
        let cfg = DesConfig {
            n_requests: 6_000,
            seed: 31,
            ..Default::default()
        };
        let script = FaultScript {
            failures: vec![GpuFailure {
                pool: 1,
                n_gpus: 3,
                start_ms: 5_000.0,
                recover_ms: 20_000.0,
                warm_ms: 3_000.0,
                warm_factor: 2.5,
            }],
            stragglers: vec![Straggler {
                pool: 0,
                n_gpus: 2,
                start_ms: 10_000.0,
                end_ms: 30_000.0,
                factor: 1.7,
            }],
        };
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        let serial_in = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_faults(&script);
        let mut serial = Simulator::run_input(&serial_in).unwrap();
        let want = summary(&mut serial);
        let gen_in = SimInput::generated(&pools, &router, &cfg, &w)
            .with_faults(&script);
        for shards in [1usize, 2] {
            let (mut got, _) =
                run_sharded_input(&gen_in, shards, 777).unwrap();
            assert_eq!(summary(&mut got), want, "shards={shards}");
        }
        // And the fault script actually bit: the unfaulted run differs.
        let plain_in = SimInput::stream(&pools, &router, &cfg, &sampled);
        let mut plain = Simulator::run_input(&plain_in).unwrap();
        assert_ne!(summary(&mut plain), want);
    }

    #[test]
    #[cfg_attr(miri, ignore = "drives full simulations; too slow")]
    fn retry_runs_stay_bit_identical_across_shard_counts() {
        use crate::des::retry::{AdmissionSpec, RetryConfig, RetrySpec};
        // Saturating load so timeouts, retries, doomed admissions, and
        // the breaker all fire in both pools.
        let pools = vec![
            SimPool { gpu: a100(), n_gpus: 1, ctx_budget: 4096.0,
                      batch_cap: None },
            SimPool { gpu: a100(), n_gpus: 1, ctx_budget: 8192.0,
                      batch_cap: None },
        ];
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 200.0);
        let router = RoutingPolicy::Length { b_short: 4096.0 };
        let rc = RetryConfig {
            retry: Some(RetrySpec {
                max_attempts: 3,
                timeout_ms: 2_000.0,
                backoff_base_ms: 250.0,
                backoff_cap_ms: 1_000.0,
            }),
            admission: Some(AdmissionSpec {
                max_queue_depth: 64,
                breaker_open_depth: 32,
                breaker_close_depth: 8,
            }),
        };
        for mode in [MetricsMode::Exact, MetricsMode::Streaming] {
            let cfg = DesConfig {
                n_requests: 4_000,
                seed: 37,
                metrics: mode,
                ..Default::default()
            };
            let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
            let serial_in = SimInput::stream(&pools, &router, &cfg,
                                             &sampled)
                .with_retries(&rc);
            let mut serial = Simulator::run_input(&serial_in).unwrap();
            let want = summary(&mut serial);
            assert!(serial.n_attempts > 4_000, "retries must fire");
            assert!(serial.n_abandoned + serial.n_shed > 0);
            let gen_in = SimInput::generated(&pools, &router, &cfg, &w)
                .with_retries(&rc);
            for shards in [1usize, 2] {
                for chunk in [777usize, DEFAULT_CHUNK_SIZE] {
                    let (mut got, _) =
                        run_sharded_input(&gen_in, shards, chunk).unwrap();
                    assert_eq!(summary(&mut got), want,
                               "{mode:?} shards={shards} chunk={chunk}");
                }
            }
        }
    }
}
