//! The DES engine (paper §3.1 Phase 2).
//!
//! Semantics (DESIGN.md "DES semantics"):
//! * requests arrive on a Poisson stream and are routed on arrival;
//! * each pool is a FIFO queue in front of `n` GPU instances;
//! * a request holds one KV slot on one instance for
//!   `iters(L_in, L_out) * t_iter(n_eff)` ms, where `n_eff` is the
//!   instance's effective slot capacity (KV-limited, possibly batch-capped);
//! * TTFT = slot wait + chunked prefill + one iteration (paper Eq. 5,
//!   measured rather than approximated);
//! * exactly two events per request, so 10^4 requests simulate in
//!   milliseconds;
//! * requests still queued when the event stream drains (a dead pool:
//!   live pools always drain) are counted as unserved — never silently
//!   censored out of the SLO numbers (see
//!   [`crate::des::metrics::DesResult::n_unserved`]).
//!
//! Hot-path structure (perf pass iteration 4, this PR's tentpole):
//! requests live in an index-based arena (`Vec<Req>`, ids flow through
//! the router, the pool FIFOs, and event payloads); arrivals are
//! merge-consumed from the time-sorted input slice; completions and
//! cap-window drains are scheduled on a [`CalendarQueue`] (O(1) amortized
//! vs the reference heap's O(log n)); and the whole run executes over a
//! *borrowed* request stream (`&[SampledRequest]`) so sweeps replaying
//! one cached stream across many candidates never copy it. The
//! all-events-heap baseline lives in [`crate::des::reference`] and the
//! `des_regression` suite pins this engine against it bit-for-bit.
//!
//! A `CapWindow` models a grid demand-response event (paper §4.8): during
//! [start, end) the pool's admission capacity drops to `cap` slots per
//! GPU; in-flight requests are never preempted. Fault injection
//! ([`crate::des::faults`]) follows the same pattern: down instances and
//! service-time inflation are evaluated functionally at admission, and
//! the only fault events are queue re-examinations at each recovery.
//!
//! Entry points: [`Simulator::run_input`] consumes the unified
//! [`SimInput`] (and is what everything routes through);
//! [`Simulator::run_stream`] survives as a deprecated wrapper.

use crate::des::event::{CalendarQueue, EventKind};
use crate::des::faults::CompiledFaults;
use crate::des::input::{ArrivalsSource, ConfigError, SimInput};
use crate::des::memory::{self, MemState, MemoryConfig};
use crate::des::metrics::{DesResult, MetricsCollector, MetricsMode,
                          PoolResult};
use crate::des::pool::DesPool;
use crate::des::retry::{ClosedLoopState, Phase, RetryConfig};
use crate::gpu::profile::GpuProfile;
use crate::router::{RouteRequest, RoutingPolicy};
use crate::workload::rng::Pcg64;
use crate::workload::spec::{SampledRequest, WorkloadSpec};
use crate::workload::streams;

/// Pool construction spec for the simulator.
#[derive(Debug, Clone)]
pub struct SimPool {
    pub gpu: GpuProfile,
    pub n_gpus: usize,
    /// Context budget the pool's KV cache is provisioned for.
    pub ctx_budget: f64,
    /// Steady-state batch cap (vLLM max_num_seqs), None = KV-limited.
    pub batch_cap: Option<u32>,
}

/// A temporary batch-cap reduction (demand-response event, §4.8).
#[derive(Debug, Clone, Copy)]
pub struct CapWindow {
    pub start_ms: f64,
    pub end_ms: f64,
    pub cap: u32,
}

/// Simulation parameters.
#[derive(Debug, Clone)]
pub struct DesConfig {
    pub n_requests: usize,
    pub seed: u64,
    /// Warmup fraction: requests *arriving* before
    /// `warmup_frac * last_arrival` are excluded from statistics (0 =
    /// paper behavior: measure the whole run from the empty state).
    /// Time-based on purpose — dropping the first K requests by index
    /// diverges under non-stationary arrivals, where a burst front-loads
    /// the discarded window.
    pub warmup_frac: f64,
    /// Optional demand-response window applied to every pool.
    pub cap_window: Option<CapWindow>,
    /// Semantic-class mix for multi-model fleets (ModelRouter): requests
    /// draw a class from this distribution; None = single class 0.
    pub class_probs: Option<Vec<f64>>,
    /// Latency aggregation: exact sample vectors (default) or the
    /// O(pools)-memory streaming sketch.
    pub metrics: MetricsMode,
    /// When set, additionally collect per-window TTFT stats over
    /// fixed-width windows of this many ms (time-windowed SLO
    /// evaluation; see [`crate::des::metrics::WindowedStats`]).
    pub window_ms: Option<f64>,
}

impl Default for DesConfig {
    fn default() -> Self {
        DesConfig {
            n_requests: 10_000,
            seed: 42,
            warmup_frac: 0.0,
            cap_window: None,
            class_probs: None,
            metrics: MetricsMode::Exact,
            window_ms: None,
        }
    }
}

/// Arena slot for one request: arrival time plus the (router-transformed)
/// prompt/completion lengths. Indexed by `u32` ids everywhere. Shared
/// with the sharded executor in [`crate::des::shard`], whose arena
/// recycles slots at admission instead of holding one per request.
pub(crate) struct Req {
    pub(crate) arrival_ms: f64,
    pub(crate) l_in: f64,
    pub(crate) l_out: f64,
}

/// Effective per-instance slot cap for `pool` at time `t`. Shared with
/// the memory-mode admission path ([`crate::des::memory`]), which runs
/// the identical compute scan before its occupancy test.
pub(crate) fn eff_cap(
    cap_window: &Option<CapWindow>,
    pool: &DesPool,
    t: f64,
) -> u32 {
    let mut cap = pool.slots_per_gpu;
    if let Some(w) = cap_window {
        if t >= w.start_ms && t < w.end_ms {
            cap = cap.min(w.cap.max(1));
        }
    }
    cap
}

/// Try to admit request `req_id` to `pool_idx` at time `now`.
///
/// The iteration latency is evaluated at the *admission concurrency*
/// (the instance's busy count after this request joins): continuous
/// batching runs faster iterations at lower concurrency, which is the
/// §4.8 recalibration effect and what produces the paper's low
/// lightly-loaded TTFTs. Held for the request's full duration
/// (conservative: the batch may shrink later).
#[allow(clippy::too_many_arguments)]
pub(crate) fn try_admit(
    pools: &mut [DesPool],
    pool_idx: usize,
    req_id: u32,
    reqs: &[Req],
    now: f64,
    events: &mut CalendarQueue,
    cap_window: &Option<CapWindow>,
    faults: Option<&CompiledFaults>,
    metrics: &mut MetricsCollector,
) -> bool {
    let eff = eff_cap(cap_window, &pools[pool_idx], now);
    let pool = &mut pools[pool_idx];
    // Least-loaded instance with headroom under the effective cap.
    // Instances down under the fault script admit nothing (fail-stop
    // without preemption: in-flight requests still complete).
    let mut best: Option<(usize, u32)> = None;
    for (i, inst) in pool.instances.iter().enumerate() {
        if faults.is_some_and(|f| f.is_down(pool_idx, i, now)) {
            continue;
        }
        if inst.busy < eff {
            let free = eff - inst.busy;
            if best.map_or(true, |(_, bf)| free > bf) {
                best = Some((i, free));
            }
        }
    }
    let Some((inst, _)) = best else { return false };
    pool.acquire(inst, now);
    let req = &reqs[req_id as usize];
    let n_at_admit = pool.instances[inst].busy as f64;
    // Stragglers and post-recovery warm-up inflate the iteration
    // latency at admission time (x1.0 with no active window), which
    // propagates to hold, prefill, and TTFT below.
    let slow = faults.map_or(1.0, |f| f.slowdown(pool_idx, inst, now));
    let t_iter = pool.gpu.t_iter(n_at_admit) * slow;
    let hold = pool.gpu.iters(req.l_in, req.l_out) * t_iter;
    events.push(
        now + hold,
        EventKind::Completion {
            req: req_id,
            pool: pool_idx as u16,
            instance: inst as u16,
        },
    );
    // Stats are recorded at admission (wait/TTFT known; E2E = wait +
    // hold is deterministic given admission).
    let wait = now - req.arrival_ms;
    let prefill = (req.l_in / pool.gpu.chunk).ceil() * t_iter;
    let ttft = wait + prefill + t_iter;
    let e2e = wait + hold;
    metrics.record(pool_idx, req.arrival_ms, wait, ttft, e2e);
    true
}

/// Admit queued requests while capacity allows.
#[allow(clippy::too_many_arguments)]
fn drain_queue(
    pools: &mut [DesPool],
    pool_idx: usize,
    reqs: &[Req],
    now: f64,
    events: &mut CalendarQueue,
    cap_window: &Option<CapWindow>,
    faults: Option<&CompiledFaults>,
    metrics: &mut MetricsCollector,
) {
    while let Some(&head) = pools[pool_idx].queue.front() {
        if !try_admit(
            pools, pool_idx, head, reqs, now, events, cap_window, faults,
            metrics,
        ) {
            break;
        }
        pools[pool_idx].queue.pop_front();
    }
}

/// Closed-loop admission: identical slot selection and timing math to
/// [`try_admit`], plus the attempt-deadline check. An attempt admitted
/// with `now + hold <= deadline` completes normally — latency is
/// recorded against the request's *first* arrival, so waits accumulate
/// across failed attempts and backoffs (first-attempt-to-final-success,
/// the client-visible number). An attempt admitted too late to finish
/// in time is Doomed: it holds its slot (wasted work, the retry-storm
/// metastability mechanism) until its timeout event releases it, and
/// no completion is scheduled.
#[allow(clippy::too_many_arguments)]
pub(crate) fn try_admit_closed(
    pools: &mut [DesPool],
    pool_idx: usize,
    req_id: u32,
    reqs: &[Req],
    now: f64,
    events: &mut CalendarQueue,
    cap_window: &Option<CapWindow>,
    faults: Option<&CompiledFaults>,
    metrics: &mut MetricsCollector,
    closed: &mut ClosedLoopState,
) -> bool {
    let eff = eff_cap(cap_window, &pools[pool_idx], now);
    let pool = &mut pools[pool_idx];
    let mut best: Option<(usize, u32)> = None;
    for (i, inst) in pool.instances.iter().enumerate() {
        if faults.is_some_and(|f| f.is_down(pool_idx, i, now)) {
            continue;
        }
        if inst.busy < eff {
            let free = eff - inst.busy;
            if best.map_or(true, |(_, bf)| free > bf) {
                best = Some((i, free));
            }
        }
    }
    let Some((inst, _)) = best else { return false };
    pool.acquire(inst, now);
    let req = &reqs[req_id as usize];
    let n_at_admit = pool.instances[inst].busy as f64;
    let slow = faults.map_or(1.0, |f| f.slowdown(pool_idx, inst, now));
    let t_iter = pool.gpu.t_iter(n_at_admit) * slow;
    let hold = pool.gpu.iters(req.l_in, req.l_out) * t_iter;
    let st = &mut closed.states[req_id as usize];
    st.instance = inst as u16;
    if now + hold <= st.deadline_ms {
        st.phase = Phase::InFlight;
        events.push(
            now + hold,
            EventKind::Completion {
                req: req_id,
                pool: pool_idx as u16,
                instance: inst as u16,
            },
        );
        let first = st.first_arrival_ms;
        let wait = now - first;
        let prefill = (req.l_in / pool.gpu.chunk).ceil() * t_iter;
        let ttft = wait + prefill + t_iter;
        let e2e = wait + hold;
        metrics.record(pool_idx, first, wait, ttft, e2e);
    } else {
        // Doomed: slot stays busy until the pending timeout fires.
        st.phase = Phase::Doomed;
    }
    true
}

/// Start (or restart) an attempt for `req_id` at time `now`: shed on
/// an open breaker, admit, shed on a full queue, or enqueue. The
/// attempt's timeout event is scheduled exactly once — for a Doomed
/// immediate admission or on enqueue — never for an on-time in-flight
/// admission (its completion precedes the deadline by construction).
#[allow(clippy::too_many_arguments)]
pub(crate) fn start_attempt(
    pools: &mut [DesPool],
    req_id: u32,
    reqs: &[Req],
    now: f64,
    events: &mut CalendarQueue,
    cap_window: &Option<CapWindow>,
    faults: Option<&CompiledFaults>,
    metrics: &mut MetricsCollector,
    closed: &mut ClosedLoopState,
) {
    let (pool_idx, first, attempt) = {
        let st = &closed.states[req_id as usize];
        (st.pool as usize, st.first_arrival_ms, st.attempt)
    };
    metrics.record_attempt(first);
    // An open breaker sheds instantly — terminal, the cheap rejection
    // that lets a melted-down pool drain (see `des::retry`).
    if closed.breaker_is_open(pool_idx) {
        closed.states[req_id as usize].phase = Phase::Done;
        metrics.record_shed(first);
        return;
    }
    let deadline = closed.deadline_after(now);
    closed.states[req_id as usize].deadline_ms = deadline;
    if try_admit_closed(
        pools, pool_idx, req_id, reqs, now, events, cap_window, faults,
        metrics, closed,
    ) {
        // A doomed admission still needs its timeout to free the slot
        // (a doomed deadline is always finite: infinite deadlines admit
        // everything on time).
        if closed.states[req_id as usize].phase == Phase::Doomed {
            events.push(
                deadline,
                EventKind::Timeout {
                    req: req_id,
                    pool: pool_idx as u16,
                    attempt,
                },
            );
        }
        return;
    }
    let bound = closed.queue_bound();
    if bound > 0 && pools[pool_idx].queue.len() >= bound {
        closed.states[req_id as usize].phase = Phase::Done;
        metrics.record_shed(first);
        return;
    }
    closed.states[req_id as usize].phase = Phase::Queued;
    pools[pool_idx].enqueue(req_id);
    if deadline.is_finite() {
        events.push(
            deadline,
            EventKind::Timeout {
                req: req_id,
                pool: pool_idx as u16,
                attempt,
            },
        );
    }
    let len = pools[pool_idx].queue.len();
    closed.note_queue_len(pool_idx, len);
}

/// After a timeout (or terminal shed path): schedule the next attempt
/// behind its deterministic backoff, or record a final abandonment.
pub(crate) fn abandon_or_retry(
    req_id: u32,
    now: f64,
    events: &mut CalendarQueue,
    metrics: &mut MetricsCollector,
    closed: &mut ClosedLoopState,
) {
    let st = closed.states[req_id as usize];
    if st.attempt < closed.max_attempts() {
        closed.states[req_id as usize].phase = Phase::Backoff;
        let delay = closed.backoff_after(st.global_id, st.attempt);
        events.push(
            now + delay,
            EventKind::Retry { req: req_id, pool: st.pool },
        );
    } else {
        closed.states[req_id as usize].phase = Phase::Done;
        metrics.record_abandoned(st.first_arrival_ms);
    }
}

/// Closed-loop queue drain: like [`drain_queue`] but through
/// [`try_admit_closed`], with a breaker-hysteresis check after every
/// pop (queued attempts keep their already-scheduled timeouts, so no
/// new timeout events are pushed here).
#[allow(clippy::too_many_arguments)]
pub(crate) fn drain_queue_closed(
    pools: &mut [DesPool],
    pool_idx: usize,
    reqs: &[Req],
    now: f64,
    events: &mut CalendarQueue,
    cap_window: &Option<CapWindow>,
    faults: Option<&CompiledFaults>,
    metrics: &mut MetricsCollector,
    closed: &mut ClosedLoopState,
) {
    while let Some(&head) = pools[pool_idx].queue.front() {
        if !try_admit_closed(
            pools, pool_idx, head, reqs, now, events, cap_window, faults,
            metrics, closed,
        ) {
            break;
        }
        pools[pool_idx].queue.pop_front();
        let len = pools[pool_idx].queue.len();
        closed.note_queue_len(pool_idx, len);
    }
}

/// The simulator: workload x pools x router -> latency distributions.
pub struct Simulator {
    pub workload: WorkloadSpec,
    pub pools: Vec<SimPool>,
    pub router: RoutingPolicy,
    pub config: DesConfig,
}

impl Simulator {
    pub fn new(
        workload: WorkloadSpec,
        pools: Vec<SimPool>,
        router: RoutingPolicy,
        config: DesConfig,
    ) -> Self {
        assert!(
            router.n_pools() <= pools.len(),
            "router expects {} pools, got {}",
            router.n_pools(),
            pools.len()
        );
        Simulator { workload, pools, router, config }
    }

    /// Run the simulation (samples the workload's request stream).
    pub fn run(&self) -> DesResult {
        let sampled = self
            .workload
            .sample_requests(self.config.n_requests, self.config.seed);
        let input =
            SimInput::stream(&self.pools, &self.router, &self.config,
                             &sampled);
        match Self::run_input(&input) {
            Ok(r) => r,
            Err(e) => panic!("{e}"),
        }
    }

    /// Run on an explicit, time-ordered request stream. The stream is
    /// borrowed — replaying one cached sample across many candidates
    /// copies nothing. Panics on invalid input exactly as the
    /// pre-`SimInput` API did.
    #[deprecated(note = "build a SimInput and call Simulator::run_input")]
    pub fn run_with_requests(&self, sampled: &[SampledRequest]) -> DesResult {
        let input =
            SimInput::stream(&self.pools, &self.router, &self.config,
                             sampled);
        match Self::run_input(&input) {
            Ok(r) => r,
            Err(e) => panic!("{e}"),
        }
    }

    /// The unified entry point: validate, compile the fault script,
    /// materialize generator-driven arrivals if needed, and run the
    /// core. Everything in the input is borrowed — replaying one
    /// cached stream across many candidates copies nothing.
    pub fn run_input(input: &SimInput<'_>) -> Result<DesResult, ConfigError> {
        input.validate()?;
        let faults = input.compiled_faults();
        match input.arrivals {
            ArrivalsSource::Stream(sampled) => Ok(run_core(
                input.pools, input.router, input.config, sampled,
                faults.as_ref(), input.retries, input.memory,
            )),
            ArrivalsSource::Generator(w) => {
                let sampled = w.sample_requests(
                    input.config.n_requests, input.config.seed,
                );
                Ok(run_core(
                    input.pools, input.router, input.config, &sampled,
                    faults.as_ref(), input.retries, input.memory,
                ))
            }
        }
    }

    /// Run over a materialized stream — a compatibility wrapper that
    /// panics on invalid input exactly as the pre-`SimInput` API did.
    #[deprecated(note = "build a SimInput and call Simulator::run_input")]
    pub fn run_stream(
        pool_specs: &[SimPool],
        router: &RoutingPolicy,
        config: &DesConfig,
        sampled: &[SampledRequest],
    ) -> DesResult {
        let input = SimInput::stream(pool_specs, router, config, sampled);
        match Self::run_input(&input) {
            Ok(r) => r,
            Err(e) => panic!("{e}"),
        }
    }
}

/// The DES core: no `Simulator` construction (and no workload, pool,
/// or router clone) required — everything is borrowed. Inputs are
/// pre-validated by [`Simulator::run_input`].
fn run_core(
    pool_specs: &[SimPool],
    router: &RoutingPolicy,
    config: &DesConfig,
    sampled: &[SampledRequest],
    faults: Option<&CompiledFaults>,
    retries: Option<&RetryConfig>,
    mem_cfg: Option<&MemoryConfig>,
) -> DesResult {
    {
        let n = sampled.len();
        debug_assert!(sampled
            .windows(2)
            .all(|w| w[0].arrival_ms <= w[1].arrival_ms));
        let mut route_rng = Pcg64::new(config.seed, streams::ROUTING);
        // Closed-loop state exists iff a retry config is attached; the
        // None path below is byte-for-byte the open-loop simulator.
        let mut closed: Option<ClosedLoopState> =
            retries.map(|c| ClosedLoopState::new(c, config.seed,
                                                 pool_specs.len()));

        let mut pools: Vec<DesPool> = pool_specs
            .iter()
            .map(|p| {
                DesPool::new(p.gpu.clone(), p.n_gpus, p.ctx_budget,
                             p.batch_cap)
            })
            .collect();
        // Memory-mode state exists iff a memory config is attached; the
        // None path below is byte-for-byte the open-loop simulator.
        let mut mem: Option<MemState> =
            mem_cfg.map(|m| MemState::new(m, &pools));

        // Index-based request arena. Arrivals are already time-sorted, so
        // only completions (and cap-window drains) live in the calendar
        // queue; arrivals are merge-consumed from the sorted slice.
        let mut reqs: Vec<Req> = sampled
            .iter()
            .map(|s| Req {
                arrival_ms: s.arrival_ms,
                l_in: s.l_in,
                l_out: s.l_out,
            })
            .collect();
        let mut events = CalendarQueue::with_capacity(64);
        if let Some(w) = &config.cap_window {
            for p in 0..pools.len() {
                events.push(w.end_ms, EventKind::Drain { pool: p as u16 });
            }
        }
        // Fault recoveries re-examine the pool's queue, exactly like a
        // cap-window end. Pushed at init, after cap drains, in script
        // order — the relative order the sharded engine preserves.
        if let Some(f) = faults {
            for &(t, pool) in f.drains() {
                events.push(t, EventKind::Drain { pool });
            }
        }

        // Time-based warmup: the stream is known upfront, so the cutoff
        // instant is warmup_frac of the arrival span. warmup_frac = 0
        // keeps every request (bit-identical to the historical behavior).
        let warmup_time_ms = config.warmup_frac
            * sampled.last().map_or(0.0, |r| r.arrival_ms);
        let mut metrics = MetricsCollector::new(
            config.metrics, pools.len(), n, config.window_ms, warmup_time_ms,
        );
        let mut n_compressed = 0usize;
        let mut n_events = 0usize;
        let mut horizon = 0.0f64;
        let mut next_arrival: usize = 0;

        loop {
            // Arrivals win ties (matching the reference heap's FIFO seq
            // ordering, where arrivals are pushed first).
            let take_arrival = next_arrival < n
                && events
                    .next_time()
                    .map_or(true, |t| reqs[next_arrival].arrival_ms <= t);
            if take_arrival {
                let req = next_arrival as u32;
                next_arrival += 1;
                n_events += 1;
                let r = &reqs[req as usize];
                let now = r.arrival_ms;
                horizon = horizon.max(now);
                metrics.record_arrival(now);
                let class = match &config.class_probs {
                    None => 0,
                    Some(probs) => {
                        let u = route_rng.uniform();
                        let mut cum = 0.0;
                        let mut cls = probs.len() - 1;
                        for (i, p) in probs.iter().enumerate() {
                            cum += p;
                            if u < cum {
                                cls = i;
                                break;
                            }
                        }
                        cls
                    }
                };
                let decision = router.route(
                    RouteRequest { l_in: r.l_in, l_out: r.l_out, class },
                    &mut route_rng,
                );
                let r = &mut reqs[req as usize];
                r.l_in = decision.request.l_in;
                r.l_out = decision.request.l_out;
                if decision.compressed {
                    n_compressed += 1;
                }
                if let Some(cl) = closed.as_mut() {
                    // Stream index doubles as the global request id on
                    // the serial engines.
                    cl.init_request(req as usize, req as u64, now);
                    cl.states[req as usize].pool = decision.pool as u16;
                    start_attempt(
                        &mut pools, req, &reqs, now, &mut events,
                        &config.cap_window, faults, &mut metrics, cl,
                    );
                } else if let Some(ms) = mem.as_mut() {
                    let (l_in, l_out) = (r.l_in, r.l_out);
                    ms.init_request(req, l_in, l_out, now);
                    if !ms.try_admit(
                        &mut pools, decision.pool, req, now, &mut events,
                        &config.cap_window, faults,
                    ) {
                        pools[decision.pool].enqueue(req);
                    }
                } else if !try_admit(
                    &mut pools, decision.pool, req, &reqs, now, &mut events,
                    &config.cap_window, faults, &mut metrics,
                ) {
                    pools[decision.pool].enqueue(req);
                }
                continue;
            }
            let Some(ev) = events.pop() else { break };
            n_events += 1;
            let now = ev.time_ms;
            horizon = horizon.max(now);
            match ev.kind {
                EventKind::Arrival { .. } => unreachable!("arrivals merged"),
                EventKind::Completion { req, pool, instance } => {
                    pools[pool as usize].release(instance as usize, now);
                    if let Some(cl) = closed.as_mut() {
                        cl.states[req as usize].phase = Phase::Done;
                        drain_queue_closed(
                            &mut pools, pool as usize, &reqs, now,
                            &mut events, &config.cap_window, faults,
                            &mut metrics, cl,
                        );
                    } else {
                        drain_queue(
                            &mut pools, pool as usize, &reqs, now,
                            &mut events, &config.cap_window, faults,
                            &mut metrics,
                        );
                    }
                }
                EventKind::Drain { pool } => {
                    if let Some(cl) = closed.as_mut() {
                        drain_queue_closed(
                            &mut pools, pool as usize, &reqs, now,
                            &mut events, &config.cap_window, faults,
                            &mut metrics, cl,
                        );
                    } else if let Some(ms) = mem.as_mut() {
                        ms.drain(
                            &mut pools, pool as usize, now, &mut events,
                            &config.cap_window, faults,
                        );
                    } else {
                        drain_queue(
                            &mut pools, pool as usize, &reqs, now,
                            &mut events, &config.cap_window, faults,
                            &mut metrics,
                        );
                    }
                }
                EventKind::MemCompletion { req, pool, instance, gen } => {
                    let ms = mem
                        .as_mut()
                        .expect("memory events exist only in memory mode");
                    ms.on_completion(
                        &mut pools, pool as usize, instance as usize, req,
                        gen, now, &mut events, &config.cap_window, faults,
                        &mut metrics,
                    );
                }
                EventKind::MemPressure { pool, instance, epoch } => {
                    let ms = mem
                        .as_mut()
                        .expect("memory events exist only in memory mode");
                    ms.on_pressure(
                        &mut pools, pool as usize, instance as usize,
                        epoch, now, &mut events, &config.cap_window,
                        faults, &mut metrics,
                    );
                }
                EventKind::Timeout { req, pool, attempt } => {
                    let cl = closed
                        .as_mut()
                        .expect("timeouts exist only in closed-loop runs");
                    let st = cl.states[req as usize];
                    if st.attempt != attempt {
                        continue; // superseded by a later attempt
                    }
                    match st.phase {
                        Phase::Queued => {
                            // Eager removal: the queue never holds
                            // expired requests, so the final unserved
                            // scan and every drain see live ones only.
                            let q = &mut pools[pool as usize].queue;
                            if let Some(pos) =
                                q.iter().position(|&r| r == req)
                            {
                                q.remove(pos);
                            }
                            let len = pools[pool as usize].queue.len();
                            cl.note_queue_len(pool as usize, len);
                            abandon_or_retry(
                                req, now, &mut events, &mut metrics, cl,
                            );
                        }
                        Phase::Doomed => {
                            // The wasted-work slot frees only now.
                            pools[pool as usize]
                                .release(st.instance as usize, now);
                            abandon_or_retry(
                                req, now, &mut events, &mut metrics, cl,
                            );
                            drain_queue_closed(
                                &mut pools, pool as usize, &reqs, now,
                                &mut events, &config.cap_window, faults,
                                &mut metrics, cl,
                            );
                        }
                        // Completed (or already moved on): stale no-op.
                        _ => {}
                    }
                }
                EventKind::Retry { req, pool: _ } => {
                    let cl = closed
                        .as_mut()
                        .expect("retries exist only in closed-loop runs");
                    cl.states[req as usize].attempt += 1;
                    start_attempt(
                        &mut pools, req, &reqs, now, &mut events,
                        &config.cap_window, faults, &mut metrics, cl,
                    );
                }
            }
        }

        let (n_unserved, max_unserved_wait, pool_unserved) = metrics
            .scan_unserved(&pools, |req| reqs[req as usize].arrival_ms,
                           horizon);
        let mem_raw = mem.as_ref().map(|m| m.raws());
        let (kv_peak, kv_mean, n_preempted, preempt_stall) = match &mem_raw
        {
            Some(raws) => memory::overall_from_raw(raws, horizon),
            None => (0.0, 0.0, 0, 0.0),
        };

        DesResult {
            per_pool: pools
                .iter()
                .zip(metrics.per_pool)
                .zip(pool_unserved)
                .enumerate()
                .map(|(i, ((p, stats), n_unserved))| {
                    let (pk, mn, np, st) = match &mem_raw {
                        Some(raws) => {
                            let (pk, mn) = memory::pool_util_from_raw(
                                &raws[i], horizon,
                            );
                            (pk, mn, raws[i].n_preempted, raws[i].stall_ms)
                        }
                        None => (0.0, 0.0, 0, 0.0),
                    };
                    PoolResult {
                        stats,
                        utilization: p.utilization(horizon),
                        max_queue_depth: p.max_queue_depth,
                        slots_per_gpu: p.slots_per_gpu,
                        n_gpus: p.instances.len(),
                        n_unserved,
                        n_preempted: np,
                        preempt_stall_ms: st,
                        kv_peak_util: pk,
                        kv_mean_util: mn,
                    }
                })
                .collect(),
            overall: metrics.overall,
            horizon_ms: horizon,
            n_requests: n,
            n_compressed,
            n_events,
            n_unserved,
            max_unserved_wait_ms: max_unserved_wait,
            n_attempts: metrics.n_attempts,
            n_abandoned: metrics.n_abandoned,
            n_shed: metrics.n_shed,
            windows: metrics.windows,
            n_preempted,
            preempt_stall_ms: preempt_stall,
            kv_peak_util: kv_peak,
            kv_mean_util: kv_mean,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::catalog::GpuCatalog;
    use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

    fn h100() -> GpuProfile {
        GpuCatalog::standard().get("H100").unwrap().clone()
    }

    fn a100() -> GpuProfile {
        GpuCatalog::standard().get("A100").unwrap().clone()
    }

    fn azure(lambda: f64) -> WorkloadSpec {
        WorkloadSpec::builtin(BuiltinTrace::Azure, lambda)
    }

    fn two_pool(gpu: GpuProfile, n_s: usize, n_l: usize, b: f64, max: f64)
        -> (Vec<SimPool>, RoutingPolicy)
    {
        (
            vec![
                SimPool { gpu: gpu.clone(), n_gpus: n_s, ctx_budget: b,
                          batch_cap: None },
                SimPool { gpu, n_gpus: n_l, ctx_budget: max, batch_cap: None },
            ],
            RoutingPolicy::Length { b_short: b },
        )
    }

    #[test]
    fn conserves_requests() {
        let (pools, router) = two_pool(a100(), 4, 4, 4096.0, 8192.0);
        let cfg = DesConfig { n_requests: 5_000, ..Default::default() };
        let sim = Simulator::new(azure(100.0), pools, router, cfg);
        let mut r = sim.run();
        assert_eq!(r.overall.count, 5_000);
        let pool_sum: usize = r.per_pool.iter().map(|p| p.stats.count).sum();
        assert_eq!(pool_sum, 5_000);
        assert!(r.horizon_ms > 0.0);
        assert!(r.overall.p99_ttft() > 0.0);
    }

    #[test]
    fn deterministic_across_runs() {
        let (pools, router) = two_pool(h100(), 2, 2, 4096.0, 8192.0);
        let cfg =
            DesConfig { n_requests: 2_000, seed: 7, ..Default::default() };
        let mut a = Simulator::new(azure(150.0), pools.clone(),
                                   router.clone(), cfg.clone())
            .run();
        let mut b = Simulator::new(azure(150.0), pools, router, cfg).run();
        assert_eq!(a.overall.p99_ttft(), b.overall.p99_ttft());
        assert_eq!(a.horizon_ms, b.horizon_ms);
    }

    #[test]
    fn light_load_has_no_queueing() {
        // 5 req/s on 4 H100s: waits should be ~0, TTFT ~ prefill + iter.
        let (pools, router) = two_pool(h100(), 2, 2, 4096.0, 8192.0);
        let cfg = DesConfig { n_requests: 3_000, ..Default::default() };
        let sim = Simulator::new(azure(5.0), pools, router, cfg);
        let mut r = sim.run();
        assert!(r.overall.wait.p99() < 1e-9, "wait = {}", r.overall.wait.p99());
        assert!(r.overall.p99_ttft() < 500.0);
    }

    #[test]
    fn overload_explodes_wait() {
        // 400 req/s on 1 A100: queue grows without bound.
        let pools = vec![SimPool {
            gpu: a100(), n_gpus: 1, ctx_budget: 8192.0, batch_cap: None,
        }];
        let sim = Simulator::new(
            azure(400.0), pools, RoutingPolicy::Random { n_pools: 1 },
            DesConfig { n_requests: 8_000, ..Default::default() },
        );
        let mut r = sim.run();
        let w99 = r.overall.wait.p99();
        assert!(w99 > 10_000.0, "wait = {w99}");
        assert!(r.per_pool[0].utilization > 0.9);
    }

    #[test]
    fn utilization_scales_with_load() {
        let mk = |lam| {
            let (pools, router) = two_pool(h100(), 3, 3, 4096.0, 8192.0);
            let cfg = DesConfig { n_requests: 6_000, ..Default::default() };
            let sim = Simulator::new(azure(lam), pools, router, cfg);
            let r = sim.run();
            (r.per_pool[0].utilization, r.per_pool[1].utilization)
        };
        let (lo_s, _) = mk(20.0);
        let (hi_s, _) = mk(200.0);
        assert!(hi_s > lo_s * 3.0, "{lo_s} -> {hi_s}");
    }

    #[test]
    fn short_pool_receives_expected_fraction() {
        let (pools, router) = two_pool(a100(), 4, 4, 4096.0, 8192.0);
        let cfg = DesConfig { n_requests: 20_000, ..Default::default() };
        let sim = Simulator::new(azure(100.0), pools, router, cfg);
        let r = sim.run();
        let frac = r.per_pool[0].stats.count as f64 / r.n_requests as f64;
        // Azure F(4096) = 0.97.
        assert!((frac - 0.97).abs() < 0.01, "frac = {frac}");
    }

    #[test]
    fn cap_window_increases_wait_during_event() {
        // Strangle a comfortable fleet to 1 slot/GPU for a mid-run window.
        let pools = vec![SimPool {
            gpu: h100(), n_gpus: 2, ctx_budget: 8192.0, batch_cap: Some(64),
        }];
        let base_cfg =
            DesConfig { n_requests: 10_000, seed: 3, ..Default::default() };
        let base = Simulator::new(
            azure(60.0), pools.clone(), RoutingPolicy::Random { n_pools: 1 },
            base_cfg.clone(),
        )
        .run();
        let mut capped_cfg = base_cfg;
        capped_cfg.cap_window = Some(CapWindow {
            start_ms: 30_000.0, end_ms: 105_000.0, cap: 1,
        });
        let capped = Simulator::new(
            azure(60.0), pools, RoutingPolicy::Random { n_pools: 1 },
            capped_cfg,
        )
        .run();
        let mut b = base.overall.clone();
        let mut c = capped.overall.clone();
        assert!(c.wait.p99() > b.wait.p99() + 100.0,
                "base {} capped {}", b.wait.p99(), c.wait.p99());
        // And the queue must fully drain afterwards (same request count).
        assert_eq!(capped.overall.count, 10_000);
    }

    #[test]
    fn compress_and_route_counts_compressions() {
        let (pools, _) = two_pool(a100(), 4, 4, 2048.0, 8192.0);
        let sim = Simulator::new(
            azure(50.0), pools,
            RoutingPolicy::CompressAndRoute { b_short: 2048.0, gamma: 1.5 },
            DesConfig { n_requests: 10_000, ..Default::default() },
        );
        let r = sim.run();
        // Azure mass in (2048, 3072] is ~17%.
        let frac = r.n_compressed as f64 / r.n_requests as f64;
        assert!((0.10..0.25).contains(&frac), "compressed frac = {frac}");
    }

    #[test]
    fn warmup_excludes_requests_by_arrival_time() {
        // Time-based warmup: requests arriving before 20% of the arrival
        // span are dropped — exactly those, as counted on the stream.
        let (pools, router) = two_pool(a100(), 2, 2, 4096.0, 8192.0);
        let cfg = DesConfig {
            n_requests: 1_000, warmup_frac: 0.2, ..Default::default()
        };
        let w = azure(50.0);
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        let cutoff = 0.2 * sampled.last().unwrap().arrival_ms;
        let expected =
            sampled.iter().filter(|r| r.arrival_ms >= cutoff).count();
        let r = Simulator::new(w, pools, router, cfg).run();
        assert_eq!(r.overall.count, expected);
        // Poisson arrivals: the time-based count is near (but not
        // necessarily exactly) the index-based 800.
        assert!((700..=900).contains(&expected), "expected = {expected}");
        assert_eq!(r.n_unserved, 0);
    }

    #[test]
    fn dead_pool_requests_are_unserved_not_censored() {
        // Long requests route to a pool with zero GPUs: they queue
        // forever. Pre-fix, they simply vanished from the stats and the
        // fleet "met" its SLO on the short traffic alone.
        let pools = vec![
            SimPool { gpu: h100(), n_gpus: 4, ctx_budget: 4096.0,
                      batch_cap: None },
            SimPool { gpu: h100(), n_gpus: 0, ctx_budget: 8192.0,
                      batch_cap: None },
        ];
        let cfg = DesConfig { n_requests: 5_000, ..Default::default() };
        let sim = Simulator::new(
            azure(20.0), pools, RoutingPolicy::Length { b_short: 4096.0 },
            cfg,
        );
        let mut r = sim.run();
        assert!(r.n_unserved > 0);
        assert_eq!(r.overall.count + r.n_unserved, 5_000);
        assert_eq!(r.per_pool[1].stats.count, 0);
        assert_eq!(r.per_pool[1].n_unserved, r.n_unserved);
        // The served traffic is fast…
        assert!(r.overall.p99_ttft() < 500.0);
        // …but the backlog has waited essentially the whole horizon.
        assert!(r.max_unserved_wait_ms > 500.0);
        assert!(!r.meets_slo(500.0), "censored backlog must fail the SLO");
        // Attainment counts the backlog in the denominator.
        let att = r.attainment(500.0);
        let served_frac = r.overall.count as f64 / 5_000.0;
        assert!(att <= served_frac + 1e-12, "att {att} served {served_frac}");
    }

    #[test]
    fn windowed_stats_cover_all_measured_requests() {
        // 10 req/s on 4+4 A100s: comfortably stable, so every window
        // must pass a generous SLO.
        let (pools, router) = two_pool(a100(), 4, 4, 4096.0, 8192.0);
        let cfg = DesConfig {
            n_requests: 4_000,
            window_ms: Some(5_000.0),
            ..Default::default()
        };
        let mut r = Simulator::new(azure(10.0), pools, router, cfg).run();
        let windows = r.windows.take().unwrap();
        let arrived: usize =
            (0..windows.n_windows()).map(|i| windows.n_arrived(i)).sum();
        let served: usize =
            (0..windows.n_windows()).map(|i| windows.n_served(i)).sum();
        assert_eq!(arrived, 4_000);
        assert_eq!(served, 4_000);
        assert!(windows.n_windows() >= 4);
        // A comfortable stationary fleet meets the SLO in every window.
        let mut ws = windows;
        assert!(ws.all_meet_slo(2_000.0));
    }

    #[test]
    fn counts_two_events_per_request_plus_drains() {
        let (pools, router) = two_pool(a100(), 4, 4, 4096.0, 8192.0);
        let n_pools = pools.len();
        let cfg = DesConfig { n_requests: 3_000, ..Default::default() };
        let r = Simulator::new(azure(80.0), pools.clone(), router.clone(), cfg)
            .run();
        assert_eq!(r.n_events, 2 * 3_000);
        let capped = DesConfig {
            n_requests: 3_000,
            cap_window: Some(CapWindow {
                start_ms: 5_000.0, end_ms: 20_000.0, cap: 4,
            }),
            ..Default::default()
        };
        let rc = Simulator::new(azure(80.0), pools, router, capped).run();
        assert_eq!(rc.n_events, 2 * 3_000 + n_pools);
    }

    #[test]
    fn streaming_mode_matches_exact_within_tolerance() {
        let (pools, router) = two_pool(a100(), 4, 4, 4096.0, 8192.0);
        let exact_cfg = DesConfig { n_requests: 8_000, ..Default::default() };
        let stream_cfg = DesConfig {
            metrics: MetricsMode::Streaming,
            ..exact_cfg.clone()
        };
        let mut e = Simulator::new(azure(100.0), pools.clone(), router.clone(),
                                   exact_cfg).run();
        let mut s = Simulator::new(azure(100.0), pools, router, stream_cfg)
            .run();
        assert_eq!(e.overall.count, s.overall.count);
        assert_eq!(e.n_events, s.n_events);
        assert_eq!(e.horizon_ms, s.horizon_ms);
        let (ep, sp) = (e.overall.p99_ttft(), s.overall.p99_ttft());
        assert!((sp / ep - 1.0).abs() < 0.03, "exact {ep} streaming {sp}");
        // Utilization accounting is metrics-independent.
        for (pe, ps) in e.per_pool.iter().zip(&s.per_pool) {
            assert_eq!(pe.utilization, ps.utilization);
            assert_eq!(pe.stats.count, ps.stats.count);
        }
    }

    #[test]
    fn run_stream_matches_run_on_same_sample() {
        let (pools, router) = two_pool(h100(), 2, 3, 4096.0, 8192.0);
        let w = azure(90.0);
        let cfg =
            DesConfig { n_requests: 4_000, seed: 13, ..Default::default() };
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        let mut via_run = Simulator::new(w, pools.clone(), router.clone(),
                                         cfg.clone()).run();
        let input = SimInput::stream(&pools, &router, &cfg, &sampled);
        let mut via_stream = Simulator::run_input(&input).unwrap();
        assert_eq!(via_run.overall.p99_ttft(), via_stream.overall.p99_ttft());
        assert_eq!(via_run.n_events, via_stream.n_events);
        assert_eq!(via_run.horizon_ms, via_stream.horizon_ms);
    }

    #[test]
    fn run_input_rejects_router_pool_mismatch() {
        let pools = vec![SimPool {
            gpu: a100(), n_gpus: 2, ctx_budget: 8192.0, batch_cap: None,
        }];
        let router = RoutingPolicy::Length { b_short: 4096.0 };
        let cfg = DesConfig::default();
        let sampled: Vec<crate::workload::spec::SampledRequest> = vec![];
        let input = SimInput::stream(&pools, &router, &cfg, &sampled);
        let err = Simulator::run_input(&input).map(|_| ()).unwrap_err();
        assert!(matches!(err,
                         ConfigError::RouterPoolMismatch { expected: 2,
                                                           got: 1 }));
    }

    #[test]
    fn empty_fault_script_is_bit_identical_to_none() {
        use crate::des::faults::FaultScript;
        let (pools, router) = two_pool(a100(), 3, 3, 4096.0, 8192.0);
        let cfg =
            DesConfig { n_requests: 4_000, seed: 5, ..Default::default() };
        let w = azure(120.0);
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        let plain = SimInput::stream(&pools, &router, &cfg, &sampled);
        let script = FaultScript::default();
        let faulted = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_faults(&script);
        let mut a = Simulator::run_input(&plain).unwrap();
        let mut b = Simulator::run_input(&faulted).unwrap();
        assert_eq!(a.overall.p99_ttft(), b.overall.p99_ttft());
        assert_eq!(a.overall.wait.p99(), b.overall.wait.p99());
        assert_eq!(a.n_events, b.n_events);
        assert_eq!(a.horizon_ms, b.horizon_ms);
    }

    #[test]
    fn failures_add_one_drain_event_each_and_raise_wait() {
        use crate::des::faults::{FaultScript, GpuFailure};
        // A comfortable single pool; kill all but one GPU mid-run.
        let pools = vec![SimPool {
            gpu: a100(), n_gpus: 4, ctx_budget: 8192.0, batch_cap: None,
        }];
        let router = RoutingPolicy::Random { n_pools: 1 };
        let cfg =
            DesConfig { n_requests: 6_000, seed: 9, ..Default::default() };
        let w = azure(80.0);
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        let base = Simulator::run_input(
            &SimInput::stream(&pools, &router, &cfg, &sampled),
        )
        .unwrap();
        let script = FaultScript {
            failures: vec![GpuFailure {
                pool: 0,
                n_gpus: 3,
                start_ms: 10_000.0,
                recover_ms: 40_000.0,
                warm_ms: 0.0,
                warm_factor: 1.0,
            }],
            stragglers: vec![],
        };
        let input = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_faults(&script);
        let faulted = Simulator::run_input(&input).unwrap();
        assert_eq!(faulted.n_events, base.n_events + 1,
                   "one drain per failure");
        // Everything still completes after recovery…
        assert_eq!(faulted.overall.count, 6_000);
        assert_eq!(faulted.n_unserved, 0);
        // …but the outage queue shows up in the wait distribution.
        let (mut b, mut f) = (base.overall.clone(), faulted.overall.clone());
        assert!(f.wait.p99() > b.wait.p99() + 100.0,
                "base {} faulted {}", b.wait.p99(), f.wait.p99());
    }

    #[test]
    fn lenient_closed_loop_is_bit_identical_when_nothing_queues() {
        use crate::des::retry::{RetryConfig, RetrySpec};
        // Light load: no attempt ever queues, so a huge client timeout
        // schedules no timeout events and the closed-loop run matches
        // the open-loop one bit for bit — events, horizon, latencies.
        let (pools, router) = two_pool(h100(), 4, 4, 4096.0, 8192.0);
        let cfg = DesConfig { n_requests: 2_000, ..Default::default() };
        let w = azure(2.0);
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        let open = SimInput::stream(&pools, &router, &cfg, &sampled);
        let rc = RetryConfig {
            retry: Some(RetrySpec {
                max_attempts: 3,
                timeout_ms: 1e9,
                backoff_base_ms: 100.0,
                backoff_cap_ms: 400.0,
            }),
            admission: None,
        };
        let closed = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_retries(&rc);
        let mut a = Simulator::run_input(&open).unwrap();
        let mut b = Simulator::run_input(&closed).unwrap();
        assert_eq!(a.n_events, b.n_events);
        assert_eq!(a.horizon_ms, b.horizon_ms);
        assert_eq!(a.overall.count, b.overall.count);
        assert_eq!(a.overall.p99_ttft(), b.overall.p99_ttft());
        assert_eq!(a.overall.wait.p99(), b.overall.wait.p99());
        assert_eq!(b.n_attempts, 2_000);
        assert_eq!(b.n_abandoned, 0);
        assert_eq!(b.n_shed, 0);
        assert_eq!(b.retry_amplification(), 1.0);
    }

    #[test]
    fn timeouts_abandon_requests_and_conserve_counts() {
        use crate::des::retry::{RetryConfig, RetrySpec};
        // 400 req/s on 1 A100 with a 2 s deadline and no retries:
        // most of the queue times out instead of waiting forever.
        let pools = vec![SimPool {
            gpu: a100(), n_gpus: 1, ctx_budget: 8192.0, batch_cap: None,
        }];
        let router = RoutingPolicy::Random { n_pools: 1 };
        let cfg =
            DesConfig { n_requests: 4_000, seed: 11, ..Default::default() };
        let w = azure(400.0);
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        let rc = RetryConfig {
            retry: Some(RetrySpec {
                max_attempts: 1,
                timeout_ms: 2_000.0,
                backoff_base_ms: 0.0,
                backoff_cap_ms: 0.0,
            }),
            admission: None,
        };
        let input = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_retries(&rc);
        let mut r = Simulator::run_input(&input).unwrap();
        assert_eq!(r.n_attempts, 4_000, "one attempt per request");
        assert!(r.n_abandoned > 1_000, "abandoned = {}", r.n_abandoned);
        assert_eq!(r.n_shed, 0);
        // Timeouts empty the queues, so nothing is left unserved.
        assert_eq!(r.n_unserved, 0);
        assert_eq!(
            r.overall.count + r.n_abandoned, 4_000,
            "served + abandoned must conserve the stream"
        );
        assert_eq!(r.retry_amplification(), 1.0);
        assert!(r.goodput_rps() < r.throughput_rps());
        assert!(!r.meets_slo(500.0), "abandonment must poison the SLO");
        // Served requests all finished within their deadline.
        assert!(r.overall.e2e.p99() <= 2_000.0 + 1e-9);
    }

    #[test]
    fn naive_retries_amplify_offered_load() {
        use crate::des::retry::{RetryConfig, RetrySpec};
        let pools = vec![SimPool {
            gpu: a100(), n_gpus: 1, ctx_budget: 8192.0, batch_cap: None,
        }];
        let router = RoutingPolicy::Random { n_pools: 1 };
        let cfg =
            DesConfig { n_requests: 4_000, seed: 11, ..Default::default() };
        let w = azure(400.0);
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        let rc = RetryConfig {
            retry: Some(RetrySpec {
                max_attempts: 3,
                timeout_ms: 2_000.0,
                backoff_base_ms: 100.0,
                backoff_cap_ms: 400.0,
            }),
            admission: None,
        };
        let input = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_retries(&rc);
        let r = Simulator::run_input(&input).unwrap();
        assert!(r.n_attempts > 4_000, "attempts = {}", r.n_attempts);
        assert!(r.retry_amplification() > 1.2,
                "amplification = {}", r.retry_amplification());
        assert_eq!(r.overall.count + r.n_abandoned, 4_000);
        assert_eq!(r.n_unserved, 0);
    }

    #[test]
    fn queue_bound_sheds_and_bounds_depth() {
        use crate::des::retry::{AdmissionSpec, RetryConfig};
        let pools = vec![SimPool {
            gpu: a100(), n_gpus: 1, ctx_budget: 8192.0, batch_cap: None,
        }];
        let router = RoutingPolicy::Random { n_pools: 1 };
        let cfg =
            DesConfig { n_requests: 4_000, seed: 11, ..Default::default() };
        let w = azure(400.0);
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        let rc = RetryConfig {
            retry: None,
            admission: Some(AdmissionSpec {
                max_queue_depth: 8,
                breaker_open_depth: 0,
                breaker_close_depth: 0,
            }),
        };
        let input = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_retries(&rc);
        let r = Simulator::run_input(&input).unwrap();
        assert!(r.n_shed > 0, "shed = {}", r.n_shed);
        assert!(r.per_pool[0].max_queue_depth <= 8,
                "depth = {}", r.per_pool[0].max_queue_depth);
        // No timeouts: the bounded queue fully drains after the last
        // arrival, so everything is either served or shed.
        assert_eq!(r.overall.count + r.n_shed, 4_000);
        assert_eq!(r.n_unserved, 0);
        assert_eq!(r.n_attempts, 4_000);
    }

    #[test]
    fn circuit_breaker_sheds_with_hysteresis() {
        use crate::des::retry::{AdmissionSpec, RetryConfig};
        let pools = vec![SimPool {
            gpu: a100(), n_gpus: 1, ctx_budget: 8192.0, batch_cap: None,
        }];
        let router = RoutingPolicy::Random { n_pools: 1 };
        let cfg =
            DesConfig { n_requests: 4_000, seed: 11, ..Default::default() };
        let w = azure(400.0);
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        let rc = RetryConfig {
            retry: None,
            admission: Some(AdmissionSpec {
                max_queue_depth: 0,
                breaker_open_depth: 16,
                breaker_close_depth: 4,
            }),
        };
        let input = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_retries(&rc);
        let r = Simulator::run_input(&input).unwrap();
        assert!(r.n_shed > 0, "shed = {}", r.n_shed);
        // The queue only grows while the breaker is closed, so its peak
        // stays near the open threshold.
        assert!(r.per_pool[0].max_queue_depth <= 17,
                "depth = {}", r.per_pool[0].max_queue_depth);
        assert_eq!(r.overall.count + r.n_shed, 4_000);
        assert_eq!(r.n_unserved, 0);
    }

    fn tight_memory(policy: crate::des::memory::PolicyKind)
        -> crate::des::memory::MemoryConfig
    {
        use crate::des::memory::{MemoryConfig, MemorySpec};
        // A100 @ 80 GB HBM, 71 GB weights, 1 MB/token: 9000 KV
        // token-slots per GPU — a handful of Azure requests, far below
        // the 128-slot compute cap, so memory binds first.
        MemoryConfig {
            spec: MemorySpec {
                hbm_gb: None,
                weights_gb: 71.0,
                bytes_per_token: 1e6,
            },
            policy,
            swap_out_ms: 2.0,
            swap_in_ms: 4.0,
        }
    }

    #[test]
    fn loose_memory_model_reproduces_open_loop_latencies() {
        use crate::des::memory::{MemoryConfig, MemorySpec, PolicyKind};
        // Capacity far beyond what the compute cap can ever make
        // resident: admission never blocks on memory, nothing is
        // preempted, and every request fires exactly one arrival and
        // one MemCompletion — the same 2n event count, and identical
        // wait/TTFT/E2E values (memory mode computes them with the
        // same formulas, just committed at completion time).
        let (pools, router) = two_pool(a100(), 3, 3, 4096.0, 8192.0);
        let cfg =
            DesConfig { n_requests: 3_000, seed: 5, ..Default::default() };
        let w = azure(100.0);
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        let loose = MemoryConfig {
            spec: MemorySpec {
                hbm_gb: Some(10_000.0),
                weights_gb: 0.0,
                bytes_per_token: 1e3,
            },
            policy: PolicyKind::EvictRecompute,
            swap_out_ms: 0.0,
            swap_in_ms: 0.0,
        };
        let open = SimInput::stream(&pools, &router, &cfg, &sampled);
        let memful = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_memory(&loose);
        let mut a = Simulator::run_input(&open).unwrap();
        let mut b = Simulator::run_input(&memful).unwrap();
        assert_eq!(a.n_events, b.n_events);
        assert_eq!(a.horizon_ms, b.horizon_ms);
        assert_eq!(a.overall.count, b.overall.count);
        assert_eq!(a.overall.p99_ttft(), b.overall.p99_ttft());
        assert_eq!(a.overall.wait.p99(), b.overall.wait.p99());
        // E2E is committed at completion in memory mode
        // ((admit + hold) - arrival vs (admit - arrival) + hold), so
        // agreement is to float reassociation, not bitwise.
        let (ae, be) = (a.overall.e2e.p99(), b.overall.e2e.p99());
        assert!((ae - be).abs() < 1e-6, "{ae} vs {be}");
        assert_eq!(b.n_preempted, 0);
        assert_eq!(b.preempt_stall_ms, 0.0);
        assert!(b.kv_peak_util > 0.0 && b.kv_peak_util < 0.1);
        assert!(b.kv_mean_util > 0.0 && b.kv_mean_util < b.kv_peak_util);
        assert_eq!(a.n_preempted, 0);
        assert_eq!(a.kv_peak_util, 0.0);
    }

    #[test]
    fn tight_memory_with_eviction_preempts_and_conserves() {
        use crate::des::memory::PolicyKind;
        let pools = vec![SimPool {
            gpu: a100(), n_gpus: 2, ctx_budget: 8192.0, batch_cap: None,
        }];
        let router = RoutingPolicy::Random { n_pools: 1 };
        let cfg =
            DesConfig { n_requests: 2_000, seed: 17, ..Default::default() };
        let w = azure(60.0);
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        for policy in
            [PolicyKind::EvictRecompute, PolicyKind::EvictSwap]
        {
            let mc = tight_memory(policy);
            let input = SimInput::stream(&pools, &router, &cfg, &sampled)
                .with_memory(&mc);
            let mut r = Simulator::run_input(&input).unwrap();
            assert!(r.n_preempted > 0, "{policy:?}: no thrash");
            assert!(r.preempt_stall_ms > 0.0, "{policy:?}");
            assert_eq!(
                r.per_pool[0].n_preempted, r.n_preempted,
                "{policy:?}: single pool owns every eviction"
            );
            // Conservation: every request is served or stranded.
            assert_eq!(
                r.overall.count + r.n_unserved, 2_000,
                "{policy:?}"
            );
            // Occupancy never overflows while >= 2 residents share an
            // instance; a lone oversized resident may exceed 1.0.
            assert!(r.kv_peak_util > 0.5, "{policy:?}");
            assert!(
                r.kv_mean_util > 0.0 && r.kv_mean_util <= 1.0,
                "{policy:?}: mean {}", r.kv_mean_util
            );
            assert!(r.overall.p99_ttft() > 0.0);
            // Latency ordering survives preemption accounting.
            let (waits, ttfts, e2es) = (
                r.overall.wait.values(),
                r.overall.ttft.values(),
                r.overall.e2e.values(),
            );
            for i in 0..r.overall.count {
                assert!(waits[i] >= 0.0);
                assert!(ttfts[i] >= waits[i] - 1e-9, "{policy:?}");
                assert!(e2es[i] >= ttfts[i] - 1e-9, "{policy:?}");
            }
        }
    }

    #[test]
    fn no_preemption_policy_blocks_admission_and_never_overflows() {
        use crate::des::memory::PolicyKind;
        let pools = vec![SimPool {
            gpu: a100(), n_gpus: 2, ctx_budget: 8192.0, batch_cap: None,
        }];
        let router = RoutingPolicy::Random { n_pools: 1 };
        let cfg =
            DesConfig { n_requests: 2_000, seed: 17, ..Default::default() };
        let w = azure(60.0);
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        let mc = tight_memory(PolicyKind::None);
        let input = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_memory(&mc);
        let mut r = Simulator::run_input(&input).unwrap();
        assert_eq!(r.n_preempted, 0);
        assert_eq!(r.preempt_stall_ms, 0.0);
        // Peak reservation makes overflow structurally impossible.
        assert!(
            r.kv_peak_util <= 1.0 + 1e-12,
            "peak {}", r.kv_peak_util
        );
        assert_eq!(r.overall.count + r.n_unserved, 2_000);
        // Blocking admission queues harder than evicting: the same
        // workload waits at least as long as under recompute's
        // optimistic admission at the P50.
        assert!(r.overall.wait.p99() > 0.0);
        assert!(r.overall.p99_ttft() > 0.0);
    }

    #[test]
    fn memory_rejects_retry_combination_and_undersized_pools() {
        use crate::des::memory::{MemoryConfig, MemorySpec, PolicyKind};
        use crate::des::retry::{RetryConfig, RetrySpec};
        let (pools, router) = two_pool(a100(), 2, 2, 4096.0, 8192.0);
        let cfg = DesConfig::default();
        let sampled: Vec<crate::workload::spec::SampledRequest> = vec![];
        let mc = tight_memory(PolicyKind::EvictRecompute);
        let rc = RetryConfig {
            retry: Some(RetrySpec {
                max_attempts: 2,
                timeout_ms: 1e6,
                backoff_base_ms: 10.0,
                backoff_cap_ms: 40.0,
            }),
            admission: None,
        };
        let both = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_retries(&rc)
            .with_memory(&mc);
        let err = Simulator::run_input(&both).map(|_| ()).unwrap_err();
        assert!(matches!(err, ConfigError::InvalidMemory(_)));
        assert!(err.to_string().contains("retry"));
        // Capacity below the pool's context budget is caught up front.
        let tiny = MemoryConfig {
            spec: MemorySpec {
                hbm_gb: None,
                weights_gb: 79.999,
                bytes_per_token: 1e6,
            },
            policy: PolicyKind::None,
            swap_out_ms: 0.0,
            swap_in_ms: 0.0,
        };
        let input = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_memory(&tiny);
        let err = Simulator::run_input(&input).map(|_| ()).unwrap_err();
        assert!(matches!(err, ConfigError::InvalidMemory(_)));
    }

    #[test]
    fn stragglers_inflate_ttft_without_changing_counts() {
        use crate::des::faults::{FaultScript, Straggler};
        let pools = vec![SimPool {
            gpu: h100(), n_gpus: 2, ctx_budget: 8192.0, batch_cap: None,
        }];
        let router = RoutingPolicy::Random { n_pools: 1 };
        let cfg =
            DesConfig { n_requests: 4_000, seed: 21, ..Default::default() };
        let w = azure(30.0);
        let sampled = w.sample_requests(cfg.n_requests, cfg.seed);
        let base = Simulator::run_input(
            &SimInput::stream(&pools, &router, &cfg, &sampled),
        )
        .unwrap();
        let script = FaultScript {
            failures: vec![],
            stragglers: vec![Straggler {
                pool: 0,
                n_gpus: 2,
                start_ms: 0.0,
                end_ms: 1e12,
                factor: 4.0,
            }],
        };
        let input = SimInput::stream(&pools, &router, &cfg, &sampled)
            .with_faults(&script);
        let slow = Simulator::run_input(&input).unwrap();
        // Stragglers add no events (inflation is admission-time only).
        assert_eq!(slow.n_events, base.n_events);
        assert_eq!(slow.overall.count, base.overall.count);
        let (mut b, mut s) = (base.overall.clone(), slow.overall.clone());
        assert!(s.ttft.p99() > b.ttft.p99() * 2.0,
                "base {} straggler {}", b.ttft.p99(), s.ttft.p99());
    }
}
