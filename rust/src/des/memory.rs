//! KV-cache memory as a first-class simulated resource (ROADMAP
//! item 3, grounded in Nie et al.'s queueing-theoretic stability
//! analysis of LLM inference under KV memory constraints).
//!
//! The compute model bounds *concurrency* (KV slots at a context
//! budget); this module additionally bounds *token-granular occupancy*:
//! a request holds `L_in` token-slots of KV at admission and grows by
//! one token-slot per generated token, linearly over its hold (the
//! same service-time step model the engines already use). A fleet can
//! be compute-feasible yet memory-unstable under heavy-tailed lengths
//! — the "looks idle but is actually broken" failure class.
//!
//! # Protocol (shared bit-identically by all three engines)
//!
//! A [`MemoryConfig`] attaches to a `SimInput` via `with_memory`; not
//! attaching one keeps the open-loop path byte-identical (the PR-9
//! retries pattern). Per instance, a [`MemState`] ledger tracks
//! resident requests, their linear occupancy ramps, and a piecewise
//! trapezoid integral for mean-utilization reporting. Admission picks
//! the compute instance exactly like open-loop `try_admit`, then
//! applies the policy's memory test:
//!
//! * **no-preemption** reserves the projected *peak* (`L_in + L_out`)
//!   up front: admission blocks until the peak fits, and overflow is
//!   impossible (admission-block only, no new event kinds fire).
//! * **evict-recompute / evict-swap** admit optimistically when the
//!   *current* occupancy plus the request's base footprint (plus one
//!   token-slot of headroom, which keeps crossing times strictly
//!   positive) fits, and schedule a `MemPressure` event at the
//!   projected capacity-crossing instant. Pressure evicts the *newest*
//!   resident (LIFO, vLLM-style; the oldest is never evicted, which is
//!   what guarantees progress and termination): recompute victims
//!   requeue at the front and re-prefill from scratch; swap victims
//!   pay a fixed swap-out + swap-in latency and resume their remaining
//!   decode with their KV footprint restored.
//!
//! Stale events are cancelled by generation counters (per request, for
//! `MemCompletion`) and epochs (per instance, for `MemPressure`) —
//! never by deleting from the queue, so all three engines process the
//! identical event multiset. Latencies are committed at the *final*
//! completion: TTFT is re-staged if a victim lost its first token, so
//! `meets_slo` judges latency inclusive of preemption stalls.

use crate::des::engine::{eff_cap, CapWindow};
use crate::des::event::{CalendarQueue, EventKind, EventQueue};
use crate::des::faults::CompiledFaults;
use crate::des::input::ConfigError;
use crate::des::metrics::MetricsCollector;
use crate::des::pool::DesPool;
use crate::gpu::profile::GpuProfile;

/// Per-GPU HBM budget for KV cache, derived from the `gpu/` model.
#[derive(Debug, Clone, PartialEq)]
pub struct MemorySpec {
    /// Total HBM per GPU in GB; `None` uses the pool GPU's `vram_gb`.
    pub hbm_gb: Option<f64>,
    /// Resident model weights in GB, subtracted from the HBM budget.
    pub weights_gb: f64,
    /// KV-cache bytes per token (2 x layers x kv_heads x head_dim x
    /// dtype bytes for the served model).
    pub bytes_per_token: f64,
}

impl MemorySpec {
    /// KV capacity of one `gpu` instance, in token-slots.
    pub fn capacity_tokens(&self, gpu: &GpuProfile) -> f64 {
        let hbm = self.hbm_gb.unwrap_or(gpu.vram_gb);
        (((hbm - self.weights_gb).max(0.0) * 1e9) / self.bytes_per_token)
            .floor()
    }
}

/// What happens when projected occupancy crosses capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Admission-block only: reserve the projected peak up front.
    None,
    /// Evict the newest resident; it requeues and re-prefills.
    EvictRecompute,
    /// Evict the newest resident; it pays a fixed swap round-trip and
    /// resumes its remaining decode.
    EvictSwap,
}

impl PolicyKind {
    /// Trait-object dispatch for the policy's behavior flags — the one
    /// sanctioned bridge from config data to policy behavior (detlint
    /// R7 forbids string-typed policy entry points in this module).
    pub fn as_policy(&self) -> &'static dyn PreemptionPolicy {
        match self {
            PolicyKind::None => &NoPreemption,
            PolicyKind::EvictRecompute => &Recompute,
            PolicyKind::EvictSwap => &Swap,
        }
    }
}

/// Behavior of a preemption policy. The engines never branch on policy
/// *names*; they consume these flags through trait dispatch.
pub trait PreemptionPolicy {
    fn name(&self) -> &'static str;
    /// Reserve the projected peak at admission (overflow impossible).
    fn reserves_peak(&self) -> bool;
    /// Schedule pressure events and evict on capacity crossings.
    fn evicts(&self) -> bool;
    /// Victims keep their generated tokens (swap) instead of
    /// re-prefilling from scratch (recompute).
    fn preserves_progress(&self) -> bool;
}

/// Admission-block-only policy (`PolicyKind::None`).
pub struct NoPreemption;

impl PreemptionPolicy for NoPreemption {
    fn name(&self) -> &'static str {
        "none"
    }
    fn reserves_peak(&self) -> bool {
        true
    }
    fn evicts(&self) -> bool {
        false
    }
    fn preserves_progress(&self) -> bool {
        false
    }
}

/// Evict-and-recompute policy (`PolicyKind::EvictRecompute`).
pub struct Recompute;

impl PreemptionPolicy for Recompute {
    fn name(&self) -> &'static str {
        "evict-recompute"
    }
    fn reserves_peak(&self) -> bool {
        false
    }
    fn evicts(&self) -> bool {
        true
    }
    fn preserves_progress(&self) -> bool {
        false
    }
}

/// Evict-and-swap policy (`PolicyKind::EvictSwap`).
pub struct Swap;

impl PreemptionPolicy for Swap {
    fn name(&self) -> &'static str {
        "evict-swap"
    }
    fn reserves_peak(&self) -> bool {
        false
    }
    fn evicts(&self) -> bool {
        true
    }
    fn preserves_progress(&self) -> bool {
        true
    }
}

/// The KV-cache memory model attached to a `SimInput` via
/// `with_memory`. `None` (not attaching) keeps the open-loop
/// semantics bit-identically.
#[derive(Debug, Clone, PartialEq)]
pub struct MemoryConfig {
    pub spec: MemorySpec,
    pub policy: PolicyKind,
    /// Fixed swap-out latency per eviction (evict-swap only), ms.
    pub swap_out_ms: f64,
    /// Fixed swap-in latency per resume (evict-swap only), ms.
    pub swap_in_ms: f64,
}

impl MemoryConfig {
    /// Check the config against a fleet. Run automatically by every
    /// `SimInput`-based entry point when a config is attached.
    pub fn validate(
        &self,
        pools: &[crate::des::engine::SimPool],
    ) -> Result<(), ConfigError> {
        let bad = |msg: String| Err(ConfigError::InvalidMemory(msg));
        let s = &self.spec;
        if !(s.bytes_per_token.is_finite() && s.bytes_per_token > 0.0) {
            return bad(format!(
                "bytes_per_token {} must be finite and > 0",
                s.bytes_per_token
            ));
        }
        if !(s.weights_gb.is_finite() && s.weights_gb >= 0.0) {
            return bad(format!(
                "weights_gb {} must be finite and >= 0",
                s.weights_gb
            ));
        }
        if let Some(h) = s.hbm_gb {
            if !(h.is_finite() && h > 0.0) {
                return bad(format!("hbm_gb {h} must be finite and > 0"));
            }
        }
        for (label, v) in
            [("swap_out_ms", self.swap_out_ms), ("swap_in_ms", self.swap_in_ms)]
        {
            if !(v.is_finite() && v >= 0.0) {
                return bad(format!("{label} {v} must be finite and >= 0"));
            }
        }
        for (i, p) in pools.iter().enumerate() {
            let cap = s.capacity_tokens(&p.gpu);
            if cap < 1.0 {
                return bad(format!(
                    "pool {i}: KV capacity is {cap} tokens (weights \
                     exceed HBM?)"
                ));
            }
            if cap < p.ctx_budget {
                return bad(format!(
                    "pool {i}: KV capacity {cap} tokens is below the \
                     context budget {} (one max-context request cannot \
                     fit)",
                    p.ctx_budget
                ));
            }
        }
        Ok(())
    }

    /// Parse a memory config from the shipped TOML subset: a single
    /// `[memory]` section with `key = value` lines and `#` comments
    /// (see `data/memory/example.toml`). Hand-rolled like
    /// `RetryConfig::from_toml_str` — the build is offline and vendors
    /// no TOML crate.
    pub fn from_toml_str(text: &str) -> Result<Self, ConfigError> {
        enum Section {
            None,
            Memory,
        }
        let bad = |line: usize, msg: String| {
            Err(ConfigError::InvalidMemory(format!(
                "memory config line {line}: {msg}"
            )))
        };
        let mut seen = false;
        let mut cfg = MemoryConfig {
            spec: MemorySpec {
                hbm_gb: None,
                weights_gb: f64::NAN,
                bytes_per_token: f64::NAN,
            },
            policy: PolicyKind::None,
            swap_out_ms: 0.0,
            swap_in_ms: 0.0,
        };
        let mut section = Section::None;
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = match raw.split_once('#') {
                Some((head, _)) => head.trim(),
                None => raw.trim(),
            };
            if line.is_empty() {
                continue;
            }
            if let Some(name) =
                line.strip_prefix('[').and_then(|l| l.strip_suffix(']'))
            {
                section = match name.trim() {
                    "memory" => {
                        if seen {
                            return bad(
                                lineno,
                                "duplicate [memory] section".to_string(),
                            );
                        }
                        seen = true;
                        Section::Memory
                    }
                    other => {
                        return bad(
                            lineno,
                            format!("unknown section [{other}]"),
                        )
                    }
                };
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                return bad(lineno, format!("expected key = value: {line}"));
            };
            let (key, value) = (key.trim(), value.trim());
            let num = || -> Result<f64, ConfigError> {
                value.parse::<f64>().map_err(|_| {
                    ConfigError::InvalidMemory(format!(
                        "memory config line {lineno}: {key} = {value} is \
                         not a number"
                    ))
                })
            };
            match section {
                Section::None => {
                    return bad(
                        lineno,
                        format!("{key} outside the [memory] section"),
                    )
                }
                Section::Memory => match key {
                    "hbm_gb" => cfg.spec.hbm_gb = Some(num()?),
                    "weights_gb" => cfg.spec.weights_gb = num()?,
                    "bytes_per_token" => cfg.spec.bytes_per_token = num()?,
                    "policy" => {
                        let Some(kind) = parse_policy(value) else {
                            return bad(
                                lineno,
                                format!(
                                    "unknown policy {value} (expected \
                                     \"none\", \"evict-recompute\", or \
                                     \"evict-swap\")"
                                ),
                            );
                        };
                        cfg.policy = kind;
                    }
                    "swap_out_ms" => cfg.swap_out_ms = num()?,
                    "swap_in_ms" => cfg.swap_in_ms = num()?,
                    other => {
                        return bad(
                            lineno,
                            format!("unknown memory key {other}"),
                        )
                    }
                },
            }
        }
        if !seen {
            return Err(ConfigError::InvalidMemory(
                "a [memory] section is required".to_string(),
            ));
        }
        if cfg.spec.weights_gb.is_nan() {
            return Err(ConfigError::InvalidMemory(
                "[memory]: weights_gb is required".to_string(),
            ));
        }
        if cfg.spec.bytes_per_token.is_nan() {
            return Err(ConfigError::InvalidMemory(
                "[memory]: bytes_per_token is required".to_string(),
            ));
        }
        Ok(cfg)
    }
}

/// Config-string to policy mapping for the TOML loader. Quoted and
/// bare forms are both accepted.
fn parse_policy(value: &str) -> Option<PolicyKind> {
    match value.trim_matches('"') {
        "none" => Some(PolicyKind::None),
        "evict-recompute" => Some(PolicyKind::EvictRecompute),
        "evict-swap" => Some(PolicyKind::EvictSwap),
        _ => Option::None,
    }
}

/// The one scheduler operation the memory protocol needs, implemented
/// by both the production calendar queue and the reference heap — so
/// the whole protocol lives here once and all three engines share it
/// bit-identically.
pub(crate) trait EventSink {
    fn push_event(&mut self, time_ms: f64, kind: EventKind);
}

impl EventSink for CalendarQueue {
    fn push_event(&mut self, time_ms: f64, kind: EventKind) {
        self.push(time_ms, kind);
    }
}

impl EventSink for EventQueue {
    fn push_event(&mut self, time_ms: f64, kind: EventKind) {
        self.push(time_ms, kind);
    }
}

/// Per-request memory-mode run state, indexed by the engine's request
/// id (the serial arena index, or the sharded executor's recycled
/// arena slot). `gen` is never reset — it outlives slot recycling, so
/// a stale `MemCompletion` from a previous occupant can never match.
#[derive(Debug, Clone)]
struct MemRun {
    arrival_ms: f64,
    l_in: f64,
    l_out: f64,
    /// Decode tokens completed in prior legs (swap resume state).
    g_done: f64,
    /// First-admission wait; NaN until first admitted.
    wait0_ms: f64,
    /// Staged TTFT against the original arrival; NaN until the first
    /// token is (projected to be) produced; un-staged if an eviction
    /// lands before `first_token_ms`.
    ttft_ms: f64,
    first_token_ms: f64,
    /// When the request was last evicted; NaN while resident/queued.
    evict_ms: f64,
    admit_ms: f64,
    /// Occupancy at the current leg's admission, token-slots.
    base: f64,
    /// Occupancy growth this leg, token-slots per ms.
    rate: f64,
    hold_ms: f64,
    admitted_before: bool,
    gen: u32,
}

impl MemRun {
    fn fresh() -> Self {
        MemRun {
            arrival_ms: 0.0,
            l_in: 0.0,
            l_out: 0.0,
            g_done: 0.0,
            wait0_ms: f64::NAN,
            ttft_ms: f64::NAN,
            first_token_ms: f64::NAN,
            evict_ms: f64::NAN,
            admit_ms: 0.0,
            base: 0.0,
            rate: 0.0,
            hold_ms: 0.0,
            admitted_before: false,
            gen: 0,
        }
    }
}

/// Per-instance occupancy ledger: resident set (admission order),
/// piecewise-linear occupancy, trapezoid token-ms integral, and the
/// epoch that cancels stale pressure events.
#[derive(Debug, Clone)]
struct MemInstance {
    cap: f64,
    residents: Vec<u32>,
    occ: f64,
    rate: f64,
    last_ms: f64,
    epoch: u64,
    token_ms: f64,
    peak: f64,
    /// Peak-reservation bookkeeping (no-preemption policy only).
    reserved: f64,
}

impl MemInstance {
    fn new(cap: f64) -> Self {
        MemInstance {
            cap,
            residents: Vec::new(),
            occ: 0.0,
            rate: 0.0,
            last_ms: 0.0,
            epoch: 0,
            token_ms: 0.0,
            peak: 0.0,
            reserved: 0.0,
        }
    }

    /// Advance the ledger to `now`: occupancy is linear between
    /// events, so the token-ms integral over the elapsed segment is
    /// the exact trapezoid.
    fn rebase(&mut self, now: f64) {
        let dt = now - self.last_ms;
        if dt > 0.0 {
            self.token_ms += dt * (self.occ + 0.5 * self.rate * dt);
            self.occ += self.rate * dt;
            self.last_ms = now;
            self.peak = self.peak.max(self.occ);
        }
    }
}

/// Raw per-pool memory aggregates, assembled identically by the
/// serial, reference, and sharded result paths (the sharded merge
/// moves each pool's values from its owner shard, so the final f64
/// arithmetic is shared and bit-identical).
#[derive(Debug, Clone, Default)]
pub(crate) struct MemPoolRaw {
    pub(crate) token_ms: f64,
    pub(crate) peak_frac: f64,
    pub(crate) cap_slots: f64,
    pub(crate) n_preempted: usize,
    pub(crate) stall_ms: f64,
}

/// Fleet-level memory metrics from per-pool raws, in pool-index order.
/// Returns `(kv_peak_util, kv_mean_util, n_preempted,
/// preempt_stall_ms)`. Shared by all three result paths.
pub(crate) fn overall_from_raw(
    raw: &[MemPoolRaw],
    horizon_ms: f64,
) -> (f64, f64, usize, f64) {
    let mut peak = 0.0f64;
    let mut token_ms = 0.0f64;
    let mut cap_slots = 0.0f64;
    let mut n_preempted = 0usize;
    let mut stall = 0.0f64;
    for r in raw {
        peak = peak.max(r.peak_frac);
        token_ms += r.token_ms;
        cap_slots += r.cap_slots;
        n_preempted += r.n_preempted;
        stall += r.stall_ms;
    }
    let mean = if horizon_ms > 0.0 && cap_slots > 0.0 {
        token_ms / (horizon_ms * cap_slots)
    } else {
        0.0
    };
    (peak, mean, n_preempted, stall)
}

/// Per-pool memory metrics from one pool's raw aggregates. Returns
/// `(kv_peak_util, kv_mean_util)`.
pub(crate) fn pool_util_from_raw(
    raw: &MemPoolRaw,
    horizon_ms: f64,
) -> (f64, f64) {
    let mean = if horizon_ms > 0.0 && raw.cap_slots > 0.0 {
        raw.token_ms / (horizon_ms * raw.cap_slots)
    } else {
        0.0
    };
    (raw.peak_frac, mean)
}

/// The shared memory-protocol state machine. One per run; engines call
/// into it at arrivals, completions, pressure events, and drains. All
/// scheduling goes through [`EventSink`], so the production calendar
/// queue and the reference heap execute the identical protocol.
pub(crate) struct MemState {
    reserves_peak: bool,
    evicts: bool,
    preserves_progress: bool,
    swap_out_ms: f64,
    swap_in_ms: f64,
    /// `insts[pool][instance]` occupancy ledgers.
    insts: Vec<Vec<MemInstance>>,
    runs: Vec<MemRun>,
    n_preempted: Vec<usize>,
    stall_ms: Vec<f64>,
}

impl MemState {
    pub(crate) fn new(cfg: &MemoryConfig, pools: &[DesPool]) -> Self {
        let policy = cfg.policy.as_policy();
        MemState {
            reserves_peak: policy.reserves_peak(),
            evicts: policy.evicts(),
            preserves_progress: policy.preserves_progress(),
            swap_out_ms: cfg.swap_out_ms,
            swap_in_ms: cfg.swap_in_ms,
            insts: pools
                .iter()
                .map(|p| {
                    let cap = cfg.spec.capacity_tokens(&p.gpu);
                    (0..p.instances.len())
                        .map(|_| MemInstance::new(cap))
                        .collect()
                })
                .collect(),
            runs: Vec::new(),
            n_preempted: vec![0; pools.len()],
            stall_ms: vec![0.0; pools.len()],
        }
    }

    /// Register (or re-register, on a recycled arena slot) a routed
    /// request. Everything resets except `gen`, which must outlive
    /// slot recycling to keep stale-event cancellation sound.
    pub(crate) fn init_request(
        &mut self,
        req: u32,
        l_in: f64,
        l_out: f64,
        arrival_ms: f64,
    ) {
        let i = req as usize;
        if self.runs.len() <= i {
            self.runs.resize_with(i + 1, MemRun::fresh);
        }
        let gen = self.runs[i].gen;
        let mut run = MemRun::fresh();
        run.gen = gen;
        run.arrival_ms = arrival_ms;
        run.l_in = l_in;
        run.l_out = l_out;
        self.runs[i] = run;
    }

    /// Try to admit `req` to `pool_idx` at `now`: the open-loop
    /// compute scan (least-loaded instance under the effective cap,
    /// skipping faulted-down instances) followed by the policy's
    /// memory test on the chosen instance. Latencies are *not*
    /// recorded here — they commit at the final completion.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn try_admit<E: EventSink>(
        &mut self,
        pools: &mut [DesPool],
        pool_idx: usize,
        req: u32,
        now: f64,
        events: &mut E,
        cap_window: &Option<CapWindow>,
        faults: Option<&CompiledFaults>,
    ) -> bool {
        let eff = eff_cap(cap_window, &pools[pool_idx], now);
        let pool = &mut pools[pool_idx];
        let mut best: Option<(usize, u32)> = None;
        for (i, inst) in pool.instances.iter().enumerate() {
            if faults.is_some_and(|f| f.is_down(pool_idx, i, now)) {
                continue;
            }
            if inst.busy < eff {
                let free = eff - inst.busy;
                if best.map_or(true, |(_, bf)| free > bf) {
                    best = Some((i, free));
                }
            }
        }
        let Some((inst, _)) = best else { return false };
        let (resumed, base, need) = {
            let run = &self.runs[req as usize];
            let resumed = self.preserves_progress && run.admitted_before;
            let base = if resumed {
                run.l_in + run.g_done
            } else {
                run.l_in
            };
            (resumed, base, run.l_in + run.l_out)
        };
        {
            let m = &mut self.insts[pool_idx][inst];
            m.rebase(now);
            let fits = if self.reserves_peak {
                m.reserved + need <= m.cap
            } else {
                // One token-slot of headroom keeps the next crossing
                // strictly after `now` (no zero-dt pressure loops).
                m.occ + base + 1.0 <= m.cap
            };
            if !fits {
                return false;
            }
        }
        pool.acquire(inst, now);
        let n_at_admit = pool.instances[inst].busy as f64;
        let slow = faults.map_or(1.0, |f| f.slowdown(pool_idx, inst, now));
        let t_iter = pool.gpu.t_iter(n_at_admit) * slow;
        let gen;
        let hold;
        {
            let run = &mut self.runs[req as usize];
            let (pre_ms, leg_tokens, leg_hold) = if resumed {
                // Swap resume: KV (prompt + produced tokens) returns
                // via a fixed swap round-trip; only the remaining
                // decode runs, with no re-prefill.
                let left = (run.l_out - run.g_done).max(1.0);
                let pre = self.swap_out_ms + self.swap_in_ms;
                (pre, left, pre + left * t_iter)
            } else {
                let pre = (run.l_in / pool.gpu.chunk).ceil() * t_iter;
                (
                    pre,
                    run.l_out.max(1.0),
                    pool.gpu.iters(run.l_in, run.l_out) * t_iter,
                )
            };
            if run.wait0_ms.is_nan() {
                run.wait0_ms = now - run.arrival_ms;
            }
            if run.ttft_ms.is_nan() {
                run.ttft_ms = (now - run.arrival_ms) + pre_ms + t_iter;
                run.first_token_ms = run.arrival_ms + run.ttft_ms;
            }
            if run.evict_ms.is_finite() {
                let stall = (now - run.evict_ms)
                    + if resumed {
                        self.swap_out_ms + self.swap_in_ms
                    } else {
                        0.0
                    };
                self.stall_ms[pool_idx] += stall;
                run.evict_ms = f64::NAN;
            }
            run.admitted_before = true;
            run.admit_ms = now;
            run.base = base;
            run.rate = leg_tokens / leg_hold;
            run.hold_ms = leg_hold;
            gen = run.gen;
            hold = leg_hold;
        }
        events.push_event(
            now + hold,
            EventKind::MemCompletion {
                req,
                pool: pool_idx as u16,
                instance: inst as u16,
                gen,
            },
        );
        {
            let run_rate = self.runs[req as usize].rate;
            let m = &mut self.insts[pool_idx][inst];
            m.residents.push(req);
            m.occ += base;
            m.rate += run_rate;
            m.peak = m.peak.max(m.occ);
            if self.reserves_peak {
                m.reserved += need;
            }
            m.epoch += 1;
        }
        self.schedule_pressure(pool_idx, inst, now, events);
        true
    }

    /// Admit queued requests while compute *and* memory allow (FIFO:
    /// a blocked head blocks the queue — head-of-line semantics,
    /// matching the open-loop drain).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn drain<E: EventSink>(
        &mut self,
        pools: &mut [DesPool],
        pool_idx: usize,
        now: f64,
        events: &mut E,
        cap_window: &Option<CapWindow>,
        faults: Option<&CompiledFaults>,
    ) {
        while let Some(&head) = pools[pool_idx].queue.front() {
            if !self.try_admit(
                pools, pool_idx, head, now, events, cap_window, faults,
            ) {
                break;
            }
            pools[pool_idx].queue.pop_front();
        }
    }

    /// Commit a `MemCompletion`. Returns `false` (and touches
    /// nothing) when the event is stale — its `gen` was invalidated by
    /// an eviction or a recycled slot.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn on_completion<E: EventSink>(
        &mut self,
        pools: &mut [DesPool],
        pool_idx: usize,
        inst: usize,
        req: u32,
        gen: u32,
        now: f64,
        events: &mut E,
        cap_window: &Option<CapWindow>,
        faults: Option<&CompiledFaults>,
        metrics: &mut MetricsCollector,
    ) -> bool {
        if self.runs[req as usize].gen != gen {
            return false;
        }
        pools[pool_idx].release(inst, now);
        let (arrival, wait0, ttft) = {
            let run = &mut self.runs[req as usize];
            let contrib = run.base + run.rate * (now - run.admit_ms);
            let need = run.l_in + run.l_out;
            let m = &mut self.insts[pool_idx][inst];
            m.rebase(now);
            m.occ -= contrib;
            m.rate -= run.rate;
            if let Some(pos) = m.residents.iter().position(|&r| r == req) {
                m.residents.remove(pos);
            }
            if self.reserves_peak {
                m.reserved -= need;
            }
            if m.residents.is_empty() {
                // Snap to empty: keeps float drift out of the ledger.
                m.occ = 0.0;
                m.rate = 0.0;
            }
            m.epoch += 1;
            // Pre-invalidate before any slot recycling can re-arm it.
            run.gen = run.gen.wrapping_add(1);
            (run.arrival_ms, run.wait0_ms, run.ttft_ms)
        };
        metrics.record(pool_idx, arrival, wait0, ttft, now - arrival);
        self.schedule_pressure(pool_idx, inst, now, events);
        self.drain(pools, pool_idx, now, events, cap_window, faults);
        true
    }

    /// Handle a `MemPressure` crossing: stale-epoch events no-op; a
    /// live crossing evicts the newest resident (never the sole or
    /// oldest one — the oldest always runs to completion, which is
    /// what rules out livelock).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn on_pressure<E: EventSink>(
        &mut self,
        pools: &mut [DesPool],
        pool_idx: usize,
        inst: usize,
        epoch: u64,
        now: f64,
        events: &mut E,
        cap_window: &Option<CapWindow>,
        faults: Option<&CompiledFaults>,
        metrics: &mut MetricsCollector,
    ) {
        {
            let m = &self.insts[pool_idx][inst];
            if m.epoch != epoch || m.residents.len() < 2 {
                return;
            }
        }
        let victim = *self.insts[pool_idx][inst]
            .residents
            .last()
            .expect("len >= 2");
        let arrival = {
            let run = &mut self.runs[victim as usize];
            let contrib = run.base + run.rate * (now - run.admit_ms);
            let produced = (run.rate * (now - run.admit_ms)).floor().max(0.0);
            let m = &mut self.insts[pool_idx][inst];
            m.rebase(now);
            m.residents.pop();
            m.occ -= contrib;
            m.rate -= run.rate;
            m.epoch += 1;
            run.g_done = if self.preserves_progress {
                (run.g_done + produced).min(run.l_out)
            } else {
                0.0
            };
            // Cancels the victim's pending completion.
            run.gen = run.gen.wrapping_add(1);
            if now < run.first_token_ms {
                // First token lost: TTFT re-stages at re-admission.
                run.ttft_ms = f64::NAN;
            }
            run.evict_ms = now;
            run.arrival_ms
        };
        pools[pool_idx].release(inst, now);
        self.n_preempted[pool_idx] += 1;
        metrics.record_preempted(arrival);
        // Victims requeue at the *front*: they re-admit before newer
        // queued work (FIFO fairness under preemption).
        let pool = &mut pools[pool_idx];
        pool.queue.push_front(victim);
        pool.max_queue_depth = pool.max_queue_depth.max(pool.queue.len());
        self.schedule_pressure(pool_idx, inst, now, events);
        self.drain(pools, pool_idx, now, events, cap_window, faults);
    }

    /// Schedule the next capacity-crossing event for an instance, if a
    /// genuine crossing can precede the instance's next completion
    /// (later crossings are rescheduled by the completion itself, so
    /// pushing them would only queue guaranteed-stale events and
    /// stretch the horizon).
    fn schedule_pressure<E: EventSink>(
        &mut self,
        pool_idx: usize,
        inst: usize,
        now: f64,
        events: &mut E,
    ) {
        if !self.evicts {
            return;
        }
        let runs = &self.runs;
        let m = &self.insts[pool_idx][inst];
        if m.residents.len() < 2 || m.rate <= 0.0 {
            return;
        }
        let headroom = m.cap - m.occ;
        let t_cross = if headroom <= 0.0 {
            now
        } else {
            now + headroom / m.rate
        };
        let mut next_completion = f64::INFINITY;
        for &r in &m.residents {
            let done =
                runs[r as usize].admit_ms + runs[r as usize].hold_ms;
            if done < next_completion {
                next_completion = done;
            }
        }
        if t_cross >= next_completion {
            return;
        }
        events.push_event(
            t_cross.max(now),
            EventKind::MemPressure {
                pool: pool_idx as u16,
                instance: inst as u16,
                epoch: m.epoch,
            },
        );
    }

    /// Raw per-pool aggregates for result assembly (pool-index order).
    pub(crate) fn pool_raw(&self, p: usize) -> MemPoolRaw {
        let mut token_ms = 0.0;
        let mut peak_frac = 0.0f64;
        let mut cap_slots = 0.0;
        for m in &self.insts[p] {
            token_ms += m.token_ms;
            if m.cap > 0.0 {
                peak_frac = peak_frac.max(m.peak / m.cap);
            }
            cap_slots += m.cap;
        }
        MemPoolRaw {
            token_ms,
            peak_frac,
            cap_slots,
            n_preempted: self.n_preempted[p],
            stall_ms: self.stall_ms[p],
        }
    }

    /// All pools' raw aggregates, in pool-index order.
    pub(crate) fn raws(&self) -> Vec<MemPoolRaw> {
        (0..self.insts.len()).map(|p| self.pool_raw(p)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::engine::SimPool;
    use crate::gpu::catalog::GpuCatalog;

    fn a100() -> GpuProfile {
        GpuCatalog::standard().get("A100").unwrap().clone()
    }

    fn spec() -> MemorySpec {
        MemorySpec {
            hbm_gb: None,
            weights_gb: 60.0,
            bytes_per_token: 160_000.0,
        }
    }

    fn pools() -> Vec<SimPool> {
        vec![SimPool {
            gpu: a100(),
            n_gpus: 2,
            ctx_budget: 8192.0,
            batch_cap: None,
        }]
    }

    #[test]
    fn capacity_derives_from_the_gpu_model() {
        // A100: 80 GB - 60 GB weights = 20 GB / 160 KB per token.
        let cap = spec().capacity_tokens(&a100());
        assert_eq!(cap, 125_000.0);
        // Explicit HBM overrides the catalog vram_gb.
        let s = MemorySpec { hbm_gb: Some(100.0), ..spec() };
        assert_eq!(s.capacity_tokens(&a100()), 250_000.0);
        // Weights exceeding HBM clamp to zero capacity.
        let s = MemorySpec { weights_gb: 200.0, ..spec() };
        assert_eq!(s.capacity_tokens(&a100()), 0.0);
    }

    #[test]
    fn policy_flags_dispatch_through_the_trait() {
        let none = PolicyKind::None.as_policy();
        assert_eq!(none.name(), "none");
        assert!(none.reserves_peak() && !none.evicts());
        let rc = PolicyKind::EvictRecompute.as_policy();
        assert_eq!(rc.name(), "evict-recompute");
        assert!(rc.evicts() && !rc.preserves_progress());
        let sw = PolicyKind::EvictSwap.as_policy();
        assert_eq!(sw.name(), "evict-swap");
        assert!(sw.evicts() && sw.preserves_progress());
    }

    #[test]
    fn validate_rejects_bad_specs() {
        let fleet = pools();
        let ok = MemoryConfig {
            spec: spec(),
            policy: PolicyKind::EvictRecompute,
            swap_out_ms: 0.0,
            swap_in_ms: 0.0,
        };
        assert!(ok.validate(&fleet).is_ok());
        let mut bad = ok.clone();
        bad.spec.bytes_per_token = 0.0;
        assert!(matches!(
            bad.validate(&fleet),
            Err(ConfigError::InvalidMemory(_))
        ));
        let mut bad = ok.clone();
        bad.spec.weights_gb = -1.0;
        assert!(bad.validate(&fleet).is_err());
        let mut bad = ok.clone();
        bad.swap_in_ms = f64::NAN;
        assert!(bad.validate(&fleet).is_err());
        // Capacity below one max-context request is a config error,
        // not a silent livelock.
        let mut bad = ok.clone();
        bad.spec.weights_gb = 79.9;
        let err = bad.validate(&fleet).unwrap_err();
        assert!(err.to_string().contains("context budget"));
    }

    #[test]
    fn toml_round_trips_the_full_section() {
        let text = "\
# KV memory model\n\
[memory]\n\
hbm_gb = 80.0  # override\n\
weights_gb = 60.0\n\
bytes_per_token = 160000.0\n\
policy = \"evict-swap\"\n\
swap_out_ms = 3.0\n\
swap_in_ms = 5.0\n";
        let c = MemoryConfig::from_toml_str(text).unwrap();
        assert_eq!(c.spec.hbm_gb, Some(80.0));
        assert_eq!(c.spec.weights_gb, 60.0);
        assert_eq!(c.spec.bytes_per_token, 160_000.0);
        assert_eq!(c.policy, PolicyKind::EvictSwap);
        assert_eq!(c.swap_out_ms, 3.0);
        assert_eq!(c.swap_in_ms, 5.0);
    }

    #[test]
    fn toml_defaults_policy_and_swap_latencies() {
        let c = MemoryConfig::from_toml_str(
            "[memory]\nweights_gb = 10\nbytes_per_token = 1e5\n",
        )
        .unwrap();
        assert_eq!(c.policy, PolicyKind::None);
        assert_eq!(c.spec.hbm_gb, None);
        assert_eq!(c.swap_out_ms, 0.0);
        assert_eq!(c.swap_in_ms, 0.0);
        for p in ["none", "evict-recompute", "evict-swap"] {
            let text = format!(
                "[memory]\nweights_gb = 1\nbytes_per_token = 1\n\
                 policy = {p}\n"
            );
            assert!(MemoryConfig::from_toml_str(&text).is_ok(), "{p}");
        }
    }

    #[test]
    fn toml_rejects_malformed_input() {
        for bad in [
            "weights_gb = 1",                       // unsectioned key
            "[explosion]",                          // unknown section
            "[memory]\nweights_gb = much",          // non-number
            "[memory]\n[memory]",                   // duplicate section
            "[memory]\nwat = 1",                    // unknown key
            "[memory]\npolicy = \"drop-tables\"",   // unknown policy
            "[memory]\nweights_gb = 1",             // missing bytes/token
            "[memory]\nbytes_per_token = 1",        // missing weights
            "",                                     // no section at all
        ] {
            let err = MemoryConfig::from_toml_str(bad).unwrap_err();
            assert!(
                matches!(err, ConfigError::InvalidMemory(_)),
                "{bad:?} -> {err}"
            );
        }
    }

    #[test]
    fn ledger_integrates_linear_occupancy_exactly() {
        let mut m = MemInstance::new(1000.0);
        // One resident: base 100, rate 2 tokens/ms for 50 ms.
        m.occ = 100.0;
        m.rate = 2.0;
        m.peak = 100.0;
        m.rebase(50.0);
        // Trapezoid: 50 * (100 + 0.5*2*50) = 50 * 150 = 7500.
        assert_eq!(m.token_ms, 7_500.0);
        assert_eq!(m.occ, 200.0);
        assert_eq!(m.peak, 200.0);
        // Zero-dt rebase is a no-op (no drift).
        m.rebase(50.0);
        assert_eq!(m.token_ms, 7_500.0);
    }

    #[test]
    fn overall_raws_aggregate_in_pool_order() {
        let raw = vec![
            MemPoolRaw {
                token_ms: 1_000.0,
                peak_frac: 0.5,
                cap_slots: 10.0,
                n_preempted: 3,
                stall_ms: 40.0,
            },
            MemPoolRaw {
                token_ms: 3_000.0,
                peak_frac: 0.9,
                cap_slots: 30.0,
                n_preempted: 1,
                stall_ms: 2.0,
            },
        ];
        let (peak, mean, n, stall) = overall_from_raw(&raw, 100.0);
        assert_eq!(peak, 0.9);
        assert_eq!(mean, 4_000.0 / (100.0 * 40.0));
        assert_eq!(n, 4);
        assert_eq!(stall, 42.0);
        let (p0, m0) = pool_util_from_raw(&raw[0], 100.0);
        assert_eq!(p0, 0.5);
        assert_eq!(m0, 1.0);
        // Degenerate horizons report zero, not NaN.
        assert_eq!(overall_from_raw(&raw, 0.0).1, 0.0);
        assert_eq!(overall_from_raw(&[], 100.0), (0.0, 0.0, 0, 0.0));
    }
}
