//! Min-heap event queue for the DES (paper §3.1: "each pool runs n GPU
//! instances, each simulating continuous batching with a min-heap event
//! queue").

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Event payloads. Request ids index the simulator's request table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A request hits the router.
    Arrival { req: u32 },
    /// A request finishes service and frees its slot.
    Completion { req: u32, pool: u16, instance: u16 },
    /// A batch-cap window boundary: re-examine the pool's queue (grid-flex
    /// short events restore capacity without a completion to trigger it).
    Drain { pool: u16 },
}

/// A timestamped event. Earlier `time_ms` pops first; ties break on a
/// monotonically increasing sequence number so ordering is deterministic.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub time_ms: f64,
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time_ms == other.time_ms && self.seq == other.seq
    }
}
impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap semantics inside BinaryHeap (a max-heap).
        other
            .time_ms
            .partial_cmp(&self.time_ms)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic min-heap event queue.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    next_seq: u64,
}

impl EventQueue {
    pub fn with_capacity(n: usize) -> Self {
        EventQueue { heap: BinaryHeap::with_capacity(n), next_seq: 0 }
    }

    pub fn push(&mut self, time_ms: f64, kind: EventKind) {
        debug_assert!(time_ms.is_finite());
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { time_ms, seq, kind });
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    pub fn peek(&self) -> Option<&Event> {
        self.heap.peek()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::default();
        q.push(5.0, EventKind::Arrival { req: 0 });
        q.push(1.0, EventKind::Arrival { req: 1 });
        q.push(3.0, EventKind::Arrival { req: 2 });
        let order: Vec<f64> = std::iter::from_fn(|| q.pop().map(|e| e.time_ms))
            .collect();
        assert_eq!(order, vec![1.0, 3.0, 5.0]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::default();
        q.push(2.0, EventKind::Arrival { req: 10 });
        q.push(2.0, EventKind::Arrival { req: 11 });
        q.push(2.0, EventKind::Arrival { req: 12 });
        let reqs: Vec<u32> = std::iter::from_fn(|| {
            q.pop().map(|e| match e.kind {
                EventKind::Arrival { req } => req,
                _ => unreachable!(),
            })
        })
        .collect();
        assert_eq!(reqs, vec![10, 11, 12]);
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::default();
        q.push(10.0, EventKind::Arrival { req: 0 });
        q.push(1.0, EventKind::Arrival { req: 1 });
        assert_eq!(q.pop().unwrap().time_ms, 1.0);
        q.push(0.5, EventKind::Completion { req: 1, pool: 0, instance: 0 });
        assert_eq!(q.pop().unwrap().time_ms, 0.5);
        assert_eq!(q.pop().unwrap().time_ms, 10.0);
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn large_volume_stays_sorted() {
        let mut q = EventQueue::with_capacity(10_000);
        let mut rng = crate::workload::rng::Pcg64::new(3, 0);
        for i in 0..10_000 {
            q.push(rng.uniform() * 1e6, EventKind::Arrival { req: i });
        }
        let mut prev = -1.0;
        while let Some(e) = q.pop() {
            assert!(e.time_ms >= prev);
            prev = e.time_ms;
        }
    }
}
