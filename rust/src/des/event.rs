//! Event scheduling for the DES.
//!
//! Two schedulers share the [`Event`] type:
//!
//! * [`EventQueue`] — the original `BinaryHeap` min-heap. O(log n) per
//!   operation. Kept as the *reference* scheduler: the all-events-heap
//!   reference simulator ([`crate::des::reference`]) and the regression
//!   suite pin the production engine against it bit-for-bit.
//! * [`CalendarQueue`] — a classic calendar queue (Brown 1988): events
//!   hash into `width`-ms day buckets; pop scans only the current day.
//!   With the self-tuning resize keeping ~1 event per bucket, push and
//!   pop are O(1) amortized, which is what lets the production engine
//!   sustain much higher event volumes than the heap. Pops follow the
//!   exact same total order as the heap — `(time_ms, seq)` — so the two
//!   schedulers are interchangeable bit-for-bit.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Event payloads. Request ids index the simulator's request arena.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A request hits the router.
    Arrival { req: u32 },
    /// A request finishes service and frees its slot.
    Completion { req: u32, pool: u16, instance: u16 },
    /// A capacity-restoring boundary — a batch-cap window ending or a
    /// failed instance recovering ([`crate::des::faults`]): re-examine
    /// the pool's queue (capacity returned without a completion to
    /// trigger it).
    Drain { pool: u16 },
    /// Closed-loop only ([`crate::des::retry`]): the client deadline
    /// of `req`'s attempt number `attempt` expires. Stale once the
    /// request completed or moved on to a later attempt.
    Timeout { req: u32, pool: u16, attempt: u32 },
    /// Closed-loop only: `req`'s backoff ends; start its next attempt
    /// against the same pool.
    Retry { req: u32, pool: u16 },
    /// Memory-mode only ([`crate::des::memory`]): a request's current
    /// service leg completes. Stale once `gen` no longer matches the
    /// request's generation (the leg was preempted).
    MemCompletion { req: u32, pool: u16, instance: u16, gen: u32 },
    /// Memory-mode only: the instance's projected KV occupancy crosses
    /// capacity. Stale once `epoch` no longer matches (any admission,
    /// completion, or eviction bumps the instance epoch).
    MemPressure { pool: u16, instance: u16, epoch: u64 },
}

/// A timestamped event. Earlier `time_ms` pops first; ties break on a
/// monotonically increasing sequence number so ordering is deterministic.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    pub time_ms: f64,
    pub seq: u64,
    pub kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time_ms == other.time_ms && self.seq == other.seq
    }
}
impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap semantics inside BinaryHeap (a max-heap).
        other
            .time_ms
            .partial_cmp(&self.time_ms)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic min-heap event queue (the reference scheduler).
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Event>,
    next_seq: u64,
}

impl EventQueue {
    pub fn with_capacity(n: usize) -> Self {
        EventQueue { heap: BinaryHeap::with_capacity(n), next_seq: 0 }
    }

    pub fn push(&mut self, time_ms: f64, kind: EventKind) {
        debug_assert!(time_ms.is_finite());
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Event { time_ms, seq, kind });
    }

    pub fn pop(&mut self) -> Option<Event> {
        self.heap.pop()
    }

    pub fn peek(&self) -> Option<&Event> {
        self.heap.peek()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

/// A bucket entry: the event plus its precomputed absolute day index
/// (`floor(time_ms / width)`), so the pop scan compares integers instead
/// of re-deriving float boundaries.
#[derive(Debug, Clone, Copy)]
struct CalEntry {
    day: u64,
    ev: Event,
}

/// Smallest bucket width the resize estimator will pick, ms.
const MIN_WIDTH: f64 = 1e-6;
/// Bucket-count bounds (powers of two).
const MIN_BUCKETS: usize = 16;
const MAX_BUCKETS: usize = 1 << 20;

/// Deterministic calendar queue with the same `(time_ms, seq)` pop order
/// as [`EventQueue`].
///
/// Invariant: no queued entry has `day < vday` — `push` rewinds the
/// cursor when an earlier event arrives, and the cursor only advances
/// past days proven empty. Within one day all candidates live in a single
/// bucket, so the per-day min scan yields the global minimum.
#[derive(Debug)]
pub struct CalendarQueue {
    buckets: Vec<Vec<CalEntry>>,
    /// `buckets.len() - 1`; the bucket count is a power of two.
    mask: usize,
    /// Bucket width in ms (re-estimated on resize).
    width: f64,
    /// Absolute (un-wrapped) day index the cursor is scanning.
    vday: u64,
    len: usize,
    next_seq: u64,
    /// Cached `(bucket, position)` of the current minimum, valid until the
    /// next push / pop / resize.
    cached_min: Option<(usize, usize)>,
}

impl Default for CalendarQueue {
    fn default() -> Self {
        Self::with_capacity(0)
    }
}

impl CalendarQueue {
    /// `capacity` is a hint for the expected steady-state queue length.
    pub fn with_capacity(capacity: usize) -> Self {
        let n_buckets = capacity
            .next_power_of_two()
            .clamp(MIN_BUCKETS, MAX_BUCKETS);
        CalendarQueue {
            buckets: vec![Vec::new(); n_buckets],
            mask: n_buckets - 1,
            width: 1.0,
            vday: 0,
            len: 0,
            next_seq: 0,
            cached_min: None,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn day_of(&self, time_ms: f64) -> u64 {
        // Non-negative finite / positive width: the cast saturates safely.
        (time_ms / self.width) as u64
    }

    pub fn push(&mut self, time_ms: f64, kind: EventKind) {
        debug_assert!(time_ms.is_finite() && time_ms >= 0.0);
        let seq = self.next_seq;
        self.next_seq += 1;
        let entry = CalEntry {
            day: self.day_of(time_ms),
            ev: Event { time_ms, seq, kind },
        };
        self.insert(entry);
        if self.len > 2 * (self.mask + 1) && self.mask + 1 < MAX_BUCKETS {
            self.resize();
        }
    }

    fn insert(&mut self, entry: CalEntry) {
        if entry.day < self.vday {
            // An earlier event arrived: rewind the cursor to its day.
            self.vday = entry.day;
        }
        self.cached_min = None;
        let b = (entry.day & self.mask as u64) as usize;
        self.buckets[b].push(entry);
        self.len += 1;
    }

    /// Time of the earliest queued event without removing it.
    pub fn next_time(&mut self) -> Option<f64> {
        if self.len == 0 {
            return None;
        }
        let (b, i) = match self.cached_min {
            Some(loc) => loc,
            None => {
                let loc = self.locate_min();
                self.cached_min = Some(loc);
                loc
            }
        };
        Some(self.buckets[b][i].ev.time_ms)
    }

    pub fn pop(&mut self) -> Option<Event> {
        if self.len == 0 {
            return None;
        }
        let (b, i) = match self.cached_min.take() {
            Some(loc) => loc,
            None => self.locate_min(),
        };
        let entry = self.buckets[b].swap_remove(i);
        self.len -= 1;
        if self.len * 8 < self.mask + 1 && self.mask + 1 > MIN_BUCKETS {
            self.resize();
        }
        Some(entry.ev)
    }

    /// Find the `(bucket, position)` of the minimum `(time_ms, seq)`
    /// event. Requires `len > 0`. Advances the cursor past empty days;
    /// after a fruitless full lap, jumps directly to the earliest day.
    fn locate_min(&mut self) -> (usize, usize) {
        debug_assert!(self.len > 0);
        let n_buckets = self.mask + 1;
        let mut scanned = 0usize;
        loop {
            let b = (self.vday & self.mask as u64) as usize;
            let mut best: Option<(usize, f64, u64)> = None;
            for (i, e) in self.buckets[b].iter().enumerate() {
                if e.day != self.vday {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some((_, t, s)) => {
                        e.ev.time_ms < t || (e.ev.time_ms == t && e.ev.seq < s)
                    }
                };
                if better {
                    best = Some((i, e.ev.time_ms, e.ev.seq));
                }
            }
            if let Some((i, _, _)) = best {
                return (b, i);
            }
            self.vday += 1;
            scanned += 1;
            if scanned >= n_buckets {
                // A whole lap without an eligible event: every queued
                // entry lives in a later "year". Jump to the earliest day.
                let min_day = self
                    .buckets
                    .iter()
                    .flatten()
                    .map(|e| e.day)
                    .min()
                    .expect("len > 0 implies a queued entry");
                self.vday = min_day;
                scanned = 0;
            }
        }
    }

    /// Re-bucket into a size fitted to the current population, with the
    /// width re-estimated from the observed event-time span. Pop order is
    /// unaffected (ordering is by `(time_ms, seq)`, not bucket layout).
    fn resize(&mut self) {
        let entries: Vec<CalEntry> = self
            .buckets
            .iter_mut()
            .flat_map(std::mem::take)
            .collect();
        let n_buckets = entries
            .len()
            .next_power_of_two()
            .clamp(MIN_BUCKETS, MAX_BUCKETS);
        let mut min_t = f64::INFINITY;
        let mut max_t = f64::NEG_INFINITY;
        for e in &entries {
            min_t = min_t.min(e.ev.time_ms);
            max_t = max_t.max(e.ev.time_ms);
        }
        let span = max_t - min_t;
        if span > 0.0 && !entries.is_empty() {
            // Aim for ~one event per day bucket across the populated span.
            self.width = (2.0 * span / entries.len() as f64).max(MIN_WIDTH);
        }
        self.buckets = vec![Vec::new(); n_buckets];
        self.mask = n_buckets - 1;
        self.len = 0;
        self.cached_min = None;
        self.vday = u64::MAX;
        let mut min_day = u64::MAX;
        for e in entries {
            let day = self.day_of(e.ev.time_ms);
            min_day = min_day.min(day);
            let b = (day & self.mask as u64) as usize;
            self.buckets[b].push(CalEntry { day, ev: e.ev });
            self.len += 1;
        }
        self.vday = if min_day == u64::MAX { 0 } else { min_day };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::default();
        q.push(5.0, EventKind::Arrival { req: 0 });
        q.push(1.0, EventKind::Arrival { req: 1 });
        q.push(3.0, EventKind::Arrival { req: 2 });
        let order: Vec<f64> = std::iter::from_fn(|| q.pop().map(|e| e.time_ms))
            .collect();
        assert_eq!(order, vec![1.0, 3.0, 5.0]);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::default();
        q.push(2.0, EventKind::Arrival { req: 10 });
        q.push(2.0, EventKind::Arrival { req: 11 });
        q.push(2.0, EventKind::Arrival { req: 12 });
        let reqs: Vec<u32> = std::iter::from_fn(|| {
            q.pop().map(|e| match e.kind {
                EventKind::Arrival { req } => req,
                _ => unreachable!(),
            })
        })
        .collect();
        assert_eq!(reqs, vec![10, 11, 12]);
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::default();
        q.push(10.0, EventKind::Arrival { req: 0 });
        q.push(1.0, EventKind::Arrival { req: 1 });
        assert_eq!(q.pop().unwrap().time_ms, 1.0);
        q.push(0.5, EventKind::Completion { req: 1, pool: 0, instance: 0 });
        assert_eq!(q.pop().unwrap().time_ms, 0.5);
        assert_eq!(q.pop().unwrap().time_ms, 10.0);
        assert!(q.pop().is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn large_volume_stays_sorted() {
        // Miri executes this in the nightly soundness job; shrink the
        // volume there so the interpreter finishes in seconds.
        let n: usize = if cfg!(miri) { 300 } else { 10_000 };
        let mut q = EventQueue::with_capacity(n);
        let mut rng = crate::workload::rng::Pcg64::new(3, 0);
        for i in 0..n as u32 {
            q.push(rng.uniform() * 1e6, EventKind::Arrival { req: i });
        }
        let mut prev = -1.0;
        while let Some(e) = q.pop() {
            assert!(e.time_ms >= prev);
            prev = e.time_ms;
        }
    }

    // ---- calendar queue ----

    #[test]
    fn calendar_pops_in_time_order_with_ties() {
        let mut q = CalendarQueue::default();
        q.push(2.0, EventKind::Arrival { req: 10 });
        q.push(2.0, EventKind::Arrival { req: 11 });
        q.push(1.0, EventKind::Arrival { req: 12 });
        q.push(2.0, EventKind::Arrival { req: 13 });
        let order: Vec<u32> = std::iter::from_fn(|| {
            q.pop().map(|e| match e.kind {
                EventKind::Arrival { req } => req,
                _ => unreachable!(),
            })
        })
        .collect();
        // Time order first, then insertion (seq) order on ties.
        assert_eq!(order, vec![12, 10, 11, 13]);
        assert!(q.is_empty());
        assert!(q.pop().is_none());
    }

    #[test]
    fn calendar_next_time_matches_pop() {
        let mut q = CalendarQueue::default();
        q.push(7.0, EventKind::Drain { pool: 0 });
        q.push(3.0, EventKind::Drain { pool: 1 });
        assert_eq!(q.next_time(), Some(3.0));
        assert_eq!(q.pop().unwrap().time_ms, 3.0);
        assert_eq!(q.next_time(), Some(7.0));
        // Pushing an earlier event must rewind the cursor.
        q.push(1.0, EventKind::Drain { pool: 2 });
        assert_eq!(q.next_time(), Some(1.0));
        assert_eq!(q.pop().unwrap().time_ms, 1.0);
        assert_eq!(q.pop().unwrap().time_ms, 7.0);
        assert_eq!(q.next_time(), None);
    }

    #[test]
    fn calendar_handles_far_future_events() {
        // Events many "years" apart exercise the direct-jump path.
        let mut q = CalendarQueue::with_capacity(4);
        q.push(1e9, EventKind::Drain { pool: 0 });
        q.push(0.5, EventKind::Drain { pool: 1 });
        q.push(1e6, EventKind::Drain { pool: 2 });
        assert_eq!(q.pop().unwrap().time_ms, 0.5);
        assert_eq!(q.pop().unwrap().time_ms, 1e6);
        assert_eq!(q.pop().unwrap().time_ms, 1e9);
        assert!(q.pop().is_none());
    }

    /// The load-bearing property: the calendar queue pops in the exact
    /// order the reference heap does, across random interleaved
    /// push/pop traffic (including resize churn and same-time ties).
    #[test]
    fn calendar_matches_heap_order_under_random_traffic() {
        // Scaled down under miri (interpreted execution); the full
        // fuzz volume still runs in every native test job.
        let cases: usize = if cfg!(miri) { 2 } else { 20 };
        let steps: usize = if cfg!(miri) { 300 } else { 4_000 };
        let mut rng = crate::workload::rng::Pcg64::new(99, 7);
        for case in 0..cases {
            let mut heap = EventQueue::default();
            let mut cal = CalendarQueue::default();
            let mut now = 0.0f64;
            let mut pending = 0usize;
            for step in 0..steps as u32 {
                let push = pending == 0 || rng.uniform() < 0.55;
                if push {
                    // Mixture of near-future, same-time, and far spikes.
                    let u = rng.uniform();
                    let dt = if u < 0.05 {
                        0.0
                    } else if u < 0.95 {
                        rng.uniform() * 50.0
                    } else {
                        1e4 + rng.uniform() * 1e6
                    };
                    let t = now + dt;
                    heap.push(t, EventKind::Arrival { req: step });
                    cal.push(t, EventKind::Arrival { req: step });
                    pending += 1;
                } else {
                    let a = heap.pop().unwrap();
                    let b = cal.pop().unwrap();
                    assert_eq!(
                        (a.time_ms, a.seq, a.kind),
                        (b.time_ms, b.seq, b.kind),
                        "case {case} step {step}"
                    );
                    now = a.time_ms;
                    pending -= 1;
                }
                assert_eq!(heap.len(), cal.len());
            }
            while let Some(a) = heap.pop() {
                let b = cal.pop().unwrap();
                assert_eq!((a.time_ms, a.seq, a.kind),
                           (b.time_ms, b.seq, b.kind));
            }
            assert!(cal.is_empty());
        }
    }

    #[test]
    fn calendar_resize_preserves_contents() {
        let mut q = CalendarQueue::with_capacity(4);
        // Push enough to force growth, then drain to force shrinkage.
        for i in 0..500u32 {
            q.push(i as f64 * 0.37, EventKind::Arrival { req: i });
        }
        assert_eq!(q.len(), 500);
        let mut prev = -1.0;
        let mut n = 0;
        while let Some(e) = q.pop() {
            assert!(e.time_ms >= prev);
            prev = e.time_ms;
            n += 1;
        }
        assert_eq!(n, 500);
    }
}
