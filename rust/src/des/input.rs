//! The unified simulation input (`SimInput`) and typed configuration
//! errors — one front door for all four DES entry points.
//!
//! Historically `Simulator::run_stream`, `shard::run_streamed`,
//! `shard::run_sharded`, and `reference::run_reference` drifted into
//! four divergent argument lists, each re-asserting its own invariants
//! with panics. A [`SimInput`] bundles what they all consume — pools,
//! routing policy, config, an arrivals source, and an optional fault
//! script — and every entry point now validates it up front, returning
//! [`ConfigError`] instead of aborting the process:
//!
//! * [`Simulator::run_input`](crate::des::engine::Simulator::run_input)
//! * [`run_reference_input`](crate::des::reference::run_reference_input)
//! * [`run_streamed_input`](crate::des::shard::run_streamed_input)
//! * [`run_sharded_input`](crate::des::shard::run_sharded_input)
//!
//! The old signatures survive as thin `#[deprecated]` wrappers that
//! panic on invalid input exactly as before (the regression suites pin
//! them); everything is still borrowed, so the zero-copy sweep
//! contract is unchanged.

use std::fmt;

use crate::des::engine::{DesConfig, SimPool};
use crate::des::faults::{CompiledFaults, FaultScript};
use crate::des::memory::MemoryConfig;
use crate::des::retry::RetryConfig;
use crate::router::RoutingPolicy;
use crate::workload::spec::{SampledRequest, WorkloadSpec};

/// Typed validation errors for simulation inputs. Display strings keep
/// the historical panic texts, so the deprecated wrappers (which panic
/// with `{error}`) abort with the same messages as before.
#[derive(Debug, Clone, PartialEq)]
pub enum ConfigError {
    /// The router addresses more pools than the fleet has.
    RouterPoolMismatch { expected: usize, got: usize },
    /// `warmup_frac` outside `[0, 1)` (or not finite).
    InvalidWarmup { warmup_frac: f64 },
    /// Nonzero warmup on a streaming entry point, where the time-based
    /// cutoff is unknowable up front.
    WarmupUnsupported { warmup_frac: f64 },
    /// `window_ms` set but not finite and positive.
    InvalidWindow { window_ms: f64 },
    InvalidClassProbs(String),
    InvalidCapWindow(String),
    InvalidFaults(String),
    /// Malformed closed-loop retry/admission config
    /// ([`crate::des::retry`]).
    InvalidRetries(String),
    /// Malformed KV-cache memory model config
    /// ([`crate::des::memory`]).
    InvalidMemory(String),
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::RouterPoolMismatch { expected, got } => {
                write!(f, "router expects {expected} pools, got {got}")
            }
            ConfigError::InvalidWarmup { warmup_frac } => {
                write!(f, "warmup_frac must be in [0, 1), got {warmup_frac}")
            }
            ConfigError::WarmupUnsupported { warmup_frac } => {
                write!(
                    f,
                    "generator-driven runs require warmup_frac = 0 (the \
                     time-based cutoff needs the last arrival, unknown \
                     while streaming); got {warmup_frac}"
                )
            }
            ConfigError::InvalidWindow { window_ms } => {
                write!(
                    f,
                    "window_ms must be finite and > 0, got {window_ms}"
                )
            }
            ConfigError::InvalidClassProbs(msg) => {
                write!(f, "invalid class_probs: {msg}")
            }
            ConfigError::InvalidCapWindow(msg) => {
                write!(f, "invalid cap_window: {msg}")
            }
            ConfigError::InvalidFaults(msg) => {
                write!(f, "invalid fault script: {msg}")
            }
            ConfigError::InvalidRetries(msg) => {
                write!(f, "invalid retry config: {msg}")
            }
            ConfigError::InvalidMemory(msg) => {
                write!(f, "invalid memory config: {msg}")
            }
        }
    }
}

impl std::error::Error for ConfigError {}

impl DesConfig {
    /// Validate the entry-point-independent invariants. Called by every
    /// `SimInput`-based entry point; streaming entry points additionally
    /// require `warmup_frac == 0`.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if !(self.warmup_frac.is_finite()
            && (0.0..1.0).contains(&self.warmup_frac))
        {
            return Err(ConfigError::InvalidWarmup {
                warmup_frac: self.warmup_frac,
            });
        }
        if let Some(w) = self.window_ms {
            if !(w.is_finite() && w > 0.0) {
                return Err(ConfigError::InvalidWindow { window_ms: w });
            }
        }
        if let Some(w) = &self.cap_window {
            if !(w.start_ms.is_finite()
                && w.end_ms.is_finite()
                && w.start_ms >= 0.0
                && w.end_ms >= w.start_ms)
            {
                return Err(ConfigError::InvalidCapWindow(format!(
                    "[{}, {}) is not a valid time window",
                    w.start_ms, w.end_ms
                )));
            }
        }
        if let Some(probs) = &self.class_probs {
            if probs.is_empty() {
                return Err(ConfigError::InvalidClassProbs(
                    "empty class distribution".to_string(),
                ));
            }
            if probs.iter().any(|p| !p.is_finite() || *p < 0.0) {
                return Err(ConfigError::InvalidClassProbs(format!(
                    "probabilities must be finite and >= 0: {probs:?}"
                )));
            }
            if probs.iter().sum::<f64>() <= 0.0 {
                return Err(ConfigError::InvalidClassProbs(
                    "probabilities sum to 0".to_string(),
                ));
            }
        }
        Ok(())
    }
}

/// Where a run's arrivals come from.
#[derive(Clone, Copy)]
pub enum ArrivalsSource<'a> {
    /// An explicit, time-ordered, materialized stream (the request
    /// count is the slice length; `config.n_requests` is ignored).
    Stream(&'a [SampledRequest]),
    /// A workload sampled/generated on demand: serial entry points
    /// materialize `config.n_requests` requests; streaming entry
    /// points pull them chunk-by-chunk in O(in-flight) memory.
    Generator(&'a WorkloadSpec),
}

/// The unified, borrowed input consumed by all four DES entry points.
pub struct SimInput<'a> {
    pub pools: &'a [SimPool],
    pub router: &'a RoutingPolicy,
    pub config: &'a DesConfig,
    pub arrivals: ArrivalsSource<'a>,
    /// Optional deterministic fault schedule (see
    /// [`crate::des::faults`]).
    pub faults: Option<&'a FaultScript>,
    /// Optional closed-loop client/admission behavior (see
    /// [`crate::des::retry`]). `None` keeps the open-loop semantics
    /// bit-identically.
    pub retries: Option<&'a RetryConfig>,
    /// Optional KV-cache memory model (see [`crate::des::memory`]).
    /// `None` keeps the open-loop semantics bit-identically.
    pub memory: Option<&'a MemoryConfig>,
}

impl<'a> SimInput<'a> {
    /// Input over a materialized request stream.
    pub fn stream(
        pools: &'a [SimPool],
        router: &'a RoutingPolicy,
        config: &'a DesConfig,
        sampled: &'a [SampledRequest],
    ) -> Self {
        SimInput {
            pools,
            router,
            config,
            arrivals: ArrivalsSource::Stream(sampled),
            faults: None,
            retries: None,
            memory: None,
        }
    }

    /// Input over a generator-driven workload
    /// (`config.n_requests` arrivals).
    pub fn generated(
        pools: &'a [SimPool],
        router: &'a RoutingPolicy,
        config: &'a DesConfig,
        workload: &'a WorkloadSpec,
    ) -> Self {
        SimInput {
            pools,
            router,
            config,
            arrivals: ArrivalsSource::Generator(workload),
            faults: None,
            retries: None,
            memory: None,
        }
    }

    /// Attach a fault script.
    pub fn with_faults(mut self, faults: &'a FaultScript) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Attach a closed-loop retry/admission config.
    pub fn with_retries(mut self, retries: &'a RetryConfig) -> Self {
        self.retries = Some(retries);
        self
    }

    /// Attach a KV-cache memory model. Not attaching one keeps the
    /// open-loop semantics byte-for-byte.
    pub fn with_memory(mut self, memory: &'a MemoryConfig) -> Self {
        self.memory = Some(memory);
        self
    }

    /// Validate router/pool coherence, the config, and the fault
    /// script. Every entry point calls this before touching state.
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.router.n_pools() > self.pools.len() {
            return Err(ConfigError::RouterPoolMismatch {
                expected: self.router.n_pools(),
                got: self.pools.len(),
            });
        }
        self.config.validate()?;
        if let Some(f) = self.faults {
            f.validate(self.pools.len())?;
        }
        if let Some(r) = self.retries {
            r.validate()?;
        }
        if let Some(m) = self.memory {
            m.validate(self.pools)?;
            if self.retries.is_some() {
                return Err(ConfigError::InvalidMemory(
                    "memory model cannot be combined with a retry \
                     config yet"
                        .to_string(),
                ));
            }
        }
        Ok(())
    }

    /// Streaming-entry-point validation: everything above, plus the
    /// no-warmup constraint (the time-based cutoff needs the last
    /// arrival, which a streaming run does not know up front).
    pub(crate) fn validate_streaming(&self) -> Result<(), ConfigError> {
        self.validate()?;
        if self.config.warmup_frac != 0.0 {
            return Err(ConfigError::WarmupUnsupported {
                warmup_frac: self.config.warmup_frac,
            });
        }
        Ok(())
    }

    /// Compile the fault script (if any) against this fleet. `None`
    /// scripts cost nothing; empty scripts compile to empty views that
    /// are bit-identical to no script at all.
    pub(crate) fn compiled_faults(&self) -> Option<CompiledFaults> {
        self.faults.map(|f| CompiledFaults::compile(f, self.pools))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::engine::CapWindow;
    use crate::gpu::catalog::GpuCatalog;

    fn pools(n: usize) -> Vec<SimPool> {
        let gpu = GpuCatalog::standard().get("A100").unwrap().clone();
        vec![
            SimPool {
                gpu,
                n_gpus: 2,
                ctx_budget: 8192.0,
                batch_cap: None
            };
            n
        ]
    }

    #[test]
    fn default_config_validates() {
        assert!(DesConfig::default().validate().is_ok());
    }

    #[test]
    fn warmup_out_of_range_is_rejected() {
        for bad in [-0.1, 1.0, 1.5, f64::NAN, f64::INFINITY] {
            let cfg = DesConfig { warmup_frac: bad, ..Default::default() };
            assert!(
                matches!(
                    cfg.validate(),
                    Err(ConfigError::InvalidWarmup { .. })
                ),
                "warmup_frac = {bad}"
            );
        }
        let ok = DesConfig { warmup_frac: 0.99, ..Default::default() };
        assert!(ok.validate().is_ok());
    }

    #[test]
    fn bad_windows_probs_and_caps_are_rejected() {
        let cfg =
            DesConfig { window_ms: Some(0.0), ..Default::default() };
        assert!(matches!(
            cfg.validate(),
            Err(ConfigError::InvalidWindow { .. })
        ));
        let cfg = DesConfig {
            class_probs: Some(vec![]),
            ..Default::default()
        };
        assert!(matches!(
            cfg.validate(),
            Err(ConfigError::InvalidClassProbs(_))
        ));
        let cfg = DesConfig {
            class_probs: Some(vec![0.5, -0.1]),
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
        let cfg = DesConfig {
            cap_window: Some(CapWindow {
                start_ms: 10.0,
                end_ms: 5.0,
                cap: 1,
            }),
            ..Default::default()
        };
        assert!(matches!(
            cfg.validate(),
            Err(ConfigError::InvalidCapWindow(_))
        ));
    }

    #[test]
    fn input_catches_router_pool_mismatch() {
        let fleet = pools(1);
        let router = RoutingPolicy::Length { b_short: 4096.0 };
        let cfg = DesConfig::default();
        let sampled: Vec<crate::workload::spec::SampledRequest> = vec![];
        let input = SimInput::stream(&fleet, &router, &cfg, &sampled);
        let err = input.validate().unwrap_err();
        assert_eq!(
            err,
            ConfigError::RouterPoolMismatch { expected: 2, got: 1 }
        );
        assert_eq!(err.to_string(), "router expects 2 pools, got 1");
    }

    #[test]
    fn streaming_validation_rejects_warmup_with_the_legacy_message() {
        let fleet = pools(2);
        let router = RoutingPolicy::Length { b_short: 4096.0 };
        let cfg =
            DesConfig { warmup_frac: 0.2, ..Default::default() };
        let w = crate::workload::spec::WorkloadSpec::builtin(
            crate::workload::spec::BuiltinTrace::Azure,
            50.0,
        );
        let input = SimInput::generated(&fleet, &router, &cfg, &w);
        assert!(input.validate().is_ok(), "serial path allows warmup");
        let err = input.validate_streaming().unwrap_err();
        assert!(matches!(
            err,
            ConfigError::WarmupUnsupported { warmup_frac } if
                warmup_frac == 0.2
        ));
        // The deprecated wrappers panic with this Display — it must
        // keep the historical "warmup_frac = 0" substring.
        assert!(err.to_string().contains("warmup_frac = 0"));
    }
}
