//! Closed-loop client behavior: deadlines, retries, and admission
//! control (ISSUE 9 / the retry-storm metastability family).
//!
//! Open-loop clients wait forever, so the simulator could not express
//! the most common real-fleet robustness failure: a transient overload
//! that turns into a sustained outage because timed-out clients retry
//! into an already-saturated fleet. This module adds the plain-data
//! configuration ([`RetrySpec`], [`AdmissionSpec`], [`RetryConfig`])
//! plus the deterministic backoff function and the per-request state
//! machine shared by all three engines.
//!
//! # Execution model
//!
//! Everything here is gated on a [`RetryConfig`] being attached to the
//! `SimInput` (`with_retries`): runs without one are bit-identical to
//! the open-loop simulator, event for event.
//!
//! With a config attached, each *request* becomes a sequence of
//! *attempts* against one pool (retries are sticky: they re-enter the
//! pool the router originally chose, consuming no extra routing
//! draws, so a request's whole lifecycle stays inside one shard):
//!
//! * **Deadlines.** Every attempt carries a client deadline
//!   `start + timeout_ms`. A timed-out attempt abandons its queue slot
//!   — and, if it was admitted too late to finish in time, its
//!   in-flight decode keeps the GPU slot busy until the deadline
//!   (wasted work, the mechanism behind retry-storm metastability).
//! * **Retries.** A failed attempt (timeout or shed) retries up to
//!   `max_attempts` total attempts, after an exponential backoff with
//!   deterministic jitter: a pure function of
//!   `(seed, request id, attempt)` via the named
//!   [`workload::streams::RETRY`](crate::workload::streams::RETRY)
//!   substream — bit-identical on every engine at every shard count.
//! * **Admission control.** A pool may bound its queue depth
//!   (arrivals beyond `max_queue_depth` are shed — terminal, clients
//!   do not retry sheds into a pool that told them to go away until
//!   the breaker half of the spec lets them) and may run a hysteretic
//!   circuit breaker: the breaker opens when the queue reaches
//!   `breaker_open_depth` and closes once it drains to
//!   `breaker_close_depth`; while open, every new attempt is shed
//!   immediately.
//!
//! Shed is terminal by design: a shed is the *server* telling the
//! client to back off, and modelling it as instant cheap rejection is
//! exactly what lets the breaker regime recover in the `retry_storm`
//! scenario. A timeout, by contrast, is the *client* giving up, and
//! does retry.

use crate::des::input::ConfigError;
use crate::workload::rng::Pcg64;
use crate::workload::streams;

/// Salt mixed into the user seed for backoff jitter so the retry
/// stream never correlates with workload, routing, or fault draws at
/// the same seed (mirrors `FAULT_SEED_SALT` in `des::faults`).
const RETRY_SEED_SALT: u64 = 0x517c_c1b7_2722_0a95;

/// Client-side retry/timeout policy.
#[derive(Debug, Clone, PartialEq)]
pub struct RetrySpec {
    /// Total attempts per request (1 = timeout only, no retries).
    pub max_attempts: u32,
    /// Client deadline per attempt, ms after the attempt starts.
    pub timeout_ms: f64,
    /// First backoff interval; attempt `a` (1-based) waits
    /// `min(cap, base * 2^(a-1))` scaled by jitter in `[0.5, 1.5)`.
    pub backoff_base_ms: f64,
    /// Ceiling on the exponential backoff interval.
    pub backoff_cap_ms: f64,
}

/// Server-side admission policy for every pool. Zero values disable
/// the corresponding mechanism.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AdmissionSpec {
    /// Shed arrivals once the pool queue holds this many requests
    /// (0 = unbounded queue).
    pub max_queue_depth: usize,
    /// Open the circuit breaker when the queue reaches this depth
    /// (0 = no breaker).
    pub breaker_open_depth: usize,
    /// Close the breaker once the queue drains to this depth; must be
    /// strictly below `breaker_open_depth` (hysteresis).
    pub breaker_close_depth: usize,
}

/// The closed-loop configuration attached to a `SimInput` via
/// `with_retries`. At least one of the two specs must be present.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RetryConfig {
    pub retry: Option<RetrySpec>,
    pub admission: Option<AdmissionSpec>,
}

/// Deterministic backoff interval before attempt `attempt + 1` of the
/// request with global id `global_id`: exponential in the attempt
/// number, capped, with jitter in `[0.5, 1.5)` drawn from a fresh
/// [`streams::RETRY`] generator keyed on `(seed, global_id, attempt)`.
/// A pure function — no engine state, no draw-order coupling — which
/// is what makes retry schedules bit-identical across engines and
/// shard counts.
pub fn backoff_ms(
    seed: u64,
    global_id: u64,
    attempt: u32,
    spec: &RetrySpec,
) -> f64 {
    let exp = attempt.saturating_sub(1).min(63);
    let base = (spec.backoff_base_ms * (1u64 << exp) as f64)
        .min(spec.backoff_cap_ms);
    let mix = global_id
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(u64::from(attempt).wrapping_mul(0xbf58_476d_1ce4_e5b9));
    let mut rng = Pcg64::new(
        seed.wrapping_add(RETRY_SEED_SALT) ^ mix,
        streams::RETRY,
    );
    base * (0.5 + rng.uniform())
}

impl RetryConfig {
    /// Check the config. Run automatically by every `SimInput`-based
    /// entry point when a config is attached.
    pub fn validate(&self) -> Result<(), ConfigError> {
        let bad = |msg: String| Err(ConfigError::InvalidRetries(msg));
        if self.retry.is_none() && self.admission.is_none() {
            return bad(
                "at least one of [retry] or [admission] is required"
                    .to_string(),
            );
        }
        if let Some(r) = &self.retry {
            if r.max_attempts == 0 {
                return bad("max_attempts must be >= 1".to_string());
            }
            if !(r.timeout_ms.is_finite() && r.timeout_ms > 0.0) {
                return bad(format!(
                    "timeout_ms {} must be finite and > 0",
                    r.timeout_ms
                ));
            }
            if !(r.backoff_base_ms.is_finite() && r.backoff_base_ms >= 0.0) {
                return bad(format!(
                    "backoff_base_ms {} invalid",
                    r.backoff_base_ms
                ));
            }
            if !(r.backoff_cap_ms.is_finite()
                && r.backoff_cap_ms >= r.backoff_base_ms)
            {
                return bad(format!(
                    "backoff_cap_ms {} must be finite and >= \
                     backoff_base_ms {}",
                    r.backoff_cap_ms, r.backoff_base_ms
                ));
            }
        }
        if let Some(a) = &self.admission {
            if a.max_queue_depth == 0 && a.breaker_open_depth == 0 {
                return bad(
                    "admission spec enables nothing (max_queue_depth \
                     and breaker_open_depth are both 0)"
                        .to_string(),
                );
            }
            if a.breaker_open_depth == 0 && a.breaker_close_depth != 0 {
                return bad(format!(
                    "breaker_close_depth {} without breaker_open_depth",
                    a.breaker_close_depth
                ));
            }
            if a.breaker_open_depth > 0
                && a.breaker_close_depth >= a.breaker_open_depth
            {
                return bad(format!(
                    "breaker_close_depth {} must be < \
                     breaker_open_depth {} (hysteresis)",
                    a.breaker_close_depth, a.breaker_open_depth
                ));
            }
        }
        Ok(())
    }

    /// Parse a retry config from the shipped TOML subset: `[retry]`
    /// and `[admission]` sections with `key = value` lines and `#`
    /// comments (see `data/retry/example.toml`). Hand-rolled like
    /// `FaultScript::from_toml_str` — the build is offline and vendors
    /// no TOML crate.
    pub fn from_toml_str(text: &str) -> Result<Self, ConfigError> {
        enum Section {
            None,
            Retry,
            Admission,
        }
        let bad = |line: usize, msg: String| {
            Err(ConfigError::InvalidRetries(format!(
                "retry config line {line}: {msg}"
            )))
        };
        let mut cfg = RetryConfig::default();
        let mut section = Section::None;
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = match raw.split_once('#') {
                Some((head, _)) => head.trim(),
                None => raw.trim(),
            };
            if line.is_empty() {
                continue;
            }
            if let Some(name) =
                line.strip_prefix('[').and_then(|l| l.strip_suffix(']'))
            {
                section = match name.trim() {
                    "retry" => {
                        if cfg.retry.is_some() {
                            return bad(
                                lineno,
                                "duplicate [retry] section".to_string(),
                            );
                        }
                        cfg.retry = Some(RetrySpec {
                            max_attempts: 1,
                            timeout_ms: f64::NAN,
                            backoff_base_ms: 0.0,
                            backoff_cap_ms: f64::NAN,
                        });
                        Section::Retry
                    }
                    "admission" => {
                        if cfg.admission.is_some() {
                            return bad(
                                lineno,
                                "duplicate [admission] section".to_string(),
                            );
                        }
                        cfg.admission = Some(AdmissionSpec::default());
                        Section::Admission
                    }
                    other => {
                        return bad(
                            lineno,
                            format!("unknown section [{other}]"),
                        )
                    }
                };
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                return bad(lineno, format!("expected key = value: {line}"));
            };
            let (key, value) = (key.trim(), value.trim());
            let num = || -> Result<f64, ConfigError> {
                value.parse::<f64>().map_err(|_| {
                    ConfigError::InvalidRetries(format!(
                        "retry config line {lineno}: {key} = {value} is \
                         not a number"
                    ))
                })
            };
            let int = || -> Result<usize, ConfigError> {
                value.parse::<usize>().map_err(|_| {
                    ConfigError::InvalidRetries(format!(
                        "retry config line {lineno}: {key} = {value} is \
                         not a non-negative integer"
                    ))
                })
            };
            match section {
                Section::None => {
                    return bad(
                        lineno,
                        format!(
                            "{key} outside a [retry]/[admission] section"
                        ),
                    )
                }
                Section::Retry => {
                    let r = cfg.retry.as_mut().expect("pushed");
                    match key {
                        "max_attempts" => {
                            r.max_attempts = int()?.min(u32::MAX as usize)
                                as u32
                        }
                        "timeout_ms" => r.timeout_ms = num()?,
                        "backoff_base_ms" => r.backoff_base_ms = num()?,
                        "backoff_cap_ms" => r.backoff_cap_ms = num()?,
                        other => {
                            return bad(
                                lineno,
                                format!("unknown retry key {other}"),
                            )
                        }
                    }
                }
                Section::Admission => {
                    let a = cfg.admission.as_mut().expect("pushed");
                    match key {
                        "max_queue_depth" => a.max_queue_depth = int()?,
                        "breaker_open_depth" => {
                            a.breaker_open_depth = int()?
                        }
                        "breaker_close_depth" => {
                            a.breaker_close_depth = int()?
                        }
                        other => {
                            return bad(
                                lineno,
                                format!("unknown admission key {other}"),
                            )
                        }
                    }
                }
            }
        }
        if let Some(r) = &mut cfg.retry {
            if r.timeout_ms.is_nan() {
                return Err(ConfigError::InvalidRetries(
                    "[retry]: timeout_ms is required".to_string(),
                ));
            }
            if r.backoff_cap_ms.is_nan() {
                r.backoff_cap_ms = r.backoff_base_ms;
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

/// Attempt lifecycle of one request under a [`RetryConfig`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Phase {
    /// Waiting in a pool queue.
    Queued,
    /// Admitted and on track to complete before its deadline.
    InFlight,
    /// Admitted but mathematically unable to finish before the
    /// deadline: the slot stays busy (wasted work) until the timeout
    /// event releases it.
    Doomed,
    /// Timed out / waiting out a backoff before the next attempt.
    Backoff,
    /// Terminal: served, abandoned, or shed.
    Done,
}

/// Per-request closed-loop state, indexed by the engine's request id
/// (stream index on the serial engines, arena slot on the sharded
/// one — `global_id` carries the stream-global id in either case so
/// backoff draws agree everywhere).
#[derive(Debug, Clone, Copy)]
pub(crate) struct ReqState {
    pub global_id: u64,
    pub first_arrival_ms: f64,
    pub deadline_ms: f64,
    /// 1-based attempt counter.
    pub attempt: u32,
    pub pool: u16,
    pub instance: u16,
    pub phase: Phase,
}

/// The engine-side closed-loop machine: owned config, per-request
/// states, and per-pool breaker flags. Engines consult it at arrival,
/// admission, timeout, and retry time; every decision is a pure
/// function of `(config, seed, request, queue length)`, which keeps
/// the three engines bit-identical.
#[derive(Debug, Clone)]
pub(crate) struct ClosedLoopState {
    pub cfg: RetryConfig,
    pub seed: u64,
    pub states: Vec<ReqState>,
    pub breaker_open: Vec<bool>,
}

impl ClosedLoopState {
    pub fn new(cfg: &RetryConfig, seed: u64, n_pools: usize) -> Self {
        ClosedLoopState {
            cfg: cfg.clone(),
            seed,
            states: Vec::new(),
            breaker_open: vec![false; n_pools],
        }
    }

    /// (Re)initialize the state slot for a request starting attempt 1.
    pub fn init_request(
        &mut self,
        id: usize,
        global_id: u64,
        arrival_ms: f64,
    ) {
        if self.states.len() <= id {
            self.states.resize(
                id + 1,
                ReqState {
                    global_id: 0,
                    first_arrival_ms: 0.0,
                    deadline_ms: f64::INFINITY,
                    attempt: 1,
                    pool: 0,
                    instance: 0,
                    phase: Phase::Done,
                },
            );
        }
        self.states[id] = ReqState {
            global_id,
            first_arrival_ms: arrival_ms,
            deadline_ms: f64::INFINITY,
            attempt: 1,
            pool: 0,
            instance: 0,
            phase: Phase::Done,
        };
    }

    /// Deadline for an attempt starting at `now`: infinite when no
    /// retry spec is attached (admission-only configs time nothing
    /// out, and no timeout event is ever scheduled).
    pub fn deadline_after(&self, now: f64) -> f64 {
        match &self.cfg.retry {
            Some(r) => now + r.timeout_ms,
            None => f64::INFINITY,
        }
    }

    pub fn max_attempts(&self) -> u32 {
        self.cfg.retry.as_ref().map_or(1, |r| r.max_attempts)
    }

    /// Backoff before the attempt after `attempt`, for the request
    /// with stream-global id `global_id`.
    pub fn backoff_after(&self, global_id: u64, attempt: u32) -> f64 {
        let spec = self.cfg.retry.as_ref().expect("retries enabled");
        backoff_ms(self.seed, global_id, attempt, spec)
    }

    /// Queue-depth bound (0 = unbounded).
    pub fn queue_bound(&self) -> usize {
        self.cfg.admission.as_ref().map_or(0, |a| a.max_queue_depth)
    }

    pub fn breaker_is_open(&self, pool: usize) -> bool {
        self.breaker_open[pool]
    }

    /// Hysteresis update after a queue-length change: opens at
    /// `>= breaker_open_depth` (on growth), closes at
    /// `<= breaker_close_depth` (on drain). Called with the queue
    /// length *after* every enqueue and dequeue, in event order, so
    /// every engine sees the identical open/close history.
    pub fn note_queue_len(&mut self, pool: usize, len: usize) {
        let Some(a) = &self.cfg.admission else { return };
        if a.breaker_open_depth == 0 {
            return;
        }
        let open = &mut self.breaker_open[pool];
        if !*open && len >= a.breaker_open_depth {
            *open = true;
        } else if *open && len <= a.breaker_close_depth {
            *open = false;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> RetrySpec {
        RetrySpec {
            max_attempts: 4,
            timeout_ms: 8_000.0,
            backoff_base_ms: 1_000.0,
            backoff_cap_ms: 8_000.0,
        }
    }

    #[test]
    fn backoff_is_a_pure_function_with_bounded_jitter() {
        let s = spec();
        for attempt in 1..=6u32 {
            let nominal = (1_000.0 * (1u64 << (attempt - 1)) as f64)
                .min(8_000.0);
            for id in [0u64, 1, 17, 1 << 40] {
                let a = backoff_ms(42, id, attempt, &s);
                let b = backoff_ms(42, id, attempt, &s);
                assert_eq!(a.to_bits(), b.to_bits(), "pure function");
                assert!(
                    a >= 0.5 * nominal && a < 1.5 * nominal,
                    "attempt {attempt} id {id}: {a} vs nominal {nominal}"
                );
            }
        }
    }

    #[test]
    fn backoff_varies_with_request_seed_and_attempt() {
        let s = spec();
        let base = backoff_ms(42, 7, 1, &s);
        assert_ne!(base.to_bits(), backoff_ms(42, 8, 1, &s).to_bits());
        assert_ne!(base.to_bits(), backoff_ms(43, 7, 1, &s).to_bits());
        assert_ne!(base.to_bits(), backoff_ms(42, 7, 2, &s).to_bits());
    }

    #[test]
    fn validate_rejects_bad_configs() {
        assert!(RetryConfig::default().validate().is_err());
        let mut c = RetryConfig {
            retry: Some(spec()),
            admission: None,
        };
        assert!(c.validate().is_ok());
        c.retry.as_mut().unwrap().max_attempts = 0;
        assert!(matches!(
            c.validate(),
            Err(ConfigError::InvalidRetries(_))
        ));
        let c = RetryConfig {
            retry: Some(RetrySpec { timeout_ms: 0.0, ..spec() }),
            admission: None,
        };
        assert!(c.validate().is_err());
        let c = RetryConfig {
            retry: Some(RetrySpec {
                backoff_cap_ms: 10.0,
                backoff_base_ms: 100.0,
                ..spec()
            }),
            admission: None,
        };
        assert!(c.validate().is_err(), "cap below base");
        let c = RetryConfig {
            retry: None,
            admission: Some(AdmissionSpec::default()),
        };
        assert!(c.validate().is_err(), "admission enabling nothing");
        let c = RetryConfig {
            retry: None,
            admission: Some(AdmissionSpec {
                max_queue_depth: 0,
                breaker_open_depth: 8,
                breaker_close_depth: 8,
            }),
        };
        assert!(c.validate().is_err(), "no hysteresis gap");
    }

    #[test]
    fn toml_round_trips_both_sections() {
        let text = "\
# closed-loop example
[retry]
max_attempts = 4
timeout_ms = 8000    # client deadline
backoff_base_ms = 1000
backoff_cap_ms = 8000

[admission]
max_queue_depth = 64
breaker_open_depth = 32
breaker_close_depth = 8
";
        let c = RetryConfig::from_toml_str(text).unwrap();
        assert_eq!(c.retry.as_ref().unwrap(), &spec());
        assert_eq!(
            c.admission.as_ref().unwrap(),
            &AdmissionSpec {
                max_queue_depth: 64,
                breaker_open_depth: 32,
                breaker_close_depth: 8,
            }
        );
    }

    #[test]
    fn toml_defaults_cap_to_base_and_requires_timeout() {
        let c = RetryConfig::from_toml_str(
            "[retry]\ntimeout_ms = 500\nbackoff_base_ms = 100",
        )
        .unwrap();
        let r = c.retry.unwrap();
        assert_eq!(r.max_attempts, 1);
        assert_eq!(r.backoff_cap_ms, 100.0);
        assert!(RetryConfig::from_toml_str("[retry]\nmax_attempts = 2")
            .is_err());
    }

    #[test]
    fn toml_rejects_malformed_input() {
        assert!(RetryConfig::from_toml_str("timeout_ms = 5").is_err());
        assert!(RetryConfig::from_toml_str("[explosion]").is_err());
        assert!(RetryConfig::from_toml_str(
            "[retry]\ntimeout_ms = abc"
        )
        .is_err());
        assert!(RetryConfig::from_toml_str(
            "[retry]\ntimeout_ms = 5\n[retry]\ntimeout_ms = 5"
        )
        .is_err());
        assert!(RetryConfig::from_toml_str(
            "[admission]\nwat = 1"
        )
        .is_err());
        assert!(RetryConfig::from_toml_str("").is_err());
    }

    #[test]
    fn breaker_hysteresis_opens_high_closes_low() {
        let cfg = RetryConfig {
            retry: None,
            admission: Some(AdmissionSpec {
                max_queue_depth: 0,
                breaker_open_depth: 4,
                breaker_close_depth: 1,
            }),
        };
        let mut s = ClosedLoopState::new(&cfg, 1, 1);
        for len in [1usize, 2, 3] {
            s.note_queue_len(0, len);
            assert!(!s.breaker_is_open(0), "len {len}");
        }
        s.note_queue_len(0, 4);
        assert!(s.breaker_is_open(0));
        // Stays open through the hysteresis band...
        for len in [3usize, 2] {
            s.note_queue_len(0, len);
            assert!(s.breaker_is_open(0), "len {len}");
        }
        // ...and closes only at the close depth.
        s.note_queue_len(0, 1);
        assert!(!s.breaker_is_open(0));
        s.note_queue_len(0, 4);
        assert!(s.breaker_is_open(0), "reopens on the next spike");
    }
}
