//! Per-request metric collection for the DES (paper §3.1 Phase 2 step 3:
//! queue wait, TTFT, end-to-end latency; SLO check is P99 TTFT <= T).
//!
//! Collection has two modes (see [`MetricsMode`]): the default **exact**
//! mode stores every sample (what all scenario tables use, so published
//! numbers are bit-stable), and **streaming** mode aggregates into
//! O(1)-memory [`crate::util::stats::LogHistogram`] sketches so memory
//! stays O(pools) instead of O(requests) — the mode the perf harness and
//! high-volume sweeps run in.

use crate::util::stats::Samples;

/// How the DES aggregates per-request latencies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MetricsMode {
    /// Store every sample; exact nearest-rank percentiles (the default —
    /// scenario tables depend on exact values).
    #[default]
    Exact,
    /// Streaming log-histogram sketch: O(1) memory per metric,
    /// percentiles within ~1% relative error.
    Streaming,
}

/// Latency samples for one pool (or the fleet overall).
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    pub wait: Samples,
    pub ttft: Samples,
    pub e2e: Samples,
    pub count: usize,
}

impl LatencyStats {
    /// Pre-size the exact-mode sample buffers (perf pass iteration 2:
    /// avoids realloc churn in the DES hot loop).
    pub fn with_capacity(n: usize) -> Self {
        LatencyStats {
            wait: Samples::with_capacity(n),
            ttft: Samples::with_capacity(n),
            e2e: Samples::with_capacity(n),
            count: 0,
        }
    }

    /// Streaming-sketch collection: memory independent of request count.
    pub fn streaming() -> Self {
        LatencyStats {
            wait: Samples::streaming(),
            ttft: Samples::streaming(),
            e2e: Samples::streaming(),
            count: 0,
        }
    }

    /// Collector for the given mode, pre-sized for `n` exact samples.
    pub fn for_mode(mode: MetricsMode, n: usize) -> Self {
        match mode {
            MetricsMode::Exact => Self::with_capacity(n),
            MetricsMode::Streaming => Self::streaming(),
        }
    }

    pub fn record(&mut self, wait_ms: f64, ttft_ms: f64, e2e_ms: f64) {
        self.wait.push(wait_ms);
        self.ttft.push(ttft_ms);
        self.e2e.push(e2e_ms);
        self.count += 1;
    }

    pub fn p99_ttft(&mut self) -> f64 {
        self.ttft.p99()
    }
}

/// Full DES output: per-pool and overall stats plus run metadata.
#[derive(Debug, Clone)]
pub struct DesResult {
    pub per_pool: Vec<PoolResult>,
    pub overall: LatencyStats,
    /// Simulated horizon, ms (last completion).
    pub horizon_ms: f64,
    pub n_requests: usize,
    /// Requests the router compressed (CompressAndRoute).
    pub n_compressed: usize,
    /// Simulation events processed (arrivals + completions + drains) —
    /// the numerator of the perf harness's events/sec metric.
    pub n_events: usize,
}

/// Summary for one pool after the run.
#[derive(Debug, Clone)]
pub struct PoolResult {
    pub stats: LatencyStats,
    /// Mean slot utilization over the horizon.
    pub utilization: f64,
    pub max_queue_depth: usize,
    pub slots_per_gpu: u32,
    pub n_gpus: usize,
}

impl DesResult {
    /// The paper's SLO check: overall P99 TTFT <= slo.
    pub fn meets_slo(&mut self, slo_ms: f64) -> bool {
        self.overall.p99_ttft() <= slo_ms
    }

    /// Fraction of requests with TTFT <= slo (the "99.98%" style numbers
    /// in Table 5). Exact in exact metrics mode; within one sketch bin in
    /// streaming mode.
    pub fn attainment(&self, slo_ms: f64) -> f64 {
        self.overall.ttft.fraction_le(slo_ms)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_percentiles() {
        let mut s = LatencyStats::default();
        for i in 1..=1000 {
            s.record(i as f64, 2.0 * i as f64, 3.0 * i as f64);
        }
        assert_eq!(s.count, 1000);
        assert_eq!(s.wait.p99(), 990.0);
        assert_eq!(s.p99_ttft(), 1980.0);
    }

    #[test]
    fn slo_and_attainment() {
        let mut r = DesResult {
            per_pool: vec![],
            overall: LatencyStats::default(),
            horizon_ms: 1000.0,
            n_requests: 100,
            n_compressed: 0,
            n_events: 200,
        };
        for i in 0..100 {
            let ttft = if i < 98 { 10.0 } else { 600.0 };
            r.overall.record(0.0, ttft, ttft + 5.0);
        }
        assert!(!r.meets_slo(500.0)); // p99 = 600
        assert!(r.meets_slo(700.0));
        assert!((r.attainment(500.0) - 0.98).abs() < 1e-12);
    }

    #[test]
    fn streaming_stats_track_percentiles_approximately() {
        let mut exact = LatencyStats::with_capacity(2000);
        let mut sketch = LatencyStats::for_mode(MetricsMode::Streaming, 2000);
        for i in 1..=2000 {
            let v = i as f64 * 0.7;
            exact.record(0.0, v, v + 1.0);
            sketch.record(0.0, v, v + 1.0);
        }
        assert_eq!(exact.count, sketch.count);
        // Zero waits are exact in both modes.
        assert_eq!(exact.wait.p99(), 0.0);
        assert_eq!(sketch.wait.p99(), 0.0);
        let (e, s) = (exact.p99_ttft(), sketch.p99_ttft());
        assert!((s / e - 1.0).abs() < 0.02, "exact {e} sketch {s}");
    }
}
