//! Per-request metric collection for the DES (paper §3.1 Phase 2 step 3:
//! queue wait, TTFT, end-to-end latency; SLO check is P99 TTFT <= T).
//!
//! Collection has two modes (see [`MetricsMode`]): the default **exact**
//! mode stores every sample (what all scenario tables use, so published
//! numbers are bit-stable), and **streaming** mode aggregates into
//! O(1)-memory [`crate::util::stats::LogHistogram`] sketches so memory
//! stays O(pools) instead of O(requests) — the mode the perf harness and
//! high-volume sweeps run in.
//!
//! Three semantics matter for honest SLO numbers (this PR's bugfixes):
//!
//! * **No censoring.** Requests that are still queued when the event
//!   stream drains (a dead or wedged pool) are counted as
//!   [`DesResult::n_unserved`], included in the [`DesResult::attainment`]
//!   denominator, and fail [`DesResult::meets_slo`] outright — at drain
//!   they will never be served, so their TTFT is unbounded.
//! * **No vacuous attainment.** An empty sample answers NaN, never 1.0
//!   (see [`crate::util::stats::Samples::fraction_le`]).
//! * **Time-based warmup.** `warmup_frac` discards requests *arriving*
//!   before `warmup_frac * last_arrival`, not the first K by index —
//!   index-based warmup diverges under non-stationary arrivals, where a
//!   burst front-loads the discarded window.
//!
//! For non-stationary arrivals, [`WindowedStats`] additionally buckets
//! TTFT by arrival time into fixed-width windows so the SLO can be
//! checked *per window* (a fleet sized for the long-run mean passes the
//! aggregate P99 while failing every peak window).

use crate::des::pool::DesPool;
use crate::util::stats::Samples;

/// How the DES aggregates per-request latencies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum MetricsMode {
    /// Store every sample; exact nearest-rank percentiles (the default —
    /// scenario tables depend on exact values).
    #[default]
    Exact,
    /// Streaming log-histogram sketch: O(1) memory per metric,
    /// percentiles within ~1% relative error.
    Streaming,
}

/// Latency samples for one pool (or the fleet overall).
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    pub wait: Samples,
    pub ttft: Samples,
    pub e2e: Samples,
    pub count: usize,
}

impl LatencyStats {
    /// Pre-size the exact-mode sample buffers (perf pass iteration 2:
    /// avoids realloc churn in the DES hot loop).
    pub fn with_capacity(n: usize) -> Self {
        LatencyStats {
            wait: Samples::with_capacity(n),
            ttft: Samples::with_capacity(n),
            e2e: Samples::with_capacity(n),
            count: 0,
        }
    }

    /// Streaming-sketch collection: memory independent of request count.
    pub fn streaming() -> Self {
        LatencyStats {
            wait: Samples::streaming(),
            ttft: Samples::streaming(),
            e2e: Samples::streaming(),
            count: 0,
        }
    }

    /// Collector for the given mode, pre-sized for `n` exact samples.
    pub fn for_mode(mode: MetricsMode, n: usize) -> Self {
        match mode {
            MetricsMode::Exact => Self::with_capacity(n),
            MetricsMode::Streaming => Self::streaming(),
        }
    }

    pub fn record(&mut self, wait_ms: f64, ttft_ms: f64, e2e_ms: f64) {
        self.wait.push(wait_ms);
        self.ttft.push(ttft_ms);
        self.e2e.push(e2e_ms);
        self.count += 1;
    }

    pub fn p99_ttft(&mut self) -> f64 {
        self.ttft.p99()
    }

    /// Fold another collector into this one (shard-merge path): sample
    /// multisets concatenate, so percentiles / attainment over the merge
    /// are bit-identical to a single-collector run (see
    /// [`crate::util::stats::Samples::merge`]).
    pub fn merge(&mut self, other: &LatencyStats) {
        self.wait.merge(&other.wait);
        self.ttft.merge(&other.ttft);
        self.e2e.merge(&other.e2e);
        self.count += other.count;
    }
}

/// TTFT statistics bucketed by arrival time into fixed-width windows
/// (the time-windowed SLO evaluation behind `DesConfig::window_ms`).
///
/// Each window tracks how many measured requests *arrived* in it and the
/// TTFTs of those that were eventually served; the difference is the
/// window's unserved count. Works in both metrics modes, and both DES
/// engines produce bit-identical windows (bucketing depends only on
/// arrival time, which the engines share).
#[derive(Debug, Clone)]
pub struct WindowedStats {
    width_ms: f64,
    mode: MetricsMode,
    /// Absolute index of window 0 (`floor(first_arrival / width)`), so a
    /// replay trace with a large time offset (epoch-style timestamps, or
    /// a long warmup) doesn't allocate empty windows from t = 0.
    base: usize,
    arrived: Vec<usize>,
    ttft: Vec<Samples>,
    /// Closed-loop only: requests shed by admission control, bucketed
    /// by *first* arrival time (all zero on open-loop runs).
    shed: Vec<usize>,
    /// Closed-loop only: requests that exhausted their retry budget.
    abandoned: Vec<usize>,
    /// Memory-mode only ([`crate::des::memory`]): evictions charged to
    /// the victim's arrival window (all zero otherwise). A request
    /// evicted twice counts twice — this tracks preemption *events*,
    /// the thrash signature, not distinct victims.
    preempted: Vec<usize>,
}

impl WindowedStats {
    pub fn new(width_ms: f64, mode: MetricsMode) -> Self {
        assert!(width_ms > 0.0 && width_ms.is_finite());
        WindowedStats {
            width_ms,
            mode,
            base: 0,
            arrived: Vec::new(),
            ttft: Vec::new(),
            shed: Vec::new(),
            abandoned: Vec::new(),
            preempted: Vec::new(),
        }
    }

    pub fn width_ms(&self) -> f64 {
        self.width_ms
    }

    /// Hard cap on allocated windows (~64 MB worst case): storage is
    /// dense from the first measured arrival, so a tiny width over a
    /// long horizon — or a replay trace with a huge internal gap — must
    /// fail loudly instead of grinding into an OOM.
    const MAX_WINDOWS: usize = 1 << 20;

    /// Relative window slot for `arrival_ms`, growing storage as needed.
    /// The first recorded arrival anchors window 0; recording happens in
    /// arrival-time order (and a request's service is never recorded
    /// before its arrival), so nothing can precede the anchor.
    fn slot(&mut self, arrival_ms: f64) -> usize {
        let abs = (arrival_ms / self.width_ms) as usize;
        if self.ttft.is_empty() {
            self.base = abs;
        }
        debug_assert!(abs >= self.base, "record precedes first arrival");
        let i = abs.saturating_sub(self.base);
        assert!(
            i < Self::MAX_WINDOWS,
            "window_ms = {} spans more than {} windows over this \
             horizon; use a wider window",
            self.width_ms,
            Self::MAX_WINDOWS
        );
        while self.ttft.len() <= i {
            self.arrived.push(0);
            self.shed.push(0);
            self.abandoned.push(0);
            self.preempted.push(0);
            self.ttft.push(match self.mode {
                MetricsMode::Exact => Samples::new(),
                MetricsMode::Streaming => Samples::streaming(),
            });
        }
        i
    }

    /// Count a measured request arriving at `arrival_ms` (the window's
    /// attainment denominator).
    pub fn record_arrival(&mut self, arrival_ms: f64) {
        let i = self.slot(arrival_ms);
        self.arrived[i] += 1;
    }

    /// Record the TTFT of a served request against its arrival window.
    pub fn record_served(&mut self, arrival_ms: f64, ttft_ms: f64) {
        let i = self.slot(arrival_ms);
        self.ttft[i].push(ttft_ms);
    }

    /// Count a request shed by admission control against its *first*
    /// arrival's window (closed-loop runs only).
    pub fn record_shed(&mut self, arrival_ms: f64) {
        let i = self.slot(arrival_ms);
        self.shed[i] += 1;
    }

    /// Count a request that exhausted its retry budget against its
    /// first arrival's window (closed-loop runs only).
    pub fn record_abandoned(&mut self, arrival_ms: f64) {
        let i = self.slot(arrival_ms);
        self.abandoned[i] += 1;
    }

    /// Count an eviction against the victim's arrival window
    /// (memory-mode runs only). The victim is still in flight — it
    /// stays in the window's arrival denominator and is served (or
    /// unserved) like any other request.
    pub fn record_preempted(&mut self, arrival_ms: f64) {
        let i = self.slot(arrival_ms);
        self.preempted[i] += 1;
    }

    pub fn n_windows(&self) -> usize {
        self.ttft.len()
    }

    /// Window `i` covers `[start_ms(i), start_ms(i) + width_ms)` in
    /// absolute simulation time.
    pub fn start_ms(&self, i: usize) -> f64 {
        (self.base + i) as f64 * self.width_ms
    }

    pub fn n_arrived(&self, i: usize) -> usize {
        self.arrived[i]
    }

    pub fn n_served(&self, i: usize) -> usize {
        self.ttft[i].len()
    }

    /// Arrived in window `i` but never admitted before the run
    /// drained. Shed and abandoned requests reached a terminal answer
    /// (just not service), so they are not "unserved" — each arrival
    /// lands in exactly one of served/shed/abandoned/unserved.
    pub fn n_unserved(&self, i: usize) -> usize {
        self.arrived[i]
            .saturating_sub(self.ttft[i].len())
            .saturating_sub(self.shed[i])
            .saturating_sub(self.abandoned[i])
    }

    /// Requests first arriving in window `i` that were shed by
    /// admission control.
    pub fn n_shed(&self, i: usize) -> usize {
        self.shed[i]
    }

    /// Requests first arriving in window `i` that ran out of retry
    /// attempts.
    pub fn n_abandoned(&self, i: usize) -> usize {
        self.abandoned[i]
    }

    /// Evictions charged to window-`i` arrivals (memory-mode runs).
    pub fn n_preempted(&self, i: usize) -> usize {
        self.preempted[i]
    }

    /// P99 TTFT over requests that arrived in window `i`; NaN if none
    /// were served.
    pub fn p99_ttft(&mut self, i: usize) -> f64 {
        if self.ttft[i].is_empty() {
            return f64::NAN;
        }
        self.ttft[i].p99()
    }

    /// Fraction of window-`i` arrivals with TTFT <= `slo_ms`; unserved
    /// arrivals count against attainment. NaN for an empty window.
    pub fn attainment(&self, i: usize, slo_ms: f64) -> f64 {
        let arrived = self.arrived[i];
        if arrived == 0 {
            return f64::NAN;
        }
        let served = self.ttft[i].len();
        let served_le = if served == 0 {
            0.0
        } else {
            self.ttft[i].fraction_le(slo_ms) * served as f64
        };
        served_le / arrived as f64
    }

    /// A window with no arrivals passes vacuously; otherwise every
    /// arrival must have been *served* — not shed, not abandoned, not
    /// left queued — and the window P99 TTFT must meet the SLO.
    pub fn meets_slo(&mut self, i: usize, slo_ms: f64) -> bool {
        if self.arrived[i] == 0 {
            return true;
        }
        self.n_unserved(i) == 0
            && self.shed[i] == 0
            && self.abandoned[i] == 0
            && self.p99_ttft(i) <= slo_ms
    }

    /// Size-to-peak feasibility: *every* window meets the SLO.
    pub fn all_meet_slo(&mut self, slo_ms: f64) -> bool {
        for i in 0..self.n_windows() {
            if !self.meets_slo(i, slo_ms) {
                return false;
            }
        }
        true
    }

    /// Fold another windowed series into this one (shard-merge path).
    /// Windows align on *absolute* indices — each side anchors its base
    /// at its own first measured arrival, so the merged base is the
    /// earlier of the two. Per-window arrival counts add and TTFT
    /// samples merge multiset-exactly, making the merged series
    /// bit-identical (counts, per-window percentiles, attainment) to a
    /// single-collector run over the union of the streams.
    pub fn merge(&mut self, other: &WindowedStats) {
        assert!(
            self.width_ms == other.width_ms,
            "window width mismatch: {} vs {}",
            self.width_ms,
            other.width_ms
        );
        assert_eq!(self.mode, other.mode, "metrics mode mismatch");
        if other.ttft.is_empty() {
            return;
        }
        if self.ttft.is_empty() {
            *self = other.clone();
            return;
        }
        let new_base = self.base.min(other.base);
        let self_end = self.base + self.ttft.len();
        let other_end = other.base + other.ttft.len();
        let new_len = self_end.max(other_end) - new_base;
        assert!(
            new_len <= Self::MAX_WINDOWS,
            "merged series spans more than {} windows",
            Self::MAX_WINDOWS
        );
        let mut arrived = vec![0usize; new_len];
        let mut shed = vec![0usize; new_len];
        let mut abandoned = vec![0usize; new_len];
        let mut preempted = vec![0usize; new_len];
        let mut ttft: Vec<Samples> = (0..new_len)
            .map(|_| match self.mode {
                MetricsMode::Exact => Samples::new(),
                MetricsMode::Streaming => Samples::streaming(),
            })
            .collect();
        let off = self.base - new_base;
        for (i, t) in self.ttft.drain(..).enumerate() {
            ttft[off + i] = t;
        }
        for (i, &a) in self.arrived.iter().enumerate() {
            arrived[off + i] = a;
        }
        for (i, &s) in self.shed.iter().enumerate() {
            shed[off + i] = s;
        }
        for (i, &a) in self.abandoned.iter().enumerate() {
            abandoned[off + i] = a;
        }
        for (i, &p) in self.preempted.iter().enumerate() {
            preempted[off + i] = p;
        }
        let off = other.base - new_base;
        for (i, t) in other.ttft.iter().enumerate() {
            ttft[off + i].merge(t);
        }
        for (i, &a) in other.arrived.iter().enumerate() {
            arrived[off + i] += a;
        }
        for (i, &s) in other.shed.iter().enumerate() {
            shed[off + i] += s;
        }
        for (i, &a) in other.abandoned.iter().enumerate() {
            abandoned[off + i] += a;
        }
        for (i, &p) in other.preempted.iter().enumerate() {
            preempted[off + i] += p;
        }
        self.base = new_base;
        self.arrived = arrived;
        self.shed = shed;
        self.abandoned = abandoned;
        self.preempted = preempted;
        self.ttft = ttft;
    }
}

/// Shared per-run metric collection for both DES engines (production
/// calendar-queue and the reference heap): per-pool + overall latency
/// stats, optional windowed stats, and the time-based warmup gate.
/// Keeping the recording rules here guarantees the two engines stay
/// bit-identical.
#[derive(Debug)]
pub struct MetricsCollector {
    pub per_pool: Vec<LatencyStats>,
    pub overall: LatencyStats,
    pub windows: Option<WindowedStats>,
    /// Requests arriving before this instant are excluded from stats.
    pub warmup_time_ms: f64,
    /// Closed-loop counters (all zero on open-loop runs): attempts
    /// started, requests abandoned after exhausting retries, and
    /// requests shed by admission control. Warmup-gated on the
    /// request's *first* arrival, like every other stat.
    pub n_attempts: usize,
    pub n_abandoned: usize,
    pub n_shed: usize,
}

impl MetricsCollector {
    pub fn new(
        mode: MetricsMode,
        n_pools: usize,
        n_requests: usize,
        window_ms: Option<f64>,
        warmup_time_ms: f64,
    ) -> Self {
        let per_pool_cap = n_requests / n_pools.max(1) + 16;
        MetricsCollector {
            per_pool: (0..n_pools)
                .map(|_| LatencyStats::for_mode(mode, per_pool_cap))
                .collect(),
            overall: LatencyStats::for_mode(mode, n_requests),
            windows: window_ms.map(|w| WindowedStats::new(w, mode)),
            warmup_time_ms,
            n_attempts: 0,
            n_abandoned: 0,
            n_shed: 0,
        }
    }

    /// Whether a request arriving at `arrival_ms` is measured (past the
    /// time-based warmup cutoff).
    pub fn measured(&self, arrival_ms: f64) -> bool {
        arrival_ms >= self.warmup_time_ms
    }

    /// Count an arrival (windowed attainment denominators).
    pub fn record_arrival(&mut self, arrival_ms: f64) {
        if !self.measured(arrival_ms) {
            return;
        }
        if let Some(w) = &mut self.windows {
            w.record_arrival(arrival_ms);
        }
    }

    /// Record a served request's latencies (called at admission).
    pub fn record(
        &mut self,
        pool: usize,
        arrival_ms: f64,
        wait_ms: f64,
        ttft_ms: f64,
        e2e_ms: f64,
    ) {
        if !self.measured(arrival_ms) {
            return;
        }
        self.per_pool[pool].record(wait_ms, ttft_ms, e2e_ms);
        self.overall.record(wait_ms, ttft_ms, e2e_ms);
        if let Some(w) = &mut self.windows {
            w.record_served(arrival_ms, ttft_ms);
        }
    }

    /// Count one attempt of a request that first arrived at
    /// `first_arrival_ms` (closed-loop runs; retries make this exceed
    /// the request count — the retry-amplification numerator).
    pub fn record_attempt(&mut self, first_arrival_ms: f64) {
        if self.measured(first_arrival_ms) {
            self.n_attempts += 1;
        }
    }

    /// Count a request abandoned after its last allowed attempt.
    pub fn record_abandoned(&mut self, first_arrival_ms: f64) {
        if !self.measured(first_arrival_ms) {
            return;
        }
        self.n_abandoned += 1;
        if let Some(w) = &mut self.windows {
            w.record_abandoned(first_arrival_ms);
        }
    }

    /// Count a request shed (terminally) by admission control.
    pub fn record_shed(&mut self, first_arrival_ms: f64) {
        if !self.measured(first_arrival_ms) {
            return;
        }
        self.n_shed += 1;
        if let Some(w) = &mut self.windows {
            w.record_shed(first_arrival_ms);
        }
    }

    /// Count an eviction against the victim's arrival window
    /// (memory-mode runs; warmup-gated like every other windowed
    /// stat — the structural per-pool preemption counters in
    /// [`crate::des::memory`] are *not* gated).
    pub fn record_preempted(&mut self, arrival_ms: f64) {
        if !self.measured(arrival_ms) {
            return;
        }
        if let Some(w) = &mut self.windows {
            w.record_preempted(arrival_ms);
        }
    }

    /// Post-run anti-censoring scan, shared by both engines: every
    /// measured request still sitting in a pool queue when the event
    /// stream drained (a dead or wedged pool — live pools always drain)
    /// is unserved, never silently dropped. Returns
    /// `(n_unserved, max_unserved_wait_ms, per_pool_unserved)`.
    pub fn scan_unserved<F: Fn(u32) -> f64>(
        &self,
        pools: &[DesPool],
        arrival_of: F,
        horizon_ms: f64,
    ) -> (usize, f64, Vec<usize>) {
        let mut n_unserved = 0usize;
        let mut max_wait = 0.0f64;
        let mut per_pool = vec![0usize; pools.len()];
        for (p, pool) in pools.iter().enumerate() {
            for &req in &pool.queue {
                let arrival = arrival_of(req);
                if !self.measured(arrival) {
                    continue;
                }
                n_unserved += 1;
                per_pool[p] += 1;
                max_wait = max_wait.max(horizon_ms - arrival);
            }
        }
        (n_unserved, max_wait, per_pool)
    }
}

/// Full DES output: per-pool and overall stats plus run metadata.
#[derive(Debug, Clone)]
pub struct DesResult {
    pub per_pool: Vec<PoolResult>,
    pub overall: LatencyStats,
    /// Simulated horizon, ms (last event processed).
    pub horizon_ms: f64,
    pub n_requests: usize,
    /// Requests the router compressed (CompressAndRoute).
    pub n_compressed: usize,
    /// Simulation events processed (arrivals + completions + drains) —
    /// the numerator of the perf harness's events/sec metric.
    pub n_events: usize,
    /// Measured requests still queued when the event stream drained
    /// (e.g. routed to a dead pool). Censoring these silently is the bug
    /// that let an overloaded-or-broken fleet report perfect attainment.
    pub n_unserved: usize,
    /// Largest wait-so-far (horizon - arrival) among unserved requests;
    /// 0 when every request was served. Diagnostic — `meets_slo` fails
    /// on any unserved request regardless of this value.
    pub max_unserved_wait_ms: f64,
    /// Closed-loop only: attempts started for measured requests
    /// (retries inflate this past the request count). 0 on open-loop
    /// runs.
    pub n_attempts: usize,
    /// Closed-loop only: measured requests that timed out on their
    /// last allowed attempt (the client gave up).
    pub n_abandoned: usize,
    /// Closed-loop only: measured requests terminally rejected by
    /// admission control (bounded queue or open circuit breaker).
    pub n_shed: usize,
    /// Per-window TTFT series when `DesConfig::window_ms` was set.
    pub windows: Option<WindowedStats>,
    /// Memory-mode only: evictions across the run (a request evicted
    /// twice counts twice). 0 on memory-less runs.
    pub n_preempted: usize,
    /// Memory-mode only: total time victims spent between eviction and
    /// re-admission (plus swap round-trips), ms. The preemption-delay
    /// account — served latencies already include it.
    pub preempt_stall_ms: f64,
    /// Memory-mode only: max over instances of peak KV occupancy over
    /// capacity. Can exceed 1.0 when a sole resident outgrows its
    /// instance (nothing can be evicted to make room). 0 otherwise.
    pub kv_peak_util: f64,
    /// Memory-mode only: time-averaged KV occupancy over total
    /// capacity across the horizon. 0 on memory-less runs.
    pub kv_mean_util: f64,
}

/// Summary for one pool after the run.
#[derive(Debug, Clone)]
pub struct PoolResult {
    pub stats: LatencyStats,
    /// Mean slot utilization over the horizon.
    pub utilization: f64,
    pub max_queue_depth: usize,
    pub slots_per_gpu: u32,
    pub n_gpus: usize,
    /// Measured requests still in this pool's queue at the end of the
    /// run.
    pub n_unserved: usize,
    /// Memory-mode only: evictions in this pool (structural — not
    /// warmup-gated, unlike the latency stats).
    pub n_preempted: usize,
    /// Memory-mode only: victim stall time in this pool, ms.
    pub preempt_stall_ms: f64,
    /// Memory-mode only: max over this pool's instances of peak KV
    /// occupancy over capacity.
    pub kv_peak_util: f64,
    /// Memory-mode only: time-averaged KV occupancy over this pool's
    /// capacity across the horizon.
    pub kv_mean_util: f64,
}

impl DesResult {
    /// The paper's SLO check — overall P99 TTFT <= slo — hardened
    /// against censoring: any unserved request fails it. Unserved means
    /// still queued when the event stream *drained*, so it will never be
    /// served — its TTFT is unbounded no matter how short its wait-so-far
    /// looks when a short horizon cuts the run off.
    pub fn meets_slo(&mut self, slo_ms: f64) -> bool {
        if self.overall.count == 0 {
            // Nothing measured (e.g. warmup swallowed the whole run,
            // which also hides unserved backlogs from the scan): with
            // real traffic the check is undefined, and undefined must
            // not read as passing. A zero-request run passes vacuously.
            return self.n_requests == 0
                && self.n_abandoned == 0
                && self.n_shed == 0;
        }
        // Closed-loop runs measure first-attempt-to-final-success
        // latency (waits/TTFT are against the *first* arrival), and
        // a request whose final answer was "give up" or "go away"
        // fails the SLO no matter how fast the answer came.
        self.n_unserved == 0
            && self.n_abandoned == 0
            && self.n_shed == 0
            && self.overall.p99_ttft() <= slo_ms
    }

    /// Windowed SLO check: every window must meet the SLO (the
    /// size-to-peak feasibility criterion). Falls back to the aggregate
    /// [`Self::meets_slo`] when the run collected no windows.
    pub fn meets_slo_in_every_window(&mut self, slo_ms: f64) -> bool {
        match &mut self.windows {
            Some(w) => w.all_meet_slo(slo_ms),
            None => self.meets_slo(slo_ms),
        }
    }

    /// Measured requests that reached *any* terminal answer or were
    /// stranded: served + abandoned + shed + unserved. The attainment
    /// and retry-amplification denominator.
    pub fn n_measured(&self) -> usize {
        self.overall.count + self.n_unserved + self.n_abandoned + self.n_shed
    }

    /// Fraction of requests with TTFT <= slo (the "99.98%" style numbers
    /// in Table 5). Exact in exact metrics mode; within one sketch bin in
    /// streaming mode. Unserved, abandoned, and shed requests count
    /// against attainment (they are in the denominator); NaN when
    /// nothing was measured at all.
    pub fn attainment(&self, slo_ms: f64) -> f64 {
        let denom = self.n_measured();
        if denom == 0 {
            return f64::NAN;
        }
        let served_le = if self.overall.count == 0 {
            0.0
        } else {
            self.overall.ttft.fraction_le(slo_ms)
                * self.overall.count as f64
        };
        served_le / denom as f64
    }

    /// Useful work per second: requests *served to completion* over
    /// the horizon. Open-loop runs have goodput == throughput.
    pub fn goodput_rps(&self) -> f64 {
        if self.horizon_ms <= 0.0 {
            return 0.0;
        }
        self.overall.count as f64 / (self.horizon_ms / 1000.0)
    }

    /// Offered work per second: *attempts* over the horizon (each
    /// retry is another unit of offered load). Falls back to the
    /// served count on open-loop runs, where attempts are not
    /// tracked and every request is exactly one attempt.
    pub fn throughput_rps(&self) -> f64 {
        if self.horizon_ms <= 0.0 {
            return 0.0;
        }
        let offered =
            if self.n_attempts > 0 { self.n_attempts } else {
                self.overall.count
            };
        offered as f64 / (self.horizon_ms / 1000.0)
    }

    /// Attempts per measured request — 1.0 means no retries; a
    /// sustained value above 1 after the triggering perturbation has
    /// passed is the metastable retry-storm signature. 1.0 on
    /// open-loop runs (attempts untracked) and when nothing was
    /// measured.
    pub fn retry_amplification(&self) -> f64 {
        let denom = self.n_measured();
        if self.n_attempts == 0 || denom == 0 {
            return 1.0;
        }
        self.n_attempts as f64 / denom as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empty_result() -> DesResult {
        DesResult {
            per_pool: vec![],
            overall: LatencyStats::default(),
            horizon_ms: 1000.0,
            n_requests: 100,
            n_compressed: 0,
            n_events: 200,
            n_unserved: 0,
            max_unserved_wait_ms: 0.0,
            n_attempts: 0,
            n_abandoned: 0,
            n_shed: 0,
            windows: None,
            n_preempted: 0,
            preempt_stall_ms: 0.0,
            kv_peak_util: 0.0,
            kv_mean_util: 0.0,
        }
    }

    #[test]
    fn windowed_preemptions_count_events_not_victims() {
        for mode in [MetricsMode::Exact, MetricsMode::Streaming] {
            let mut w = WindowedStats::new(1000.0, mode);
            w.record_arrival(100.0);
            // The same victim evicted twice: two preemption events,
            // still one arrival, and (eventually) one served request.
            w.record_preempted(100.0);
            w.record_preempted(100.0);
            w.record_served(100.0, 250.0);
            assert_eq!(w.n_preempted(0), 2);
            assert_eq!(w.n_unserved(0), 0);
            // Preemption alone does not fail the window — the stall is
            // already inside the served TTFT, which is what's judged.
            assert!(w.meets_slo(0, 500.0), "{mode:?}");
            assert!(!w.meets_slo(0, 200.0), "{mode:?}");
            // Counts survive the shard merge, including re-anchoring.
            let mut early = WindowedStats::new(1000.0, mode);
            early.record_arrival(50.0);
            early.record_served(50.0, 10.0);
            let mut m = w.clone();
            m.merge(&early);
            assert_eq!(m.n_preempted(0), 2);
            let mut empty = WindowedStats::new(1000.0, mode);
            empty.merge(&w);
            assert_eq!(empty.n_preempted(0), 2);
        }
    }

    #[test]
    fn record_and_percentiles() {
        let mut s = LatencyStats::default();
        for i in 1..=1000 {
            s.record(i as f64, 2.0 * i as f64, 3.0 * i as f64);
        }
        assert_eq!(s.count, 1000);
        assert_eq!(s.wait.p99(), 990.0);
        assert_eq!(s.p99_ttft(), 1980.0);
    }

    #[test]
    fn slo_and_attainment() {
        let mut r = empty_result();
        for i in 0..100 {
            let ttft = if i < 98 { 10.0 } else { 600.0 };
            r.overall.record(0.0, ttft, ttft + 5.0);
        }
        assert!(!r.meets_slo(500.0)); // p99 = 600
        assert!(r.meets_slo(700.0));
        assert!((r.attainment(500.0) - 0.98).abs() < 1e-12);
    }

    #[test]
    fn unserved_requests_poison_slo_and_attainment() {
        let mut r = empty_result();
        for _ in 0..80 {
            r.overall.record(0.0, 10.0, 15.0);
        }
        // 20 requests never served; the oldest has waited 900 ms.
        r.n_unserved = 20;
        r.max_unserved_wait_ms = 900.0;
        // Served-only P99 is 10 ms, but the backlog is permanent (the
        // event stream drained) — the pre-fix check (p99 only) would
        // have passed.
        assert!(r.overall.p99_ttft() <= 500.0);
        assert!(!r.meets_slo(500.0));
        // Attainment counts the unserved in the denominator: 80/100.
        assert!((r.attainment(500.0) - 0.80).abs() < 1e-12);
        // A short horizon (wait-so-far under the SLO) must not re-hide
        // the backlog: unserved-at-drain means never-served.
        r.max_unserved_wait_ms = 100.0;
        assert!(!r.meets_slo(500.0));
        r.n_unserved = 0;
        assert!(r.meets_slo(500.0));
    }

    #[test]
    fn empty_result_reports_nan_attainment_not_perfect() {
        let mut r = empty_result();
        assert!(r.attainment(500.0).is_nan());
        // Real traffic but nothing measured: undefined, never "passing".
        assert!(!r.meets_slo(500.0));
        // A literally empty simulation passes vacuously.
        r.n_requests = 0;
        assert!(r.meets_slo(500.0));
        // A dead pool: nothing served, everything unserved -> 0%, and
        // the vacuous 0-ms P99 of the empty sample can never pass.
        let mut dead = empty_result();
        dead.n_unserved = 50;
        assert_eq!(dead.attainment(500.0), 0.0);
        assert!(!dead.meets_slo(500.0));
    }

    #[test]
    fn closed_loop_counters_poison_slo_and_feed_amplification() {
        let mut r = empty_result();
        for _ in 0..90 {
            r.overall.record(0.0, 10.0, 15.0);
        }
        r.n_abandoned = 6;
        r.n_shed = 4;
        r.n_attempts = 150;
        // Served P99 is fine, but 10 requests got a terminal "no".
        assert!(!r.meets_slo(500.0));
        assert_eq!(r.n_measured(), 100);
        assert!((r.attainment(500.0) - 0.90).abs() < 1e-12);
        assert!((r.retry_amplification() - 1.5).abs() < 1e-12);
        // horizon 1000 ms: goodput 90 rps, throughput 150 rps.
        assert!((r.goodput_rps() - 90.0).abs() < 1e-9);
        assert!((r.throughput_rps() - 150.0).abs() < 1e-9);
        r.n_abandoned = 0;
        r.n_shed = 0;
        assert!(r.meets_slo(500.0));
    }

    #[test]
    fn open_loop_results_report_unit_amplification() {
        let mut r = empty_result();
        for _ in 0..50 {
            r.overall.record(0.0, 10.0, 15.0);
        }
        assert_eq!(r.retry_amplification(), 1.0);
        assert!((r.goodput_rps() - r.throughput_rps()).abs() < 1e-12);
        assert_eq!(empty_result().retry_amplification(), 1.0);
    }

    #[test]
    fn windowed_shed_and_abandoned_fail_their_window_only() {
        for mode in [MetricsMode::Exact, MetricsMode::Streaming] {
            let mut w = WindowedStats::new(1000.0, mode);
            // Window 0: clean.
            w.record_arrival(100.0);
            w.record_served(100.0, 50.0);
            // Window 1: one served, one shed, one abandoned.
            for t in [1100.0, 1200.0, 1300.0] {
                w.record_arrival(t);
            }
            w.record_served(1100.0, 50.0);
            w.record_shed(1200.0);
            w.record_abandoned(1300.0);
            assert_eq!(w.n_shed(1), 1);
            assert_eq!(w.n_abandoned(1), 1);
            // Shed/abandoned are terminal, not "unserved".
            assert_eq!(w.n_unserved(1), 0);
            assert!(w.meets_slo(0, 500.0), "{mode:?}");
            assert!(!w.meets_slo(1, 500.0), "{mode:?}");
            // They count against window attainment: 1 of 3 attained.
            assert!((w.attainment(1, 500.0) - 1.0 / 3.0).abs() < 1e-12);

            // Shard-merge carries the counters through re-anchoring.
            let mut early = WindowedStats::new(1000.0, mode);
            early.record_arrival(100.0);
            early.record_served(100.0, 10.0);
            let mut m = w.clone();
            m.merge(&early);
            assert_eq!(m.n_shed(1), 1);
            assert_eq!(m.n_abandoned(1), 1);
            assert_eq!(m.n_arrived(0), 2);
        }
    }

    #[test]
    fn streaming_stats_track_percentiles_approximately() {
        let mut exact = LatencyStats::with_capacity(2000);
        let mut sketch = LatencyStats::for_mode(MetricsMode::Streaming, 2000);
        for i in 1..=2000 {
            let v = i as f64 * 0.7;
            exact.record(0.0, v, v + 1.0);
            sketch.record(0.0, v, v + 1.0);
        }
        assert_eq!(exact.count, sketch.count);
        // Zero waits are exact in both modes.
        assert_eq!(exact.wait.p99(), 0.0);
        assert_eq!(sketch.wait.p99(), 0.0);
        let (e, s) = (exact.p99_ttft(), sketch.p99_ttft());
        assert!((s / e - 1.0).abs() < 0.02, "exact {e} sketch {s}");
    }

    #[test]
    fn windowed_stats_bucket_by_arrival_time() {
        for mode in [MetricsMode::Exact, MetricsMode::Streaming] {
            let mut w = WindowedStats::new(1000.0, mode);
            // Window 0: three arrivals, all served fast.
            for t in [100.0, 400.0, 900.0] {
                w.record_arrival(t);
                w.record_served(t, 50.0);
            }
            // Window 2: two arrivals, one served slow, one never served.
            w.record_arrival(2100.0);
            w.record_served(2100.0, 800.0);
            w.record_arrival(2500.0);
            assert_eq!(w.n_windows(), 3);
            assert_eq!(w.start_ms(2), 2000.0);
            assert_eq!(w.n_arrived(0), 3);
            assert_eq!(w.n_unserved(0), 0);
            assert_eq!(w.n_arrived(1), 0);
            assert_eq!(w.n_unserved(2), 1);
            assert_eq!(w.p99_ttft(0), 50.0);
            assert!(w.p99_ttft(1).is_nan());
            assert!((w.attainment(0, 500.0) - 1.0).abs() < 1e-12);
            assert!(w.attainment(1, 500.0).is_nan());
            // Window 2: 0 of 2 arrivals attained (one slow, one unserved).
            assert!((w.attainment(2, 500.0) - 0.0).abs() < 1e-12);
            assert!((w.attainment(2, 900.0) - 0.5).abs() < 1e-12);
            assert!(w.meets_slo(0, 500.0), "{mode:?}");
            assert!(w.meets_slo(1, 500.0), "empty window passes vacuously");
            assert!(!w.meets_slo(2, 900.0), "unserved arrival must fail");
            assert!(!w.all_meet_slo(500.0));
        }
    }

    #[test]
    fn collector_gates_on_time_based_warmup() {
        let mut c = MetricsCollector::new(
            MetricsMode::Exact, 2, 100, Some(500.0), 1000.0,
        );
        c.record_arrival(400.0); // warmup: dropped
        c.record(0, 400.0, 1.0, 2.0, 3.0);
        assert_eq!(c.overall.count, 0);
        c.record_arrival(1200.0);
        c.record(1, 1200.0, 1.0, 2.0, 3.0);
        assert_eq!(c.overall.count, 1);
        assert_eq!(c.per_pool[0].count, 0);
        assert_eq!(c.per_pool[1].count, 1);
        // The first *measured* arrival anchors window 0 (base offset):
        // no empty windows are allocated for the warmup span.
        let w = c.windows.as_ref().unwrap();
        assert_eq!(w.n_windows(), 1);
        assert_eq!(w.start_ms(0), 1000.0);
        assert_eq!(w.n_arrived(0), 1);
        assert_eq!(w.n_served(0), 1);
    }

    #[test]
    fn merged_windows_match_a_single_collector() {
        for mode in [MetricsMode::Exact, MetricsMode::Streaming] {
            // One collector sees everything; two shards split the same
            // stream by parity and are merged (later-base into earlier).
            let mut all = WindowedStats::new(1000.0, mode);
            let mut a = WindowedStats::new(1000.0, mode);
            let mut b = WindowedStats::new(1000.0, mode);
            for i in 0..50usize {
                let t = 3000.0 + i as f64 * 137.0;
                let shard = if i % 2 == 0 { &mut a } else { &mut b };
                all.record_arrival(t);
                shard.record_arrival(t);
                if i % 7 != 0 {
                    all.record_served(t, 10.0 + i as f64);
                    shard.record_served(t, 10.0 + i as f64);
                }
            }
            let mut m = a.clone();
            m.merge(&b);
            assert_eq!(m.n_windows(), all.n_windows());
            for i in 0..all.n_windows() {
                assert_eq!(m.start_ms(i), all.start_ms(i));
                assert_eq!(m.n_arrived(i), all.n_arrived(i));
                assert_eq!(m.n_served(i), all.n_served(i));
                let (x, y) = (m.p99_ttft(i), all.p99_ttft(i));
                assert!(
                    x == y || (x.is_nan() && y.is_nan()),
                    "{mode:?} window {i}: {x} vs {y}"
                );
            }
            // Merging into an empty series adopts the other verbatim,
            // and an empty right-hand side is a no-op.
            let mut empty = WindowedStats::new(1000.0, mode);
            empty.merge(&all);
            assert_eq!(empty.n_windows(), all.n_windows());
            let before = m.n_windows();
            m.merge(&WindowedStats::new(1000.0, mode));
            assert_eq!(m.n_windows(), before);
        }
    }

    #[test]
    #[should_panic(expected = "window width mismatch")]
    fn merging_mismatched_window_widths_panics() {
        let mut a = WindowedStats::new(1000.0, MetricsMode::Exact);
        a.record_arrival(10.0);
        let mut b = WindowedStats::new(500.0, MetricsMode::Exact);
        b.record_arrival(10.0);
        a.merge(&b);
    }

    #[test]
    fn windowed_stats_anchor_at_first_arrival_not_time_zero() {
        // An epoch-offset replay trace must not allocate ~10^8 empty
        // windows between t = 0 and the first arrival.
        let mut w = WindowedStats::new(10_000.0, MetricsMode::Exact);
        let epoch = 1.7e12;
        w.record_arrival(epoch + 500.0);
        w.record_served(epoch + 500.0, 42.0);
        w.record_arrival(epoch + 25_000.0);
        assert_eq!(w.n_windows(), 3);
        assert_eq!(w.start_ms(0), (epoch / 10_000.0).floor() * 10_000.0);
        assert_eq!(w.n_arrived(0), 1);
        assert_eq!(w.n_unserved(2), 1);
    }
}
