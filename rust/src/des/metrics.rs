//! Per-request metric collection for the DES (paper §3.1 Phase 2 step 3:
//! queue wait, TTFT, end-to-end latency; SLO check is P99 TTFT <= T).

use crate::util::stats::Samples;

/// Latency samples for one pool (or the fleet overall).
#[derive(Debug, Clone, Default)]
pub struct LatencyStats {
    pub wait: Samples,
    pub ttft: Samples,
    pub e2e: Samples,
    pub count: usize,
}

impl LatencyStats {
    /// Pre-size the sample buffers (perf pass iteration 2: avoids
    /// realloc churn in the DES hot loop).
    pub fn with_capacity(n: usize) -> Self {
        LatencyStats {
            wait: Samples::with_capacity(n),
            ttft: Samples::with_capacity(n),
            e2e: Samples::with_capacity(n),
            count: 0,
        }
    }

    pub fn record(&mut self, wait_ms: f64, ttft_ms: f64, e2e_ms: f64) {
        self.wait.push(wait_ms);
        self.ttft.push(ttft_ms);
        self.e2e.push(e2e_ms);
        self.count += 1;
    }

    pub fn p99_ttft(&mut self) -> f64 {
        self.ttft.p99()
    }
}

/// Full DES output: per-pool and overall stats plus run metadata.
#[derive(Debug, Clone)]
pub struct DesResult {
    pub per_pool: Vec<PoolResult>,
    pub overall: LatencyStats,
    /// Simulated horizon, ms (last completion).
    pub horizon_ms: f64,
    pub n_requests: usize,
    /// Requests the router compressed (CompressAndRoute).
    pub n_compressed: usize,
}

/// Summary for one pool after the run.
#[derive(Debug, Clone)]
pub struct PoolResult {
    pub stats: LatencyStats,
    /// Mean slot utilization over the horizon.
    pub utilization: f64,
    pub max_queue_depth: usize,
    pub slots_per_gpu: u32,
    pub n_gpus: usize,
}

impl DesResult {
    /// The paper's SLO check: overall P99 TTFT <= slo.
    pub fn meets_slo(&mut self, slo_ms: f64) -> bool {
        self.overall.p99_ttft() <= slo_ms
    }

    /// Fraction of requests with TTFT <= slo (the "99.98%" style numbers
    /// in Table 5).
    pub fn attainment(&self, slo_ms: f64) -> f64 {
        let v = self.overall.ttft.values();
        if v.is_empty() {
            return 1.0;
        }
        v.iter().filter(|&&t| t <= slo_ms).count() as f64 / v.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_percentiles() {
        let mut s = LatencyStats::default();
        for i in 1..=1000 {
            s.record(i as f64, 2.0 * i as f64, 3.0 * i as f64);
        }
        assert_eq!(s.count, 1000);
        assert_eq!(s.wait.p99(), 990.0);
        assert_eq!(s.p99_ttft(), 1980.0);
    }

    #[test]
    fn slo_and_attainment() {
        let mut r = DesResult {
            per_pool: vec![],
            overall: LatencyStats::default(),
            horizon_ms: 1000.0,
            n_requests: 100,
            n_compressed: 0,
        };
        for i in 0..100 {
            let ttft = if i < 98 { 10.0 } else { 600.0 };
            r.overall.record(0.0, ttft, ttft + 5.0);
        }
        assert!(!r.meets_slo(500.0)); // p99 = 600
        assert!(r.meets_slo(700.0));
        assert!((r.attainment(500.0) - 0.98).abs() < 1e-12);
    }
}
