//! Deterministic fault injection for the DES (ROADMAP item 5).
//!
//! A [`FaultScript`] is plain data: GPU failures (with recovery times
//! and an optional post-recovery warm-up inflation window) and
//! stragglers (service-time inflation windows). Scripts come from three
//! places — hand-written structs, a TOML file
//! ([`FaultScript::from_toml_str`], see `data/faults/example.toml`), or
//! a seeded stochastic model ([`FaultScript::generate`], Poisson
//! failures with exponential MTTR draws) — and all three produce the
//! same deterministic replay: the script fully determines every outage.
//!
//! # Execution model: faults as a pure function of (pool, instance, t)
//!
//! The engines never carry mutable fault state. A script compiles into
//! a per-pool view ([`CompiledFaults`]) queried at admission time,
//! mirroring how `CapWindow` membership is evaluated functionally in
//! `eff_cap`:
//!
//! * **Failures** mark the *top* `n_gpus` instances of the pool as down
//!   over `[start_ms, recover_ms)`: a down instance admits nothing, but
//!   requests already running on it complete normally (fail-stop
//!   without preemption, consistent with the cap-window rule that
//!   in-flight requests are never preempted). Utilization stays
//!   relative to *nominal* capacity, so an outage shows up as lost
//!   utilization, not a shrunken denominator.
//! * **Inflations** (stragglers, and the warm-up window
//!   `[recover_ms, recover_ms + warm_ms)` after each failure) multiply
//!   the iteration latency `t_iter` at admission by the product of all
//!   windows covering the chosen instance — inflating hold, prefill,
//!   and TTFT exactly as a slow or cold GPU would.
//!
//! Because admission-time evaluation needs no new events, the only
//! events a script adds are queue re-examinations ([`Self::drains`],
//! reusing `EventKind::Drain`) at each failure's `recover_ms` — the one
//! moment admission capacity *increases* while a queue may be waiting.
//! Straggler boundaries and failure starts change no admission
//! capacity, so they need no events. Drains are pushed at init in
//! script order (after cap-window drains); each shard pushes only its
//! owned pools' drains in the same order, preserving the per-pool
//! relative event order — which is exactly the invariant the sharded
//! engine's bit-identity proof rests on (see `crate::des::shard`).

use crate::des::engine::SimPool;
use crate::des::input::ConfigError;
use crate::workload::rng::Pcg64;
use crate::workload::streams;

/// Salt mixed into the user seed for [`FaultScript::generate`] so the
/// fault stream never correlates with the arrival/length/routing
/// streams drawn from the same seed (which own Pcg64 streams 1–3 and
/// the generator's 4+2k/5+2k block streams).
const FAULT_SEED_SALT: u64 = 0x9e37_79b9_7f4a_7c15;

/// One GPU outage: the top `n_gpus` instances of `pool` stop admitting
/// over `[start_ms, recover_ms)`, then serve at `warm_factor` x
/// iteration latency over `[recover_ms, recover_ms + warm_ms)` while
/// caches refill (cold start).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuFailure {
    pub pool: usize,
    /// Concurrently failed instances (the pool's top indices).
    pub n_gpus: usize,
    pub start_ms: f64,
    pub recover_ms: f64,
    /// Cold-start window length after recovery (0 = instant warm).
    pub warm_ms: f64,
    /// Iteration-latency multiplier during the warm-up window.
    pub warm_factor: f64,
}

/// A straggler episode: the top `n_gpus` instances of `pool` serve at
/// `factor` x iteration latency over `[start_ms, end_ms)`.
#[derive(Debug, Clone, PartialEq)]
pub struct Straggler {
    pub pool: usize,
    pub n_gpus: usize,
    pub start_ms: f64,
    pub end_ms: f64,
    pub factor: f64,
}

/// A deterministic fault schedule for one simulation run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultScript {
    pub failures: Vec<GpuFailure>,
    pub stragglers: Vec<Straggler>,
}

/// Parameters for the seeded stochastic script generator.
#[derive(Debug, Clone)]
pub struct FaultModel {
    /// Per-GPU failure rate (failures per GPU-day); the paper's Eq. 6
    /// presets use 0.0065/day.
    pub failures_per_gpu_day: f64,
    /// Mean time to recovery, drawn exponentially per failure.
    pub mttr_ms: f64,
    /// Cold-start window after each recovery.
    pub warm_ms: f64,
    /// Iteration-latency multiplier while warming up.
    pub warm_factor: f64,
}

const MS_PER_DAY: f64 = 86_400_000.0;

impl FaultScript {
    pub fn is_empty(&self) -> bool {
        self.failures.is_empty() && self.stragglers.is_empty()
    }

    /// Check the script against a fleet of `n_pools` pools. Run
    /// automatically by every `SimInput`-based entry point.
    pub fn validate(&self, n_pools: usize) -> Result<(), ConfigError> {
        let bad = |msg: String| Err(ConfigError::InvalidFaults(msg));
        for (i, f) in self.failures.iter().enumerate() {
            if f.pool >= n_pools {
                return bad(format!(
                    "failure #{i}: pool {} out of range ({n_pools} pools)",
                    f.pool
                ));
            }
            if f.n_gpus == 0 {
                return bad(format!("failure #{i}: n_gpus must be >= 1"));
            }
            if !(f.start_ms.is_finite() && f.start_ms >= 0.0) {
                return bad(format!(
                    "failure #{i}: start_ms {} invalid", f.start_ms
                ));
            }
            if !(f.recover_ms.is_finite() && f.recover_ms > f.start_ms) {
                return bad(format!(
                    "failure #{i}: recover_ms {} must be finite and after \
                     start_ms {}",
                    f.recover_ms, f.start_ms
                ));
            }
            if !(f.warm_ms.is_finite() && f.warm_ms >= 0.0) {
                return bad(format!(
                    "failure #{i}: warm_ms {} invalid", f.warm_ms
                ));
            }
            if !(f.warm_factor.is_finite() && f.warm_factor > 0.0) {
                return bad(format!(
                    "failure #{i}: warm_factor {} must be finite and > 0",
                    f.warm_factor
                ));
            }
        }
        // Same-pool outage windows must not overlap: the compiled
        // down-set would silently union them, so "2 GPUs down twice"
        // and "2 GPUs down once" become indistinguishable and the
        // script no longer means what it says. Adjacent half-open
        // windows ([a,b) then [b,c)) are fine.
        for (i, a) in self.failures.iter().enumerate() {
            for (j, b) in self.failures.iter().enumerate().skip(i + 1) {
                if a.pool == b.pool
                    && a.start_ms < b.recover_ms
                    && b.start_ms < a.recover_ms
                {
                    return bad(format!(
                        "failures #{i} and #{j} overlap on pool {}: \
                         [{}, {}) and [{}, {})",
                        a.pool, a.start_ms, a.recover_ms, b.start_ms,
                        b.recover_ms
                    ));
                }
            }
        }
        for (i, s) in self.stragglers.iter().enumerate() {
            if s.pool >= n_pools {
                return bad(format!(
                    "straggler #{i}: pool {} out of range ({n_pools} pools)",
                    s.pool
                ));
            }
            if s.n_gpus == 0 {
                return bad(format!("straggler #{i}: n_gpus must be >= 1"));
            }
            if !(s.start_ms.is_finite() && s.start_ms >= 0.0) {
                return bad(format!(
                    "straggler #{i}: start_ms {} invalid", s.start_ms
                ));
            }
            if !(s.end_ms.is_finite() && s.end_ms > s.start_ms) {
                return bad(format!(
                    "straggler #{i}: end_ms {} must be finite and after \
                     start_ms {}",
                    s.end_ms, s.start_ms
                ));
            }
            if !(s.factor.is_finite() && s.factor > 0.0) {
                return bad(format!(
                    "straggler #{i}: factor {} must be finite and > 0",
                    s.factor
                ));
            }
        }
        Ok(())
    }

    /// Parse a fault script from the shipped TOML subset: `[[failure]]`
    /// and `[[straggler]]` sections with `key = value` lines and `#`
    /// comments (see `data/faults/example.toml`). Hand-rolled on
    /// purpose — the build is offline and vendors no TOML crate.
    pub fn from_toml_str(text: &str) -> Result<Self, ConfigError> {
        enum Section {
            None,
            Failure,
            Straggler,
        }
        let bad = |line: usize, msg: String| {
            Err(ConfigError::InvalidFaults(format!(
                "fault script line {line}: {msg}"
            )))
        };
        let mut script = FaultScript::default();
        let mut section = Section::None;
        for (idx, raw) in text.lines().enumerate() {
            let lineno = idx + 1;
            let line = match raw.split_once('#') {
                Some((head, _)) => head.trim(),
                None => raw.trim(),
            };
            if line.is_empty() {
                continue;
            }
            if let Some(name) =
                line.strip_prefix("[[").and_then(|l| l.strip_suffix("]]"))
            {
                section = match name.trim() {
                    "failure" => {
                        script.failures.push(GpuFailure {
                            pool: 0,
                            n_gpus: 1,
                            start_ms: 0.0,
                            recover_ms: f64::NAN,
                            warm_ms: 0.0,
                            warm_factor: 1.0,
                        });
                        Section::Failure
                    }
                    "straggler" => {
                        script.stragglers.push(Straggler {
                            pool: 0,
                            n_gpus: 1,
                            start_ms: 0.0,
                            end_ms: f64::NAN,
                            factor: f64::NAN,
                        });
                        Section::Straggler
                    }
                    other => {
                        return bad(
                            lineno,
                            format!("unknown section [[{other}]]"),
                        )
                    }
                };
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                return bad(lineno, format!("expected key = value: {line}"));
            };
            let (key, value) = (key.trim(), value.trim());
            let num = || -> Result<f64, ConfigError> {
                value.parse::<f64>().map_err(|_| {
                    ConfigError::InvalidFaults(format!(
                        "fault script line {lineno}: {key} = {value} is \
                         not a number"
                    ))
                })
            };
            let int = || -> Result<usize, ConfigError> {
                value.parse::<usize>().map_err(|_| {
                    ConfigError::InvalidFaults(format!(
                        "fault script line {lineno}: {key} = {value} is \
                         not a non-negative integer"
                    ))
                })
            };
            match section {
                Section::None => {
                    return bad(
                        lineno,
                        format!(
                            "{key} outside a [[failure]]/[[straggler]] \
                             section"
                        ),
                    )
                }
                Section::Failure => {
                    let f = script.failures.last_mut().expect("pushed");
                    match key {
                        "pool" => f.pool = int()?,
                        "n_gpus" => f.n_gpus = int()?,
                        "start_ms" => f.start_ms = num()?,
                        "recover_ms" => f.recover_ms = num()?,
                        "warm_ms" => f.warm_ms = num()?,
                        "warm_factor" => f.warm_factor = num()?,
                        other => {
                            return bad(
                                lineno,
                                format!("unknown failure key {other}"),
                            )
                        }
                    }
                }
                Section::Straggler => {
                    let s = script.stragglers.last_mut().expect("pushed");
                    match key {
                        "pool" => s.pool = int()?,
                        "n_gpus" => s.n_gpus = int()?,
                        "start_ms" => s.start_ms = num()?,
                        "end_ms" => s.end_ms = num()?,
                        "factor" => s.factor = num()?,
                        other => {
                            return bad(
                                lineno,
                                format!("unknown straggler key {other}"),
                            )
                        }
                    }
                }
            }
        }
        for (i, f) in script.failures.iter().enumerate() {
            if f.recover_ms.is_nan() {
                return Err(ConfigError::InvalidFaults(format!(
                    "failure #{i}: recover_ms is required"
                )));
            }
        }
        for (i, s) in script.stragglers.iter().enumerate() {
            if s.end_ms.is_nan() || s.factor.is_nan() {
                return Err(ConfigError::InvalidFaults(format!(
                    "straggler #{i}: end_ms and factor are required"
                )));
            }
        }
        Ok(script)
    }

    /// Draw a script from a stochastic fault model: per pool, failure
    /// times form a Poisson process at `n_gpus x failures_per_gpu_day`
    /// and each failure's MTTR is an independent exponential draw.
    /// Deterministic in `(model, pools, horizon_ms, seed)`; the RNG is
    /// salted so it never correlates with the simulation's own streams.
    pub fn generate(
        model: &FaultModel,
        pools: &[SimPool],
        horizon_ms: f64,
        seed: u64,
    ) -> FaultScript {
        let mut rng = Pcg64::new(
            seed.wrapping_add(FAULT_SEED_SALT),
            streams::FAULT_SCRIPT,
        );
        let mut script = FaultScript::default();
        for (p, pool) in pools.iter().enumerate() {
            if pool.n_gpus == 0 || model.failures_per_gpu_day <= 0.0 {
                continue;
            }
            let rate_per_ms =
                pool.n_gpus as f64 * model.failures_per_gpu_day / MS_PER_DAY;
            let mut t = rng.exponential(rate_per_ms);
            while t < horizon_ms {
                let mttr = rng.exponential(1.0 / model.mttr_ms);
                script.failures.push(GpuFailure {
                    pool: p,
                    n_gpus: 1,
                    start_ms: t,
                    recover_ms: t + mttr,
                    warm_ms: model.warm_ms,
                    warm_factor: model.warm_factor,
                });
                // Serialized per pool: the next failure draws from the
                // recovery instant, so generated scripts always pass
                // the overlap check in [`Self::validate`].
                t += mttr + rng.exponential(rate_per_ms);
            }
        }
        script
    }
}

/// One outage shape for N+k sizing: `k` concurrent failures at
/// `fail_at_ms`, recovering together after `mttr_ms` with a cold-start
/// window. [`Self::script`] instantiates it for a pool;
/// `EvalEngine::size_for_failures` searches the smallest fleet that
/// rides it out in every SLO window.
#[derive(Debug, Clone)]
pub struct OutageSpec {
    pub fail_at_ms: f64,
    pub mttr_ms: f64,
    pub warm_ms: f64,
    pub warm_factor: f64,
}

impl OutageSpec {
    /// The k-concurrent-failures script on `pool` (empty when k = 0,
    /// which is bit-identical to running with no script at all).
    pub fn script(&self, pool: usize, k: usize) -> FaultScript {
        let mut s = FaultScript::default();
        if k > 0 {
            s.failures.push(GpuFailure {
                pool,
                n_gpus: k,
                start_ms: self.fail_at_ms,
                recover_ms: self.fail_at_ms + self.mttr_ms,
                warm_ms: self.warm_ms,
                warm_factor: self.warm_factor,
            });
        }
        s
    }
}

/// Per-run compiled view of a script: per-pool down/inflation windows
/// plus the drain-event schedule. Pure data — shared read-only across
/// shard threads.
#[derive(Debug, Clone)]
pub struct CompiledFaults {
    /// Per pool: `(start_ms, end_ms, lo_inst)` — instances with index
    /// >= `lo_inst` are down during `[start, end)`.
    down: Vec<Vec<(f64, f64, usize)>>,
    /// Per pool: `(start_ms, end_ms, lo_inst, factor)` inflation
    /// windows (stragglers and post-recovery warm-ups).
    slow: Vec<Vec<(f64, f64, usize, f64)>>,
    /// Queue re-examination events `(time_ms, pool)`, in script order.
    drains: Vec<(f64, u16)>,
}

impl CompiledFaults {
    /// Compile `script` against the fleet. The script must have been
    /// validated against `pools.len()` pools.
    pub fn compile(script: &FaultScript, pools: &[SimPool]) -> Self {
        let n_pools = pools.len();
        let mut down = vec![Vec::new(); n_pools];
        let mut slow = vec![Vec::new(); n_pools];
        let mut drains = Vec::with_capacity(script.failures.len());
        for f in &script.failures {
            let lo = pools[f.pool].n_gpus.saturating_sub(f.n_gpus);
            down[f.pool].push((f.start_ms, f.recover_ms, lo));
            drains.push((f.recover_ms, f.pool as u16));
            if f.warm_ms > 0.0 && f.warm_factor != 1.0 {
                slow[f.pool].push((
                    f.recover_ms,
                    f.recover_ms + f.warm_ms,
                    lo,
                    f.warm_factor,
                ));
            }
        }
        for s in &script.stragglers {
            let lo = pools[s.pool].n_gpus.saturating_sub(s.n_gpus);
            slow[s.pool].push((s.start_ms, s.end_ms, lo, s.factor));
        }
        CompiledFaults { down, slow, drains }
    }

    /// Is instance `inst` of `pool` down (not admitting) at time `t`?
    /// Windows are `[start, end)`: at `recover_ms` the instance is back
    /// up, which is what the drain event at that instant relies on.
    #[inline]
    pub fn is_down(&self, pool: usize, inst: usize, t: f64) -> bool {
        self.down[pool]
            .iter()
            .any(|&(s, e, lo)| inst >= lo && t >= s && t < e)
    }

    /// Iteration-latency multiplier for `inst` of `pool` at time `t`:
    /// the product of all inflation windows covering it (1.0 outside
    /// any window). Evaluated in fixed script order, so the f64
    /// product is bit-identical wherever it is computed.
    #[inline]
    pub fn slowdown(&self, pool: usize, inst: usize, t: f64) -> f64 {
        let mut factor = 1.0;
        for &(s, e, lo, f) in &self.slow[pool] {
            if inst >= lo && t >= s && t < e {
                factor *= f;
            }
        }
        factor
    }

    /// Queue re-examination schedule: one `(recover_ms, pool)` entry
    /// per failure, in script order. The serial engines push these as
    /// `Drain` events at init (after cap-window drains); shards push
    /// only their owned pools' entries, in the same order.
    pub fn drains(&self) -> &[(f64, u16)] {
        &self.drains
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::catalog::GpuCatalog;

    fn pools(n_gpus: usize) -> Vec<SimPool> {
        let gpu = GpuCatalog::standard().get("A100").unwrap().clone();
        vec![SimPool {
            gpu,
            n_gpus,
            ctx_budget: 8192.0,
            batch_cap: None,
        }]
    }

    fn outage(pool: usize, k: usize, start: f64, end: f64) -> GpuFailure {
        GpuFailure {
            pool,
            n_gpus: k,
            start_ms: start,
            recover_ms: end,
            warm_ms: 0.0,
            warm_factor: 1.0,
        }
    }

    #[test]
    fn compile_marks_top_instances_down_half_open() {
        let script = FaultScript {
            failures: vec![outage(0, 2, 100.0, 200.0)],
            stragglers: vec![],
        };
        let c = CompiledFaults::compile(&script, &pools(4));
        // Top two instances (2, 3) down during [100, 200).
        assert!(!c.is_down(0, 1, 150.0));
        assert!(c.is_down(0, 2, 150.0));
        assert!(c.is_down(0, 3, 100.0), "start is inclusive");
        assert!(!c.is_down(0, 3, 200.0), "recover instant is up");
        assert!(!c.is_down(0, 3, 99.9));
        assert_eq!(c.drains(), &[(200.0, 0)]);
    }

    #[test]
    fn overlapping_failures_union_and_oversized_k_clamps() {
        // `validate` rejects same-pool overlaps at the API boundary
        // (see validate_rejects_bad_scripts); this pins the
        // compile-level union semantics directly, plus the clamp of an
        // oversized n_gpus to the whole pool.
        let script = FaultScript {
            failures: vec![
                outage(0, 1, 0.0, 300.0),
                outage(0, 9, 100.0, 200.0), // > fleet size: whole pool
            ],
            stragglers: vec![],
        };
        let c = CompiledFaults::compile(&script, &pools(3));
        assert!(c.is_down(0, 0, 150.0), "oversized failure covers all");
        assert!(!c.is_down(0, 0, 250.0));
        assert!(c.is_down(0, 2, 250.0), "first failure still active");
    }

    #[test]
    fn slowdown_multiplies_overlapping_windows() {
        let script = FaultScript {
            failures: vec![GpuFailure {
                pool: 0,
                n_gpus: 1,
                start_ms: 0.0,
                recover_ms: 100.0,
                warm_ms: 50.0,
                warm_factor: 3.0,
            }],
            stragglers: vec![Straggler {
                pool: 0,
                n_gpus: 2,
                start_ms: 120.0,
                end_ms: 400.0,
                factor: 2.0,
            }],
        };
        let c = CompiledFaults::compile(&script, &pools(2));
        // Warm window [100, 150) on instance 1; straggler [120, 400)
        // on both.
        assert_eq!(c.slowdown(0, 1, 110.0), 3.0);
        assert_eq!(c.slowdown(0, 1, 130.0), 6.0, "windows multiply");
        assert_eq!(c.slowdown(0, 0, 130.0), 2.0);
        assert_eq!(c.slowdown(0, 1, 150.0), 2.0, "warm end exclusive");
        assert_eq!(c.slowdown(0, 0, 500.0), 1.0);
    }

    #[test]
    fn validate_rejects_bad_scripts() {
        let ok = FaultScript {
            failures: vec![outage(0, 1, 10.0, 20.0)],
            stragglers: vec![],
        };
        assert!(ok.validate(1).is_ok());
        assert!(matches!(
            ok.validate(0),
            Err(ConfigError::InvalidFaults(_))
        ));
        let backwards = FaultScript {
            failures: vec![outage(0, 1, 20.0, 10.0)],
            stragglers: vec![],
        };
        assert!(backwards.validate(1).is_err());
        let zero_width = FaultScript {
            failures: vec![],
            stragglers: vec![Straggler {
                pool: 0,
                n_gpus: 1,
                start_ms: 5.0,
                end_ms: 5.0,
                factor: 2.0,
            }],
        };
        assert!(zero_width.validate(1).is_err());
    }

    #[test]
    fn validate_rejects_overlapping_same_pool_outages() {
        let overlapping = FaultScript {
            failures: vec![
                outage(0, 1, 0.0, 300.0),
                outage(0, 2, 100.0, 200.0),
            ],
            stragglers: vec![],
        };
        let err = overlapping.validate(1).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("overlap"), "{msg}");
        assert!(msg.contains("pool 0"), "{msg}");
        assert!(msg.contains("[0, 300)") && msg.contains("[100, 200)"),
                "message must name both windows: {msg}");
        // Different pools may overlap freely…
        let cross_pool = FaultScript {
            failures: vec![
                outage(0, 1, 0.0, 300.0),
                outage(1, 2, 100.0, 200.0),
            ],
            stragglers: vec![],
        };
        assert!(cross_pool.validate(2).is_ok());
        // …and adjacent half-open windows on one pool are not overlaps.
        let adjacent = FaultScript {
            failures: vec![
                outage(0, 1, 0.0, 100.0),
                outage(0, 1, 100.0, 200.0),
            ],
            stragglers: vec![],
        };
        assert!(adjacent.validate(1).is_ok());
    }

    #[test]
    fn toml_round_trips_failures_and_stragglers() {
        let text = "\
# two GPUs die mid-peak, recover cold
[[failure]]
pool = 0
n_gpus = 2
start_ms = 10000    # mid-peak
recover_ms = 20000
warm_ms = 2000
warm_factor = 2.0

[[straggler]]
pool = 1
n_gpus = 1
start_ms = 0
end_ms = 5000
factor = 1.5
";
        let s = FaultScript::from_toml_str(text).unwrap();
        assert_eq!(s.failures.len(), 1);
        assert_eq!(s.stragglers.len(), 1);
        let f = &s.failures[0];
        assert_eq!((f.pool, f.n_gpus), (0, 2));
        assert_eq!((f.start_ms, f.recover_ms), (10_000.0, 20_000.0));
        assert_eq!((f.warm_ms, f.warm_factor), (2_000.0, 2.0));
        let g = &s.stragglers[0];
        assert_eq!((g.pool, g.n_gpus), (1, 1));
        assert_eq!((g.start_ms, g.end_ms, g.factor), (0.0, 5_000.0, 1.5));
        assert!(s.validate(2).is_ok());
    }

    #[test]
    fn toml_rejects_malformed_input() {
        assert!(FaultScript::from_toml_str("pool = 0").is_err());
        assert!(FaultScript::from_toml_str("[[explosion]]").is_err());
        assert!(FaultScript::from_toml_str(
            "[[failure]]\nrecover_ms = abc"
        )
        .is_err());
        assert!(
            FaultScript::from_toml_str("[[failure]]\npool = 0").is_err(),
            "recover_ms is required"
        );
        assert!(FaultScript::from_toml_str("[[failure]]\nwat = 1").is_err());
    }

    #[test]
    fn generate_is_deterministic_and_bounded() {
        let model = FaultModel {
            failures_per_gpu_day: 400.0, // absurdly high to get draws
            mttr_ms: 5_000.0,
            warm_ms: 1_000.0,
            warm_factor: 2.0,
        };
        let fleet = pools(8);
        let a = FaultScript::generate(&model, &fleet, 3_600_000.0, 7);
        let b = FaultScript::generate(&model, &fleet, 3_600_000.0, 7);
        assert_eq!(a, b, "same seed, same script");
        let c = FaultScript::generate(&model, &fleet, 3_600_000.0, 8);
        assert_ne!(a, c, "different seed, different script");
        assert!(!a.failures.is_empty());
        assert!(a.validate(1).is_ok());
        for f in &a.failures {
            assert!(f.start_ms < 3_600_000.0);
            assert!(f.recover_ms > f.start_ms);
        }
        // ~8 GPU-hours at 400/day, serialized behind ~5 s MTTRs:
        // ≈ 112 expected failures.
        assert!((50..400).contains(&a.failures.len()),
                "{} failures", a.failures.len());
    }
}
