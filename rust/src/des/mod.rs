//! Request-level discrete-event simulation (paper §3.1 Phase 2).
//!
//! Each request fires exactly two events — arrival and completion — so
//! simulating 10^4 requests takes milliseconds. The fidelity lever is the
//! *slot model*: each GPU instance exposes `n_max` KV slots and a request
//! holds one slot for its full `iters x t_iter(n_max)` duration. That is
//! what surfaces the head-of-line blocking Erlang-C misses on heavy-tailed
//! workloads (paper §4.2).

pub mod engine;
pub mod event;
pub mod faults;
pub mod input;
pub mod memory;
pub mod metrics;
pub mod pool;
pub mod reference;
pub mod retry;
pub mod shard;
