//! `fleet-sim` — the inference-fleet-sim CLI (L3 leader entrypoint).
//!
//! All planning runs in-process on the rust coordinator; the Phase-1
//! analytical sweep optionally executes the AOT-compiled JAX/Pallas
//! artifact via PJRT (`--backend aot`). Python never runs at plan time.

use fleet_sim::cli::args::Args;
use fleet_sim::cli::commands;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(
        &argv,
        &["fast", "mixed", "explain", "json", "scale"],
    ) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    match commands::run(&args) {
        Ok(out) => print!("{out}"),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
