//! Paper-style ASCII tables for case-study output.
//!
//! Every puzzle in §4 of the paper reports a small table; this renderer
//! produces aligned, boxed output that the CLI, examples, and bench
//! harnesses share so that EXPERIMENTS.md diffs read like the paper.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple column-aligned table with a header row.
#[derive(Debug, Clone)]
pub struct Table {
    title: Option<String>,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            title: None,
            aligns: headers.iter().map(|_| Align::Right).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn with_title(mut self, title: impl Into<String>) -> Self {
        self.title = Some(title.into());
        self
    }

    /// Set per-column alignment (defaults to right).
    pub fn align(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        self.row(&cells.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String], aligns: &[Align]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                let cell = &cells[i];
                let pad = widths[i] - cell.len();
                match aligns[i] {
                    Align::Left => {
                        s.push(' ');
                        s.push_str(cell);
                        s.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        s.push_str(&" ".repeat(pad + 1));
                        s.push_str(cell);
                        s.push(' ');
                    }
                }
                s.push('|');
            }
            s
        };
        out.push_str(&sep);
        out.push('\n');
        let lefts: Vec<Align> = vec![Align::Left; ncol];
        out.push_str(&fmt_row(&self.headers, &lefts));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &self.aligns));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }
}

/// Format a dollar amount as the paper does: `$155K`, `$1.47M`, `$845K`.
pub fn dollars(v: f64) -> String {
    if v >= 995_000.0 {
        format!("${:.2}M", v / 1e6)
    } else if v >= 1_000.0 {
        format!("${:.0}K", v / 1e3)
    } else {
        format!("${v:.0}")
    }
}

/// Format milliseconds compactly: `17 ms`, `1,052 ms`, `inf`; NaN (an
/// undefined statistic, e.g. the P99 of a pool that served nothing)
/// renders as `-`.
pub fn millis(v: f64) -> String {
    if v.is_nan() {
        return "-".to_string();
    }
    if !v.is_finite() {
        return "inf".to_string();
    }
    let n = v.round() as i64;
    if n >= 1000 {
        format!("{},{:03} ms", n / 1000, n % 1000)
    } else if v < 10.0 && v > 0.0 {
        format!("{v:.1} ms")
    } else {
        format!("{n} ms")
    }
}

/// Format a percentage with one decimal: `98.4%`. NaN (undefined — e.g.
/// attainment over zero requests) renders as `-`, never `100%`.
pub fn percent(frac: f64) -> String {
    if frac.is_nan() {
        return "-".to_string();
    }
    format!("{:.1}%", frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["B_short", "GPUs", "$/yr"]).with_title("T");
        t.row_strs(&["512", "15", "$290K"]);
        t.row_strs(&["4096", "8", "$155K"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines[0], "T");
        // All body lines equal width.
        let w = lines[1].len();
        assert!(lines[1..].iter().all(|l| l.len() == w), "{r}");
        assert!(r.contains("$155K"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["1"]);
    }

    #[test]
    fn dollar_formatting() {
        assert_eq!(dollars(155_000.0), "$155K");
        assert_eq!(dollars(1_470_000.0), "$1.47M");
        assert_eq!(dollars(845_200.0), "$845K");
        assert_eq!(dollars(420.0), "$420");
    }

    #[test]
    fn millis_formatting() {
        assert_eq!(millis(17.0), "17 ms");
        assert_eq!(millis(1052.0), "1,052 ms");
        assert_eq!(millis(7.9), "7.9 ms");
        assert_eq!(millis(f64::INFINITY), "inf");
        assert_eq!(millis(f64::NAN), "-");
    }

    #[test]
    fn percent_formatting() {
        assert_eq!(percent(0.984), "98.4%");
        assert_eq!(percent(f64::NAN), "-");
    }
}
