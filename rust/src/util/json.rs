//! Minimal JSON parser and writer.
//!
//! Supports the full JSON grammar (RFC 8259) minus some exotic corners we
//! reject deliberately (unpaired surrogates). Numbers parse to `f64`,
//! matching what the planner needs (CDF breakpoints, artifact metadata).
//! Object key order is preserved so emitted files diff cleanly.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key-value pairs in document order.
    Obj(Vec<(String, Json)>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after document"));
        }
        Ok(v)
    }

    /// Look up a key in an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => {
                pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object view as a map (loses duplicate keys; fine for our files).
    pub fn as_map(&self) -> Option<BTreeMap<&str, &Json>> {
        match self {
            Json::Obj(pairs) => {
                Some(pairs.iter().map(|(k, v)| (k.as_str(), v)).collect())
            }
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !pairs.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        // JSON has no inf/nan; emit null like serde_json does.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(pairs)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            // High surrogate: require a low surrogate next.
                            if self.bump() != Some(b'\\')
                                || self.bump() != Some(b'u')
                            {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo - 0xDC00);
                            char::from_u32(c)
                                .ok_or_else(|| self.err("bad codepoint"))?
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("unpaired surrogate"));
                        } else {
                            char::from_u32(cp)
                                .ok_or_else(|| self.err("bad codepoint"))?
                        };
                        s.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    if len == 0 || start + len > self.bytes.len() {
                        return Err(self.err("invalid utf-8"));
                    }
                    self.pos = start + len;
                    match std::str::from_utf8(&self.bytes[start..start + len]) {
                        Ok(frag) => s.push_str(frag),
                        Err(_) => return Err(self.err("invalid utf-8")),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = match self.bump() {
                Some(c @ b'0'..=b'9') => (c - b'0') as u32,
                Some(c @ b'a'..=b'f') => (c - b'a' + 10) as u32,
                Some(c @ b'A'..=b'F') => (c - b'A' + 10) as u32,
                _ => return Err(self.err("invalid \\u escape")),
            };
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // Integer part: 0 or [1-9][0-9]*
        match self.peek() {
            Some(b'0') => self.pos += 1,
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit required after '.'"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit required in exponent"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("number out of range"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC2..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF4 => 4,
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("0.25").unwrap(), Json::Num(0.25));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["", "{", "[1,", "tru", "01", "1.", "\"\\x\"", "{\"a\"}",
                    "[1] extra", "\"\\ud800\""] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn escapes_round_trip() {
        let s = Json::Str("a\"b\\c\nd\te\u{0001}é€".into());
        let text = s.to_string_compact();
        assert_eq!(Json::parse(&text).unwrap(), s);
    }

    #[test]
    fn surrogate_pairs() {
        let v = Json::parse("\"\\ud83d\\ude00\"").unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z": 1, "a": 2}"#).unwrap();
        match &v {
            Json::Obj(pairs) => {
                assert_eq!(pairs[0].0, "z");
                assert_eq!(pairs[1].0, "a");
            }
            _ => panic!(),
        }
    }

    #[test]
    fn pretty_round_trip() {
        let v = Json::parse(r#"{"cdf": [[512, 0.638], [1024, 0.831]], "n": 3}"#)
            .unwrap();
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn numbers_round_trip() {
        for n in [0.0, -0.5, 1e-9, 123456789.25, 65536.0, 2.21] {
            let text = Json::Num(n).to_string_compact();
            assert_eq!(Json::parse(&text).unwrap().as_f64(), Some(n));
        }
    }

    #[test]
    fn deep_nesting() {
        let mut text = String::new();
        for _ in 0..100 {
            text.push('[');
        }
        text.push('1');
        for _ in 0..100 {
            text.push(']');
        }
        assert!(Json::parse(&text).is_ok());
    }
}
