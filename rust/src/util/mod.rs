//! Shared substrates: JSON, statistics, tables, parallelism.
//!
//! These exist because the build environment is fully offline — crates like
//! `serde_json` are unavailable — and because the paper's tooling needs only
//! a narrow slice of each: a JSON reader for CDF files and artifact
//! metadata, streaming percentile statistics for the DES, paper-style ASCII
//! tables for the case studies, and a scoped thread map for Phase-2
//! verification.

pub mod json;
pub mod parallel;
pub mod stats;
pub mod table;
