//! Sample statistics for DES metrics: percentiles, moments, SCV.
//!
//! Two aggregation strategies share the [`Samples`] front end:
//!
//! * **Exact** (the default): store every value and answer percentiles by
//!   the nearest-rank method on a sorted copy — exact, deterministic, and
//!   cheap at the 10^4–10^5 sample sizes the simulator produces. Memory is
//!   O(requests).
//! * **Streaming**: a base-2 [`LogHistogram`] sketch (HDR-histogram style:
//!   64 sub-bins per power of two, so every bin is ~1.6% wide in relative
//!   terms). Memory is O(1) per metric regardless of request count, which
//!   is what keeps high-volume DES runs at O(pools) instead of
//!   O(requests). Quantiles are approximate within the bin width; moments
//!   (mean/variance/SCV) and min/max stay exact because they are tracked
//!   as running scalars.
//!
//! The paper's SLO check is a P99 over the sample (§3.1 Phase 2); exact
//! mode is what every scenario table uses, so published numbers are
//! unchanged. Streaming mode backs the perf harness (`fleet-sim bench`)
//! and anything that simulates more requests than it wants to keep.

use std::fmt;

/// Sub-bin bits per power of two: 2^6 = 64 sub-bins, giving a relative
/// bin width of 2^(1/64) - 1 ~ 1.1%.
const SUB_BITS: u32 = 6;
const SUBBINS: usize = 1 << SUB_BITS;
/// Values below 2^-10 ms (~1 µs) collapse into the zero bin — the DES
/// records exact zeros for no-wait admissions, which must stay exact.
const MIN_EXP: i32 = -10;
/// Values at or above 2^40 ms clamp into the top bin (reported as the
/// exact tracked maximum).
const MAX_EXP: i32 = 40;
const N_BINS: usize = (MAX_EXP - MIN_EXP) as usize * SUBBINS + 2;
/// `(value.to_bits() >> (52 - SUB_BITS))` of the smallest finite bin.
const INDEX_OFFSET: u64 = ((1023 + MIN_EXP) as u64) << SUB_BITS;

/// Streaming log-spaced histogram over non-negative values (ms).
///
/// Bins are derived from the IEEE-754 bit pattern (exponent plus the top
/// `SUB_BITS` mantissa bits), so binning costs a couple of integer ops —
/// no `ln` in the hot path — and is exactly deterministic.
#[derive(Clone)]
pub struct LogHistogram {
    counts: Vec<u64>,
    n: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl fmt::Debug for LogHistogram {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("LogHistogram")
            .field("n", &self.n)
            .field("min", &self.min)
            .field("max", &self.max)
            .field("mean", &self.mean())
            .finish()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram {
            counts: vec![0; N_BINS],
            n: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Bin index for a value. Bin 0 holds zeros / sub-µs values; the last
    /// bin holds the (unreachable in practice) >= 2^40 ms overflow.
    fn bin_of(v: f64) -> usize {
        if !v.is_finite() || v <= 0.0 {
            return 0;
        }
        const LO_BITS: u64 = ((1023 + MIN_EXP) as u64) << 52;
        const HI_BITS: u64 = ((1023 + MAX_EXP) as u64) << 52;
        let bits = v.to_bits();
        if bits < LO_BITS {
            return 0;
        }
        if bits >= HI_BITS {
            return N_BINS - 1;
        }
        ((bits >> (52 - SUB_BITS)) - INDEX_OFFSET) as usize + 1
    }

    /// Arithmetic midpoint of a finite bin's edges.
    fn value_of(bin: usize) -> f64 {
        debug_assert!(bin > 0 && bin < N_BINS - 1);
        let idx = bin as u64 - 1 + INDEX_OFFSET;
        let lo = f64::from_bits(idx << (52 - SUB_BITS));
        let hi = f64::from_bits((idx + 1) << (52 - SUB_BITS));
        0.5 * (lo + hi)
    }

    pub fn push(&mut self, v: f64) {
        self.counts[Self::bin_of(v)] += 1;
        self.n += 1;
        self.sum += v;
        self.sum_sq += v * v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.sum / self.n as f64
    }

    /// Population variance (exact: tracked moments, not bin centers).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.sum_sq / self.n as f64 - m * m).max(0.0)
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Nearest-rank quantile, `q` in [0, 1]. Returns the midpoint of the
    /// selected bin, clamped into the exact observed [min, max] (so a
    /// single-valued histogram answers exactly, and the zero bin answers
    /// exactly 0).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.n as f64).ceil() as u64)
            .clamp(1, self.n);
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                if i == 0 {
                    return 0.0f64.clamp(self.min, self.max);
                }
                if i == N_BINS - 1 {
                    return self.max;
                }
                return Self::value_of(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fold another histogram into this one (shard-merge path). Bin
    /// counts, `n`, min and max merge exactly, so quantiles and
    /// `fraction_le` over the merged histogram are bit-identical to a
    /// single-collector run regardless of merge order; `sum`/`sum_sq`
    /// (mean/variance) are order-dependent in the last ULPs.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.n += other.n;
        // detlint: ulp-ok -- mean/variance are documented as
        // order-dependent in the last ULPs; quantiles stay exact
        self.sum += other.sum;
        // detlint: ulp-ok -- same contract as `sum` above
        self.sum_sq += other.sum_sq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Fraction of recorded values <= `x` (within one bin width).
    /// An empty histogram has no defined fraction and returns NaN — a
    /// pool that served nothing must not report 100% SLO attainment.
    pub fn fraction_le(&self, x: f64) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        if x >= self.max {
            return 1.0;
        }
        if x < self.min {
            return 0.0;
        }
        let b = Self::bin_of(x);
        let cum: u64 = self.counts[..=b].iter().sum();
        cum as f64 / self.n as f64
    }
}

/// Internal storage for [`Samples`].
#[derive(Debug, Clone)]
enum Repr {
    Exact { values: Vec<f64>, sorted: bool },
    Sketch(LogHistogram),
}

/// Accumulates samples and answers percentile / moment queries.
#[derive(Debug, Clone)]
pub struct Samples {
    repr: Repr,
}

impl Default for Samples {
    fn default() -> Self {
        Samples { repr: Repr::Exact { values: Vec::new(), sorted: false } }
    }
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        Samples {
            repr: Repr::Exact { values: Vec::with_capacity(n), sorted: false },
        }
    }

    /// O(1)-memory streaming variant (percentiles answered by the
    /// [`LogHistogram`] sketch; `values()` returns an empty slice).
    pub fn streaming() -> Self {
        Samples { repr: Repr::Sketch(LogHistogram::new()) }
    }

    pub fn is_streaming(&self) -> bool {
        matches!(self.repr, Repr::Sketch(_))
    }

    pub fn push(&mut self, v: f64) {
        match &mut self.repr {
            Repr::Exact { values, sorted } => {
                values.push(v);
                *sorted = false;
            }
            Repr::Sketch(h) => h.push(v),
        }
    }

    pub fn len(&self) -> usize {
        match &self.repr {
            Repr::Exact { values, .. } => values.len(),
            Repr::Sketch(h) => h.count() as usize,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn mean(&self) -> f64 {
        match &self.repr {
            Repr::Exact { values, .. } => {
                if values.is_empty() {
                    return 0.0;
                }
                values.iter().sum::<f64>() / values.len() as f64
            }
            Repr::Sketch(h) => h.mean(),
        }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        match &self.repr {
            Repr::Exact { values, .. } => {
                if values.len() < 2 {
                    return 0.0;
                }
                let m = self.mean();
                values.iter().map(|v| (v - m) * (v - m)).sum::<f64>()
                    / values.len() as f64
            }
            Repr::Sketch(h) => h.variance(),
        }
    }

    /// Squared coefficient of variation Cs² = Var/Mean² (paper §2.2).
    pub fn scv(&self) -> f64 {
        let m = self.mean();
        if m.abs() < 1e-12 {
            return 0.0;
        }
        self.variance() / (m * m)
    }

    pub fn min(&self) -> f64 {
        match &self.repr {
            Repr::Exact { values, .. } => {
                values.iter().copied().fold(f64::INFINITY, f64::min)
            }
            Repr::Sketch(h) => h.min(),
        }
    }

    pub fn max(&self) -> f64 {
        match &self.repr {
            Repr::Exact { values, .. } => {
                values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
            }
            Repr::Sketch(h) => h.max(),
        }
    }

    /// Nearest-rank percentile, `q` in [0, 100]. Empty samples return 0
    /// (legacy convention — callers that must distinguish "no data" from
    /// "instant" check `is_empty()` first or use [`Self::fraction_le`],
    /// which answers NaN when empty).
    pub fn percentile(&mut self, q: f64) -> f64 {
        match &mut self.repr {
            Repr::Exact { values, sorted } => {
                if values.is_empty() {
                    return 0.0;
                }
                if !*sorted {
                    values.sort_by(|a, b| {
                        a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)
                    });
                    *sorted = true;
                }
                let n = values.len();
                let rank = ((q / 100.0) * n as f64).ceil() as usize;
                values[rank.clamp(1, n) - 1]
            }
            Repr::Sketch(h) => h.quantile(q / 100.0),
        }
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    /// Fraction of recorded values <= `x` (exact in exact mode; within one
    /// bin width in streaming mode). Empty samples return NaN: "everything
    /// we served met the SLO" is vacuous when nothing was served, and the
    /// old `1.0` let dead pools report perfect attainment.
    pub fn fraction_le(&self, x: f64) -> f64 {
        match &self.repr {
            Repr::Exact { values, .. } => {
                if values.is_empty() {
                    return f64::NAN;
                }
                values.iter().filter(|&&v| v <= x).count() as f64
                    / values.len() as f64
            }
            Repr::Sketch(h) => h.fraction_le(x),
        }
    }

    /// Fold another collection into this one (shard-merge path). Both
    /// sides must share a representation. Exact mode concatenates the
    /// sample multisets, so every percentile / `fraction_le` answer over
    /// the merge is bit-identical to a single-collector run; streaming
    /// mode merges sketches (see [`LogHistogram::merge`]).
    pub fn merge(&mut self, other: &Samples) {
        match (&mut self.repr, &other.repr) {
            (
                Repr::Exact { values, sorted },
                Repr::Exact { values: theirs, .. },
            ) => {
                values.extend_from_slice(theirs);
                *sorted = false;
            }
            (Repr::Sketch(h), Repr::Sketch(theirs)) => h.merge(theirs),
            _ => panic!("cannot merge samples across metrics modes"),
        }
    }

    /// The raw values in insertion order (sorted after a percentile
    /// query). Streaming samples keep no values: returns `&[]`.
    pub fn values(&self) -> &[f64] {
        match &self.repr {
            Repr::Exact { values, .. } => values,
            Repr::Sketch(_) => &[],
        }
    }
}

/// Streaming mean/variance (Welford) for cheap online monitoring where we
/// don't need percentiles (e.g. per-pool utilization traces).
#[derive(Debug, Clone, Copy, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let mut s = Samples::new();
        for v in 1..=100 {
            s.push(v as f64);
        }
        assert_eq!(s.percentile(50.0), 50.0);
        assert_eq!(s.percentile(99.0), 99.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.percentile(1.0), 1.0);
    }

    #[test]
    fn percentile_small_samples() {
        let mut s = Samples::new();
        s.push(7.0);
        assert_eq!(s.p99(), 7.0);
        assert_eq!(s.p50(), 7.0);
        let mut e = Samples::new();
        assert_eq!(e.p99(), 0.0);
    }

    #[test]
    fn percentile_after_push_resorts() {
        let mut s = Samples::new();
        s.push(10.0);
        assert_eq!(s.p99(), 10.0);
        s.push(20.0);
        assert_eq!(s.p99(), 20.0);
    }

    #[test]
    fn moments() {
        let mut s = Samples::new();
        for v in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(v);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 4.0).abs() < 1e-12);
        assert!((s.scv() - 4.0 / 25.0).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let data: Vec<f64> =
            (0..1000).map(|i| ((i * 7919) % 100) as f64).collect();
        let mut w = Welford::default();
        let mut s = Samples::new();
        for &x in &data {
            w.push(x);
            s.push(x);
        }
        assert!((w.mean() - s.mean()).abs() < 1e-9);
        assert!((w.variance() - s.variance()).abs() < 1e-6);
    }

    #[test]
    #[cfg_attr(miri, ignore = "needs the full 20k-sample volume")]
    fn exponential_scv_close_to_one() {
        // Deterministic inverse-CDF samples of Exp(1). The 0.02
        // tolerance needs the full tail; do not shrink n.
        let mut s = Samples::new();
        let n = 20000;
        for i in 0..n {
            let u = (i as f64 + 0.5) / n as f64;
            s.push(-(1.0 - u).ln());
        }
        assert!((s.scv() - 1.0).abs() < 0.02, "scv = {}", s.scv());
    }

    // ---- streaming sketch ----

    #[test]
    fn sketch_binning_round_trips_within_bin_width() {
        // value -> bin -> midpoint must stay within half a bin (~0.6%).
        for &v in &[1e-2, 0.5, 1.0, 3.7, 100.0, 1234.5, 9.9e6] {
            let b = LogHistogram::bin_of(v);
            assert!(b > 0 && b < N_BINS - 1, "v={v} bin={b}");
            let mid = LogHistogram::value_of(b);
            assert!(
                (mid / v - 1.0).abs() < 0.01,
                "v={v} mid={mid} rel={}",
                (mid / v - 1.0).abs()
            );
        }
    }

    #[test]
    fn sketch_bins_are_monotone_in_value() {
        let mut prev = 0usize;
        let mut v = 1e-4;
        while v < 1e10 {
            let b = LogHistogram::bin_of(v);
            assert!(b >= prev, "bin({v}) = {b} < {prev}");
            prev = b;
            v *= 1.003;
        }
    }

    #[test]
    fn sketch_handles_zero_and_extremes() {
        let mut h = LogHistogram::new();
        for _ in 0..99 {
            h.push(0.0);
        }
        h.push(2e12); // beyond 2^40 ms -> top bin, reported as exact max
        assert_eq!(h.quantile(0.50), 0.0);
        assert_eq!(h.quantile(1.0), 2e12);
        assert_eq!(h.count(), 100);
        assert!((h.fraction_le(0.0) - 0.99).abs() < 1e-12);
    }

    #[test]
    fn sketch_single_value_is_exact() {
        let mut h = LogHistogram::new();
        for _ in 0..10 {
            h.push(123.456);
        }
        // Clamping into [min, max] recovers the exact value.
        assert_eq!(h.quantile(0.5), 123.456);
        assert_eq!(h.quantile(0.99), 123.456);
        assert_eq!(h.min(), 123.456);
        assert_eq!(h.max(), 123.456);
    }

    #[test]
    fn streaming_percentiles_close_to_exact() {
        let mut exact = Samples::new();
        let mut sketch = Samples::streaming();
        // The 2% bound is set by bin width, not sample count, so the
        // miri run can use a smaller volume.
        let n = if cfg!(miri) { 2000 } else { 20000 };
        for i in 0..n {
            // Heavy-tailed deterministic sample (Exp quantiles, scaled).
            let u = (i as f64 + 0.5) / n as f64;
            let v = 250.0 * -(1.0 - u).ln();
            exact.push(v);
            sketch.push(v);
        }
        assert!(sketch.is_streaming());
        assert_eq!(exact.len(), sketch.len());
        for q in [10.0, 50.0, 90.0, 99.0, 99.9] {
            let e = exact.percentile(q);
            let s = sketch.percentile(q);
            assert!(
                (s / e - 1.0).abs() < 0.02,
                "q={q}: exact {e} sketch {s}"
            );
        }
        assert!((exact.mean() - sketch.mean()).abs() < 1e-9);
        assert!((exact.variance() - sketch.variance()).abs() < 1e-3);
        assert_eq!(exact.min(), sketch.min());
        assert_eq!(exact.max(), sketch.max());
        assert!(sketch.values().is_empty());
    }

    #[test]
    fn merge_matches_single_collector_in_both_reprs() {
        // Quantiles and fraction_le over a merge must be bit-identical
        // to pushing everything into one collector, in either repr.
        let make = |streaming: bool| {
            if streaming {
                Samples::streaming()
            } else {
                Samples::new()
            }
        };
        for streaming in [false, true] {
            let mut whole = make(streaming);
            let mut left = make(streaming);
            let mut right = make(streaming);
            let n: usize = if cfg!(miri) { 500 } else { 5000 };
            for i in 0..n {
                let v = 0.37 * ((i * 7919) % 997) as f64;
                whole.push(v);
                // Interleave so neither part is a sorted prefix.
                if i % 3 == 0 {
                    left.push(v);
                } else {
                    right.push(v);
                }
            }
            left.merge(&right);
            assert_eq!(left.len(), whole.len());
            for q in [1.0, 50.0, 99.0, 99.9] {
                assert_eq!(
                    left.percentile(q),
                    whole.percentile(q),
                    "streaming={streaming} q={q}"
                );
            }
            assert_eq!(left.min(), whole.min());
            assert_eq!(left.max(), whole.max());
            assert_eq!(left.fraction_le(100.0), whole.fraction_le(100.0));
            assert!((left.mean() - whole.mean()).abs() < 1e-9);
        }
        // Merging an empty part is a no-op.
        let mut s = Samples::new();
        s.push(2.0);
        s.merge(&Samples::new());
        assert_eq!(s.len(), 1);
    }

    #[test]
    #[should_panic(expected = "across metrics modes")]
    fn merge_rejects_mixed_reprs() {
        let mut a = Samples::new();
        a.merge(&Samples::streaming());
    }

    #[test]
    fn fraction_le_matches_between_reprs() {
        let mut exact = Samples::new();
        let mut sketch = Samples::streaming();
        for i in 0..1000 {
            let v = i as f64;
            exact.push(v);
            sketch.push(v);
        }
        for x in [0.0, 10.0, 499.5, 999.0, 2000.0] {
            let e = exact.fraction_le(x);
            let s = sketch.fraction_le(x);
            assert!((e - s).abs() < 0.02, "x={x}: exact {e} sketch {s}");
        }
        // Vacuous attainment: empty samples answer NaN in both reprs
        // (never 1.0 — that hid dead pools behind "perfect" attainment).
        assert!(Samples::new().fraction_le(1.0).is_nan());
        assert!(Samples::streaming().fraction_le(1.0).is_nan());
    }
}
