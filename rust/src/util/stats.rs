//! Sample statistics for DES metrics: percentiles, moments, SCV.
//!
//! The DES collects per-request latencies; the SLO check is a P99 over the
//! sample (paper §3.1 Phase 2). Percentiles use the nearest-rank method on
//! a sorted copy — exact, deterministic, and cheap at the 10^4–10^5 sample
//! sizes the simulator produces.

/// Accumulates samples and answers percentile / moment queries.
#[derive(Debug, Clone, Default)]
pub struct Samples {
    values: Vec<f64>,
    sorted: bool,
}

impl Samples {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(n: usize) -> Self {
        Samples { values: Vec::with_capacity(n), sorted: false }
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
        self.sorted = false;
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.values.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        self.values.iter().map(|v| (v - m) * (v - m)).sum::<f64>()
            / self.values.len() as f64
    }

    /// Squared coefficient of variation Cs² = Var/Mean² (paper §2.2).
    pub fn scv(&self) -> f64 {
        let m = self.mean();
        if m.abs() < 1e-12 {
            return 0.0;
        }
        self.variance() / (m * m)
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Nearest-rank percentile, `q` in [0, 100]. Empty samples return 0.
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        if !self.sorted {
            self.values
                .sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            self.sorted = true;
        }
        let n = self.values.len();
        let rank = ((q / 100.0) * n as f64).ceil() as usize;
        self.values[rank.clamp(1, n) - 1]
    }

    pub fn p50(&mut self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&mut self) -> f64 {
        self.percentile(99.0)
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }
}

/// Streaming mean/variance (Welford) for cheap online monitoring where we
/// don't need percentiles (e.g. per-pool utilization traces).
#[derive(Debug, Clone, Copy, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let mut s = Samples::new();
        for v in 1..=100 {
            s.push(v as f64);
        }
        assert_eq!(s.percentile(50.0), 50.0);
        assert_eq!(s.percentile(99.0), 99.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert_eq!(s.percentile(1.0), 1.0);
    }

    #[test]
    fn percentile_small_samples() {
        let mut s = Samples::new();
        s.push(7.0);
        assert_eq!(s.p99(), 7.0);
        assert_eq!(s.p50(), 7.0);
        let mut e = Samples::new();
        assert_eq!(e.p99(), 0.0);
    }

    #[test]
    fn percentile_after_push_resorts() {
        let mut s = Samples::new();
        s.push(10.0);
        assert_eq!(s.p99(), 10.0);
        s.push(20.0);
        assert_eq!(s.p99(), 20.0);
    }

    #[test]
    fn moments() {
        let mut s = Samples::new();
        for v in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(v);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 4.0).abs() < 1e-12);
        assert!((s.scv() - 4.0 / 25.0).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let data: Vec<f64> = (0..1000).map(|i| ((i * 7919) % 100) as f64).collect();
        let mut w = Welford::default();
        let mut s = Samples::new();
        for &x in &data {
            w.push(x);
            s.push(x);
        }
        assert!((w.mean() - s.mean()).abs() < 1e-9);
        assert!((w.variance() - s.variance()).abs() < 1e-6);
    }

    #[test]
    fn exponential_scv_close_to_one() {
        // Deterministic inverse-CDF samples of Exp(1).
        let mut s = Samples::new();
        let n = 20000;
        for i in 0..n {
            let u = (i as f64 + 0.5) / n as f64;
            s.push(-(1.0 - u).ln());
        }
        assert!((s.scv() - 1.0).abs() < 0.02, "scv = {}", s.scv());
    }
}
