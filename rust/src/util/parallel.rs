//! Scoped-thread parallel map for Phase-2 DES verification.
//!
//! The planner verifies the top-k analytical candidates by simulation;
//! each simulation is independent, so we fan out over std threads
//! (tokio is unavailable offline, and the work is CPU-bound anyway).

/// Map `f` over `items` using up to `max_threads` worker threads,
/// preserving input order in the output.
pub fn par_map<T, R, F>(items: Vec<T>, max_threads: usize, f: F) -> Vec<R>
where
    T: Send + Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = max_threads.max(1).min(n);
    if threads == 1 {
        return items.iter().map(|t| f(t)).collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let out_slots: Vec<std::sync::Mutex<&mut Option<R>>> =
        out.iter_mut().map(std::sync::Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(&items[i]);
                **out_slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    out.into_iter().map(|o| o.expect("worker completed")).collect()
}

/// Default parallelism: available cores, capped to keep the box responsive.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<u64> = (0..100).collect();
        let out = par_map(items, 8, |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_thread_path() {
        let out = par_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_input() {
        let out: Vec<i32> = par_map(Vec::<i32>::new(), 4, |x| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_threads_than_items() {
        let out = par_map(vec![5], 16, |x| x * x);
        assert_eq!(out, vec![25]);
    }

    #[test]
    fn actually_parallel() {
        // All threads must be in-flight simultaneously for this to finish:
        // a barrier waits for `threads` participants.
        let threads = 4;
        let barrier = std::sync::Barrier::new(threads);
        let items: Vec<usize> = (0..threads).collect();
        let out = par_map(items, threads, |_| {
            barrier.wait();
            1
        });
        assert_eq!(out.len(), threads);
    }
}
