//! The unified evaluation engine behind every scenario and the planner.
//!
//! `EvalEngine` centralizes the three things the paper's experiments all
//! share (and that each scenario used to hand-wire):
//!
//! 1. **Phase-1 backend selection** — the analytical sweep runs on the
//!    pure-rust [`NativeSweep`] by default, or on the AOT-compiled
//!    JAX/Pallas artifact via PJRT when built with the `pjrt` feature.
//! 2. **Phase-2 DES verification** — candidates are replayed through the
//!    discrete-event simulator on a *shared sampled-request stream*: the
//!    `(workload, λ, n_requests, seed)`-keyed cache means fifty candidates
//!    evaluated against the same workload sample it once instead of fifty
//!    times. Results are bit-identical to per-candidate resampling because
//!    `Simulator::run` derives its stream from exactly this key.
//! 3. **Parallel sweeps** — every minimal-fleet search (per-threshold,
//!    per-GPU-type, per-pairing) fans out over [`par_map`] worker threads,
//!    in deterministic input order.
//!
//! Scenarios declare *what* to evaluate ([`SweepJob`]s); the engine owns
//! *how*.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::des::engine::{DesConfig, SimPool, Simulator};
use crate::des::faults::{FaultScript, OutageSpec};
use crate::des::input::SimInput;
use crate::des::memory::MemoryConfig;
use crate::des::retry::RetryConfig;
use crate::des::metrics::DesResult;
use crate::des::shard::{run_streamed_input, DEFAULT_CHUNK_SIZE};
use crate::gpu::catalog::GpuCatalog;
use crate::gpu::profile::GpuProfile;
use crate::optimizer::analytic::{rank_feasible, NativeSweep, SweepEval};
use crate::optimizer::candidates::{generate, n_min_for_slice, Candidate,
                                   CandidateResult, GenOptions};
use crate::optimizer::planner::{plan_pools, Verification};
use crate::queueing::mgc::{analyze_pool, PoolSpec, WorkloadHist};
use crate::router::RoutingPolicy;
use crate::util::parallel::{default_threads, par_map};
use crate::workload::spec::{ArrivalSpec, SampledRequest, WorkloadSpec};

/// Phase-1 evaluator owned by the engine.
enum Backend {
    Native(NativeSweep),
    #[cfg(feature = "pjrt")]
    Aot(crate::runtime::sweep::AotSweep),
}

impl Backend {
    fn as_eval(&self) -> &dyn SweepEval {
        match self {
            Backend::Native(n) => n,
            #[cfg(feature = "pjrt")]
            Backend::Aot(a) => a,
        }
    }
}

/// Cache key for one sampled request stream (paper §3.1 Phase 2 steps
/// 1–2): the workload fingerprint (CDF breakpoints, prompt fraction, λ)
/// plus the stream's `(n_requests, seed)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct StreamKey {
    workload: u64,
    n: usize,
    seed: u64,
}

fn fnv1a(hash: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *hash ^= b as u64;
        *hash = hash.wrapping_mul(0x0000_0100_0000_01b3);
    }
}

fn workload_fingerprint(w: &WorkloadSpec) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    fnv1a(&mut h, w.name.as_bytes());
    fnv1a(&mut h, &w.lambda_rps.to_bits().to_le_bytes());
    fnv1a(&mut h, &w.input_fraction.to_bits().to_le_bytes());
    for &(l, p) in w.cdf.points() {
        fnv1a(&mut h, &l.to_bits().to_le_bytes());
        fnv1a(&mut h, &p.to_bits().to_le_bytes());
    }
    // The arrival spec is part of the stream identity: an NHPP workload
    // at mean λ must never share a cached stream with stationary Poisson
    // at the same λ. Replay traces hash every timestamp — O(trace) per
    // cache lookup, but each lookup fronts a DES run over that same
    // stream, which dwarfs the hash.
    match &w.arrivals {
        ArrivalSpec::Poisson => fnv1a(&mut h, &[0u8]),
        ArrivalSpec::Nhpp { profile_rps, period_ms } => {
            fnv1a(&mut h, &[1u8]);
            fnv1a(&mut h, &period_ms.to_bits().to_le_bytes());
            for &(t, r) in profile_rps {
                fnv1a(&mut h, &t.to_bits().to_le_bytes());
                fnv1a(&mut h, &r.to_bits().to_le_bytes());
            }
        }
        ArrivalSpec::Replay { timestamps, rate_scale } => {
            fnv1a(&mut h, &[2u8]);
            fnv1a(&mut h, &rate_scale.to_bits().to_le_bytes());
            for &t in timestamps {
                fnv1a(&mut h, &t.to_bits().to_le_bytes());
            }
        }
    }
    h
}

/// One minimal-fleet search unit inside a scenario sweep: size the
/// smallest feasible fleet for this GPU pairing / split threshold, then
/// DES-verify it.
#[derive(Debug, Clone)]
pub struct SweepJob {
    pub gpu_s: GpuProfile,
    pub gpu_l: GpuProfile,
    /// Split threshold; ignored for homogeneous jobs.
    pub b_short: f64,
    /// Size a single-pool fleet instead of a two-pool split.
    pub homogeneous: bool,
}

impl SweepJob {
    pub fn two_pool(gpu_s: &GpuProfile, gpu_l: &GpuProfile, b_short: f64)
        -> Self
    {
        SweepJob {
            gpu_s: gpu_s.clone(),
            gpu_l: gpu_l.clone(),
            b_short,
            homogeneous: false,
        }
    }

    pub fn homogeneous(gpu: &GpuProfile) -> Self {
        SweepJob {
            gpu_s: gpu.clone(),
            gpu_l: gpu.clone(),
            b_short: f64::INFINITY,
            homogeneous: true,
        }
    }
}

/// The unified evaluation engine.
pub struct EvalEngine {
    pub catalog: GpuCatalog,
    /// Worker threads for parallel sweeps and Phase-2 verification.
    pub threads: usize,
    backend: Backend,
    // BTreeMap, not HashMap: nothing iterates the cache today, but the
    // determinism lint (R1) bans hash-ordered containers in result
    // paths outright so an innocent `.values()` can never creep in.
    cache: Mutex<BTreeMap<StreamKey, Arc<Vec<SampledRequest>>>>,
}

impl Default for EvalEngine {
    fn default() -> Self {
        EvalEngine::standard()
    }
}

impl EvalEngine {
    /// Native Phase-1 backend over the given catalog.
    pub fn native(catalog: GpuCatalog) -> Self {
        EvalEngine {
            catalog,
            threads: default_threads(),
            backend: Backend::Native(NativeSweep),
            cache: Mutex::new(BTreeMap::new()),
        }
    }

    /// Native backend over the standard paper catalog.
    pub fn standard() -> Self {
        Self::native(GpuCatalog::standard())
    }

    /// AOT/PJRT Phase-1 backend (requires the `pjrt` feature).
    #[cfg(feature = "pjrt")]
    pub fn aot(catalog: GpuCatalog,
               sweep: crate::runtime::sweep::AotSweep) -> Self {
        EvalEngine {
            catalog,
            threads: default_threads(),
            backend: Backend::Aot(sweep),
            cache: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// The Phase-1 evaluator in use.
    pub fn sweep_eval(&self) -> &dyn SweepEval {
        self.backend.as_eval()
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.as_eval().backend()
    }

    /// Phase 1: generate + evaluate + rank candidates for a workload.
    pub fn phase1(
        &self,
        workload: &WorkloadSpec,
        gen: &GenOptions,
        slo_ms: f64,
    ) -> anyhow::Result<(Vec<Candidate>, Vec<CandidateResult>, Vec<usize>)> {
        let cands = generate(workload, &self.catalog, gen);
        let results = self.sweep_eval().eval(workload, &cands, slo_ms)?;
        let ranked = rank_feasible(&cands, &results);
        Ok((cands, results, ranked))
    }

    /// Deterministic, order-preserving parallel map over `items` with the
    /// engine's worker-thread budget.
    pub fn par_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + Sync,
        R: Send,
        F: Fn(&T) -> R + Sync,
    {
        par_map(items, self.threads, f)
    }

    /// The shared sampled request stream for `(workload, n, seed)` —
    /// sampled once, reused by every simulation against the same key.
    pub fn sampled_stream(
        &self,
        workload: &WorkloadSpec,
        n_requests: usize,
        seed: u64,
    ) -> Arc<Vec<SampledRequest>> {
        let key = StreamKey {
            workload: workload_fingerprint(workload),
            n: n_requests,
            seed,
        };
        if let Some(hit) = self.cache.lock().unwrap().get(&key) {
            return Arc::clone(hit);
        }
        // Sample outside the lock (the expensive part); racing duplicates
        // produce identical vectors, so last-write-wins is benign.
        let stream = Arc::new(workload.sample_requests(n_requests, seed));
        self.cache
            .lock()
            .unwrap()
            .entry(key)
            .or_insert_with(|| Arc::clone(&stream))
            .clone()
    }

    /// Number of distinct request streams currently cached.
    pub fn cached_streams(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Streams above this request count are never materialized (or
    /// cached): the engine switches to the generator-driven executor,
    /// which re-derives the stream from the same `(workload, seed)` key
    /// on every run. The cutoff is a memory policy, not a semantic one —
    /// both paths are bit-identical (pinned by `shard_regression`).
    pub const STREAM_CACHE_MAX: usize = 1 << 20;

    /// DES run on an explicit pool layout, reusing the cached request
    /// stream. Bit-identical to `Simulator::run` with the same config —
    /// and everything is borrowed: no workload, pool, router, or
    /// request-vector clone per candidate.
    ///
    /// Above [`Self::STREAM_CACHE_MAX`] requests (with `warmup_frac` 0,
    /// the generator path's precondition), the run switches to the
    /// O(in-flight)-memory generator-driven executor instead of
    /// materializing and caching a multi-gigabyte stream.
    pub fn simulate(
        &self,
        workload: &WorkloadSpec,
        pools: &[SimPool],
        router: &RoutingPolicy,
        cfg: &DesConfig,
    ) -> DesResult {
        self.simulate_faulted(workload, pools, router, cfg, None)
    }

    /// [`Self::simulate`] with an optional deterministic fault script
    /// ([`crate::des::faults`]) applied to the fleet. `None` (and the
    /// empty script) is bit-identical to the unfaulted run; both the
    /// cached-stream and the generator-driven dispatch inject the same
    /// script, so the memory-policy cutoff stays semantics-free.
    pub fn simulate_faulted(
        &self,
        workload: &WorkloadSpec,
        pools: &[SimPool],
        router: &RoutingPolicy,
        cfg: &DesConfig,
        faults: Option<&FaultScript>,
    ) -> DesResult {
        self.simulate_robust(workload, pools, router, cfg, faults, None)
    }

    /// [`Self::simulate_faulted`] with an optional closed-loop client
    /// behavior layer ([`crate::des::retry`]): deadlines, retries with
    /// deterministic backoff, and server-side admission control. `None`
    /// is bit-identical to the open-loop run; both the cached-stream and
    /// the generator-driven dispatch attach the same config, so the
    /// memory-policy cutoff stays semantics-free.
    #[allow(clippy::too_many_arguments)]
    pub fn simulate_robust(
        &self,
        workload: &WorkloadSpec,
        pools: &[SimPool],
        router: &RoutingPolicy,
        cfg: &DesConfig,
        faults: Option<&FaultScript>,
        retries: Option<&RetryConfig>,
    ) -> DesResult {
        self.simulate_with(workload, pools, router, cfg, faults, retries,
                           None)
    }

    /// [`Self::simulate_robust`] with an optional KV-cache memory model
    /// ([`crate::des::memory`]): token-granular occupancy, memory-bounded
    /// admission, and preemption. `None` is bit-identical to the
    /// memory-less run; both the cached-stream and the generator-driven
    /// dispatch attach the same config, so the memory-policy cutoff
    /// stays semantics-free.
    #[allow(clippy::too_many_arguments)]
    pub fn simulate_with(
        &self,
        workload: &WorkloadSpec,
        pools: &[SimPool],
        router: &RoutingPolicy,
        cfg: &DesConfig,
        faults: Option<&FaultScript>,
        retries: Option<&RetryConfig>,
        memory: Option<&MemoryConfig>,
    ) -> DesResult {
        if cfg.n_requests > Self::STREAM_CACHE_MAX && cfg.warmup_frac == 0.0
        {
            let mut input =
                SimInput::generated(pools, router, cfg, workload);
            if let Some(f) = faults {
                input = input.with_faults(f);
            }
            if let Some(r) = retries {
                input = input.with_retries(r);
            }
            if let Some(m) = memory {
                input = input.with_memory(m);
            }
            let (r, _) = run_streamed_input(&input, DEFAULT_CHUNK_SIZE)
                .unwrap_or_else(|e| panic!("{e}"));
            return r;
        }
        let stream = self.sampled_stream(workload, cfg.n_requests, cfg.seed);
        let mut input = SimInput::stream(pools, router, cfg, &stream);
        if let Some(f) = faults {
            input = input.with_faults(f);
        }
        if let Some(r) = retries {
            input = input.with_retries(r);
        }
        if let Some(m) = memory {
            input = input.with_memory(m);
        }
        Simulator::run_input(&input).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Phase 2: DES-verify one candidate with the production router.
    pub fn verify(
        &self,
        workload: &WorkloadSpec,
        cand: &Candidate,
        cfg: &DesConfig,
        slo_ms: f64,
    ) -> Verification {
        let (pools, router) = plan_pools(cand);
        let mut r = self.simulate(workload, &pools, &router, cfg);
        let p99 = r.overall.p99_ttft();
        // A pool that served nothing has no P99: report NaN (rendered
        // "-"), never a healthy-looking vacuous 0 ms.
        let mut pool_p99 = |i: usize| -> f64 {
            match r.per_pool.get_mut(i) {
                Some(p) if p.stats.count > 0 => p.stats.ttft.p99(),
                Some(_) => f64::NAN,
                None => 0.0,
            }
        };
        let p99_s = pool_p99(0);
        let p99_l = pool_p99(1);
        Verification {
            p99_ttft_ms: p99,
            p99_ttft_short_ms: p99_s,
            p99_ttft_long_ms: p99_l,
            utilization: r.per_pool.iter().map(|p| p.utilization).collect(),
            // Unserved-aware: a candidate whose backlog never drained
            // cannot pass on the strength of its served requests alone.
            passed: r.meets_slo(slo_ms),
        }
    }

    /// Size-to-peak: smallest homogeneous fleet **at or above the
    /// analytic peak-rate floor** whose DES run meets the SLO in every
    /// time window, not just in the run aggregate (`cfg.window_ms` must
    /// be set). This is the sizing mode for non-stationary workloads: a
    /// fleet sized for the long-run mean passes the aggregate P99 while
    /// failing every peak window.
    ///
    /// The search starts from the analytic utilization-cap floor at the
    /// profile's *peak* rate (size-to-peak means sustained-peak
    /// capacity; fleets below that floor, which could only survive by
    /// riding short bursts out in queue, are deliberately out of scope)
    /// and walks upward; each step replays the same cached request
    /// stream, so the whole search costs a handful of DES runs. Returns
    /// the fleet size and its DES result, or None if no fleet within
    /// `max_gpus` satisfies every window.
    pub fn size_to_peak(
        &self,
        w: &WorkloadSpec,
        gpu: &GpuProfile,
        slo_ms: f64,
        max_gpus: u32,
        cfg: &DesConfig,
    ) -> Option<(u32, DesResult)> {
        assert!(
            cfg.window_ms.is_some(),
            "size_to_peak requires DesConfig::window_ms"
        );
        let ctx = w.cdf.max_len();
        let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
        let peak_rps = match &w.arrivals {
            ArrivalSpec::Nhpp { profile_rps, .. } => profile_rps
                .iter()
                .map(|&(_, r)| r)
                .fold(w.lambda_rps, f64::max),
            _ => w.lambda_rps,
        };
        let start = n_min_for_slice(&hist, 0.0, ctx, peak_rps / 1000.0, gpu,
                                    ctx)
            .unwrap_or(1);
        for n in start..=max_gpus {
            let pools = [SimPool {
                gpu: gpu.clone(),
                n_gpus: n as usize,
                ctx_budget: ctx,
                batch_cap: None,
            }];
            let mut r = self.simulate(
                w, &pools, &RoutingPolicy::Random { n_pools: 1 }, cfg,
            );
            if r.meets_slo_in_every_window(slo_ms) {
                return Some((n, r));
            }
        }
        None
    }

    /// Empirical N+k sizing: smallest homogeneous fleet that meets the
    /// SLO **in every window while `k` of its GPUs are down** on the
    /// `outage` schedule ([`OutageSpec::script`]) — failure at
    /// `fail_at_ms`, recovery after `mttr_ms`, then a cold-start
    /// window. The analytic counterpart is Eq. 6's availability-target
    /// sizing ([`crate::optimizer::reliability`]); this mode answers
    /// the question Eq. 6 cannot: does N+k *stay inside the SLO during
    /// the outage*, not merely keep enough long-run capacity.
    ///
    /// `k = 0` degenerates to an empty fault script and is identical
    /// to [`Self::size_to_peak`] by construction (same floor, same
    /// walk, same windows test). Like `size_to_peak`, requires
    /// `cfg.window_ms`.
    #[allow(clippy::too_many_arguments)]
    pub fn size_for_failures(
        &self,
        w: &WorkloadSpec,
        gpu: &GpuProfile,
        slo_ms: f64,
        k: u32,
        max_gpus: u32,
        cfg: &DesConfig,
        outage: &OutageSpec,
    ) -> Option<(u32, DesResult)> {
        assert!(
            cfg.window_ms.is_some(),
            "size_for_failures requires DesConfig::window_ms"
        );
        let ctx = w.cdf.max_len();
        let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
        let peak_rps = match &w.arrivals {
            ArrivalSpec::Nhpp { profile_rps, .. } => profile_rps
                .iter()
                .map(|&(_, r)| r)
                .fold(w.lambda_rps, f64::max),
            _ => w.lambda_rps,
        };
        let start = n_min_for_slice(&hist, 0.0, ctx, peak_rps / 1000.0, gpu,
                                    ctx)
            .unwrap_or(1);
        let script = outage.script(0, k as usize);
        for n in start..=max_gpus {
            let pools = [SimPool {
                gpu: gpu.clone(),
                n_gpus: n as usize,
                ctx_budget: ctx,
                batch_cap: None,
            }];
            let mut r = self.simulate_faulted(
                w, &pools, &RoutingPolicy::Random { n_pools: 1 }, cfg,
                Some(&script),
            );
            if r.meets_slo_in_every_window(slo_ms) {
                return Some((n, r));
            }
        }
        None
    }

    /// Memory-aware sizing: smallest homogeneous fleet that meets the
    /// SLO **in every window with the KV-cache memory model attached**
    /// ([`crate::des::memory`]). The analytic counterpart (and
    /// [`Self::size_to_peak`]) sizes for compute alone; on heavy-tailed
    /// context workloads the binding constraint is KV capacity, so the
    /// memory-aware fleet is never smaller. Same floor, same upward
    /// walk, same every-window test; requires `cfg.window_ms`.
    #[allow(clippy::too_many_arguments)]
    pub fn size_for_memory(
        &self,
        w: &WorkloadSpec,
        gpu: &GpuProfile,
        slo_ms: f64,
        max_gpus: u32,
        cfg: &DesConfig,
        memory: &MemoryConfig,
    ) -> Option<(u32, DesResult)> {
        assert!(
            cfg.window_ms.is_some(),
            "size_for_memory requires DesConfig::window_ms"
        );
        let ctx = w.cdf.max_len();
        let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
        let peak_rps = match &w.arrivals {
            ArrivalSpec::Nhpp { profile_rps, .. } => profile_rps
                .iter()
                .map(|&(_, r)| r)
                .fold(w.lambda_rps, f64::max),
            _ => w.lambda_rps,
        };
        let start = n_min_for_slice(&hist, 0.0, ctx, peak_rps / 1000.0, gpu,
                                    ctx)
            .unwrap_or(1);
        for n in start..=max_gpus {
            let pools = [SimPool {
                gpu: gpu.clone(),
                n_gpus: n as usize,
                ctx_budget: ctx,
                batch_cap: None,
            }];
            let mut r = self.simulate_with(
                w, &pools, &RoutingPolicy::Random { n_pools: 1 }, cfg,
                None, None, Some(memory),
            );
            if r.meets_slo_in_every_window(slo_ms) {
                return Some((n, r));
            }
        }
        None
    }

    // ------- minimal-fleet sizing (hoisted from scenarios::common) -------

    /// Smallest per-pool GPU count meeting the analytical SLO for the
    /// (lo, hi] slice, starting from the utilization-cap lower bound.
    #[allow(clippy::too_many_arguments)]
    pub fn min_pool_gpus(
        hist: &WorkloadHist,
        lo: f64,
        hi: f64,
        lambda_ms: f64,
        gpu: &GpuProfile,
        ctx: f64,
        slo_ms: f64,
        max_gpus: u32,
    ) -> Option<u32> {
        let start = n_min_for_slice(hist, lo, hi, lambda_ms, gpu, ctx)?;
        for n in start..=max_gpus {
            let spec = PoolSpec { gpu: gpu.clone(), n_gpus: n as usize,
                                  ctx_budget: ctx };
            if analyze_pool(hist, lo, hi, lambda_ms, &spec).meets_slo(slo_ms) {
                return Some(n);
            }
        }
        None
    }

    /// Minimal two-pool candidate (analytic Phase 1) for a threshold and
    /// GPU pairing; None if either pool cannot meet the SLO within
    /// `max_gpus`.
    #[allow(clippy::too_many_arguments)]
    pub fn min_two_pool(
        w: &WorkloadSpec,
        hist: &WorkloadHist,
        gpu_s: &GpuProfile,
        gpu_l: &GpuProfile,
        b_short: f64,
        slo_ms: f64,
        max_gpus: u32,
    ) -> Option<Candidate> {
        let max_len = w.cdf.max_len();
        let lam = w.lambda_per_ms();
        let n_s = Self::min_pool_gpus(hist, 0.0, b_short, lam, gpu_s, b_short,
                                      slo_ms, max_gpus)?;
        let n_l = Self::min_pool_gpus(hist, b_short, max_len, lam, gpu_l,
                                      max_len, slo_ms, max_gpus)?;
        Some(Candidate {
            b_short,
            n_s,
            n_l,
            gpu_s: gpu_s.clone(),
            gpu_l: gpu_l.clone(),
            ctx_s: b_short,
            ctx_l: max_len,
        })
    }

    /// Minimal homogeneous candidate.
    pub fn min_homogeneous(
        w: &WorkloadSpec,
        hist: &WorkloadHist,
        gpu: &GpuProfile,
        slo_ms: f64,
        max_gpus: u32,
    ) -> Option<Candidate> {
        let max_len = w.cdf.max_len();
        let n = Self::min_pool_gpus(hist, 0.0, max_len, w.lambda_per_ms(), gpu,
                                    max_len, slo_ms, max_gpus)?;
        Some(Candidate {
            b_short: max_len * 2.0,
            n_s: n,
            n_l: 0,
            gpu_s: gpu.clone(),
            gpu_l: gpu.clone(),
            ctx_s: max_len,
            ctx_l: max_len,
        })
    }

    /// Homogeneous fleet sized by the utilization cap only (ignoring the
    /// SLO) — the paper's Table-1 "homogeneous baseline".
    pub fn rho_cap_homogeneous(
        w: &WorkloadSpec,
        hist: &WorkloadHist,
        gpu: &GpuProfile,
        max_gpus: u32,
    ) -> Option<Candidate> {
        let max_len = w.cdf.max_len();
        let lam = w.lambda_per_ms();
        let start = n_min_for_slice(hist, 0.0, max_len, lam, gpu, max_len)?;
        let n = start.min(max_gpus);
        Some(Candidate {
            b_short: max_len * 2.0,
            n_s: n,
            n_l: 0,
            gpu_s: gpu.clone(),
            gpu_l: gpu.clone(),
            ctx_s: max_len,
            ctx_l: max_len,
        })
    }

    /// Run every [`SweepJob`] in parallel: minimal-fleet search + Phase-2
    /// DES verification per job, preserving input order. `None` entries
    /// are jobs whose fleet is SLO-infeasible within `max_gpus`.
    #[allow(clippy::too_many_arguments)]
    pub fn sweep_min_fleets(
        &self,
        w: &WorkloadSpec,
        hist: &WorkloadHist,
        jobs: Vec<SweepJob>,
        slo_ms: f64,
        max_gpus: u32,
        des: &DesConfig,
    ) -> Vec<Option<(Candidate, Verification)>> {
        self.par_map(jobs, |job| {
            let cand = if job.homogeneous {
                Self::min_homogeneous(w, hist, &job.gpu_s, slo_ms, max_gpus)
            } else {
                Self::min_two_pool(w, hist, &job.gpu_s, &job.gpu_l,
                                   job.b_short, slo_ms, max_gpus)
            }?;
            let v = self.verify(w, &cand, des, slo_ms);
            Some((cand, v))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec::BuiltinTrace;

    fn azure() -> WorkloadSpec {
        WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0)
    }

    #[test]
    fn stream_cache_hits_on_same_key() {
        let e = EvalEngine::standard();
        let w = azure();
        let a = e.sampled_stream(&w, 2_000, 7);
        let b = e.sampled_stream(&w, 2_000, 7);
        assert!(Arc::ptr_eq(&a, &b), "same key must share one stream");
        assert_eq!(e.cached_streams(), 1);
        let c = e.sampled_stream(&w, 2_000, 8);
        assert!(!Arc::ptr_eq(&a, &c));
        let d = e.sampled_stream(&w.at_lambda(50.0), 2_000, 7);
        assert!(!Arc::ptr_eq(&a, &d), "different λ must not share streams");
        assert_eq!(e.cached_streams(), 3);
    }

    #[test]
    fn cached_stream_matches_direct_sampling() {
        let e = EvalEngine::standard();
        let w = azure();
        let s = e.sampled_stream(&w, 1_000, 42);
        assert_eq!(*s, w.sample_requests(1_000, 42));
    }

    #[test]
    fn engine_verify_matches_simulator_run() {
        // The cache path must be bit-identical to Simulator::run.
        let e = EvalEngine::standard();
        let w = azure();
        let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
        let cand = EvalEngine::min_two_pool(
            &w, &hist, e.catalog.get("H100").unwrap(),
            e.catalog.get("H100").unwrap(), 2048.0, 500.0, 64)
            .expect("feasible");
        let cfg = DesConfig { n_requests: 2_000, ..Default::default() };
        let v = e.verify(&w, &cand, &cfg, 500.0);
        let (pools, router) = plan_pools(&cand);
        let mut direct = Simulator::new(w.clone(), pools, router, cfg).run();
        assert_eq!(v.p99_ttft_ms, direct.overall.p99_ttft());
        assert_eq!(v.utilization.len(), 2);
    }

    #[test]
    fn sweep_min_fleets_preserves_order_and_flags_infeasible() {
        let e = EvalEngine::standard();
        let w = azure();
        let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
        let h100 = e.catalog.get("H100").unwrap().clone();
        let jobs = vec![
            SweepJob::two_pool(&h100, &h100, 2048.0),
            SweepJob::homogeneous(&h100),
            SweepJob::two_pool(&h100, &h100, 4096.0),
        ];
        let des = DesConfig { n_requests: 2_000, ..Default::default() };
        let rows = e.sweep_min_fleets(&w, &hist, jobs, 500.0, 256, &des);
        assert_eq!(rows.len(), 3);
        assert!(rows[0].is_some() && rows[1].is_some());
        let (cand, v) = rows[0].as_ref().unwrap();
        assert_eq!(cand.b_short, 2048.0);
        assert!(v.p99_ttft_ms > 0.0);
        let infeasible = e.sweep_min_fleets(
            &w, &hist,
            vec![SweepJob::two_pool(&h100, &h100, 2048.0)],
            500.0, 1, &des);
        assert!(infeasible[0].is_none());
    }

    #[test]
    fn nhpp_and_poisson_streams_never_collide_in_cache() {
        let e = EvalEngine::standard();
        let poisson = azure(); // λ = 100 stationary
        let nhpp = azure()
            .with_nhpp(vec![(0.0, 50.0), (5_000.0, 150.0)], 10_000.0);
        // Same mean λ (100 rps), same (n, seed) — distinct streams.
        assert!((nhpp.lambda_rps - poisson.lambda_rps).abs() < 1e-9);
        let a = e.sampled_stream(&poisson, 1_000, 7);
        let b = e.sampled_stream(&nhpp, 1_000, 7);
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(e.cached_streams(), 2);
        assert_ne!(*a, *b, "NHPP stream must differ from Poisson");
    }

    #[test]
    fn size_to_peak_satisfies_every_window() {
        let e = EvalEngine::standard();
        let w = azure()
            .with_nhpp(vec![(0.0, 40.0), (10_000.0, 200.0)], 20_000.0);
        let gpu = e.catalog.get("H100").unwrap().clone();
        let cfg = DesConfig {
            n_requests: 4_000,
            window_ms: Some(5_000.0),
            ..Default::default()
        };
        let (n, mut r) =
            e.size_to_peak(&w, &gpu, 500.0, 128, &cfg).expect("feasible");
        assert!(n >= 1);
        assert_eq!(r.n_unserved, 0);
        assert!(r.meets_slo_in_every_window(500.0));
        let ws = r.windows.as_ref().expect("windowed run");
        assert!(ws.n_windows() >= 4);
    }

    #[test]
    fn size_for_failures_zero_matches_size_to_peak() {
        let e = EvalEngine::standard();
        let w = azure()
            .with_nhpp(vec![(0.0, 40.0), (10_000.0, 200.0)], 20_000.0);
        let gpu = e.catalog.get("H100").unwrap().clone();
        let cfg = DesConfig {
            n_requests: 4_000,
            window_ms: Some(5_000.0),
            ..Default::default()
        };
        let outage = OutageSpec {
            fail_at_ms: 10_000.0,
            mttr_ms: 10_000.0,
            warm_ms: 2_000.0,
            warm_factor: 2.0,
        };
        let (n0, mut r0) =
            e.size_to_peak(&w, &gpu, 500.0, 128, &cfg).expect("feasible");
        let (nk, mut rk) = e
            .size_for_failures(&w, &gpu, 500.0, 0, 128, &cfg, &outage)
            .expect("feasible");
        // k = 0 compiles to an empty script: same floor, same walk,
        // bit-identical winner.
        assert_eq!(nk, n0);
        assert_eq!(rk.overall.p99_ttft(), r0.overall.p99_ttft());
        assert_eq!(rk.n_events, r0.n_events);
    }

    #[test]
    fn size_for_failures_is_monotone_in_k() {
        // A whole-run outage (failure at t = 0, recovery beyond the
        // horizon) makes k permanently-down GPUs *exactly* a fleet of
        // n - k: the least-loaded scan skips the down tail, so the
        // admission sequence over the alive prefix is bit-identical.
        // Hence size(k) == size(0) + k, the strongest monotonicity.
        let e = EvalEngine::standard();
        let w = azure(); // stationary λ = 100
        let gpu = e.catalog.get("H100").unwrap().clone();
        let cfg = DesConfig {
            n_requests: 3_000,
            window_ms: Some(5_000.0),
            ..Default::default()
        };
        let outage = OutageSpec {
            fail_at_ms: 0.0,
            mttr_ms: 600_000.0,
            warm_ms: 0.0,
            warm_factor: 1.0,
        };
        let n0 = e
            .size_for_failures(&w, &gpu, 500.0, 0, 128, &cfg, &outage)
            .expect("feasible")
            .0;
        for k in [1u32, 2] {
            let nk = e
                .size_for_failures(&w, &gpu, 500.0, k, 128, &cfg, &outage)
                .expect("feasible")
                .0;
            assert_eq!(nk, n0 + k, "k = {k}");
        }
    }

    #[test]
    fn size_for_memory_matches_compute_sizing_when_memory_is_loose() {
        use crate::des::memory::{MemoryConfig, MemorySpec, PolicyKind};
        // A memory model that never binds must not change the sizing
        // walk: window TTFTs are bit-identical to the open loop, so the
        // every-window test admits the same smallest fleet.
        let e = EvalEngine::standard();
        let w = azure()
            .with_nhpp(vec![(0.0, 40.0), (10_000.0, 200.0)], 20_000.0);
        let gpu = e.catalog.get("H100").unwrap().clone();
        let cfg = DesConfig {
            n_requests: 3_000,
            window_ms: Some(5_000.0),
            ..Default::default()
        };
        let loose = MemoryConfig {
            spec: MemorySpec {
                hbm_gb: Some(10_000.0),
                weights_gb: 0.0,
                bytes_per_token: 1e3,
            },
            policy: PolicyKind::EvictRecompute,
            swap_out_ms: 0.0,
            swap_in_ms: 0.0,
        };
        let (n0, mut r0) =
            e.size_to_peak(&w, &gpu, 500.0, 128, &cfg).expect("feasible");
        let (nm, mut rm) = e
            .size_for_memory(&w, &gpu, 500.0, 128, &cfg, &loose)
            .expect("feasible");
        assert_eq!(nm, n0);
        assert_eq!(rm.overall.p99_ttft(), r0.overall.p99_ttft());
        assert_eq!(rm.n_preempted, 0);
        assert!(rm.kv_peak_util > 0.0 && rm.kv_peak_util < 0.05,
                "loose pool must sit near-empty, got {}", rm.kv_peak_util);
        assert!(rm.kv_mean_util <= rm.kv_peak_util);
    }

    #[test]
    fn simulate_robust_none_is_open_loop_and_some_counts_attempts() {
        use crate::des::retry::{RetryConfig, RetrySpec};
        let e = EvalEngine::standard();
        let w = azure();
        let gpu = e.catalog.get("H100").unwrap().clone();
        // Generously over-provisioned: with a 60 s deadline nothing can
        // time out, so the closed-loop run serves every request on its
        // first attempt.
        let pools = [SimPool {
            gpu,
            n_gpus: 16,
            ctx_budget: w.cdf.max_len(),
            batch_cap: None,
        }];
        let router = RoutingPolicy::Random { n_pools: 1 };
        let cfg = DesConfig { n_requests: 2_000, ..Default::default() };
        let open = e.simulate_faulted(&w, &pools, &router, &cfg, None);
        let robust =
            e.simulate_robust(&w, &pools, &router, &cfg, None, None);
        assert_eq!(open.n_events, robust.n_events);
        assert_eq!(open.horizon_ms, robust.horizon_ms);
        assert_eq!(robust.n_attempts, 0, "open loop records no attempts");
        let rc = RetryConfig {
            retry: Some(RetrySpec {
                max_attempts: 3,
                timeout_ms: 60_000.0,
                backoff_base_ms: 250.0,
                backoff_cap_ms: 1_000.0,
            }),
            admission: None,
        };
        let closed =
            e.simulate_robust(&w, &pools, &router, &cfg, None, Some(&rc));
        assert_eq!(closed.n_attempts, 2_000, "lenient config: one per req");
        assert_eq!(
            closed.overall.count + closed.n_abandoned + closed.n_shed,
            2_000
        );
    }

    #[test]
    fn phase1_ranks_feasible_candidates() {
        let e = EvalEngine::standard();
        let (cands, results, ranked) = e
            .phase1(&azure(), &GenOptions::default(), 500.0)
            .unwrap();
        assert_eq!(cands.len(), results.len());
        assert!(!ranked.is_empty());
        for pair in ranked.windows(2) {
            assert!(results[pair[0]].cost_yr <= results[pair[1]].cost_yr);
        }
        assert_eq!(e.backend_name(), "native");
    }
}
