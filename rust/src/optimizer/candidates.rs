//! Candidate fleet configurations for the Phase-1 sweep (paper §3.1).
//!
//! A candidate fixes `(B_short, n_s, n_l, GPU type per pool)`. The
//! generator exploits pool independence to keep the grid small: for each
//! `(B_short, gpu_s, gpu_l)` it brackets the GPU counts around the
//! utilization-cap lower bound `n_min = ceil(lambda_pool * E[S] / rho_max)`
//! instead of sweeping all of 1..512 — the same candidates a full grid
//! would rank highest, at ~1% of the evaluations.

use crate::gpu::catalog::GpuCatalog;
use crate::gpu::profile::GpuProfile;
use crate::queueing::erlang::C_MAX;
use crate::queueing::mgc::{PoolSpec, RHO_MAX, WorkloadHist};
use crate::workload::spec::WorkloadSpec;

/// One fleet configuration under evaluation.
#[derive(Debug, Clone)]
pub struct Candidate {
    /// Split threshold in tokens; >= max workload length means a
    /// homogeneous (single-pool) fleet with n_l == 0.
    pub b_short: f64,
    pub n_s: u32,
    pub n_l: u32,
    pub gpu_s: GpuProfile,
    pub gpu_l: GpuProfile,
    /// Context budgets per pool (b_short and the workload max).
    pub ctx_s: f64,
    pub ctx_l: f64,
}

impl Candidate {
    pub fn is_homogeneous(&self) -> bool {
        self.n_l == 0
    }

    pub fn total_gpus(&self) -> u32 {
        self.n_s + self.n_l
    }

    pub fn cost_per_year(&self) -> f64 {
        self.n_s as f64 * self.gpu_s.cost_per_year()
            + self.n_l as f64 * self.gpu_l.cost_per_year()
    }

    pub fn label(&self) -> String {
        if self.is_homogeneous() {
            format!("{} homo x{}", self.gpu_s.name, self.n_s)
        } else {
            format!(
                "{}x{} short(B={}) + {}x{} long",
                self.gpu_s.name, self.n_s, self.b_short, self.gpu_l.name,
                self.n_l
            )
        }
    }

    pub fn short_spec(&self) -> PoolSpec {
        PoolSpec { gpu: self.gpu_s.clone(), n_gpus: self.n_s as usize,
                   ctx_budget: self.ctx_s }
    }

    pub fn long_spec(&self) -> PoolSpec {
        PoolSpec { gpu: self.gpu_l.clone(), n_gpus: self.n_l.max(1) as usize,
                   ctx_budget: self.ctx_l }
    }
}

/// Phase-1 evaluation of one candidate (mirrors the AOT artifact's output
/// columns; see python/compile/model.py OUTPUT_COLUMNS).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CandidateResult {
    pub rho_s: f64,
    pub rho_l: f64,
    pub ttft99_s: f64,
    pub ttft99_l: f64,
    pub w99_s: f64,
    pub w99_l: f64,
    pub cost_yr: f64,
    pub feasible: bool,
}

impl CandidateResult {
    pub fn worst_ttft(&self) -> f64 {
        self.ttft99_s.max(self.ttft99_l)
    }
}

/// Candidate-generation options.
#[derive(Debug, Clone)]
pub struct GenOptions {
    /// Split thresholds to sweep (paper §4.1 uses {512 .. 12288}).
    pub thresholds: Vec<f64>,
    /// Include the homogeneous (no-split) baseline.
    pub include_homogeneous: bool,
    /// Allow different GPU types per pool (paper §4.6).
    pub allow_mixed: bool,
    /// How many counts above the utilization lower bound to explore.
    pub headroom: u32,
    /// Cap on GPUs per pool.
    pub max_gpus_per_pool: u32,
}

impl Default for GenOptions {
    fn default() -> Self {
        GenOptions {
            thresholds: vec![512.0, 1024.0, 2048.0, 3072.0, 4096.0, 8192.0,
                             12288.0, 16384.0, 32768.0],
            include_homogeneous: true,
            allow_mixed: false,
            headroom: 4,
            max_gpus_per_pool: C_MAX as u32,
        }
    }
}

/// Utilization-cap lower bound on the GPU count for a pool slice.
/// Returns None if the slice is empty (no pool needed).
pub fn n_min_for_slice(
    hist: &WorkloadHist,
    lo: f64,
    hi: f64,
    lambda_total_ms: f64,
    gpu: &GpuProfile,
    ctx: f64,
) -> Option<u32> {
    let alpha = hist.mass(lo, hi);
    if alpha <= 1e-12 {
        return None;
    }
    // Mean iteration count over the slice.
    let n = gpu.n_eff(ctx);
    let mut i1 = 0.0;
    for (p, &l) in hist.probs.iter().zip(&hist.lens) {
        if l > lo && l <= hi {
            let l_in = (l * hist.input_frac).ceil();
            let l_out = (l - l_in).max(1.0);
            i1 += p * gpu.iters(l_in, l_out);
        }
    }
    i1 /= alpha;
    // Under the equilibrium service model (mgc::equilibrium_batch) the
    // rho <= RHO_MAX constraint has the closed form
    //   c >= x H + x W / (n_eff * rho_max),  x = lambda_pool * E[iters].
    let x = lambda_total_ms * alpha * i1; // demanded tokens/ms
    let c = x * gpu.h_ms_per_slot + x * gpu.w_ms / (n * RHO_MAX);
    Some((c.ceil() as u32).max(1))
}

/// Generate the Phase-1 candidate set for a workload.
pub fn generate(
    workload: &WorkloadSpec,
    catalog: &GpuCatalog,
    opts: &GenOptions,
) -> Vec<Candidate> {
    let hist = WorkloadHist::from_cdf(&workload.cdf, workload.input_fraction);
    let max_len = workload.cdf.max_len();
    let lam = workload.lambda_per_ms();
    let mut out = Vec::new();

    let gpus = catalog.profiles();
    for gpu_s in gpus {
        // Skip GPUs that cannot hold the short context at all.
        for &b in &opts.thresholds {
            if b >= max_len {
                continue; // covered by the homogeneous candidates
            }
            if !gpu_s.supports_context(b) {
                continue;
            }
            let long_types: Vec<&GpuProfile> = if opts.allow_mixed {
                gpus.iter().collect()
            } else {
                vec![gpu_s]
            };
            for gpu_l in long_types {
                if !gpu_l.supports_context(max_len) {
                    continue;
                }
                let Some(ns_min) =
                    n_min_for_slice(&hist, 0.0, b, lam, gpu_s, b)
                else {
                    continue;
                };
                let Some(nl_min) =
                    n_min_for_slice(&hist, b, max_len, lam, gpu_l, max_len)
                else {
                    continue;
                };
                for ds in 0..=opts.headroom {
                    for dl in 0..=opts.headroom {
                        let n_s = (ns_min + ds).min(opts.max_gpus_per_pool);
                        let n_l = (nl_min + dl).min(opts.max_gpus_per_pool);
                        out.push(Candidate {
                            b_short: b,
                            n_s,
                            n_l,
                            gpu_s: gpu_s.clone(),
                            gpu_l: gpu_l.clone(),
                            ctx_s: b,
                            ctx_l: max_len,
                        });
                    }
                }
            }
        }
        if opts.include_homogeneous && gpu_s.supports_context(max_len) {
            if let Some(n_min) =
                n_min_for_slice(&hist, 0.0, max_len, lam, gpu_s, max_len)
            {
                for d in 0..=opts.headroom * 2 {
                    out.push(Candidate {
                        b_short: max_len * 2.0,
                        n_s: (n_min + d).min(opts.max_gpus_per_pool),
                        n_l: 0,
                        gpu_s: gpu_s.clone(),
                        gpu_l: gpu_s.clone(),
                        ctx_s: max_len,
                        ctx_l: max_len,
                    });
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec::BuiltinTrace;

    fn azure100() -> WorkloadSpec {
        WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0)
    }

    #[test]
    fn generates_two_pool_and_homogeneous() {
        let cands = generate(&azure100(), &GpuCatalog::standard(),
                             &GenOptions::default());
        assert!(!cands.is_empty());
        assert!(cands.iter().any(|c| c.is_homogeneous()));
        assert!(cands.iter().any(|c| !c.is_homogeneous()));
        // All thresholds beyond the Azure max (8192) fold into homo.
        assert!(cands.iter().all(|c| c.is_homogeneous() || c.b_short < 8192.0));
    }

    #[test]
    fn mixed_mode_generates_cross_type_pools() {
        let mut opts = GenOptions::default();
        let base = generate(&azure100(), &GpuCatalog::standard(), &opts).len();
        opts.allow_mixed = true;
        let cands = generate(&azure100(), &GpuCatalog::standard(), &opts);
        assert!(cands.len() > base);
        assert!(cands
            .iter()
            .any(|c| !c.is_homogeneous() && c.gpu_s.name != c.gpu_l.name));
    }

    #[test]
    fn n_min_respects_utilization_cap() {
        let w = azure100();
        let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
        let cat = GpuCatalog::standard();
        let h100 = cat.get("H100").unwrap();
        let n = n_min_for_slice(&hist, 0.0, 1e9, w.lambda_per_ms(), h100,
                                8192.0)
            .unwrap();
        // Sanity: a handful of H100s serve Azure at 100 req/s (Table 3).
        assert!((4..=12).contains(&n), "n_min = {n}");
        // Empty slice -> None.
        assert!(n_min_for_slice(&hist, 1e8, 1e9, w.lambda_per_ms(), h100,
                                8192.0)
            .is_none());
    }

    #[test]
    fn candidate_cost_and_labels() {
        let cat = GpuCatalog::standard();
        let c = Candidate {
            b_short: 4096.0,
            n_s: 3,
            n_l: 5,
            gpu_s: cat.get("A100").unwrap().clone(),
            gpu_l: cat.get("A100").unwrap().clone(),
            ctx_s: 4096.0,
            ctx_l: 65536.0,
        };
        assert_eq!(c.total_gpus(), 8);
        // Table 1: 8 A100s = $155K/yr.
        assert!((c.cost_per_year() - 154_876.8).abs() < 10.0);
        assert!(c.label().contains("A100"));
    }

    #[test]
    fn slower_gpus_need_more_units() {
        let w = azure100();
        let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
        let cat = GpuCatalog::standard();
        let n_a10g = n_min_for_slice(&hist, 0.0, 1e9, w.lambda_per_ms(),
                                     cat.get("A10G").unwrap(), 8192.0)
            .unwrap();
        let n_h100 = n_min_for_slice(&hist, 0.0, 1e9, w.lambda_per_ms(),
                                     cat.get("H100").unwrap(), 8192.0)
            .unwrap();
        assert!(n_a10g > n_h100 * 2, "a10g {n_a10g} vs h100 {n_h100}");
    }
}
