//! The two-phase fleet optimizer (paper §3.1, Figure 1).
//!
//! Phase 1 ranks candidate configurations with the analytical M/G/c model
//! (native or AOT-compiled JAX/Pallas evaluator); Phase 2 verifies the
//! top-k by discrete-event simulation and returns the cheapest candidate
//! that *empirically* meets the P99-TTFT SLO. Reliability-aware sizing
//! (§3.5) is applied to the winner.

use crate::des::engine::{DesConfig, SimPool};
use crate::gpu::catalog::GpuCatalog;
use crate::optimizer::analytic::{rank_feasible, NativeSweep, SweepEval};
use crate::optimizer::candidates::{generate, Candidate, CandidateResult,
                                   GenOptions};
use crate::optimizer::engine::EvalEngine;
use crate::optimizer::reliability::NodeAvail;
use crate::router::RoutingPolicy;
use crate::util::parallel::default_threads;
use crate::util::table::{dollars, millis};
use crate::workload::spec::WorkloadSpec;

/// Phase-2 verification outcome for one candidate.
#[derive(Debug, Clone)]
pub struct Verification {
    pub p99_ttft_ms: f64,
    pub p99_ttft_short_ms: f64,
    pub p99_ttft_long_ms: f64,
    pub utilization: Vec<f64>,
    pub passed: bool,
}

/// A fully evaluated plan entry (candidate + both phases).
#[derive(Debug, Clone)]
pub struct PlanEntry {
    pub candidate: Candidate,
    pub analytic: CandidateResult,
    pub verification: Option<Verification>,
}

/// The optimizer's output.
#[derive(Debug, Clone)]
pub struct FleetPlan {
    /// Cheapest DES-verified configuration, if any passed.
    pub chosen: Option<PlanEntry>,
    /// All Phase-2-verified entries, cheapest first.
    pub verified: Vec<PlanEntry>,
    /// Phase-1 feasible count (for reporting).
    pub n_phase1_feasible: usize,
    pub n_candidates: usize,
    /// Production GPU counts after reliability adjustment (§3.5).
    pub production_n_s: u32,
    pub production_n_l: u32,
    pub backend: &'static str,
}

impl FleetPlan {
    /// One-paragraph human summary.
    pub fn summary(&self) -> String {
        match &self.chosen {
            Some(e) => {
                let v = e.verification.as_ref().unwrap();
                format!(
                    "{} — {} / yr, DES P99 TTFT {} (short {}, long {}); \
                     production counts with node_avail: {} short + {} long \
                     [{} candidates, {} phase-1 feasible, backend {}]",
                    e.candidate.label(),
                    dollars(e.analytic.cost_yr),
                    millis(v.p99_ttft_ms),
                    millis(v.p99_ttft_short_ms),
                    millis(v.p99_ttft_long_ms),
                    self.production_n_s,
                    self.production_n_l,
                    self.n_candidates,
                    self.n_phase1_feasible,
                    self.backend,
                )
            }
            None => format!(
                "no feasible configuration found ({} candidates, {} phase-1 \
                 feasible, backend {})",
                self.n_candidates, self.n_phase1_feasible, self.backend
            ),
        }
    }
}

/// The two-phase optimizer.
pub struct FleetOptimizer {
    pub catalog: GpuCatalog,
    pub slo_ms: f64,
    pub gen: GenOptions,
    /// How many Phase-1 leaders go to DES verification.
    pub top_k: usize,
    pub des: DesConfig,
    /// Reliability adjustment applied to the winner (§3.5).
    pub node_avail: NodeAvail,
    /// Worker threads for Phase-2.
    pub threads: usize,
}

impl FleetOptimizer {
    pub fn new(catalog: GpuCatalog, slo_ms: f64) -> Self {
        FleetOptimizer {
            catalog,
            slo_ms,
            gen: GenOptions::default(),
            top_k: 8,
            des: DesConfig::default(),
            node_avail: NodeAvail::default(),
            threads: default_threads(),
        }
    }

    /// Phase 1 only: generate + evaluate + rank. Returns (candidates,
    /// results, ranked indices).
    pub fn phase1(
        &self,
        workload: &WorkloadSpec,
        eval: &dyn SweepEval,
    ) -> anyhow::Result<(Vec<Candidate>, Vec<CandidateResult>, Vec<usize>)> {
        let cands = generate(workload, &self.catalog, &self.gen);
        let results = eval.eval(workload, &cands, self.slo_ms)?;
        let ranked = rank_feasible(&cands, &results);
        Ok((cands, results, ranked))
    }

    /// Phase 2: DES-verify one candidate with the production LengthRouter.
    pub fn verify(
        &self,
        workload: &WorkloadSpec,
        cand: &Candidate,
    ) -> Verification {
        EvalEngine::native(self.catalog.clone())
            .verify(workload, cand, &self.des, self.slo_ms)
    }

    /// Full two-phase plan with the given Phase-1 backend.
    pub fn plan_with(
        &self,
        workload: &WorkloadSpec,
        eval: &dyn SweepEval,
    ) -> anyhow::Result<FleetPlan> {
        let (cands, results, ranked) = self.phase1(workload, eval)?;
        let n_feasible = ranked.len();
        let top: Vec<usize> = ranked.into_iter().take(self.top_k).collect();

        // Phase-2 verification goes through the evaluation engine: the
        // top-k candidates share one cached request stream and fan out
        // over worker threads.
        let engine =
            EvalEngine::native(self.catalog.clone()).with_threads(self.threads);
        let verified: Vec<PlanEntry> = engine.par_map(top, |&i| {
            let v = engine.verify(workload, &cands[i], &self.des, self.slo_ms);
            PlanEntry {
                candidate: cands[i].clone(),
                analytic: results[i],
                verification: Some(v),
            }
        });

        let chosen = verified
            .iter()
            .find(|e| e.verification.as_ref().unwrap().passed)
            .cloned();
        let (prod_s, prod_l) = match &chosen {
            Some(e) => (
                self.node_avail.production_count(e.candidate.n_s),
                self.node_avail.production_count(e.candidate.n_l),
            ),
            None => (0, 0),
        };
        Ok(FleetPlan {
            chosen,
            verified,
            n_phase1_feasible: n_feasible,
            n_candidates: cands.len(),
            production_n_s: prod_s,
            production_n_l: prod_l,
            backend: eval.backend(),
        })
    }

    /// Full two-phase plan with the native Phase-1 evaluator.
    pub fn plan(&self, workload: &WorkloadSpec) -> FleetPlan {
        self.plan_with(workload, &NativeSweep)
            .expect("native sweep is infallible")
    }
}

/// Materialize a candidate into DES pools + the production router.
pub fn plan_pools(cand: &Candidate) -> (Vec<SimPool>, RoutingPolicy) {
    if cand.is_homogeneous() {
        (
            vec![SimPool {
                gpu: cand.gpu_s.clone(),
                n_gpus: cand.n_s as usize,
                ctx_budget: cand.ctx_l,
                batch_cap: None,
            }],
            RoutingPolicy::Random { n_pools: 1 },
        )
    } else {
        (
            vec![
                SimPool {
                    gpu: cand.gpu_s.clone(),
                    n_gpus: cand.n_s as usize,
                    ctx_budget: cand.ctx_s,
                    batch_cap: None,
                },
                SimPool {
                    gpu: cand.gpu_l.clone(),
                    n_gpus: cand.n_l as usize,
                    ctx_budget: cand.ctx_l,
                    batch_cap: None,
                },
            ],
            RoutingPolicy::Length { b_short: cand.b_short },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec::BuiltinTrace;

    fn opt(slo: f64) -> FleetOptimizer {
        let mut o = FleetOptimizer::new(GpuCatalog::standard(), slo);
        o.des.n_requests = 6_000;
        o
    }

    #[test]
    fn plans_lmsys_two_pool_and_meets_slo() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 100.0);
        let plan = opt(500.0).plan(&w);
        let chosen = plan.chosen.as_ref().expect("plan found");
        let v = chosen.verification.as_ref().unwrap();
        assert!(v.passed, "DES P99 = {}", v.p99_ttft_ms);
        // The winner should be a split fleet (Table 1's headline effect).
        assert!(!chosen.candidate.is_homogeneous());
        assert!(plan.n_phase1_feasible > 0);
        // Verified list is cost-ascending.
        for pair in plan.verified.windows(2) {
            assert!(pair[0].analytic.cost_yr <= pair[1].analytic.cost_yr);
        }
    }

    #[test]
    fn production_counts_exceed_raw() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
        let mut o = opt(500.0);
        o.node_avail = NodeAvail::five_percent_rule();
        let plan = o.plan(&w);
        let c = plan.chosen.as_ref().unwrap();
        assert!(plan.production_n_s >= c.candidate.n_s);
        assert!(
            plan.production_n_s + plan.production_n_l
                > c.candidate.total_gpus() - 1
        );
    }

    #[test]
    fn impossible_slo_returns_no_plan() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 100.0);
        let plan = opt(0.5).plan(&w); // 0.5 ms: below one iteration
        assert!(plan.chosen.is_none());
        assert_eq!(plan.n_phase1_feasible, 0);
    }

    #[test]
    fn plan_summary_mentions_cost_and_backend() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 50.0);
        let plan = opt(500.0).plan(&w);
        let s = plan.summary();
        assert!(s.contains("backend native"), "{s}");
        assert!(s.contains('$'), "{s}");
    }

    #[test]
    fn verify_reports_pool_breakdown() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0);
        let o = opt(500.0);
        let (cands, _, ranked) = o.phase1(&w, &NativeSweep).unwrap();
        let split = ranked
            .iter()
            .find(|&&i| !cands[i].is_homogeneous())
            .copied()
            .unwrap();
        let v = o.verify(&w, &cands[split]);
        assert!(v.p99_ttft_short_ms > 0.0);
        assert_eq!(v.utilization.len(), 2);
    }
}
