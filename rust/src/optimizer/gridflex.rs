//! Grid demand-response analysis — `grid_flex_analysis()` (paper §4.8,
//! Table 9).
//!
//! For each target power-reduction percentage the function:
//!
//! 1. inverts the logistic GPU power model to the implied batch cap
//!    (`n_max'`),
//! 2. *recalibrates* the M/G/c service rate at the reduced concurrency —
//!    iterations are faster at lower batch (t_iter(n') < t_iter(n)), so
//!    the analytical model must not reuse the full-batch service time,
//! 3. re-evaluates Kimura P99 TTFT and stability,
//! 4. verifies by DES — a full steady-state run, plus a windowed run for
//!    the short-event bound (a 75 s curtailment inside a longer horizon).

use crate::des::engine::{CapWindow, DesConfig, SimPool, Simulator};
use crate::gpu::profile::GpuProfile;
use crate::queueing::mgc::RHO_MAX;
use crate::router::RoutingPolicy;
use crate::workload::spec::WorkloadSpec;

/// One row of the grid-flexibility curve.
#[derive(Debug, Clone)]
pub struct FlexPoint {
    pub flex: f64,
    /// Batch cap implied by the power target.
    pub n_max: u32,
    /// Per-GPU power at that cap, watts.
    pub w_per_gpu: f64,
    /// Fleet power, kW.
    pub fleet_kw: f64,
    /// Recalibrated analytical P99 TTFT (inf = unstable).
    pub p99_analytic_ms: f64,
    /// Steady-state DES P99 TTFT.
    pub p99_des_ms: f64,
    /// DES P99 TTFT for requests arriving during a short DR window.
    pub p99_event_ms: f64,
    /// Stable at steady state (analytical rho <= RHO_MAX).
    pub steady_ok: bool,
    /// SLO met during a short event window.
    pub event_ok: bool,
}

/// Parameters of the analysis.
#[derive(Debug, Clone)]
pub struct GridFlexConfig {
    /// Flex levels to sweep (fractions of nominal power).
    pub flex_levels: Vec<f64>,
    /// Fleet size (GPUs).
    pub n_gpus: usize,
    /// Baseline batch cap (vLLM max_num_seqs).
    pub baseline_cap: u32,
    /// P99 TTFT SLO, ms.
    pub slo_ms: f64,
    /// DES request count (paper: N = 15 000).
    pub n_requests: usize,
    /// Short-event duration, ms (paper: ~75 s).
    pub event_ms: f64,
    pub seed: u64,
}

impl Default for GridFlexConfig {
    fn default() -> Self {
        GridFlexConfig {
            flex_levels: vec![0.0, 0.10, 0.20, 0.30, 0.40, 0.50],
            n_gpus: 40,
            baseline_cap: 128,
            slo_ms: 500.0,
            n_requests: 15_000,
            event_ms: 75_000.0,
            seed: 42,
        }
    }
}

/// Recalibrated analytical P99 TTFT at batch cap `cap` (paper §4.8:
/// "the M/G/c service rate is recalibrated at each batch cap").
pub fn analytic_p99_at_cap(
    workload: &WorkloadSpec,
    gpu: &GpuProfile,
    n_gpus: usize,
    ctx: f64,
    cap: u32,
) -> (f64, f64) {
    // Reuse the standard pool model with a batch-capped clone of the
    // profile: n_eff(ctx) then reflects min(n_max, cap) and the
    // equilibrium recalibration happens inside analyze_pool.
    let mut capped = gpu.clone();
    capped.max_num_seqs = capped.max_num_seqs.min(cap as f64).max(1.0);
    let hist = crate::queueing::mgc::WorkloadHist::from_cdf(
        &workload.cdf, workload.input_fraction);
    let spec = crate::queueing::mgc::PoolSpec {
        gpu: capped, n_gpus, ctx_budget: ctx,
    };
    let a = crate::queueing::mgc::analyze_pool(
        &hist, 0.0, ctx, workload.lambda_per_ms(), &spec);
    (a.ttft99_ms, a.rho)
}

/// Run the full grid-flexibility analysis.
pub fn grid_flex_analysis(
    workload: &WorkloadSpec,
    gpu: &GpuProfile,
    cfg: &GridFlexConfig,
) -> Vec<FlexPoint> {
    let ctx = workload.cdf.max_len();
    let mut out = Vec::with_capacity(cfg.flex_levels.len());
    for &flex in &cfg.flex_levels {
        let cap = if flex <= 0.0 {
            cfg.baseline_cap
        } else {
            (gpu.batch_cap_for_flex(flex) as u32).min(cfg.baseline_cap)
        };
        let n_eff = (gpu.n_eff(ctx).min(cap as f64)).max(1.0);
        let w_per_gpu = gpu.power_w(n_eff);
        let fleet_kw = w_per_gpu * cfg.n_gpus as f64 / 1000.0;

        let (p99_analytic, rho) =
            analytic_p99_at_cap(workload, gpu, cfg.n_gpus, ctx, cap);
        let steady_ok = rho <= RHO_MAX && p99_analytic <= cfg.slo_ms;
        let p99_analytic =
            if rho > RHO_MAX { f64::INFINITY } else { p99_analytic };

        // Steady-state DES at the cap.
        let pools = vec![SimPool {
            gpu: gpu.clone(),
            n_gpus: cfg.n_gpus,
            ctx_budget: ctx,
            batch_cap: Some(cap),
        }];
        let des_cfg = DesConfig {
            n_requests: cfg.n_requests,
            seed: cfg.seed,
            ..Default::default()
        };
        let mut steady = Simulator::new(
            workload.clone(),
            pools.clone(),
            RoutingPolicy::Random { n_pools: 1 },
            des_cfg.clone(),
        )
        .run();
        let p99_des = steady.overall.p99_ttft();

        // Short-event DES: full capacity except a cap window mid-run.
        let expected_span_ms =
            cfg.n_requests as f64 / workload.lambda_per_ms();
        let start = (expected_span_ms * 0.3).max(1.0);
        let window = CapWindow { start_ms: start, end_ms: start + cfg.event_ms,
                                 cap };
        let event_pools = vec![SimPool {
            gpu: gpu.clone(),
            n_gpus: cfg.n_gpus,
            ctx_budget: ctx,
            batch_cap: Some(cfg.baseline_cap),
        }];
        let event = Simulator::new(
            workload.clone(),
            event_pools,
            RoutingPolicy::Random { n_pools: 1 },
            DesConfig { cap_window: Some(window), ..des_cfg },
        )
        .run();
        // P99 over requests that arrived inside the window.
        let mut in_window = crate::util::stats::Samples::new();
        {
            // Re-derive arrival times to filter: same seed stream.
            let sampled = workload.sample_requests(cfg.n_requests, cfg.seed);
            for (s, &t) in sampled.iter().zip(event.overall.ttft.values()) {
                if s.arrival_ms >= window.start_ms
                    && s.arrival_ms < window.end_ms
                {
                    in_window.push(t);
                }
            }
        }
        let p99_event = if in_window.is_empty() {
            0.0
        } else {
            in_window.p99()
        };
        out.push(FlexPoint {
            flex,
            n_max: cap,
            w_per_gpu,
            fleet_kw,
            p99_analytic_ms: p99_analytic,
            p99_des_ms: p99_des,
            p99_event_ms: p99_event,
            steady_ok,
            event_ok: p99_event <= cfg.slo_ms,
        })
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::catalog::GpuCatalog;
    use crate::workload::spec::BuiltinTrace;

    fn setup() -> (WorkloadSpec, GpuProfile, GridFlexConfig) {
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 200.0);
        let gpu = GpuCatalog::standard().get("H100").unwrap().clone();
        let mut cfg = GridFlexConfig::default();
        cfg.n_requests = 8_000; // keep tests quick
        (w, gpu, cfg)
    }

    #[test]
    fn reproduces_table9_cap_and_power_columns() {
        let (w, gpu, cfg) = setup();
        let rows = grid_flex_analysis(&w, &gpu, &cfg);
        assert_eq!(rows.len(), 6);
        // n_max column: 128, 48, 24, 13, 6-7, 1.
        assert_eq!(rows[0].n_max, 128);
        assert_eq!(rows[1].n_max, 48);
        assert_eq!(rows[2].n_max, 24);
        assert_eq!(rows[3].n_max, 13);
        assert!((6..=7).contains(&rows[4].n_max));
        assert_eq!(rows[5].n_max, 1);
        // Fleet kW: 23.3 at baseline, monotone decreasing.
        assert!((rows[0].fleet_kw - 23.3).abs() < 0.3, "{}", rows[0].fleet_kw);
        for wpair in rows.windows(2) {
            assert!(wpair[1].fleet_kw < wpair[0].fleet_kw);
        }
    }

    #[test]
    fn stability_degrades_with_depth() {
        let (w, gpu, cfg) = setup();
        let rows = grid_flex_analysis(&w, &gpu, &cfg);
        // Shallow flex is steady-state safe; 50% collapses.
        assert!(rows[0].steady_ok);
        assert!(rows[1].steady_ok);
        assert!(!rows[5].steady_ok, "50% flex must be unstable");
        assert!(rows[5].p99_des_ms > cfg.slo_ms);
        // Once unstable, it stays unstable at deeper flex.
        let first_bad = rows.iter().position(|r| !r.steady_ok).unwrap();
        assert!(rows[first_bad..].iter().all(|r| !r.steady_ok));
    }

    #[test]
    fn short_events_tolerate_deeper_flex_than_steady_state() {
        // Insight 8: the event-window bound is at least as permissive as
        // the steady-state bound.
        let (w, gpu, cfg) = setup();
        let rows = grid_flex_analysis(&w, &gpu, &cfg);
        let steady_depth = rows.iter().filter(|r| r.steady_ok).count();
        let event_depth = rows.iter().filter(|r| r.event_ok).count();
        assert!(event_depth >= steady_depth,
                "event {event_depth} vs steady {steady_depth}");
    }

    #[test]
    fn des_and_analytic_agree_when_stable() {
        let (w, gpu, cfg) = setup();
        let rows = grid_flex_analysis(&w, &gpu, &cfg);
        for r in rows.iter().filter(|r| r.steady_ok) {
            assert!(r.p99_des_ms <= cfg.slo_ms,
                    "flex {}: DES {} violates SLO despite stable analytics",
                    r.flex, r.p99_des_ms);
        }
    }

    #[test]
    fn recalibration_speeds_up_iterations() {
        // t_iter(6) << t_iter(128): the recalibrated service model must
        // reflect that (paper §4.8 "recalibrated at each batch cap").
        let (w, gpu, _) = setup();
        let (p99_cap13, rho13) = analytic_p99_at_cap(&w, &gpu, 40, 8192.0, 13);
        let (p99_full, rho_full) =
            analytic_p99_at_cap(&w, &gpu, 40, 8192.0, 128);
        // Both stable; the recalibrated model keeps TTFT in the same
        // regime because the equilibrium batch sits below both caps
        // (Table 9's constant analytic column).
        assert!(rho13 < RHO_MAX && rho_full < RHO_MAX, "{rho13} {rho_full}");
        assert!(p99_cap13.is_finite() && p99_full.is_finite());
        assert!((p99_cap13 / p99_full - 1.0).abs() < 0.5,
                "cap13 {p99_cap13} vs full {p99_full}");
    }
}
