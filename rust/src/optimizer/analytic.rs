//! Phase-1 analytical sweep evaluation (paper §3.1, steps 1-4).
//!
//! Two interchangeable evaluators implement [`SweepEval`]:
//!
//! * [`NativeSweep`] — pure rust, built on [`crate::queueing::mgc`]; used
//!   for small sweeps and as the cross-validation oracle;
//! * [`crate::runtime::sweep::AotSweep`] — the JAX/Pallas computation
//!   AOT-compiled to `artifacts/sweep.hlo.txt`, executed via PJRT; the
//!   batched hot path for large candidate grids.
//!
//! `rust/tests/runtime_parity.rs` asserts the two agree.

use crate::optimizer::candidates::{Candidate, CandidateResult};
use crate::queueing::mgc::{analyze_pool, RHO_MAX, WorkloadHist};
use crate::workload::spec::WorkloadSpec;

/// A batched Phase-1 evaluator.
pub trait SweepEval {
    /// Evaluate all candidates against the workload. `slo_ms` feeds the
    /// feasibility column.
    fn eval(
        &self,
        workload: &WorkloadSpec,
        candidates: &[Candidate],
        slo_ms: f64,
    ) -> anyhow::Result<Vec<CandidateResult>>;

    /// Human-readable backend name for reports.
    fn backend(&self) -> &'static str;
}

/// Pure-rust evaluator.
#[derive(Debug, Default, Clone)]
pub struct NativeSweep;

/// Prefix-sum cache over the workload histogram for one prefill chunk
/// size: turns every candidate's slice integration (alpha, E[I], E[I²],
/// conditional P99) from an O(K) scan into O(log K) lookups. Built once
/// per distinct chunk in the sweep (perf pass iteration 1 — see
/// EXPERIMENTS.md §Perf).
struct SliceCache {
    /// cum_p[i] = sum of probs[..i]; len K+1.
    cum_p: Vec<f64>,
    cum_pi: Vec<f64>,
    cum_pi2: Vec<f64>,
}

impl SliceCache {
    fn build(hist: &WorkloadHist, chunk: f64) -> Self {
        let k = hist.probs.len();
        let mut cum_p = Vec::with_capacity(k + 1);
        let mut cum_pi = Vec::with_capacity(k + 1);
        let mut cum_pi2 = Vec::with_capacity(k + 1);
        let (mut a, mut b, mut c) = (0.0, 0.0, 0.0);
        cum_p.push(0.0);
        cum_pi.push(0.0);
        cum_pi2.push(0.0);
        for (p, &l) in hist.probs.iter().zip(&hist.lens) {
            let l_in = (l * hist.input_frac).ceil();
            let l_out = (l - l_in).max(1.0);
            let it = (l_in / chunk).ceil() + l_out.max(1.0);
            a += p;
            b += p * it;
            c += p * it * it;
            cum_p.push(a);
            cum_pi.push(b);
            cum_pi2.push(c);
        }
        SliceCache { cum_p, cum_pi, cum_pi2 }
    }

    /// (alpha, E[I], E[I²], p99_len) over the (lo, hi] slice.
    fn slice(&self, lens: &[f64], lo: f64, hi: f64)
        -> (f64, f64, f64, f64)
    {
        let i0 = lens.partition_point(|&l| l <= lo);
        let i1 = lens.partition_point(|&l| l <= hi);
        let alpha = self.cum_p[i1] - self.cum_p[i0];
        if alpha <= 1e-12 {
            return (0.0, 0.0, 0.0, 0.0);
        }
        let e1 = (self.cum_pi[i1] - self.cum_pi[i0]) / alpha;
        let e2 = (self.cum_pi2[i1] - self.cum_pi2[i0]) / alpha;
        // Conditional P99: first bin in range whose cumulative reaches
        // 0.99 * alpha (same semantics as WorkloadHist::conditional_quantile).
        let target = self.cum_p[i0] + 0.99 * alpha;
        let idx = self.cum_p[i0 + 1..=i1]
            .partition_point(|&c| c < target - 1e-15);
        let p99 = lens[(i0 + idx).min(i1 - 1)];
        (alpha, e1, e2, p99)
    }
}

/// Pool evaluation from precomputed slice moments (the same math as
/// `analyze_pool`, factored so the cached path reuses it exactly).
#[allow(clippy::too_many_arguments)]
fn eval_pool_from_moments(
    gpu: &crate::gpu::profile::GpuProfile,
    ctx: f64,
    n_gpus: u32,
    lambda_pool_ms: f64,
    i1: f64,
    i2: f64,
    p99_len: f64,
    input_frac: f64,
) -> crate::queueing::mgc::PoolAnalysis {
    use crate::queueing::mgc::{equilibrium_batch, PoolAnalysis};
    let n = gpu.n_eff(ctx);
    let c = (n_gpus as usize).clamp(1, crate::queueing::erlang::C_MAX);
    let cs2 = (i2 / (i1 * i1) - 1.0).max(0.0);
    let a = lambda_pool_ms * i1 / c as f64;
    let n_bar = equilibrium_batch(gpu, n, a);
    let t_bar = gpu.t_iter(n_bar);
    let es = i1 * t_bar / n;
    let rho = lambda_pool_ms * es / c as f64;
    let w99 = crate::queueing::kimura::w99(rho, c, es, cs2);
    let l_in99 = (p99_len * input_frac).ceil();
    let prefill99 = (l_in99 / gpu.chunk).ceil() * t_bar;
    PoolAnalysis {
        alpha: 0.0, // filled by caller
        lambda_ms: lambda_pool_ms,
        es_ms: es,
        cs2,
        rho,
        w99_ms: w99,
        prefill99_ms: prefill99,
        ttft99_ms: w99 + prefill99 + t_bar,
        stable: rho < 1.0,
    }
}

impl NativeSweep {
    /// Evaluate a single candidate against a prebuilt histogram
    /// (reference path; the batched `eval` uses the prefix-sum cache).
    pub fn eval_one(
        hist: &WorkloadHist,
        max_len: f64,
        lambda_ms: f64,
        cand: &Candidate,
        slo_ms: f64,
    ) -> CandidateResult {
        let hi_short = cand.b_short.min(max_len * 2.0);
        let short = analyze_pool(hist, 0.0, hi_short, lambda_ms,
                                 &cand.short_spec());
        let long = if cand.is_homogeneous() {
            crate::queueing::mgc::PoolAnalysis::empty()
        } else {
            analyze_pool(hist, hi_short, max_len, lambda_ms, &cand.long_spec())
        };
        // A candidate that routes traffic long but has no long pool is
        // invalid (mirrors the L2 model's `dangling` check).
        let dangling =
            cand.is_homogeneous() && hist.mass(cand.b_short, max_len) > 1e-9;
        let feasible = short.meets_slo(slo_ms) && long.meets_slo(slo_ms)
            && !dangling;
        CandidateResult {
            rho_s: short.rho,
            rho_l: long.rho,
            ttft99_s: short.ttft99_ms,
            ttft99_l: long.ttft99_ms,
            w99_s: short.w99_ms,
            w99_l: long.w99_ms,
            cost_yr: cand.cost_per_year(),
            feasible,
        }
    }
}

impl SweepEval for NativeSweep {
    fn eval(
        &self,
        workload: &WorkloadSpec,
        candidates: &[Candidate],
        slo_ms: f64,
    ) -> anyhow::Result<Vec<CandidateResult>> {
        use crate::queueing::mgc::{PoolAnalysis, RHO_MAX};
        let hist =
            WorkloadHist::from_cdf(&workload.cdf, workload.input_fraction);
        let max_len = workload.cdf.max_len();
        let lam = workload.lambda_per_ms();

        // One prefix-sum cache per distinct chunk size in the grid.
        let mut caches: Vec<(u64, SliceCache)> = Vec::new();
        let mut cache_for = |chunk: f64, hist: &WorkloadHist| -> usize {
            let key = chunk.to_bits();
            if let Some(i) = caches.iter().position(|(k, _)| *k == key) {
                return i;
            }
            caches.push((key, SliceCache::build(hist, chunk)));
            caches.len() - 1
        };
        // Pre-populate (avoids borrow gymnastics in the loop below).
        let idxs: Vec<(usize, usize)> = candidates
            .iter()
            .map(|c| {
                (
                    cache_for(c.gpu_s.chunk, &hist),
                    cache_for(c.gpu_l.chunk, &hist),
                )
            })
            .collect();

        let meets = |a: &PoolAnalysis, alpha: f64| {
            alpha <= 1e-12
                || (a.stable && a.rho <= RHO_MAX && a.ttft99_ms <= slo_ms)
        };

        Ok(candidates
            .iter()
            .zip(idxs)
            .map(|(cand, (ci_s, ci_l))| {
                let hi_short = cand.b_short.min(max_len * 2.0);
                let (alpha_s, i1s, i2s, p99s) =
                    caches[ci_s].1.slice(&hist.lens, 0.0, hi_short);
                let short = if alpha_s <= 1e-12 {
                    PoolAnalysis::empty()
                } else {
                    eval_pool_from_moments(
                        &cand.gpu_s, cand.ctx_s, cand.n_s, lam * alpha_s,
                        i1s, i2s, p99s, hist.input_frac,
                    )
                };
                let (alpha_l, long) = if cand.is_homogeneous() {
                    (0.0, PoolAnalysis::empty())
                } else {
                    let (alpha_l, i1l, i2l, p99l) =
                        caches[ci_l].1.slice(&hist.lens, hi_short, max_len);
                    let a = if alpha_l <= 1e-12 {
                        PoolAnalysis::empty()
                    } else {
                        eval_pool_from_moments(
                            &cand.gpu_l, cand.ctx_l, cand.n_l, lam * alpha_l,
                            i1l, i2l, p99l, hist.input_frac,
                        )
                    };
                    (alpha_l, a)
                };
                let dangling = cand.is_homogeneous()
                    && caches[ci_s]
                        .1
                        .slice(&hist.lens, cand.b_short, max_len)
                        .0
                        > 1e-9;
                let alpha_l_eff =
                    if cand.is_homogeneous() { 0.0 } else { alpha_l };
                let feasible = meets(&short, alpha_s)
                    && meets(&long, alpha_l_eff)
                    && !dangling;
                CandidateResult {
                    rho_s: short.rho,
                    rho_l: long.rho,
                    ttft99_s: short.ttft99_ms,
                    ttft99_l: long.ttft99_ms,
                    w99_s: short.w99_ms,
                    w99_l: long.w99_ms,
                    cost_yr: cand.cost_per_year(),
                    feasible,
                }
            })
            .collect())
    }

    fn backend(&self) -> &'static str {
        "native"
    }
}

/// Rank feasible results by cost (then fewer GPUs, then lower worst TTFT).
/// Returns indices into the candidate slice, cheapest first.
pub fn rank_feasible(
    candidates: &[Candidate],
    results: &[CandidateResult],
) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..results.len())
        .filter(|&i| results[i].feasible)
        .collect();
    idx.sort_by(|&a, &b| {
        results[a]
            .cost_yr
            .partial_cmp(&results[b].cost_yr)
            .unwrap()
            .then(candidates[a].total_gpus().cmp(&candidates[b].total_gpus()))
            .then(
                results[a]
                    .worst_ttft()
                    .partial_cmp(&results[b].worst_ttft())
                    .unwrap(),
            )
    });
    idx
}

/// Sanity guard used by feasibility checks: rho cap (paper §3.1 step 3).
pub fn within_rho_cap(r: &CandidateResult) -> bool {
    r.rho_s <= RHO_MAX && r.rho_l <= RHO_MAX
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::catalog::GpuCatalog;
    use crate::optimizer::candidates::{generate, GenOptions};
    use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

    fn lmsys100() -> WorkloadSpec {
        WorkloadSpec::builtin(BuiltinTrace::Lmsys, 100.0)
    }

    #[test]
    fn sweep_finds_feasible_candidates() {
        let w = lmsys100();
        let cands =
            generate(&w, &GpuCatalog::standard(), &GenOptions::default());
        let res = NativeSweep.eval(&w, &cands, 500.0).unwrap();
        assert_eq!(res.len(), cands.len());
        let ranked = rank_feasible(&cands, &res);
        assert!(!ranked.is_empty(), "no feasible candidate found");
        // Ranking is by cost ascending.
        for w in ranked.windows(2) {
            assert!(res[w[0]].cost_yr <= res[w[1]].cost_yr);
        }
    }

    #[test]
    fn split_beats_homogeneous_on_lmsys() {
        // The paper's headline Table-1 effect: a well-placed split is much
        // cheaper than the homogeneous A100 fleet.
        let w = lmsys100();
        let mut opts = GenOptions::default();
        opts.headroom = 6;
        let cands = generate(&w, &GpuCatalog::standard(), &opts);
        let res = NativeSweep.eval(&w, &cands, 500.0).unwrap();
        let best_split = (0..cands.len())
            .filter(|&i| {
                !cands[i].is_homogeneous()
                    && cands[i].gpu_s.name == "A100"
                    && res[i].feasible
            })
            .map(|i| res[i].cost_yr)
            .fold(f64::INFINITY, f64::min);
        let best_homo = (0..cands.len())
            .filter(|&i| {
                cands[i].is_homogeneous()
                    && cands[i].gpu_s.name == "A100"
                    && within_rho_cap(&res[i])
                    && res[i].rho_s > 0.0
            })
            .map(|i| res[i].cost_yr)
            .fold(f64::INFINITY, f64::min);
        // Our linear-roofline physics yields a smaller saving than the
        // paper's -43% (see EXPERIMENTS.md T1 notes), but the split must
        // be strictly cheaper.
        assert!(
            best_split < best_homo * 0.95,
            "split {best_split} vs homo {best_homo}"
        );
    }

    #[test]
    fn feasibility_requires_slo() {
        let w = lmsys100();
        let cands =
            generate(&w, &GpuCatalog::standard(), &GenOptions::default());
        let relaxed = NativeSweep.eval(&w, &cands, 10_000.0).unwrap();
        let strict = NativeSweep.eval(&w, &cands, 1.0).unwrap();
        let n_relaxed = relaxed.iter().filter(|r| r.feasible).count();
        let n_strict = strict.iter().filter(|r| r.feasible).count();
        assert!(n_relaxed > n_strict);
        assert_eq!(n_strict, 0, "1 ms SLO cannot be met (prefill alone)");
    }

    #[test]
    fn cached_batch_path_matches_reference_eval_one() {
        // The prefix-sum fast path (perf pass) must agree with the direct
        // per-candidate integration bit-for-bit-ish on every candidate.
        for (trace, lam) in [(BuiltinTrace::Lmsys, 100.0),
                             (BuiltinTrace::Azure, 150.0),
                             (BuiltinTrace::Agent, 20.0)] {
            let w = WorkloadSpec::builtin(trace, lam);
            let mut opts = GenOptions::default();
            opts.allow_mixed = true;
            let cands = generate(&w, &GpuCatalog::standard(), &opts);
            let fast = NativeSweep.eval(&w, &cands, 500.0).unwrap();
            let hist = crate::queueing::mgc::WorkloadHist::from_cdf(
                &w.cdf, w.input_fraction);
            let max_len = w.cdf.max_len();
            for (i, c) in cands.iter().enumerate() {
                let slow = NativeSweep::eval_one(
                    &hist, max_len, w.lambda_per_ms(), c, 500.0);
                assert_eq!(fast[i].feasible, slow.feasible, "cand {i}");
                for (a, b, what) in [
                    (fast[i].rho_s, slow.rho_s, "rho_s"),
                    (fast[i].rho_l, slow.rho_l, "rho_l"),
                    (fast[i].ttft99_s, slow.ttft99_s, "ttft_s"),
                    (fast[i].ttft99_l, slow.ttft99_l, "ttft_l"),
                ] {
                    if a.is_finite() || b.is_finite() {
                        assert!((a - b).abs() <= 1e-9 + 1e-9 * b.abs(),
                                "cand {i} {what}: {a} vs {b}");
                    }
                }
            }
        }
    }

    #[test]
    fn results_match_direct_pool_analysis() {
        use crate::queueing::mgc::{analyze_two_pool, WorkloadHist};
        let w = lmsys100();
        let cat = GpuCatalog::standard();
        let cand = Candidate {
            b_short: 4096.0,
            n_s: 3,
            n_l: 5,
            gpu_s: cat.get("A100").unwrap().clone(),
            gpu_l: cat.get("A100").unwrap().clone(),
            ctx_s: 4096.0,
            ctx_l: 65536.0,
        };
        let res = NativeSweep.eval(&w, std::slice::from_ref(&cand), 500.0)
            .unwrap()[0];
        let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
        let (s, l) = analyze_two_pool(
            &hist, 4096.0, 65536.0, w.lambda_per_ms(),
            &cand.short_spec(), &cand.long_spec(),
        );
        assert!((res.rho_s - s.rho).abs() < 1e-12);
        assert!((res.ttft99_l - l.ttft99_ms).abs() < 1e-12);
    }
}
