//! Disaggregated prefill/decode fleet planning (paper §4.7, Table 8).
//!
//! DistServe/Splitwise-style serving splits the two phases onto separate
//! pools. The model:
//!
//! * **Prefill pool** — compute-bound workers processing one prompt at a
//!   time (batch 1): service = ceil(L_in/chunk) * t_iter(1). M/G/c over
//!   the GPU count.
//! * **KV transfer** — multiplies raw prefill time by `BETA_TTFT` = 1.80
//!   on the TTFT path (paper Table 8 caption:
//!   fleet_sim/optimizer/disagg.py).
//! * **Decode pool** — memory-bound continuous batching at
//!   `n_D = min(n_max(ctx), max_num_seqs)`; TPOT = t_iter(n_D); service =
//!   L_out * t_iter(n_D) / n_D per request (Eq. 4 with no prefill term).
//!
//! Feasibility: P99 TTFT <= TTFT SLO, TPOT <= TPOT SLO, rho <= 0.85 in
//! both pools. The DisaggFleetOptimizer sizes each (prefill GPU, decode
//! GPU) pairing minimally and ranks by cost; a dedicated two-stage DES
//! verifies the winner.

use crate::des::event::{EventKind, EventQueue};
use crate::gpu::catalog::GpuCatalog;
use crate::gpu::profile::GpuProfile;
use crate::queueing::kimura;
use crate::queueing::mgc::{analyze_pool, PoolSpec, RHO_MAX, WorkloadHist};
use crate::util::stats::Samples;
use crate::workload::rng::Pcg64;
use crate::workload::spec::WorkloadSpec;
use crate::workload::streams;

/// KV-transfer TTFT multiplier (paper Table 8: BETA_TTFT = 1.80).
pub const BETA_TTFT: f64 = 1.80;

/// vLLM default max_num_seqs — caps the decode batch (paper §4.8 Table 9
/// baseline and the Table 8 TPOT figures are consistent with 128).
pub const MAX_NUM_SEQS: f64 = 128.0;

/// One disaggregated configuration.
#[derive(Debug, Clone)]
pub struct DisaggConfig {
    pub gpu_prefill: GpuProfile,
    pub gpu_decode: GpuProfile,
    pub n_prefill: u32,
    pub n_decode: u32,
}

impl DisaggConfig {
    pub fn cost_per_year(&self) -> f64 {
        self.n_prefill as f64 * self.gpu_prefill.cost_per_year()
            + self.n_decode as f64 * self.gpu_decode.cost_per_year()
    }

    pub fn label(&self) -> String {
        format!(
            "{}P + {}D  {}({}P+{}D)",
            self.gpu_prefill.name,
            self.gpu_decode.name,
            self.n_prefill + self.n_decode,
            self.n_prefill,
            self.n_decode
        )
    }
}

/// Analytical evaluation of a disaggregated configuration.
#[derive(Debug, Clone, Copy)]
pub struct DisaggAnalysis {
    pub rho_prefill: f64,
    pub rho_decode: f64,
    /// P99 TTFT including queue wait and the BETA_TTFT transfer penalty.
    pub ttft99_ms: f64,
    /// Time per output token at the decode batch level.
    pub tpot_ms: f64,
    pub cost_yr: f64,
    pub feasible: bool,
}

/// Service moments of the prefill phase over the workload.
fn prefill_moments(hist: &WorkloadHist, gpu: &GpuProfile) -> (f64, f64, f64) {
    let t1 = gpu.t_iter(1.0);
    let mut m1 = 0.0;
    let mut m2 = 0.0;
    for (p, &l) in hist.probs.iter().zip(&hist.lens) {
        let l_in = (l * hist.input_frac).ceil();
        let s = (l_in / gpu.chunk).ceil() * t1;
        m1 += p * s;
        m2 += p * s * s;
    }
    let cs2 = (m2 / (m1 * m1) - 1.0).max(0.0);
    (m1, m2, cs2)
}

/// Decode-phase moments at batch level n_d.
fn decode_moments(hist: &WorkloadHist, gpu: &GpuProfile, n_d: f64)
    -> (f64, f64, f64)
{
    let t = gpu.t_iter(n_d);
    let mut m1 = 0.0;
    let mut m2 = 0.0;
    for (p, &l) in hist.probs.iter().zip(&hist.lens) {
        let l_in = (l * hist.input_frac).ceil();
        let l_out = (l - l_in).max(1.0);
        let s = l_out * t / n_d;
        m1 += p * s;
        m2 += p * s * s;
    }
    let cs2 = (m2 / (m1 * m1) - 1.0).max(0.0);
    (m1, m2, cs2)
}

/// Decode batch level for a GPU at the workload's max context.
pub fn decode_batch(gpu: &GpuProfile, ctx: f64) -> f64 {
    gpu.n_eff(ctx).min(MAX_NUM_SEQS)
}

/// Evaluate one configuration analytically.
pub fn analyze(
    workload: &WorkloadSpec,
    cfg: &DisaggConfig,
    ttft_slo_ms: f64,
    tpot_slo_ms: f64,
) -> DisaggAnalysis {
    let hist = WorkloadHist::from_cdf(&workload.cdf, workload.input_fraction);
    let lam = workload.lambda_per_ms();
    let ctx = workload.cdf.max_len();

    // Prefill pool: M/G/c over batch-1 workers.
    let (es_p, _m2p, cs2_p) = prefill_moments(&hist, &cfg.gpu_prefill);
    let rho_p = lam * es_p / cfg.n_prefill as f64;
    let w99_p = kimura::w99(rho_p, cfg.n_prefill as usize, es_p, cs2_p);

    // P99 raw prefill from the P99 prompt.
    let p99_len = hist.conditional_quantile(0.0, ctx, 0.99);
    let l_in99 = (p99_len * hist.input_frac).ceil();
    let raw_prefill99 = (l_in99 / cfg.gpu_prefill.chunk).ceil()
        * cfg.gpu_prefill.t_iter(1.0);

    // Decode pool.
    let n_d = decode_batch(&cfg.gpu_decode, ctx);
    let (es_d, _m2d, _cs2_d) = decode_moments(&hist, &cfg.gpu_decode, n_d);
    let rho_d = lam * es_d / cfg.n_decode as f64;
    let tpot = cfg.gpu_decode.t_iter(n_d);

    let ttft99 = w99_p + BETA_TTFT * raw_prefill99 + tpot;
    let feasible = rho_p <= RHO_MAX
        && rho_d <= RHO_MAX
        && ttft99 <= ttft_slo_ms
        && tpot <= tpot_slo_ms;

    DisaggAnalysis {
        rho_prefill: rho_p,
        rho_decode: rho_d,
        ttft99_ms: ttft99,
        tpot_ms: tpot,
        cost_yr: cfg.cost_per_year(),
        feasible,
    }
}

/// The DisaggFleetOptimizer: minimally size every (prefill, decode) GPU
/// pairing and rank feasible configurations by cost.
pub struct DisaggFleetOptimizer {
    pub catalog: GpuCatalog,
    pub ttft_slo_ms: f64,
    pub tpot_slo_ms: f64,
    pub max_gpus_per_pool: u32,
}

impl DisaggFleetOptimizer {
    pub fn new(
        catalog: GpuCatalog,
        ttft_slo_ms: f64,
        tpot_slo_ms: f64,
    ) -> Self {
        DisaggFleetOptimizer { catalog, ttft_slo_ms, tpot_slo_ms,
                               max_gpus_per_pool: 256 }
    }

    /// All pairings, minimally sized; feasible ones first, by cost.
    pub fn sweep(&self, workload: &WorkloadSpec)
        -> Vec<(DisaggConfig, DisaggAnalysis)>
    {
        let mut out = Vec::new();
        let ctx = workload.cdf.max_len();
        // Disaggregated workers hold a full model shard each; small-VRAM
        // cards (A10G) are out of scope, matching the paper's Table 8
        // which evaluates A100/H100 only.
        let eligible: Vec<_> = self
            .catalog
            .profiles()
            .iter()
            .filter(|g| g.vram_gb >= 40.0 && g.supports_context(ctx))
            .collect();
        for gp in &eligible {
            for gd in &eligible {
                if let Some(cfg) = self.size_pair(workload, gp, gd) {
                    let a = analyze(workload, &cfg, self.ttft_slo_ms,
                                    self.tpot_slo_ms);
                    out.push((cfg, a));
                }
            }
        }
        out.sort_by(|a, b| {
            b.1.feasible
                .cmp(&a.1.feasible)
                .then(a.1.cost_yr.partial_cmp(&b.1.cost_yr).unwrap())
        });
        out
    }

    /// Minimal (n_prefill, n_decode) for a pairing, or None if infeasible
    /// within the pool cap.
    fn size_pair(
        &self,
        workload: &WorkloadSpec,
        gp: &GpuProfile,
        gd: &GpuProfile,
    ) -> Option<DisaggConfig> {
        let mut cfg = DisaggConfig {
            gpu_prefill: gp.clone(),
            gpu_decode: gd.clone(),
            n_prefill: 1,
            n_decode: 1,
        };
        // Grow prefill until rho cap + TTFT hold (TTFT depends on wait).
        while cfg.n_prefill <= self.max_gpus_per_pool {
            let a = analyze(workload, &cfg, self.ttft_slo_ms, self.tpot_slo_ms);
            if a.rho_prefill <= RHO_MAX && a.ttft99_ms <= self.ttft_slo_ms {
                break;
            }
            // TPOT is count-independent; bail early if it can never pass.
            if a.tpot_ms > self.tpot_slo_ms {
                return None;
            }
            cfg.n_prefill += 1;
        }
        // Grow decode until its rho cap holds.
        while cfg.n_decode <= self.max_gpus_per_pool {
            let a = analyze(workload, &cfg, self.ttft_slo_ms, self.tpot_slo_ms);
            if a.rho_decode <= RHO_MAX {
                return if a.feasible { Some(cfg) } else { None };
            }
            cfg.n_decode += 1;
        }
        None
    }

    /// Aggregated baseline for comparison rows (Table 8 top rows): a
    /// homogeneous fleet sized by the standard pool model.
    pub fn aggregated_baseline(
        &self,
        workload: &WorkloadSpec,
        gpu: &GpuProfile,
    ) -> Option<(u32, f64, f64)> {
        let hist =
            WorkloadHist::from_cdf(&workload.cdf, workload.input_fraction);
        let ctx = workload.cdf.max_len();
        let lam = workload.lambda_per_ms();
        for n in 1..=self.max_gpus_per_pool {
            let spec = PoolSpec { gpu: gpu.clone(), n_gpus: n as usize,
                                  ctx_budget: ctx };
            let a = analyze_pool(&hist, 0.0, ctx, lam, &spec);
            if a.rho <= RHO_MAX && a.ttft99_ms <= self.ttft_slo_ms {
                return Some((n, gpu.cost_per_year() * n as f64, a.ttft99_ms));
            }
        }
        None
    }
}

/// Two-stage DES for disaggregated serving: requests pass the prefill pool
/// (batch-1 workers, service scaled by BETA_TTFT for KV transfer), then
/// the decode pool (slot model). Returns (P99 TTFT, P99 E2E, mean decode
/// occupancy).
pub fn simulate_disagg(
    workload: &WorkloadSpec,
    cfg: &DisaggConfig,
    n_requests: usize,
    seed: u64,
) -> (f64, f64, f64) {
    let ctx = workload.cdf.max_len();
    let reqs = workload.sample_requests(n_requests, seed);
    let n_d = decode_batch(&cfg.gpu_decode, ctx) as u32;
    let t_decode = cfg.gpu_decode.t_iter(n_d as f64);

    let mut events = EventQueue::with_capacity(n_requests * 2);
    for (i, r) in reqs.iter().enumerate() {
        events.push(r.arrival_ms, EventKind::Arrival { req: i as u32 });
    }

    // Prefill: c workers, one request each. Decode: n_decode * n_d slots.
    let mut prefill_busy: u32 = 0;
    let mut prefill_q: std::collections::VecDeque<u32> = Default::default();
    let mut decode_busy: u32 = 0;
    let decode_cap = cfg.n_decode * n_d;
    let mut decode_q: std::collections::VecDeque<u32> = Default::default();

    let mut ttft = Samples::with_capacity(n_requests);
    let mut e2e = Samples::with_capacity(n_requests);
    let mut occ_accum = 0.0;
    let mut occ_last = 0.0;
    let mut _rng = Pcg64::new(seed, streams::DISAGG_SIM);

    // Event encoding: pool 0 = prefill worker done (server freed), pool 2
    // = KV transfer landed (decode admission), pool 1 = decode done. The
    // worker is busy only for the raw prefill; the BETA_TTFT - 1 transfer
    // tail overlaps with the worker's next prompt (latency-only cost,
    // matching the analytical model).
    while let Some(ev) = events.pop() {
        let now = ev.time_ms;
        match ev.kind {
            EventKind::Arrival { req } => {
                if prefill_busy < cfg.n_prefill {
                    prefill_busy += 1;
                    let r = &reqs[req as usize];
                    let raw = (r.l_in / cfg.gpu_prefill.chunk).ceil()
                        * cfg.gpu_prefill.t_iter(1.0);
                    events.push(
                        now + raw,
                        EventKind::Completion { req, pool: 0, instance: 0 },
                    );
                } else {
                    prefill_q.push_back(req);
                }
            }
            EventKind::Completion { req, pool: 0, .. } => {
                // Prefill compute done: free the worker, schedule the KV
                // transfer tail.
                let r = &reqs[req as usize];
                let raw = (r.l_in / cfg.gpu_prefill.chunk).ceil()
                    * cfg.gpu_prefill.t_iter(1.0);
                events.push(
                    now + raw * (BETA_TTFT - 1.0),
                    EventKind::Completion { req, pool: 2, instance: 0 },
                );
                // Start next queued prefill.
                if let Some(next) = prefill_q.pop_front() {
                    let nr = &reqs[next as usize];
                    let nraw = (nr.l_in / cfg.gpu_prefill.chunk).ceil()
                        * cfg.gpu_prefill.t_iter(1.0);
                    let kind =
                        EventKind::Completion { req: next, pool: 0,
                                                instance: 0 };
                    events.push(now + nraw, kind);
                } else {
                    prefill_busy -= 1;
                }
            }
            EventKind::Completion { req, pool: 2, .. } => {
                // KV transfer landed: admit to decode if a slot is free
                // (TTFT = first decode iteration after admission).
                let r = &reqs[req as usize];
                if decode_busy < decode_cap {
                    occ_accum += decode_busy as f64 * (now - occ_last);
                    occ_last = now;
                    decode_busy += 1;
                    ttft.push(now - r.arrival_ms + t_decode);
                    events.push(
                        now + r.l_out * t_decode,
                        EventKind::Completion { req, pool: 1, instance: 0 },
                    );
                } else {
                    decode_q.push_back(req);
                }
            }
            EventKind::Completion { req, pool: 1, .. } => {
                let r = &reqs[req as usize];
                e2e.push(now - r.arrival_ms);
                occ_accum += decode_busy as f64 * (now - occ_last);
                occ_last = now;
                decode_busy -= 1;
                if let Some(next) = decode_q.pop_front() {
                    decode_busy += 1;
                    let nr = &reqs[next as usize];
                    ttft.push(now - nr.arrival_ms + t_decode);
                    let kind =
                        EventKind::Completion { req: next, pool: 1,
                                                instance: 0 };
                    events.push(now + nr.l_out * t_decode, kind);
                }
            }
            _ => {}
        }
    }
    let horizon = occ_last.max(1.0);
    let mean_occ = occ_accum / horizon / decode_cap.max(1) as f64;
    (ttft.p99(), e2e.p99(), mean_occ)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec::BuiltinTrace;

    fn azure100() -> WorkloadSpec {
        WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0)
    }

    fn optimizer() -> DisaggFleetOptimizer {
        DisaggFleetOptimizer::new(GpuCatalog::standard(), 500.0, 100.0)
    }

    #[test]
    fn prefill_pool_is_tiny_at_lambda_100() {
        // §4.7: prefill is the cheap phase — a handful of workers carries
        // all of lambda = 100 req/s (the paper's "one A100"; our Azure
        // calibration needs <= 3 A100s / 1 H100).
        let o = optimizer();
        let sweep = o.sweep(&azure100());
        let a100p = sweep
            .iter()
            .find(|(c, _)| c.gpu_prefill.name == "A100"
                  && c.gpu_decode.name == "H100")
            .expect("A100P+H100D sized");
        assert!(a100p.0.n_prefill <= 3, "{:?}", a100p.0);
        assert!(a100p.0.n_prefill < a100p.0.n_decode);
        assert!(a100p.1.feasible);
        let h100p = sweep
            .iter()
            .find(|(c, _)| c.gpu_prefill.name == "H100"
                  && c.gpu_decode.name == "H100")
            .expect("H100P+H100D sized");
        assert_eq!(h100p.0.n_prefill, 1, "{:?}", h100p.0);
    }

    #[test]
    fn h100_decode_needs_half_the_gpus_of_a100() {
        // §4.7: H100 decode ~2.5x A100 throughput -> 3 vs 6 workers.
        let o = optimizer();
        let sweep = o.sweep(&azure100());
        let h100d = sweep.iter()
            .find(|(c, _)| c.gpu_decode.name == "H100"
                  && c.gpu_prefill.name == "A100").unwrap().0.n_decode;
        let a100d = sweep.iter()
            .find(|(c, _)| c.gpu_decode.name == "A100"
                  && c.gpu_prefill.name == "A100").map(|(c, _)| c.n_decode);
        if let Some(a100d) = a100d {
            assert!(a100d as f64 / h100d as f64 >= 1.5,
                    "A100D {a100d} vs H100D {h100d}");
        }
    }

    #[test]
    fn tpot_matches_table8_batch_model() {
        // Table 8: TPOT 45 ms (H100 decode at batch 128) / 91 ms (A100).
        let cat = GpuCatalog::standard();
        let h100 = cat.get("H100").unwrap();
        let a100 = cat.get("A100").unwrap();
        let ctx = 8192.0;
        assert!((h100.t_iter(decode_batch(h100, ctx)) - 44.96).abs() < 0.1);
        assert!((a100.t_iter(decode_batch(a100, ctx)) - 91.2).abs() < 0.1);
    }

    #[test]
    fn premium_gpu_pays_off_in_decode_not_prefill() {
        // Insight 7: the cheapest feasible config should use the cheaper
        // GPU (A100) for prefill and H100 for decode — not the reverse.
        let o = optimizer();
        let sweep = o.sweep(&azure100());
        let feasible: Vec<_> =
            sweep.iter().filter(|(_, a)| a.feasible).collect();
        assert!(!feasible.is_empty());
        let best = &feasible[0];
        let reverse = sweep.iter().find(|(c, _)| {
            c.gpu_prefill.name == "H100" && c.gpu_decode.name == "A100"
        });
        if let Some((_, rev)) = reverse {
            assert!(best.1.cost_yr <= rev.cost_yr,
                    "best {} vs H100P+A100D {}", best.1.cost_yr, rev.cost_yr);
        }
        assert_eq!(best.0.gpu_decode.name, "H100",
                   "premium GPU should sit in decode: {}", best.0.label());
    }

    #[test]
    fn disagg_vs_aggregated_tradeoff() {
        // Table 8 shape: disaggregation trades TTFT for decode-pool
        // efficiency. Under our Eq.-4-faithful physics the cost saving is
        // smaller than the paper's 35-46% (chunked prefill is cheap in
        // aggregate throughput — see EXPERIMENTS.md T8 notes); we assert
        // the structural claims: the prefill pool is a small add-on, the
        // best config stays within ~1.6x of the aggregated baseline, and
        // it delivers a strictly better TPOT guarantee than aggregated
        // A100 serving.
        let o = optimizer();
        let sweep = o.sweep(&azure100());
        let best = sweep.iter().find(|(_, a)| a.feasible).unwrap();
        let cat = GpuCatalog::standard();
        let agg = o
            .aggregated_baseline(&azure100(), cat.get("H100").unwrap())
            .expect("aggregated H100 baseline");
        assert!(best.1.cost_yr < agg.1 * 1.6,
                "disagg {} vs aggregated {}", best.1.cost_yr, agg.1);
        assert!(best.0.n_prefill as f64 <= 0.35 * best.0.n_decode as f64 + 1.0);
        assert!(best.1.tpot_ms <= 100.0);
    }

    #[test]
    fn tight_ttft_slo_excludes_disagg() {
        // §4.7: "for TTFT SLO <= 100 ms, disaggregated serving is not
        // viable" — the BETA_TTFT transfer penalty dominates.
        let o = DisaggFleetOptimizer::new(GpuCatalog::standard(), 60.0, 100.0);
        let sweep = o.sweep(&azure100());
        assert!(sweep.iter().all(|(_, a)| !a.feasible),
                "no disagg config should meet a 60 ms TTFT SLO");
    }

    #[test]
    fn des_verifies_analytical_ttft() {
        let o = optimizer();
        let sweep = o.sweep(&azure100());
        let (cfg, a) = sweep.iter().find(|(_, a)| a.feasible).unwrap();
        let (p99_ttft, p99_e2e, occ) = simulate_disagg(&azure100(), cfg,
                                                       10_000, 11);
        assert!(p99_e2e > p99_ttft);
        assert!((0.0..=1.0).contains(&occ));
        // DES and analytical TTFT within 2.5x of each other (both include
        // the 1.8x transfer penalty; queueing assumptions differ).
        let ratio = p99_ttft / a.ttft99_ms;
        assert!((0.4..2.5).contains(&ratio),
                "DES {p99_ttft} vs analytic {} (ratio {ratio})", a.ttft99_ms);
    }

    #[test]
    fn requests_conserved_in_disagg_des() {
        let cat = GpuCatalog::standard();
        let cfg = DisaggConfig {
            gpu_prefill: cat.get("A100").unwrap().clone(),
            gpu_decode: cat.get("H100").unwrap().clone(),
            n_prefill: 1,
            n_decode: 3,
        };
        let (ttft, e2e, _) = simulate_disagg(&azure100(), &cfg, 4_000, 5);
        assert!(ttft > 0.0 && e2e > 0.0);
    }
}
