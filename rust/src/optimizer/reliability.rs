//! Reliability-aware sizing (paper §3.5, Eq. 6).
//!
//! `node_avail` A = 1 / (1 + r_f * MTTR) is the steady-state fraction of
//! nodes in operation, with r_f in failures per node-day and MTTR in days.
//! A pool analytically sized to n GPUs is rounded up to ceil(n / A) in
//! production. The pre-computed constants come from published failure data
//! (Kokolis et al. 2024: 6.50 failures / 1000 node-days on RSC-1;
//! Cui et al. 2025: ~5% H100 overprovisioning recommendation).
//!
//! Eq. 6 restores *long-run average* capacity; it says nothing about
//! SLO attainment *during* an outage. The empirical counterpart is
//! [`crate::optimizer::engine::EvalEngine::size_for_failures`], which
//! sizes the fleet so every SLO window holds while k GPUs are down on a
//! deterministic fault script — the `n_plus_k` scenario contrasts the
//! two on the diurnal trace.

/// Node availability model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodeAvail {
    /// Steady-state availability in (0, 1].
    pub a: f64,
}

impl Default for NodeAvail {
    /// Default: perfect availability (sizing-only studies).
    fn default() -> Self {
        NodeAvail { a: 1.0 }
    }
}

impl NodeAvail {
    /// Eq. 6: A = 1 / (1 + r_f * MTTR).
    pub fn from_failure_model(
        failures_per_node_day: f64,
        mttr_days: f64,
    ) -> Self {
        assert!(failures_per_node_day >= 0.0 && mttr_days >= 0.0);
        NodeAvail { a: 1.0 / (1.0 + failures_per_node_day * mttr_days) }
    }

    /// Soft failures (driver reset, ~4 h MTTR) at the RSC-1 rate.
    pub fn soft_failure() -> Self {
        Self::from_failure_model(0.0065, 4.0 / 24.0)
    }

    /// Hard failures (GPU/NVLink swap, ~48 h MTTR) at the RSC-1 rate.
    pub fn hard_failure() -> Self {
        Self::from_failure_model(0.0065, 2.0)
    }

    /// The 5% overprovisioning rule (Cui et al. 2025).
    pub fn five_percent_rule() -> Self {
        NodeAvail { a: 0.95 }
    }

    /// Production GPU count: ceil(n / A) (paper §3.5).
    pub fn production_count(&self, n: u32) -> u32 {
        if n == 0 {
            return 0;
        }
        (n as f64 / self.a).ceil() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants_match_paper_table() {
        // §3.5: soft 0.9989, hard 0.9871, rule 0.95.
        assert!((NodeAvail::soft_failure().a - 0.9989).abs() < 1e-4);
        assert!((NodeAvail::hard_failure().a - 0.9871).abs() < 1e-4);
        assert_eq!(NodeAvail::five_percent_rule().a, 0.95);
    }

    #[test]
    fn production_rounding() {
        let hard = NodeAvail::hard_failure();
        // 24 / 0.9871 = 24.31 -> 25.
        assert_eq!(hard.production_count(24), 25);
        // Small pools round up too: 1 / 0.9871 -> 2? No: 1.013 -> 2 is
        // wrong; ceil(1.013) = 2. The paper's rule is a strict ceil.
        assert_eq!(hard.production_count(1), 2);
        assert_eq!(NodeAvail::default().production_count(7), 7);
        assert_eq!(hard.production_count(0), 0);
    }

    #[test]
    fn five_percent_rule_adds_one_in_twenty() {
        let r = NodeAvail::five_percent_rule();
        assert_eq!(r.production_count(20), 22); // 21.05 -> 22
        assert_eq!(r.production_count(19), 20);
    }

    #[test]
    fn perfect_repair_is_identity() {
        let a = NodeAvail::from_failure_model(0.5, 0.0);
        assert_eq!(a.a, 1.0);
        assert_eq!(a.production_count(13), 13);
    }
}
