//! What-if λ sweeps: GPU step thresholds (paper §4.4, Table 4).
//!
//! For a fixed GPU type and layout discipline, answer: how many GPUs does
//! each arrival rate need, and at what λ does a given fleet run out of
//! headroom ("provision more before λ = ...")?

use crate::gpu::catalog::GpuCatalog;
use crate::gpu::profile::GpuProfile;
use crate::optimizer::analytic::{rank_feasible, NativeSweep, SweepEval};
use crate::optimizer::candidates::{generate, Candidate, GenOptions};
use crate::util::parallel::{default_threads, par_map};
use crate::workload::spec::WorkloadSpec;

/// One row of the step-threshold table.
#[derive(Debug, Clone)]
pub struct StepRow {
    pub lambda_rps: f64,
    pub candidate: Candidate,
    pub cost_yr: f64,
    /// Largest λ (req/s) this fleet still serves within SLO; provision
    /// more before traffic reaches it. None for the last bracket.
    pub headroom_rps: Option<f64>,
}

/// Sweep arrival rates and find the minimal feasible fleet at each.
pub struct WhatIfSweep {
    pub catalog: GpuCatalog,
    pub slo_ms: f64,
    pub gen: GenOptions,
    /// Worker threads for the per-λ sweeps (each bracket is independent).
    pub threads: usize,
}

impl WhatIfSweep {
    pub fn new(catalog: GpuCatalog, slo_ms: f64) -> Self {
        WhatIfSweep {
            catalog,
            slo_ms,
            gen: GenOptions::default(),
            threads: default_threads(),
        }
    }

    /// Restrict the candidate space to one GPU type (Table 4 is H100-only).
    pub fn for_gpu(mut self, gpu: &GpuProfile) -> Self {
        self.catalog = GpuCatalog::from_profiles(vec![gpu.clone()]);
        self
    }

    /// Minimal feasible candidate at one λ.
    pub fn size_at(&self, workload: &WorkloadSpec, lambda_rps: f64)
        -> Option<(Candidate, f64)>
    {
        let w = workload.at_lambda(lambda_rps);
        let cands = generate(&w, &self.catalog, &self.gen);
        let res = NativeSweep.eval(&w, &cands, self.slo_ms).ok()?;
        let ranked = rank_feasible(&cands, &res);
        ranked.first().map(|&i| (cands[i].clone(), res[i].cost_yr))
    }

    /// Largest λ a fixed candidate still serves feasibly (binary search
    /// on the analytical model; 1 req/s resolution).
    pub fn headroom(&self, workload: &WorkloadSpec, cand: &Candidate,
                    lo_rps: f64, hi_rps: f64) -> f64 {
        let feasible_at = |rps: f64| {
            let w = workload.at_lambda(rps);
            NativeSweep
                .eval(&w, std::slice::from_ref(cand), self.slo_ms)
                .map(|r| r[0].feasible)
                .unwrap_or(false)
        };
        let (mut lo, mut hi) = (lo_rps, hi_rps);
        if !feasible_at(lo) {
            return lo;
        }
        while !feasible_at(hi) && hi - lo > 1.0 {
            let mid = 0.5 * (lo + hi);
            if feasible_at(mid) {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo.floor()
    }

    /// The full Table-4 style sweep. Each λ bracket (sizing + headroom
    /// bisection) is independent, so brackets fan out over worker threads
    /// while the output stays in input order.
    pub fn sweep(
        &self,
        workload: &WorkloadSpec,
        lambdas: &[f64],
    ) -> Vec<StepRow> {
        let hi = lambdas.last().copied().unwrap_or(0.0) * 2.0;
        let indexed: Vec<(usize, f64)> =
            lambdas.iter().copied().enumerate().collect();
        par_map(indexed, self.threads, |&(i, lam)| {
            let (cand, cost) = self.size_at(workload, lam)?;
            let headroom = if i + 1 < lambdas.len() {
                Some(self.headroom(workload, &cand, lam, hi))
            } else {
                None
            };
            Some(StepRow { lambda_rps: lam, candidate: cand, cost_yr: cost,
                           headroom_rps: headroom })
        })
        .into_iter()
        .flatten()
        .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::spec::BuiltinTrace;

    fn sweeper() -> WhatIfSweep {
        let cat = GpuCatalog::standard();
        let h100 = cat.get("H100").unwrap().clone();
        WhatIfSweep::new(cat, 500.0).for_gpu(&h100)
    }

    fn azure() -> WorkloadSpec {
        WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0)
    }

    #[test]
    fn gpu_count_grows_sublinearly() {
        // Insight 4: traffic x16 -> GPUs well under x16.
        let s = sweeper();
        let rows = s.sweep(&azure(), &[25.0, 100.0, 400.0]);
        assert_eq!(rows.len(), 3);
        let g0 = rows[0].candidate.total_gpus() as f64;
        let g2 = rows[2].candidate.total_gpus() as f64;
        let traffic_ratio = 400.0 / 25.0;
        let gpu_ratio = g2 / g0;
        // Sub-linear: GPUs-per-req/s falls as traffic grows. (The paper's
        // 16x-traffic -> 5.75x-GPUs is stronger because its small fleets
        // are wait-dominated; see EXPERIMENTS.md T4 notes.)
        assert!(gpu_ratio < traffic_ratio,
                "gpus {g0} -> {g2} (x{gpu_ratio}) vs traffic x{traffic_ratio}");
        assert!(g2 / 400.0 < g0 / 25.0, "marginal GPUs/rps must decline");
        // Costs are monotone in lambda.
        assert!(rows[0].cost_yr < rows[1].cost_yr);
        assert!(rows[1].cost_yr < rows[2].cost_yr);
    }

    #[test]
    fn headroom_exceeds_sizing_lambda() {
        let s = sweeper();
        let rows = s.sweep(&azure(), &[50.0, 100.0]);
        let r = &rows[0];
        let h = r.headroom_rps.unwrap();
        assert!(h >= 50.0, "headroom {h} below sizing point");
        // And the fleet really is infeasible just past the headroom.
        let w = azure().at_lambda(h + 25.0);
        let res = NativeSweep
            .eval(&w, std::slice::from_ref(&r.candidate), 500.0)
            .unwrap();
        assert!(!res[0].feasible);
    }

    #[test]
    fn last_bracket_has_no_headroom_entry() {
        let s = sweeper();
        let rows = s.sweep(&azure(), &[50.0, 150.0]);
        assert!(rows.last().unwrap().headroom_rps.is_none());
        assert!(rows.first().unwrap().headroom_rps.is_some());
    }

    #[test]
    fn headroom_of_infeasible_lambda_returns_lo() {
        let s = sweeper();
        let (cand, _) = s.size_at(&azure(), 25.0).unwrap();
        // At 10x the sizing rate the candidate is infeasible from the lo
        // bound already.
        let h = s.headroom(&azure(), &cand, 2000.0, 4000.0);
        assert_eq!(h, 2000.0);
    }
}
