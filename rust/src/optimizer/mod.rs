//! The paper's contribution: the two-phase fleet optimizer (§3.1) and its
//! companions — disaggregated P/D planning (§4.7), grid-flex analysis
//! (§4.8), reliability-aware sizing (§3.5), and what-if λ sweeps (§4.4).

pub mod analytic;
pub mod candidates;
pub mod disagg;
pub mod gridflex;
pub mod planner;
pub mod reliability;
pub mod whatif;
