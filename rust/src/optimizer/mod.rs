//! The paper's contribution: the two-phase fleet optimizer (§3.1) and its
//! companions — disaggregated P/D planning (§4.7), grid-flex analysis
//! (§4.8), reliability-aware sizing (§3.5), and what-if λ sweeps (§4.4).
//!
//! [`engine::EvalEngine`] is the shared substrate: Phase-1 backend
//! selection, the cached sampled-request stream for Phase-2 DES runs, and
//! the parallel minimal-fleet sweeps every scenario dispatches through.

pub mod analytic;
pub mod candidates;
pub mod disagg;
pub mod engine;
pub mod gridflex;
pub mod planner;
pub mod reliability;
pub mod whatif;
