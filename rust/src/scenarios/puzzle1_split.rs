//! Puzzle 1 (§4.1, Table 1): where exactly should I split?
//!
//! Sweeps B_short for LMSYS (λ=100, A100, SLO 500 ms) plus the Azure and
//! agent variants, reporting the Pareto frontier the paper prints:
//! per-threshold minimal fleets, cost vs the homogeneous baseline, and the
//! DES SLO verdict.

use crate::gpu::catalog::GpuCatalog;
use crate::queueing::mgc::WorkloadHist;
use crate::scenarios::common::*;
use crate::util::table::{dollars, millis, percent, Table};
use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

pub const THRESHOLDS: [f64; 6] = [512.0, 1024.0, 2048.0, 4096.0, 8192.0,
                                  12288.0];

fn sweep_table(
    name: &str,
    w: &WorkloadSpec,
    gpu_name: &str,
    slo: f64,
    opts: &ScenarioOpts,
) -> Table {
    let cat = GpuCatalog::standard();
    let gpu = cat.require(gpu_name).unwrap().clone();
    let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
    let max_len = w.cdf.max_len();

    // The paper's homogeneous baseline is utilization-cap sized.
    let homo = rho_cap_homogeneous(w, &hist, &gpu, opts.max_gpus).unwrap();
    let homo_cost = homo.cost_per_year();

    let mut t = Table::new(&["B_short", "alpha_s", "n_s", "n_l", "GPUs",
                             "$/yr", "saving", "P99 TTFT", "SLO"])
        .with_title(format!(
            "{name}: B_short Pareto frontier ({gpu_name}, λ={} req/s, \
             SLO={slo} ms; homogeneous baseline: {} GPUs at {})",
            w.lambda_rps, homo.n_s, dollars(homo_cost)
        ));
    for &b in THRESHOLDS.iter().filter(|&&b| b < max_len) {
        let alpha = hist.mass(0.0, b);
        match min_two_pool(w, &hist, &gpu, &gpu, b, slo, opts.max_gpus) {
            Some(cand) => {
                let (p99, _, _, _) = verify_candidate(w, &cand, opts);
                let saving = 1.0 - cand.cost_per_year() / homo_cost;
                t.row(&[
                    format!("{b:.0}"),
                    percent(alpha),
                    cand.n_s.to_string(),
                    cand.n_l.to_string(),
                    cand.total_gpus().to_string(),
                    dollars(cand.cost_per_year()),
                    format!("{:+.1}%", saving * 100.0),
                    millis(p99),
                    check(p99 <= slo).to_string(),
                ]);
            }
            None => {
                t.row(&[
                    format!("{b:.0}"),
                    percent(alpha),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "infeasible".into(),
                ]);
            }
        }
    }
    // Homogeneous row for reference.
    let (p99_homo, _, _, _) = verify_candidate(w, &homo, opts);
    t.row(&[
        "homo".into(),
        percent(1.0),
        homo.n_s.to_string(),
        "0".into(),
        homo.n_s.to_string(),
        dollars(homo_cost),
        "+0.0%".into(),
        millis(p99_homo),
        check(p99_homo <= slo).to_string(),
    ]);
    t
}

pub fn run(opts: &ScenarioOpts) -> PuzzleReport {
    let lmsys = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 100.0);
    let azure = WorkloadSpec::builtin(BuiltinTrace::Azure, 200.0);
    let agent = WorkloadSpec::builtin(BuiltinTrace::Agent, 200.0);
    let tables = vec![
        sweep_table("LMSYS", &lmsys, "A100", 500.0, opts),
        sweep_table("Azure", &azure, "A100", 500.0, opts),
        sweep_table("Agent", &agent, "A100", 500.0, opts),
    ];
    PuzzleReport {
        id: 1,
        title: "Where exactly should I split?".into(),
        tables,
        insight: "The optimal B_short cannot be read off the CDF: it \
                  balances slot efficiency, traffic fraction, and Erlang \
                  fragmentation across both pools, and too-high thresholds \
                  become SLO-infeasible from long-pool prefill alone."
            .into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lmsys_frontier_has_a_winning_split() {
        let opts = ScenarioOpts::fast();
        let report = run(&opts);
        assert_eq!(report.tables.len(), 3);
        let rendered = report.tables[0].render();
        // At least one split row shows a positive saving.
        assert!(rendered.contains('+'), "{rendered}");
        // Very high thresholds on the agent workload must be infeasible
        // or expensive (the paper's B=32768 failure mode).
        assert!(report.tables[0].n_rows() == 7);
    }
}
