//! Puzzle 1 (§4.1, Table 1): where exactly should I split?
//!
//! Sweeps B_short for LMSYS (λ=100, A100, SLO 500 ms) plus the Azure and
//! agent variants, reporting the Pareto frontier the paper prints:
//! per-threshold minimal fleets, cost vs the homogeneous baseline, and the
//! DES SLO verdict. Every threshold's minimal-fleet search + verification
//! runs in parallel through the engine.

use crate::optimizer::engine::{EvalEngine, SweepJob};
use crate::queueing::mgc::WorkloadHist;
use crate::scenarios::common::*;
use crate::scenarios::{Scenario, ScenarioSpec, Topology};
use crate::util::table::{dollars, millis, percent, Table};
use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

pub const THRESHOLDS: [f64; 6] = [512.0, 1024.0, 2048.0, 4096.0, 8192.0,
                                  12288.0];
pub const SLO_MS: f64 = 500.0;

fn sweep_table(
    engine: &EvalEngine,
    name: &str,
    w: &WorkloadSpec,
    gpu_name: &str,
    slo: f64,
    opts: &ScenarioOpts,
) -> Table {
    let gpu = engine.catalog.require(gpu_name).unwrap().clone();
    let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
    let max_len = w.cdf.max_len();

    // The paper's homogeneous baseline is utilization-cap sized.
    let homo = EvalEngine::rho_cap_homogeneous(w, &hist, &gpu, opts.max_gpus)
        .unwrap();
    let homo_cost = homo.cost_per_year();

    let thresholds: Vec<f64> =
        THRESHOLDS.iter().copied().filter(|&b| b < max_len).collect();
    let jobs: Vec<SweepJob> = thresholds
        .iter()
        .map(|&b| SweepJob::two_pool(&gpu, &gpu, b))
        .collect();
    let rows = engine.sweep_min_fleets(
        w, &hist, jobs, slo, opts.max_gpus, &opts.des(),
    );

    let mut t = Table::new(&["B_short", "alpha_s", "n_s", "n_l", "GPUs",
                             "$/yr", "saving", "P99 TTFT", "SLO"])
        .with_title(format!(
            "{name}: B_short Pareto frontier ({gpu_name}, λ={} req/s, \
             SLO={slo} ms; homogeneous baseline: {} GPUs at {})",
            w.lambda_rps, homo.n_s, dollars(homo_cost)
        ));
    for (&b, row) in thresholds.iter().zip(&rows) {
        let alpha = hist.mass(0.0, b);
        match row {
            Some((cand, v)) => {
                let p99 = v.p99_ttft_ms;
                let saving = 1.0 - cand.cost_per_year() / homo_cost;
                t.row(&[
                    format!("{b:.0}"),
                    percent(alpha),
                    cand.n_s.to_string(),
                    cand.n_l.to_string(),
                    cand.total_gpus().to_string(),
                    dollars(cand.cost_per_year()),
                    format!("{:+.1}%", saving * 100.0),
                    millis(p99),
                    check(p99 <= slo).to_string(),
                ]);
            }
            None => {
                t.row(&[
                    format!("{b:.0}"),
                    percent(alpha),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "infeasible".into(),
                ]);
            }
        }
    }
    // Homogeneous row for reference.
    let vh = engine.verify(w, &homo, &opts.des(), slo);
    t.row(&[
        "homo".into(),
        percent(1.0),
        homo.n_s.to_string(),
        "0".into(),
        homo.n_s.to_string(),
        dollars(homo_cost),
        "+0.0%".into(),
        millis(vh.p99_ttft_ms),
        check(vh.p99_ttft_ms <= slo).to_string(),
    ]);
    t
}

/// Registry entry for the B_short Pareto-frontier scenario.
pub struct SplitThreshold;

impl Scenario for SplitThreshold {
    fn id(&self) -> &'static str {
        "puzzle1"
    }

    fn name(&self) -> &'static str {
        "split-threshold"
    }

    fn title(&self) -> &'static str {
        "Where exactly should I split?"
    }

    fn spec(&self) -> ScenarioSpec {
        ScenarioSpec {
            workloads: vec![("lmsys", 100.0), ("azure", 200.0),
                            ("agent", 200.0)],
            gpus: vec!["A100"],
            thresholds: THRESHOLDS.to_vec(),
            lambda_sweep: vec![],
            slo_ms: SLO_MS,
            router: "LengthRouter",
            topology: Topology::TwoPool,
        }
    }

    fn run(&self, engine: &EvalEngine, opts: &ScenarioOpts) -> PuzzleReport {
        let lmsys = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 100.0);
        let azure = WorkloadSpec::builtin(BuiltinTrace::Azure, 200.0);
        let agent = WorkloadSpec::builtin(BuiltinTrace::Agent, 200.0);
        let tables = vec![
            sweep_table(engine, "LMSYS", &lmsys, "A100", SLO_MS, opts),
            sweep_table(engine, "Azure", &azure, "A100", SLO_MS, opts),
            sweep_table(engine, "Agent", &agent, "A100", SLO_MS, opts),
        ];
        PuzzleReport {
            id: 1,
            title: self.title().into(),
            tables,
            insight: "The optimal B_short cannot be read off the CDF: it \
                      balances slot efficiency, traffic fraction, and Erlang \
                      fragmentation across both pools, and too-high \
                      thresholds become SLO-infeasible from long-pool \
                      prefill alone."
                .into(),
        }
    }
}

/// Legacy entry point (CLI `puzzle 1`, benches): registry + default engine.
pub fn run(opts: &ScenarioOpts) -> PuzzleReport {
    SplitThreshold.run(&crate::scenarios::default_engine(opts), opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lmsys_frontier_has_a_winning_split() {
        let opts = ScenarioOpts::fast();
        let report = run(&opts);
        assert_eq!(report.tables.len(), 3);
        let rendered = report.tables[0].render();
        // At least one split row shows a positive saving.
        assert!(rendered.contains('+'), "{rendered}");
        // Very high thresholds on the agent workload must be infeasible
        // or expensive (the paper's B=32768 failure mode).
        assert!(report.tables[0].n_rows() == 7);
    }
}
