//! Puzzle 3 (§4.3, Table 3): which GPU type is actually cheapest?
//!
//! Azure workload at λ=100: the instinct "faster GPU, fewer GPUs, lower
//! cost" is wrong — the cheap A10G in a two-pool layout undercuts the
//! H100 fleets, while H100 wins on rack space and short-request latency.
//! The per-GPU-type minimal-fleet searches run in parallel.

use crate::optimizer::engine::EvalEngine;
use crate::queueing::mgc::WorkloadHist;
use crate::scenarios::common::*;
use crate::scenarios::{Scenario, ScenarioSpec, Topology};
use crate::util::table::{dollars, millis, Align, Table};
use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

pub const LAMBDA: f64 = 100.0;
pub const SLO_MS: f64 = 500.0;

/// One evaluated layout.
#[derive(Debug, Clone)]
pub struct LayoutRow {
    pub gpu: String,
    pub layout: String,
    pub gpus: u32,
    pub cost_yr: f64,
    pub p99_short: f64,
    pub p99_long: f64,
    pub slo_ok: bool,
}

/// Evaluate homogeneous + best-two-pool layouts for every GPU type, in
/// parallel, through the given engine.
pub fn evaluate_with(
    engine: &EvalEngine,
    opts: &ScenarioOpts,
) -> Vec<LayoutRow> {
    let w = WorkloadSpec::builtin(BuiltinTrace::Azure, LAMBDA);
    let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
    let per_gpu = engine.par_map(vec!["A10G", "A100", "H100"], |name| {
        let gpu = engine.catalog.require(name).unwrap().clone();
        let mut rows = Vec::new();
        // Homogeneous.
        if let Some(cand) =
            EvalEngine::min_homogeneous(&w, &hist, &gpu, SLO_MS, opts.max_gpus)
        {
            let v = engine.verify(&w, &cand, &opts.des(), SLO_MS);
            rows.push(LayoutRow {
                gpu: (*name).into(),
                layout: "Homo".into(),
                gpus: cand.total_gpus(),
                cost_yr: cand.cost_per_year(),
                p99_short: v.p99_ttft_ms,
                p99_long: 0.0,
                slo_ok: v.passed,
            });
        }
        // Best two-pool over a handful of thresholds.
        let best = [2048.0, 3072.0, 4096.0]
            .iter()
            .filter_map(|&b| EvalEngine::min_two_pool(&w, &hist, &gpu, &gpu, b,
                                                      SLO_MS, opts.max_gpus))
            .min_by(|a, b| a.cost_per_year().total_cmp(&b.cost_per_year()));
        if let Some(cand) = best {
            let v = engine.verify(&w, &cand, &opts.des(), SLO_MS);
            rows.push(LayoutRow {
                gpu: (*name).into(),
                layout: format!("Two-pool B={}", cand.b_short),
                gpus: cand.total_gpus(),
                cost_yr: cand.cost_per_year(),
                p99_short: v.p99_ttft_short_ms,
                p99_long: v.p99_ttft_long_ms,
                slo_ok: v.passed,
            });
        }
        rows
    });
    let mut rows: Vec<LayoutRow> = per_gpu.into_iter().flatten().collect();
    rows.sort_by(|a, b| a.cost_yr.total_cmp(&b.cost_yr));
    rows
}

/// Evaluate with a default engine (legacy signature used by benches).
pub fn evaluate(opts: &ScenarioOpts) -> Vec<LayoutRow> {
    evaluate_with(&crate::scenarios::default_engine(opts), opts)
}

/// Registry entry for the GPU-type comparison scenario.
pub struct GpuTypeChoice;

impl Scenario for GpuTypeChoice {
    fn id(&self) -> &'static str {
        "puzzle3"
    }

    fn name(&self) -> &'static str {
        "gpu-type"
    }

    fn title(&self) -> &'static str {
        "Which GPU type is actually cheapest?"
    }

    fn spec(&self) -> ScenarioSpec {
        ScenarioSpec {
            workloads: vec![("azure", LAMBDA)],
            gpus: vec!["A10G", "A100", "H100"],
            thresholds: vec![2048.0, 3072.0, 4096.0],
            lambda_sweep: vec![],
            slo_ms: SLO_MS,
            router: "LengthRouter",
            topology: Topology::TwoPool,
        }
    }

    fn run(&self, engine: &EvalEngine, opts: &ScenarioOpts) -> PuzzleReport {
        let rows = evaluate_with(engine, opts);
        let mut t = Table::new(&["GPU", "Layout", "GPUs", "Cost/yr",
                                 "P99 short/long", "SLO"])
            .with_title(format!(
                "GPU type vs layout (Azure, λ={LAMBDA}, SLO={SLO_MS} ms)"
            ))
            .align(&[Align::Left, Align::Left, Align::Right, Align::Right,
                     Align::Right, Align::Right]);
        for r in &rows {
            let lat = if r.p99_long > 0.0 {
                format!("{} / {}", millis(r.p99_short), millis(r.p99_long))
            } else {
                millis(r.p99_short)
            };
            t.row(&[
                r.gpu.clone(),
                r.layout.clone(),
                r.gpus.to_string(),
                dollars(r.cost_yr),
                lat,
                check(r.slo_ok).to_string(),
            ]);
        }

        // Decision table (paper's "different constraints, different
        // choices").
        let cheapest = rows.iter().filter(|r| r.slo_ok).min_by(
            |a, b| a.cost_yr.total_cmp(&b.cost_yr));
        let fewest = rows.iter().filter(|r| r.slo_ok).min_by_key(|r| r.gpus);
        let fastest = rows.iter().filter(|r| r.slo_ok).min_by(
            |a, b| a.p99_short.total_cmp(&b.p99_short));
        let mut d = Table::new(&["Priority", "Choice"])
            .align(&[Align::Left, Align::Left]);
        if let Some(r) = cheapest {
            d.row(&["Minimum annual cost".into(),
                    format!("{} {} ({})", r.gpu, r.layout,
                            dollars(r.cost_yr))]);
        }
        if let Some(r) = fewest {
            d.row(&["Minimum rack space / power".into(),
                    format!("{} {} ({} GPUs)", r.gpu, r.layout, r.gpus)]);
        }
        if let Some(r) = fastest {
            d.row(&["Best short-request latency".into(),
                    format!("{} {} ({} P99)", r.gpu, r.layout,
                            millis(r.p99_short))]);
        }
        d.row(&["Long-context / agent workload".into(),
                "H100 or A100 (A10G VRAM limits KV cache)".into()]);

        PuzzleReport {
            id: 3,
            title: self.title().into(),
            tables: vec![t, d],
            insight: "GPU cost depends on pool topology, not just price and \
                      throughput: the slot multiplier from a well-chosen \
                      B_short makes the slower, cheaper A10G the \
                      minimum-cost option, while H100 wins on footprint and \
                      latency."
                .into(),
        }
    }
}

/// Legacy entry point (CLI `puzzle 3`, benches): registry + default engine.
pub fn run(opts: &ScenarioOpts) -> PuzzleReport {
    GpuTypeChoice.run(&crate::scenarios::default_engine(opts), opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a10g_two_pool_is_cheapest_h100_fewest() {
        let rows = evaluate(&ScenarioOpts::fast());
        let ok: Vec<_> = rows.iter().filter(|r| r.slo_ok).collect();
        assert!(!ok.is_empty());
        let cheapest = ok.iter().min_by(|a, b| a.cost_yr.total_cmp(&b.cost_yr))
            .unwrap();
        assert_eq!(cheapest.gpu, "A10G", "{cheapest:?}");
        let fewest = ok.iter().min_by_key(|r| r.gpus).unwrap();
        assert_eq!(fewest.gpu, "H100", "{fewest:?}");
        // And the cheapest H100 config costs more than the A10G one.
        let h100_min = ok.iter().filter(|r| r.gpu == "H100")
            .map(|r| r.cost_yr).fold(f64::INFINITY, f64::min);
        assert!(cheapest.cost_yr < h100_min);
    }
}
