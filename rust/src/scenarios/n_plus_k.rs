//! The `n_plus_k` scenario (report id 11): does Eq. 6 sizing survive a
//! real outage?
//!
//! Paper §3.5 sizes for reliability analytically: availability
//! A = 1 / (1 + r_f · MTTR) and a production count of ceil(n / A)
//! (Eq. 6). That restores *long-run average* capacity but is blind to
//! `k` — it prescribes the same fleet whether one GPU fails or three
//! fail at the worst moment. This scenario injects a deterministic
//! k-GPU outage at the diurnal peak ([`crate::des::faults`]) and
//! contrasts three fleets per k:
//!
//! * **Eq. 6**: `NodeAvail::hard_failure().production_count(n0)` over
//!   the size-to-peak baseline `n0` — k-independent by construction;
//! * **naive N+k**: `n0 + k` spares, the operator's rule of thumb;
//! * **empirical**: [`EvalEngine::size_for_failures`], the smallest
//!   fleet that meets the SLO in **every window while the outage is in
//!   progress** (including post-recovery cold-start inflation).
//!
//! The table also replays the Eq. 6 fleet through the same fault
//! script: the rows where it fails its windows — and where the
//! empirical size exceeds the analytic one — are the gap between
//! availability accounting and SLO attainment during the outage.

use crate::des::faults::OutageSpec;
use crate::optimizer::engine::EvalEngine;
use crate::optimizer::reliability::NodeAvail;
use crate::router::RoutingPolicy;
use crate::scenarios::common::*;
use crate::scenarios::diurnal::{self, LAMBDA_HI, LAMBDA_LO, SLO_MS,
                                WINDOW_MS};
use crate::scenarios::{Scenario, ScenarioSpec, Topology};
use crate::util::table::{dollars, millis, Table};

/// Concurrent GPU failures swept (k = 0 pins the no-fault baseline).
pub const MAX_K: u32 = 2;
/// Outage start (ms): the first peak phase of the diurnal profile, so
/// the failure lands where capacity matters most — and inside the
/// horizon of even `--fast` runs.
pub const FAIL_AT_MS: f64 = 10_000.0;
/// Mean time to recovery (ms): the whole peak phase.
pub const MTTR_MS: f64 = 10_000.0;
/// Cold-start window after recovery (ms) and its slowdown factor
/// (cache refill / router re-warm).
pub const WARM_MS: f64 = 2_000.0;
pub const WARM_FACTOR: f64 = 2.0;

/// The outage schedule shared by every row.
pub fn outage() -> OutageSpec {
    OutageSpec {
        fail_at_ms: FAIL_AT_MS,
        mttr_ms: MTTR_MS,
        warm_ms: WARM_MS,
        warm_factor: WARM_FACTOR,
    }
}

/// Registry entry for the N+k reliability-sizing scenario.
pub struct NPlusK;

impl Scenario for NPlusK {
    fn id(&self) -> &'static str {
        "n_plus_k"
    }

    fn name(&self) -> &'static str {
        "n-plus-k"
    }

    fn title(&self) -> &'static str {
        "N+k sizing: Eq. 6 availability vs surviving the outage"
    }

    fn spec(&self) -> ScenarioSpec {
        ScenarioSpec {
            workloads: vec![("azure", (LAMBDA_LO + LAMBDA_HI) / 2.0)],
            gpus: vec!["H100"],
            thresholds: vec![],
            lambda_sweep: vec![LAMBDA_LO, LAMBDA_HI],
            slo_ms: SLO_MS,
            router: "Random",
            topology: Topology::SinglePool,
        }
    }

    fn run(&self, engine: &EvalEngine, opts: &ScenarioOpts) -> PuzzleReport {
        let gpu = engine.catalog.get("H100").unwrap().clone();
        let w = diurnal::workload();
        let mut cfg = opts.des();
        if cfg.window_ms.is_none() {
            cfg.window_ms = Some(WINDOW_MS);
        }
        let spec = outage();

        // The fault-free baseline every sizing rule starts from.
        let Some((n0, _)) =
            engine.size_to_peak(&w, &gpu, SLO_MS, opts.max_gpus, &cfg)
        else {
            return PuzzleReport {
                id: 11,
                title: self.title().into(),
                tables: vec![],
                insight: format!(
                    "No H100 fleet within max_gpus = {} meets the \
                     {SLO_MS} ms SLO in every window at the {LAMBDA_HI} \
                     req/s peak; raise max_gpus to size this profile.",
                    opts.max_gpus
                ),
            };
        };
        let avail = NodeAvail::hard_failure();
        // Eq. 6 prescribes one number regardless of k.
        let n_eq6 = avail.production_count(n0);

        let mut table = Table::new(&[
            "k down", "Eq. 6 fleet", "naive N+k", "empirical fleet",
            "Eq. 6 meets SLO?", "Eq. 6 == empirical",
        ])
        .with_title(format!(
            "N+k sizing on the diurnal Azure trace (n0 = {n0} H100s, \
             k GPUs fail at the {:.0} s peak for {:.0} s, {:.0} s \
             cold-start x{WARM_FACTOR} after recovery, SLO {SLO_MS} ms)",
            FAIL_AT_MS / 1000.0,
            MTTR_MS / 1000.0,
            WARM_MS / 1000.0,
        ));

        let mut n_disagree = 0usize;
        let mut worst_gap = 0u32;
        for k in 0..=MAX_K {
            let script = spec.script(0, k as usize);
            // Replay the Eq. 6 fleet through this outage.
            let mut r_eq6 = engine.simulate_faulted(
                &w,
                &[sim_pool(&gpu, n_eq6, &w)],
                &RoutingPolicy::Random { n_pools: 1 },
                &cfg,
                Some(&script),
            );
            let eq6_ok = r_eq6.meets_slo_in_every_window(SLO_MS);
            let empirical = engine.size_for_failures(
                &w, &gpu, SLO_MS, k, opts.max_gpus, &cfg, &spec,
            );
            let (emp_cell, agree_cell) = match &empirical {
                Some((n_emp, _)) => {
                    if *n_emp != n_eq6 {
                        n_disagree += 1;
                        worst_gap =
                            worst_gap.max(n_emp.saturating_sub(n_eq6));
                    }
                    (n_emp.to_string(), check(*n_emp == n_eq6).to_string())
                }
                None => ("-".to_string(), "-".to_string()),
            };
            table.row(&[
                k.to_string(),
                n_eq6.to_string(),
                (n0 + k).to_string(),
                emp_cell,
                format!("{} ({})", check(eq6_ok),
                        millis(r_eq6.overall.p99_ttft())),
                agree_cell,
            ]);
        }

        let emp_max = engine
            .size_for_failures(
                &w, &gpu, SLO_MS, MAX_K, opts.max_gpus, &cfg, &spec,
            )
            .map(|(n, _)| n);
        let delta_cost = emp_max.map_or(0.0, |n| {
            gpu.cost_per_year() * n.saturating_sub(n_eq6) as f64
        });
        PuzzleReport {
            id: 11,
            title: self.title().into(),
            tables: vec![table],
            insight: format!(
                "Eq. 6 turns the availability model into one production \
                 count — {n_eq6} GPUs over the n0 = {n0} baseline — no \
                 matter how many GPUs fail at once, because it restores \
                 long-run average capacity, not worst-window capacity. \
                 Simulating the outage disagrees with it in \
                 {n_disagree}/{} of the k values (largest shortfall: \
                 {worst_gap} GPUs): surviving k = {MAX_K} concurrent \
                 failures through the peak empirically requires {} — \
                 {} per year above the Eq. 6 fleet. Deterministic fault \
                 injection is what makes that gap measurable at all.",
                MAX_K + 1,
                emp_max.map_or("more GPUs than max_gpus allows"
                                   .to_string(),
                               |n| format!("{n} GPUs")),
                dollars(delta_cost),
            ),
        }
    }
}

/// Single homogeneous pool at the workload's full context budget.
fn sim_pool(
    gpu: &crate::gpu::profile::GpuProfile,
    n: u32,
    w: &crate::workload::spec::WorkloadSpec,
) -> crate::des::engine::SimPool {
    crate::des::engine::SimPool {
        gpu: gpu.clone(),
        n_gpus: n as usize,
        ctx_budget: w.cdf.max_len(),
        batch_cap: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenarios::default_engine;

    #[test]
    fn eq6_and_empirical_sizing_disagree_for_some_k() {
        let opts = ScenarioOpts::fast();
        let engine = default_engine(&opts);
        let report = NPlusK.run(&engine, &opts);
        assert_eq!(report.id, 11);
        assert_eq!(report.tables.len(), 1, "{}", report.insight);
        let table = report.tables[0].render();
        // Eq. 6 is k-independent; the empirical mode is not. At least
        // one k must disagree (k = 0 alone guarantees it: ceil(n0/A)
        // strictly exceeds the no-fault requirement n0), so the agree
        // column cannot be all-"yes".
        assert!(table.contains("FAIL"), "{table}");
        assert!(report.insight.contains("Eq. 6"));

        // The structural guarantee behind the FAIL: the analytic
        // production count never equals the k = 0 empirical size.
        let gpu = engine.catalog.get("H100").unwrap().clone();
        let w = diurnal::workload();
        let mut cfg = opts.des();
        cfg.window_ms = Some(WINDOW_MS);
        let (n0, _) = engine
            .size_to_peak(&w, &gpu, SLO_MS, opts.max_gpus, &cfg)
            .expect("feasible");
        assert!(NodeAvail::hard_failure().production_count(n0) > n0);
    }
}
