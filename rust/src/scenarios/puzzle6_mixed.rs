//! Puzzle 6 (§4.6, Tables 6 & 7): does mixing GPU types save money?
//!
//! Azure: cheap A10Gs in the short pool + premium GPUs only where the long
//! context warrants them. LMSYS at 65K max context: the long-pool GPU
//! choice decides SLO feasibility outright — some pairings are invalid at
//! any count (long-context prefill on slow chunks blows the budget). The
//! five pairings size + verify in parallel.

use crate::optimizer::engine::{EvalEngine, SweepJob};
use crate::queueing::mgc::WorkloadHist;
use crate::scenarios::common::*;
use crate::scenarios::{Scenario, ScenarioSpec, Topology};
use crate::util::table::{dollars, millis, Align, Table};
use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

pub const LAMBDA: f64 = 100.0;
pub const SLO_MS: f64 = 500.0;

#[derive(Debug, Clone)]
pub struct MixRow {
    pub config: String,
    pub gpus: u32,
    pub cost_yr: f64,
    pub p99_short: f64,
    pub p99_long: f64,
    pub feasible: bool,
}

/// Evaluate the five GPU pairings (in parallel) through the given engine.
pub fn evaluate_with(
    engine: &EvalEngine,
    trace: BuiltinTrace,
    b_short: f64,
    opts: &ScenarioOpts,
) -> Vec<MixRow> {
    let w = WorkloadSpec::builtin(trace, LAMBDA);
    let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
    let pairs = [("A100", "A100"), ("A10G", "H100"), ("A10G", "A100"),
                 ("A10G", "A10G"), ("H100", "H100")];
    let jobs: Vec<SweepJob> = pairs
        .iter()
        .map(|(s, l)| {
            SweepJob::two_pool(
                engine.catalog.require(s).unwrap(),
                engine.catalog.require(l).unwrap(),
                b_short,
            )
        })
        .collect();
    let sized =
        engine.sweep_min_fleets(&w, &hist, jobs, SLO_MS, opts.max_gpus,
                                &opts.des());
    let mut rows = Vec::new();
    for ((s, l), row) in pairs.iter().zip(sized) {
        let config = if s == l {
            format!("All-{s}")
        } else {
            format!("{s} Ps + {l} Pl")
        };
        match row {
            Some((cand, v)) => {
                // Table 7 verdicts are per-pool: a long pool violating the
                // SLO fails the config even though long traffic is too
                // rare to move the fleet-wide P99.
                // NaN P99 means the pool served nothing: an idle pool
                // passes vacuously (!(NaN > SLO)), while a dead pool
                // with queued traffic is caught by `v.passed`.
                rows.push(MixRow {
                    config,
                    gpus: cand.total_gpus(),
                    cost_yr: cand.cost_per_year(),
                    p99_short: v.p99_ttft_short_ms,
                    p99_long: v.p99_ttft_long_ms,
                    feasible: v.passed
                        && !(v.p99_ttft_short_ms > SLO_MS)
                        && !(v.p99_ttft_long_ms > SLO_MS),
                });
            }
            None => rows.push(MixRow {
                config,
                gpus: 0,
                cost_yr: f64::INFINITY,
                p99_short: f64::NAN,
                p99_long: f64::NAN,
                feasible: false,
            }),
        }
    }
    rows.sort_by(|a, b| a.cost_yr.total_cmp(&b.cost_yr));
    rows
}

/// Evaluate with a default engine (legacy signature used by benches).
pub fn evaluate(trace: BuiltinTrace, b_short: f64, opts: &ScenarioOpts)
    -> Vec<MixRow>
{
    evaluate_with(&crate::scenarios::default_engine(opts), trace, b_short,
                  opts)
}

fn table_for(engine: &EvalEngine, name: &str, trace: BuiltinTrace,
             b_short: f64, opts: &ScenarioOpts) -> Table {
    let rows = evaluate_with(engine, trace, b_short, opts);
    let mut t = Table::new(&["Config", "GPUs", "Cost/yr", "P99-short",
                             "P99-long", "SLO"])
        .with_title(format!(
            "Mixed GPU types, {name} workload (λ={LAMBDA}, B={b_short}, \
             SLO={SLO_MS} ms)"
        ))
        .align(&[Align::Left, Align::Right, Align::Right, Align::Right,
                 Align::Right, Align::Right]);
    for r in &rows {
        if r.cost_yr.is_finite() {
            t.row(&[
                r.config.clone(),
                r.gpus.to_string(),
                dollars(r.cost_yr),
                millis(r.p99_short),
                millis(r.p99_long),
                check(r.feasible).to_string(),
            ]);
        } else {
            t.row(&[
                r.config.clone(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "infeasible at any count".into(),
            ]);
        }
    }
    t
}

/// Registry entry for the mixed-GPU-types scenario.
pub struct MixedGpuTypes;

impl Scenario for MixedGpuTypes {
    fn id(&self) -> &'static str {
        "puzzle6"
    }

    fn name(&self) -> &'static str {
        "mixed-gpu"
    }

    fn title(&self) -> &'static str {
        "Does mixing GPU types save money?"
    }

    fn spec(&self) -> ScenarioSpec {
        ScenarioSpec {
            workloads: vec![("azure", LAMBDA), ("lmsys", LAMBDA)],
            gpus: vec!["A10G", "A100", "H100"],
            thresholds: vec![3072.0, 4096.0],
            lambda_sweep: vec![],
            slo_ms: SLO_MS,
            router: "LengthRouter",
            topology: Topology::MixedTwoPool,
        }
    }

    fn run(&self, engine: &EvalEngine, opts: &ScenarioOpts) -> PuzzleReport {
        let tables = vec![
            table_for(engine, "Azure", BuiltinTrace::Azure, 3072.0, opts),
            table_for(engine, "LMSYS (65K max ctx)", BuiltinTrace::Lmsys,
                      4096.0, opts),
        ];
        PuzzleReport {
            id: 6,
            title: self.title().into(),
            tables,
            insight: "Mixing is not just a cost play: on LMSYS the long-pool \
                      GPU decides feasibility — slow chunked prefill on a \
                      65K prompt can exceed the whole SLO budget no matter \
                      how many cards you add. Joint optimization over pool \
                      assignment and GPU type is required; some pairings are \
                      simply invalid."
                .into(),
        }
    }
}

/// Legacy entry point (CLI `puzzle 6`, benches): registry + default engine.
pub fn run(opts: &ScenarioOpts) -> PuzzleReport {
    MixedGpuTypes.run(&crate::scenarios::default_engine(opts), opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn azure_mixing_saves_vs_all_a100() {
        let rows = evaluate(BuiltinTrace::Azure, 3072.0,
                            &ScenarioOpts::fast());
        let all_a100 = rows.iter().find(|r| r.config == "All-A100").unwrap();
        let mixed = rows
            .iter()
            .find(|r| r.config.starts_with("A10G Ps"))
            .filter(|r| r.feasible);
        if let Some(m) = mixed {
            assert!(m.cost_yr < all_a100.cost_yr,
                    "mixed {} vs all-A100 {}", m.cost_yr, all_a100.cost_yr);
        }
    }

    #[test]
    fn lmsys_long_pool_gpu_choice_matters() {
        let rows = evaluate(BuiltinTrace::Lmsys, 4096.0,
                            &ScenarioOpts::fast());
        // A10G cannot hold the 65K long pool VRAM/SLO-wise; with an H100
        // long pool the same short pool becomes feasible.
        let a10g_long = rows.iter().find(|r| r.config == "All-A10G").unwrap();
        let h100_long = rows
            .iter()
            .find(|r| r.config == "A10G Ps + H100 Pl")
            .unwrap();
        assert!(
            !a10g_long.feasible || a10g_long.cost_yr > h100_long.cost_yr,
            "A10G long pool should lose: {a10g_long:?} vs {h100_long:?}"
        );
        assert!(h100_long.feasible, "{h100_long:?}");
    }
}
