//! Shared scenario plumbing: options, report struct, minimal-fleet sizing.

use crate::des::engine::{DesConfig, SimPool, Simulator};
use crate::gpu::profile::GpuProfile;
use crate::optimizer::candidates::{n_min_for_slice, Candidate};
use crate::queueing::mgc::{analyze_pool, PoolSpec, WorkloadHist};
use crate::router::RoutingPolicy;
use crate::util::table::Table;
use crate::workload::spec::WorkloadSpec;

/// Knobs shared by every scenario.
#[derive(Debug, Clone)]
pub struct ScenarioOpts {
    /// DES request count (paper uses 10^4–1.5x10^4).
    pub n_requests: usize,
    pub seed: u64,
    /// Max GPUs per pool when searching for a minimal feasible fleet.
    pub max_gpus: u32,
}

impl Default for ScenarioOpts {
    fn default() -> Self {
        ScenarioOpts { n_requests: 10_000, seed: 42, max_gpus: 256 }
    }
}

impl ScenarioOpts {
    /// Reduced-fidelity settings for quick CLI runs / CI.
    pub fn fast() -> Self {
        ScenarioOpts { n_requests: 3_000, seed: 42, max_gpus: 256 }
    }

    pub fn des(&self) -> DesConfig {
        DesConfig {
            n_requests: self.n_requests,
            seed: self.seed,
            ..Default::default()
        }
    }
}

/// A regenerated paper table plus its insight line.
#[derive(Debug, Clone)]
pub struct PuzzleReport {
    pub id: usize,
    pub title: String,
    pub tables: Vec<Table>,
    pub insight: String,
}

impl PuzzleReport {
    pub fn render(&self) -> String {
        let mut out = format!("=== Puzzle {}: {} ===\n", self.id, self.title);
        for t in &self.tables {
            out.push_str(&t.render());
            out.push('\n');
        }
        out.push_str(&format!("Insight: {}\n", self.insight));
        out
    }
}

/// Smallest per-pool GPU count meeting the analytical SLO for the slice
/// (starting from the utilization-cap lower bound).
pub fn min_pool_gpus(
    hist: &WorkloadHist,
    lo: f64,
    hi: f64,
    lambda_ms: f64,
    gpu: &GpuProfile,
    ctx: f64,
    slo_ms: f64,
    max_gpus: u32,
) -> Option<u32> {
    let start = n_min_for_slice(hist, lo, hi, lambda_ms, gpu, ctx)?;
    for n in start..=max_gpus {
        let spec = PoolSpec { gpu: gpu.clone(), n_gpus: n as usize, ctx_budget: ctx };
        if analyze_pool(hist, lo, hi, lambda_ms, &spec).meets_slo(slo_ms) {
            return Some(n);
        }
    }
    None
}

/// Minimal two-pool candidate (analytic Phase 1) for a threshold and GPU
/// pairing; None if either pool cannot meet the SLO within `max_gpus`.
pub fn min_two_pool(
    w: &WorkloadSpec,
    hist: &WorkloadHist,
    gpu_s: &GpuProfile,
    gpu_l: &GpuProfile,
    b_short: f64,
    slo_ms: f64,
    max_gpus: u32,
) -> Option<Candidate> {
    let max_len = w.cdf.max_len();
    let lam = w.lambda_per_ms();
    let n_s = min_pool_gpus(hist, 0.0, b_short, lam, gpu_s, b_short, slo_ms,
                            max_gpus)?;
    let n_l = min_pool_gpus(hist, b_short, max_len, lam, gpu_l, max_len,
                            slo_ms, max_gpus)?;
    Some(Candidate {
        b_short,
        n_s,
        n_l,
        gpu_s: gpu_s.clone(),
        gpu_l: gpu_l.clone(),
        ctx_s: b_short,
        ctx_l: max_len,
    })
}

/// Minimal homogeneous candidate.
pub fn min_homogeneous(
    w: &WorkloadSpec,
    hist: &WorkloadHist,
    gpu: &GpuProfile,
    slo_ms: f64,
    max_gpus: u32,
) -> Option<Candidate> {
    let max_len = w.cdf.max_len();
    let n = min_pool_gpus(hist, 0.0, max_len, w.lambda_per_ms(), gpu, max_len,
                          slo_ms, max_gpus)?;
    Some(Candidate {
        b_short: max_len * 2.0,
        n_s: n,
        n_l: 0,
        gpu_s: gpu.clone(),
        gpu_l: gpu.clone(),
        ctx_s: max_len,
        ctx_l: max_len,
    })
}

/// Homogeneous fleet sized by the utilization cap only (ignoring the SLO)
/// — the paper's Table-1 "homogeneous baseline".
pub fn rho_cap_homogeneous(
    w: &WorkloadSpec,
    hist: &WorkloadHist,
    gpu: &GpuProfile,
    max_gpus: u32,
) -> Option<Candidate> {
    let max_len = w.cdf.max_len();
    let lam = w.lambda_per_ms();
    let start = n_min_for_slice(hist, 0.0, max_len, lam, gpu, max_len)?;
    let n = start.min(max_gpus);
    Some(Candidate {
        b_short: max_len * 2.0,
        n_s: n,
        n_l: 0,
        gpu_s: gpu.clone(),
        gpu_l: gpu.clone(),
        ctx_s: max_len,
        ctx_l: max_len,
    })
}

/// DES-verify a candidate with the production LengthRouter; returns
/// (overall P99 TTFT, short P99, long P99, per-pool utilization).
pub fn verify_candidate(
    w: &WorkloadSpec,
    cand: &Candidate,
    opts: &ScenarioOpts,
) -> (f64, f64, f64, Vec<f64>) {
    let (pools, router) = crate::optimizer::planner::plan_pools(cand);
    let sim = Simulator::new(w.clone(), pools, router, opts.des());
    let mut r = sim.run();
    let short = r.per_pool[0].stats.ttft.p99();
    let long = if r.per_pool.len() > 1 {
        r.per_pool[1].stats.ttft.p99()
    } else {
        0.0
    };
    (
        r.overall.p99_ttft(),
        short,
        long,
        r.per_pool.iter().map(|p| p.utilization).collect(),
    )
}

/// DES on an explicit pool layout + router.
pub fn simulate(
    w: &WorkloadSpec,
    pools: Vec<SimPool>,
    router: RoutingPolicy,
    opts: &ScenarioOpts,
) -> crate::des::metrics::DesResult {
    Simulator::new(w.clone(), pools, router, opts.des()).run()
}

pub fn check(ok: bool) -> &'static str {
    if ok {
        "yes"
    } else {
        "FAIL"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::catalog::GpuCatalog;
    use crate::workload::spec::BuiltinTrace;

    #[test]
    fn min_two_pool_is_minimal_and_feasible() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 100.0);
        let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
        let gpu = GpuCatalog::standard().get("A100").unwrap().clone();
        let cand = min_two_pool(&w, &hist, &gpu, &gpu, 4096.0, 500.0, 256)
            .expect("feasible");
        // Feasible at (n_s, n_l)…
        let s = analyze_pool(&hist, 0.0, 4096.0, w.lambda_per_ms(),
                             &cand.short_spec());
        assert!(s.meets_slo(500.0));
        // …but not with one fewer short GPU (minimality), unless already 1.
        if cand.n_s > 1 {
            let mut smaller = cand.short_spec();
            smaller.n_gpus -= 1;
            assert!(!analyze_pool(&hist, 0.0, 4096.0, w.lambda_per_ms(),
                                  &smaller)
                .meets_slo(500.0));
        }
    }

    #[test]
    fn rho_cap_baseline_smaller_or_equal_to_slo_sized() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 100.0);
        let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
        let gpu = GpuCatalog::standard().get("A100").unwrap().clone();
        let cap = rho_cap_homogeneous(&w, &hist, &gpu, 256).unwrap();
        if let Some(slo) = min_homogeneous(&w, &hist, &gpu, 500.0, 256) {
            assert!(cap.n_s <= slo.n_s);
        }
    }

    #[test]
    fn verify_candidate_reports_pools() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 50.0);
        let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
        let gpu = GpuCatalog::standard().get("H100").unwrap().clone();
        let cand = min_two_pool(&w, &hist, &gpu, &gpu, 2048.0, 500.0, 64)
            .unwrap();
        let opts = ScenarioOpts::fast();
        let (overall, short, long, util) = verify_candidate(&w, &cand, &opts);
        assert!(overall > 0.0 && short > 0.0 && long > 0.0);
        assert_eq!(util.len(), 2);
    }
}
