//! Shared scenario plumbing: options, report struct, and thin wrappers
//! over the minimal-fleet sizing that now lives in
//! [`crate::optimizer::engine::EvalEngine`].
//!
//! The free functions here are the stable convenience API for one-off
//! calls (CLI helpers, tests, external users). Scenario sweeps and
//! anything evaluating many candidates should go through an `EvalEngine`
//! instance instead, which adds the shared request-stream cache and
//! parallel fan-out; `verify_candidate` below constructs a throwaway
//! engine and gets neither.

use crate::des::engine::{DesConfig, SimPool, Simulator};
use crate::gpu::profile::GpuProfile;
use crate::optimizer::candidates::Candidate;
use crate::optimizer::engine::EvalEngine;
use crate::queueing::mgc::WorkloadHist;
use crate::router::RoutingPolicy;
use crate::util::parallel::default_threads;
use crate::util::table::Table;
use crate::workload::spec::WorkloadSpec;

/// Knobs shared by every scenario.
#[derive(Debug, Clone)]
pub struct ScenarioOpts {
    /// DES request count (paper uses 10^4–1.5x10^4).
    pub n_requests: usize,
    pub seed: u64,
    /// Max GPUs per pool when searching for a minimal feasible fleet.
    pub max_gpus: u32,
    /// Worker threads for the engine's parallel sweeps (1 = serial).
    pub threads: usize,
    /// Windowed-SLO evaluation: collect per-window TTFT stats over
    /// fixed-width windows of this many ms (`--window`; None = aggregate
    /// only, scenarios with windowed semantics supply their own
    /// default). Commands that don't render windows still collect them
    /// when this is set — harmless but unused there.
    pub window_ms: Option<f64>,
}

impl Default for ScenarioOpts {
    fn default() -> Self {
        ScenarioOpts {
            n_requests: 10_000,
            seed: 42,
            max_gpus: 256,
            threads: default_threads(),
            window_ms: None,
        }
    }
}

impl ScenarioOpts {
    /// Reduced-fidelity settings for quick CLI runs / CI.
    pub fn fast() -> Self {
        ScenarioOpts { n_requests: 3_000, ..Default::default() }
    }

    /// Same fidelity, single-threaded sweeps (determinism cross-checks).
    pub fn serial(mut self) -> Self {
        self.threads = 1;
        self
    }

    pub fn des(&self) -> DesConfig {
        DesConfig {
            n_requests: self.n_requests,
            seed: self.seed,
            window_ms: self.window_ms,
            ..Default::default()
        }
    }
}

/// A regenerated paper table plus its insight line.
#[derive(Debug, Clone)]
pub struct PuzzleReport {
    pub id: usize,
    pub title: String,
    pub tables: Vec<Table>,
    pub insight: String,
}

impl PuzzleReport {
    pub fn render(&self) -> String {
        let mut out = format!("=== Puzzle {}: {} ===\n", self.id, self.title);
        for t in &self.tables {
            out.push_str(&t.render());
            out.push('\n');
        }
        out.push_str(&format!("Insight: {}\n", self.insight));
        out
    }
}

/// Smallest per-pool GPU count meeting the analytical SLO for the slice
/// (starting from the utilization-cap lower bound).
#[allow(clippy::too_many_arguments)]
pub fn min_pool_gpus(
    hist: &WorkloadHist,
    lo: f64,
    hi: f64,
    lambda_ms: f64,
    gpu: &GpuProfile,
    ctx: f64,
    slo_ms: f64,
    max_gpus: u32,
) -> Option<u32> {
    EvalEngine::min_pool_gpus(hist, lo, hi, lambda_ms, gpu, ctx, slo_ms,
                              max_gpus)
}

/// Minimal two-pool candidate (analytic Phase 1) for a threshold and GPU
/// pairing; None if either pool cannot meet the SLO within `max_gpus`.
pub fn min_two_pool(
    w: &WorkloadSpec,
    hist: &WorkloadHist,
    gpu_s: &GpuProfile,
    gpu_l: &GpuProfile,
    b_short: f64,
    slo_ms: f64,
    max_gpus: u32,
) -> Option<Candidate> {
    EvalEngine::min_two_pool(w, hist, gpu_s, gpu_l, b_short, slo_ms, max_gpus)
}

/// Minimal homogeneous candidate.
pub fn min_homogeneous(
    w: &WorkloadSpec,
    hist: &WorkloadHist,
    gpu: &GpuProfile,
    slo_ms: f64,
    max_gpus: u32,
) -> Option<Candidate> {
    EvalEngine::min_homogeneous(w, hist, gpu, slo_ms, max_gpus)
}

/// Homogeneous fleet sized by the utilization cap only (ignoring the SLO)
/// — the paper's Table-1 "homogeneous baseline".
pub fn rho_cap_homogeneous(
    w: &WorkloadSpec,
    hist: &WorkloadHist,
    gpu: &GpuProfile,
    max_gpus: u32,
) -> Option<Candidate> {
    EvalEngine::rho_cap_homogeneous(w, hist, gpu, max_gpus)
}

/// DES-verify a candidate with the production LengthRouter; returns
/// (overall P99 TTFT, short P99, long P99, per-pool utilization).
pub fn verify_candidate(
    w: &WorkloadSpec,
    cand: &Candidate,
    opts: &ScenarioOpts,
) -> (f64, f64, f64, Vec<f64>) {
    let v = EvalEngine::standard().verify(w, cand, &opts.des(), f64::INFINITY);
    (v.p99_ttft_ms, v.p99_ttft_short_ms, v.p99_ttft_long_ms, v.utilization)
}

/// DES on an explicit pool layout + router.
pub fn simulate(
    w: &WorkloadSpec,
    pools: Vec<SimPool>,
    router: RoutingPolicy,
    opts: &ScenarioOpts,
) -> crate::des::metrics::DesResult {
    Simulator::new(w.clone(), pools, router, opts.des()).run()
}

pub fn check(ok: bool) -> &'static str {
    if ok {
        "yes"
    } else {
        "FAIL"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::catalog::GpuCatalog;
    use crate::queueing::mgc::analyze_pool;
    use crate::workload::spec::BuiltinTrace;

    #[test]
    fn min_two_pool_is_minimal_and_feasible() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 100.0);
        let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
        let gpu = GpuCatalog::standard().get("A100").unwrap().clone();
        let cand = min_two_pool(&w, &hist, &gpu, &gpu, 4096.0, 500.0, 256)
            .expect("feasible");
        // Feasible at (n_s, n_l)…
        let s = analyze_pool(&hist, 0.0, 4096.0, w.lambda_per_ms(),
                             &cand.short_spec());
        assert!(s.meets_slo(500.0));
        // …but not with one fewer short GPU (minimality), unless already 1.
        if cand.n_s > 1 {
            let mut smaller = cand.short_spec();
            smaller.n_gpus -= 1;
            assert!(!analyze_pool(&hist, 0.0, 4096.0, w.lambda_per_ms(),
                                  &smaller)
                .meets_slo(500.0));
        }
    }

    #[test]
    fn rho_cap_baseline_smaller_or_equal_to_slo_sized() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Lmsys, 100.0);
        let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
        let gpu = GpuCatalog::standard().get("A100").unwrap().clone();
        let cap = rho_cap_homogeneous(&w, &hist, &gpu, 256).unwrap();
        if let Some(slo) = min_homogeneous(&w, &hist, &gpu, 500.0, 256) {
            assert!(cap.n_s <= slo.n_s);
        }
    }

    #[test]
    fn verify_candidate_reports_pools() {
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, 50.0);
        let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
        let gpu = GpuCatalog::standard().get("H100").unwrap().clone();
        let cand = min_two_pool(&w, &hist, &gpu, &gpu, 2048.0, 500.0, 64)
            .unwrap();
        let opts = ScenarioOpts::fast();
        let (overall, short, long, util) = verify_candidate(&w, &cand, &opts);
        assert!(overall > 0.0 && short > 0.0 && long > 0.0);
        assert_eq!(util.len(), 2);
    }
}
