//! The `diurnal` scenario (report id 10): size to the mean or size to
//! the peak?
//!
//! A two-phase diurnal NHPP rate profile (off-peak / peak, repeating)
//! over the Azure trace lengths. The analytic Phase 1 sees only the
//! long-run mean rate, so the "mean-sized" fleet passes the stationary
//! check — and may even pass the *aggregate* DES P99 — while failing the
//! SLO in every peak window. Time-windowed SLO evaluation
//! ([`crate::des::metrics::WindowedStats`]) makes the failure visible,
//! and [`EvalEngine::size_to_peak`] finds the smallest fleet that meets
//! the SLO in **every** window. The table reports both fleets' costs:
//! the delta is the price of the peak.

use crate::des::engine::SimPool;
use crate::des::metrics::DesResult;
use crate::optimizer::engine::EvalEngine;
use crate::queueing::mgc::WorkloadHist;
use crate::router::RoutingPolicy;
use crate::scenarios::common::*;
use crate::scenarios::{Scenario, ScenarioSpec, Topology};
use crate::util::table::{dollars, millis, percent, Table};
use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

/// Off-peak arrival rate (req/s), first half of every period.
pub const LAMBDA_LO: f64 = 40.0;
/// Peak arrival rate (req/s), second half of every period.
pub const LAMBDA_HI: f64 = 200.0;
/// Diurnal period (ms): 10 s off-peak + 10 s peak.
pub const PERIOD_MS: f64 = 20_000.0;
/// SLO-evaluation window width (ms): four windows per period.
pub const WINDOW_MS: f64 = 5_000.0;
pub const SLO_MS: f64 = 500.0;

/// The diurnal workload: Azure lengths, two-phase cyclic NHPP arrivals.
pub fn workload() -> WorkloadSpec {
    WorkloadSpec::builtin(BuiltinTrace::Azure, 100.0).with_nhpp(
        vec![(0.0, LAMBDA_LO), (PERIOD_MS / 2.0, LAMBDA_HI)],
        PERIOD_MS,
    )
}

/// Registry entry for the diurnal size-to-peak scenario.
pub struct Diurnal;

impl Scenario for Diurnal {
    fn id(&self) -> &'static str {
        "diurnal"
    }

    fn name(&self) -> &'static str {
        "size-to-peak"
    }

    fn title(&self) -> &'static str {
        "Sizing for the mean fails the peak (windowed SLO)"
    }

    fn spec(&self) -> ScenarioSpec {
        ScenarioSpec {
            workloads: vec![("azure", (LAMBDA_LO + LAMBDA_HI) / 2.0)],
            gpus: vec!["H100"],
            thresholds: vec![],
            lambda_sweep: vec![LAMBDA_LO, LAMBDA_HI],
            slo_ms: SLO_MS,
            router: "Random",
            topology: Topology::SinglePool,
        }
    }

    fn run(&self, engine: &EvalEngine, opts: &ScenarioOpts) -> PuzzleReport {
        let gpu = engine.catalog.get("H100").unwrap().clone();
        let w = workload();
        let ctx = w.cdf.max_len();
        let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);
        let mut cfg = opts.des();
        if cfg.window_ms.is_none() {
            cfg.window_ms = Some(WINDOW_MS);
        }

        // Mean-sized: the stationary analytic fleet at the long-run mean
        // rate (what a planner blind to the profile would deploy).
        let n_mean = EvalEngine::min_homogeneous(
            &w, &hist, &gpu, SLO_MS, opts.max_gpus,
        )
        .map_or(1, |c| c.n_s);
        let mut r_mean = engine.simulate(
            &w,
            &[SimPool { gpu: gpu.clone(), n_gpus: n_mean as usize,
                        ctx_budget: ctx, batch_cap: None }],
            &RoutingPolicy::Random { n_pools: 1 },
            &cfg,
        );

        // Peak-sized: smallest fleet meeting the SLO in every window.
        // Degrade to an infeasibility report instead of panicking if the
        // GPU budget cannot cover the peak.
        let Some((n_peak, mut r_peak)) =
            engine.size_to_peak(&w, &gpu, SLO_MS, opts.max_gpus, &cfg)
        else {
            return PuzzleReport {
                id: 10,
                title: self.title().into(),
                tables: vec![],
                insight: format!(
                    "No H100 fleet within max_gpus = {} meets the \
                     {SLO_MS} ms SLO in every window at the {LAMBDA_HI} \
                     req/s peak; raise max_gpus to size this profile.",
                    opts.max_gpus
                ),
            };
        };

        let count_passing = |r: &mut DesResult| -> (usize, usize) {
            let ws = r.windows.as_mut().expect("windowed run");
            let total = ws.n_windows();
            let passing =
                (0..total).filter(|&i| ws.meets_slo(i, SLO_MS)).count();
            (passing, total)
        };
        let (pass_mean, total) = count_passing(&mut r_mean);
        let (pass_peak, _) = count_passing(&mut r_peak);

        let mut fleet = Table::new(&[
            "Config", "GPUs", "Cost/yr", "agg P99 TTFT", "windows OK",
            "all windows",
        ])
        .with_title(format!(
            "Diurnal Azure fleet (λ {LAMBDA_LO}→{LAMBDA_HI} req/s, \
             period {:.0} s, SLO {SLO_MS} ms)",
            PERIOD_MS / 1000.0
        ));
        for (label, n, r, pass) in [
            ("Mean-sized", n_mean, &mut r_mean, pass_mean),
            ("Peak-sized", n_peak, &mut r_peak, pass_peak),
        ] {
            fleet.row(&[
                label.to_string(),
                n.to_string(),
                dollars(gpu.cost_per_year() * n as f64),
                millis(r.overall.p99_ttft()),
                format!("{pass}/{total}"),
                check(pass == total).to_string(),
            ]);
        }

        // Side-by-side windowed P99 series: where exactly the mean-sized
        // fleet loses the SLO, and that the peak-sized one never does.
        let mut series = Table::new(&[
            "window", "arrivals", "mean P99", "mean att.", "mean SLO",
            "peak P99", "peak SLO",
        ])
        .with_title(format!(
            "Windowed P99 TTFT ({:.0} s windows; peaks occupy the second \
             half of each period)",
            WINDOW_MS / 1000.0
        ));
        {
            use crate::report::windows::{window_label, window_verdict};
            let wm = r_mean.windows.as_mut().expect("windowed run");
            let wp = r_peak.windows.as_mut().expect("windowed run");
            for i in 0..wm.n_windows().min(wp.n_windows()) {
                series.row(&[
                    window_label(wm, i),
                    wm.n_arrived(i).to_string(),
                    millis(wm.p99_ttft(i)),
                    percent(wm.attainment(i, SLO_MS)),
                    window_verdict(wm, i, SLO_MS),
                    millis(wp.p99_ttft(i)),
                    window_verdict(wp, i, SLO_MS),
                ]);
            }
        }

        PuzzleReport {
            id: 10,
            title: self.title().into(),
            tables: vec![fleet, series],
            insight: format!(
                "The mean-sized fleet ({n_mean} GPUs) satisfies the \
                 stationary analytic check at the long-run mean rate and \
                 meets the SLO in {pass_mean}/{total} windows — every \
                 miss is a peak window, where the queue it cannot drain \
                 blows P99 TTFT by orders of magnitude. Sizing to the \
                 peak ({n_peak} GPUs) costs {} more per year and meets \
                 the SLO in every window; windowed evaluation is what \
                 makes the difference visible at all.",
                dollars(gpu.cost_per_year()
                        * n_peak.saturating_sub(n_mean) as f64)
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenarios::default_engine;

    #[test]
    fn mean_sized_fails_a_peak_window_peak_sized_never_does() {
        let opts = ScenarioOpts::fast();
        let report = Diurnal.run(&default_engine(&opts), &opts);
        let fleet = report.tables[0].render();
        // Mean-sized row fails the all-windows check; peak-sized passes.
        let mean_row = fleet.lines().find(|l| l.contains("Mean-sized"))
            .unwrap();
        assert!(mean_row.contains("FAIL"), "{fleet}");
        let peak_row = fleet.lines().find(|l| l.contains("Peak-sized"))
            .unwrap();
        assert!(peak_row.contains("yes"), "{fleet}");

        // In the windowed series every row's final (peak) column is
        // "yes" and at least one mean column says FAIL.
        let series = report.tables[1].render();
        let mut mean_fails = 0;
        for line in series.lines().filter(|l| l.contains(") s")) {
            let cells: Vec<&str> =
                line.split('|').map(str::trim).collect();
            // cells[0] is empty (leading '|'); last non-empty is peak SLO.
            let peak_slo = cells[cells.len() - 2];
            assert_eq!(peak_slo, "yes", "{series}");
            if cells[5] == "FAIL" {
                mean_fails += 1;
            }
        }
        assert!(mean_fails >= 1, "{series}");
        assert!(report.insight.contains("peak"));
    }
}
