//! Puzzle 7 (§4.7, Table 8): when should I switch to disaggregated serving?
//!
//! DisaggFleetOptimizer sweep over prefill/decode GPU pairings (A100/H100)
//! on Azure at λ=100, against the aggregated baselines, with the two-stage
//! DES verifying the analytical TTFT. The per-configuration DES runs fan
//! out over the engine's worker threads (the two-stage `simulate_disagg`
//! owns its sampling, so this scenario uses the engine for parallelism
//! rather than the stream cache).

use crate::optimizer::disagg::{simulate_disagg, DisaggFleetOptimizer};
use crate::optimizer::engine::EvalEngine;
use crate::scenarios::common::*;
use crate::scenarios::{Scenario, ScenarioSpec, Topology};
use crate::util::table::{dollars, millis, Align, Table};
use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

pub const LAMBDA: f64 = 100.0;
pub const TTFT_SLO_MS: f64 = 500.0;
pub const TPOT_SLO_MS: f64 = 100.0;

/// Registry entry for the disaggregated-serving scenario.
pub struct DisaggServing;

impl Scenario for DisaggServing {
    fn id(&self) -> &'static str {
        "puzzle7"
    }

    fn name(&self) -> &'static str {
        "disagg"
    }

    fn title(&self) -> &'static str {
        "When should I switch to disaggregated serving?"
    }

    fn spec(&self) -> ScenarioSpec {
        ScenarioSpec {
            workloads: vec![("azure", LAMBDA)],
            gpus: vec!["A100", "H100"],
            thresholds: vec![],
            lambda_sweep: vec![],
            slo_ms: TTFT_SLO_MS,
            router: "prefill->decode pipeline",
            topology: Topology::Disaggregated,
        }
    }

    fn run(&self, engine: &EvalEngine, opts: &ScenarioOpts) -> PuzzleReport {
        let w = WorkloadSpec::builtin(BuiltinTrace::Azure, LAMBDA);
        let o = DisaggFleetOptimizer::new(engine.catalog.clone(),
                                          TTFT_SLO_MS, TPOT_SLO_MS);

        let mut t = Table::new(&["Config", "GPUs", "Cost/yr", "TTFT",
                                 "TTFT(DES)", "TPOT", "SLO"])
            .with_title(format!(
                "Disaggregated P/D configurations (Azure λ={LAMBDA}, TTFT \
                 SLO={TTFT_SLO_MS} ms, TPOT SLO={TPOT_SLO_MS} ms, \
                 KV-transfer BETA_TTFT=1.80)"
            ))
            .align(&[Align::Left, Align::Left, Align::Right, Align::Right,
                     Align::Right, Align::Right, Align::Right]);

        // Aggregated baselines first (paper's table shape).
        for name in ["A100", "H100"] {
            let gpu = engine.catalog.require(name).unwrap();
            if let Some((n, cost, ttft)) = o.aggregated_baseline(&w, gpu) {
                t.row(&[
                    format!("All-{name} aggregated"),
                    n.to_string(),
                    dollars(cost),
                    millis(ttft),
                    "-".into(),
                    "-".into(),
                    check(ttft <= TTFT_SLO_MS).to_string(),
                ]);
            }
        }
        // The analytic sweep is cheap; each config's two-stage DES
        // verification is the expensive part and runs in parallel.
        let sweep = o.sweep(&w);
        let des_rows = engine.par_map(sweep, |(cfg, a)| {
            let (des_ttft, _, _) =
                simulate_disagg(&w, cfg, opts.n_requests, opts.seed);
            (cfg.clone(), *a, des_ttft)
        });
        for (cfg, a, des_ttft) in des_rows {
            t.row(&[
                cfg.label(),
                (cfg.n_prefill + cfg.n_decode).to_string(),
                dollars(a.cost_yr),
                millis(a.ttft99_ms),
                millis(des_ttft),
                millis(a.tpot_ms),
                check(a.feasible).to_string(),
            ]);
        }

        PuzzleReport {
            id: 7,
            title: self.title().into(),
            tables: vec![t],
            insight: "The premium GPU earns its cost in decode, not prefill: \
                      H100 decode workers serve ~2x the requests of A100 per \
                      card, while a small prefill pool (1 H100 / <=3 A100) \
                      carries all prompts. Under the chunked-prefill service \
                      model the cost gap vs aggregated serving is narrower \
                      than the paper's testbed (see EXPERIMENTS.md T8); the \
                      TTFT penalty from the 1.8x KV transfer and the TPOT \
                      guarantee trade-off reproduce."
                .into(),
        }
    }
}

/// Legacy entry point (CLI `puzzle 7`, benches): registry + default engine.
pub fn run(opts: &ScenarioOpts) -> PuzzleReport {
    DisaggServing.run(&crate::scenarios::default_engine(opts), opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_contains_aggregated_and_disagg_rows() {
        let report = run(&ScenarioOpts::fast());
        let body = report.tables[0].render();
        assert!(body.contains("aggregated"), "{body}");
        assert!(body.contains("P + "), "{body}");
        // Best feasible disagg config decodes on H100.
        let first_disagg = body
            .lines()
            .find(|l| l.contains("P + ") && l.contains("yes"))
            .expect("a feasible disagg row");
        assert!(first_disagg.contains("H100D"), "{first_disagg}");
    }
}
