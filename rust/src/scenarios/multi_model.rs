//! Multi-model fleets via the ModelRouter (paper §3.4): a semantic
//! classifier assigns each request to one of N model-specific pools.
//!
//! Scenario: a gateway serving three model classes — a small/fast model
//! for simple queries (60%), the 70B chat model (30%), and a long-context
//! reasoning class (10%) — each with its own pool, GPU type, and context
//! budget. The planner question: does class isolation hold when one class
//! is heavy-tailed?

use crate::des::engine::{DesConfig, SimPool};
use crate::optimizer::engine::EvalEngine;
use crate::router::RoutingPolicy;
use crate::scenarios::common::{check, PuzzleReport, ScenarioOpts};
use crate::scenarios::{Scenario, ScenarioSpec, Topology};
use crate::util::table::{millis, Align, Table};
use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

/// Class mix: (name, probability, pool GPU, pool size, ctx budget).
pub fn classes() -> Vec<(&'static str, f64, &'static str, usize, f64)> {
    vec![
        ("simple (small model)", 0.60, "A10G", 10, 4096.0),
        ("chat 70B", 0.30, "A100", 6, 8192.0),
        ("long-context", 0.10, "H100", 8, 65536.0),
    ]
}

/// Run the multi-model DES through the given engine; returns
/// (class name, P99 TTFT, utilization, request count) per class.
pub fn evaluate_with(engine: &EvalEngine, lambda_rps: f64, opts: &ScenarioOpts)
    -> Vec<(String, f64, f64, usize)>
{
    let spec = classes();
    let pools: Vec<SimPool> = spec
        .iter()
        .map(|(_, _, gpu, n, ctx)| SimPool {
            gpu: engine.catalog.require(gpu).unwrap().clone(),
            n_gpus: *n,
            ctx_budget: *ctx,
            batch_cap: None,
        })
        .collect();
    let router = RoutingPolicy::Model { class_to_pool: vec![0, 1, 2] };
    // Lengths: use the LMSYS CDF truncated per class budget is overkill —
    // the class mix itself drives the story; lengths come from LMSYS.
    let w = WorkloadSpec::builtin(BuiltinTrace::Lmsys, lambda_rps)
        .truncated(65536.0)
        .unwrap();
    let cfg = DesConfig {
        n_requests: opts.n_requests,
        seed: opts.seed,
        class_probs: Some(spec.iter().map(|c| c.1).collect()),
        ..Default::default()
    };
    let mut r = engine.simulate(&w, &pools, &router, &cfg);
    spec.iter()
        .zip(r.per_pool.iter_mut())
        .map(|((name, ..), p)| {
            (name.to_string(), p.stats.ttft.p99(), p.utilization,
             p.stats.count)
        })
        .collect()
}

/// Evaluate with a default engine (legacy signature used by tests/CLI).
pub fn evaluate(lambda_rps: f64, opts: &ScenarioOpts)
    -> Vec<(String, f64, f64, usize)>
{
    evaluate_with(&crate::scenarios::default_engine(opts), lambda_rps, opts)
}

/// Registry entry for the multi-model fleet scenario.
pub struct MultiModelFleet;

impl Scenario for MultiModelFleet {
    fn id(&self) -> &'static str {
        "multimodel"
    }

    fn name(&self) -> &'static str {
        "multi-model"
    }

    fn title(&self) -> &'static str {
        "Multi-model fleets (ModelRouter)"
    }

    fn spec(&self) -> ScenarioSpec {
        ScenarioSpec {
            workloads: vec![("lmsys", 100.0)],
            gpus: vec!["A10G", "A100", "H100"],
            thresholds: vec![],
            lambda_sweep: vec![],
            slo_ms: 500.0,
            router: "ModelRouter",
            topology: Topology::MultiPool,
        }
    }

    fn run(&self, engine: &EvalEngine, opts: &ScenarioOpts) -> PuzzleReport {
        let rows = evaluate_with(engine, 100.0, opts);
        let mut t = Table::new(&["Class", "requests", "P99 TTFT", "util",
                                 "SLO 500ms"])
            .with_title("Multi-model fleet via ModelRouter (λ=100 req/s, \
                         3 classes, LMSYS lengths)")
            .align(&[Align::Left, Align::Right, Align::Right, Align::Right,
                     Align::Right]);
        for (name, p99, util, count) in &rows {
            t.row(&[
                name.clone(),
                count.to_string(),
                millis(*p99),
                format!("{:.0}%", util * 100.0),
                check(*p99 <= 500.0).to_string(),
            ]);
        }
        PuzzleReport {
            id: 9,
            title: self.title().into(),
            tables: vec![t],
            insight: "Class isolation via the semantic router keeps each \
                      model's latency independent: the heavy long-context \
                      class cannot head-of-line block the small-model pool."
                .into(),
        }
    }
}

/// Legacy entry point (CLI `multimodel`): registry + default engine.
pub fn run(opts: &ScenarioOpts) -> PuzzleReport {
    MultiModelFleet.run(&crate::scenarios::default_engine(opts), opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classes_are_isolated_and_mix_respected() {
        let opts = ScenarioOpts { n_requests: 9_000, ..ScenarioOpts::fast() };
        let rows = evaluate(100.0, &opts);
        assert_eq!(rows.len(), 3);
        let total: usize = rows.iter().map(|r| r.3).sum();
        assert_eq!(total, 9_000);
        // Mix ~ 60/30/10.
        let frac0 = rows[0].3 as f64 / total as f64;
        assert!((frac0 - 0.6).abs() < 0.03, "frac0 = {frac0}");
        // The simple-class pool stays fast regardless of the heavy class.
        assert!(rows[0].1 < 500.0, "simple-class P99 = {}", rows[0].1);
    }
}
