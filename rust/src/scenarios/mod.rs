//! The scenario registry: the paper's case studies (§4, Puzzles 1–8) and
//! extensions as declarative, engine-driven scenarios.
//!
//! Every scenario implements the [`Scenario`] trait: an `id`/`name` pair
//! for CLI lookup, a declarative [`ScenarioSpec`] (workloads, GPUs, λ
//! sweep, SLO, router, topology) for listings and docs, and a `run` that
//! regenerates the corresponding paper table through one shared
//! [`EvalEngine`] — so every scenario inherits the engine's parallel
//! minimal-fleet sweeps and cached request streams instead of hand-wiring
//! its own plumbing.
//!
//! The CLI (`fleet-sim scenarios` / `fleet-sim run --scenario <id|name>`,
//! plus the legacy `puzzle N` / `reproduce-all`), the bench harnesses
//! (`rust/benches/tableN_*.rs`), and `examples/reproduce_all.rs` all call
//! through here so EXPERIMENTS.md is regenerated from one code path.
//! Adding a scenario means writing a spec + a short `run` and pushing one
//! `Box::new(...)` into [`registry`].

pub mod common;
pub mod diurnal;
pub mod kv_stability;
pub mod multi_model;
pub mod n_plus_k;
pub mod puzzle1_split;
pub mod puzzle2_agent;
pub mod puzzle3_gpu_type;
pub mod puzzle4_steps;
pub mod puzzle5_routers;
pub mod puzzle6_mixed;
pub mod puzzle7_disagg;
pub mod puzzle8_gridflex;
pub mod retry_storm;

pub use crate::optimizer::engine::EvalEngine;
pub use common::{PuzzleReport, ScenarioOpts};

/// Pool topology a scenario exercises (for listings and docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    /// One homogeneous pool.
    SinglePool,
    /// Length-split short/long pools (the paper's core design).
    TwoPool,
    /// Two pools with different GPU types per pool.
    MixedTwoPool,
    /// Separate prefill and decode pools (DistServe-style).
    Disaggregated,
    /// N class-specific pools behind the ModelRouter.
    MultiPool,
}

impl Topology {
    pub fn name(self) -> &'static str {
        match self {
            Topology::SinglePool => "single-pool",
            Topology::TwoPool => "two-pool",
            Topology::MixedTwoPool => "mixed two-pool",
            Topology::Disaggregated => "prefill/decode",
            Topology::MultiPool => "multi-pool",
        }
    }
}

/// Declarative description of a scenario: what it evaluates, independent
/// of how the engine runs it. Shown by `fleet-sim scenarios`.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    /// Workload traces swept, as `(builtin trace name, λ req/s)`.
    pub workloads: Vec<(&'static str, f64)>,
    /// GPU types involved.
    pub gpus: Vec<&'static str>,
    /// Split thresholds swept (empty when the topology is fixed).
    pub thresholds: Vec<f64>,
    /// Arrival-rate sweep (what-if scenarios; empty otherwise).
    pub lambda_sweep: Vec<f64>,
    /// P99 TTFT SLO in ms.
    pub slo_ms: f64,
    /// Router used in DES verification.
    pub router: &'static str,
    pub topology: Topology,
}

impl ScenarioSpec {
    /// Compact one-line summary for the `scenarios` listing.
    pub fn summary(&self) -> String {
        let wl: Vec<String> = self
            .workloads
            .iter()
            .map(|(t, l)| format!("{t}@{l:.0}rps"))
            .collect();
        format!(
            "{} | {} | SLO {:.0} ms | {} | {}",
            wl.join(","),
            self.gpus.join("/"),
            self.slo_ms,
            self.router,
            self.topology.name()
        )
    }
}

/// A registered scenario. `run` regenerates the paper table(s) through
/// the shared evaluation engine.
pub trait Scenario: Sync {
    /// Stable CLI id (`puzzle1` … `puzzle8`, `multimodel`).
    fn id(&self) -> &'static str;
    /// Human-friendly CLI alias (`split-threshold`, `gridflex`, …).
    fn name(&self) -> &'static str;
    /// Report title.
    fn title(&self) -> &'static str;
    fn spec(&self) -> ScenarioSpec;
    fn run(&self, engine: &EvalEngine, opts: &ScenarioOpts) -> PuzzleReport;
}

/// All built-in scenarios, in paper order.
pub fn registry() -> Vec<Box<dyn Scenario>> {
    vec![
        Box::new(puzzle1_split::SplitThreshold),
        Box::new(puzzle2_agent::AgentSlo),
        Box::new(puzzle3_gpu_type::GpuTypeChoice),
        Box::new(puzzle4_steps::StepThresholds),
        Box::new(puzzle5_routers::RouterComparison),
        Box::new(puzzle6_mixed::MixedGpuTypes),
        Box::new(puzzle7_disagg::DisaggServing),
        Box::new(puzzle8_gridflex::GridFlexibility),
        Box::new(multi_model::MultiModelFleet),
        Box::new(diurnal::Diurnal),
        Box::new(n_plus_k::NPlusK),
        Box::new(retry_storm::RetryStorm),
        Box::new(kv_stability::KvStability),
    ]
}

/// Look a scenario up by id or name (case-insensitive).
pub fn find(key: &str) -> Option<Box<dyn Scenario>> {
    let k = key.trim();
    registry().into_iter().find(|s| {
        s.id().eq_ignore_ascii_case(k) || s.name().eq_ignore_ascii_case(k)
    })
}

/// Engine matching the options' thread budget (native backend, standard
/// catalog).
pub fn default_engine(opts: &ScenarioOpts) -> EvalEngine {
    EvalEngine::standard().with_threads(opts.threads)
}

/// Run puzzle `n` (1..=8) through the registry.
pub fn run(n: usize, opts: &ScenarioOpts) -> anyhow::Result<PuzzleReport> {
    anyhow::ensure!((1..=8).contains(&n), "no puzzle {n} (1..=8)");
    let s = find(&format!("puzzle{n}")).expect("registry covers puzzles 1..=8");
    Ok(s.run(&default_engine(opts), opts))
}

/// All puzzles in order, sharing one engine (and its stream cache).
pub fn run_all(opts: &ScenarioOpts) -> Vec<PuzzleReport> {
    let engine = default_engine(opts);
    (1..=8)
        .map(|n| {
            find(&format!("puzzle{n}"))
                .expect("1..=8 valid")
                .run(&engine, opts)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all_scenarios_with_unique_keys() {
        let reg = registry();
        assert_eq!(reg.len(), 13);
        let mut ids: Vec<&str> = reg.iter().map(|s| s.id()).collect();
        let mut names: Vec<&str> = reg.iter().map(|s| s.name()).collect();
        ids.sort();
        ids.dedup();
        names.sort();
        names.dedup();
        assert_eq!(ids.len(), 13, "duplicate scenario ids");
        assert_eq!(names.len(), 13, "duplicate scenario names");
        for n in 1..=8 {
            assert!(find(&format!("puzzle{n}")).is_some());
        }
        assert!(find("diurnal").is_some());
        assert_eq!(find("size-to-peak").unwrap().id(), "diurnal");
        assert!(find("n_plus_k").is_some());
        assert_eq!(find("n-plus-k").unwrap().id(), "n_plus_k");
        assert!(find("retry_storm").is_some());
        assert_eq!(find("retry-storm").unwrap().id(), "retry_storm");
        assert!(find("kv_stability").is_some());
        assert_eq!(find("kv-stability").unwrap().id(), "kv_stability");
    }

    #[test]
    fn find_matches_id_and_name_case_insensitively() {
        assert_eq!(find("PUZZLE3").unwrap().id(), "puzzle3");
        assert_eq!(find("gpu-type").unwrap().id(), "puzzle3");
        assert_eq!(find("multimodel").unwrap().name(), "multi-model");
        assert!(find("puzzle99").is_none());
    }

    #[test]
    fn specs_are_well_formed() {
        for s in registry() {
            let spec = s.spec();
            assert!(!spec.workloads.is_empty(), "{}", s.id());
            assert!(!spec.gpus.is_empty(), "{}", s.id());
            assert!(spec.slo_ms > 0.0, "{}", s.id());
            assert!(!spec.summary().is_empty());
        }
    }

    #[test]
    fn run_rejects_out_of_range() {
        assert!(run(0, &ScenarioOpts::fast()).is_err());
        assert!(run(9, &ScenarioOpts::fast()).is_err());
    }
}
