//! The paper's case studies (§4, Puzzles 1–8) as reproducible scenarios.
//!
//! Each puzzle module exposes `run(&ScenarioOpts) -> PuzzleReport`
//! regenerating the corresponding paper table; the CLI (`fleet-sim puzzle
//! N`), the bench harnesses (`rust/benches/tableN_*.rs`), and
//! `examples/reproduce_all.rs` all call through here so EXPERIMENTS.md is
//! regenerated from one code path.

pub mod common;
pub mod multi_model;
pub mod puzzle1_split;
pub mod puzzle2_agent;
pub mod puzzle3_gpu_type;
pub mod puzzle4_steps;
pub mod puzzle5_routers;
pub mod puzzle6_mixed;
pub mod puzzle7_disagg;
pub mod puzzle8_gridflex;

pub use common::{PuzzleReport, ScenarioOpts};

/// Run puzzle `n` (1..=8).
pub fn run(n: usize, opts: &ScenarioOpts) -> anyhow::Result<PuzzleReport> {
    Ok(match n {
        1 => puzzle1_split::run(opts),
        2 => puzzle2_agent::run(opts),
        3 => puzzle3_gpu_type::run(opts),
        4 => puzzle4_steps::run(opts),
        5 => puzzle5_routers::run(opts),
        6 => puzzle6_mixed::run(opts),
        7 => puzzle7_disagg::run(opts),
        8 => puzzle8_gridflex::run(opts),
        other => anyhow::bail!("no puzzle {other} (1..=8)"),
    })
}

/// All puzzles in order.
pub fn run_all(opts: &ScenarioOpts) -> Vec<PuzzleReport> {
    (1..=8).map(|n| run(n, opts).expect("1..=8 valid")).collect()
}
