//! Puzzle 2 (§4.2, Table 2): why is my agent fleet failing SLO?
//!
//! A homogeneous H100 fleet serving the agent trace reads low utilization
//! and near-zero queue wait, yet fails its 1 s P99 TTFT SLO — and doubling
//! the fleet does not fix it. The failure mode (giant-prompt service) is
//! invisible to Erlang-C; the two-pool design isolates and protects the
//! short, interactive traffic. The three homogeneous fleet sizes simulate
//! in parallel on one cached request stream.

use crate::des::engine::SimPool;
use crate::optimizer::engine::EvalEngine;
use crate::queueing::mgc::{analyze_pool, PoolSpec, WorkloadHist};
use crate::router::RoutingPolicy;
use crate::scenarios::common::*;
use crate::scenarios::{Scenario, ScenarioSpec, Topology};
use crate::util::table::{dollars, millis, Table};
use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

pub const LAMBDA: f64 = 20.0;
pub const SLO_MS: f64 = 1000.0;

/// Registry entry for the agent-fleet SLO investigation.
pub struct AgentSlo;

impl Scenario for AgentSlo {
    fn id(&self) -> &'static str {
        "puzzle2"
    }

    fn name(&self) -> &'static str {
        "agent-slo"
    }

    fn title(&self) -> &'static str {
        "Why is my agent fleet failing SLO?"
    }

    fn spec(&self) -> ScenarioSpec {
        ScenarioSpec {
            workloads: vec![("agent", LAMBDA)],
            gpus: vec!["H100"],
            thresholds: vec![4096.0],
            lambda_sweep: vec![],
            slo_ms: SLO_MS,
            router: "LengthRouter",
            topology: Topology::TwoPool,
        }
    }

    fn run(&self, engine: &EvalEngine, opts: &ScenarioOpts) -> PuzzleReport {
        let gpu = engine.catalog.get("H100").unwrap().clone();
        let w = WorkloadSpec::builtin(BuiltinTrace::Agent, LAMBDA);
        let ctx = w.cdf.max_len();
        let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);

        let mut t = Table::new(&["Config", "GPUs", "Cost/yr", "Util",
                                 "Wait99", "Erlang W99", "P99 TTFT", "SLO"])
            .with_title(format!(
                "Agent fleet SLO analysis (λ={LAMBDA} req/s, H100, \
                 SLO={SLO_MS} ms)"
            ));

        // The three homogeneous fleet sizes are independent simulations.
        let homo_rows = engine.par_map(vec![40usize, 64, 128], |&n| {
            let mut r = engine.simulate(
                &w,
                &[SimPool { gpu: gpu.clone(), n_gpus: n, ctx_budget: ctx,
                            batch_cap: None }],
                &RoutingPolicy::Random { n_pools: 1 },
                &opts.des(),
            );
            let a = analyze_pool(&hist, 0.0, 1e12, w.lambda_per_ms(),
                                 &PoolSpec { gpu: gpu.clone(), n_gpus: n,
                                             ctx_budget: ctx });
            let p99 = r.overall.p99_ttft();
            (n, r.per_pool[0].utilization, r.overall.wait.p99(), a.w99_ms, p99)
        });
        for (n, util, wait99, erlang_w99, p99) in homo_rows {
            t.row(&[
                format!("Homo {}K ctx", (ctx / 1024.0) as u64),
                n.to_string(),
                dollars(gpu.cost_per_year() * n as f64),
                format!("{:.0}%", util * 100.0),
                millis(wait99),
                millis(erlang_w99),
                millis(p99),
                check(p99 <= SLO_MS).to_string(),
            ]);
        }

        // Two-pool: short pool isolated at 4K.
        let (n_s, n_l) = (4usize, 60usize);
        let pools = vec![
            SimPool { gpu: gpu.clone(), n_gpus: n_s, ctx_budget: 4096.0,
                      batch_cap: None },
            SimPool { gpu: gpu.clone(), n_gpus: n_l, ctx_budget: ctx,
                      batch_cap: None },
        ];
        let mut r = engine.simulate(
            &w, &pools, &RoutingPolicy::Length { b_short: 4096.0 },
            &opts.des());
        let short_p99 = r.per_pool[0].stats.ttft.p99();
        let long_p99 = r.per_pool[1].stats.ttft.p99();
        t.row(&[
            format!("Two-pool 4K/{}K", (ctx / 1024.0) as u64),
            (n_s + n_l).to_string(),
            dollars(gpu.cost_per_year() * (n_s + n_l) as f64),
            format!("{:.0}%", r.per_pool[1].utilization * 100.0),
            millis(r.overall.wait.p99()),
            "-".into(),
            format!("{} / {}", millis(short_p99), millis(long_p99)),
            check(short_p99 <= SLO_MS).to_string(),
        ]);

        PuzzleReport {
            id: 2,
            title: self.title().into(),
            tables: vec![t],
            insight: "For agent workloads the analytical queue model reads \
                      healthy (near-zero W99 at <45% utilization) while DES \
                      measures P99 TTFT above the SLO — the tail is service, \
                      not queueing, so adding GPUs does not help. Splitting \
                      isolates short requests (P99 in the tens of ms)."
                .into(),
        }
    }
}

/// Legacy entry point (CLI `puzzle 2`, benches): registry + default engine.
pub fn run(opts: &ScenarioOpts) -> PuzzleReport {
    AgentSlo.run(&crate::scenarios::default_engine(opts), opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_fails_two_pool_protects_short() {
        let report = run(&ScenarioOpts::fast());
        let body = report.tables[0].render();
        // At least one homo row FAILs while the two-pool row passes.
        assert!(body.contains("FAIL"), "{body}");
        let last = body.lines().rev().nth(1).unwrap();
        assert!(last.contains("yes"), "{body}");
    }
}
