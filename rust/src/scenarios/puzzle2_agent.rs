//! Puzzle 2 (§4.2, Table 2): why is my agent fleet failing SLO?
//!
//! A homogeneous H100 fleet serving the agent trace reads low utilization
//! and near-zero queue wait, yet fails its 1 s P99 TTFT SLO — and doubling
//! the fleet does not fix it. The failure mode (giant-prompt service) is
//! invisible to Erlang-C; the two-pool design isolates and protects the
//! short, interactive traffic.

use crate::des::engine::SimPool;
use crate::gpu::catalog::GpuCatalog;
use crate::queueing::mgc::{analyze_pool, PoolSpec, WorkloadHist};
use crate::router::RoutingPolicy;
use crate::scenarios::common::*;
use crate::util::table::{dollars, millis, Table};
use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

pub const LAMBDA: f64 = 20.0;
pub const SLO_MS: f64 = 1000.0;

pub fn run(opts: &ScenarioOpts) -> PuzzleReport {
    let cat = GpuCatalog::standard();
    let gpu = cat.get("H100").unwrap().clone();
    let w = WorkloadSpec::builtin(BuiltinTrace::Agent, LAMBDA);
    let ctx = w.cdf.max_len();
    let hist = WorkloadHist::from_cdf(&w.cdf, w.input_fraction);

    let mut t = Table::new(&["Config", "GPUs", "Cost/yr", "Util", "Wait99",
                             "Erlang W99", "P99 TTFT", "SLO"])
        .with_title(format!(
            "Agent fleet SLO analysis (λ={LAMBDA} req/s, H100, \
             SLO={SLO_MS} ms)"
        ));

    for n in [40usize, 64, 128] {
        let r = simulate(
            &w,
            vec![SimPool { gpu: gpu.clone(), n_gpus: n, ctx_budget: ctx,
                           batch_cap: None }],
            RoutingPolicy::Random { n_pools: 1 },
            opts,
        );
        let mut stats = r.overall.clone();
        let a = analyze_pool(&hist, 0.0, 1e12, w.lambda_per_ms(),
                             &PoolSpec { gpu: gpu.clone(), n_gpus: n,
                                         ctx_budget: ctx });
        let p99 = stats.p99_ttft();
        t.row(&[
            format!("Homo {}K ctx", (ctx / 1024.0) as u64),
            n.to_string(),
            dollars(gpu.cost_per_year() * n as f64),
            format!("{:.0}%", r.per_pool[0].utilization * 100.0),
            millis(stats.wait.p99()),
            millis(a.w99_ms),
            millis(p99),
            check(p99 <= SLO_MS).to_string(),
        ]);
    }

    // Two-pool: short pool isolated at 4K.
    let (n_s, n_l) = (4usize, 60usize);
    let pools = vec![
        SimPool { gpu: gpu.clone(), n_gpus: n_s, ctx_budget: 4096.0,
                  batch_cap: None },
        SimPool { gpu: gpu.clone(), n_gpus: n_l, ctx_budget: ctx,
                  batch_cap: None },
    ];
    let mut r = simulate(&w, pools, RoutingPolicy::Length { b_short: 4096.0 },
                         opts);
    let short_p99 = r.per_pool[0].stats.ttft.p99();
    let long_p99 = r.per_pool[1].stats.ttft.p99();
    t.row(&[
        format!("Two-pool 4K/{}K", (ctx / 1024.0) as u64),
        (n_s + n_l).to_string(),
        dollars(gpu.cost_per_year() * (n_s + n_l) as f64),
        format!("{:.0}%", r.per_pool[1].utilization * 100.0),
        millis(r.overall.wait.p99()),
        "-".into(),
        format!("{} / {}", millis(short_p99), millis(long_p99)),
        check(short_p99 <= SLO_MS).to_string(),
    ]);

    PuzzleReport {
        id: 2,
        title: "Why is my agent fleet failing SLO?".into(),
        tables: vec![t],
        insight: "For agent workloads the analytical queue model reads \
                  healthy (near-zero W99 at <45% utilization) while DES \
                  measures P99 TTFT above the SLO — the tail is service, \
                  not queueing, so adding GPUs does not help. Splitting \
                  isolates short requests (P99 in the tens of ms)."
            .into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_fails_two_pool_protects_short() {
        let report = run(&ScenarioOpts::fast());
        let body = report.tables[0].render();
        // At least one homo row FAILs while the two-pool row passes.
        assert!(body.contains("FAIL"), "{body}");
        let last = body.lines().rev().nth(1).unwrap();
        assert!(last.contains("yes"), "{body}");
    }
}
