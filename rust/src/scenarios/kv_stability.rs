//! The `kv_stability` scenario (report id 13): when is a fleet that
//! passes its compute SLO still unstable, because the binding resource
//! is KV-cache memory?
//!
//! The M/G/c analytic model (and the memory-less DES it is verified
//! against) prices compute only: a request holds a batch slot for its
//! service time, and capacity planning reduces to slots and iteration
//! latency. But on heavy-tailed context workloads the scarcer resource
//! is KV-cache HBM — every resident request pins its prompt tokens and
//! one token-slot per generated token until it completes ([`crate::
//! des::memory`]). The scenario sizes the smallest compute-feasible
//! fleet on the LMSYS trace, then replays the same fleet under three
//! memory regimes:
//!
//! * **A — stable**: a loose memory model (capacity far above the
//!   working set). Zero preemptions; the run is the compute baseline
//!   and every window passes — memory exists but never binds.
//! * **B — preemption thrash**: a tight model with `evict-recompute`.
//!   Optimistic admission overcommits, occupancy crosses capacity,
//!   victims lose their KV state and re-prefill from scratch — wasted
//!   work that re-inflates occupancy, the memory analogue of a retry
//!   storm ([`crate::scenarios::retry_storm`]).
//! * **C — admission-stable**: the same tight model with the blocking
//!   `none` policy: admission reserves peak occupancy up front, so the
//!   pool never overcommits and never preempts. Latency moves into the
//!   queue, where it is visible to sizing, instead of into eviction
//!   churn.
//!
//! The punchline is the divergence: [`EvalEngine::size_for_memory`]
//! re-runs the sizing walk with the memory model attached and lands on
//! a fleet at least as large as the compute answer — the gap is the
//! capacity the analytic model cannot see.

use crate::des::engine::SimPool;
use crate::des::memory::{MemoryConfig, MemorySpec, PolicyKind};
use crate::des::metrics::DesResult;
use crate::optimizer::engine::EvalEngine;
use crate::router::RoutingPolicy;
use crate::scenarios::common::*;
use crate::scenarios::{Scenario, ScenarioSpec, Topology};
use crate::util::table::Table;
use crate::workload::spec::{BuiltinTrace, WorkloadSpec};

/// Arrival rate (req/s) on the truncated LMSYS trace.
pub const LAMBDA_RPS: f64 = 60.0;
pub const SLO_MS: f64 = 500.0;
pub const WINDOW_MS: f64 = 5_000.0;
/// Token cap on the LMSYS CDF: keeps the per-request KV footprint
/// within one A100's tight-regime capacity (capacity must cover the
/// largest admissible request; see [`tight_memory`]).
pub const MAX_CTX: f64 = 8_192.0;
/// Floor on the request count: enough horizon for several SLO windows
/// even under `--fast`.
pub const MIN_REQUESTS: usize = 3_000;

/// LMSYS trace truncated to [`MAX_CTX`] tokens at [`LAMBDA_RPS`].
pub fn workload() -> WorkloadSpec {
    WorkloadSpec::builtin(BuiltinTrace::Lmsys, LAMBDA_RPS)
        .truncated(MAX_CTX)
        .expect("lmsys CDF truncates at 8192 tokens")
}

/// Regime A: memory modeled but never binding — ~7M token-slots per
/// GPU, three orders of magnitude above the working set.
pub fn loose_memory() -> MemoryConfig {
    MemoryConfig {
        spec: MemorySpec {
            hbm_gb: None,
            weights_gb: 10.0,
            bytes_per_token: 1e4,
        },
        policy: PolicyKind::EvictRecompute,
        swap_out_ms: 0.0,
        swap_in_ms: 0.0,
    }
}

/// Regimes B and C: 10 GB of KV HBM at 1 MB per token — 10,000
/// token-slots per A100, barely above the [`MAX_CTX`] footprint of the
/// largest admissible request, so concurrent decodes fight for cache.
pub fn tight_memory(policy: PolicyKind) -> MemoryConfig {
    MemoryConfig {
        spec: MemorySpec {
            hbm_gb: None,
            weights_gb: 70.0,
            bytes_per_token: 1e6,
        },
        policy,
        swap_out_ms: 2.0,
        swap_in_ms: 4.0,
    }
}

/// The three regime runs on the minimal compute-feasible fleet, plus
/// the memory-aware sizing answer; None if no fleet within
/// `opts.max_gpus` passes every window compute-only.
pub struct KvRuns {
    /// Smallest fleet passing every window with no memory model.
    pub n_compute: u32,
    /// Smallest fleet passing every window with [`tight_memory`]
    /// attached (None if not feasible within `max_gpus`).
    pub n_mem: Option<u32>,
    /// Regime A: loose memory on the compute-sized fleet.
    pub stable: DesResult,
    /// Regime B: tight memory + evict-recompute on the same fleet.
    pub thrash: DesResult,
    /// Regime C: tight memory + blocking admission on the same fleet.
    pub blocked: DesResult,
}

/// Size the smallest compute-feasible fleet, replay the three memory
/// regimes on exactly that fleet, then re-size memory-aware.
pub fn run_regimes(
    engine: &EvalEngine,
    opts: &ScenarioOpts,
) -> Option<KvRuns> {
    let w = workload();
    let mut cfg = opts.des();
    cfg.n_requests = opts.n_requests.max(MIN_REQUESTS);
    if cfg.window_ms.is_none() {
        cfg.window_ms = Some(WINDOW_MS);
    }
    let gpu = engine.catalog.get("A100").unwrap().clone();
    let (n_compute, _) =
        engine.size_to_peak(&w, &gpu, SLO_MS, opts.max_gpus, &cfg)?;
    let pools = [SimPool {
        gpu: gpu.clone(),
        n_gpus: n_compute as usize,
        ctx_budget: w.cdf.max_len(),
        batch_cap: None,
    }];
    let router = RoutingPolicy::Random { n_pools: 1 };
    let loose = loose_memory();
    let evict = tight_memory(PolicyKind::EvictRecompute);
    let block = tight_memory(PolicyKind::None);
    let stable = engine
        .simulate_with(&w, &pools, &router, &cfg, None, None, Some(&loose));
    let thrash = engine
        .simulate_with(&w, &pools, &router, &cfg, None, None, Some(&evict));
    let blocked = engine
        .simulate_with(&w, &pools, &router, &cfg, None, None, Some(&block));
    let n_mem = engine
        .size_for_memory(&w, &gpu, SLO_MS, opts.max_gpus, &cfg, &evict)
        .map(|(n, _)| n);
    Some(KvRuns { n_compute, n_mem, stable, thrash, blocked })
}

fn failed_windows(r: &mut DesResult, slo_ms: f64) -> usize {
    let w = r.windows.as_mut().expect("windowed run");
    (0..w.n_windows()).filter(|&i| !w.meets_slo(i, slo_ms)).count()
}

/// Registry entry for the KV-cache memory-stability scenario.
pub struct KvStability;

impl Scenario for KvStability {
    fn id(&self) -> &'static str {
        "kv_stability"
    }

    fn name(&self) -> &'static str {
        "kv-stability"
    }

    fn title(&self) -> &'static str {
        "KV-cache stability: admission blocking vs preemption thrash"
    }

    fn spec(&self) -> ScenarioSpec {
        ScenarioSpec {
            workloads: vec![("lmsys", LAMBDA_RPS)],
            gpus: vec!["A100"],
            thresholds: vec![],
            lambda_sweep: vec![],
            slo_ms: SLO_MS,
            router: "Random",
            topology: Topology::SinglePool,
        }
    }

    fn run(&self, engine: &EvalEngine, opts: &ScenarioOpts) -> PuzzleReport {
        let Some(mut runs) = run_regimes(engine, opts) else {
            return PuzzleReport {
                id: 13,
                title: self.title().into(),
                tables: vec![],
                insight: format!(
                    "No A100 fleet within max_gpus = {} passes every \
                     window at {LAMBDA_RPS} req/s; raise max_gpus to \
                     stage the regimes.",
                    opts.max_gpus
                ),
            };
        };
        let mut table = Table::new(&[
            "regime", "served", "preempted", "stall ms", "kv peak",
            "kv mean", "p99 ttft ms", "windows failed",
        ])
        .with_title(format!(
            "KV-cache regimes on {} A100s (lmsys@{LAMBDA_RPS:.0}rps <= \
             {MAX_CTX:.0} tokens, SLO {SLO_MS:.0} ms, {WINDOW_MS:.0} ms \
             windows)",
            runs.n_compute,
        ));
        for (label, r) in [
            ("A: loose memory (stable)", &mut runs.stable),
            ("B: tight + evict-recompute", &mut runs.thrash),
            ("C: tight + admission block", &mut runs.blocked),
        ] {
            let failed = failed_windows(r, SLO_MS);
            table.row(&[
                label.to_string(),
                r.overall.count.to_string(),
                r.n_preempted.to_string(),
                format!("{:.0}", r.preempt_stall_ms),
                format!("{:.3}", r.kv_peak_util),
                format!("{:.3}", r.kv_mean_util),
                format!("{:.0}", r.overall.p99_ttft()),
                failed.to_string(),
            ]);
        }
        let sizing = match runs.n_mem {
            Some(nm) => format!(
                "re-sizing with the memory model attached lands on \
                 {nm} GPUs vs {} compute-only — the gap is the \
                 capacity M/G/c cannot see",
                runs.n_compute
            ),
            None => format!(
                "no fleet within max_gpus passes every window with the \
                 tight memory model — the compute answer ({} GPUs) was \
                 never the real capacity",
                runs.n_compute
            ),
        };
        PuzzleReport {
            id: 13,
            title: self.title().into(),
            tables: vec![table],
            insight: format!(
                "The same compute-sized fleet, three memory regimes: \
                 loose memory reproduces the compute baseline (0 \
                 preemptions); tight memory with eviction preempts {} \
                 times and burns {:.0} ms of progress re-prefilling — \
                 occupancy-driven wasted work, the memory analogue of \
                 a retry storm; blocking admission holds occupancy at \
                 or under capacity (peak {:.3}) with zero preemptions, \
                 trading churn for visible queueing. And {sizing}.",
                runs.thrash.n_preempted,
                runs.thrash.preempt_stall_ms,
                runs.blocked.kv_peak_util,
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenarios::default_engine;

    #[test]
    fn kv_stability_shows_three_regimes() {
        let opts = ScenarioOpts::fast();
        let engine = default_engine(&opts);
        let mut runs = run_regimes(&engine, &opts).expect("feasible fleet");
        let n_req = opts.n_requests.max(MIN_REQUESTS);

        // Regime A: memory modeled, never binding. The ledger runs (a
        // nonzero peak) but nothing is preempted and every window
        // passes, exactly like the compute-only baseline.
        assert_eq!(runs.stable.n_preempted, 0);
        assert_eq!(runs.stable.preempt_stall_ms, 0.0);
        assert!(runs.stable.meets_slo_in_every_window(SLO_MS));
        assert!(runs.stable.kv_peak_util > 0.0);
        assert!(runs.stable.kv_peak_util < 0.5,
                "loose pool must not bind, got {}",
                runs.stable.kv_peak_util);
        assert_eq!(runs.stable.overall.count + runs.stable.n_unserved,
                   n_req, "conservation (A)");

        // Regime B: tight memory + eviction thrashes — victims lose
        // their KV state, re-prefill, and the tail inflates.
        assert!(runs.thrash.n_preempted > 0, "tight memory must preempt");
        assert!(runs.thrash.preempt_stall_ms > 0.0);
        assert!(runs.thrash.kv_peak_util > 0.5,
                "eviction fires only near capacity, got {}",
                runs.thrash.kv_peak_util);
        assert!(runs.thrash.overall.p99_ttft()
                    > runs.stable.overall.p99_ttft(),
                "preemption churn must inflate the served tail");
        assert_eq!(runs.thrash.overall.count + runs.thrash.n_unserved,
                   n_req, "conservation (B)");

        // Regime C: blocking admission never overcommits — zero
        // preemptions and occupancy capped by the reservation ledger.
        assert_eq!(runs.blocked.n_preempted, 0);
        assert_eq!(runs.blocked.preempt_stall_ms, 0.0);
        assert!(runs.blocked.kv_peak_util <= 1.0 + 1e-12,
                "reservations must cap occupancy, got {}",
                runs.blocked.kv_peak_util);
        assert_eq!(runs.blocked.overall.count + runs.blocked.n_unserved,
                   n_req, "conservation (C)");

        // The divergence: memory-aware sizing never under-sizes the
        // compute answer.
        if let Some(nm) = runs.n_mem {
            assert!(nm >= runs.n_compute,
                    "memory-aware {nm} < compute {}", runs.n_compute);
        }

        // The report renders one row per regime.
        let report = KvStability.run(&engine, &opts);
        assert_eq!(report.id, 13);
        assert_eq!(report.tables.len(), 1);
        let body = report.tables[0].render();
        assert!(body.contains("A: loose memory"), "{body}");
        assert!(body.contains("B: tight + evict-recompute"), "{body}");
        assert!(body.contains("C: tight + admission block"), "{body}");
        assert!(report.insight.contains("retry storm"));
    }
}
